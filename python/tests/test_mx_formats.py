"""Unit + property tests for the MX element codecs and block quantizer."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from compile.mx import (
    FP4_E2M1,
    FP6_E2M3,
    FP8_E4M3,
    INT4,
    MXConfig,
    fp_qdq,
    int_qdq,
    mx_qdq_ref,
)

FP4_VALUES = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


class TestFP4Codec:
    def test_representable_values_fixed(self):
        for v in FP4_VALUES:
            assert float(fp_qdq(jnp.float32(v), FP4_E2M1)) == v
            assert float(fp_qdq(jnp.float32(-v), FP4_E2M1)) == -v

    def test_saturation(self):
        assert float(fp_qdq(jnp.float32(100.0), FP4_E2M1)) == 6.0
        assert float(fp_qdq(jnp.float32(-7.0), FP4_E2M1)) == -6.0

    def test_midpoint_rounding_nearest_even(self):
        # 2.5 is midway between 2 and 3 -> ties-to-even picks 2 (mantissa 0).
        assert float(fp_qdq(jnp.float32(2.5), FP4_E2M1)) == 2.0
        # 3.5 midway between 3 and 4 -> 4.
        assert float(fp_qdq(jnp.float32(3.5), FP4_E2M1)) == 4.0

    def test_subnormal(self):
        assert float(fp_qdq(jnp.float32(0.26), FP4_E2M1)) == 0.5
        assert float(fp_qdq(jnp.float32(0.24), FP4_E2M1)) == 0.0

    @given(st.floats(-6.0, 6.0, allow_nan=False))
    def test_nearest_of_grid(self, v):
        grid = np.array([s * g for g in FP4_VALUES for s in (1, -1)])
        q = float(fp_qdq(jnp.float32(v), FP4_E2M1))
        best = np.min(np.abs(grid - v))
        assert abs(abs(q - v) - best) < 1e-6


class TestFP8Codec:
    def test_max(self):
        assert float(fp_qdq(jnp.float32(1e9), FP8_E4M3)) == 448.0

    def test_exact_small_ints(self):
        for v in range(0, 17):
            assert float(fp_qdq(jnp.float32(v), FP8_E4M3)) == float(v)

    @given(st.floats(-448, 448, allow_nan=False))
    def test_relative_error_bound(self, v):
        q = float(fp_qdq(jnp.float32(v), FP8_E4M3))
        if abs(v) >= 2 ** -6:  # normal range: rel err <= 2^-(mbits+1)
            assert abs(q - v) <= abs(v) * (2 ** -4 + 1e-7)


class TestFP6Codec:
    def test_max(self):
        assert float(fp_qdq(jnp.float32(100.0), FP6_E2M3)) == 7.5

    def test_step(self):
        # mantissa has 3 bits -> step 0.125 in [1, 2)
        assert float(fp_qdq(jnp.float32(1.06), FP6_E2M3)) == 1.0
        assert float(fp_qdq(jnp.float32(1.07), FP6_E2M3)) == 1.125


class TestINT4Codec:
    def test_range(self):
        assert float(int_qdq(jnp.float32(100.0), INT4)) == 7.0
        assert float(int_qdq(jnp.float32(-100.0), INT4)) == -8.0

    @given(st.integers(-8, 7))
    def test_integers_exact(self, k):
        assert float(int_qdq(jnp.float32(k), INT4)) == float(k)


def _blocks(x, b):
    return np.asarray(x).reshape(-1, b)


@pytest.mark.parametrize("fmt", ["mxfp4", "mxfp6", "mxfp8"])
@pytest.mark.parametrize("block", [8, 16, 32, 64])
def test_mx_qdq_idempotent_fp(fmt, block):
    """QDQ is a projection for fp element formats: the block max is itself
    representable, so a second pass reproduces the same scale and values."""
    rng = np.random.default_rng(1)
    x = jnp.asarray((rng.standard_normal((4, 256)) * 10).astype(np.float32))
    cfg = MXConfig.from_name(fmt, block)
    q = mx_qdq_ref(x, cfg)
    q2 = mx_qdq_ref(q, cfg)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))


@pytest.mark.parametrize("block", [8, 32])
def test_mx_qdq_eventually_idempotent_int4(block):
    """INT4's asymmetric code range ([-8, 7]) means a block whose new max is
    the -8 code re-derives a doubled scale on the next pass — strict
    idempotence fails by design (two's complement), but the map reaches a
    fixed point by the second application."""
    rng = np.random.default_rng(2)
    x = jnp.asarray((rng.standard_normal((8, 256)) * 10).astype(np.float32))
    cfg = MXConfig.from_name("mxint4", block)
    q2 = mx_qdq_ref(mx_qdq_ref(x, cfg), cfg)
    q3 = mx_qdq_ref(q2, cfg)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q3))


@pytest.mark.parametrize("fmt", ["mxfp4", "mxint4", "nvfp4"])
def test_mx_zero_block(fmt):
    cfg = MXConfig.from_name(fmt)
    x = jnp.zeros((2, 64), jnp.float32)
    q = mx_qdq_ref(x, cfg)
    assert not np.any(np.isnan(np.asarray(q)))
    np.testing.assert_array_equal(np.asarray(q), 0.0)


@given(
    st.integers(0, 2 ** 32 - 1),
    st.sampled_from(["mxfp4", "mxint4", "mxfp6", "mxfp8", "nvfp4"]),
    st.sampled_from([8, 16, 32]),
    st.floats(0.01, 1e4),
)
def test_mx_error_bounded_by_block_max(seed, fmt, block, scale):
    """|x - QDQ(x)| <= amax(block) / 2^emax * (element step bound)."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((2, 64)) * scale).astype(np.float32)
    cfg = MXConfig.from_name(fmt, block)
    q = np.asarray(mx_qdq_ref(jnp.asarray(x), cfg))
    err = np.abs(x - q).reshape(-1, block)
    amax = np.abs(x).reshape(-1, block).max(axis=1)
    # worst case: fp4 clipping region (values in (6,8)*s map to 6*s -> err
    # up to amax/4); nvfp4's E4M3 scale can additionally sit ~6% low,
    # compounding to just over amax/2 in adversarial blocks.
    frac = 0.51 if fmt == "nvfp4" else 0.5
    bound = amax * frac + 1e-6
    assert np.all(err.max(axis=1) <= bound)


@given(st.integers(0, 2 ** 32 - 1))
def test_mx_sign_preserved(seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((4, 64)) * 3).astype(np.float32)
    cfg = MXConfig.from_name("mxfp4")
    q = np.asarray(mx_qdq_ref(jnp.asarray(x), cfg))
    assert np.all(q * x >= 0.0)  # no sign flips (zero allowed)


def test_bits_per_element_accounting():
    assert MXConfig.from_name("mxfp4").bits_per_element == 4 + 8 / 32
    assert MXConfig.from_name("mxint4").bits_per_element == 4 + 8 / 32
    assert MXConfig.from_name("nvfp4").bits_per_element == 4 + 8 / 16
    assert MXConfig.from_name("none").bits_per_element == 32.0


def test_nvfp4_finer_than_mxfp4_on_nonpow2_blocks():
    """E4M3 scales track amax more tightly than E8M0 -> lower error on
    blocks whose max is far from a power of two."""
    rng = np.random.default_rng(7)
    x = jnp.asarray((rng.standard_normal((64, 64)) * 2.9).astype(np.float32))
    e_mx = float(jnp.mean((x - mx_qdq_ref(x, MXConfig.from_name("mxfp4", 16))) ** 2))
    e_nv = float(jnp.mean((x - mx_qdq_ref(x, MXConfig.from_name("nvfp4", 16))) ** 2))
    assert e_nv < e_mx
