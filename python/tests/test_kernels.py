"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, block sizes, formats and value scales; QDQ kernels
must be *bit-exact* against `mx_qdq_ref`, GEMM-bearing kernels allclose.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.mx import MXConfig, mx_qdq_ref
from compile.kernels import affine_qdq_pallas, block_hadamard_pallas, mx_qdq_pallas
from compile.kernels.ref import affine_qdq_ref, block_hadamard_ref, hadamard_matrix

FMTS = ["mxfp4", "mxint4", "mxfp6", "mxfp8", "nvfp4"]


@given(
    seed=st.integers(0, 2 ** 32 - 1),
    rows=st.integers(1, 40),
    nblocks=st.integers(1, 6),
    fmt=st.sampled_from(FMTS),
    block=st.sampled_from([8, 16, 32]),
    logscale=st.floats(-6, 6),
)
@settings(max_examples=40)
def test_mx_qdq_kernel_bitexact(seed, rows, nblocks, fmt, block, logscale):
    rng = np.random.default_rng(seed)
    d = nblocks * block
    x = jnp.asarray(
        (rng.standard_normal((rows, d)) * 2.0 ** logscale).astype(np.float32)
    )
    cfg = MXConfig.from_name(fmt, block)
    ref = np.asarray(mx_qdq_ref(x, cfg))
    ker = np.asarray(mx_qdq_pallas(x, cfg))
    if fmt == "nvfp4":
        # The non-power-of-two E4M3 scale path divides by a general f32;
        # XLA's reciprocal-multiply rewrite differs between the two jitted
        # programs by <= 1 ULP. E8M0 formats divide by exact powers of two
        # and must match bit-for-bit.
        np.testing.assert_allclose(ref, ker, rtol=3e-7, atol=0)
    else:
        np.testing.assert_array_equal(ref, ker)


@pytest.mark.parametrize("tile_rows", [1, 7, 32, 128])
def test_mx_qdq_kernel_tile_row_invariance(tile_rows):
    """The grid decomposition must not change results (rows are independent)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((50, 128)).astype(np.float32))
    cfg = MXConfig.from_name("mxfp4")
    base = mx_qdq_pallas(x, cfg, tile_rows=128)
    np.testing.assert_array_equal(
        np.asarray(base), np.asarray(mx_qdq_pallas(x, cfg, tile_rows=tile_rows))
    )


def test_mx_qdq_kernel_3d_shapes():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 9, 64)).astype(np.float32))
    cfg = MXConfig.from_name("mxint4")
    np.testing.assert_array_equal(
        np.asarray(mx_qdq_ref(x, cfg)), np.asarray(mx_qdq_pallas(x, cfg))
    )


class TestHadamard:
    def test_matrix_orthogonal(self):
        for n in (2, 8, 32, 128):
            h = np.asarray(hadamard_matrix(n))
            np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-6)

    @given(
        seed=st.integers(0, 2 ** 32 - 1),
        rows=st.integers(1, 16),
        nblocks=st.integers(1, 4),
        block=st.sampled_from([8, 16, 32, 64]),
    )
    def test_kernel_matches_ref(self, seed, rows, nblocks, block):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((rows, nblocks * block)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(block_hadamard_ref(x, block)),
            np.asarray(block_hadamard_pallas(x, block)),
            atol=1e-5,
        )

    def test_energy_preserved(self):
        """Orthogonality: ||Hx|| == ||x|| per row."""
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal((8, 128)).astype(np.float32))
        y = block_hadamard_ref(x, 32)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=1),
            np.linalg.norm(np.asarray(y), axis=1),
            rtol=1e-5,
        )

    def test_involution(self):
        """Normalized Sylvester H is symmetric -> applying twice = identity."""
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
        y = block_hadamard_ref(block_hadamard_ref(x, 32), 32)
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)

    def test_outlier_diffusion(self):
        """A single spike spreads to 1/sqrt(B) of its magnitude — the
        outlier-reduction mechanism rotation methods rely on."""
        x = np.zeros((1, 32), np.float32)
        x[0, 3] = 32.0
        y = np.asarray(block_hadamard_ref(jnp.asarray(x), 32))
        np.testing.assert_allclose(np.abs(y), 32.0 / np.sqrt(32), atol=1e-5)


@given(
    seed=st.integers(0, 2 ** 32 - 1),
    rows=st.integers(1, 12),
    fmt=st.sampled_from(["mxfp4", "mxint4", "none"]),
)
@settings(max_examples=20)
def test_affine_qdq_kernel_matches_ref(seed, rows, fmt):
    rng = np.random.default_rng(seed)
    d = 64
    x = jnp.asarray(rng.standard_normal((rows, d)).astype(np.float32))
    a = jnp.asarray((rng.standard_normal((d, d)) / np.sqrt(d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    cfg = MXConfig.from_name(fmt)
    ref = affine_qdq_ref(x, a, v, cfg)
    ker = affine_qdq_pallas(x, a, v, cfg)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker), atol=2e-5, rtol=1e-5)
