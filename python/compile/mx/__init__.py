"""Microscaling (MX) data-format substrate — build-time Python side.

Mirrors `rust/src/mx/` (the request-path implementation). The two are
cross-checked bit-exactly through golden files written by
`python/compile/golden.py` and read by `rust/tests/golden_mx.rs`.
"""

from .formats import (
    ElementFormat,
    FP4_E2M1,
    FP6_E2M3,
    FP8_E4M3,
    INT4,
    FORMATS,
    fp_qdq,
    int_qdq,
)
from .quantize import MXConfig, mx_qdq_ref, nvfp4_qdq_ref, quantize_tensor

__all__ = [
    "ElementFormat",
    "FP4_E2M1",
    "FP6_E2M3",
    "FP8_E4M3",
    "INT4",
    "FORMATS",
    "fp_qdq",
    "int_qdq",
    "MXConfig",
    "mx_qdq_ref",
    "nvfp4_qdq_ref",
    "quantize_tensor",
]
