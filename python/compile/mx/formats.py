"""Element codecs for microscaling formats (OCP MX spec v1.0).

Each MX block shares a power-of-two scale (E8M0); elements within the block
are stored in a narrow format. This module implements quantize-dequantize
(QDQ, "fake quantization") for the element formats used in the paper:

- FP4 E2M1  (MXFP4 elements): values ±{0, .5, 1, 1.5, 2, 3, 4, 6}
- INT4      (MXINT4 elements): two's-complement fixed point, integers [-8, 7]
- FP6 E2M3  (MXFP6 elements):  max 7.5
- FP8 E4M3  (MXFP8 elements, and NVFP4 *scales*): max 448

All math is f32 `jax.numpy`; round-to-nearest-even comes from `jnp.round`
operating on grid units, matching IEEE RNE on these tiny grids.

`emax` is the exponent of the largest representable magnitude — the `r_max`
of Eq. (1) in the paper: the shared scale is `2^(floor(log2 amax) - emax)`.
"""

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ElementFormat:
    """A narrow element format inside an MX block.

    Attributes:
        name:  canonical name used in configs and artifact manifests.
        kind:  "fp" or "int".
        ebits: exponent bits (fp only).
        mbits: mantissa bits (fp), or integer magnitude bits (int).
        emax:  exponent of the max representable value (the paper's r_max).
        maxval: largest representable magnitude.
        bits:  total storage bits per element (for footprint accounting).
    """

    name: str
    kind: str
    ebits: int
    mbits: int
    emax: int
    maxval: float
    bits: int


FP4_E2M1 = ElementFormat("fp4_e2m1", "fp", ebits=2, mbits=1, emax=2, maxval=6.0, bits=4)
FP6_E2M3 = ElementFormat("fp6_e2m3", "fp", ebits=2, mbits=3, emax=2, maxval=7.5, bits=6)
FP8_E4M3 = ElementFormat("fp8_e4m3", "fp", ebits=4, mbits=3, emax=8, maxval=448.0, bits=8)
# INT4: sign + 3 magnitude bits interpreted as fixed point with 2 fractional
# bits relative to the shared exponent; in Eq.-(1) terms r_max = 2 and the
# element quantizer is round+clamp to integers in [-8, 7] (see int_qdq).
INT4 = ElementFormat("int4", "int", ebits=0, mbits=3, emax=2, maxval=7.0, bits=4)

FORMATS = {f.name: f for f in (FP4_E2M1, FP6_E2M3, FP8_E4M3, INT4)}


def fp_qdq(v, fmt: ElementFormat):
    """Round `v` (already divided by the shared scale) to the nearest value
    representable in the floating-point element format, saturating at
    ±fmt.maxval. Handles subnormals (e.g. ±0.5 for FP4 E2M1).
    """
    assert fmt.kind == "fp"
    bias = 2 ** (fmt.ebits - 1) - 1
    emin = 1 - bias  # smallest normal exponent; subnormal step = 2^(emin-mbits)
    a = jnp.abs(v)
    sign = jnp.sign(v)
    a = jnp.minimum(a, fmt.maxval)
    # Exponent of the enclosing binade, clamped into [emin, emax]. a == 0
    # hits the emin clamp (log2(0) = -inf) and quantizes to 0 exactly.
    e = jnp.clip(jnp.floor(jnp.log2(jnp.maximum(a, 1e-38))), emin, fmt.emax)
    step = jnp.exp2(e - fmt.mbits)
    q = jnp.round(a / step) * step
    # Rounding can carry into the next binade (e.g. 5.9 -> 6.0); re-saturate.
    q = jnp.minimum(q, fmt.maxval)
    return sign * q


def int_qdq(v, fmt: ElementFormat = INT4):
    """Round `v` (already divided by the shared scale and pre-multiplied by
    2^(2) fixed-point shift folded into the scale) to an integer in
    [-(2^(mbits), 2^mbits - 1], i.e. [-8, 7] for INT4."""
    assert fmt.kind == "int"
    lo = -float(2 ** fmt.mbits)
    hi = float(2 ** fmt.mbits - 1)
    return jnp.clip(jnp.round(v), lo, hi)


def element_qdq(v, fmt: ElementFormat):
    """Dispatch QDQ in the scaled domain for any element format."""
    if fmt.kind == "fp":
        return fp_qdq(v, fmt)
    return int_qdq(v, fmt)
