"""MX quantization (Eq. (1) of the paper) — pure-jnp reference path.

The Pallas kernels in `python/compile/kernels/` must agree with these
functions bit-for-bit (asserted by `python/tests/test_kernels.py`); the Rust
substrate in `rust/src/mx/` is cross-checked through golden files.

Quantization of a block `x_I`:

    s = 2^( floor(log2 max|x_I|) - emax )      # shared E8M0 scale
    QDQ(x_j) = s * Q_e(x_j / s)                # element codec in scaled domain

Scales are clamped to the E8M0 exponent range [-127, 127]; an all-zero block
uses scale 1 (its elements QDQ to 0 regardless).
"""

from dataclasses import dataclass, field

import jax.numpy as jnp

from .formats import FORMATS, ElementFormat, FP4_E2M1, FP8_E4M3, element_qdq, fp_qdq

# E8M0 shared-scale exponent range.
SCALE_EMIN = -127
SCALE_EMAX = 127


@dataclass(frozen=True)
class MXConfig:
    """A full MX tensor-quantization configuration.

    `name` values accepted by `from_name`: "none", "mxfp4", "mxint4",
    "mxfp6", "mxfp8" (block 32 unless overridden) and "nvfp4" (block 16,
    E4M3 scales).
    """

    name: str
    element: ElementFormat = field(default=FP4_E2M1)
    block_size: int = 32
    nv: bool = False  # NVFP4: FP8-E4M3 scale instead of E8M0 power-of-two

    @staticmethod
    def from_name(name: str, block_size: int | None = None) -> "MXConfig":
        if name == "none":
            return MXConfig("none", FP4_E2M1, block_size or 32)
        if name == "nvfp4":
            return MXConfig("nvfp4", FP4_E2M1, block_size or 16, nv=True)
        table = {
            "mxfp4": "fp4_e2m1",
            "mxint4": "int4",
            "mxfp6": "fp6_e2m3",
            "mxfp8": "fp8_e4m3",
        }
        if name not in table:
            raise ValueError(f"unknown quant format {name!r}")
        return MXConfig(name, FORMATS[table[name]], block_size or 32)

    @property
    def bits_per_element(self) -> float:
        """Storage bits per element including the amortized shared scale."""
        if self.name == "none":
            return 32.0
        return self.element.bits + 8.0 / self.block_size


def _block_scales(amax, emax: int):
    """Power-of-two shared scale per block from the block abs-max (Eq. 1)."""
    e = jnp.floor(jnp.log2(jnp.maximum(amax, 1e-38))) - emax
    e = jnp.clip(e, SCALE_EMIN, SCALE_EMAX)
    scale = jnp.exp2(e)
    return jnp.where(amax > 0, scale, jnp.ones_like(scale))


def mx_qdq_ref(x, cfg: MXConfig):
    """Quantize-dequantize `x` along its last axis with MX blocks.

    Works for any leading shape; requires `x.shape[-1] % cfg.block_size == 0`.
    """
    if cfg.name == "none":
        return x
    if cfg.nv:
        return nvfp4_qdq_ref(x, cfg)
    b = cfg.block_size
    d = x.shape[-1]
    assert d % b == 0, f"last dim {d} not divisible by block size {b}"
    shape = x.shape
    xb = x.reshape(shape[:-1] + (d // b, b))
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    s = _block_scales(amax, cfg.element.emax)
    q = s * element_qdq(xb / s, cfg.element)
    return q.reshape(shape).astype(x.dtype)


def nvfp4_qdq_ref(x, cfg: MXConfig):
    """NVFP4: FP4 E2M1 elements with an FP8 E4M3 shared scale (block 16),
    plus NVIDIA's second-level per-tensor f32 scale that keeps every block's
    `amax/6` inside E4M3 range (otherwise large tensors saturate at 448).

    The E4M3 scale tracks amax more tightly than E8M0's power-of-two grid,
    which is why the paper's Table 15 spreads are smaller.
    """
    b = cfg.block_size
    d = x.shape[-1]
    assert d % b == 0
    shape = x.shape
    xb = x.reshape(shape[:-1] + (d // b, b))
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    tmax = jnp.max(jnp.abs(x))
    # per-tensor scale: map the largest block scale to the top of E4M3 range.
    ts = jnp.where(tmax > 0, tmax / (FP4_E2M1.maxval * FP8_E4M3.maxval), 1.0)
    s = fp_qdq(amax / (FP4_E2M1.maxval * ts), FP8_E4M3)
    s = jnp.where(s > 0, s, jnp.ones_like(s)) * ts
    q = s * fp_qdq(xb / s, FP4_E2M1)
    return q.reshape(shape).astype(x.dtype)


def quantize_tensor(w, cfg: MXConfig):
    """QDQ a 2-D weight matrix `w` (out, in) with blocks along the *input*
    dimension (the reduction axis of the matmul, matching activations)."""
    return mx_qdq_ref(w, cfg)
