"""Experiment sweep driver: produce every weight/transform variant that the
Rust benches evaluate (`make experiments`).

Stages (each idempotent — existing artifacts are skipped, so the sweep is
resumable and can run in the background while the Rust side builds):

  table1     methods x {MXFP4, MXINT4}                (headline, Tables 1/16+)
  table6     same variants, perplexity read by the ppl bench
  table15    NVFP4 subset
  fig2       feature-study transforms + per-block-size LATMiX/QuaRot variants
  table2     transformation x granularity ablation
  table3     FP-fused snapshots (computational invariance)
  table14    drop-one-transform variants
  ablations  init / loss / calib-size / seeds / steps / lambda / temperature
             (Tables 7-13, reduced grids: 3-5 points per axis; the paper's
             shape — saturation / robustness — is preserved, documented in
             EXPERIMENTS.md)

Scale note: budgets are sized for a 1-core CPU testbed. `--fast` shrinks
training steps further for smoke runs.
"""

import argparse
import os
import sys
import time
from dataclasses import replace

import numpy as np

from .baselines import METHODS, TABLE1_METHODS, TABLE15_METHODS, latmix_config_for
from .config import LatmixConfig, ModelConfig, QuantSpec
from .folding import fold_params, np_params
from .gptq import quantize_weights
from .latmix import learn_feature_transform, learn_transforms
from .lxt import load_lxt, save_lxt
from .mx.quantize import MXConfig
from .pipeline import ART, default_calib, load_fp_params, run_variant
from .transforms import init_matrix, random_hadamard, block_diagonal

STEPS_MAIN = 120   # Table-1 learned methods
STEPS_ABL = 60     # ablation axes


def _lcfg(steps=STEPS_MAIN, **kw):
    return replace(LatmixConfig(), steps=steps, **kw)


def stage_table1(cfg, art, fast):
    steps = 20 if fast else STEPS_MAIN
    calib = default_calib(_lcfg(steps))
    for fmt in ("mxfp4", "mxint4"):
        qspec = QuantSpec(act=fmt, weight=fmt)
        for m in TABLE1_METHODS:
            run_variant(m, qspec, cfg, _lcfg(steps), calib, art)


def stage_table15(cfg, art, fast):
    steps = 20 if fast else 80
    calib = default_calib(_lcfg(steps))
    qspec = QuantSpec(act="nvfp4", weight="nvfp4", block_size=16)
    for m in TABLE15_METHODS:
        run_variant(m, qspec, cfg, _lcfg(steps), calib, art)


def stage_table2(cfg, art, fast):
    """Transformation x granularity ablation (MXFP4 ppl)."""
    steps = 20 if fast else STEPS_ABL
    calib = default_calib(_lcfg(steps))
    qspec = QuantSpec()
    # (tag, method-name, lcfg overrides) — "none" + hadamard rows reuse
    # gptq / quarot / mr-gptq variants from table1.
    rows = [
        ("t2_orth_block", dict(param="qr", learn_matrix=False, learn_bias=False, granularity="block")),
        ("t2_orth_full", dict(param="qr", learn_matrix=False, learn_bias=False)),
        ("t2_orthbias_block", dict(param="qr", learn_matrix=False, learn_bias=True, granularity="block")),
        ("t2_orthbias_full", dict(param="qr", learn_matrix=False, learn_bias=True)),
        ("t2_inv_block", dict(param="lu", learn_bias=False, granularity="block")),
        ("t2_inv_full", dict(param="lu", learn_bias=False)),
        ("t2_latmix_block", dict(param="lu", granularity="block")),
    ]
    for tag, kw in rows:
        lcfg = _lcfg(steps, **kw)
        wpath = os.path.join(art, "weights", f"{tag}_{qspec.tag}.lxt")
        if os.path.exists(wpath):
            print(f"[exp] {tag}: cached", flush=True)
            continue
        params0 = load_fp_params(cfg, art)
        res = learn_transforms(params0, cfg, lcfg, qspec, calib, t3=32, verbose=False)
        folded = fold_params(params0, cfg, res["a1"], res["v1"], res["a2s"], res["v2s"], 32)
        q = quantize_weights(folded, cfg, qspec.weight_cfg, "gptq",
                             calib[:16], qspec.act_cfg, 32)
        save_lxt(wpath, np_params(q))
        print(f"[exp] {tag}: done", flush=True)


def stage_table3(cfg, art, fast):
    """FP model with T1/T2 fused at several training steps — NO quantization
    (computational-invariance check)."""
    steps = 20 if fast else STEPS_MAIN
    snap_steps = (0, 1, 30, 60) if not fast else (0, 1)
    done = all(
        os.path.exists(os.path.join(art, "weights", f"fp_fused_step{s}.lxt"))
        for s in list(snap_steps) + [steps]
    )
    if done:
        print("[exp] table3: cached", flush=True)
        return
    calib = default_calib(_lcfg(steps))
    params0 = load_fp_params(cfg, art)
    res = learn_transforms(
        params0, cfg, _lcfg(steps), QuantSpec(), calib, t3=32,
        snapshot_steps=snap_steps, verbose=False,
    )
    res["snapshots"][steps] = (res["a1"], res["v1"], res["a2s"], res["v2s"])
    for s, (a1, v1, a2s, v2s) in res["snapshots"].items():
        # Fold only T1/T2 (the learned transforms). T3 is an *online* op:
        # folding its inverse into wd is only valid when the serving graph
        # applies the Hadamard — the FP graph used for this table does not.
        folded = fold_params(params0, cfg, a1, v1, a2s, v2s, t3=None)
        save_lxt(os.path.join(art, "weights", f"fp_fused_step{s}.lxt"), np_params(folded))
    print("[exp] table3: done", flush=True)


def stage_table14(cfg, art, fast):
    """Drop-one-transform: reuse the Table-1 latmix-lu transforms, re-fold
    with one of T1/T2/T3 removed, re-GPTQ."""
    tpath = os.path.join(art, "transforms", "latmix-lu_mxfp4_b32.lxt")
    if not os.path.exists(tpath):
        print("[exp] table14: missing latmix-lu transforms, skipped", flush=True)
        return
    t = load_lxt(tpath)
    a2s = [t[f"a2.{i}"] for i in range(cfg.n_layers)]
    v2s = [t[f"v2.{i}"] for i in range(cfg.n_layers)]
    qspec = QuantSpec()
    calib = default_calib(_lcfg())
    variants = {
        "not3": dict(a1=t["a1"], v1=t["v1"], a2s=a2s, v2s=v2s, t3=None),
        "not1": dict(a1=None, v1=None, a2s=a2s, v2s=v2s, t3=32),
        "not2": dict(a1=t["a1"], v1=t["v1"], a2s=None, v2s=None, t3=32),
    }
    for tag, kw in variants.items():
        wpath = os.path.join(art, "weights", f"t14_{tag}_{qspec.tag}.lxt")
        if os.path.exists(wpath):
            print(f"[exp] t14_{tag}: cached", flush=True)
            continue
        params0 = load_fp_params(cfg, art)
        folded = fold_params(params0, cfg, kw["a1"], kw["v1"], kw["a2s"], kw["v2s"], kw["t3"])
        q = quantize_weights(folded, cfg, qspec.weight_cfg, "gptq",
                             calib[:16], qspec.act_cfg, kw["t3"])
        save_lxt(wpath, np_params(q))
        print(f"[exp] t14_{tag}: done", flush=True)


def stage_fig2(cfg, art, fast):
    """Fig. 2 feature study: learn rotation + affine transforms minimizing
    E(T) on captured features; save them for the Rust fig2 benches. Also
    per-block-size LATMiX/QuaRot weight variants for Fig. 2b."""
    fpath = os.path.join(art, "features", "resid_calib.lxt")
    if not os.path.exists(fpath):
        from .aot import emit_features
        emit_features(cfg, art)
    feats = load_lxt(fpath)["features"][:1024]
    tdir = os.path.join(art, "transforms")
    os.makedirs(tdir, exist_ok=True)
    steps = 40 if fast else 400
    for b in (8, 16, 32, 64, 128):
        out = os.path.join(tdir, f"fig2_learned_b{b}.lxt")
        if os.path.exists(out):
            continue
        mx = MXConfig.from_name("mxfp4", b)
        a_rot, v_rot, m_rot = learn_feature_transform(
            feats, mx, kind="qr", steps=steps, lr=3e-3, learn_matrix=False,
            learn_bias=False, init="orthogonal", lam=0.0,
        )
        a_aff, v_aff, m_aff = learn_feature_transform(
            feats, mx, kind="lu", steps=steps, lr=3e-3, lam=0.01,
            init="bd_hadamard_noise",
        )
        save_lxt(out, {
            "rot_a": a_rot, "rot_v": v_rot, "aff_a": a_aff, "aff_v": v_aff,
        })
        print(f"[exp] fig2 b={b}: E_rot={m_rot:.5f} E_aff={m_aff:.5f}", flush=True)
    # Fig. 2b: ppl-vs-block-size weight variants
    steps2 = 20 if fast else STEPS_ABL
    calib = default_calib(_lcfg(steps2))
    for b in (8, 16, 64):
        qspec = QuantSpec(act="mxfp4", weight="mxfp4", block_size=b)
        run_variant("latmix-lu", qspec, cfg, _lcfg(steps2), calib, art)
        run_variant("quarot", qspec, cfg, _lcfg(steps2), calib, art)
        run_variant("mr-gptq", qspec, cfg, _lcfg(steps2), calib, art)
        run_variant("gptq", qspec, cfg, _lcfg(steps2), calib, art)


def stage_ablations(cfg, art, fast):
    """Tables 7-13 (reduced grids)."""
    steps = 20 if fast else STEPS_ABL
    qspec = QuantSpec()
    base = _lcfg(steps)
    calib = default_calib(base)

    def custom(tag, lcfg, weight_quant="gptq", calib_override=None):
        wpath = os.path.join(art, "weights", f"{tag}_{qspec.tag}.lxt")
        if os.path.exists(wpath):
            print(f"[exp] {tag}: cached", flush=True)
            return
        c = calib_override if calib_override is not None else calib
        params0 = load_fp_params(cfg, art)
        res = learn_transforms(params0, cfg, lcfg, qspec, c, t3=32, verbose=False)
        folded = fold_params(params0, cfg, res["a1"], res["v1"], res["a2s"], res["v2s"], 32)
        q = quantize_weights(folded, cfg, qspec.weight_cfg, weight_quant,
                             c[:16], qspec.act_cfg, 32)
        save_lxt(wpath, np_params(q))
        print(f"[exp] {tag}: done", flush=True)

    # Table 7: initialization (both LU and QR on the interesting subset)
    for init in ("identity", "orthogonal", "bd_orthogonal_noise", "hadamard",
                 "bd_hadamard", "bd_hadamard_noise"):
        custom(f"t7_lu_{init}", replace(base, init=init, param="lu"))
    for init in ("identity", "bd_orthogonal_noise", "bd_hadamard_noise"):
        custom(f"t7_qr_{init}", replace(base, init=init, param="qr"))
    # Table 8: loss ablation (kl == latmix-lu main run)
    custom("t8_mse", replace(base, loss="mse"))
    custom("t8_ce", replace(base, loss="ce"))
    # Table 9: calibration set size
    for n in (1, 4, 16, 64):
        c = default_calib(replace(base, calib_samples=max(n, 1)))[:max(n, 1)]
        custom(f"t9_n{n}", replace(base, calib_samples=n), calib_override=c)
    # Table 10: calibration subset seeds
    for seed in (1, 2, 3):
        c = default_calib(base, seed=100 + seed)
        custom(f"t10_seed{seed}", replace(base, seed=seed), calib_override=c)
    # Table 11: training steps via snapshots of one longer run
    t11_steps = (0, 15, 30, 60, 120)
    missing = [s for s in t11_steps
               if not os.path.exists(os.path.join(art, "weights", f"t11_s{s}_{qspec.tag}.lxt"))]
    if missing:
        params0 = load_fp_params(cfg, art)
        lcfg11 = replace(base, steps=120)
        res = learn_transforms(params0, cfg, lcfg11, qspec, calib, t3=32,
                               snapshot_steps=t11_steps, verbose=False)
        for s, (a1, v1, a2s, v2s) in res["snapshots"].items():
            folded = fold_params(params0, cfg, a1, v1, a2s, v2s, 32)
            q = quantize_weights(folded, cfg, qspec.weight_cfg, "gptq",
                                 calib[:16], qspec.act_cfg, 32)
            save_lxt(os.path.join(art, "weights", f"t11_s{s}_{qspec.tag}.lxt"), np_params(q))
        print("[exp] table11: done", flush=True)
    # Table 12: lambda sweep
    for lam in (0.001, 0.1, 1.0, 10.0):
        custom(f"t12_lam{lam}", replace(base, lam=lam))
    # Table 13: temperature sweep
    for temp in (0.1, 0.75, 1.5, 5.0):
        custom(f"t13_T{temp}", replace(base, temperature=temp))


STAGES = {
    "table1": stage_table1,
    "table15": stage_table15,
    "table2": stage_table2,
    "table3": stage_table3,
    "fig2": stage_fig2,
    "table14": stage_table14,
    "ablations": stage_ablations,
}
# table14 depends on table1's latmix-lu transforms -> keep order.
ORDER = ["table1", "fig2", "table2", "table3", "table14", "table15", "ablations"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", default=",".join(ORDER))
    ap.add_argument("--out", default=ART)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    cfg = ModelConfig()
    t0 = time.time()
    for s in args.stages.split(","):
        print(f"=== stage {s} ({time.time()-t0:.0f}s) ===", flush=True)
        STAGES[s](cfg, args.out, args.fast)
    print(f"=== all stages done ({time.time()-t0:.0f}s) ===", flush=True)


if __name__ == "__main__":
    main()
