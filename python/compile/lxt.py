"""`.lxt` — the LATMiX tensor container (python writer/reader).

A deliberately tiny binary format shared with `rust/src/io/lxt.rs` (offline
environment: no safetensors/serde). Layout, all little-endian:

    magic   b"LXT1"
    u32     n_tensors
    per tensor:
      u16   name_len, name bytes (utf-8)
      u8    dtype (0 = f32, 1 = i32)
      u8    ndim
      u32 * ndim   dims
      raw   data (dtype * prod(dims) bytes)

Both sides must round-trip bit-exactly; `rust/tests/golden_mx.rs` depends
on it for the cross-language golden checks.
"""

import struct

import numpy as np

MAGIC = b"LXT1"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_INV = {0: np.float32, 1: np.int32}


def save_lxt(path: str, tensors: dict):
    """Write `{name: ndarray}` to `path`. Arrays are converted to f32/i32."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            a = np.asarray(arr)
            if a.dtype not in DTYPES:
                a = a.astype(np.int32 if np.issubdtype(a.dtype, np.integer) else np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[a.dtype], a.ndim))
            for dim in a.shape:
                f.write(struct.pack("<I", dim))
            f.write(np.ascontiguousarray(a).tobytes())


def load_lxt(path: str) -> dict:
    """Read an `.lxt` file back into `{name: ndarray}`."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path}: bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = np.dtype(DTYPES_INV[dt])
            count = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(count * dtype.itemsize), dtype=dtype)
            out[name] = data.reshape(dims).copy()
    return out
