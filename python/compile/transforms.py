"""Invertible affine transformations: parameterizations, initialization, and
materialization (Sec. 3.2, Eqs. 5-7), plus the baselines' restricted families.

Parameterizations (all exposing `(spec, params)` where `spec` is static
metadata and `params` a pytree of arrays — jit-friendly):

- **lu** (Eq. 5, Glow-style):  `A = P L (U + diag(s))`, `P` a fixed
  permutation, `L` unit lower-triangular, `U` strictly upper, `s` learned as
  `log|s|` with signs frozen at init (the paper's stabilized variant).
- **qr** (Eq. 6):  `A = Q0 expm(skew(G)) (R + diag(s))` — the learned
  orthogonal factor is *composed with* the initial `Q0` so `G = 0` reproduces
  the init exactly (initializing the paper's `Q = expm(skew(G))` at an
  arbitrary rotation would need a matrix logarithm).
  Restrictions of qr give the baselines: `learn_matrix=False` → SpinQuant-style
  pure rotations; `learn_upper=False` → OSTQuant-style `Q diag(s)`.
- **kron**: `A = kron(Aa, Ab)` — FlatQuant's matrix structure (Sun et al.).
- **blockdiag**: independent sub-transforms per MX block — the BRQ /
  MR-GPTQ granularity (Table 2 "Block" rows).
- **fixed**: a frozen matrix (random Hadamard / rotation baselines).

Initialization strategies (Table 7): identity / full or block-diagonal
orthogonal / full or block-diagonal Hadamard, each optionally `_noise`.
"""

from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from .kernels.ref import hadamard_matrix


# ---------------------------------------------------------------------------
# Initial matrices


def random_orthogonal(d: int, rng) -> np.ndarray:
    """Haar-ish random rotation via QR of a Gaussian matrix."""
    m = rng.standard_normal((d, d))
    q, r = np.linalg.qr(m)
    return (q * np.sign(np.diag(r))).astype(np.float32)


def random_hadamard(d: int, rng) -> np.ndarray:
    """Randomized Hadamard: H diag(+-1) — orthogonal, magnitude-spreading."""
    h = np.asarray(hadamard_matrix(d))
    signs = rng.integers(0, 2, size=d) * 2.0 - 1.0
    return (h * signs[None, :]).astype(np.float32)


def block_diagonal(blocks: list) -> np.ndarray:
    d = sum(b.shape[0] for b in blocks)
    out = np.zeros((d, d), dtype=np.float32)
    o = 0
    for b in blocks:
        k = b.shape[0]
        out[o : o + k, o : o + k] = b
        o += k
    return out


def init_matrix(d: int, strategy: str, rng, block: int = 32) -> np.ndarray:
    """Build the initial `A0` for a given Table-7 strategy."""
    noise = 0.0
    base = strategy
    if strategy.endswith("_noise"):
        noise = 1e-3
        base = strategy[: -len("_noise")]
    if base == "identity":
        a = np.eye(d, dtype=np.float32)
    elif base == "orthogonal":
        a = random_orthogonal(d, rng)
    elif base == "bd_orthogonal":
        a = block_diagonal([random_orthogonal(block, rng) for _ in range(d // block)])
    elif base == "hadamard":
        a = random_hadamard(d, rng)
    elif base == "bd_hadamard":
        a = block_diagonal([random_hadamard(block, rng) for _ in range(d // block)])
    else:
        raise ValueError(f"unknown init strategy {strategy!r}")
    if noise > 0:
        mask = a == 0.0
        a = a + (rng.standard_normal((d, d)) * noise).astype(np.float32) * mask
    return a


# ---------------------------------------------------------------------------
# Spec + params


@dataclass(frozen=True)
class TSpec:
    """Static description of one transform parameterization (hashable, safe
    to close over in jitted functions; arrays live in the params pytree)."""

    kind: str                 # lu | qr | kron | blockdiag | fixed
    dim: int
    learn_bias: bool = True
    learn_matrix: bool = True  # qr: False -> rotation-only (SpinQuant-like)
    learn_upper: bool = True   # qr: False -> Q diag(s) (OSTQuant-like)
    block: int = 0             # blockdiag sub-size
    sub_kind: str = "lu"       # blockdiag: inner parameterization


def make_param(a0: np.ndarray, kind: str, **kw):
    """Build `(spec, params)` initialized so materialize(spec, params) == (A0, 0)."""
    d = a0.shape[0]
    if kind == "lu":
        spec = TSpec("lu", d, learn_bias=kw.get("learn_bias", True))
        p, l, u = jax.scipy.linalg.lu(jnp.asarray(a0))
        s = jnp.diag(u)
        params = {
            "perm": p,
            "lower": jnp.tril(l, -1),
            "upper": jnp.triu(u, 1),
            "log_s": jnp.log(jnp.abs(s) + 1e-12),
            "sign_s": jnp.sign(jnp.where(s == 0, 1.0, s)),
            "v": jnp.zeros(d, jnp.float32),
        }
        return spec, params
    if kind == "qr":
        spec = TSpec(
            "qr",
            d,
            learn_bias=kw.get("learn_bias", True),
            learn_matrix=kw.get("learn_matrix", True),
            learn_upper=kw.get("learn_upper", True),
        )
        q0, r0 = jnp.linalg.qr(jnp.asarray(a0))
        sgn = jnp.sign(jnp.where(jnp.diag(r0) == 0, 1.0, jnp.diag(r0)))
        q0 = q0 * sgn[None, :]
        r0 = r0 * sgn[:, None]
        s = jnp.diag(r0)
        params = {
            "q0": q0,
            "g": jnp.zeros((d, d), jnp.float32),
            "upper": jnp.triu(r0, 1),
            "log_s": jnp.log(jnp.abs(s) + 1e-12),
            "sign_s": jnp.sign(jnp.where(s == 0, 1.0, s)),
            "v": jnp.zeros(d, jnp.float32),
        }
        return spec, params
    if kind == "kron":
        # factor d = da * db with da the largest power of two <= sqrt-ish
        da = kw.get("da") or _kron_factor(d)
        db = d // da
        spec = TSpec("kron", d, learn_bias=kw.get("learn_bias", True))
        rng = np.random.default_rng(kw.get("seed", 0))
        params = {
            "a": jnp.asarray(random_hadamard(da, rng)),
            "b": jnp.asarray(random_orthogonal(db, rng)),
            "v": jnp.zeros(d, jnp.float32),
        }
        return spec, params
    if kind == "blockdiag":
        b = kw.get("block", 32)
        nb = d // b
        sub_kind = kw.get("sub_kind", "lu")
        spec = TSpec(
            "blockdiag",
            d,
            learn_bias=kw.get("learn_bias", True),
            learn_matrix=kw.get("learn_matrix", True),
            learn_upper=kw.get("learn_upper", True),
            block=b,
            sub_kind=sub_kind,
        )
        subs = []
        for i in range(nb):
            _, sp = make_param(
                np.asarray(a0[i * b : (i + 1) * b, i * b : (i + 1) * b]),
                sub_kind,
                learn_bias=kw.get("learn_bias", True),
                learn_matrix=kw.get("learn_matrix", True),
                learn_upper=kw.get("learn_upper", True),
            )
            subs.append(sp)
        stacked = {
            k: jnp.stack([s[k] for s in subs]) for k in subs[0] if k != "v"
        }
        stacked["v"] = jnp.zeros(d, jnp.float32)
        return spec, stacked
    if kind == "fixed":
        spec = TSpec("fixed", d, learn_bias=False, learn_matrix=False)
        return spec, {"a": jnp.asarray(a0), "v": jnp.zeros(d, jnp.float32)}
    raise ValueError(kind)


def _kron_factor(d: int) -> int:
    """Largest power-of-two factor of d not exceeding sqrt(d)*2 (FlatQuant
    uses two lightweight near-square factors)."""
    best = 1
    k = 1
    while k <= d:
        if d % k == 0 and k * k <= d * 2:
            best = k
        k *= 2
    return best


def _lu_mat(spec: TSpec, p: dict):
    d = spec.dim if spec.kind == "lu" else spec.block
    l = jnp.tril(p["lower"], -1) + jnp.eye(d)
    s = p["sign_s"] * jnp.exp(p["log_s"])
    u = jnp.triu(p["upper"], 1) + jnp.diag(s)
    return p["perm"] @ l @ u


def _qr_mat(spec: TSpec, p: dict):
    d = p["g"].shape[-1]
    g = p["g"]
    q = p["q0"] @ jsl.expm(0.5 * (g - g.T))
    log_s = p["log_s"] if spec.learn_matrix else jax.lax.stop_gradient(p["log_s"])
    upper = p["upper"]
    if not (spec.learn_matrix and spec.learn_upper):
        upper = jax.lax.stop_gradient(upper)
    s = p["sign_s"] * jnp.exp(log_s)
    r = jnp.triu(upper, 1) + jnp.diag(s)
    return q @ r


def materialize(spec: TSpec, params: dict):
    """Return `(A, v)`; differentiable in `params`."""
    v = params["v"] if spec.learn_bias else jax.lax.stop_gradient(params["v"])
    if spec.kind == "lu":
        return _lu_mat(spec, params), v
    if spec.kind == "qr":
        return _qr_mat(spec, params), v
    if spec.kind == "kron":
        return jnp.kron(params["a"], params["b"]), v
    if spec.kind == "fixed":
        return params["a"], v
    if spec.kind == "blockdiag":
        sub_spec = TSpec(
            spec.sub_kind,
            spec.block,
            learn_bias=spec.learn_bias,
            learn_matrix=spec.learn_matrix,
            learn_upper=spec.learn_upper,
        )
        subp = {k: val for k, val in params.items() if k != "v"}
        fn = _lu_mat if spec.sub_kind == "lu" else _qr_mat
        mats = jax.vmap(lambda q: fn(sub_spec, q))(subp)
        nb = spec.dim // spec.block
        a = jsl.block_diag(*[mats[i] for i in range(nb)])
        return a, v
    raise ValueError(spec.kind)


# Which params receive gradients, per kind.
_TRAINABLE = {
    "lu": {"lower", "upper", "log_s", "v"},
    "qr": {"g", "upper", "log_s", "v"},
    "kron": {"a", "b", "v"},
    "blockdiag": None,  # resolved from sub_kind
    "fixed": set(),
}


def trainable_keys(spec: TSpec) -> set:
    keys = _TRAINABLE[spec.kind if spec.kind != "blockdiag" else spec.sub_kind]
    keys = set(keys)
    if not spec.learn_bias:
        keys.discard("v")
    if spec.kind == "qr" or (spec.kind == "blockdiag" and spec.sub_kind == "qr"):
        if not spec.learn_matrix:
            keys -= {"upper", "log_s"}
        elif not spec.learn_upper:
            keys.discard("upper")
    return keys


def split_params(spec: TSpec, params: dict):
    """Partition into (trainable, frozen) dicts."""
    keys = trainable_keys(spec)
    train = {k: v for k, v in params.items() if k in keys}
    frozen = {k: v for k, v in params.items() if k not in keys}
    return train, frozen


# ---------------------------------------------------------------------------
# Regularizer + diagnostics


def vol_regularizer(spec: TSpec, params: dict):
    """Log-domain volume regularizer (Eq. 7, practical form):
    `(sum_i log|s_i|)^2` — shares minima with `(prod|s_i| - 1)^2`."""
    if "log_s" not in params:
        return jnp.float32(0.0)
    return jnp.sum(params["log_s"]) ** 2


def orthogonality_deviation(a) -> float:
    """Fig. 3a metric: spectral distance of `A` from the orthogonal group."""
    d = a.shape[0]
    return float(jnp.linalg.norm(a.T @ a - jnp.eye(d), ord=2))


def off_block_diagonal_norm(a, block: int = 32) -> float:
    """Fig. 3b metric: spectral norm of `A` with its block-diagonal zeroed."""
    d = a.shape[0]
    mask = np.ones((d, d), dtype=np.float32)
    for o in range(0, d, block):
        mask[o : o + block, o : o + block] = 0.0
    return float(jnp.linalg.norm(a * mask, ord=2))


def condition_number(a) -> float:
    """Fig. 6 metric."""
    s = jnp.linalg.svd(a, compute_uv=False)
    return float(s[0] / s[-1])
