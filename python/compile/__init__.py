"""LATMiX build-time package: L1 Pallas kernels, L2 JAX model + PTQ pipeline,
and the AOT lowering that produces the artifacts the Rust coordinator serves.

Python in this tree runs ONCE (`make artifacts`); it is never imported on the
request path.
"""
