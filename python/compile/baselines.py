"""Method registry: every quantization method of Tables 1/6 (+ NVFP Table 15)
expressed as (transform recipe, weight-quant scheme, online-T3 flag).

All methods run through the *same* pipeline (`pipeline.quantize_model`) and
the same folded-graph forward — the paper's "same experimental setup"
fairness requirement (Sec. 5.1, App. D.2).

| method       | T1                          | T2 (per head)      | weights | T3 |
|--------------|-----------------------------|--------------------|---------|----|
| rtn          | —                           | —                  | RTN     | no |
| gptq         | —                           | —                  | GPTQ    | no |
| quarot-rtn   | random Hadamard (full)      | random Hadamard    | RTN     | 32 |
| quarot       | random Hadamard (full)      | random Hadamard    | GPTQ    | 32 |
| spinquant    | learned rotation (CE loss)  | learned rotation   | GPTQ    | 32 |
| ostquant     | learned Q·diag(s) (KL)      | learned Q·diag(s)  | GPTQ    | 32 |
| flatquant    | learned kron(Aa,Ab) (KL)    | learned affine     | GPTQ    | 32 |
| mr-gptq      | block-diag Hadamard         | random Hadamard    | GPTQ    | 32 |
| brq          | learned block-diag rotation | learned rotation   | GPTQ    | 32 |
| latmix-lu    | learned affine (LU, KL+vol) | learned affine     | GPTQ    | 32 |
| latmix-qr    | learned affine (QR, KL+vol) | learned affine     | GPTQ    | 32 |

Learned baselines reuse `latmix.learn_transforms` with the restricted
parameter family + their native loss, exactly the paper's re-implementation
strategy ("execute all methods under the same experimental setup").
"""

from dataclasses import dataclass, replace

import numpy as np

from .config import LatmixConfig, ModelConfig
from .transforms import block_diagonal, random_hadamard


@dataclass(frozen=True)
class MethodSpec:
    name: str
    transform: str          # none | fixed_hadamard | fixed_bd_hadamard | learned
    weight_quant: str       # rtn | gptq
    t3: int | None = 32
    # learned-transform knobs (map onto LatmixConfig):
    param: str = "lu"       # lu | qr | kron
    loss: str = "kl"
    learn_bias: bool = True
    learn_matrix: bool = True
    learn_upper: bool = True
    granularity: str = "full"
    lam: float = 0.1


METHODS = {
    "fp16": MethodSpec("fp16", "none", "none", t3=None),
    "rtn": MethodSpec("rtn", "none", "rtn", t3=None),
    "gptq": MethodSpec("gptq", "none", "gptq", t3=None),
    "quarot-rtn": MethodSpec("quarot-rtn", "fixed_hadamard", "rtn"),
    "quarot": MethodSpec("quarot", "fixed_hadamard", "gptq"),
    "spinquant": MethodSpec(
        "spinquant", "learned", "gptq",
        param="qr", loss="ce", learn_bias=False, learn_matrix=False, lam=0.0,
    ),
    "ostquant": MethodSpec(
        "ostquant", "learned", "gptq",
        param="qr", loss="kl", learn_bias=False, learn_upper=False,
    ),
    "flatquant": MethodSpec(
        "flatquant", "learned", "gptq", param="kron", loss="kl", learn_bias=False,
    ),
    "mr-gptq": MethodSpec("mr-gptq", "fixed_bd_hadamard", "gptq"),
    "brq": MethodSpec(
        "brq", "learned", "gptq",
        param="qr", loss="kl", learn_bias=False, learn_matrix=False,
        granularity="block",
    ),
    "latmix-lu": MethodSpec("latmix-lu", "learned", "gptq", param="lu"),
    "latmix-qr": MethodSpec("latmix-qr", "learned", "gptq", param="qr"),
    # RTN-weight variants of LATMiX used by ablations
    "latmix-lu-rtn": MethodSpec("latmix-lu-rtn", "learned", "rtn", param="lu"),
}

# Ordered as in Table 1.
TABLE1_METHODS = [
    "rtn", "quarot-rtn", "gptq", "quarot", "spinquant", "ostquant",
    "flatquant", "mr-gptq", "latmix-lu", "latmix-qr",
]

TABLE15_METHODS = [
    "rtn", "gptq", "spinquant", "flatquant", "mr-gptq", "latmix-lu", "latmix-qr",
]


def fixed_transforms(method: MethodSpec, cfg: ModelConfig, seed: int = 0):
    """Materialize the non-learned transform families."""
    rng = np.random.default_rng(seed)
    d, dh = cfg.d_model, cfg.head_dim
    if method.transform == "fixed_hadamard":
        a1 = random_hadamard(d, rng)
    elif method.transform == "fixed_bd_hadamard":
        a1 = block_diagonal([random_hadamard(32, rng) for _ in range(d // 32)])
    else:
        raise ValueError(method.transform)
    a2s = [random_hadamard(dh, rng) for _ in range(cfg.n_layers)]
    return a1, np.zeros(d, np.float32), a2s, [np.zeros(dh, np.float32)] * cfg.n_layers


def latmix_config_for(method: MethodSpec, base: LatmixConfig) -> LatmixConfig:
    """Map a learned method onto its LatmixConfig."""
    return replace(
        base,
        param=method.param if method.param in ("lu", "qr") else "kron",
        loss=method.loss,
        learn_bias=method.learn_bias,
        learn_matrix=method.learn_matrix,
        granularity=method.granularity,
        lam=method.lam,
    )
