"""Transformation folding (App. B + C): rewrite the weight pytree so the
transformed-and-quantized model has *zero* runtime overhead beyond the online
T3 block-Hadamard.

Row-vector conventions (`y = x @ W + b`):

T1 (global, residual stream; `x' = x @ A1 + v1`):
  - embedding rows:            Ẽ   = E @ A1 + v1
  - block inputs (q/k/v/g/u):  W̃   = A1⁻¹ @ W,     b̃ = b − v1 @ A1⁻¹ @ W
  - block outputs (o/d):       W̃   = W @ A1,        b̃ = b @ A1      (Ã1 only —
    v1 enters the stream once, at the embedding; App. C.1)
  - lm head:                   like a block input.

T2 (per layer, per head, `dh×dh`; values `o' = o @ A2 + v2` per head):
  - value proj  (d, H, dh):    W̃ᵥ[:,h,:] = Wᵥ[:,h,:] @ A2,  b̃ᵥ[h] = bᵥ[h] @ A2 + v2
  - out proj    (H, dh, d):    W̃ₒ[h]     = A2⁻¹ @ Wₒ[h],
                               b̃ₒ        = bₒ − Σ_h v2 @ A2⁻¹ @ Wₒ[h]
  The v2 term cancels through attention because softmax rows sum to 1
  (P @ V2 = V2, App. B Eq. 29).

T3 (online block-Hadamard H before down-proj): W̃_d = H_bdᵀ @ W_d, so
`(x @ H_bd) @ W̃_d = x @ W_d`.

All folds are pure jnp — *differentiable* — so LATMiX training folds the
candidate transforms on the fly and backpropagates through the fold
(`latmix.py`), guaranteeing the trained objective is exactly the deployed
model.
"""

import numpy as np
import jax.numpy as jnp

from .config import ModelConfig
from .kernels.ref import hadamard_matrix


def fold_norm_scales(params: dict) -> dict:
    """Fold RMSNorm γ into the following linear layers (QuaRot step 0);
    norms become pure normalizations (γ = 1). Exact."""
    out = {"embed": params["embed"], "layers": [], "bhead": params["bhead"]}
    for lp in params["layers"]:
        g1 = lp["ln1"][:, None]
        g2 = lp["ln2"][:, None]
        nl = dict(lp)
        nl["wq"] = g1 * lp["wq"]
        nl["wk"] = g1 * lp["wk"]
        nl["wv"] = g1 * lp["wv"]
        nl["wg"] = g2 * lp["wg"]
        nl["wu"] = g2 * lp["wu"]
        nl["ln1"] = jnp.ones_like(lp["ln1"])
        nl["ln2"] = jnp.ones_like(lp["ln2"])
        out["layers"].append(nl)
    out["lnf"] = jnp.ones_like(params["lnf"])
    out["head"] = params["lnf"][:, None] * params["head"]
    return out


def _fold_in(w, b, a_inv, v):
    """Input-side fold: layer now consumes transformed activations."""
    wn = a_inv @ w
    bn = b - v @ wn
    return wn, bn


def fold_params(
    params: dict,
    cfg: ModelConfig,
    a1=None,
    v1=None,
    a2s=None,
    v2s=None,
    t3: int | None = None,
) -> dict:
    """Return the folded weight pytree. Any transform may be None (skipped).

    `a2s`/`v2s` are per-layer lists of (dh, dh) matrices / (dh,) vectors.
    Expects γ-folded params (`fold_norm_scales`) — asserted loosely.
    """
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    out = {"lnf": params["lnf"], "bhead": params["bhead"], "layers": []}
    if a1 is not None:
        a1 = jnp.asarray(a1)
        v1 = jnp.zeros(d, jnp.float32) if v1 is None else jnp.asarray(v1)
        a1_inv = jnp.linalg.inv(a1)
        out["embed"] = params["embed"] @ a1 + v1
        out["head"], out["bhead"] = _fold_in(params["head"], params["bhead"], a1_inv, v1)
    else:
        out["embed"] = params["embed"]
        out["head"] = params["head"]

    for li, lp in enumerate(params["layers"]):
        nl = dict(lp)
        if a1 is not None:
            for wk_, bk_ in (("wq", "bq"), ("wk", "bk"), ("wv", "bv"), ("wg", "bg"), ("wu", "bu")):
                nl[wk_], nl[bk_] = _fold_in(nl[wk_], nl[bk_], a1_inv, v1)
            nl["wo"] = nl["wo"] @ a1
            nl["bo"] = nl["bo"] @ a1
            nl["wd"] = nl["wd"] @ a1
            nl["bd"] = nl["bd"] @ a1
        if a2s is not None and a2s[li] is not None:
            a2 = jnp.asarray(a2s[li])
            v2 = (
                jnp.zeros(dh, jnp.float32)
                if v2s is None or v2s[li] is None
                else jnp.asarray(v2s[li])
            )
            a2_inv = jnp.linalg.inv(a2)
            wv = nl["wv"].reshape(d, h, dh)
            nl["wv"] = jnp.einsum("dhi,ij->dhj", wv, a2).reshape(d, d)
            nl["bv"] = (nl["bv"].reshape(h, dh) @ a2 + v2).reshape(d)
            wo = nl["wo"].reshape(h, dh, d)
            wo_t = jnp.einsum("ij,hjd->hid", a2_inv, wo)
            nl["bo"] = nl["bo"] - jnp.einsum("i,hid->d", v2, wo_t)
            nl["wo"] = wo_t.reshape(d, d)
        if t3:
            hm = hadamard_matrix(t3)
            f = nl["wd"].shape[0]
            wd = nl["wd"].reshape(f // t3, t3, d)
            nl["wd"] = jnp.einsum("ij,njd->nid", hm.T, wd).reshape(f, d)
        out["layers"].append(nl)
    return out


def np_params(params) -> dict:
    """Flatten the pytree to `{flat_name: np.ndarray}` for `.lxt` export."""
    flat = {"embed": params["embed"], "lnf": params["lnf"], "head": params["head"], "bhead": params["bhead"]}
    for i, lp in enumerate(params["layers"]):
        for k, v in lp.items():
            flat[f"layers.{i}.{k}"] = v
    return {k: np.asarray(v) for k, v in flat.items()}


def from_np_params(flat: dict, cfg: ModelConfig) -> dict:
    """Inverse of `np_params`."""
    layers = []
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        layers.append(
            {k[len(pre):]: jnp.asarray(v) for k, v in flat.items() if k.startswith(pre)}
        )
    return {
        "embed": jnp.asarray(flat["embed"]),
        "layers": layers,
        "lnf": jnp.asarray(flat["lnf"]),
        "head": jnp.asarray(flat["head"]),
        "bhead": jnp.asarray(flat["bhead"]),
    }
