"""latmix-tiny: a pre-RMSNorm Llama-style transformer in JAX (Layer 2).

Conventions
-----------
- Row-vector activations: `y = x @ W + b`, `W: (in, out)`. All linear layers
  carry biases (zero at init) because folding affine transforms introduces
  bias terms (App. C).
- Activation fake-quantization (`qdq`) is applied at every *linear input*
  inside transformer blocks — q/k/v, attention out-proj, gate/up, down —
  matching the QuaRot/MR-GPTQ setup the paper builds on. Attention internals
  (RoPE, softmax) and the lm head stay full precision.
- The online T3 block-Hadamard (when enabled) hits the down-proj input; its
  inverse is pre-folded into `wd` by the pipeline.
- Transform learning never touches this file: `folding.fold_params` rewrites
  the weight pytree (differentiably during LATMiX training), so one forward
  implementation serves the float teacher, the student, and the AOT graphs.

Three entry points, all jit/AOT friendly:
- `forward_seq`   — full-sequence logits (training, perplexity, 0-shot).
- `forward_prefill` — logits for the last position + the KV cache.
- `forward_decode`  — one token per active slot with per-slot positions
  (continuous batching: each batch lane is an independent sequence).
"""

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .config import ModelConfig
from .mx.quantize import MXConfig, mx_qdq_ref
from .kernels import block_hadamard_pallas, mx_qdq_pallas
from .kernels.ref import block_hadamard_ref

EPS = 1e-5


# ---------------------------------------------------------------------------
# Parameters


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Initialize the weight pytree (scaled-normal init, zero biases)."""
    rng = np.random.default_rng(seed)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def mat(shape, scale):
        return jnp.asarray((rng.standard_normal(shape) * scale).astype(np.float32))

    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            {
                "ln1": jnp.ones(d, jnp.float32),
                "wq": mat((d, d), d ** -0.5),
                "bq": jnp.zeros(d, jnp.float32),
                "wk": mat((d, d), d ** -0.5),
                "bk": jnp.zeros(d, jnp.float32),
                "wv": mat((d, d), d ** -0.5),
                "bv": jnp.zeros(d, jnp.float32),
                "wo": mat((d, d), (2 * d * cfg.n_layers) ** -0.5),
                "bo": jnp.zeros(d, jnp.float32),
                "ln2": jnp.ones(d, jnp.float32),
                "wg": mat((d, f), d ** -0.5),
                "bg": jnp.zeros(f, jnp.float32),
                "wu": mat((d, f), d ** -0.5),
                "bu": jnp.zeros(f, jnp.float32),
                "wd": mat((f, d), (2 * f * cfg.n_layers) ** -0.5),
                "bd": jnp.zeros(d, jnp.float32),
            }
        )
    return {
        "embed": mat((v, d), 1.0),
        "layers": layers,
        "lnf": jnp.ones(d, jnp.float32),
        "head": mat((d, v), d ** -0.5),
        "bhead": jnp.zeros(v, jnp.float32),
    }


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Building blocks


def rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS) * g


def _rope_angles(pos, dh: int, theta: float):
    """pos: (...,) int32 -> cos/sin of shape (..., dh//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = pos[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., dh); rotate pairs (even, odd)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape)


def make_qdq(act_cfg: MXConfig | None, ste: bool, use_pallas: bool):
    """Activation fake-quant hook. `ste=True` adds the straight-through
    estimator used while learning transforms (gradients pass the quantizer)."""
    if act_cfg is None or act_cfg.name == "none":
        return lambda t: t
    fn = mx_qdq_pallas if use_pallas else mx_qdq_ref

    def qdq(t):
        q = fn(t, act_cfg)
        if ste:
            return t + jax.lax.stop_gradient(q - t)
        return q

    return qdq


def _attn_core(q, k, v, mask, cfg: ModelConfig):
    """q,k,v: (B, T, H, dh); mask: (B?, T, S) boolean keep-mask."""
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    logits = jnp.where(mask[:, None, :, :], logits, -1e9)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", p, v)


def _block(params, x, pos, mask, cfg, qdq, t3, use_pallas, kv=None, kv_pos=None, taps=None):
    """One transformer block. If `kv=(k_cache, v_cache)` is given, attention
    runs against the cache (decode); otherwise self-attention over `x`.

    When `taps` is a dict (un-jitted calibration runs only) the four linear
    inputs are recorded: `attn_in` (q/k/v), `o_in`, `ffn_in` (gate/up),
    `down_in` — the Hessian sources for GPTQ.

    Returns (x_out, (k_new, v_new)) — the new K/V rows for cache updates.
    """
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    hx = rmsnorm(x, params["ln1"])
    hq = qdq(hx)
    if taps is not None:
        taps.setdefault("attn_in", []).append(hq.reshape(-1, d))
    q = (hq @ params["wq"] + params["bq"]).reshape(b, t, h, dh)
    k = (hq @ params["wk"] + params["bk"]).reshape(b, t, h, dh)
    v = (hq @ params["wv"] + params["bv"]).reshape(b, t, h, dh)
    cos, sin = _rope_angles(pos, dh, cfg.rope_theta)
    q = apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
    k = apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])
    if kv is not None:
        kc, vc = kv
        ks = _scatter_rows(kc, k[:, 0], kv_pos)
        vs = _scatter_rows(vc, v[:, 0], kv_pos)
        o = _attn_core(q, ks, vs, mask, cfg)
        k_out, v_out = ks, vs
    else:
        o = _attn_core(q, k, v, mask, cfg)
        k_out, v_out = k, v
    o = o.reshape(b, t, d)
    o = qdq(o)
    if taps is not None:
        taps.setdefault("o_in", []).append(o.reshape(-1, d))
    x = x + o @ params["wo"] + params["bo"]

    hx = rmsnorm(x, params["ln2"])
    hq = qdq(hx)
    if taps is not None:
        taps.setdefault("ffn_in", []).append(hq.reshape(-1, d))
    gate = jax.nn.silu(hq @ params["wg"] + params["bg"])
    up = hq @ params["wu"] + params["bu"]
    ff = gate * up
    if t3:
        bh = block_hadamard_pallas if use_pallas else block_hadamard_ref
        ff = bh(ff, t3)
    ff = qdq(ff)
    if taps is not None:
        taps.setdefault("down_in", []).append(ff.reshape(-1, ff.shape[-1]))
    x = x + ff @ params["wd"] + params["bd"]
    return x, (k_out, v_out)


def _scatter_rows(cache, new_row, pos):
    """cache: (B, S, H, dh); new_row: (B, H, dh); pos: (B,) int32.
    Per-lane scatter via one-hot (no batched dynamic_update_slice in HLO)."""
    s = cache.shape[1]
    oh = (jnp.arange(s)[None, :] == pos[:, None]).astype(cache.dtype)
    return cache * (1.0 - oh[:, :, None, None]) + new_row[:, None] * oh[:, :, None, None]


# ---------------------------------------------------------------------------
# Entry points


def forward_seq(
    params,
    tokens,
    cfg: ModelConfig,
    act_cfg: MXConfig | None = None,
    t3: int | None = None,
    ste: bool = False,
    use_pallas: bool = False,
    taps: list | None = None,
    return_states: bool = False,
):
    """Full-sequence causal logits: tokens (B, T) -> (B, T, vocab).

    `taps`: per-layer list of capture dicts (GPTQ calibration, un-jitted).
    `return_states=True` additionally returns the stacked post-block residual
    states (n_layers, B, T, d) — the per-block MSE distillation target.
    """
    b, t = tokens.shape
    qdq = make_qdq(act_cfg, ste, use_pallas)
    x = params["embed"][tokens]
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None, :], (b, t))
    mask = jnp.broadcast_to(
        jnp.tril(jnp.ones((t, t), bool))[None, :, :], (b, t, t)
    )
    states = []
    for li, lp in enumerate(params["layers"]):
        x, _ = _block(
            lp, x, pos, mask, cfg, qdq, t3, use_pallas,
            taps=None if taps is None else taps[li],
        )
        if return_states:
            states.append(x)
    x = rmsnorm(x, params["lnf"])
    logits = x @ params["head"] + params["bhead"]
    if return_states:
        return logits, jnp.stack(states)
    return logits


def init_kv(cfg: ModelConfig, batch: int, max_seq: int):
    """Zeroed KV cache pytree: list of (k, v), each (B, S, H, dh)."""
    shape = (batch, max_seq, cfg.n_heads, cfg.head_dim)
    return [
        (jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))
        for _ in range(cfg.n_layers)
    ]


def forward_prefill(
    params,
    tokens,
    length,
    cfg: ModelConfig,
    max_seq: int,
    act_cfg: MXConfig | None = None,
    t3: int | None = None,
    use_pallas: bool = False,
):
    """Prefill: tokens (B, T) padded, `length` (B,) actual prompt lengths.
    Returns (logits_at_last (B, vocab), kv) with K/V written at [0, T)."""
    b, t = tokens.shape
    qdq = make_qdq(act_cfg, False, use_pallas)
    x = params["embed"][tokens]
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None, :], (b, t))
    causal = jnp.tril(jnp.ones((t, t), bool))[None, :, :]
    valid = (jnp.arange(t)[None, :] < length[:, None])[:, None, :]
    mask = jnp.logical_and(causal, valid)
    kv_out = []
    for lp in params["layers"]:
        x, (k, v) = _block(lp, x, pos, mask, cfg, qdq, t3, use_pallas)
        pad = max_seq - t
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_out.append((k, v))
    x = rmsnorm(x, params["lnf"])
    logits = x @ params["head"] + params["bhead"]
    last = jnp.clip(length - 1, 0, t - 1)
    logits_last = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
    return logits_last, kv_out


def forward_decode(
    params,
    token,
    kv,
    pos,
    cfg: ModelConfig,
    act_cfg: MXConfig | None = None,
    t3: int | None = None,
    use_pallas: bool = False,
):
    """One decode step with per-slot positions (continuous batching).

    token (B,) int32, pos (B,) int32 — position at which `token` sits.
    Returns (logits (B, vocab), kv_new)."""
    b = token.shape[0]
    s = kv[0][0].shape[1]
    qdq = make_qdq(act_cfg, False, use_pallas)
    x = params["embed"][token][:, None, :]
    posv = pos[:, None]
    mask = (jnp.arange(s)[None, :] <= pos[:, None])[:, None, :]
    kv_new = []
    for lp, lkv in zip(params["layers"], kv):
        x, (k, v) = _block(
            lp, x, posv, mask, cfg, qdq, t3, use_pallas, kv=lkv, kv_pos=pos
        )
        kv_new.append((k, v))
    x = rmsnorm(x, params["lnf"])
    return (x @ params["head"] + params["bhead"])[:, 0], kv_new


# ---------------------------------------------------------------------------
# Losses / metrics


def lm_loss(params, tokens, cfg, **fwd_kw):
    """Next-token cross-entropy (mean over all positions)."""
    logits = forward_seq(params, tokens[:, :-1], cfg, **fwd_kw)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def perplexity(params, tokens, cfg: ModelConfig, batch: int = 8, **fwd_kw) -> float:
    """Corpus perplexity over token matrix (N, T)."""
    total, count = 0.0, 0
    loss_fn = jax.jit(
        functools.partial(lm_loss, cfg=cfg, **fwd_kw), static_argnames=()
    )
    for i in range(0, tokens.shape[0], batch):
        chunk = tokens[i : i + batch]
        total += float(loss_fn(params, jnp.asarray(chunk))) * chunk.shape[0]
        count += chunk.shape[0]
    return float(np.exp(total / max(count, 1)))
