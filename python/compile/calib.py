"""SynthText — the synthetic language standing in for WikiText2, plus the
seven synthetic zero-shot tasks standing in for the paper's commonsense
suite (ARC-E/C, HellaSwag, WinoGrande, PIQA, BoolQ, OBQA).

Substitution rationale (DESIGN.md §3.3): the corpus mixes "natural" text
(topic-conditioned affine word chains with Zipf noise — learnable structure
with a real train/held-out generalization gap) with task-patterned sentences,
so the pretrained tiny LM acquires partial task competence exactly the way an
LLM acquires commonsense: from distributional exposure. Zero-shot evaluation
then scores *fresh* task instances by length-normalized choice log-likelihood,
the LM-eval-harness protocol. Quantization degrades accuracy smoothly, which
is what Table 1's recovery metric needs.

Token map (vocab 256):
    0 PAD   1 BOS   2 SEP   3 EOS
    4..13   digits 0..9
    14..20  task markers (COPY REV PARITY MAJ MODSUM AGREE RETR)
    21..27  reserved
    28 EVEN 29 ODD  30 A    31 B
    32..255 word tokens
"""

import numpy as np

PAD, BOS, SEP, EOS = 0, 1, 2, 3
DIG0 = 4
M_COPY, M_REV, M_PARITY, M_MAJ, M_MODSUM, M_AGREE, M_RETR = range(14, 21)
EVEN, ODD, TOK_A, TOK_B = 28, 29, 30, 31
WORD0, WORD1 = 32, 256  # word-token range
NWORDS = WORD1 - WORD0

TASKS = ["copy", "reverse", "parity", "majority", "modsum", "agree", "retrieve"]

# Per-topic affine chain coefficients for "natural" text.
_TOPICS = [(5, 17), (7, 3), (11, 29), (13, 41), (17, 7), (19, 23), (23, 5), (29, 13)]


def _verb_for(s: int) -> int:
    """Deterministic agreement rule: subject word -> verb word."""
    return WORD0 + 64 + (7 * (s - WORD0) + 3) % 64


def _zipf_word(rng) -> int:
    r = min(rng.zipf(1.5), NWORDS)
    return WORD0 + int(r) - 1


def _natural_sentence(rng) -> list:
    t = rng.integers(len(_TOPICS))
    a, b = _TOPICS[t]
    w = int(rng.integers(NWORDS))
    out = []
    for _ in range(int(rng.integers(6, 13))):
        out.append(WORD0 + w)
        if rng.random() < 0.8:
            w = (a * w + b) % NWORDS
        else:
            w = _zipf_word(rng) - WORD0
    return out


def make_task_instance(task: str, rng):
    """Return (prompt_tokens, correct_completion, distractor_completions).

    The training corpus embeds `prompt + correct` as a sentence; zero-shot
    eval presents all four completions for likelihood scoring.
    """
    if task == "copy":
        k = int(rng.integers(3, 6))
        words = [int(w) for w in rng.integers(WORD0, WORD1, size=k)]
        prompt = [M_COPY] + words + [SEP]
        correct = list(words)
        distract = [
            list(rng.permuted(words)) if k > 1 else [int(rng.integers(WORD0, WORD1))]
            for _ in range(2)
        ] + [[int(w) for w in rng.integers(WORD0, WORD1, size=k)]]
    elif task == "reverse":
        k = int(rng.integers(3, 6))
        words = [int(w) for w in rng.integers(WORD0, WORD1, size=k)]
        prompt = [M_REV] + words + [SEP]
        correct = words[::-1]
        distract = [list(words), list(rng.permuted(words)),
                    [int(w) for w in rng.integers(WORD0, WORD1, size=k)]]
    elif task == "parity":
        k = 6
        bits = rng.integers(0, 2, size=k)
        seq = [TOK_A if b else TOK_B for b in bits]
        prompt = [M_PARITY] + seq + [SEP]
        n_a = int(bits.sum())
        correct = [EVEN if n_a % 2 == 0 else ODD]
        distract = [[ODD if n_a % 2 == 0 else EVEN], [TOK_A], [TOK_B]]
    elif task == "majority":
        k = 7
        bits = rng.integers(0, 2, size=k)
        seq = [TOK_A if b else TOK_B for b in bits]
        prompt = [M_MAJ] + seq + [SEP]
        maj = TOK_A if bits.sum() * 2 > k else TOK_B
        anti = TOK_B if maj == TOK_A else TOK_A
        correct = [maj]
        distract = [[anti], [EVEN], [ODD]]
    elif task == "modsum":
        a, b = int(rng.integers(10)), int(rng.integers(10))
        prompt = [M_MODSUM, DIG0 + a, DIG0 + b, SEP]
        c = (a + b) % 10
        wrong = rng.permuted([d for d in range(10) if d != c])[:3]
        correct = [DIG0 + c]
        distract = [[DIG0 + int(w)] for w in wrong]
    elif task == "agree":
        s = int(rng.integers(WORD0, WORD0 + 64))
        prompt = [M_AGREE, s, SEP]
        correct = [_verb_for(s)]
        others = rng.permuted(
            [w for w in range(WORD0 + 64, WORD0 + 128) if w != _verb_for(s)]
        )[:3]
        distract = [[int(w)] for w in others]
    elif task == "retrieve":
        keys = rng.permuted(np.arange(WORD0, WORD0 + 64))[:3]
        vals = rng.permuted(np.arange(WORD0 + 128, WORD0 + 192))[:3]
        pairs = []
        for kk, vv in zip(keys, vals):
            pairs += [int(kk), int(vv)]
        qi = int(rng.integers(3))
        prompt = [M_RETR] + pairs + [int(keys[qi]), SEP]
        correct = [int(vals[qi])]
        distract = [[int(vals[j])] for j in range(3) if j != qi]
        distract.append([int(rng.integers(WORD0 + 128, WORD0 + 192))])
        distract = distract[:3]
    else:
        raise ValueError(task)
    return prompt, correct, distract


def _task_sentence(rng) -> list:
    task = TASKS[int(rng.integers(len(TASKS)))]
    prompt, correct, _ = make_task_instance(task, rng)
    return prompt + correct


def make_corpus(n_seqs: int, seq_len: int, seed: int = 0) -> np.ndarray:
    """Token matrix `(n_seqs, seq_len)` of BOS-started, SEP-joined sentences.
    60% natural text / 40% task patterns."""
    rng = np.random.default_rng(seed)
    out = np.zeros((n_seqs, seq_len), dtype=np.int32)
    for i in range(n_seqs):
        toks = [BOS]
        while len(toks) < seq_len:
            s = _natural_sentence(rng) if rng.random() < 0.45 else _task_sentence(rng)
            toks += s + [SEP]
        out[i] = toks[:seq_len]
    return out


def make_eval_tasks(n_per_task: int, seed: int = 1234, max_len: int = 48):
    """Zero-shot eval set: for each task, `n_per_task` fresh instances.

    Returns a dict of arrays ready for `.lxt` export to the Rust harness:
      tasks_<name>_tokens  (n, 4, max_len) i32 — BOS + prompt + choice, padded
      tasks_<name>_prompt_len (n,) i32        — scoring starts at this index
      tasks_<name>_len     (n, 4) i32          — total length per choice
      tasks_<name>_label   (n,) i32            — index of the correct choice
    """
    rng = np.random.default_rng(seed)
    out = {}
    for task in TASKS:
        toks = np.zeros((n_per_task, 4, max_len), dtype=np.int32)
        plen = np.zeros((n_per_task,), dtype=np.int32)
        tlen = np.zeros((n_per_task, 4), dtype=np.int32)
        label = np.zeros((n_per_task,), dtype=np.int32)
        for i in range(n_per_task):
            prompt, correct, distract = make_task_instance(task, rng)
            choices = [correct] + distract
            order = rng.permutation(4)
            label[i] = int(np.argwhere(order == 0)[0][0])
            plen[i] = 1 + len(prompt)
            for slot, ci in enumerate(order):
                seq = [BOS] + prompt + choices[ci]
                tlen[i, slot] = len(seq)
                toks[i, slot, : len(seq)] = seq
        out[f"tasks_{task}_tokens"] = toks
        out[f"tasks_{task}_prompt_len"] = plen
        out[f"tasks_{task}_len"] = tlen
        out[f"tasks_{task}_label"] = label
    return out
