"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published `xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every graph takes the *weights as runtime arguments* (fixed order, recorded
in the manifest), so one compiled executable serves every quantization
method — RTN / GPTQ / QuaRot / SpinQuant / LATMiX weights are just different
argument sets. What differs per graph is the *activation* quantization
config and the online T3 Hadamard, which are data-dependent and live in the
HLO (lowered from the L1 Pallas kernels, interpret mode).

Graph kinds (shapes static per artifact):
  logits_ppl_<tag>    tokens (8, 128)                  -> logits (8, 128, V)
  logits_score_<tag>  tokens (8, 48)                   -> logits (8, 48, V)
  prefill_<tag>_b<B>  tokens (B, 32), len (B,)         -> last-logits, KV
  decode_<tag>_b<B>   token (B,), pos (B,), KV         -> logits, KV'
where <tag> = fp | <act_fmt>_b<bs>[_t3].

Also exports: eval datasets (ppl heldout + 7 zero-shot tasks), captured
residual-stream features for the Fig. 2 study, golden cross-check files for
the Rust MX/GPTQ ports, and `manifest.txt`.
"""

import argparse
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import calib
from .config import ModelConfig, QuantSpec
from .folding import fold_norm_scales, np_params
from .lxt import save_lxt
from .model import forward_decode, forward_prefill, forward_seq, init_kv, init_params
from .mx.quantize import MXConfig, mx_qdq_ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

PPL_SHAPE = (8, 128)
SCORE_SHAPE = (8, 48)
PREFILL_LEN = 32
KV_SEQ = 160
SERVE_BATCHES = (1, 2, 4, 8)


# ---------------------------------------------------------------------------
# Weight argument ordering


def weight_names(cfg: ModelConfig) -> list:
    """Canonical argument order for all graphs (must match rust/src/model)."""
    names = ["embed"]
    per_layer = [
        "ln1", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
        "ln2", "wg", "bg", "wu", "bu", "wd", "bd",
    ]
    for i in range(cfg.n_layers):
        names += [f"layers.{i}.{k}" for k in per_layer]
    names += ["lnf", "head", "bhead"]
    return names


def params_to_args(params, cfg: ModelConfig) -> list:
    flat = np_params(params)
    return [jnp.asarray(flat[n]) for n in weight_names(cfg)]


def args_to_params(args: list, cfg: ModelConfig) -> dict:
    names = weight_names(cfg)
    flat = dict(zip(names, args))
    layers = []
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        layers.append({k[len(pre):]: v for k, v in flat.items() if k.startswith(pre)})
    return {
        "embed": flat["embed"],
        "layers": layers,
        "lnf": flat["lnf"],
        "head": flat["head"],
        "bhead": flat["bhead"],
    }


# ---------------------------------------------------------------------------
# Lowering


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: print with full constants. The default printer elides
    # large array constants as `{...}`, which xla_extension 0.5.1's text
    # parser silently accepts as a degenerate literal — e.g. the RoPE
    # frequency vector collapses and every transformer output beyond
    # position 0 is garbage. (Found the hard way; see EXPERIMENTS.md.)
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # ... and without metadata: jax 0.8 emits `source_end_line` etc. that
    # the 0.5.1 text parser rejects as unknown attributes.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def quant_tag(qname: str, block: int, t3: int) -> str:
    base = "fp" if qname == "none" else f"{qname}_b{block}"
    return base + ("_t3" if t3 else "")


def _act_cfg(qname: str, block: int):
    return None if qname == "none" else MXConfig.from_name(qname, block)


def lower_logits(cfg, qname, block, t3, shape, use_pallas=True):
    act = _act_cfg(qname, block)

    def fn(tokens, *weights):
        params = args_to_params(list(weights), cfg)
        return (
            forward_seq(
                params, tokens, cfg, act_cfg=act, t3=t3 or None, use_pallas=use_pallas
            ),
        )

    tok_spec = jax.ShapeDtypeStruct(shape, jnp.int32)
    w_specs = [
        jax.ShapeDtypeStruct(a.shape, a.dtype)
        for a in params_to_args(init_params(cfg, 0), cfg)
    ]
    return jax.jit(fn).lower(tok_spec, *w_specs)


def _kv_specs(cfg, batch):
    kv = init_kv(cfg, batch, KV_SEQ)
    flat = []
    for k, v in kv:
        flat += [k, v]
    return [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat]


def _kv_from_flat(flat, cfg):
    return [(flat[2 * i], flat[2 * i + 1]) for i in range(cfg.n_layers)]


def lower_prefill(cfg, qname, block, t3, batch, use_pallas=True):
    act = _act_cfg(qname, block)

    def fn(tokens, length, *weights):
        params = args_to_params(list(weights), cfg)
        logits, kv = forward_prefill(
            params, tokens, length, cfg, KV_SEQ, act_cfg=act, t3=t3 or None,
            use_pallas=use_pallas,
        )
        out = [logits]
        for k, v in kv:
            out += [k, v]
        return tuple(out)

    tok = jax.ShapeDtypeStruct((batch, PREFILL_LEN), jnp.int32)
    length = jax.ShapeDtypeStruct((batch,), jnp.int32)
    w_specs = [
        jax.ShapeDtypeStruct(a.shape, a.dtype)
        for a in params_to_args(init_params(cfg, 0), cfg)
    ]
    return jax.jit(fn).lower(tok, length, *w_specs)


def lower_decode(cfg, qname, block, t3, batch, use_pallas=True):
    act = _act_cfg(qname, block)

    def fn(token, pos, *rest):
        nw = len(weight_names(cfg))
        weights = list(rest[:nw])
        kv = _kv_from_flat(list(rest[nw:]), cfg)
        params = args_to_params(weights, cfg)
        logits, kv2 = forward_decode(
            params, token, kv, pos, cfg, act_cfg=act, t3=t3 or None,
            use_pallas=use_pallas,
        )
        out = [logits]
        for k, v in kv2:
            out += [k, v]
        return tuple(out)

    token = jax.ShapeDtypeStruct((batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    w_specs = [
        jax.ShapeDtypeStruct(a.shape, a.dtype)
        for a in params_to_args(init_params(cfg, 0), cfg)
    ]
    return jax.jit(fn).lower(token, pos, *w_specs, *_kv_specs(cfg, batch))


# Eval graphs: (format, block, t3) combos the benches consume.
EVAL_QUANTS = [
    ("none", 32, 0),
    ("mxfp4", 32, 0), ("mxfp4", 32, 32),
    ("mxint4", 32, 0), ("mxint4", 32, 32),
    ("nvfp4", 16, 0), ("nvfp4", 16, 32),
    # Fig. 2b block-size sweep
    ("mxfp4", 8, 0), ("mxfp4", 8, 32),
    ("mxfp4", 16, 0), ("mxfp4", 16, 32),
    ("mxfp4", 64, 0), ("mxfp4", 64, 32),
]

SERVE_QUANTS = [("none", 32, 0), ("mxfp4", 32, 32)]


def emit_graphs(cfg: ModelConfig, out_dir: str, fast: bool = False):
    gdir = os.path.join(out_dir, "graphs")
    os.makedirs(gdir, exist_ok=True)
    manifest = []

    def write(name, lowered):
        path = os.path.join(gdir, f"{name}.hlo.txt")
        if not os.path.exists(path):
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
        manifest.append(name)
        print(f"[aot] {name}", flush=True)

    quants = EVAL_QUANTS[:4] if fast else EVAL_QUANTS
    for qname, block, t3 in quants:
        tag = quant_tag(qname, block, t3)
        write(f"logits_ppl_{tag}", lower_logits(cfg, qname, block, t3, PPL_SHAPE))
        write(f"logits_score_{tag}", lower_logits(cfg, qname, block, t3, SCORE_SHAPE))
    batches = (1, 4) if fast else SERVE_BATCHES
    for qname, block, t3 in SERVE_QUANTS:
        tag = quant_tag(qname, block, t3)
        for b in batches:
            write(f"prefill_{tag}_b{b}", lower_prefill(cfg, qname, block, t3, b))
            write(f"decode_{tag}_b{b}", lower_decode(cfg, qname, block, t3, b))
    return manifest


# ---------------------------------------------------------------------------
# Eval data, features, goldens


def emit_eval_data(cfg: ModelConfig, out_dir: str):
    ddir = os.path.join(out_dir, "eval")
    os.makedirs(ddir, exist_ok=True)
    heldout = calib.make_corpus(16, PPL_SHAPE[1], seed=777_000)
    save_lxt(os.path.join(ddir, "ppl_heldout.lxt"), {"tokens": heldout})
    tasks = calib.make_eval_tasks(25, seed=777_001, max_len=SCORE_SHAPE[1])
    save_lxt(os.path.join(ddir, "zeroshot.lxt"), tasks)
    print("[aot] eval data", flush=True)


def emit_features(cfg: ModelConfig, out_dir: str):
    """Capture residual-stream activations from the trained FP model (layer
    inputs to q/k/v) — the Fig. 2 feature set.

    Substitution (DESIGN.md §3.3): latmix-tiny's activations are near-
    Gaussian (kurtosis ≈ 3) — a 0.9M-param model never develops the massive
    systematic outlier channels that motivate the paper (Llama-class models
    show per-channel magnitude ratios of 10-100x). We therefore inject the
    LLM outlier pattern explicitly: a fixed set of channels is amplified by
    deterministic factors in [6, 24], exactly the structure rotation methods
    are designed to diffuse. Raw features are kept alongside.
    """
    from .lxt import load_lxt
    from .folding import from_np_params

    fdir = os.path.join(out_dir, "features")
    os.makedirs(fdir, exist_ok=True)
    fpath = os.path.join(out_dir, "weights", "fp_raw.lxt")
    if os.path.exists(fpath):
        params = fold_norm_scales(from_np_params(load_lxt(fpath), cfg))
    else:
        params = fold_norm_scales(init_params(cfg, 0))
    toks = calib.make_corpus(8, 128, seed=901)
    taps = [dict() for _ in range(cfg.n_layers)]
    forward_seq(params, jnp.asarray(toks), cfg, taps=taps)
    raw = np.asarray(taps[cfg.n_layers // 2]["attn_in"][0])
    rng = np.random.default_rng(902)
    feats = raw.copy()
    d = feats.shape[1]
    outlier_channels = rng.permutation(d)[: max(4, d // 16)]
    factors = np.exp(rng.uniform(np.log(6.0), np.log(24.0), size=len(outlier_channels)))
    for c, f in zip(outlier_channels, factors):
        feats[:, c] *= f.astype(np.float32)
    save_lxt(
        os.path.join(fdir, "resid_calib.lxt"),
        {"features": feats, "features_raw": raw,
         "outlier_channels": outlier_channels.astype(np.int32)},
    )
    print(f"[aot] features {feats.shape} ({len(outlier_channels)} outlier channels)", flush=True)


def emit_goldens(out_dir: str):
    """Golden files for the Rust MX-codec cross-check (bit-exact contract)."""
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.default_rng(42)
    x = (rng.standard_normal((16, 128)) * np.exp2(rng.integers(-8, 9, (16, 1)))).astype(
        np.float32
    )
    tensors = {"input": x}
    for fmt in ("mxfp4", "mxint4", "mxfp6", "mxfp8", "nvfp4"):
        for block in (8, 16, 32):
            cfg = MXConfig.from_name(fmt, block)
            q = np.asarray(mx_qdq_ref(jnp.asarray(x), cfg))
            tensors[f"{fmt}_b{block}"] = q
    save_lxt(os.path.join(gdir, "mx_qdq.lxt"), tensors)
    print("[aot] goldens", flush=True)


def write_manifest(cfg: ModelConfig, graphs: list, out_dir: str):
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for k, v in cfg.items():
            f.write(f"model.{k}={v}\n")
        f.write(f"kv_seq={KV_SEQ}\n")
        f.write(f"prefill_len={PREFILL_LEN}\n")
        f.write(f"ppl_shape={PPL_SHAPE[0]}x{PPL_SHAPE[1]}\n")
        f.write(f"score_shape={SCORE_SHAPE[0]}x{SCORE_SHAPE[1]}\n")
        f.write("weight_order=" + ",".join(weight_names(cfg)) + "\n")
        for g in graphs:
            f.write(f"graph={g}\n")
    print("[aot] manifest", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=ART)
    ap.add_argument("--fast", action="store_true", help="subset of graphs (CI)")
    args = ap.parse_args()
    cfg = ModelConfig()
    os.makedirs(args.out, exist_ok=True)
    graphs = emit_graphs(cfg, args.out, fast=args.fast)
    emit_eval_data(cfg, args.out)
    emit_features(cfg, args.out)
    emit_goldens(args.out)
    write_manifest(cfg, graphs, args.out)


if __name__ == "__main__":
    main()
