"""PTQ pipeline orchestration: FP checkpoint -> (transform learning ->)
folding -> weight quantization -> `.lxt` artifacts for the Rust coordinator.

Each method x format variant becomes `artifacts/weights/<method>_<fmt>.lxt`
(folded, weight-QDQ'd tensors — runtime arguments of the shared HLO graphs)
plus `artifacts/transforms/<method>_<fmt>.lxt` (the learned A1/v1/A2s for the
analysis benches) and training traces for Figs. 3/6.

Idempotent: variants whose artifact files already exist are skipped, so the
experiment sweep (`python -m compile.experiments`) is resumable.
"""

import os
import time

import numpy as np
import jax.numpy as jnp

from .baselines import METHODS, MethodSpec, fixed_transforms, latmix_config_for
from .calib import make_corpus
from .config import LatmixConfig, ModelConfig, QuantSpec
from .folding import fold_norm_scales, fold_params, from_np_params, np_params
from .gptq import quantize_weights
from .latmix import learn_transforms
from .lxt import load_lxt, save_lxt

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def load_fp_params(cfg: ModelConfig, art_dir: str = ART):
    """Load the pretrained checkpoint, γ-folded (the pipeline's step 0)."""
    flat = load_lxt(os.path.join(art_dir, "weights", "fp_raw.lxt"))
    return fold_norm_scales(from_np_params(flat, cfg))


def quantize_model(
    params0,
    cfg: ModelConfig,
    method: MethodSpec,
    qspec: QuantSpec,
    lcfg: LatmixConfig,
    calib: np.ndarray,
    seed: int = 0,
    verbose: bool = True,
):
    """Run one method end to end on γ-folded params.

    Returns (quantized_folded_params, transforms_dict_or_None, trace)."""
    t3 = method.t3
    transforms = None
    trace = []
    if method.transform == "none":
        folded = params0
    elif method.transform.startswith("fixed"):
        a1, v1, a2s, v2s = fixed_transforms(method, cfg, seed)
        folded = fold_params(params0, cfg, a1, v1, a2s, v2s, t3)
        transforms = {"a1": a1, "v1": v1, "a2s": a2s, "v2s": v2s}
    else:  # learned
        mcfg = latmix_config_for(method, lcfg)
        result = learn_transforms(
            params0, cfg, mcfg, qspec, calib, t3=t3, verbose=verbose
        )
        transforms = result
        trace = result["trace"]
        folded = fold_params(
            params0, cfg, result["a1"], result["v1"], result["a2s"], result["v2s"], t3
        )

    if method.weight_quant == "none":
        return folded, transforms, trace
    qparams = quantize_weights(
        folded,
        cfg,
        qspec.weight_cfg,
        method=method.weight_quant,
        calib_tokens=calib[: min(16, calib.shape[0])],
        act_cfg=qspec.act_cfg,
        t3=t3,
    )
    return qparams, transforms, trace


def variant_tag(method_name: str, qspec: QuantSpec) -> str:
    return f"{method_name}_{qspec.tag}"


def transforms_to_flat(transforms: dict) -> dict:
    flat = {"a1": transforms["a1"], "v1": transforms["v1"]}
    for i, (a2, v2) in enumerate(zip(transforms["a2s"], transforms["v2s"])):
        flat[f"a2.{i}"] = np.asarray(a2)
        flat[f"v2.{i}"] = np.asarray(v2)
    return flat


def run_variant(
    method_name: str,
    qspec: QuantSpec,
    cfg: ModelConfig,
    lcfg: LatmixConfig,
    calib: np.ndarray,
    art_dir: str = ART,
    tag: str | None = None,
    force: bool = False,
    verbose: bool = True,
):
    """Produce (and cache) the artifacts for one method x format variant.
    Returns the weights path."""
    tag = tag or variant_tag(method_name, qspec)
    wpath = os.path.join(art_dir, "weights", f"{tag}.lxt")
    if os.path.exists(wpath) and not force:
        if verbose:
            print(f"[pipeline] {tag}: cached", flush=True)
        return wpath
    t0 = time.time()
    method = METHODS[method_name]
    params0 = load_fp_params(cfg, art_dir)
    qparams, transforms, trace = quantize_model(
        params0, cfg, method, qspec, lcfg, calib, verbose=verbose
    )
    os.makedirs(os.path.dirname(wpath), exist_ok=True)
    save_lxt(wpath, np_params(qparams))
    if transforms is not None:
        tdir = os.path.join(art_dir, "transforms")
        os.makedirs(tdir, exist_ok=True)
        save_lxt(os.path.join(tdir, f"{tag}.lxt"), transforms_to_flat(transforms))
    if trace:
        trdir = os.path.join(art_dir, "traces")
        os.makedirs(trdir, exist_ok=True)
        with open(os.path.join(trdir, f"{tag}.csv"), "w") as f:
            f.write("step,loss,orth_dev,off_block,cond\n")
            for row in trace:
                f.write(",".join(f"{x:.6g}" for x in row) + "\n")
    if verbose:
        print(f"[pipeline] {tag}: done in {time.time()-t0:.0f}s -> {wpath}", flush=True)
    return wpath


def default_calib(lcfg: LatmixConfig, seed: int = 0) -> np.ndarray:
    """Calibration corpus — the SynthText *training* distribution (the paper
    reuses WikiText2-train for both transform learning and GPTQ)."""
    return make_corpus(max(lcfg.calib_samples, 16), lcfg.seq, seed=seed)
