"""Layer-1 Pallas kernels (build-time only; lowered into artifact HLO).

All kernels run with `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the interpret path (which lowers to plain HLO ops) is
both the correctness oracle target and the shipping configuration on this
testbed. Real-TPU structure (BlockSpec / VMEM / MXU mapping) is analyzed in
DESIGN.md §6 and EXPERIMENTS.md §Perf.
"""

from .mx_quant import mx_qdq_pallas
from .hadamard import block_hadamard_pallas
from .affine_mx import affine_qdq_pallas

__all__ = ["mx_qdq_pallas", "block_hadamard_pallas", "affine_qdq_pallas"]
