"""Pallas kernel: online block-Hadamard transform (the paper's T3).

TPU mapping (DESIGN.md §6): instead of CUDA warp-butterflies, the transform
is expressed as a batched `(N_B) x (B x B)` constant-matrix multiply so Mosaic
schedules it on the MXU — a 32x32 tile is a single systolic pass, and the
Hadamard constant lives in VMEM once per kernel instantiation. For B = 32 and
d = 256 this adds 2*B*d = 16K MACs per row, ~1.6% of the adjacent d x 4d GEMM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import hadamard_matrix

DEFAULT_TILE_ROWS = 128


def _bh_kernel(x_ref, h_ref, o_ref, *, block: int):
    tile = x_ref[...]
    rows, d = tile.shape
    h = h_ref[...]
    xb = tile.reshape(rows, d // block, block)
    yb = jax.lax.dot_general(
        xb, h, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = yb.reshape(rows, d).astype(tile.dtype)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _bh_2d(x, block: int, tile_rows: int):
    rows, d = x.shape
    h = hadamard_matrix(block)
    grid = (pl.cdiv(rows, tile_rows),)
    return pl.pallas_call(
        functools.partial(_bh_kernel, block=block),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block, block), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, d), lambda i: (i, 0)),
        interpret=True,
    )(x, h)


def block_hadamard_pallas(x, block: int, tile_rows: int = DEFAULT_TILE_ROWS):
    """Apply the normalized block-Hadamard to the last axis of `x`."""
    d = x.shape[-1]
    assert d % block == 0
    lead = x.shape[:-1]
    rows = 1
    for s in lead:
        rows *= s
    x2 = x.reshape(max(rows, 1), d)
    tr = min(tile_rows, x2.shape[0])
    return _bh_2d(x2, block, tr).reshape(lead + (d,))
