"""Pure-jnp correctness oracles for every Pallas kernel.

The oracle for MX QDQ is the `mx` package reference; the block-Hadamard and
fused affine+QDQ oracles are defined here. `python/tests/test_kernels.py`
sweeps shapes/dtypes/block-sizes with hypothesis and asserts allclose (and for
QDQ, bit-exact equality) between each kernel and its oracle.
"""

import jax.numpy as jnp

from ..mx.quantize import MXConfig, mx_qdq_ref  # noqa: F401  (re-export)


def hadamard_matrix(n: int):
    """Sylvester-construction Hadamard matrix, normalized to be orthogonal
    (H @ H.T = I). Requires n a power of two."""
    assert n & (n - 1) == 0 and n > 0, f"Hadamard size {n} not a power of 2"
    h = jnp.ones((1, 1), dtype=jnp.float32)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return h / jnp.sqrt(jnp.float32(n))


def block_hadamard_ref(x, block: int):
    """Apply the online T3 transform: multiply each `block`-sized group of the
    last axis by a normalized Hadamard matrix."""
    d = x.shape[-1]
    assert d % block == 0
    h = hadamard_matrix(block)
    xb = x.reshape(x.shape[:-1] + (d // block, block))
    yb = jnp.einsum("...nb,bc->...nc", xb, h)
    return yb.reshape(x.shape).astype(x.dtype)


def affine_qdq_ref(x, a, v, cfg: MXConfig):
    """Fused `QDQ(x @ A^T + v)` — the transformed-activation fake-quant used
    in the LATMiX training forward (Sec. 3.2) before folding."""
    y = x @ a.T + v
    return mx_qdq_ref(y, cfg)
