"""Pallas kernel: fused affine transform + MX QDQ — `QDQ(x @ A^T + v)`.

This is the LATMiX *training-time* hot-spot (Sec. 3.2): every transformed
activation is pushed through the learned affine map and fake-quantized before
the (full-precision) weight matmul. Fusing the transform GEMM with the QDQ
epilogue removes one full HBM round-trip of the transformed tensor.

TPU mapping (DESIGN.md §6): grid over row tiles; each step computes a
`(TILE_ROWS, d) @ (d, d)` MXU GEMM with `A^T` resident in VMEM (d = 256 f32
-> 256 KiB, well within budget), adds the bias on the VPU, then applies the
same block-reduce + codec epilogue as `mx_quant.py` while the tile is still
in VMEM. One read of x, one write of the QDQ'd output per element.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..mx.quantize import MXConfig
from .mx_quant import _qdq_block_body

DEFAULT_TILE_ROWS = 128


def _affine_qdq_kernel(x_ref, at_ref, v_ref, o_ref, *, cfg: MXConfig):
    tile = x_ref[...]
    rows, d = tile.shape
    y = (
        jax.lax.dot_general(
            tile, at_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + v_ref[...]
    )
    if cfg.name != "none":
        b = cfg.block_size
        y = _qdq_block_body(y.reshape(rows, d // b, b), cfg).reshape(rows, d)
    o_ref[...] = y.astype(tile.dtype)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _affine_qdq_2d(x, a, v, cfg: MXConfig, tile_rows: int):
    rows, d = x.shape
    grid = (pl.cdiv(rows, tile_rows),)
    return pl.pallas_call(
        functools.partial(_affine_qdq_kernel, cfg=cfg),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_rows, d), lambda i: (i, 0)),
        interpret=True,
    )(x, a.T, v)


def affine_qdq_pallas(x, a, v, cfg: MXConfig, tile_rows: int = DEFAULT_TILE_ROWS):
    """Fused `QDQ(x @ A^T + v)` along the last axis; any leading shape."""
    d = x.shape[-1]
    lead = x.shape[:-1]
    rows = 1
    for s in lead:
        rows *= s
    x2 = x.reshape(max(rows, 1), d)
    tr = min(tile_rows, x2.shape[0])
    return _affine_qdq_2d(x2, a, v, cfg, tr).reshape(lead + (d,))
