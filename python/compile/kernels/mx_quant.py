"""Pallas kernel: MX quantize-dequantize along the last axis.

TPU mapping (DESIGN.md §6): the CUDA-native layout (one warp per 32-element
block with shuffle max-reduce) is rethought as a VMEM tiling problem —
each grid step streams a `(TILE_ROWS, d)` tile HBM→VMEM, views it as
`(TILE_ROWS, N_B, B)`, reduces the lane axis on the VPU for the block abs-max,
derives the E8M0 scale with exp2/floor(log2), applies the element codec
vectorized, and writes the tile back. One HBM read + one write per element,
no scratch, no atomics. With f32 and d=256, a 128-row tile is 128 KiB of
VMEM — far inside a 16 MiB budget, leaving room for double buffering.

Runs under `interpret=True` (CPU PJRT cannot execute Mosaic custom-calls);
bit-exact vs `mx.quantize.mx_qdq_ref` by construction (same jnp ops).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..mx.formats import element_qdq, fp_qdq, FP4_E2M1, FP8_E4M3
from ..mx.quantize import MXConfig, SCALE_EMAX, SCALE_EMIN

DEFAULT_TILE_ROWS = 128


def _qdq_block_body(xb, cfg: MXConfig, ts=None):
    """Shared QDQ math on an `(..., N_B, B)` view — identical to the ref.

    For NVFP4, `ts` is the pre-computed per-tensor second-level scale (a
    global reduction, so it is computed outside the tiled kernel and passed
    in as a scalar operand).
    """
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    if cfg.nv:
        ts = jnp.float32(1.0) if ts is None else ts
        s = fp_qdq(amax / (FP4_E2M1.maxval * ts), FP8_E4M3)
        s = jnp.where(s > 0, s, jnp.ones_like(s)) * ts
        return s * fp_qdq(xb / s, FP4_E2M1)
    e = jnp.floor(jnp.log2(jnp.maximum(amax, 1e-38))) - cfg.element.emax
    e = jnp.clip(e, SCALE_EMIN, SCALE_EMAX)
    s = jnp.where(amax > 0, jnp.exp2(e), jnp.ones_like(amax))
    return s * element_qdq(xb / s, cfg.element)


def _mx_qdq_kernel(x_ref, ts_ref, o_ref, *, cfg: MXConfig):
    tile = x_ref[...]
    rows, d = tile.shape
    b = cfg.block_size
    xb = tile.reshape(rows, d // b, b)
    o_ref[...] = _qdq_block_body(xb, cfg, ts=ts_ref[0]).reshape(rows, d)


def nv_tensor_scale(x):
    """NVFP4 second-level per-tensor scale (see mx.quantize.nvfp4_qdq_ref)."""
    tmax = jnp.max(jnp.abs(x))
    return jnp.where(tmax > 0, tmax / (FP4_E2M1.maxval * FP8_E4M3.maxval), 1.0)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _mx_qdq_2d(x, cfg: MXConfig, tile_rows: int):
    rows, d = x.shape
    grid = (pl.cdiv(rows, tile_rows),)
    ts = nv_tensor_scale(x).reshape(1) if cfg.nv else jnp.ones((1,), jnp.float32)
    return pl.pallas_call(
        functools.partial(_mx_qdq_kernel, cfg=cfg),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_rows, d), lambda i: (i, 0)),
        interpret=True,
    )(x, ts)


def mx_qdq_pallas(x, cfg: MXConfig, tile_rows: int = DEFAULT_TILE_ROWS):
    """MX QDQ of `x` along its last axis; any leading shape."""
    if cfg.name == "none":
        return x
    d = x.shape[-1]
    assert d % cfg.block_size == 0
    lead = x.shape[:-1]
    rows = 1
    for s in lead:
        rows *= s
    x2 = x.reshape(max(rows, 1), d)
    tr = min(tile_rows, x2.shape[0])
    out = _mx_qdq_2d(x2, cfg, tr)
    return out.reshape(lead + (d,))
