"""Configuration dataclasses shared across the build-time pipeline.

The Rust side reads the same values from `artifacts/manifest.txt`; the
`rust/src/config/` TOML-subset parser consumes `configs/*.toml` for serving.
"""

from dataclasses import dataclass, field

from .mx.quantize import MXConfig


@dataclass(frozen=True)
class ModelConfig:
    """latmix-tiny: a pre-RMSNorm Llama-style transformer.

    Head dim (d_model / n_heads) is 32 — exactly one MX block — so the
    per-head T2 transform acts on whole MX blocks, mirroring the paper's
    SpinQuant-style R2 placement.
    """

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 384
    max_seq: int = 256
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def items(self):
        return {
            "vocab": self.vocab,
            "d_model": self.d_model,
            "n_layers": self.n_layers,
            "n_heads": self.n_heads,
            "d_ff": self.d_ff,
            "max_seq": self.max_seq,
        }.items()


@dataclass(frozen=True)
class QuantSpec:
    """Activation + weight quantization configuration for one experiment.

    `act` and `weight` are MXConfig names ("none", "mxfp4", "mxint4",
    "mxfp6", "mxfp8", "nvfp4"); `block_size` overrides the format default.
    """

    act: str = "mxfp4"
    weight: str = "mxfp4"
    block_size: int = 32

    @property
    def act_cfg(self) -> MXConfig:
        bs = 16 if self.act == "nvfp4" and self.block_size == 32 else self.block_size
        return MXConfig.from_name(self.act, bs)

    @property
    def weight_cfg(self) -> MXConfig:
        bs = (
            16
            if self.weight == "nvfp4" and self.block_size == 32
            else self.block_size
        )
        return MXConfig.from_name(self.weight, bs)

    @property
    def tag(self) -> str:
        if self.act == "none" and self.weight == "none":
            return "fp"
        return f"{self.act}_b{self.act_cfg.block_size}"


@dataclass(frozen=True)
class TrainConfig:
    """Pretraining hyperparameters for latmix-tiny (train_lm.py)."""

    steps: int = 700
    batch: int = 8
    seq: int = 128
    lr: float = 1.5e-3
    warmup: int = 50
    weight_decay: float = 0.01
    seed: int = 0


@dataclass(frozen=True)
class LatmixConfig:
    """Transformation-learning hyperparameters (Sec. 3.2 + App. D.1)."""

    steps: int = 150
    batch: int = 4
    seq: int = 64
    lr: float = 1e-3
    warmup_frac: float = 0.1
    lam: float = 0.1          # volume-regularizer weight (Eq. 9)
    temperature: float = 1.5  # distillation softmax temperature
    calib_samples: int = 64
    seed: int = 0
    loss: str = "kl"          # kl | ce | mse (Table 8)
    init: str = "bd_hadamard_noise"  # Table 7 strategies
    param: str = "lu"         # lu | qr
    learn_bias: bool = True
    learn_matrix: bool = True  # False -> orthogonal-only variants
    learn_upper: bool = True   # False -> Q diag(s) (OSTQuant-like)
    granularity: str = "full"  # full | block (Table 2)
