"""GPTQ weight quantization (Frantar et al. 2023) adapted to MX blocks —
the MR-GPTQ setting of Egiazarian et al. (2025) / the paper's Sec. 3.2
"weight quantization" stage.

Row-vector convention: layers compute `y = x @ W`, `W: (d_in, d_out)`. GPTQ
walks the *input* dimension; each quantized row's error is compensated into
the not-yet-quantized rows through the inverse-Hessian Cholesky factor.
MX block boundaries (groups of `block_size` consecutive input indices) get a
fresh shared scale computed from the *current* (error-compensated) weights —
the MX-aware analog of GPTQ's `group_size` handling.

A numpy implementation (runs once at build time; the request path only ever
sees the resulting QDQ'd tensors). Mirrored in Rust (`rust/src/quant/gptq.rs`)
and cross-checked via golden files.
"""

import numpy as np

from .config import ModelConfig
from .mx.quantize import MXConfig, mx_qdq_ref
from .model import forward_seq

PERCDAMP = 0.01


def rtn_quantize(w: np.ndarray, cfg: MXConfig) -> np.ndarray:
    """Round-to-nearest baseline for `W (d_in, d_out)`: plain MX QDQ with
    blocks along the input (reduction) dim, one scale per (block, column)."""
    import jax.numpy as jnp

    return np.asarray(mx_qdq_ref(jnp.asarray(w.T), cfg).T)


def _mx_scales(block: np.ndarray, cfg: MXConfig) -> np.ndarray:
    """Per-output-column shared scale for one MX input-block (B, d_out)."""
    amax = np.abs(block).max(axis=0)
    if cfg.nv:
        # two-level NVFP4 scale, per column group (tensor scale ~ amax here)
        from .mx.formats import FP4_E2M1

        s = amax / FP4_E2M1.maxval
        return np.where(amax > 0, s, 1.0).astype(np.float32)
    e = np.floor(np.log2(np.maximum(amax, 1e-38))) - cfg.element.emax
    e = np.clip(e, -127, 127)
    return np.where(amax > 0, np.exp2(e), 1.0).astype(np.float32)


def _qdq_cols(v: np.ndarray, s: np.ndarray, cfg: MXConfig) -> np.ndarray:
    """QDQ one weight row `v (d_out,)` with per-column scales `s`."""
    import jax.numpy as jnp

    from .mx.formats import element_qdq

    return np.asarray(s * element_qdq(jnp.asarray(v / s), cfg.element))


def gptq_quantize(
    w: np.ndarray, hessian: np.ndarray, cfg: MXConfig, percdamp: float = PERCDAMP
) -> np.ndarray:
    """Quantize `W (d_in, d_out)` with Hessian `H = X^T X (d_in, d_in)`."""
    w = w.astype(np.float64).copy()
    d_in, d_out = w.shape
    b = cfg.block_size
    h = hessian.astype(np.float64).copy()

    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    w[dead, :] = 0.0
    damp = percdamp * float(np.mean(np.diag(h)))
    h[np.diag_indices(d_in)] += damp

    # Upper-Cholesky factor of the inverse Hessian (GPTQ's propagation
    # matrix): inv = L L^T  =>  U = L^T satisfies U^T U = inv with U upper —
    # exactly torch.linalg.cholesky(inv, upper=True).
    hinv = np.linalg.inv(h)
    hinv = np.linalg.cholesky(hinv).T

    q = np.zeros_like(w)
    scales = None
    for i in range(d_in):
        if i % b == 0:
            scales = _mx_scales(w[i : i + b, :].astype(np.float32), cfg)
        d = hinv[i, i]
        qi = _qdq_cols(w[i, :].astype(np.float32), scales, cfg).astype(np.float64)
        q[i, :] = qi
        err = (w[i, :] - qi) / d
        if i + 1 < d_in:
            w[i + 1 :, :] -= np.outer(hinv[i, i + 1 :], err)
    return q.astype(np.float32)


def capture_hessians(params, tokens, cfg: ModelConfig, act_cfg, t3, batch: int = 4):
    """Run the calibration set through the (quantized-activation) model and
    accumulate per-linear-input Hessians `H = X^T X`.

    Returns `{layer_idx: {tap_name: H}}` for taps attn_in/o_in/ffn_in/down_in.
    """
    import jax.numpy as jnp

    hs = [
        {k: None for k in ("attn_in", "o_in", "ffn_in", "down_in")}
        for _ in range(cfg.n_layers)
    ]
    for i in range(0, tokens.shape[0], batch):
        taps = [dict() for _ in range(cfg.n_layers)]
        forward_seq(
            params, jnp.asarray(tokens[i : i + batch]), cfg,
            act_cfg=act_cfg, t3=t3, taps=taps,
        )
        for li in range(cfg.n_layers):
            for k, chunks in taps[li].items():
                x = np.asarray(chunks[0], dtype=np.float64)
                g = x.T @ x
                hs[li][k] = g if hs[li][k] is None else hs[li][k] + g
    return hs


TAP_FOR_WEIGHT = {
    "wq": "attn_in",
    "wk": "attn_in",
    "wv": "attn_in",
    "wo": "o_in",
    "wg": "ffn_in",
    "wu": "ffn_in",
    "wd": "down_in",
}


def quantize_weights(
    params,
    cfg: ModelConfig,
    weight_cfg: MXConfig,
    method: str = "gptq",
    calib_tokens: np.ndarray | None = None,
    act_cfg=None,
    t3=None,
):
    """QDQ all block linear weights (embeddings + head stay fp, as in the
    paper's setup). `method` is "rtn" or "gptq"."""
    import jax.numpy as jnp

    out = {k: v for k, v in params.items() if k != "layers"}
    out["layers"] = []
    hs = None
    if method == "gptq":
        assert calib_tokens is not None
        hs = capture_hessians(params, calib_tokens, cfg, act_cfg, t3)
    for li, lp in enumerate(params["layers"]):
        nl = dict(lp)
        for wname in TAP_FOR_WEIGHT:
            w = np.asarray(lp[wname])
            if method == "rtn":
                nl[wname] = jnp.asarray(rtn_quantize(w, weight_cfg))
            else:
                h = hs[li][TAP_FOR_WEIGHT[wname]]
                nl[wname] = jnp.asarray(gptq_quantize(w, h, weight_cfg))
        out["layers"].append(nl)
    return out
