"""Minimal AdamW + cosine schedule (optax is not available offline).

Used by both `train_lm.py` (pretraining) and `latmix.py` (transform
learning, per App. D.1: AdamW, cosine LR, linear warmup).
"""

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    """One AdamW step; returns (new_params, new_state)."""
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - step - lr * wd * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total_steps, base_lr, warmup, start_factor=0.1):
    """Linear warmup (start_factor -> 1) then cosine decay to 0.1 * base."""
    step = jnp.asarray(step, jnp.float32)
    warm = start_factor + (1 - start_factor) * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
    cos = 0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(step < warmup, warm, cos)
