"""LATMiX transform learning (Sec. 3.2): optimize T1 (global, d x d) and T2
(per layer, per head, dh x dh) with AdamW on free-form LU/QR parameters,
minimizing the KL distillation loss (Eq. 8) plus the volume regularizer
(Eq. 7/9), with MX fake-quantization (STE) on the transformed activations.

Key property: the student forward *folds the candidate transforms into the
weights differentiably* (`folding.fold_params`) and runs the exact deployed
graph — so the trained objective is the deployed model, and the
"computational invariance" relaxation (Table 3) is measurable by folding at
any step and evaluating in full precision.

Also hosts `learn_feature_transform`, the Fig. 2 numerical study: learn an
affine map minimizing the transformation MSE E(T) (Eq. 2) directly on
captured residual-stream features.
"""

import functools
import time
from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp

from .config import LatmixConfig, ModelConfig, QuantSpec
from .folding import fold_params
from .model import forward_seq
from .mx.quantize import MXConfig, mx_qdq_ref
from .optim import adamw_init, adamw_update, cosine_lr
from .transforms import (
    TSpec,
    condition_number,
    init_matrix,
    make_param,
    materialize,
    off_block_diagonal_norm,
    orthogonality_deviation,
    random_hadamard,
    random_orthogonal,
    split_params,
    trainable_keys,
    vol_regularizer,
)


# ---------------------------------------------------------------------------
# Transform sets (T1 + per-layer T2)


def build_transform_set(cfg: ModelConfig, lcfg: LatmixConfig):
    """Construct (specs, params) for T1 and the N per-layer T2 transforms,
    initialized per `lcfg.init` (App. D: T1 block-diagonal 32x32 random
    Hadamard for LU / random orthogonal for QR, small off-diagonal noise;
    T2 is one MX block wide, so its init is a full 32x32 Hadamard/rotation)."""
    rng = np.random.default_rng(lcfg.seed)
    d, dh = cfg.d_model, cfg.head_dim
    kw = dict(
        learn_bias=lcfg.learn_bias,
        learn_matrix=lcfg.learn_matrix,
        learn_upper=lcfg.learn_upper,
    )
    a0 = init_matrix(d, lcfg.init, rng)
    if lcfg.param == "kron":
        # FlatQuant's matrix structure: T1 = kron(Aa, Ab); the (single-MX-
        # block-wide) T2 stays an LU affine as in the paper's FlatQuant†.
        spec1, p1 = make_param(a0, "kron", learn_bias=lcfg.learn_bias)
        t2_kind = "lu"
        t2_kw = dict(learn_bias=lcfg.learn_bias)
    elif lcfg.granularity == "block":
        spec1, p1 = make_param(a0, "blockdiag", block=32, sub_kind=lcfg.param, **kw)
        t2_kind = lcfg.param
        t2_kw = kw
    else:
        spec1, p1 = make_param(a0, lcfg.param, **kw)
        t2_kind = lcfg.param
        t2_kw = kw
    t2_specs, t2_params = [], []
    for _ in range(cfg.n_layers):
        a20 = (
            random_hadamard(dh, rng) if t2_kind == "lu" else random_orthogonal(dh, rng)
        )
        s2, p2 = make_param(a20, t2_kind, **t2_kw)
        t2_specs.append(s2)
        t2_params.append(p2)
    return spec1, p1, t2_specs[0], t2_params


def materialize_set(spec1, p1, spec2, p2_list):
    a1, v1 = materialize(spec1, p1)
    a2s, v2s = [], []
    for p2 in p2_list:
        a2, v2 = materialize(spec2, p2)
        a2s.append(a2)
        v2s.append(v2)
    return a1, v1, a2s, v2s


# ---------------------------------------------------------------------------
# Losses


def kl_loss(teacher_logits, student_logits, temperature: float):
    """KL(teacher || student) with distillation temperature (Eq. 8)."""
    t = teacher_logits / temperature
    s = student_logits / temperature
    pt = jax.nn.softmax(t, axis=-1)
    return (
        jnp.mean(jnp.sum(pt * (jax.nn.log_softmax(t, -1) - jax.nn.log_softmax(s, -1)), -1))
        * temperature ** 2
    )


def ce_loss(tokens, student_logits):
    """Next-token cross-entropy (the SpinQuant objective)."""
    lp = jax.nn.log_softmax(student_logits[:, :-1], -1)
    tgt = tokens[:, 1:]
    return -jnp.mean(jnp.take_along_axis(lp, tgt[..., None], -1))


def mse_loss(teacher_states, student_states):
    """Per-transformer-block output MSE (the FlatQuant-style objective)."""
    return jnp.mean((teacher_states - student_states) ** 2)


# ---------------------------------------------------------------------------
# Training


def learn_transforms(
    params_fp,
    cfg: ModelConfig,
    lcfg: LatmixConfig,
    qspec: QuantSpec,
    corpus: np.ndarray,
    t3: int | None = 32,
    trace_every: int = 10,
    snapshot_steps: tuple = (),
    verbose: bool = True,
):
    """Learn T1/T2 on `corpus` (calibration tokens, (N, T)).

    Returns a dict:
      a1, v1       — materialized T1
      a2s, v2s     — per-layer T2
      trace        — list of (step, loss, orth_dev, off_block, cond) rows
      snapshots    — {step: (a1, v1, a2s, v2s)} for steps in snapshot_steps
                     (Table 3 invariance / Table 11 training-steps ablation);
                     a snapshot at step k reflects the state *before* step k.
      specs/params — raw parameterization state (for analysis)
    """
    act_cfg = qspec.act_cfg
    spec1, p1, spec2, p2_list = build_transform_set(cfg, lcfg)
    n = min(lcfg.calib_samples, corpus.shape[0])
    data = corpus[:n, : lcfg.seq].astype(np.int32)

    # Teacher outputs are transform-independent: precompute once.
    teacher_fwd = jax.jit(
        lambda pr, tk: forward_seq(pr, tk, cfg, return_states=lcfg.loss == "mse")
    )
    teacher_cache = {}
    nb = max(1, lcfg.batch)
    batches = [data[i : i + nb] for i in range(0, n, nb)]
    for bi, b in enumerate(batches):
        out = teacher_fwd(params_fp, jnp.asarray(b))
        teacher_cache[bi] = jax.tree_util.tree_map(jax.device_get, out)

    t1_train, t1_frozen = split_params(spec1, p1)
    t2_split = [split_params(spec2, p2) for p2 in p2_list]
    trainables = {"t1": t1_train, "t2": [t for t, _ in t2_split]}
    frozen = {"t1": t1_frozen, "t2": [f for _, f in t2_split]}

    def merge(tr, fz):
        p1m = {**fz["t1"], **tr["t1"]}
        p2m = [{**f, **t} for t, f in zip(tr["t2"], fz["t2"])]
        return p1m, p2m

    def loss_fn(tr, fz, tokens, teacher_out):
        p1m, p2m = merge(tr, fz)
        a1, v1, a2s, v2s = materialize_set(spec1, p1m, spec2, p2m)
        folded = fold_params(params_fp, cfg, a1, v1, a2s, v2s, t3)
        if lcfg.loss == "mse":
            t_logits, t_states = teacher_out
            s_logits, s_states = forward_seq(
                folded, tokens, cfg, act_cfg=act_cfg, t3=t3, ste=True,
                return_states=True,
            )
            base = mse_loss(t_states, s_states)
        else:
            s_logits = forward_seq(
                folded, tokens, cfg, act_cfg=act_cfg, t3=t3, ste=True
            )
            if lcfg.loss == "ce":
                base = ce_loss(tokens, s_logits)
            else:
                base = kl_loss(teacher_out, s_logits, lcfg.temperature)
        reg = vol_regularizer(spec1, p1m)
        for p2m_i in p2m:
            reg = reg + vol_regularizer(spec2, p2m_i)
        return base + lcfg.lam * reg, base

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def step_fn(tr, opt, lr, tokens, teacher_out):
        (loss, base), grads = grad_fn(tr, frozen, tokens, teacher_out)
        tr2, opt2 = adamw_update(grads, opt, tr, lr, wd=1e-4)
        return tr2, opt2, loss, base

    opt = adamw_init(trainables)
    trace = []
    snapshots = {}

    def snap():
        p1m, p2m = merge(trainables, frozen)
        a1, v1, a2s, v2s = materialize_set(spec1, p1m, spec2, p2m)
        return (
            np.asarray(a1),
            np.asarray(v1),
            [np.asarray(a) for a in a2s],
            [np.asarray(v) for v in v2s],
        )

    warmup = max(1, int(lcfg.steps * lcfg.warmup_frac))
    t0 = time.time()
    for step in range(lcfg.steps):
        if step in snapshot_steps:
            snapshots[step] = snap()
        bi = step % len(batches)
        lr = cosine_lr(step, lcfg.steps, lcfg.lr, warmup)
        trainables, opt, loss, base = step_fn(
            trainables, opt, lr, jnp.asarray(batches[bi]), teacher_cache[bi]
        )
        if step % trace_every == 0 or step == lcfg.steps - 1:
            p1m, _ = merge(trainables, frozen)
            a1 = materialize(spec1, p1m)[0]
            row = (
                step,
                float(loss),
                orthogonality_deviation(a1),
                off_block_diagonal_norm(a1, 32),
                condition_number(a1),
            )
            trace.append(row)
            if verbose:
                print(
                    f"  [latmix] step {step:4d} loss {float(loss):.4f} "
                    f"orthdev {row[2]:.3f} offblock {row[3]:.3f} cond {row[4]:.2f} "
                    f"({time.time() - t0:.0f}s)",
                    flush=True,
                )

    if lcfg.steps in snapshot_steps:
        snapshots[lcfg.steps] = snap()
    p1m, p2m = merge(trainables, frozen)
    a1, v1, a2s, v2s = materialize_set(spec1, p1m, spec2, p2m)
    return {
        "a1": np.asarray(a1),
        "v1": np.asarray(v1),
        "a2s": [np.asarray(a) for a in a2s],
        "v2s": [np.asarray(v) for v in v2s],
        "trace": trace,
        "snapshots": snapshots,
        "spec1": spec1,
        "params1": p1m,
    }


# ---------------------------------------------------------------------------
# Fig. 2 numerical study: learn T minimizing E(T) on raw features


def transformation_mse(x, a, v, mx_cfg: MXConfig):
    """E(T) of Eq. (2) estimated on feature rows `x (N, d)`."""
    y = x @ a + v
    q = mx_qdq_ref(y, mx_cfg)
    back = (q - v) @ jnp.linalg.inv(a)
    return jnp.mean(jnp.sum((x - back) ** 2, axis=-1)) / x.shape[-1]


def learn_feature_transform(
    feats: np.ndarray,
    mx_cfg: MXConfig,
    kind: str = "lu",
    steps: int = 300,
    lr: float = 3e-3,
    seed: int = 0,
    learn_bias: bool = True,
    learn_matrix: bool = True,
    init: str = "bd_hadamard_noise",
    lam: float = 0.1,
    verbose: bool = False,
):
    """Directly minimize E(T) (Eq. 2, with STE through the quantizer) over an
    affine/rotation family on captured features — the Fig. 2 learned curves."""
    d = feats.shape[-1]
    rng = np.random.default_rng(seed)
    spec, p = make_param(
        init_matrix(d, init, rng), kind, learn_bias=learn_bias, learn_matrix=learn_matrix
    )
    train, frozen = split_params(spec, p)
    x = jnp.asarray(feats.astype(np.float32))

    def loss_fn(tr):
        pm = {**frozen, **tr}
        a, v = materialize(spec, pm)
        y = x @ a + v
        q = mx_qdq_ref(y, mx_cfg)
        # Clipped STE. Plain STE is *degenerate* for the E(T) objective: the
        # differentiable path reconstructs x exactly (A and A^{-1} cancel),
        # so only quantization noise treated as constant remains. Gating the
        # pass-through on the per-block clipping threshold restores the
        # outlier-reduction signal: clipped elements expose d|y|/dA, and a
        # soft penalty on clipped mass steers energy below the knee.
        b = mx_cfg.block_size
        yb = y.reshape(y.shape[:-1] + (d // b, b))
        amax = jax.lax.stop_gradient(jnp.max(jnp.abs(yb), axis=-1, keepdims=True))
        s = jnp.exp2(jnp.floor(jnp.log2(jnp.maximum(amax, 1e-38))) - mx_cfg.element.emax)
        thresh = jnp.broadcast_to(s * mx_cfg.element.maxval, yb.shape).reshape(y.shape)
        clipped = jnp.abs(y) > thresh
        q_ste = jnp.where(
            clipped, q, y + jax.lax.stop_gradient(q - y)
        )
        back = (q_ste - v) @ jnp.linalg.inv(a)
        mse = jnp.mean(jnp.sum((x - back) ** 2, -1)) / d
        overflow = jnp.mean(jax.nn.relu(jnp.abs(y) - thresh) ** 2)
        return mse + 0.1 * overflow + lam * vol_regularizer(spec, pm), mse

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def step_fn(tr, opt, lr_):
        (loss, mse), g = grad_fn(tr)
        tr2, opt2 = adamw_update(g, opt, tr, lr_)
        return tr2, opt2, mse

    opt = adamw_init(train)
    # STE gradients through the quantizer are noisy: keep the best iterate
    # (by true E(T)) rather than trusting the last one.
    best = (float("inf"), train)
    for s in range(steps):
        lr_ = cosine_lr(s, steps, lr, max(1, steps // 10))
        train, opt, mse = step_fn(train, opt, lr_)
        if float(mse) < best[0]:
            best = (float(mse), jax.tree_util.tree_map(lambda x: x, train))
        if verbose and s % 50 == 0:
            print(f"  [fig2 {kind}] step {s} E(T)={float(mse):.5f}", flush=True)
    pm = {**frozen, **best[1]}
    a, v = materialize(spec, pm)
    return np.asarray(a), np.asarray(v), best[0]
