"""Pretrain latmix-tiny on SynthText (build-time substrate).

The paper quantizes *pretrained* checkpoints (Llama/Qwen); with no network
and no checkpoints, we train the substitute model from scratch — this is the
"train a small transformer and log the loss curve" half of the end-to-end
driver. Loss curve lands in artifacts/traces/pretrain_loss.csv and is quoted
in EXPERIMENTS.md.

Usage: python -m compile.train_lm [--steps N] [--out DIR]
"""

import argparse
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from .calib import make_corpus
from .config import ModelConfig, TrainConfig
from .folding import np_params
from .lxt import save_lxt
from .model import init_params, lm_loss, param_count, perplexity
from .optim import adamw_init, adamw_update, cosine_lr


def train(cfg: ModelConfig, tcfg: TrainConfig, out_dir: str, verbose: bool = True):
    rng = np.random.default_rng(tcfg.seed)
    n_train = tcfg.steps * tcfg.batch // 4 + 64  # ~4 epochs over the pool
    corpus = make_corpus(n_train, tcfg.seq, seed=tcfg.seed)
    heldout = make_corpus(64, tcfg.seq, seed=tcfg.seed + 10_000)

    params = init_params(cfg, tcfg.seed)
    if verbose:
        print(f"[pretrain] {param_count(params):,} params, {n_train} train seqs", flush=True)

    grad_fn = jax.value_and_grad(lambda p, b: lm_loss(p, b, cfg))

    @jax.jit
    def step_fn(p, opt, lr, batch):
        loss, g = grad_fn(p, batch)
        p2, opt2 = adamw_update(g, opt, p, lr, wd=tcfg.weight_decay)
        return p2, opt2, loss

    opt = adamw_init(params)
    trace = []
    t0 = time.time()
    for step in range(tcfg.steps):
        idx = rng.integers(0, corpus.shape[0], tcfg.batch)
        lr = cosine_lr(step, tcfg.steps, tcfg.lr, tcfg.warmup)
        params, opt, loss = step_fn(params, opt, lr, jnp.asarray(corpus[idx]))
        if step % 20 == 0 or step == tcfg.steps - 1:
            trace.append((step, float(loss)))
            if verbose:
                print(
                    f"[pretrain] step {step:4d}/{tcfg.steps} loss {float(loss):.4f} "
                    f"({time.time()-t0:.0f}s)",
                    flush=True,
                )

    ppl_train = perplexity(params, corpus[:32], cfg)
    ppl_held = perplexity(params, heldout, cfg)
    if verbose:
        print(f"[pretrain] ppl train={ppl_train:.3f} heldout={ppl_held:.3f}", flush=True)

    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)
    os.makedirs(os.path.join(out_dir, "traces"), exist_ok=True)
    save_lxt(os.path.join(out_dir, "weights", "fp_raw.lxt"), np_params(params))
    with open(os.path.join(out_dir, "traces", "pretrain_loss.csv"), "w") as f:
        f.write("step,loss\n")
        for s, l in trace:
            f.write(f"{s},{l:.6f}\n")
        f.write(f"# ppl_train={ppl_train:.4f} ppl_heldout={ppl_held:.4f}\n")
    return params, {"ppl_train": ppl_train, "ppl_heldout": ppl_held, "trace": trace}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=TrainConfig.steps)
    ap.add_argument("--batch", type=int, default=TrainConfig.batch)
    ap.add_argument("--seq", type=int, default=TrainConfig.seq)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    cfg = ModelConfig()
    tcfg = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq)
    train(cfg, tcfg, args.out)


if __name__ == "__main__":
    main()
