//! **End-to-end driver** (DESIGN.md §deliverables): exercises every layer of
//! the system on a real small workload and reports the paper's headline
//! metric.
//!
//! 1. Verifies the build-time substrate ran: pretraining loss curve of the
//!    substitute LM (trained from scratch on SynthText) — printed from the
//!    recorded trace.
//! 2. Quantizes the checkpoint *in Rust* with the RTN and GPTQ substrate
//!    (cross-checking the build-time python quantizers) and reports weight
//!    MSE + packed footprint.
//! 3. Evaluates FP16 / RTN / GPTQ / MR-GPTQ / LATMiX variants on the PJRT
//!    runtime: perplexity + 7-task zero-shot accuracy + recovery — the
//!    paper's Table-1 protocol.
//! 4. Serves batched generation requests through the coordinator and
//!    reports latency/throughput — the paper's Fig-4 protocol.
//!
//! ```sh
//! make pretrain artifacts experiments
//! cargo run --release --example quantize_pipeline
//! ```

use latmix::bench::Table;
use latmix::data::{load_ppl_corpus, load_tasks};
use latmix::eval::{perplexity, recovery, zero_shot};
use latmix::model::{ModelDesc, WeightSet};
use latmix::mx::{pack::PackedMx, MxConfig};
use latmix::quant::{mse, rtn_quantize};
use latmix::runtime::Runtime;
use latmix::server::{run_serving, ServeOptions};

fn main() -> anyhow::Result<()> {
    let art = latmix::artifacts_dir();

    // ---- 1. pretraining loss curve ---------------------------------------
    println!("== 1. substitute-LM pretraining (build-time) ==");
    match std::fs::read_to_string(art.join("traces").join("pretrain_loss.csv")) {
        Ok(text) => {
            let rows: Vec<&str> = text.lines().skip(1).filter(|l| !l.starts_with('#')).collect();
            let pick = |i: usize| rows.get(i).copied().unwrap_or("-");
            println!("loss curve (step,loss): start {} | mid {} | end {}",
                pick(0), pick(rows.len() / 2), pick(rows.len().saturating_sub(1)));
            if let Some(meta) = text.lines().find(|l| l.starts_with('#')) {
                println!("{}", meta.trim_start_matches("# "));
            }
        }
        Err(_) => println!("(no pretrain trace — run `make pretrain`)"),
    }

    let desc = ModelDesc::load(&art)?;
    let rt = Runtime::new(desc)?;
    let fp = WeightSet::load(&rt.desc, "fp_raw")?;

    // ---- 2. Rust-side weight quantization substrate ----------------------
    println!("\n== 2. Rust RTN quantization + packed footprint ==");
    let cfg = MxConfig::from_name("mxfp4", Some(32))?;
    let mut total_mse = 0.0;
    let mut total_f32 = 0usize;
    let mut total_packed = 0usize;
    let mut nw = 0;
    for (name, tensor) in rt.desc.weight_order.iter().zip(&fp.tensors) {
        if tensor.dims.len() == 2 && name.contains('w') && tensor.dims[0] % 32 == 0 {
            let w = tensor.as_f32()?;
            let q = rtn_quantize(w, tensor.dims[0], tensor.dims[1], &cfg);
            total_mse += mse(w, &q);
            let p = PackedMx::pack(w, cfg);
            total_f32 += w.len() * 4;
            total_packed += p.bytes();
            nw += 1;
        }
    }
    println!(
        "{} linear weights: mean RTN MSE {:.3e}, f32 {:.2} MiB -> MXFP4 {:.2} MiB ({:.2}x)",
        nw,
        total_mse / nw as f64,
        total_f32 as f64 / (1 << 20) as f64,
        total_packed as f64 / (1 << 20) as f64,
        total_f32 as f64 / total_packed as f64
    );

    // ---- 3. headline evaluation ------------------------------------------
    println!("\n== 3. perplexity + zero-shot recovery (paper Table-1 protocol) ==");
    let (corpus, n, t) = load_ppl_corpus(&art)?;
    let tasks = load_tasks(&art)?;
    let fp_ppl = perplexity(&rt, "fp", &fp, &corpus, n, t)?;
    let fp_acc = zero_shot(&rt, "fp", &fp, &tasks)?.last().unwrap().1;
    let mut tab = Table::new(
        "e2e_eval",
        "End-to-end driver: MXFP4 W+A quantization",
        &["variant", "ppl", "avg acc %", "recovery %"],
    );
    tab.row(vec![
        "FP16".into(),
        format!("{fp_ppl:.2}"),
        format!("{:.2}", fp_acc * 100.0),
        "100.00".into(),
    ]);
    for (label, wtag, gtag) in [
        ("RTN", "rtn_mxfp4_b32", "mxfp4_b32"),
        ("GPTQ", "gptq_mxfp4_b32", "mxfp4_b32"),
        ("MR-GPTQ", "mr-gptq_mxfp4_b32", "mxfp4_b32_t3"),
        ("LATMiX-LU", "latmix-lu_mxfp4_b32", "mxfp4_b32_t3"),
    ] {
        let Ok(ws) = WeightSet::load(&rt.desc, wtag) else {
            tab.row(vec![label.into(), "-".into(), "-".into(), "(run make experiments)".into()]);
            continue;
        };
        let ppl = perplexity(&rt, gtag, &ws, &corpus, n, t)?;
        let acc = zero_shot(&rt, gtag, &ws, &tasks)?.last().unwrap().1;
        tab.row(vec![
            label.into(),
            format!("{ppl:.2}"),
            format!("{:.2}", acc * 100.0),
            format!("{:.2}", recovery(acc, fp_acc)),
        ]);
    }
    tab.emit();

    // ---- 4. serving -------------------------------------------------------
    println!("== 4. batched serving (paper Fig-4 protocol) ==");
    for (label, gtag, wtag) in [
        ("FP graph", "fp", "fp_raw"),
        ("LATMiX MXFP4 graph", "mxfp4_b32_t3", "latmix-lu_mxfp4_b32"),
    ] {
        let opts =
            ServeOptions::default().tags(gtag, wtag).requests(12).max_new(24).slots(8).seed(7);
        match run_serving(&rt, &opts) {
            Ok(rep) => println!(
                "{label:>20}: {:.1} decode tok/s | ttft p50 {:.0} ms | latency p50 {:.0} ms",
                rep.core.decode_tok_per_s, rep.ttft_p50_ms, rep.latency_p50_ms
            ),
            Err(e) => println!("{label:>20}: unavailable ({e})"),
        }
    }
    println!("\nend-to-end driver complete — all three layers exercised.");
    Ok(())
}
