//! Configurable serving benchmark: sweep slot counts and request loads for
//! any (graph, weights) pair — the tool behind Fig. 4 style measurements.
//!
//! ```sh
//! cargo run --release --example serve_throughput -- \
//!     --weights latmix-lu_mxfp4_b32 --quant mxfp4_b32_t3 \
//!     --requests 16 --max-new 32 --slots 1,2,4,8
//! ```

use latmix::bench::Table;
use latmix::cli::Args;
use latmix::model::ModelDesc;
use latmix::runtime::Runtime;
use latmix::server::{run_serving, ServeOptions};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let wtag = args.opt("weights").unwrap_or("fp_raw").to_string();
    let gtag = args.opt("quant").unwrap_or("fp").to_string();
    let requests = args.opt_usize("requests", 16);
    let max_new = args.opt_usize("max-new", 32);
    let slots: Vec<usize> = args
        .opt("slots")
        .unwrap_or("1,2,4,8")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();

    let desc = ModelDesc::load(&latmix::artifacts_dir())?;
    let rt = Runtime::new(desc)?;
    let mut tab = Table::new(
        "serve_throughput",
        &format!("Serving sweep: weights={wtag} graph={gtag} requests={requests} max_new={max_new}"),
        &["slots", "decode tok/s", "total tok/s", "ttft p50 ms", "latency p50 ms", "p99 ms"],
    );
    for s in slots {
        let opts = ServeOptions::default()
            .tags(&gtag, &wtag)
            .requests(requests)
            .max_new(max_new)
            .slots(s)
            .seed(42);
        let rep = run_serving(&rt, &opts)?;
        tab.row(vec![
            s.to_string(),
            format!("{:.1}", rep.core.decode_tok_per_s),
            format!("{:.1}", rep.total_tok_per_s),
            format!("{:.1}", rep.ttft_p50_ms),
            format!("{:.1}", rep.latency_p50_ms),
            format!("{:.1}", rep.latency_p99_ms),
        ]);
    }
    tab.emit();
    Ok(())
}
