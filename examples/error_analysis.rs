//! Walkthrough of the paper's Sec. 3.1 theory on live data:
//!
//! 1. The Dirac-delta example: H4 on x = [10, 1, 0.5, 0.5] with B = 2 —
//!    block-1 error falls, block-2 error rises (why naive rotation hurts MX).
//! 2. The Theorem 3.3 trade-off: shrinking one direction of A reduces block
//!    maxima M_i but inflates ||A^{-1}||σ².
//! 3. Synthetic outlier features: E(T) for identity / full Hadamard /
//!    block-Hadamard / (if built) the learned transforms, under MXFP4 and
//!    MXINT4.
//!
//! ```sh
//! cargo run --release --example error_analysis
//! ```

use latmix::bench::Table;
use latmix::io::load_lxt;
use latmix::linalg::{block_diag, hadamard, Mat};
use latmix::mx::MxConfig;
use latmix::transform::bound::{block_max_moments, theorem_bound};
use latmix::transform::{transformation_mse, Affine};
use latmix::util::Pcg64;

fn main() -> anyhow::Result<()> {
    // ---- 1. Dirac example -------------------------------------------------
    println!("== Sec. 3.1 Dirac example: x = [10, 1, 0.5, 0.5], B = 2 ==");
    let x = [10.0f32, 1.0, 0.5, 0.5];
    let id = Affine::identity(4);
    let h4 = Affine::new(hadamard(4), vec![0.0; 4])?;
    let y = h4.forward_rows(&x);
    println!("H4 x = {y:?}  (paper: [6, 4.5, 5, 4.5])");
    for (name, t) in [("identity", &id), ("H4", &h4)] {
        let m = block_max_moments(&x, 4, t, 2);
        println!("  {name:>8}: block maxima^2 M_i = {m:?}");
    }

    // ---- 2. the trade-off --------------------------------------------------
    println!("\n== Theorem 3.3 trade-off: shrink one direction ==");
    for s in [1.0f32, 0.3, 0.05] {
        let mut a = Mat::eye(4);
        a[(0, 0)] = s;
        let t = Affine::new(a, vec![0.0; 4])?;
        let m = block_max_moments(&x, 4, &t, 2);
        let inv = t.inverse_matrix().spectral_norm();
        println!(
            "  A = diag({s},1,1,1): mean M_i = {:.2}, ||A^-1||σ² = {:.2}, bound = {:.2}",
            (m[0] + m[1]) / 2.0,
            inv * inv,
            theorem_bound(&x, 4, &t, 2)
        );
    }

    // ---- 3. outlier features ----------------------------------------------
    println!("\n== E(T) on synthetic outlier features (d=128, 3 hot channels) ==");
    let d = 128;
    let rows = 256;
    let mut rng = Pcg64::seed(5);
    let mut feats = rng.normal_vec(d * rows, 0.3);
    for r in 0..rows {
        // persistent outlier channels, heavy-tailed magnitudes
        for &c in &[5usize, 40, 99] {
            feats[r * d + c] = (8.0 + 4.0 * rng.normal().abs()) * rng.normal().signum();
        }
    }
    let bh = Affine::new(block_diag(&vec![hadamard(32); d / 32]), vec![0.0; d])?;
    let fh = Affine::new(hadamard(d), vec![0.0; d])?;
    let idd = Affine::identity(d);
    let mut tab = Table::new(
        "error_analysis",
        "E(T) on synthetic outlier features",
        &["transform", "MXFP4 B=32", "MXINT4 B=32", "bound surrogate"],
    );
    let learned = load_lxt(&latmix::artifacts_dir().join("transforms").join("fig2_learned_b32.lxt"))
        .ok()
        .and_then(|m| {
            let a = m.get("aff_a")?.as_f32().ok()?.to_vec();
            let v = m.get("aff_v")?.as_f32().ok()?.to_vec();
            Affine::new(Mat::from_vec(d, d, a), v).ok()
        });
    let fp4 = MxConfig::from_name("mxfp4", Some(32))?;
    let int4 = MxConfig::from_name("mxint4", Some(32))?;
    let mut entries: Vec<(&str, &Affine)> = vec![
        ("vanilla", &idd),
        ("full Hadamard", &fh),
        ("block Hadamard", &bh),
    ];
    if let Some(ref l) = learned {
        entries.push(("learned affine (from artifacts)", l));
    }
    for (name, t) in entries {
        tab.row(vec![
            name.into(),
            format!("{:.5}", transformation_mse(&feats, d, t, &fp4)),
            format!("{:.5}", transformation_mse(&feats, d, t, &int4)),
            format!("{:.3}", theorem_bound(&feats, d, t, 32)),
        ]);
    }
    tab.emit();
    println!("expected shape: Hadamard-family << vanilla. (The learned transform was");
    println!("fit to the *model's* features, not these synthetic ones — the matched-");
    println!("distribution comparison where it wins is `cargo bench --bench fig2_error_analysis`.)");
    Ok(())
}
