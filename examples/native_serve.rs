//! Serve without XLA: the pure-Rust `NativeExecutor` drives the same
//! continuous-batching engine, KV cache, and batch-size buckets as the
//! PJRT executor — over the same `.lxt` artifacts when present, or over
//! synthetic weights on a machine with nothing built at all.
//!
//! Like the other files in this repo-root `examples/` directory, this is a
//! documentation walkthrough, not a cargo example target (the crate lives
//! under `rust/`); copy it to `rust/examples/` to run it with
//! `cargo run --no-default-features --example native_serve`.

use latmix::coordinator::engine::{NativeExecutor, StepExecutor};
use latmix::coordinator::{Engine, EngineConfig, GenRequest};
use latmix::model::{ModelDesc, NativeDims, WeightSet};
use latmix::server::{serve_with_executor, ServeOptions};

fn main() -> anyhow::Result<()> {
    // Artifact-backed when available, synthetic otherwise — either way the
    // whole serving stack runs with no XLA toolchain on the machine.
    let art = latmix::artifacts_dir();
    let exec = match ModelDesc::load(&art) {
        Ok(desc) => {
            let ws = WeightSet::load(&desc, "fp_raw")?;
            println!("native_serve: using artifacts from {art:?}");
            NativeExecutor::new(&desc, "fp", &ws)?
        }
        Err(_) => {
            println!("native_serve: no artifacts — synthetic latmix-tiny weights");
            NativeExecutor::synthetic(NativeDims::latmix_tiny(), "fp", vec![1, 2, 4, 8], 42)?
        }
    };

    // A few hand-submitted generations...
    let mut engine = Engine::new(
        NativeExecutor::clone(&exec),
        EngineConfig { max_slots: 4, eos: -1, ..Default::default() },
    );
    let prompt = vec![1i32, 14, 100, 101, 102, 2];
    engine.submit(GenRequest::new(0, prompt.clone(), 4));
    let out = engine.run_to_completion()?;
    println!("prompt {:?} -> generated {:?}", prompt, out[0].tokens);

    // ...then the closed-loop throughput benchmark (Fig. 4 protocol).
    // ServeOptions replaces the old positional-argument pile; unset fields
    // keep their defaults (KvSpec::default() = f32 pages, 16-token blocks).
    let prefill = exec.prefill_len();
    let opts = ServeOptions::default().tags("fp", "native").requests(12).max_new(16).slots(4).seed(7);
    let rep = serve_with_executor(exec, &opts)?;
    println!(
        "prefill_len={prefill} requests={} decode tok/s={:.1} ttft p50={:.1}ms latency p50={:.1}ms kv={}B",
        rep.core.requests,
        rep.core.decode_tok_per_s,
        rep.ttft_p50_ms,
        rep.latency_p50_ms,
        rep.core.residency.kv_bytes
    );
    Ok(())
}
