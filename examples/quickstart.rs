//! Quickstart: load the AOT artifacts, evaluate the FP16 model and a
//! LATMiX-quantized variant, and generate a few tokens through the serving
//! engine.
//!
//! ```sh
//! make pretrain artifacts          # build-time python (runs once)
//! cargo run --release --example quickstart
//! ```

use latmix::coordinator::engine::XlaExecutor;
use latmix::coordinator::{Engine, EngineConfig, GenRequest};
use latmix::data::{load_ppl_corpus, load_tasks};
use latmix::eval::{perplexity, recovery, zero_shot};
use latmix::model::{ModelDesc, WeightSet};
use latmix::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let art = latmix::artifacts_dir();
    let desc = ModelDesc::load(&art)?;
    println!(
        "latmix-tiny: d={} layers={} heads={} | {} compiled graphs",
        desc.d_model, desc.n_layers, desc.n_heads, desc.graphs.len()
    );
    let rt = Runtime::new(desc)?;
    println!("PJRT platform: {}", rt.platform());

    // --- evaluate FP16 vs LATMiX-MXFP4 ------------------------------------
    let (corpus, n, t) = load_ppl_corpus(&art)?;
    let tasks = load_tasks(&art)?;
    let fp = WeightSet::load(&rt.desc, "fp_raw")?;
    let fp_ppl = perplexity(&rt, "fp", &fp, &corpus, n, t)?;
    let fp_acc = zero_shot(&rt, "fp", &fp, &tasks)?.last().unwrap().1;
    println!("FP16      : ppl {fp_ppl:.2}  zero-shot avg {:.1}%", fp_acc * 100.0);

    if let Ok(lm) = WeightSet::load(&rt.desc, "latmix-lu_mxfp4_b32") {
        let ppl = perplexity(&rt, "mxfp4_b32_t3", &lm, &corpus, n, t)?;
        let acc = zero_shot(&rt, "mxfp4_b32_t3", &lm, &tasks)?.last().unwrap().1;
        println!(
            "LATMiX-LU : ppl {ppl:.2}  zero-shot avg {:.1}%  (recovery {:.1}%)",
            acc * 100.0,
            recovery(acc, fp_acc)
        );
    } else {
        println!("LATMiX variant not built yet — run `make experiments`");
    }

    // --- generate through the serving engine ------------------------------
    let exec = XlaExecutor::new(&rt, "fp", &fp)?;
    let mut engine =
        Engine::new(exec, EngineConfig { max_slots: 2, eos: -1, ..Default::default() });
    // prompt: BOS + COPY-task marker + three words + SEP — the model should copy
    let prompt = vec![1i32, 14, 100, 101, 102, 2];
    engine.submit(GenRequest::new(0, prompt.clone(), 4));
    let out = engine.run_to_completion()?;
    println!("prompt {:?} -> generated {:?}", prompt, out[0].tokens);
    Ok(())
}
