#!/usr/bin/env python3
"""Bench-trajectory diff: compare a fresh bench snapshot against the
committed baseline and emit a comparison table.

Usage: bench_diff.py <baseline.json> <fresh.json>

Two snapshot kinds are understood, dispatched on the `"bench"` field:

- `microbench` (schema 3): per-kernel ns/unit rows keyed on (op, backend).
  For timed rows with a throughput unit, ns/unit = 1e9 / throughput;
  otherwise mean iteration time is used. Timer-free counter rows (schema 3,
  `value` + `value_unit` — e.g. the `allocs_per_step` rows from the
  counting-allocator harness) are diffed on the raw value.
  See README.md §Perf methodology.
- `serving` (schema 1): per-payload-class SLO rows keyed on class name;
  TTFT and inter-token p50/p99 milliseconds are diffed per class.

- The markdown table goes to $GITHUB_STEP_SUMMARY when set, else stdout.
- Regressions > 25% emit GitHub `::warning::` annotations on stdout —
  warn, never fail (CI perf is noisy; the table is the signal). A nonzero
  `alloc/step` counter row also warns: zero is the steady-state invariant
  (enforced hard by rust/tests/alloc_steady_state.rs), so any drift in the
  smoke bench deserves a look even though counters are not timing-noisy.
- Missing/empty baseline is fine: every row reports as `new` and the fresh
  snapshot becomes the first real baseline once committed.

Stdlib only.
"""

import json
import os
import re
import sys


def load(path):
    try:
        with open(path) as f:
            snap = json.load(f)
    except (OSError, ValueError):
        return None
    # tolerate malformed snapshots (non-dict JSON, results not a list):
    # treat them as absent rather than crashing the CI step
    if not isinstance(snap, dict) or not isinstance(snap.get("results", []), list):
        return None
    return snap


def keyed(snap):
    out = {}
    for r in (snap or {}).get("results", []):
        if isinstance(r, dict):
            out[(r.get("op", "?"), r.get("backend", "?"))] = r
    return out


def ns_per_unit(row):
    tp = row.get("throughput")
    if tp:
        return 1e9 / tp, row.get("throughput_unit", "unit")
    return row.get("mean_s", 0.0) * 1e9, "iter"


def diff_microbench(base, fresh):
    lines = ["## Bench trajectory — microbench (ns per unit, lower is better)", ""]
    warnings = []
    brows = keyed(base)
    fresh_rows = [
        r for r in (fresh or {}).get("results", []) if isinstance(r, dict)
    ]
    if not fresh_rows:
        lines.append("_no fresh BENCH_microbench.json rows — did the smoke bench run?_")
    else:
        # The committed baseline may be the schema-2 empty-rows stub from a
        # toolchain-less authoring environment ({"results": []}): say so up
        # front instead of emitting a table that looks like a comparison.
        if not brows:
            note = (
                "committed stub" if base and base.get("results") == [] else "missing/unreadable"
            )
            lines.append(
                f"_no baseline rows ({note}) — every row below is new; commit this "
                "run's BENCH_microbench.json as the first real baseline_"
            )
            lines.append("")
        lines.append("| op | backend | unit | baseline | fresh | delta |")
        lines.append("|---|---|---|---|---|---|")
        # iterate the raw list (not keyed()) so duplicate (op, backend)
        # rows stay visible instead of last-one-wins vanishing
        for row in fresh_rows:
            key = (row.get("op", "?"), row.get("backend", "?"))
            if "value_unit" in row:
                lines.extend(counter_row(key, row, brows.get(key), warnings))
                continue
            f_ns, unit = ns_per_unit(row)
            b = brows.get(key)
            if b is None:
                lines.append(f"| {key[0]} | {key[1]} | {unit} | - | {f_ns:.2f} | new |")
                continue
            b_ns, _ = ns_per_unit(b)
            delta = (f_ns - b_ns) / b_ns * 100.0 if b_ns > 0 else 0.0
            mark = " :warning:" if delta > 25.0 else ""
            lines.append(
                f"| {key[0]} | {key[1]} | {unit} | {b_ns:.2f} | {f_ns:.2f} | {delta:+.1f}%{mark} |"
            )
            if delta > 25.0:
                warnings.append(
                    f"microbench regression >25% on {key[0]!r} [{key[1]}]: "
                    f"{delta:+.1f}% ns/unit vs committed baseline"
                )
        lines.extend(shard_scaling_lines(fresh_rows))
    return lines, warnings


def counter_row(key, row, base_row, warnings):
    """Diff one schema-3 counter row (`value` + `value_unit`) on the raw
    value; a nonzero `alloc/step` reading always warns — zero allocations
    per steady-state decode step is an invariant, not a noisy timing."""
    f_v = row.get("value", 0.0)
    unit = row.get("value_unit", "count")
    alloc_drift = unit == "alloc/step" and f_v > 0
    if base_row is None or "value" not in base_row:
        b_txt, delta = "-", "new"
    else:
        b_v = base_row["value"]
        b_txt, delta = f"{b_v:g}", f"{f_v - b_v:+g}"
    mark = " :warning:" if alloc_drift else ""
    if alloc_drift:
        warnings.append(
            f"microbench counter {key[0]!r} [{key[1]}]: {f_v:g} {unit} "
            "(steady-state decode should allocate 0; see "
            "rust/tests/alloc_steady_state.rs)"
        )
    return [f"| {key[0]} | {key[1]} | {unit} | {b_txt} | {f_v:g} | {delta}{mark} |"]


def shard_scaling_lines(fresh_rows):
    """Summarize `... workers=N ...` row families as speedup vs workers=1.

    The sharded-executor rows differ only in worker count (the shard plan —
    and therefore the math — is fixed), so the interesting number is the
    fork-join scaling, not the absolute ns. Rows without a workers=1
    sibling are left to the main table.
    """
    fams = {}
    for row in fresh_rows:
        op = row.get("op", "?")
        m = re.search(r"workers=(\d+)", op)
        if not m:
            continue
        base = (
            re.sub(r"\s+", " ", op[: m.start()] + op[m.end():]).strip(),
            row.get("backend", "?"),
        )
        fams.setdefault(base, {})[int(m.group(1))] = ns_per_unit(row)[0]
    lines = []
    for (base, backend), by_w in sorted(fams.items()):
        one = by_w.get(1)
        if not one or len(by_w) < 2:
            continue
        parts = [
            f"w={w} {one / ns:.2f}x" for w, ns in sorted(by_w.items()) if w != 1 and ns > 0
        ]
        if parts:
            lines.append(f"- shard scaling `{base}` [{backend}]: " + ", ".join(parts))
    if lines:
        lines.insert(0, "")
        lines.insert(1, "**Shard scaling (speedup vs workers=1, same bit-exact output):**")
    return lines


def class_rows(snap):
    out = {}
    for c in (snap or {}).get("classes", []):
        if isinstance(c, dict):
            out[c.get("class", "?")] = c
    return out


# Serving SLO metrics diffed per payload class (schema 1 field names).
SERVING_METRICS = ("ttft_p50_ms", "ttft_p99_ms", "itl_p50_ms", "itl_p99_ms")


def diff_serving(base, fresh):
    lines = ["## Bench trajectory — serving (per-class SLO ms, lower is better)", ""]
    warnings = []
    brows = class_rows(base)
    fresh_rows = [c for c in (fresh or {}).get("classes", []) if isinstance(c, dict)]
    if not fresh_rows:
        lines.append("_no fresh BENCH_serving.json class rows — did the serving smoke run?_")
        return lines, warnings
    if not brows:
        note = (
            "committed stub" if base and base.get("classes") == [] else "missing/unreadable"
        )
        lines.append(
            f"_no baseline class rows ({note}) — every row below is new; commit this "
            "run's BENCH_serving.json as the first real baseline_"
        )
        lines.append("")
    lines.append("| class | reqs | done | metric | baseline | fresh | delta |")
    lines.append("|---|---|---|---|---|---|---|")
    for row in fresh_rows:
        name = row.get("class", "?")
        reqs, done = row.get("requests", "?"), row.get("completed", "?")
        b = brows.get(name)
        for metric in SERVING_METRICS:
            f_v = row.get(metric, 0.0)
            b_v = (b or {}).get(metric, 0.0)
            if b is None or not b_v:
                lines.append(
                    f"| {name} | {reqs} | {done} | {metric} | - | {f_v:.2f} | new |"
                )
                continue
            delta = (f_v - b_v) / b_v * 100.0
            mark = " :warning:" if delta > 25.0 else ""
            lines.append(
                f"| {name} | {reqs} | {done} | {metric} | {b_v:.2f} | {f_v:.2f} "
                f"| {delta:+.1f}%{mark} |"
            )
            if delta > 25.0:
                warnings.append(
                    f"serving regression >25% on class {name!r}: "
                    f"{delta:+.1f}% {metric} vs committed baseline"
                )
    return lines, warnings


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    base, fresh = load(sys.argv[1]), load(sys.argv[2])
    kind = ((fresh or {}).get("bench") or (base or {}).get("bench") or "microbench")
    if kind == "serving":
        lines, warnings = diff_serving(base, fresh)
    else:
        lines, warnings = diff_microbench(base, fresh)

    text = "\n".join(lines) + "\n"
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(text)
    print(text)
    for msg in warnings:
        print(f"::warning::{msg}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
