#!/usr/bin/env python3
"""Bench-trajectory diff: compare a fresh BENCH_microbench.json against the
committed baseline and emit a per-kernel ns/unit comparison table.

Usage: bench_diff.py <baseline.json> <fresh.json>

- The markdown table goes to $GITHUB_STEP_SUMMARY when set, else stdout.
- Regressions > 25% ns/unit emit GitHub `::warning::` annotations on
  stdout — warn, never fail (CI perf is noisy; the table is the signal).
- Missing/empty baseline is fine: every row reports as `new` and the fresh
  snapshot becomes the first real baseline once committed.

Rows are keyed on (op, backend) — schema 2 records which executor produced
each row (see README.md §Perf methodology). For rows with a throughput
unit, ns/unit = 1e9 / throughput; otherwise mean iteration time is used.
Stdlib only.
"""

import json
import os
import sys


def load(path):
    try:
        with open(path) as f:
            snap = json.load(f)
    except (OSError, ValueError):
        return None
    # tolerate malformed snapshots (non-dict JSON, results not a list):
    # treat them as absent rather than crashing the CI step
    if not isinstance(snap, dict) or not isinstance(snap.get("results", []), list):
        return None
    return snap


def keyed(snap):
    out = {}
    for r in (snap or {}).get("results", []):
        if isinstance(r, dict):
            out[(r.get("op", "?"), r.get("backend", "?"))] = r
    return out


def ns_per_unit(row):
    tp = row.get("throughput")
    if tp:
        return 1e9 / tp, row.get("throughput_unit", "unit")
    return row.get("mean_s", 0.0) * 1e9, "iter"


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    base, fresh = load(sys.argv[1]), load(sys.argv[2])

    lines = ["## Bench trajectory — microbench (ns per unit, lower is better)", ""]
    warnings = []
    brows = keyed(base)
    fresh_rows = [
        r for r in (fresh or {}).get("results", []) if isinstance(r, dict)
    ]
    if not fresh_rows:
        lines.append("_no fresh BENCH_microbench.json rows — did the smoke bench run?_")
    else:
        # The committed baseline may be the schema-2 empty-rows stub from a
        # toolchain-less authoring environment ({"results": []}): say so up
        # front instead of emitting a table that looks like a comparison.
        if not brows:
            note = (
                "committed stub" if base and base.get("results") == [] else "missing/unreadable"
            )
            lines.append(
                f"_no baseline rows ({note}) — every row below is new; commit this "
                "run's BENCH_microbench.json as the first real baseline_"
            )
            lines.append("")
        lines.append("| op | backend | unit | baseline | fresh | delta |")
        lines.append("|---|---|---|---|---|---|")
        # iterate the raw list (not keyed()) so duplicate (op, backend)
        # rows stay visible instead of last-one-wins vanishing
        for row in fresh_rows:
            key = (row.get("op", "?"), row.get("backend", "?"))
            f_ns, unit = ns_per_unit(row)
            b = brows.get(key)
            if b is None:
                lines.append(f"| {key[0]} | {key[1]} | {unit} | - | {f_ns:.2f} | new |")
                continue
            b_ns, _ = ns_per_unit(b)
            delta = (f_ns - b_ns) / b_ns * 100.0 if b_ns > 0 else 0.0
            mark = " :warning:" if delta > 25.0 else ""
            lines.append(
                f"| {key[0]} | {key[1]} | {unit} | {b_ns:.2f} | {f_ns:.2f} | {delta:+.1f}%{mark} |"
            )
            if delta > 25.0:
                warnings.append((key, delta))

    text = "\n".join(lines) + "\n"
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(text)
    print(text)
    for (op, backend), delta in warnings:
        print(
            f"::warning::microbench regression >25% on {op!r} [{backend}]: "
            f"{delta:+.1f}% ns/unit vs committed baseline"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
