#!/usr/bin/env bash
# Core (no-XLA) gate — exactly what CI's always-on `core` job runs:
# build + full test suite with the default `backend-xla` feature disabled,
# then a smoke microbench on the native executor that refreshes
# BENCH_microbench.json (schema 3, per-row `backend` field plus the
# allocs_per_step counter rows). Run this
# locally to reproduce the enforced CI lane on any machine; no XLA
# toolchain required. (CI's lint steps — clippy, rustfmt, and the
# `RUSTDOCFLAGS="-D warnings" cargo doc` docs gate — live in ci.yml.)
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --no-default-features
cargo test --no-default-features -q

# Smoke perf run: reduced iteration counts, still emits the full JSON.
LATMIX_BENCH_SMOKE=1 cargo bench --no-default-features --bench microbench

test -f BENCH_microbench.json
grep -q '"backend"' BENCH_microbench.json

# Packed-weights serving smoke: same open-loop run on a quantized tag with
# weights kept MX-packed end to end (the fused packed-GEMM hot path). Runs
# BEFORE the fp run so the committed BENCH_serving.json snapshot below
# stays the fp-tag baseline. Asserts conservation and that the packed
# residency actually landed in the report.
cargo run --no-default-features -q -- serve --open-loop --synthetic \
  --quant mxfp4_b32_t3 --packed-weights \
  --requests 48 --arrival-rate 400 --slots 4 --seed 7
python3 - <<'EOF'
import json
snap = json.load(open("BENCH_serving.json"))
assert snap["tag"] == "mxfp4_b32_t3", f"packed smoke ran wrong tag {snap['tag']!r}"
assert snap["lost"] == 0, f"packed smoke lost {snap['lost']} request(s)"
assert snap["resident_weight_bytes"] > 0, "packed run reported no weight residency"
print("packed serving smoke OK:", snap["requests"], "requests, 0 lost,",
      snap["resident_weight_bytes"], "resident weight bytes (MX-packed)")
EOF

# Paged-KV smoke: shared-prefix open-loop run on MXFP8 KV pages with a
# small page size, so prefix sharing, copy-on-write, and quantize-on-write
# all engage. Runs BEFORE the fp run below for the same snapshot-baseline
# reason. Asserts conservation, that prefix pages were actually shared,
# and that the paged residency keys landed in the report.
cargo run --no-default-features -q -- serve --open-loop --synthetic \
  --kv-bits 8 --kv-block 4 --shared-prefix 12 \
  --requests 48 --arrival-rate 400 --slots 4 --seed 7
python3 - <<'EOF'
import json
snap = json.load(open("BENCH_serving.json"))
assert snap["lost"] == 0, f"paged-KV smoke lost {snap['lost']} request(s)"
assert snap["kv_pages_shared"] > 0, "shared-prefix run shared no KV pages"
assert snap["kv_resident_bytes"] > 0, "paged run reported no KV residency"
print("paged-KV smoke OK:", snap["requests"], "requests, 0 lost,",
      snap["kv_pages_shared"], "page(s) prefix-shared,",
      snap["kv_resident_bytes"], "KV bytes resident (mxfp8 pages)")
EOF

# Tensor-parallel serving smoke: the same open-loop run with the executor
# sharded across 2 workers on the persistent pool (the shard parity suite
# guarantees bit-identical tokens vs 1 worker; this leg proves the pool
# substrate survives a full serving run). Runs BEFORE the fp baseline run
# below for the same snapshot-baseline reason. Asserts conservation.
cargo run --no-default-features -q -- serve --open-loop --synthetic \
  --workers 2 \
  --requests 48 --arrival-rate 400 --slots 4 --seed 7
python3 - <<'EOF'
import json
snap = json.load(open("BENCH_serving.json"))
assert snap["lost"] == 0, f"workers=2 smoke lost {snap['lost']} request(s)"
print("workers=2 serving smoke OK:", snap["requests"], "requests, 0 lost")
EOF

# Serving smoke: open-loop continuous-batching run over synthetic
# latmix-tiny weights (no artifact directory needed); refreshes
# BENCH_serving.json (schema 1, per-class SLO rows). The binary itself
# exits non-zero on any lost request; the python check re-asserts
# conservation and that every class row carries the full percentile set.
cargo run --no-default-features -q -- serve --open-loop --synthetic \
  --requests 48 --arrival-rate 400 --slots 4 --seed 7
python3 - <<'EOF'
import json
snap = json.load(open("BENCH_serving.json"))
assert snap["bench"] == "serving" and snap["schema"] == 1, "bad serving schema"
assert snap["lost"] == 0, f"serving smoke lost {snap['lost']} request(s)"
assert snap["requests"] > 0 and snap["classes"], "no serving rows"
keys = {"class", "requests", "completed", "rejected", "timed_out", "cancelled",
        "ttft_p50_ms", "ttft_p90_ms", "ttft_p99_ms",
        "itl_p50_ms", "itl_p90_ms", "itl_p99_ms"}
for c in snap["classes"]:
    missing = keys - c.keys()
    assert not missing, f"class row missing {sorted(missing)}"
print("serving smoke OK:", snap["requests"], "requests over",
      len(snap["classes"]), "classes, 0 lost")
EOF

echo "core OK: no-XLA build + tests passed, BENCH_microbench.json + BENCH_serving.json written"
