#!/usr/bin/env bash
# Core (no-XLA) gate — exactly what CI's always-on `core` job runs:
# build + full test suite with the default `backend-xla` feature disabled,
# then a smoke microbench on the native executor that refreshes
# BENCH_microbench.json (schema 2, per-row `backend` field). Run this
# locally to reproduce the enforced CI lane on any machine; no XLA
# toolchain required. (CI's lint steps — clippy, rustfmt, and the
# `RUSTDOCFLAGS="-D warnings" cargo doc` docs gate — live in ci.yml.)
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --no-default-features
cargo test --no-default-features -q

# Smoke perf run: reduced iteration counts, still emits the full JSON.
LATMIX_BENCH_SMOKE=1 cargo bench --no-default-features --bench microbench

test -f BENCH_microbench.json
grep -q '"backend"' BENCH_microbench.json
echo "core OK: no-XLA build + tests passed, BENCH_microbench.json written"
