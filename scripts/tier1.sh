#!/usr/bin/env bash
# Tier-1 gate: the always-on core lane first (scripts/core.sh — no-XLA
# build + tests + native smoke bench), then the XLA-backed release build,
# full test suite, and the default-features smoke microbench that refreshes
# BENCH_microbench.json. See README.md §Perf methodology.
set -euo pipefail
cd "$(dirname "$0")/.."

# Core lane first: the pure-Rust gate must hold wherever tier-1 runs.
./scripts/core.sh

cargo build --release
cargo test -q

# Smoke perf run: reduced iteration counts, still emits the full JSON
# (overwrites the core lane's snapshot with the default-features run).
LATMIX_BENCH_SMOKE=1 cargo bench --bench microbench

test -f BENCH_microbench.json
echo "tier1 OK: build + tests passed, BENCH_microbench.json written"
