#!/usr/bin/env bash
# Tier-1 gate: release build + full test suite + a smoke microbench run
# that emits the machine-readable perf snapshot (BENCH_microbench.json at
# the repo root). See README.md §Perf methodology.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q

# Smoke perf run: reduced iteration counts, still emits the full JSON.
LATMIX_BENCH_SMOKE=1 cargo bench --bench microbench

test -f BENCH_microbench.json
echo "tier1 OK: build + tests passed, BENCH_microbench.json written"
