//! Microbenchmarks for the perf pass (EXPERIMENTS.md §Perf): MX codec
//! pack/unpack throughput, FWHT, RTN/GPTQ, the Fig. 2 transform-learning
//! step loop (`fig2_learned`), coordinator ops (batcher admit, KV
//! gather/scatter), the native-executor decode step + engine loop, and —
//! on `backend-xla` builds with artifacts — PJRT decode-step latency per
//! compiled batch size.
//!
//! Every timed section lands in two places:
//! - the human-readable markdown table (stdout + `artifacts/results/`);
//! - `BENCH_microbench.json` at the repo root (schema 3 in README.md §Perf
//!   methodology, incl. a per-row `backend` field and timer-free counter
//!   rows such as `allocs_per_step`), the machine-readable perf
//!   trajectory tracked per PR.
//!
//! The `* scalar-ref` rows time the retained reference codec
//! (`latmix::mx::reference`) in the same process, so each JSON snapshot
//! carries its own baseline-vs-optimized comparison. `LATMIX_BENCH_SMOKE=1`
//! shrinks iteration counts for the CI smoke runs (both the no-XLA `core`
//! lane and tier-1).

use latmix::bench::{fmt_time, Bencher, JsonReport, Table};
use latmix::coordinator::engine::{Engine, EngineConfig, MockExecutor, NativeExecutor, StepExecutor};
use latmix::coordinator::{Batcher, GenRequest, KvCache, KvFormat, KvSpec};
use latmix::latmix::{learn_feature_transform, outlier_features, LearnConfig};
use latmix::linalg::{block_hadamard_apply, packed_matmul, packed_matmul_cols, Mat, PackedMat};
use latmix::model::NativeDims;
use latmix::mx::{mx_qdq_rows, pack::PackedMx, page, reference, MxConfig};
use latmix::quant::{gptq_quantize, rtn_quantize};
use latmix::util::{par, Pcg64};

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counting allocator behind the `allocs_per_step` rows (same harness as
/// `rust/tests/alloc_steady_state.rs`): counts every alloc/realloc in the
/// process so a steady-state decode step can be audited for heap traffic.
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() {
    let smoke = std::env::var("LATMIX_BENCH_SMOKE").is_ok();
    let it = |warmup: usize, iters: usize| -> (usize, usize) {
        if smoke {
            (1, iters.min(3))
        } else {
            (warmup, iters)
        }
    };
    let mut tab = Table::new(
        "microbench",
        "Hot-path microbenchmarks (criterion-lite)",
        &["op", "mean", "p99", "throughput"],
    );
    let mut json = JsonReport::new("microbench");
    let mut rng = Pcg64::seed(99);

    let elem_row =
        |tab: &mut Table, json: &mut JsonReport, r: &latmix::bench::BenchResult, n: f64| {
            tab.row(vec![
                r.name.clone(),
                fmt_time(r.mean_s),
                fmt_time(r.p99_s),
                format!("{:.0} Melem/s", r.throughput(n) / 1e6),
            ]);
            json.push(r, Some(("elem/s", n)));
        };

    // MX QDQ (f32 in/out) — the activation-quant inner loop analog.
    // scalar-ref = retained per-element division codec (the pre-PR
    // baseline); the optimized row uses LUT/exponent arithmetic + the pool.
    let n = 1 << 16;
    let x = rng.normal_vec(n, 2.0);
    let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    let (w, i) = it(3, 20);
    let r = Bencher::new("mx_qdq 64K f32 scalar-ref").with_iters(w, i).run(|| {
        let mut y = x.clone();
        reference::mx_qdq_rows_ref(&mut y, 512, &cfg);
        y
    });
    elem_row(&mut tab, &mut json, &r, n as f64);
    let r = Bencher::new("mx_qdq 64K f32").with_iters(w, i).run(|| {
        let mut y = x.clone();
        mx_qdq_rows(&mut y, 512, &cfg);
        y
    });
    elem_row(&mut tab, &mut json, &r, n as f64);

    // bit-pack + unpack: scalar-ref baseline, then the LUT/parallel codec
    let r = Bencher::new("mxfp4 pack 64K scalar-ref")
        .with_iters(w, i)
        .run(|| reference::pack_ref(&x, &cfg));
    elem_row(&mut tab, &mut json, &r, n as f64);
    let r = Bencher::new("mxfp4 pack 64K").with_iters(w, i).run(|| PackedMx::pack(&x, cfg));
    elem_row(&mut tab, &mut json, &r, n as f64);
    let packed = PackedMx::pack(&x, cfg);
    let r = Bencher::new("mxfp4 unpack 64K scalar-ref")
        .with_iters(w, i)
        .run(|| reference::unpack_ref(&cfg, n, &packed.scales, &packed.codes));
    elem_row(&mut tab, &mut json, &r, n as f64);
    let mut out = vec![0.0f32; n];
    let r = Bencher::new("mxfp4 unpack 64K").with_iters(w, i).run(|| packed.unpack_into(&mut out));
    elem_row(&mut tab, &mut json, &r, n as f64);

    // FWHT (online T3 path analog)
    let mut h = rng.normal_vec(1 << 14, 1.0);
    let (w, i) = it(3, 30);
    let r = Bencher::new("fwht 16K (B=32)").with_iters(w, i).run(|| {
        block_hadamard_apply(&mut h, 32);
    });
    elem_row(&mut tab, &mut json, &r, (1 << 14) as f64);

    // RTN / GPTQ weight quant (128x384)
    let (din, dout) = (128usize, 384usize);
    let wq = rng.normal_vec(din * dout, 0.2);
    let (wu, iu) = it(2, 10);
    let r =
        Bencher::new("rtn 128x384").with_iters(wu, iu).run(|| rtn_quantize(&wq, din, dout, &cfg));
    tab.row(vec![r.name.clone(), fmt_time(r.mean_s), fmt_time(r.p99_s),
        format!("{:.0} Melem/s", r.throughput((din * dout) as f64) / 1e6)]);
    json.push(&r, Some(("elem/s", (din * dout) as f64)));
    let hmat = {
        let mut m = Mat::eye(din);
        for i in 0..din {
            for j in 0..din {
                m[(i, j)] += 0.01 * ((i + j) % 7) as f32;
            }
            m[(i, i)] += 10.0;
        }
        m
    };
    let (wu, iu) = it(1, 5);
    let r = Bencher::new("gptq 128x384")
        .with_iters(wu, iu)
        .run(|| gptq_quantize(&wq, din, dout, &hmat, &cfg, 0.01));
    tab.row(vec![r.name.clone(), fmt_time(r.mean_s), fmt_time(r.p99_s), "-".into()]);
    json.push(&r, None);

    // dense matmul micro-kernel (transform-analysis path)
    let mm = Mat::from_vec(192, 192, rng.normal_vec(192 * 192, 1.0));
    let (wu, iu) = it(2, 10);
    let r = Bencher::new("matmul 192x192").with_iters(wu, iu).run(|| mm.matmul(&mm));
    let flops = 2.0 * 192f64 * 192.0 * 192.0;
    tab.row(vec![r.name.clone(), fmt_time(r.mean_s), fmt_time(r.p99_s),
        format!("{:.2} GFLOP/s", r.throughput(flops) / 1e9)]);
    json.push(&r, Some(("flop/s", flops)));

    // fused packed-MX GEMM vs the dense kernel above (same 192x192 shape):
    // decode-only throughput, then the full decode-inside-GEMM row — the
    // serving hot path under --packed-weights
    for fmt in ["mxfp4", "mxint4"] {
        let pcfg = MxConfig::from_name(fmt, Some(32)).unwrap();
        let pw = PackedMat::pack(&mm, pcfg).unwrap();
        let mut dst = vec![0.0f32; 192 * 192];
        let r = Bencher::new(&format!("decode_packed_{fmt}_b32 192x192"))
            .with_iters(wu, iu)
            .run(|| pw.decode_rows(0, 192, &mut dst));
        elem_row(&mut tab, &mut json, &r, (192 * 192) as f64);
        let r = Bencher::new(&format!("packed_gemm 192x192 {fmt}_b32"))
            .with_iters(wu, iu)
            .run(|| packed_matmul(&mm, &pw));
        tab.row(vec![r.name.clone(), fmt_time(r.mean_s), fmt_time(r.p99_s),
            format!("{:.2} GFLOP/s", r.throughput(flops) / 1e9)]);
        json.push(&r, Some(("flop/s", flops)));
    }

    // column-sharded fused packed GEMM: the tensor-parallel shard workers'
    // kernel (`--workers N` splits gate/up and per-head projections into
    // exactly these column slices over `par::run_workers`)
    {
        let pcfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let pw = PackedMat::pack(&mm, pcfg).unwrap();
        let shards = 4usize;
        let per = (192 + shards - 1) / shards;
        let r = Bencher::new("packed_gemm 192x192 mxfp4_b32 sharded w=4")
            .with_iters(wu, iu)
            .run(|| {
                latmix::util::par::run_workers(shards, |s| {
                    let (c0, c1) = (s * per, ((s + 1) * per).min(192));
                    packed_matmul_cols(&mm, &pw, c0, c1)
                })
            });
        tab.row(vec![r.name.clone(), fmt_time(r.mean_s), fmt_time(r.p99_s),
            format!("{:.2} GFLOP/s", r.throughput(flops) / 1e9)]);
        json.push(&r, Some(("flop/s", flops)));
    }

    // Fig. 2 transform learning (latmix::learn_feature_transform): a short
    // run of the E(T) optimizer — matmul + inverse + fake-quant + hand
    // backward per step; throughput in optimizer steps/s.
    let steps = if smoke { 5 } else { 25 };
    let feats = outlier_features(48, 64, 0.05, 7);
    let lcfg = LearnConfig { steps, trace_every: 0, ..Default::default() };
    let fig2_cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    let (wu, iu) = it(1, 5);
    let r = Bencher::new("fig2_learned d=64").with_iters(wu, iu).run(|| {
        learn_feature_transform(&feats, 64, &fig2_cfg, &lcfg).unwrap()
    });
    tab.row(vec![r.name.clone(), fmt_time(r.mean_s), fmt_time(r.p99_s),
        format!("{:.0} step/s", r.throughput(steps as f64))]);
    json.push(&r, Some(("step/s", steps as f64)));

    // batcher admit
    let (wu, iu) = it(3, 20);
    let r = Bencher::new("batcher push+admit 1K").with_iters(wu, iu).run(|| {
        let mut b = Batcher::new(vec![1, 2, 4, 8]);
        for id in 0..1000u64 {
            b.push(GenRequest::new(id, vec![1, 2, 3], 4));
        }
        let mut n = 0;
        while b.pending() > 0 {
            n += b.admit(8).len();
        }
        n
    });
    tab.row(vec![r.name.clone(), fmt_time(r.mean_s), fmt_time(r.p99_s),
        format!("{:.1} Mreq/s", r.throughput(1000.0) / 1e6)]);
    json.push(&r, Some(("req/s", 1000.0)));

    // paged KV gather + decode-step append at serving dims (4 layers, 160
    // seq, 128 row, 16-token pages, b=8): page-table materialization into
    // dense per-lane planes plus one fresh row per plane per lane
    let mut kv = KvCache::new(8, 4, 160, 128);
    let plen = 64usize;
    let plane = 160 * 128;
    for id in 0..8u64 {
        kv.alloc(id).unwrap();
        let planes: Vec<Vec<f32>> = (0..8)
            .map(|_| {
                let mut p = vec![0.0f32; plane];
                p[..plen * 128].copy_from_slice(&rng.normal_vec(plen * 128, 1.0));
                p
            })
            .collect();
        let prompt: Vec<i32> = (0..plen as i32).map(|t| t + id as i32 * 1000).collect();
        kv.write_prefill(id, &prompt, &planes, 0).unwrap();
    }
    let ids: Vec<u64> = (0..8).collect();
    let step_rows: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(8 * 128, 1.0)).collect();
    let r = Bencher::new("kv gather+append b=8").with_iters(wu, iu).run(|| {
        let g = kv.gather_batch(&ids, 8).unwrap();
        kv.append_step(&ids, 8, &step_rows).unwrap();
        g
    });
    let bytes = 8.0 * 8.0 * (160.0 * 128.0 + 128.0) * 4.0; // gather + append
    tab.row(vec![r.name.clone(), fmt_time(r.mean_s), fmt_time(r.p99_s),
        format!("{:.1} GiB/s", r.throughput(bytes) / (1 << 30) as f64)]);
    json.push(&r, Some(("byte/s", bytes)));

    // page codec cost for quantized KV: encode (quantize-on-write) plus
    // decode (gather) over one 16-token page of rows at serving width
    for fmt in ["mxfp8", "mxfp4"] {
        let cfg = KvSpec {
            format: if fmt == "mxfp8" { KvFormat::Mxfp8 } else { KvFormat::Mxfp4 },
            ..KvSpec::default()
        }
        .mx_config(128)
        .unwrap();
        let n = 16 * 128;
        let src = rng.normal_vec(n, 1.0);
        let mut scales = vec![0u8; page::scale_bytes(&cfg, n)];
        let mut codes = vec![0u8; page::code_bytes(&cfg, n)];
        let mut dst = vec![0.0f32; n];
        let r = Bencher::new(&format!("kv_page_qdq_{fmt} 16x128")).with_iters(wu, iu).run(|| {
            page::encode_run(&src, &cfg, &mut scales, &mut codes);
            page::decode_run(&cfg, &scales, &codes, &mut dst);
        });
        elem_row(&mut tab, &mut json, &r, n as f64);
    }

    // mock engine step loop (coordinator overhead without PJRT)
    let (wu, iu) = it(2, 10);
    let r = Bencher::new("mock engine 16reqx8tok").with_iters(wu, iu).run(|| {
        let mut e = Engine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 4, eos: -1, ..Default::default() },
        );
        for i in 0..16u64 {
            e.submit(GenRequest::new(i, vec![1, 2, 3], 8));
        }
        e.run_to_completion().unwrap().len()
    });
    tab.row(vec![r.name.clone(), fmt_time(r.mean_s), fmt_time(r.p99_s),
        format!("{:.0} Ktok/s", r.throughput(128.0) / 1e3)]);
    json.push(&r, Some(("tok/s", 128.0)));

    tab.emit();

    native_decode_bench(&mut json, smoke);
    substrate_bench(&mut json, smoke);
    if !smoke {
        pjrt_decode_bench(&mut json);
    }

    let path = json.emit();
    println!("json -> {}", path.display());
}

/// Native-executor decode-step latency + full engine loop at latmix-tiny
/// dims — runs everywhere, no artifacts or XLA toolchain needed.
fn native_decode_bench(json: &mut JsonReport, smoke: bool) {
    let dims = NativeDims::latmix_tiny();
    let mut tab = Table::new(
        "microbench_native",
        "Native decode-step latency (fp vs quantized spec, synthetic weights)",
        &["graph", "batch", "step mean", "step p99", "tok/s"],
    );
    let iters = if smoke { (1usize, 3usize) } else { (3, 15) };
    for tag in ["fp", "mxfp4_b32_t3"] {
        let exec = NativeExecutor::synthetic(dims, tag, vec![1, 2, 4, 8], 42).unwrap();
        let kvdims = exec.n_layers() * 2;
        for b in [1usize, 4, 8] {
            let plane = exec.kv_seq() * exec.kv_row();
            let kv: Vec<Vec<f32>> = vec![vec![0.0f32; b * plane]; kvdims];
            let tokens = vec![5i32; b];
            let pos = vec![3i32; b];
            let r = Bencher::new(&format!("native decode {tag} b={b}"))
                .with_iters(iters.0, iters.1)
                .run(|| exec.decode(&tokens, &pos, &kv, b).unwrap());
            tab.row(vec![
                tag.into(),
                b.to_string(),
                fmt_time(r.mean_s),
                fmt_time(r.p99_s),
                format!("{:.1}", b as f64 / r.mean_s),
            ]);
            json.push(&r, Some(("tok/s", b as f64)));
        }
    }
    // same quantized tag with MX-packed weights: every linear() now runs
    // the fused packed GEMM (decode on FP4 nibbles) instead of dense f32 —
    // the `--packed-weights` serving hot path
    {
        let exec = NativeExecutor::synthetic(dims, "mxfp4_b32_t3", vec![1, 2, 4, 8], 42)
            .unwrap()
            .into_packed()
            .unwrap();
        let kvdims = exec.n_layers() * 2;
        for b in [1usize, 4, 8] {
            let plane = exec.kv_seq() * exec.kv_row();
            let kv: Vec<Vec<f32>> = vec![vec![0.0f32; b * plane]; kvdims];
            let tokens = vec![5i32; b];
            let pos = vec![3i32; b];
            let r = Bencher::new(&format!("native decode mxfp4_b32_t3+packed b={b}"))
                .with_iters(iters.0, iters.1)
                .run(|| exec.decode(&tokens, &pos, &kv, b).unwrap());
            tab.row(vec![
                "mxfp4+packed".into(),
                b.to_string(),
                fmt_time(r.mean_s),
                fmt_time(r.p99_s),
                format!("{:.1}", b as f64 / r.mean_s),
            ]);
            json.push(&r, Some(("tok/s", b as f64)));
        }
    }
    // tensor-parallel sharded decode at workers=1/2/4: the shard plan is
    // fixed (head partition + d_ff bands), so the logits are bit-identical
    // across rows and the deltas are pure fork-join scaling/overhead;
    // workers=1 runs the segmented kernels serially — the honest baseline
    // for the split (`rust/tests/shard_parity.rs` gates the parity)
    {
        for workers in [1usize, 2, 4] {
            let exec = NativeExecutor::synthetic(dims, "mxfp4_b32_t3", vec![1, 2, 4, 8], 42)
                .unwrap()
                .with_workers(workers)
                .unwrap();
            let kvdims = exec.n_layers() * 2;
            let b = 4usize;
            let plane = exec.kv_seq() * exec.kv_row();
            let kv: Vec<Vec<f32>> = vec![vec![0.0f32; b * plane]; kvdims];
            let tokens = vec![5i32; b];
            let pos = vec![3i32; b];
            let r = Bencher::new(&format!("native decode mxfp4_b32_t3 workers={workers} b={b}"))
                .with_iters(iters.0, iters.1)
                .run(|| exec.decode(&tokens, &pos, &kv, b).unwrap());
            tab.row(vec![
                format!("mxfp4 w={workers}"),
                b.to_string(),
                fmt_time(r.mean_s),
                fmt_time(r.p99_s),
                format!("{:.1}", b as f64 / r.mean_s),
            ]);
            json.push(&r, Some(("tok/s", b as f64)));
        }
    }
    // paged decode step (page-table gather + fused row append) vs the
    // dense rows above: f32 pages replay the dense math bit for bit, so
    // the delta is pure paging overhead; mxfp8 pages add quantize-on-write
    // QDQ to every appended row and LUT decode to every gather
    {
        let exec = NativeExecutor::synthetic(dims, "fp", vec![1, 2, 4, 8], 42).unwrap();
        for (label, spec) in [
            ("paged-f32", KvSpec::default()),
            ("paged-mxfp8", KvSpec { format: KvFormat::Mxfp8, ..KvSpec::default() }),
        ] {
            let b = 4usize;
            let mut kv =
                KvCache::with_spec(b, exec.n_layers(), exec.kv_seq(), exec.kv_row(), spec);
            let plane = exec.kv_seq() * exec.kv_row();
            let mut rng = latmix::util::Pcg64::seed(17);
            let plen = 32usize;
            for id in 0..b as u64 {
                kv.alloc(id).unwrap();
                let planes: Vec<Vec<f32>> = (0..exec.n_layers() * 2)
                    .map(|_| {
                        let mut p = vec![0.0f32; plane];
                        let fill = rng.normal_vec(plen * exec.kv_row(), 0.5);
                        p[..plen * exec.kv_row()].copy_from_slice(&fill);
                        p
                    })
                    .collect();
                let prompt: Vec<i32> = (0..plen as i32).map(|t| t + id as i32 * 100).collect();
                kv.write_prefill(id, &prompt, &planes, 0).unwrap();
            }
            let ids: Vec<u64> = (0..b as u64).collect();
            let tokens = vec![5i32; b];
            let r = Bencher::new(&format!("native decode fp {label} b={b}"))
                .with_iters(iters.0, iters.1)
                .run(|| {
                    let pos: Vec<i32> =
                        ids.iter().map(|id| kv.pos_of(*id).unwrap() as i32).collect();
                    let g = kv.gather_batch(&ids, b).unwrap();
                    let (logits, rows) = exec.decode_append(&tokens, &pos, &g, b).unwrap();
                    kv.append_step(&ids, b, &rows).unwrap();
                    logits
                });
            tab.row(vec![
                format!("fp {label}"),
                b.to_string(),
                fmt_time(r.mean_s),
                fmt_time(r.p99_s),
                format!("{:.1}", b as f64 / r.mean_s),
            ]);
            json.push(&r, Some(("tok/s", b as f64)));
        }
    }

    // transform-spec pipeline at latmix-tiny dims: folding cost (one-time,
    // deploy path) and the per-step overhead of the unfolded reference
    // executor (T1 + per-head T2 + FfnDown applied on the fly) — the
    // gap between these two is the case for `latmix fold`.
    {
        use latmix::linalg::random_orthogonal;
        use latmix::model::NativeWeights;
        use latmix::transform::{Affine, TransformMode, TransformSite, TransformSpec};
        use latmix::util::Pcg64;
        let w = NativeWeights::synthetic(dims, 42);
        let mut rng = Pcg64::seed(7);
        let site = |d: usize, rng: &mut Pcg64| {
            Affine::new(random_orthogonal(d, rng), vec![0.0; d]).unwrap()
        };
        let mut spec = TransformSpec::new();
        spec.insert(TransformSite::Residual, site(dims.d_model, &mut rng));
        spec.insert(
            TransformSite::PerHeadValue { layer: 0, head: 0 },
            site(dims.head_dim(), &mut rng),
        );
        spec.insert(
            TransformSite::PerHeadValue { layer: 1, head: 1 },
            site(dims.head_dim(), &mut rng),
        );
        // d_ff 384 is not a power of two: use a near-identity dense affine
        spec.insert(TransformSite::FfnDown { layer: 0 }, {
            let mut a = latmix::linalg::Mat::eye(dims.d_ff);
            for e in a.data.iter_mut() {
                *e += 0.01 * rng.normal();
            }
            Affine::new(a, vec![0.0; dims.d_ff]).unwrap()
        });
        let r = Bencher::new("spec fold latmix-tiny (4 sites)")
            .with_iters(iters.0, iters.1)
            .run(|| spec.fold_into(&w).unwrap());
        tab.row(vec![
            r.name.clone(),
            "-".into(),
            fmt_time(r.mean_s),
            fmt_time(r.p99_s),
            "-".into(),
        ]);
        json.push(&r, None);
        let exec = NativeExecutor::from_weights_with_spec(
            w,
            spec,
            TransformMode::Unfolded,
            "fp",
            vec![1, 2, 4, 8],
        )
        .unwrap();
        let b = 4usize;
        let plane = exec.kv_seq() * exec.kv_row();
        let kv: Vec<Vec<f32>> = vec![vec![0.0f32; b * plane]; exec.n_layers() * 2];
        let r = Bencher::new("native decode fp+spec-unfolded b=4")
            .with_iters(iters.0, iters.1)
            .run(|| exec.decode(&[5, 6, 7, 8], &[3, 3, 3, 3], &kv, b).unwrap());
        tab.row(vec![
            "fp+spec".into(),
            b.to_string(),
            fmt_time(r.mean_s),
            fmt_time(r.p99_s),
            format!("{:.1}", b as f64 / r.mean_s),
        ]);
        json.push(&r, Some(("tok/s", b as f64)));
    }

    // full continuous-batching loop on the native executor: Batcher +
    // Scheduler + KvCache + prefill/decode, end to end
    let n_req = 8u64;
    let max_new = 4usize;
    let fp_exec = NativeExecutor::synthetic(dims, "fp", vec![1, 2, 4, 8], 42).unwrap();
    let r = Bencher::new("native engine 8reqx4tok")
        .with_iters(iters.0, iters.1)
        .run(|| {
            let mut e = Engine::new(
                fp_exec.clone(),
                EngineConfig { max_slots: 4, eos: -1, ..Default::default() },
            );
            for i in 0..n_req {
                e.submit(GenRequest::new(i, vec![1, 40 + i as i32, 50], max_new));
            }
            e.run_to_completion().unwrap().len()
        });
    let toks = (n_req as usize * max_new) as f64;
    tab.row(vec![
        r.name.clone(),
        "-".into(),
        fmt_time(r.mean_s),
        fmt_time(r.p99_s),
        format!("{:.1}", toks / r.mean_s),
    ]);
    json.push(&r, Some(("tok/s", toks)));
    tab.emit();
}

/// Execution-substrate rows: fork-join dispatch cost on the scoped-thread
/// fallback vs the persistent [`par::WorkerPool`], and the
/// `allocs_per_step` counters behind the zero-allocation steady-state
/// gate (`rust/tests/alloc_steady_state.rs` asserts 0; these rows put the
/// same number in the perf trajectory so `scripts/bench_diff.py` can warn
/// on drift).
fn substrate_bench(json: &mut JsonReport, smoke: bool) {
    let mut tab = Table::new(
        "microbench_substrate",
        "Execution substrate (scoped threads vs persistent pool)",
        &["op", "mean", "p99", "value"],
    );
    let (warmup, iters) = if smoke { (1usize, 3usize) } else { (5, 200) };

    // Fork-join overhead: one for_each_chunk dispatch over a tiny buffer
    // (64 chunks of trivial work), so the row times the barrier itself —
    // thread spawn + join on the scoped path, park/unpark on the pool.
    let mut buf = vec![0.0f32; 64 * 64];
    let pool = par::WorkerPool::new();
    for w in [1usize, 4] {
        for substrate in ["scoped", "pool"] {
            let name = format!("fork_join_overhead {substrate} w={w}");
            let r = Bencher::new(&name).with_iters(warmup, iters).run(|| {
                let buf = &mut buf;
                let body = || {
                    par::with_threads(w, || {
                        par::for_each_chunk(buf, 64, |ci, chunk| {
                            chunk[0] = ci as f32;
                        });
                    })
                };
                if substrate == "pool" {
                    pool.install(body)
                } else {
                    body()
                }
            });
            tab.row(vec![
                r.name.clone(),
                fmt_time(r.mean_s),
                fmt_time(r.p99_s),
                "-".into(),
            ]);
            json.push(&r, Some(("dispatch/s", 1.0)));
        }
    }
    drop(pool);

    // allocs_per_step: minimum allocation delta over a few steady-state
    // decode steps on a warm serving engine (min over steps excludes the
    // legitimate page-boundary KV-arena growth; see the gate test's
    // methodology notes). 0 is the healthy value.
    let dims = NativeDims::latmix_tiny();
    let fp = NativeExecutor::synthetic(dims, "fp", vec![1, 2, 4, 8], 42).unwrap();
    let packed = NativeExecutor::synthetic(dims, "mxfp4_b32_t3", vec![1, 2, 4, 8], 42)
        .unwrap()
        .into_packed()
        .unwrap();
    let mxfp8_kv = KvSpec { format: KvFormat::Mxfp8, ..KvSpec::default() };
    let variants: Vec<(&str, &NativeExecutor, KvSpec)> = vec![
        ("fp", &fp, KvSpec::default()),
        ("packed", &packed, KvSpec::default()),
        ("paged-mxfp8", &fp, mxfp8_kv),
    ];
    for (label, exec, kv) in variants {
        for w in [1usize, 4] {
            let min = par::with_threads(w, || {
                let mut e = Engine::new(
                    exec.clone(),
                    EngineConfig { max_slots: 4, eos: -1, kv, ..Default::default() },
                );
                for id in 0..4u64 {
                    let prompt: Vec<i32> = (0..12).map(|t| t + id as i32 * 100).collect();
                    e.submit(GenRequest::new(id, prompt, 64));
                }
                // step 1 admits + prefills; two more converge the arenas
                for _ in 0..3 {
                    e.step().unwrap();
                }
                let mut min = u64::MAX;
                for _ in 0..5 {
                    let before = ALLOC_COUNT.load(Ordering::Relaxed);
                    e.step().unwrap();
                    min = min.min(ALLOC_COUNT.load(Ordering::Relaxed) - before);
                }
                min
            });
            let name = format!("allocs_per_step native decode {label} w={w}");
            tab.row(vec![
                name.clone(),
                "-".into(),
                "-".into(),
                format!("{min} alloc/step"),
            ]);
            json.push_value(&name, min as f64, "alloc/step");
        }
    }
    tab.emit();
}

/// No PJRT on this build: the core lane carries native rows only.
#[cfg(not(feature = "backend-xla"))]
fn pjrt_decode_bench(_json: &mut JsonReport) {}

/// PJRT decode-step latency per batch size (needs artifacts).
#[cfg(feature = "backend-xla")]
fn pjrt_decode_bench(json: &mut JsonReport) {
    use latmix::coordinator::engine::XlaExecutor;
    use latmix::model::{ModelDesc, WeightSet};
    use latmix::runtime::Runtime;

    let art = latmix::artifacts_dir();
    let Ok(desc) = ModelDesc::load(&art) else { return };
    let Ok(rt) = Runtime::new(desc) else { return };
    let Ok(ws) = WeightSet::load(&rt.desc, "fp_raw") else { return };
    let mut tab = Table::new(
        "microbench_pjrt",
        "PJRT decode-step latency (fp vs quantized graph)",
        &["graph", "batch", "step mean", "step p99", "tok/s"],
    );
    for tag in ["fp", "mxfp4_b32_t3"] {
        let Ok(exec) = XlaExecutor::new(&rt, tag, &ws) else { continue };
        let kvdims = exec.n_layers() * 2;
        for b in [1usize, 4, 8] {
            let plane = exec.kv_seq() * exec.kv_row();
            let kv: Vec<Vec<f32>> = vec![vec![0.0f32; b * plane]; kvdims];
            let tokens = vec![5i32; b];
            let pos = vec![3i32; b];
            let r = Bencher::new(&format!("pjrt decode {tag} b={b}")).with_iters(3, 15).run(|| {
                exec.decode(&tokens, &pos, &kv, b).unwrap()
            });
            tab.row(vec![
                tag.into(),
                b.to_string(),
                fmt_time(r.mean_s),
                fmt_time(r.p99_s),
                format!("{:.1}", b as f64 / r.mean_s),
            ]);
            json.push_for(&r, Some(("tok/s", b as f64)), "xla");
        }
    }
    tab.emit();
}
