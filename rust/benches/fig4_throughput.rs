//! Fig. 4 — serving throughput (tokens/s) vs batch size for BF16(f32),
//! MR-GPTQ, Learned-Inv (LATMiX without bias) and LATMiX.
//!
//! The paper's claim: because LATMiX transforms fold into the weights, all
//! MX-quantized methods share the decode graph and their throughput is
//! indistinguishable ("at most negligible inference overhead"). Here that is
//! true *by construction* — the bench demonstrates it and quantifies the
//! quantized-graph (QDQ ops + online T3) overhead vs the f32 graph.

use latmix::bench::Table;
use latmix::model::ModelDesc;

/// Backend shim: PJRT on `backend-xla` builds, the pure-Rust executor
/// otherwise — the sweep body is identical either way.
#[cfg(feature = "backend-xla")]
mod srv {
    use latmix::model::ModelDesc;
    use latmix::runtime::Runtime;
    use latmix::server::{run_serving, ServeOptions, ServeReport};

    pub const LABEL: &str = "xla";

    pub struct Srv(Runtime);

    impl Srv {
        pub fn new(desc: ModelDesc) -> Srv {
            Srv(Runtime::new(desc).unwrap())
        }

        pub fn run(
            &self, g: &str, w: &str, n: usize, m: usize, s: usize, seed: u64,
        ) -> anyhow::Result<ServeReport> {
            let opts =
                ServeOptions::default().tags(g, w).requests(n).max_new(m).slots(s).seed(seed);
            run_serving(&self.0, &opts)
        }
    }
}

#[cfg(not(feature = "backend-xla"))]
mod srv {
    use latmix::model::ModelDesc;
    use latmix::server::{run_serving_native, ServeOptions, ServeReport};

    pub const LABEL: &str = "native";

    pub struct Srv(ModelDesc);

    impl Srv {
        pub fn new(desc: ModelDesc) -> Srv {
            Srv(desc)
        }

        pub fn run(
            &self, g: &str, w: &str, n: usize, m: usize, s: usize, seed: u64,
        ) -> anyhow::Result<ServeReport> {
            let opts =
                ServeOptions::default().tags(g, w).requests(n).max_new(m).slots(s).seed(seed);
            run_serving_native(&self.0, &opts)
        }
    }
}

fn main() {
    let art = latmix::artifacts_dir();
    let desc = match ModelDesc::load(&art) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("fig4: no artifacts ({e})");
            return;
        }
    };
    println!("fig4: serving backend = {}", srv::LABEL);
    let rt = srv::Srv::new(desc);
    // (display, graph tag, weights tag)
    let q = "mxfp4_b32_t3";
    let methods: Vec<(&str, &str, String)> = vec![
        ("FP (f32 graph)", "fp", "fp_raw".into()),
        ("MR-GPTQ", q, "mr-gptq_mxfp4_b32".into()),
        ("Learned Inv (no bias)", q, "t2_inv_full_mxfp4_b32".into()),
        ("LATMiX-LU", q, "latmix-lu_mxfp4_b32".into()),
    ];
    let slots = [1usize, 2, 4, 8];
    let mut tab = Table::new(
        "fig4_throughput",
        "Decode throughput (tok/s) vs batch size (paper Fig. 4)",
        &["method", "b=1", "b=2", "b=4", "b=8"],
    );
    let requests = 12;
    let max_new = 24;
    // Warm the executable cache: compilation must not land on whichever
    // method happens to touch a graph first.
    for (_, gtag, wtag) in &methods {
        for s in slots {
            // enough requests that every (prefill, decode) bucket compiles
            let _ = rt.run(gtag, wtag, s, 2, s, 1);
        }
    }
    for (name, gtag, wtag) in &methods {
        let mut cells = vec![name.to_string()];
        for s in slots {
            match rt.run(gtag, wtag, requests, max_new, s, 42) {
                Ok(rep) => cells.push(format!("{:.1}", rep.core.decode_tok_per_s)),
                Err(e) => {
                    eprintln!("  {name} b={s}: {e}");
                    cells.push("-".into());
                }
            }
        }
        tab.row(cells);
    }
    tab.emit();

    // latency detail at b=4
    let mut lat = Table::new(
        "fig4_latency",
        "Latency detail at 4 slots",
        &["method", "ttft p50 ms", "ttft p99 ms", "req latency p50 ms", "p99 ms"],
    );
    for (name, gtag, wtag) in &methods {
        if let Ok(rep) = rt.run(gtag, wtag, requests, max_new, 4, 43) {
            lat.row(vec![
                name.to_string(),
                format!("{:.1}", rep.ttft_p50_ms),
                format!("{:.1}", rep.ttft_p99_ms),
                format!("{:.1}", rep.latency_p50_ms),
                format!("{:.1}", rep.latency_p99_ms),
            ]);
        }
    }
    lat.emit();
}
