//! Fig. 2 — transformation analysis on captured residual-stream features.
//!
//! 2a: transformation MSE E(T) vs MX block size for {vanilla, Hadamard,
//!     block-Hadamard, learned rotation, learned affine} (+ the Theorem 3.3
//!     bound surrogate for each).
//! 2b: WikiText2→SynthText perplexity vs block size for the corresponding
//!     end-to-end quantized models (evaluated on the PJRT runtime).
//! 2c: per-MX-block error profile at B = 32.
//!
//! Shape targets (paper): learned affine lowest E(T) at every B; block-
//! Hadamard beats full Hadamard at small B; 2c: full rotation flattens but
//! raises most blocks, block-H lowers dominant blocks only, learned affine
//! lowers all blocks.

use latmix::bench::Table;
use latmix::io::load_lxt;
use latmix::linalg::{block_diag, hadamard, Mat};
use latmix::mx::MxConfig;
use latmix::transform::bound::theorem_bound;
use latmix::transform::{per_block_error, transformation_mse, Affine};
use latmix::util::Pcg64;

fn load_features() -> Option<(Vec<f32>, usize)> {
    let p = latmix::artifacts_dir().join("features").join("resid_calib.lxt");
    let map = load_lxt(&p).ok()?;
    let t = map.get("features")?;
    Some((t.as_f32().ok()?.to_vec(), t.dims[1]))
}

fn learned_transform(b: usize, which: &str, d: usize) -> Option<Affine> {
    let p = latmix::artifacts_dir()
        .join("transforms")
        .join(format!("fig2_learned_b{b}.lxt"));
    // new-style: a TransformSpec written by `latmix learn --save-spec`
    // (its Residual site is the learned affine)
    if which == "aff" {
        if let Ok(spec) = latmix::transform::TransformSpec::load(&p) {
            if let Some(t) = spec.residual() {
                if t.dim() == d {
                    return Some(t.clone());
                }
            }
        }
    }
    // legacy python export: flat `{which}_a` / `{which}_v` tensors
    let map = load_lxt(&p).ok()?;
    let a = map.get(&format!("{which}_a"))?.as_f32().ok()?.to_vec();
    let v = map.get(&format!("{which}_v"))?.as_f32().ok()?.to_vec();
    Affine::new(Mat::from_vec(d, d, a), v).ok()
}

fn block_hadamard_mat(d: usize, b: usize) -> Mat {
    let h = hadamard(b);
    block_diag(&vec![h; d / b])
}

fn main() {
    let Some((feats, d)) = load_features() else {
        eprintln!("fig2: artifacts/features missing — run `make artifacts experiments`");
        return;
    };
    let mut rng = Pcg64::seed(7);
    let full_h = Affine::new(hadamard(d), vec![0.0; d]).unwrap();
    let rand_rot =
        Affine::new(latmix::linalg::random_orthogonal(d, &mut rng), vec![0.0; d]).unwrap();
    let identity = Affine::identity(d);

    // ---- Fig. 2a: E(T) vs block size ------------------------------------
    let mut t2a = Table::new(
        "fig2a_mse",
        "Transformation MSE E(T) vs MX block size (MXFP4, captured features)",
        &["transform", "B=8", "B=16", "B=32", "B=64", "B=128"],
    );
    let blocks = [8usize, 16, 32, 64, 128];
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for (name, make) in [
        ("vanilla", None::<usize>),
        ("hadamard (full)", None),
        ("random rotation", None),
        ("block hadamard", Some(0)),
        ("learned rotation", Some(1)),
        ("learned affine (LATMiX)", Some(2)),
    ] {
        let mut vals = Vec::new();
        for &b in &blocks {
            let cfg = MxConfig::from_name("mxfp4", Some(b)).unwrap();
            let t = match (name, make) {
                ("vanilla", _) => identity.clone(),
                ("hadamard (full)", _) => full_h.clone(),
                ("random rotation", _) => rand_rot.clone(),
                ("block hadamard", _) => {
                    Affine::new(block_hadamard_mat(d, b.min(d)), vec![0.0; d]).unwrap()
                }
                ("learned rotation", _) => match learned_transform(b, "rot", d) {
                    Some(t) => t,
                    None => continue,
                },
                _ => match learned_transform(b, "aff", d) {
                    Some(t) => t,
                    None => continue,
                },
            };
            vals.push(transformation_mse(&feats, d, &t, &cfg));
        }
        rows.push((name.to_string(), vals));
    }
    for (name, vals) in &rows {
        let mut cells = vec![name.clone()];
        cells.extend(vals.iter().map(|v| format!("{v:.5}")));
        while cells.len() < 6 {
            cells.push("-".into());
        }
        t2a.row(cells);
    }
    t2a.emit();

    // ---- Theorem 3.3 bound surrogate at B=32 ----------------------------
    let mut tb = Table::new(
        "fig2_bound",
        "Theorem 3.3 factors at B=32: ||A^-1||^2_sigma * mean_i M_i (surrogate)",
        &["transform", "bound surrogate", "empirical E(T)"],
    );
    let cfg32 = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    for (name, t) in [
        ("vanilla", identity.clone()),
        ("hadamard (full)", full_h.clone()),
        ("block hadamard", Affine::new(block_hadamard_mat(d, 32), vec![0.0; d]).unwrap()),
    ]
    .into_iter()
    .chain(learned_transform(32, "rot", d).map(|t| ("learned rotation", t)))
    .chain(learned_transform(32, "aff", d).map(|t| ("learned affine (LATMiX)", t)))
    {
        tb.row(vec![
            name.to_string(),
            format!("{:.4}", theorem_bound(&feats, d, &t, 32)),
            format!("{:.5}", transformation_mse(&feats, d, &t, &cfg32)),
        ]);
    }
    tb.emit();

    // ---- Fig. 2c: per-block error profile at B=32 ------------------------
    let mut t2c = Table::new(
        "fig2c_blockerr",
        "Per-MX-block quantization error (B=32)",
        &["transform", "blocks (low->high index)"],
    );
    for (name, t) in [
        ("vanilla", identity.clone()),
        ("hadamard (full)", full_h.clone()),
        ("block hadamard", Affine::new(block_hadamard_mat(d, 32), vec![0.0; d]).unwrap()),
    ]
    .into_iter()
    .chain(learned_transform(32, "aff", d).map(|t| ("learned affine (LATMiX)", t)))
    {
        let errs = per_block_error(&feats, d, &t, &cfg32);
        let cells = errs.iter().map(|e| format!("{e:.5}")).collect::<Vec<_>>().join("  ");
        t2c.row(vec![name.to_string(), cells]);
    }
    t2c.emit();

    // ---- Fig. 2b: perplexity vs block size (runtime eval) ----------------
    fig2b();
}

fn fig2b() {
    use latmix::data::load_ppl_corpus;
    use latmix::eval::perplexity;
    use latmix::model::{ModelDesc, WeightSet};
    use latmix::runtime::{default_backend, Backend};

    let art = latmix::artifacts_dir();
    let Ok(desc) = ModelDesc::load(&art) else {
        eprintln!("fig2b: no manifest; skipping ppl-vs-B");
        return;
    };
    let Ok(rt) = default_backend(desc) else { return };
    println!("fig2b: eval backend = {}", rt.id());
    let Ok((corpus, n, t)) = load_ppl_corpus(&art) else { return };
    let mut tab = Table::new(
        "fig2b_ppl",
        "Perplexity vs MX block size (MXFP4 weights+activations)",
        &["method", "B=8", "B=16", "B=32", "B=64"],
    );
    for (method, t3) in [
        ("gptq", false),
        ("quarot", true),
        ("mr-gptq", true),
        ("latmix-lu", true),
    ] {
        let mut cells = vec![method.to_string()];
        for b in [8usize, 16, 32, 64] {
            let wtag = format!("{method}_mxfp4_b{b}");
            let gtag = format!("mxfp4_b{b}{}", if t3 { "_t3" } else { "" });
            let cell = match WeightSet::load(rt.desc(), &wtag) {
                Ok(ws) => match perplexity(&rt, &gtag, &ws, &corpus, n, t) {
                    Ok(p) => format!("{p:.2}"),
                    Err(e) => format!("err:{e}"),
                },
                Err(_) => "-".into(),
            };
            cells.push(cell);
        }
        tab.row(cells);
    }
    tab.emit();
}
