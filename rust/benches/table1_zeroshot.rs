//! Table 1 (+ per-task Tables 16-23, FlatQuant Table 4, NVFP Table 15):
//! zero-shot average accuracy and recovery for every method under MXFP4,
//! MXINT4 and NVFP4, evaluated on the PJRT runtime with the AOT graphs.
//!
//! Shape targets: LATMiX-LU/QR best or tied-best recovery; QuaRot-RTN can
//! fall below plain RTN; GPTQ > RTN; learned methods > fixed rotations.

use latmix::bench::Table;
use latmix::data::load_tasks;
use latmix::eval::{recovery, zero_shot};
use latmix::model::{ModelDesc, WeightSet};
use latmix::runtime::{default_backend, Backend, DefaultBackend};

/// (display name, weights tag prefix, uses online T3)
const METHODS: &[(&str, &str, bool)] = &[
    ("RTN", "rtn", false),
    ("QuaRot-RTN", "quarot-rtn", true),
    ("GPTQ", "gptq", false),
    ("QuaRot", "quarot", true),
    ("SpinQuant", "spinquant", true),
    ("OSTQuant", "ostquant", true),
    ("FlatQuant†", "flatquant", true),
    ("MR-GPTQ", "mr-gptq", true),
    ("LATMiX-LU (Ours)", "latmix-lu", true),
    ("LATMiX-QR (Ours)", "latmix-qr", true),
];

const NVFP_METHODS: &[&str] = &[
    "rtn", "gptq", "spinquant", "flatquant", "mr-gptq", "latmix-lu", "latmix-qr",
];

fn main() {
    let per_task = std::env::args().any(|a| a == "--per-task");
    let art = latmix::artifacts_dir();
    let desc = match ModelDesc::load(&art) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("table1: no artifacts ({e}); run `make artifacts experiments`");
            return;
        }
    };
    let rt = default_backend(desc).unwrap();
    println!("table1: eval backend = {}", rt.id());
    let tasks = load_tasks(&art).unwrap();

    // FP16 reference
    let fp_ws = WeightSet::load(rt.desc(), "fp_raw").expect("fp_raw weights");
    let fp_accs = zero_shot(&rt, "fp", &fp_ws, &tasks).unwrap();
    let fp_avg = fp_accs.last().unwrap().1;

    for (fmt, block, title) in [
        ("mxfp4", 32usize, "MXFP4"),
        ("mxint4", 32, "MXINT4"),
    ] {
        let mut tab = Table::new(
            &format!("table1_{fmt}"),
            &format!("Zero-shot accuracy / recovery, {title} (paper Table 1)"),
            &["method", "avg acc %", "recovery %"],
        );
        tab.row(vec!["FP16".into(), format!("{:.2}", fp_avg * 100.0), "100.00".into()]);
        for (name, wtag_prefix, t3) in METHODS {
            let wtag = format!("{wtag_prefix}_{fmt}_b{block}");
            let gtag = format!("{fmt}_b{block}{}", if *t3 { "_t3" } else { "" });
            match eval_variant(&rt, &wtag, &gtag, &tasks) {
                Some(accs) => {
                    let avg = accs.last().unwrap().1;
                    tab.row(vec![
                        name.to_string(),
                        format!("{:.2}", avg * 100.0),
                        format!("{:.2}", recovery(avg, fp_avg)),
                    ]);
                    if per_task {
                        emit_per_task(fmt, name, &accs, fp_avg);
                    }
                }
                None => tab.row(vec![name.to_string(), "-".into(), "-".into()]),
            }
        }
        tab.emit();
    }

    // ---- Table 15: NVFP4 --------------------------------------------------
    let mut tab = Table::new(
        "table15_nvfp",
        "Zero-shot accuracy / recovery, NVFP4 (paper Table 15)",
        &["method", "avg acc %", "recovery %"],
    );
    tab.row(vec!["FP16".into(), format!("{:.2}", fp_avg * 100.0), "100.00".into()]);
    for m in NVFP_METHODS {
        let t3 = !matches!(*m, "rtn" | "gptq");
        let wtag = format!("{m}_nvfp4_b16");
        let gtag = format!("nvfp4_b16{}", if t3 { "_t3" } else { "" });
        match eval_variant(&rt, &wtag, &gtag, &tasks) {
            Some(accs) => {
                let avg = accs.last().unwrap().1;
                tab.row(vec![
                    m.to_string(),
                    format!("{:.2}", avg * 100.0),
                    format!("{:.2}", recovery(avg, fp_avg)),
                ]);
            }
            None => tab.row(vec![m.to_string(), "-".into(), "-".into()]),
        }
    }
    tab.emit();
    println!("note: Table 4 (FlatQuant comparison) = FlatQuant† vs LATMiX rows above;");
    println!("per-benchmark Tables 16-23: rerun with --per-task");
}

fn eval_variant(
    rt: &DefaultBackend,
    wtag: &str,
    gtag: &str,
    tasks: &[latmix::data::TaskSet],
) -> Option<Vec<(String, f64)>> {
    let ws = WeightSet::load(rt.desc(), wtag).ok()?;
    match zero_shot(rt, gtag, &ws, tasks) {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("  {wtag} @ {gtag}: {e}");
            None
        }
    }
}

fn emit_per_task(fmt: &str, method: &str, accs: &[(String, f64)], fp_avg: f64) {
    let mut t = Table::new(
        &format!("table16_{fmt}_{}", method.replace([' ', '(', ')', '†'], "")),
        &format!("Per-task breakdown — {method} / {fmt}"),
        &["task", "acc %"],
    );
    for (name, a) in accs {
        t.row(vec![name.clone(), format!("{:.2}", a * 100.0)]);
    }
    t.row(vec!["recovery %".into(), format!("{:.2}", recovery(accs.last().unwrap().1, fp_avg))]);
    t.emit();
}
