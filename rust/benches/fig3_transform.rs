//! Figs. 3a/3b + Fig. 6 — learned-transformation trajectory during LATMiX
//! training: orthogonality deviation ||AᵀA − I||σ, off-block-diagonal
//! spectral norm, and condition number, per optimization step.
//!
//! The series come from the training trace the build path records
//! (`artifacts/traces/latmix-lu_mxfp4_b32.csv`); this bench re-derives the
//! same metrics *independently in Rust* from the saved final transform to
//! cross-check the trace, then prints the full series.
//!
//! Shape targets: orth-dev rises early then plateaus (3a); off-block norm
//! grows from ~0 — cross-block energy transfer emerges (3b); condition
//! number stays small (Fig. 6).

use latmix::bench::Table;
use latmix::io::load_lxt;
use latmix::linalg::Mat;

fn main() {
    let art = latmix::artifacts_dir();
    let trace_path = art.join("traces").join("latmix-lu_mxfp4_b32.csv");
    let Ok(text) = std::fs::read_to_string(&trace_path) else {
        eprintln!("fig3: {trace_path:?} missing — run `make experiments`");
        return;
    };
    let mut tab = Table::new(
        "fig3_fig6_transform",
        "Learned A1 trajectory (paper Figs. 3a, 3b, 6)",
        &["step", "loss", "orth dev (3a)", "off-block norm (3b)", "cond (Fig 6)"],
    );
    for line in text.lines().skip(1) {
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() == 5 {
            tab.row(cells.iter().map(|s| s.to_string()).collect());
        }
    }
    tab.emit();

    // Independent cross-check of the final point from the saved transform.
    let tpath = art.join("transforms").join("latmix-lu_mxfp4_b32.lxt");
    if let Ok(map) = load_lxt(&tpath) {
        if let Some(t) = map.get("a1") {
            let d = t.dims[0];
            let a = Mat::from_vec(d, d, t.as_f32().unwrap().to_vec());
            let orth_dev = {
                let mut ata = a.t().matmul(&a);
                for i in 0..d {
                    ata[(i, i)] -= 1.0;
                }
                ata.spectral_norm()
            };
            let off = a.off_block_diagonal(32).spectral_norm();
            let cond = a.condition();
            let mut check = Table::new(
                "fig3_crosscheck",
                "Rust recomputation of the final-step metrics (vs last trace row)",
                &["orth dev", "off-block norm", "cond"],
            );
            check.row(vec![
                format!("{orth_dev:.3}"),
                format!("{off:.3}"),
                format!("{cond:.2}"),
            ]);
            check.emit();
        }
    }
}
