//! Perplexity-based tables: 2 (granularity ablation), 3 (computational
//! invariance), 5/8 (loss ablation), 6 (method comparison), 7 (init),
//! 9 (calibration size), 10 (calibration seeds), 11 (training steps),
//! 12 (lambda), 13 (temperature), 14 (drop-one-transform).
//!
//! All rows evaluate precomputed weight variants (python build path) on the
//! build's default execution backend — PJRT with `backend-xla`, the
//! pure-Rust interpreter otherwise. Zero-shot averages are added where the
//! paper reports them; pass --ppl-only to skip them (faster).

use latmix::bench::Table;
use latmix::data::{load_ppl_corpus, load_tasks, TaskSet};
use latmix::eval::{perplexity, zero_shot};
use latmix::model::{ModelDesc, WeightSet};
use latmix::runtime::{default_backend, Backend, DefaultBackend};

struct Ctx {
    rt: DefaultBackend,
    corpus: Vec<i32>,
    n: usize,
    t: usize,
    tasks: Vec<TaskSet>,
    with_acc: bool,
}

impl Ctx {
    fn ppl(&self, wtag: &str, gtag: &str) -> Option<f64> {
        let ws = WeightSet::load(self.rt.desc(), wtag).ok()?;
        match perplexity(&self.rt, gtag, &ws, &self.corpus, self.n, self.t) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("  {wtag} @ {gtag}: {e}");
                None
            }
        }
    }

    fn acc(&self, wtag: &str, gtag: &str) -> Option<f64> {
        if !self.with_acc {
            return None;
        }
        let gtag = gtag.replace("logits_ppl_", "");
        let ws = WeightSet::load(self.rt.desc(), wtag).ok()?;
        zero_shot(&self.rt, &gtag, &ws, &self.tasks)
            .ok()
            .map(|a| a.last().unwrap().1)
    }

    fn row(&self, tab: &mut Table, label: &str, wtag: &str, gtag: &str, acc: bool) {
        let p = self.ppl(wtag, gtag);
        let mut cells = vec![
            label.to_string(),
            p.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
        ];
        if acc {
            cells.push(
                self.acc(wtag, gtag)
                    .map(|a| format!("{:.2}", a * 100.0))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        tab.row(cells);
    }
}

const Q: &str = "mxfp4_b32";

fn main() {
    let ppl_only = std::env::args().any(|a| a == "--ppl-only");
    let art = latmix::artifacts_dir();
    let desc = match ModelDesc::load(&art) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("ppl_tables: no artifacts ({e})");
            return;
        }
    };
    let rt = default_backend(desc).unwrap();
    println!("ppl_tables: eval backend = {}", rt.id());
    let (corpus, n, t) = load_ppl_corpus(&art).unwrap();
    let tasks = load_tasks(&art).unwrap();
    let ctx = Ctx { rt, corpus, n, t, tasks, with_acc: !ppl_only };

    table6(&ctx);
    table2(&ctx);
    table3(&ctx);
    table8(&ctx);
    table7(&ctx);
    table9(&ctx);
    table10(&ctx);
    table11(&ctx);
    table12(&ctx);
    table13(&ctx);
    table14(&ctx);
}

fn table6(ctx: &Ctx) {
    let mut tab =
        Table::new("table6_ppl", "Perplexity, MXFP4 W+A (paper Table 6)", &["method", "ppl"]);
    ctx.row(&mut tab, "FP16", "fp_raw", "fp", false);
    for (name, wtag, t3) in [
        ("RTN", "rtn", false),
        ("QuaRot-RTN", "quarot-rtn", true),
        ("GPTQ", "gptq", false),
        ("QuaRot", "quarot", true),
        ("SpinQuant", "spinquant", true),
        ("OSTQuant", "ostquant", true),
        ("FlatQuant†", "flatquant", true),
        ("BRQ (block rotation)", "brq", true),
        ("MR-GPTQ", "mr-gptq", true),
        ("LATMiX-LU (Ours)", "latmix-lu", true),
        ("LATMiX-QR (Ours)", "latmix-qr", true),
    ] {
        let gtag = format!("{Q}{}", if t3 { "_t3" } else { "" });
        ctx.row(&mut tab, name, &format!("{wtag}_{Q}"), &gtag, false);
    }
    tab.emit();
}

fn table2(ctx: &Ctx) {
    let mut tab = Table::new(
        "table2_granularity",
        "Transformation x granularity ablation, MXFP4 ppl (paper Table 2)",
        &["transform", "granularity", "ppl"],
    );
    let rows: Vec<(&str, &str, String, bool)> = vec![
        ("None", "-", format!("gptq_{Q}"), false),
        ("Random Hadamard", "Block", format!("mr-gptq_{Q}"), true),
        ("Random Hadamard", "Full", format!("quarot_{Q}"), true),
        ("Learned Orth.", "Block", format!("t2_orth_block_{Q}"), true),
        ("Learned Orth.", "Full", format!("t2_orth_full_{Q}"), true),
        ("Learned Orth. + bias", "Block", format!("t2_orthbias_block_{Q}"), true),
        ("Learned Orth. + bias", "Full", format!("t2_orthbias_full_{Q}"), true),
        ("Learned Inv.", "Block", format!("t2_inv_block_{Q}"), true),
        ("Learned Inv.", "Full", format!("t2_inv_full_{Q}"), true),
        ("LATMiX-LU", "Block", format!("t2_latmix_block_{Q}"), true),
        ("LATMiX-LU", "Full", format!("latmix-lu_{Q}"), true),
    ];
    for (tr, gran, wtag, t3) in rows {
        let gtag = format!("{Q}{}", if t3 { "_t3" } else { "" });
        let p = ctx.ppl(&wtag, &gtag);
        tab.row(vec![
            tr.into(),
            gran.into(),
            p.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    tab.emit();
}

fn table3(ctx: &Ctx) {
    let mut tab = Table::new(
        "table3_invariance",
        "FP perplexity after fusing learned T1/T2, no quantization (paper Table 3)",
        &["training steps", "ppl"],
    );
    ctx.row(&mut tab, "FP16 (no transform)", "fp_raw", "fp", false);
    for s in [0usize, 1, 30, 60, 120] {
        ctx.row(&mut tab, &format!("{s}"), &format!("fp_fused_step{s}"), "fp", false);
    }
    tab.emit();
}

fn table8(ctx: &Ctx) {
    let mut tab = Table::new(
        "table8_loss",
        "Loss-function ablation (paper Tables 5+8): ppl + 0-shot avg",
        &["loss", "ppl", "avg acc %"],
    );
    let gtag = format!("{Q}_t3");
    ctx.row(&mut tab, "MSE (per-block, FlatQuant-style)", &format!("t8_mse_{Q}"), &gtag, true);
    ctx.row(&mut tab, "CE (SpinQuant-style)", &format!("t8_ce_{Q}"), &gtag, true);
    ctx.row(&mut tab, "KL (LATMiX)", &format!("latmix-lu_{Q}"), &gtag, true);
    tab.emit();
}

fn table7(ctx: &Ctx) {
    let mut tab = Table::new(
        "table7_init",
        "Initialization ablation, ppl (paper Table 7)",
        &["init", "LU", "QR"],
    );
    let gtag = format!("{Q}_t3");
    for init in [
        "identity",
        "orthogonal",
        "bd_orthogonal_noise",
        "hadamard",
        "bd_hadamard",
        "bd_hadamard_noise",
    ] {
        let lu = ctx.ppl(&format!("t7_lu_{init}_{Q}"), &gtag);
        let qr = ctx.ppl(&format!("t7_qr_{init}_{Q}"), &gtag);
        tab.row(vec![
            init.into(),
            lu.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            qr.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    tab.emit();
}

fn table9(ctx: &Ctx) {
    let mut tab = Table::new(
        "table9_calibsize",
        "Calibration set size (paper Table 9)",
        &["samples", "ppl", "avg acc %"],
    );
    let gtag = format!("{Q}_t3");
    for nc in [1usize, 4, 16, 64] {
        ctx.row(&mut tab, &format!("{nc}"), &format!("t9_n{nc}_{Q}"), &gtag, true);
    }
    tab.emit();
}

fn table10(ctx: &Ctx) {
    let mut tab = Table::new(
        "table10_calibseed",
        "Calibration subset robustness (paper Table 10): ppl across 3 random subsets",
        &["seed", "ppl", "avg acc %"],
    );
    let gtag = format!("{Q}_t3");
    for seed in 1..=3usize {
        ctx.row(&mut tab, &format!("{seed}"), &format!("t10_seed{seed}_{Q}"), &gtag, true);
    }
    tab.emit();
}

fn table11(ctx: &Ctx) {
    let mut tab = Table::new(
        "table11_steps",
        "Transform-training steps (paper Table 11)",
        &["steps", "ppl", "avg acc %"],
    );
    let gtag = format!("{Q}_t3");
    for s in [0usize, 15, 30, 60, 120] {
        ctx.row(&mut tab, &format!("{s}"), &format!("t11_s{s}_{Q}"), &gtag, true);
    }
    tab.emit();
}

fn table12(ctx: &Ctx) {
    let mut tab = Table::new(
        "table12_lambda",
        "Volume-regularizer lambda sweep (paper Table 12)",
        &["lambda", "ppl", "avg acc %"],
    );
    let gtag = format!("{Q}_t3");
    for lam in ["0.001", "0.1", "1.0", "10.0"] {
        ctx.row(&mut tab, lam, &format!("t12_lam{lam}_{Q}"), &gtag, true);
    }
    tab.emit();
}

fn table13(ctx: &Ctx) {
    let mut tab = Table::new(
        "table13_temp",
        "Distillation temperature sweep (paper Table 13)",
        &["T", "ppl", "avg acc %"],
    );
    let gtag = format!("{Q}_t3");
    for temp in ["0.1", "0.75", "1.5", "5.0"] {
        ctx.row(&mut tab, temp, &format!("t13_T{temp}_{Q}"), &gtag, true);
    }
    tab.emit();
}

fn table14(ctx: &Ctx) {
    let mut tab = Table::new(
        "table14_single",
        "Drop-one-transform ablation (paper Table 14)",
        &["variant", "ppl"],
    );
    let gtag = format!("{Q}_t3");
    ctx.row(&mut tab, "All (T1+T2+T3)", &format!("latmix-lu_{Q}"), &gtag, false);
    ctx.row(&mut tab, "No T3", &format!("t14_not3_{Q}"), Q, false);
    ctx.row(&mut tab, "No T1", &format!("t14_not1_{Q}"), &gtag, false);
    ctx.row(&mut tab, "No T2", &format!("t14_not2_{Q}"), &gtag, false);
    tab.emit();
}
