//! Serving workload generation + eval-dataset loading.
//!
//! Zero-shot task sets and the perplexity corpus are *generated at build
//! time* by `python/compile/calib.py` and shipped in `artifacts/eval/` (one
//! generator, no cross-language drift); this module loads them. The
//! serving workload (random prompts with a Poisson-ish arrival pattern) is
//! generated here in Rust since it lives on the request path.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::io::{load_lxt, Tensor};
use crate::util::Pcg64;

pub const TASKS: [&str; 7] = [
    "copy", "reverse", "parity", "majority", "modsum", "agree", "retrieve",
];

/// One zero-shot task set (n instances x 4 choices).
#[derive(Clone, Debug)]
pub struct TaskSet {
    pub name: String,
    pub n: usize,
    pub max_len: usize,
    /// (n, 4, max_len) BOS+prompt+choice token sequences.
    pub tokens: Vec<i32>,
    /// (n,) index where the completion starts.
    pub prompt_len: Vec<i32>,
    /// (n, 4) total sequence lengths.
    pub len: Vec<i32>,
    /// (n,) correct choice index.
    pub label: Vec<i32>,
}

pub fn load_tasks(artifacts: &Path) -> Result<Vec<TaskSet>> {
    let map = load_lxt(&artifacts.join("eval").join("zeroshot.lxt"))?;
    let mut out = Vec::new();
    for task in TASKS {
        let t = |suffix: &str| -> Result<&Tensor> {
            map.get(&format!("tasks_{task}_{suffix}"))
                .with_context(|| format!("zeroshot.lxt missing tasks_{task}_{suffix}"))
        };
        let tokens = t("tokens")?;
        let n = tokens.dims[0];
        let max_len = tokens.dims[2];
        out.push(TaskSet {
            name: task.to_string(),
            n,
            max_len,
            tokens: tokens.as_i32()?.to_vec(),
            prompt_len: t("prompt_len")?.as_i32()?.to_vec(),
            len: t("len")?.as_i32()?.to_vec(),
            label: t("label")?.as_i32()?.to_vec(),
        });
    }
    Ok(out)
}

/// The held-out perplexity corpus: (n_seqs, seq_len) token matrix.
pub fn load_ppl_corpus(artifacts: &Path) -> Result<(Vec<i32>, usize, usize)> {
    let map = load_lxt(&artifacts.join("eval").join("ppl_heldout.lxt"))?;
    let t = map.get("tokens").context("ppl_heldout.lxt missing tokens")?;
    Ok((t.as_i32()?.to_vec(), t.dims[0], t.dims[1]))
}

/// Synthetic serving workload: `n` prompts of word tokens, lengths in
/// [4, max_prompt], each asking for `max_new` tokens.
pub fn serving_workload(
    n: usize,
    max_prompt: usize,
    max_new: usize,
    seed: u64,
) -> Vec<(Vec<i32>, usize)> {
    let mut rng = Pcg64::seed(seed);
    (0..n)
        .map(|_| {
            let len = 4 + rng.below((max_prompt - 4) as u64 + 1) as usize;
            let mut p = vec![1i32]; // BOS
            for _ in 1..len {
                p.push(32 + rng.below(224) as i32);
            }
            (p, max_new)
        })
        .collect()
}

/// Export a `BTreeMap<String, Tensor>` helper for writing results (used by
/// examples that persist intermediate tensors).
pub fn tensor_map(items: Vec<(&str, Tensor)>) -> BTreeMap<String, Tensor> {
    items.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_shapes() {
        let w = serving_workload(16, 24, 32, 7);
        assert_eq!(w.len(), 16);
        for (p, n) in &w {
            assert!(p.len() >= 4 && p.len() <= 24);
            assert_eq!(p[0], 1);
            assert_eq!(*n, 32);
        }
    }

    #[test]
    fn workload_deterministic() {
        assert_eq!(serving_workload(4, 16, 8, 9), serving_workload(4, 16, 8, 9));
    }
}
