//! Serving workload generation + eval-dataset loading.
//!
//! Zero-shot task sets and the perplexity corpus are *generated at build
//! time* by `python/compile/calib.py` and shipped in `artifacts/eval/` (one
//! generator, no cross-language drift); this module loads them. The
//! serving workload (random prompts with a Poisson-ish arrival pattern) is
//! generated here in Rust since it lives on the request path.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::io::{load_lxt, Tensor};
use crate::util::Pcg64;

pub const TASKS: [&str; 7] = [
    "copy", "reverse", "parity", "majority", "modsum", "agree", "retrieve",
];

/// One zero-shot task set (n instances x 4 choices).
#[derive(Clone, Debug)]
pub struct TaskSet {
    pub name: String,
    pub n: usize,
    pub max_len: usize,
    /// (n, 4, max_len) BOS+prompt+choice token sequences.
    pub tokens: Vec<i32>,
    /// (n,) index where the completion starts.
    pub prompt_len: Vec<i32>,
    /// (n, 4) total sequence lengths.
    pub len: Vec<i32>,
    /// (n,) correct choice index.
    pub label: Vec<i32>,
}

pub fn load_tasks(artifacts: &Path) -> Result<Vec<TaskSet>> {
    let map = load_lxt(&artifacts.join("eval").join("zeroshot.lxt"))?;
    let mut out = Vec::new();
    for task in TASKS {
        let t = |suffix: &str| -> Result<&Tensor> {
            map.get(&format!("tasks_{task}_{suffix}"))
                .with_context(|| format!("zeroshot.lxt missing tasks_{task}_{suffix}"))
        };
        let tokens = t("tokens")?;
        let n = tokens.dims[0];
        let max_len = tokens.dims[2];
        out.push(TaskSet {
            name: task.to_string(),
            n,
            max_len,
            tokens: tokens.as_i32()?.to_vec(),
            prompt_len: t("prompt_len")?.as_i32()?.to_vec(),
            len: t("len")?.as_i32()?.to_vec(),
            label: t("label")?.as_i32()?.to_vec(),
        });
    }
    Ok(out)
}

/// The held-out perplexity corpus: (n_seqs, seq_len) token matrix.
pub fn load_ppl_corpus(artifacts: &Path) -> Result<(Vec<i32>, usize, usize)> {
    let map = load_lxt(&artifacts.join("eval").join("ppl_heldout.lxt"))?;
    let t = map.get("tokens").context("ppl_heldout.lxt missing tokens")?;
    Ok((t.as_i32()?.to_vec(), t.dims[0], t.dims[1]))
}

/// Synthetic serving workload: `n` prompts of word tokens, lengths in
/// [4, max_prompt], each asking for `max_new` tokens.
pub fn serving_workload(
    n: usize,
    max_prompt: usize,
    max_new: usize,
    seed: u64,
) -> Vec<(Vec<i32>, usize)> {
    let mut rng = Pcg64::seed(seed);
    (0..n)
        .map(|_| {
            let len = 4 + rng.below((max_prompt - 4) as u64 + 1) as usize;
            let mut p = vec![1i32]; // BOS
            for _ in 1..len {
                p.push(32 + rng.below(224) as i32);
            }
            (p, max_new)
        })
        .collect()
}

/// One payload class for the open-loop serving benchmark: a named
/// (prompt-length range, decode budget) bucket with a sampling weight. SLO
/// percentiles are reported per class so a tail-heavy class can't hide
/// behind a chatty one.
#[derive(Clone, Debug)]
pub struct PayloadClass {
    pub name: &'static str,
    pub min_prompt: usize,
    pub max_prompt: usize,
    pub max_new: usize,
    /// Relative sampling weight (need not be normalized).
    pub weight: u64,
}

/// The default class mix: mostly short interactive turns, some mid-size,
/// a long-decode tail — the shape that makes lockstep cohorts stall and
/// continuous batching win. Prompt ranges are clamped to the model's
/// prefill window by [`open_loop_workload`].
pub fn default_payload_classes() -> Vec<PayloadClass> {
    vec![
        PayloadClass { name: "short", min_prompt: 4, max_prompt: 8, max_new: 8, weight: 6 },
        PayloadClass { name: "medium", min_prompt: 8, max_prompt: 16, max_new: 16, weight: 3 },
        PayloadClass { name: "long", min_prompt: 12, max_prompt: 24, max_new: 48, weight: 1 },
    ]
}

/// One request of an open-loop arrival schedule.
#[derive(Clone, Debug)]
pub struct OpenLoopRequest {
    /// Arrival time, seconds from benchmark start (Poisson process).
    pub arrival_s: f64,
    /// Index into the class list this request was drawn from.
    pub class: usize,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// Open-loop serving workload: `n` requests with exponential inter-arrival
/// gaps at `rate` req/s (a Poisson arrival process — the open-loop load
/// model where arrivals do not wait for completions), each drawn from
/// `classes` by weight. Prompt lengths clamp to `[1, max_prompt]`.
/// Deterministic in `seed`.
pub fn open_loop_workload(
    n: usize,
    rate: f64,
    max_prompt: usize,
    classes: &[PayloadClass],
    seed: u64,
) -> Vec<OpenLoopRequest> {
    assert!(rate > 0.0, "arrival rate must be positive");
    assert!(!classes.is_empty());
    let total_w: u64 = classes.iter().map(|c| c.weight).sum();
    assert!(total_w > 0, "class weights must not all be zero");
    let mut rng = Pcg64::seed(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // exponential gap: -ln(1-u)/rate, u in [0,1)
            t += -(1.0 - rng.f64()).ln() / rate;
            let mut pick = rng.below(total_w);
            let mut class = 0usize;
            for (i, c) in classes.iter().enumerate() {
                if pick < c.weight {
                    class = i;
                    break;
                }
                pick -= c.weight;
            }
            let c = &classes[class];
            let lo = c.min_prompt.min(max_prompt).max(1);
            let hi = c.max_prompt.min(max_prompt).max(lo);
            let len = lo + rng.below((hi - lo) as u64 + 1) as usize;
            let mut prompt = vec![1i32]; // BOS
            for _ in 1..len {
                prompt.push(32 + rng.below(224) as i32);
            }
            OpenLoopRequest { arrival_s: t, class, prompt, max_new: c.max_new }
        })
        .collect()
}

/// [`open_loop_workload`] with every prompt's first `shared` post-BOS
/// tokens overwritten by one fixed seed-derived sequence (prompts shorter
/// than the prefix are extended to cover it, clamped to `max_prompt`).
/// All prompts then agree on `tokens[0..=shared]`, so a paged KV cache
/// maps their leading pages to the same refcounted pool pages. `shared ==
/// 0` degenerates to the plain workload. K/V rows are lane-independent
/// and position-indexed, so prefix sharing is bit-safe by construction.
pub fn open_loop_workload_shared(
    n: usize,
    rate: f64,
    max_prompt: usize,
    classes: &[PayloadClass],
    shared: usize,
    seed: u64,
) -> Vec<OpenLoopRequest> {
    let mut w = open_loop_workload(n, rate, max_prompt, classes, seed);
    let shared = shared.min(max_prompt.saturating_sub(1));
    if shared == 0 {
        return w;
    }
    // distinct stream from the workload's so the prefix is not correlated
    // with any prompt's own tail
    let mut rng = Pcg64::seed(seed ^ 0x9e37_79b9_7f4a_7c15);
    let prefix: Vec<i32> = (0..shared).map(|_| 32 + rng.below(224) as i32).collect();
    for r in &mut w {
        if r.prompt.len() < shared + 1 {
            r.prompt.resize(shared + 1, 0);
        }
        r.prompt[1..shared + 1].copy_from_slice(&prefix);
    }
    w
}

/// Export a `BTreeMap<String, Tensor>` helper for writing results (used by
/// examples that persist intermediate tensors).
pub fn tensor_map(items: Vec<(&str, Tensor)>) -> BTreeMap<String, Tensor> {
    items.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_shapes() {
        let w = serving_workload(16, 24, 32, 7);
        assert_eq!(w.len(), 16);
        for (p, n) in &w {
            assert!(p.len() >= 4 && p.len() <= 24);
            assert_eq!(p[0], 1);
            assert_eq!(*n, 32);
        }
    }

    #[test]
    fn workload_deterministic() {
        assert_eq!(serving_workload(4, 16, 8, 9), serving_workload(4, 16, 8, 9));
    }

    #[test]
    fn open_loop_arrivals_increase_monotonically() {
        let classes = default_payload_classes();
        let w = open_loop_workload(64, 50.0, 32, &classes, 11);
        assert_eq!(w.len(), 64);
        let mut prev = 0.0;
        for r in &w {
            assert!(r.arrival_s > prev, "arrival times strictly increase");
            prev = r.arrival_s;
            assert!(r.class < classes.len());
            let c = &classes[r.class];
            assert!(r.prompt.len() >= c.min_prompt.min(32));
            assert!(r.prompt.len() <= c.max_prompt.min(32));
            assert_eq!(r.prompt[0], 1);
            assert_eq!(r.max_new, c.max_new);
        }
    }

    #[test]
    fn open_loop_rate_scales_gaps() {
        let classes = default_payload_classes();
        let slow = open_loop_workload(200, 10.0, 32, &classes, 3);
        let fast = open_loop_workload(200, 100.0, 32, &classes, 3);
        // same seed, 10x the rate => ~10x shorter schedule
        let ratio = slow.last().unwrap().arrival_s / fast.last().unwrap().arrival_s;
        assert!((ratio - 10.0).abs() < 1e-6, "ratio {ratio}");
    }

    #[test]
    fn open_loop_deterministic_and_mixed() {
        let classes = default_payload_classes();
        let a = open_loop_workload(100, 25.0, 32, &classes, 7);
        let b = open_loop_workload(100, 25.0, 32, &classes, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.class, y.class);
            assert!((x.arrival_s - y.arrival_s).abs() < 1e-15);
        }
        // weighted mix actually samples every class at n=100
        for i in 0..classes.len() {
            assert!(a.iter().any(|r| r.class == i), "class {i} never sampled");
        }
    }

    #[test]
    fn shared_prefix_overwrites_and_extends() {
        let classes = default_payload_classes();
        let w = open_loop_workload_shared(40, 50.0, 24, &classes, 10, 13);
        let first = &w[0].prompt;
        assert!(first.len() >= 11);
        for r in &w {
            assert_eq!(r.prompt[0], 1, "BOS survives");
            assert_eq!(&r.prompt[..11], &first[..11], "prefix identical across prompts");
            assert!(r.prompt.len() <= 24);
        }
        // shared = 0 is the plain workload, bit for bit
        let plain = open_loop_workload(40, 50.0, 24, &classes, 13);
        let zero = open_loop_workload_shared(40, 50.0, 24, &classes, 0, 13);
        for (a, b) in plain.iter().zip(&zero) {
            assert_eq!(a.prompt, b.prompt);
        }
    }

    #[test]
    fn prompt_ranges_clamp_to_prefill_window() {
        let classes = vec![PayloadClass {
            name: "wide",
            min_prompt: 10,
            max_prompt: 100,
            max_new: 4,
            weight: 1,
        }];
        let w = open_loop_workload(32, 40.0, 16, &classes, 5);
        assert!(w.iter().all(|r| r.prompt.len() <= 16));
    }
}
