//! Minimal CLI argument parser (clap is not vendorable offline).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]). Tokens starting with `--`
    /// consume the following token as their value unless it also starts with
    /// `--` or is absent (then they are boolean flags).
    pub fn parse(raw: &[String]) -> Args {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.options.insert(name.to_string(), it.next().unwrap().clone());
                    }
                    _ => out.flags.push(name.to_string()),
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        out
    }

    pub fn from_env() -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opt(name) == Some("true")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --batch 4 --verbose --rate 2.5 extra");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt_usize("batch", 0), 4);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_f64("rate", 0.0), 2.5);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("eval --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.opt("fast"), None);
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--x 1");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.opt_usize("x", 0), 1);
    }
}
