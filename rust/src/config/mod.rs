//! TOML-subset configuration parser (serde/toml are not vendorable offline).
//!
//! Supports the subset the serving configs need: `[section]` headers,
//! `key = value` with string / int / float / bool / flat arrays, `#`
//! comments. Keys are exposed as `section.key`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    fn parse(raw: &str) -> Result<Value> {
        let raw = raw.trim();
        if raw.starts_with('"') && raw.ends_with('"') && raw.len() >= 2 {
            return Ok(Value::Str(raw[1..raw.len() - 1].to_string()));
        }
        if raw == "true" {
            return Ok(Value::Bool(true));
        }
        if raw == "false" {
            return Ok(Value::Bool(false));
        }
        if raw.starts_with('[') && raw.ends_with(']') {
            let inner = &raw[1..raw.len() - 1];
            let mut items = Vec::new();
            for part in split_top_level(inner) {
                let p = part.trim();
                if !p.is_empty() {
                    items.push(Value::parse(p)?);
                }
            }
            return Ok(Value::List(items));
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = raw.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        bail!("cannot parse value {raw:?}")
    }
}

/// Split on commas not inside quotes/brackets.
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut quote = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                quote = !quote;
                cur.push(c);
            }
            '[' if !quote => {
                depth += 1;
                cur.push(c);
            }
            ']' if !quote => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !quote && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[derive(Clone, Debug, Default)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (ln, line) in text.lines().enumerate() {
            let line = strip_comment(line).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value, got {line:?}", ln + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, Value::parse(v)?);
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        match self.values.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn int(&self, key: &str) -> Option<i64> {
        match self.values.get(key) {
            Some(Value::Int(i)) => Some(*i),
            _ => None,
        }
    }

    pub fn float(&self, key: &str) -> Option<f64> {
        match self.values.get(key) {
            Some(Value::Float(f)) => Some(*f),
            Some(Value::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.values.get(key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn int_list(&self, key: &str) -> Option<Vec<i64>> {
        match self.values.get(key) {
            Some(Value::List(items)) => items
                .iter()
                .map(|v| match v {
                    Value::Int(i) => Some(*i),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    let mut quote = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => quote = !quote,
            '#' if !quote => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let c = Config::parse(
            "top = 1\n[serve]\nmodel = \"latmix-tiny\"  # comment\nbatches = [1, 2, 4]\nrate = 3.5\nverbose = true\n",
        )
        .unwrap();
        assert_eq!(c.int("top"), Some(1));
        assert_eq!(c.str("serve.model"), Some("latmix-tiny"));
        assert_eq!(c.int_list("serve.batches"), Some(vec![1, 2, 4]));
        assert_eq!(c.float("serve.rate"), Some(3.5));
        assert_eq!(c.bool("serve.verbose"), Some(true));
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = Config::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(c.str("s"), Some("a#b"));
    }

    #[test]
    fn bad_line_errors() {
        assert!(Config::parse("nonsense\n").is_err());
    }
}
