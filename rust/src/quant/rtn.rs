//! Round-to-nearest weight quantization: plain MX QDQ of `W (d_in, d_out)`
//! with blocks along the input (reduction) dimension.

use crate::mx::quantize::{qdq_block, nv_tensor_scale, MxConfig};

/// QDQ `w` (row-major, `d_in x d_out`) with one shared scale per
/// (input-block, output-column) pair — mirrors `gptq.rtn_quantize` in python.
pub fn rtn_quantize(w: &[f32], d_in: usize, d_out: usize, cfg: &MxConfig) -> Vec<f32> {
    assert_eq!(w.len(), d_in * d_out);
    assert_eq!(d_in % cfg.block_size, 0);
    let ts = if cfg.nv { nv_tensor_scale(w) } else { 1.0 };
    let mut out = w.to_vec();
    let b = cfg.block_size;
    let mut col_block = vec![0.0f32; b];
    for g in (0..d_in).step_by(b) {
        for c in 0..d_out {
            for j in 0..b {
                col_block[j] = out[(g + j) * d_out + c];
            }
            qdq_block(&mut col_block, cfg, ts);
            for j in 0..b {
                out[(g + j) * d_out + c] = col_block[j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::mse;
    use crate::util::Pcg64;

    #[test]
    fn rtn_error_reasonable() {
        let mut rng = Pcg64::seed(41);
        let (d_in, d_out) = (64, 32);
        let w = rng.normal_vec(d_in * d_out, 0.3);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let q = rtn_quantize(&w, d_in, d_out, &cfg);
        let e = mse(&w, &q);
        let var = w.iter().map(|x| (x * x) as f64).sum::<f64>() / w.len() as f64;
        assert!(e > 0.0 && e < var * 0.2, "mse {e} var {var}");
    }

    #[test]
    fn rtn_idempotent_fp4() {
        let mut rng = Pcg64::seed(42);
        let w = rng.normal_vec(32 * 8, 1.0);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let q1 = rtn_quantize(&w, 32, 8, &cfg);
        let q2 = rtn_quantize(&q1, 32, 8, &cfg);
        assert_eq!(q1, q2);
    }
}
