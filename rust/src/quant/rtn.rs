//! Round-to-nearest weight quantization: plain MX QDQ (Eq. 1) of
//! `W (d_in, d_out)` with blocks along the input (reduction) dimension —
//! the paper's simplest weight-side baseline (Table 2 "RTN" rows).

use crate::mx::quantize::{qdq_block, nv_tensor_scale, MxConfig};
use crate::util::par;

/// QDQ `w` (row-major, `d_in x d_out`) with one shared scale per
/// (input-block, output-column) pair — mirrors `gptq.rtn_quantize` in python.
///
/// Each group of `block_size` input rows is a contiguous `b * d_out` span
/// of `w` and every (group, column) tile quantizes independently, so large
/// weights fan the groups out over the scoped thread pool (bit-identical
/// to the serial loop for any worker count).
pub fn rtn_quantize(w: &[f32], d_in: usize, d_out: usize, cfg: &MxConfig) -> Vec<f32> {
    assert_eq!(w.len(), d_in * d_out);
    assert_eq!(d_in % cfg.block_size, 0);
    let ts = if cfg.nv { nv_tensor_scale(w) } else { 1.0 };
    let mut out = w.to_vec();
    let b = cfg.block_size;
    let do_group = |_gi: usize, rows: &mut [f32]| {
        let mut col_block = vec![0.0f32; b];
        for c in 0..d_out {
            for j in 0..b {
                col_block[j] = rows[j * d_out + c];
            }
            qdq_block(&mut col_block, cfg, ts);
            for j in 0..b {
                rows[j * d_out + c] = col_block[j];
            }
        }
    };
    if out.len() < par::PAR_MIN_LEN {
        for rows in out.chunks_mut(b * d_out) {
            do_group(0, rows);
        }
    } else {
        par::for_each_chunk(&mut out, b * d_out, do_group);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::mse;
    use crate::util::Pcg64;

    #[test]
    fn rtn_error_reasonable() {
        let mut rng = Pcg64::seed(41);
        let (d_in, d_out) = (64, 32);
        let w = rng.normal_vec(d_in * d_out, 0.3);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let q = rtn_quantize(&w, d_in, d_out, &cfg);
        let e = mse(&w, &q);
        let var = w.iter().map(|x| (x * x) as f64).sum::<f64>() / w.len() as f64;
        assert!(e > 0.0 && e < var * 0.2, "mse {e} var {var}");
    }

    #[test]
    fn rtn_idempotent_fp4() {
        let mut rng = Pcg64::seed(42);
        let w = rng.normal_vec(32 * 8, 1.0);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let q1 = rtn_quantize(&w, 32, 8, &cfg);
        let q2 = rtn_quantize(&q1, 32, 8, &cfg);
        assert_eq!(q1, q2);
    }
}
