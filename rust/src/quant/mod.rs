//! Weight quantization substrate (Rust side): RTN and the GPTQ port —
//! the weight-side half of the paper's W4A4 recipe (Sec. 4.2; the Table 2
//! "RTN" and "GPTQ" baseline rows and the MR-GPTQ-style block-aware
//! refresh).
//!
//! The canonical weight quantization happens at build time in
//! `python/compile/gptq.py`; this mirror exists so (a) the error-analysis
//! benches can sweep quantizers without Python, and (b) the two
//! implementations cross-check each other (`rust/tests/golden_mx.rs`).

pub mod gptq;
pub mod rtn;

pub use gptq::gptq_quantize;
pub use rtn::rtn_quantize;

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Proxy task loss for weight quantization quality: `tr((W-Q)^T H (W-Q))`,
/// the GPTQ objective itself.
pub fn hessian_loss(w: &[f32], q: &[f32], h: &crate::linalg::Mat, d_out: usize) -> f64 {
    let d_in = h.rows;
    assert_eq!(w.len(), d_in * d_out);
    let mut total = 0.0f64;
    // delta^T H delta summed over output columns
    for c in 0..d_out {
        let delta: Vec<f32> = (0..d_in).map(|r| w[r * d_out + c] - q[r * d_out + c]).collect();
        let hd = h.apply_affine(&delta, None);
        total += delta
            .iter()
            .zip(&hd)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum::<f64>();
    }
    total
}
