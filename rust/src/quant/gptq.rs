//! GPTQ (Frantar et al. 2023) with MX-block-aware scales — the paper's
//! stronger weight quantizer (Sec. 4.2, the Table 2 "GPTQ" rows, applied
//! after folding the learned transforms into the weights). Rust port of
//! `python/compile/gptq.py::gptq_quantize` (same algorithm, f64 accumulation,
//! upper-Cholesky of the damped inverse Hessian, per-MX-block scale refresh).

use crate::linalg::Mat;
use crate::mx::formats::element_qdq;
use crate::mx::quantize::{block_scale, MxConfig};
use crate::util::par;

/// Cholesky factor (lower) of a symmetric positive-definite matrix, f64.
fn cholesky_lower(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Invert an SPD matrix via its Cholesky factor.
fn spd_inverse(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let l = cholesky_lower(a, n)?;
    // solve L y = e_i, then L^T x = y
    let mut inv = vec![0.0f64; n * n];
    for col in 0..n {
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let mut s = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= l[i * n + k] * y[k];
            }
            y[i] = s / l[i * n + i];
        }
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= l[k * n + i] * inv[k * n + col];
            }
            inv[i * n + col] = s / l[i * n + i];
        }
    }
    Some(inv)
}

/// Upper-Cholesky of the inverse Hessian, the GPTQ propagation factor
/// (equivalent to `torch.linalg.cholesky(inv(H), upper=True)`).
fn hinv_upper(h: &Mat, percdamp: f64) -> Option<Vec<f64>> {
    let n = h.rows;
    let mut a: Vec<f64> = h.data.iter().map(|x| *x as f64).collect();
    let mean_diag: f64 = (0..n).map(|i| a[i * n + i]).sum::<f64>() / n as f64;
    let damp = percdamp * mean_diag;
    for i in 0..n {
        if a[i * n + i] == 0.0 {
            a[i * n + i] = 1.0;
        }
        a[i * n + i] += damp;
    }
    let inv = spd_inverse(&a, n)?;
    // Upper factor U with U^T U = inv: inv = L L^T (standard Cholesky)
    // => U = L^T. Matches torch.linalg.cholesky(inv, upper=True).
    let l = cholesky_lower(&inv, n)?;
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            out[i * n + j] = l[j * n + i];
        }
    }
    Some(out)
}

/// GPTQ-quantize `W (d_in x d_out, row-major)` given Hessian `H = X^T X`.
///
/// The error propagation runs strictly down one column — columns never
/// interact once `hinv` is fixed — so the solve is restructured
/// column-major: transpose in, run each column's quantize/propagate lane
/// independently (fanned out over the scoped thread pool for large
/// weights), transpose back. Per-column arithmetic order is unchanged from
/// the original interleaved loop, so results are bit-identical to it and
/// invariant to the worker count.
pub fn gptq_quantize(
    w: &[f32],
    d_in: usize,
    d_out: usize,
    h: &Mat,
    cfg: &MxConfig,
    percdamp: f64,
) -> Vec<f32> {
    assert_eq!(w.len(), d_in * d_out);
    assert_eq!(h.rows, d_in);
    let b = cfg.block_size;
    let hinv = hinv_upper(h, percdamp).expect("Hessian not SPD after damping");
    // transpose to column-major: each column is a contiguous lane
    let mut wt = vec![0.0f64; d_in * d_out];
    for r in 0..d_in {
        for c in 0..d_out {
            wt[c * d_in + r] = w[r * d_out + c] as f64;
        }
    }
    let dead: Vec<bool> = (0..d_in).map(|i| h[(i, i)] == 0.0).collect();
    let mut qt = vec![0.0f32; d_in * d_out];
    let hinv_ref = &hinv;
    let dead_ref = &dead;
    let do_col = |_ci: usize, wcol: &mut [f64], qcol: &mut [f32]| {
        for i in 0..d_in {
            if dead_ref[i] {
                wcol[i] = 0.0;
            }
        }
        let mut scale = 1.0f32;
        for i in 0..d_in {
            if i % b == 0 {
                // refresh the scale from the current residual block
                let mut amax = 0.0f32;
                for r in i..(i + b).min(d_in) {
                    amax = amax.max((wcol[r] as f32).abs());
                }
                scale = block_scale(amax, cfg.element.emax);
            }
            let qi = scale * element_qdq(wcol[i] as f32 / scale, cfg.element);
            qcol[i] = qi;
            let err = (wcol[i] - qi as f64) / hinv_ref[i * d_in + i];
            for r in i + 1..d_in {
                wcol[r] -= hinv_ref[i * d_in + r] * err;
            }
        }
    };
    if d_in * d_out < par::PAR_MIN_LEN {
        for (ci, (wcol, qcol)) in wt.chunks_mut(d_in).zip(qt.chunks_mut(d_in)).enumerate() {
            do_col(ci, wcol, qcol);
        }
    } else {
        par::for_each_chunk2(&mut wt, d_in, &mut qt, d_in, do_col);
    }
    // transpose back to row-major
    let mut q = vec![0.0f32; d_in * d_out];
    for c in 0..d_out {
        for r in 0..d_in {
            q[r * d_out + c] = qt[c * d_in + r];
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{hessian_loss, mse, rtn_quantize};
    use crate::util::Pcg64;

    fn calib_hessian(d_in: usize, n: usize, rng: &mut Pcg64) -> (Mat, Vec<f32>) {
        // correlated activations (low-rank structure + noise)
        let k = d_in / 4;
        let basis = Mat::from_vec(k, d_in, rng.normal_vec(k * d_in, 1.0));
        let mut xs = Vec::with_capacity(n * d_in);
        for _ in 0..n {
            let z = rng.normal_vec(k, 1.0);
            let mut row = vec![0.0f32; d_in];
            for (j, zj) in z.iter().enumerate() {
                for (r, b) in row.iter_mut().zip(basis.row(j)) {
                    *r += zj * b;
                }
            }
            for r in row.iter_mut() {
                *r += rng.normal() * 0.1;
            }
            xs.extend(row);
        }
        let mut h = Mat::zeros(d_in, d_in);
        for row in xs.chunks(d_in) {
            for i in 0..d_in {
                for j in 0..d_in {
                    h[(i, j)] += row[i] * row[j];
                }
            }
        }
        (h, xs)
    }

    #[test]
    fn gptq_beats_rtn_on_hessian_loss() {
        let mut rng = Pcg64::seed(51);
        let (d_in, d_out) = (64, 16);
        let (h, _) = calib_hessian(d_in, 128, &mut rng);
        let w = rng.normal_vec(d_in * d_out, 0.5);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let q_rtn = rtn_quantize(&w, d_in, d_out, &cfg);
        let q_gptq = gptq_quantize(&w, d_in, d_out, &h, &cfg, 0.01);
        let l_rtn = hessian_loss(&w, &q_rtn, &h, d_out);
        let l_gptq = hessian_loss(&w, &q_gptq, &h, d_out);
        assert!(
            l_gptq < l_rtn,
            "gptq {l_gptq} should beat rtn {l_rtn} on the task loss"
        );
    }

    #[test]
    fn gptq_outputs_are_mx_representable() {
        let mut rng = Pcg64::seed(52);
        let (d_in, d_out) = (32, 8);
        let (h, _) = calib_hessian(d_in, 64, &mut rng);
        let w = rng.normal_vec(d_in * d_out, 1.0);
        let cfg = MxConfig::from_name("mxint4", Some(32)).unwrap();
        let q = gptq_quantize(&w, d_in, d_out, &h, &cfg, 0.01);
        // every quantized value must round-trip through RTN unchanged for
        // the *same* scales: check idempotence of a per-column re-quant
        let e = mse(&w, &q);
        assert!(e > 0.0);
        for v in &q {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn identity_hessian_reduces_to_rtn_like() {
        // With H = I there is no correlation to exploit; GPTQ ~ RTN error.
        let mut rng = Pcg64::seed(53);
        let (d_in, d_out) = (32, 8);
        let h = Mat::eye(d_in);
        let w = rng.normal_vec(d_in * d_out, 0.5);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let q_gptq = gptq_quantize(&w, d_in, d_out, &h, &cfg, 0.0);
        let q_rtn = rtn_quantize(&w, d_in, d_out, &cfg);
        let r = mse(&q_gptq, &q_rtn);
        let base = mse(&w, &q_rtn);
        assert!(r <= base * 1.5);
    }
}
