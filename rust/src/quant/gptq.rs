//! GPTQ (Frantar et al. 2023) with MX-block-aware scales — Rust port of
//! `python/compile/gptq.py::gptq_quantize` (same algorithm, f64 accumulation,
//! upper-Cholesky of the damped inverse Hessian, per-MX-block scale refresh).

use crate::linalg::Mat;
use crate::mx::formats::{element_qdq, floor_log2};
use crate::mx::quantize::{MxConfig, SCALE_EMAX, SCALE_EMIN};

/// Cholesky factor (lower) of a symmetric positive-definite matrix, f64.
fn cholesky_lower(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Invert an SPD matrix via its Cholesky factor.
fn spd_inverse(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let l = cholesky_lower(a, n)?;
    // solve L y = e_i, then L^T x = y
    let mut inv = vec![0.0f64; n * n];
    for col in 0..n {
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let mut s = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= l[i * n + k] * y[k];
            }
            y[i] = s / l[i * n + i];
        }
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= l[k * n + i] * inv[k * n + col];
            }
            inv[i * n + col] = s / l[i * n + i];
        }
    }
    Some(inv)
}

/// Upper-Cholesky of the inverse Hessian, the GPTQ propagation factor
/// (equivalent to `torch.linalg.cholesky(inv(H), upper=True)`).
fn hinv_upper(h: &Mat, percdamp: f64) -> Option<Vec<f64>> {
    let n = h.rows;
    let mut a: Vec<f64> = h.data.iter().map(|x| *x as f64).collect();
    let mean_diag: f64 = (0..n).map(|i| a[i * n + i]).sum::<f64>() / n as f64;
    let damp = percdamp * mean_diag;
    for i in 0..n {
        if a[i * n + i] == 0.0 {
            a[i * n + i] = 1.0;
        }
        a[i * n + i] += damp;
    }
    let inv = spd_inverse(&a, n)?;
    // Upper factor U with U^T U = inv: inv = L L^T (standard Cholesky)
    // => U = L^T. Matches torch.linalg.cholesky(inv, upper=True).
    let l = cholesky_lower(&inv, n)?;
    let mut out = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            out[i * n + j] = l[j * n + i];
        }
    }
    Some(out)
}

fn mx_scale(amax: f32, emax: i32) -> f32 {
    if amax <= 0.0 {
        return 1.0;
    }
    let e = (floor_log2(amax) - emax).clamp(SCALE_EMIN, SCALE_EMAX);
    f32::from_bits((((e + 127) as u32) & 0xff) << 23)
}

/// GPTQ-quantize `W (d_in x d_out, row-major)` given Hessian `H = X^T X`.
pub fn gptq_quantize(
    w: &[f32],
    d_in: usize,
    d_out: usize,
    h: &Mat,
    cfg: &MxConfig,
    percdamp: f64,
) -> Vec<f32> {
    assert_eq!(w.len(), d_in * d_out);
    assert_eq!(h.rows, d_in);
    let b = cfg.block_size;
    let hinv = hinv_upper(h, percdamp).expect("Hessian not SPD after damping");
    let mut wf: Vec<f64> = w.iter().map(|x| *x as f64).collect();
    // dead inputs
    for i in 0..d_in {
        if h[(i, i)] == 0.0 {
            for c in 0..d_out {
                wf[i * d_out + c] = 0.0;
            }
        }
    }
    let mut q = vec![0.0f32; d_in * d_out];
    let mut scales = vec![1.0f32; d_out];
    for i in 0..d_in {
        if i % b == 0 {
            // refresh per-column scales from current residual block
            for c in 0..d_out {
                let mut amax = 0.0f32;
                for r in i..(i + b).min(d_in) {
                    amax = amax.max((wf[r * d_out + c] as f32).abs());
                }
                scales[c] = mx_scale(amax, cfg.element.emax);
            }
        }
        let dinv = hinv[i * d_in + i];
        for c in 0..d_out {
            let s = scales[c];
            let qi = s * element_qdq(wf[i * d_out + c] as f32 / s, cfg.element);
            q[i * d_out + c] = qi;
            let err = (wf[i * d_out + c] - qi as f64) / dinv;
            for r in i + 1..d_in {
                wf[r * d_out + c] -= hinv[i * d_in + r] * err;
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{hessian_loss, mse, rtn_quantize};
    use crate::util::Pcg64;

    fn calib_hessian(d_in: usize, n: usize, rng: &mut Pcg64) -> (Mat, Vec<f32>) {
        // correlated activations (low-rank structure + noise)
        let k = d_in / 4;
        let basis = Mat::from_vec(k, d_in, rng.normal_vec(k * d_in, 1.0));
        let mut xs = Vec::with_capacity(n * d_in);
        for _ in 0..n {
            let z = rng.normal_vec(k, 1.0);
            let mut row = vec![0.0f32; d_in];
            for (j, zj) in z.iter().enumerate() {
                for (r, b) in row.iter_mut().zip(basis.row(j)) {
                    *r += zj * b;
                }
            }
            for r in row.iter_mut() {
                *r += rng.normal() * 0.1;
            }
            xs.extend(row);
        }
        let mut h = Mat::zeros(d_in, d_in);
        for row in xs.chunks(d_in) {
            for i in 0..d_in {
                for j in 0..d_in {
                    h[(i, j)] += row[i] * row[j];
                }
            }
        }
        (h, xs)
    }

    #[test]
    fn gptq_beats_rtn_on_hessian_loss() {
        let mut rng = Pcg64::seed(51);
        let (d_in, d_out) = (64, 16);
        let (h, _) = calib_hessian(d_in, 128, &mut rng);
        let w = rng.normal_vec(d_in * d_out, 0.5);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let q_rtn = rtn_quantize(&w, d_in, d_out, &cfg);
        let q_gptq = gptq_quantize(&w, d_in, d_out, &h, &cfg, 0.01);
        let l_rtn = hessian_loss(&w, &q_rtn, &h, d_out);
        let l_gptq = hessian_loss(&w, &q_gptq, &h, d_out);
        assert!(
            l_gptq < l_rtn,
            "gptq {l_gptq} should beat rtn {l_rtn} on the task loss"
        );
    }

    #[test]
    fn gptq_outputs_are_mx_representable() {
        let mut rng = Pcg64::seed(52);
        let (d_in, d_out) = (32, 8);
        let (h, _) = calib_hessian(d_in, 64, &mut rng);
        let w = rng.normal_vec(d_in * d_out, 1.0);
        let cfg = MxConfig::from_name("mxint4", Some(32)).unwrap();
        let q = gptq_quantize(&w, d_in, d_out, &h, &cfg, 0.01);
        // every quantized value must round-trip through RTN unchanged for
        // the *same* scales: check idempotence of a per-column re-quant
        let e = mse(&w, &q);
        assert!(e > 0.0);
        for v in &q {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn identity_hessian_reduces_to_rtn_like() {
        // With H = I there is no correlation to exploit; GPTQ ~ RTN error.
        let mut rng = Pcg64::seed(53);
        let (d_in, d_out) = (32, 8);
        let h = Mat::eye(d_in);
        let w = rng.normal_vec(d_in * d_out, 0.5);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let q_gptq = gptq_quantize(&w, d_in, d_out, &h, &cfg, 0.0);
        let q_rtn = rtn_quantize(&w, d_in, d_out, &cfg);
        let r = mse(&q_gptq, &q_rtn);
        let base = mse(&w, &q_rtn);
        assert!(r <= base * 1.5);
    }
}
