//! Page-granular KV quantization: encode/decode contiguous runs of KV rows
//! to MX bytes (one E8M0 scale byte per block, element codes packed 4- or
//! 8-bit) for the paged `KvCache`.
//!
//! Layout is row-major and row-contained: a run of `n` rows of `row` f32
//! elements encodes to `n * row / block` scale bytes and `n * row_code_bytes`
//! code bytes, so any row range inside a page decodes independently — the
//! gather path only ever touches the `[0, pos)` prefix of a page.
//!
//! Bit-exactness contract (property-tested in `rust/tests/codec_props.rs`):
//! - MXFP8 encode→decode reproduces [`super::quantize::mx_qdq_rows`] (and
//!   the scalar `mx/reference.rs` oracle) bit-for-bit, including the
//!   denormal-scale division path and signed zeros — the byte codec
//!   [`fp8_encode`]/[`fp8_lut`] round-trips `fp_qdq` exactly.
//! - MXFP4/MXINT4 encode→decode reproduces `reference::unpack_ref ∘
//!   pack_ref` bit-for-bit (the nibble codecs canonicalize `-0.0` to `+0.0`,
//!   same as [`super::pack::PackedMx`]).

use super::formats::{exp2i, exp2i_ext, floor_log2, fp4_encode, fp4_pair_lut, fp8_encode, fp8_lut,
    int4_encode, int4_pair_lut};
use super::quantize::{MxConfig, SCALE_EMAX, SCALE_EMIN};

/// MX block size used along KV rows: the largest power of two ≤ 32 dividing
/// `row`, so every row length quantizes with row-aligned (and, for nibble
/// formats with even `row`, byte-aligned) blocks. Real rows (`d_model` a
/// multiple of 32) get the spec's B=32; the tiny mock dims degrade
/// gracefully.
pub fn kv_block(row: usize) -> usize {
    assert!(row > 0, "kv_block: empty row");
    let mut b = 32;
    while row % b != 0 {
        b /= 2;
    }
    b
}

/// Code bytes per element run of length `n` (4-bit formats pack two codes
/// per byte).
pub fn code_bytes(cfg: &MxConfig, n: usize) -> usize {
    match cfg.element.bits {
        4 => n / 2,
        8 => n,
        b => panic!("page codec: unsupported element width {b}"),
    }
}

/// Scale bytes per element run of length `n`.
pub fn scale_bytes(cfg: &MxConfig, n: usize) -> usize {
    n / cfg.block_size
}

fn check(cfg: &MxConfig, n: usize, scales: usize, codes: usize) {
    assert!(!cfg.nv && cfg.name != "none", "page codec: single-level MX only");
    assert_eq!(n % cfg.block_size, 0, "page codec: run not block-aligned");
    if cfg.element.bits == 4 {
        assert_eq!(cfg.block_size % 2, 0, "page codec: nibble blocks must be even");
    }
    assert_eq!(scales, scale_bytes(cfg, n));
    assert_eq!(codes, code_bytes(cfg, n));
}

/// Quantize a run of elements (any multiple of `cfg.block_size`) into
/// scale + code bytes. Same scale/encode discipline as `PackedMx::pack`:
/// multiply by the exact power-of-two inverse, falling back to the
/// reference division semantics for denormal-range blocks.
pub fn encode_run(src: &[f32], cfg: &MxConfig, scales: &mut [u8], codes: &mut [u8]) {
    check(cfg, src.len(), scales.len(), codes.len());
    let b = cfg.block_size;
    let emax = cfg.element.emax;
    for (bi, block) in src.chunks_exact(b).enumerate() {
        let amax = block.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let e = if amax > 0.0 {
            (floor_log2(amax) - emax).clamp(SCALE_EMIN, SCALE_EMAX)
        } else {
            0
        };
        scales[bi] = (e + 127) as u8;
        let s = exp2i(e);
        match cfg.element.bits {
            4 => {
                let is_fp = cfg.element.is_fp;
                let enc = move |v: f32| if is_fp { fp4_encode(v) } else { int4_encode(v) };
                let cb = &mut codes[bi * b / 2..(bi + 1) * b / 2];
                if s == 0.0 {
                    for (pair, byte) in block.chunks_exact(2).zip(cb.iter_mut()) {
                        *byte = enc(pair[0] / s) | (enc(pair[1] / s) << 4);
                    }
                } else {
                    let s_inv = exp2i_ext(-e);
                    for (pair, byte) in block.chunks_exact(2).zip(cb.iter_mut()) {
                        *byte = enc(pair[0] * s_inv) | (enc(pair[1] * s_inv) << 4);
                    }
                }
            }
            8 => {
                let cb = &mut codes[bi * b..(bi + 1) * b];
                if s == 0.0 {
                    for (v, byte) in block.iter().zip(cb.iter_mut()) {
                        *byte = fp8_encode(v / s);
                    }
                } else {
                    let s_inv = exp2i_ext(-e);
                    for (v, byte) in block.iter().zip(cb.iter_mut()) {
                        *byte = fp8_encode(v * s_inv);
                    }
                }
            }
            _ => unreachable!(),
        }
    }
}

/// Dequantize a run previously written by [`encode_run`]: one LUT load per
/// code byte, scale applied as `s * value` (the same multiply order as
/// `qdq_block`, keeping MXFP8 bit-identical to the fake-quant path).
pub fn decode_run(cfg: &MxConfig, scales: &[u8], codes: &[u8], dst: &mut [f32]) {
    check(cfg, dst.len(), scales.len(), codes.len());
    let b = cfg.block_size;
    match cfg.element.bits {
        4 => {
            let lut = if cfg.element.is_fp { fp4_pair_lut() } else { int4_pair_lut() };
            for (bi, chunk) in dst.chunks_exact_mut(b).enumerate() {
                let s = exp2i(scales[bi] as i32 - 127);
                let cb = &codes[bi * b / 2..(bi + 1) * b / 2];
                for (pair, byte) in chunk.chunks_exact_mut(2).zip(cb) {
                    let d = &lut[*byte as usize];
                    pair[0] = s * d[0];
                    pair[1] = s * d[1];
                }
            }
        }
        8 => {
            let lut = fp8_lut();
            for (bi, chunk) in dst.chunks_exact_mut(b).enumerate() {
                let s = exp2i(scales[bi] as i32 - 127);
                let cb = &codes[bi * b..(bi + 1) * b];
                for (v, byte) in chunk.iter_mut().zip(cb) {
                    *v = s * lut[*byte as usize];
                }
            }
        }
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::quantize::mx_qdq;
    use crate::mx::reference;
    use crate::util::Pcg64;

    fn cfg4() -> MxConfig {
        MxConfig::from_name("mxfp4", None).unwrap()
    }

    fn cfg8() -> MxConfig {
        MxConfig::from_name("mxfp8", None).unwrap()
    }

    fn sample(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        rng.normal_vec(n, 1.0)
            .into_iter()
            .enumerate()
            .map(|(i, v)| match i % 7 {
                0 => 0.0,
                1 => v * 1e-40, // denormal-scale blocks
                2 => v * 1e4,
                _ => v,
            })
            .collect()
    }

    #[test]
    fn kv_block_divides_and_caps_at_32() {
        for row in [1, 2, 4, 6, 10, 32, 96, 128, 129, 160] {
            let b = kv_block(row);
            assert_eq!(row % b, 0, "row {row} block {b}");
            assert!(b <= 32 && b >= 1);
        }
        assert_eq!(kv_block(128), 32);
        assert_eq!(kv_block(4), 4);
        assert_eq!(kv_block(129), 1);
    }

    #[test]
    fn fp8_run_matches_qdq_bitwise() {
        let cfg = cfg8();
        let mut rng = Pcg64::seed(11);
        let x = sample(&mut rng, 32 * 17);
        let mut scales = vec![0u8; scale_bytes(&cfg, x.len())];
        let mut codes = vec![0u8; code_bytes(&cfg, x.len())];
        encode_run(&x, &cfg, &mut scales, &mut codes);
        let mut got = vec![0.0f32; x.len()];
        decode_run(&cfg, &scales, &codes, &mut got);
        let want = mx_qdq(&x, x.len(), &cfg);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "fp8 page qdq mismatch: {g} vs {w}");
        }
    }

    #[test]
    fn fp4_run_matches_reference_pack_bitwise() {
        let cfg = cfg4();
        let mut rng = Pcg64::seed(12);
        let x = sample(&mut rng, 32 * 9);
        let mut scales = vec![0u8; scale_bytes(&cfg, x.len())];
        let mut codes = vec![0u8; code_bytes(&cfg, x.len())];
        encode_run(&x, &cfg, &mut scales, &mut codes);
        let (rs, rc) = reference::pack_ref(&x, &cfg);
        assert_eq!(scales, rs);
        assert_eq!(codes, rc);
        let mut got = vec![0.0f32; x.len()];
        decode_run(&cfg, &scales, &codes, &mut got);
        let want = reference::unpack_ref(&cfg, x.len(), &rs, &rc);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "fp4 page qdq mismatch: {g} vs {w}");
        }
    }

    #[test]
    fn small_block_rows_roundtrip() {
        // mock dims: kv_row = 4 -> block 4
        let mut cfg = cfg8();
        cfg.block_size = kv_block(4);
        let mut rng = Pcg64::seed(13);
        let x = sample(&mut rng, 4 * 6);
        let mut scales = vec![0u8; scale_bytes(&cfg, x.len())];
        let mut codes = vec![0u8; code_bytes(&cfg, x.len())];
        encode_run(&x, &cfg, &mut scales, &mut codes);
        let mut got = vec![0.0f32; x.len()];
        decode_run(&cfg, &scales, &codes, &mut got);
        let want = mx_qdq(&x, x.len(), &cfg);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn zero_bytes_decode_to_zero() {
        let cfg = cfg8();
        let scales = vec![0u8; 1];
        let codes = vec![0u8; 32];
        let mut out = vec![1.0f32; 32];
        decode_run(&cfg, &scales, &codes, &mut out);
        assert!(out.iter().all(|v| *v == 0.0));
    }

    // -- golden vectors: the exact bytes a persisted MX page contains.
    // The property tests above pin encode/decode to the reference
    // *implementations*; these pin the byte *layout* itself, so a codec
    // change that reshuffles stored pages (scale bias, code order, nibble
    // packing) fails against frozen constants, not against itself.

    #[test]
    fn golden_mxfp8_block_bytes_frozen() {
        let cfg = cfg8();
        let vals = [0.0f32, 1.0, -2.0, 0.5, 4.0, -0.25, 3.0, 1.5];
        let x: Vec<f32> = vals.iter().copied().cycle().take(32).collect();
        let mut scales = vec![0u8; 1];
        let mut codes = vec![0u8; 32];
        encode_run(&x, &cfg, &mut scales, &mut codes);
        // amax 4.0 -> e = floor_log2(4) - emax(8) = -6 -> E8M0 byte 121
        assert_eq!(scales, [121]);
        // scaled by 2^6: [0, 64, -128, 32, 256, -16, 192, 96] on the E4M3
        // grid; codes are sign | biased-exp<<3 | mantissa
        let pat: [u8; 8] = [0, 104, 240, 96, 120, 216, 116, 108];
        let want: Vec<u8> = pat.iter().copied().cycle().take(32).collect();
        assert_eq!(codes, want);
        // every input sits exactly on the scaled grid -> lossless decode
        let mut got = vec![0.0f32; 32];
        decode_run(&cfg, &scales, &codes, &mut got);
        for (g, w) in got.iter().zip(&x) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn golden_mxfp4_block_bytes_frozen() {
        let cfg = cfg4();
        // block 1: amax 6.0 -> e = floor_log2(6) - emax(2) = 0 -> byte 127,
        // every element already on the E2M1 grid
        let b1 = [1.0f32, -2.0, 0.5, 6.0, -1.5, 3.0, 0.0, 4.0];
        // block 2: amax 12.0 -> e = 1 -> byte 128; scaled halves land on
        // the grid except 2.5 -> 1.25, which round-ties-even snaps to 1.0
        let b2 = [12.0f32, -8.0, 2.0, 0.0, 3.0, -1.0, 6.0, 2.5];
        let mut x: Vec<f32> = b1.iter().copied().cycle().take(32).collect();
        x.extend(b2.iter().copied().cycle().take(32));
        let mut scales = vec![0u8; 2];
        let mut codes = vec![0u8; 32];
        encode_run(&x, &cfg, &mut scales, &mut codes);
        assert_eq!(scales, [127, 128]);
        // nibble codes sign<<3 | grid-index, packed low nibble first
        let p1: [u8; 4] = [194, 113, 91, 96];
        let p2: [u8; 4] = [231, 2, 147, 37];
        let mut want: Vec<u8> = p1.iter().copied().cycle().take(16).collect();
        want.extend(p2.iter().copied().cycle().take(16));
        assert_eq!(codes, want);
        let mut got = vec![0.0f32; 64];
        decode_run(&cfg, &scales, &codes, &mut got);
        let d2 = [12.0f32, -8.0, 2.0, 0.0, 3.0, -1.0, 6.0, 2.0];
        let mut dec: Vec<f32> = b1.iter().copied().cycle().take(32).collect();
        dec.extend(d2.iter().copied().cycle().take(32));
        for (i, (g, w)) in got.iter().zip(&dec).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn golden_mxfp8_degenerate_scales_frozen() {
        let cfg = cfg8();
        // all-zero block: amax == 0 pins e = 0 (byte 127) and code 0
        let x = vec![0.0f32; 32];
        let mut scales = vec![0u8; 1];
        let mut codes = vec![0u8; 32];
        encode_run(&x, &cfg, &mut scales, &mut codes);
        assert_eq!(scales, [127]);
        assert!(codes.iter().all(|c| *c == 0));
        let mut got = vec![1.0f32; 32];
        decode_run(&cfg, &scales, &codes, &mut got);
        assert!(got.iter().all(|v| v.to_bits() == 0), "+0.0 exactly");
        // subnormal-amax block: e clamps to the E8M0 bottom code (byte 0),
        // whose scale is exactly 0.0 -> the encoder's division path sends
        // every element to +-inf, saturating on the E4M3 grid at +-448
        // (codes 126 / 254); decode multiplies by 0.0 back to zeros
        let x: Vec<f32> = [1e-40f32, -1e-40].iter().copied().cycle().take(32).collect();
        encode_run(&x, &cfg, &mut scales, &mut codes);
        assert_eq!(scales, [0]);
        let want: Vec<u8> = [126u8, 254].iter().copied().cycle().take(32).collect();
        assert_eq!(codes, want);
        decode_run(&cfg, &scales, &codes, &mut got);
        assert!(got.iter().all(|v| *v == 0.0));
    }
}
