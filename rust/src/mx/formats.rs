//! Element codecs for MX formats (OCP MX spec v1.0). Bit-exact mirror of
//! `python/compile/mx/formats.py` — see that module for the semantics.

/// A narrow element format inside an MX block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ElementFormat {
    pub name: &'static str,
    pub is_fp: bool,
    pub ebits: i32,
    pub mbits: i32,
    /// Exponent of the max representable value — the paper's `r_max`.
    pub emax: i32,
    pub maxval_bits: u32, // f32 bits of maxval (const-friendly)
    pub bits: u32,
}

impl ElementFormat {
    #[inline]
    pub fn maxval(&self) -> f32 {
        f32::from_bits(self.maxval_bits)
    }
}

pub const FP4_E2M1: ElementFormat = ElementFormat {
    name: "fp4_e2m1", is_fp: true, ebits: 2, mbits: 1, emax: 2,
    maxval_bits: 0x40c00000, // 6.0
    bits: 4,
};
pub const FP6_E2M3: ElementFormat = ElementFormat {
    name: "fp6_e2m3", is_fp: true, ebits: 2, mbits: 3, emax: 2,
    maxval_bits: 0x40f00000, // 7.5
    bits: 6,
};
pub const FP8_E4M3: ElementFormat = ElementFormat {
    name: "fp8_e4m3", is_fp: true, ebits: 4, mbits: 3, emax: 8,
    maxval_bits: 0x43e00000, // 448.0
    bits: 8,
};
pub const INT4: ElementFormat = ElementFormat {
    name: "int4", is_fp: false, ebits: 0, mbits: 3, emax: 2,
    maxval_bits: 0x40e00000, // 7.0
    bits: 4,
};

/// Exact floor(log2(a)) for positive finite normal f32 (exponent-field
/// extraction). Values below the smallest normal return -127, matching the
/// python `max(a, 1e-38)` guard once downstream clamps (>= -126) apply.
#[inline]
pub fn floor_log2(a: f32) -> i32 {
    debug_assert!(a >= 0.0);
    let exp = ((a.to_bits() >> 23) & 0xff) as i32;
    if exp == 0 {
        -127
    } else {
        exp - 127
    }
}

/// Exact `2^e` by exponent-field construction — exact for `e` in
/// `[-126, 127]`, `0.0` for `e == -127` (the E8M0 bottom code). Shared by
/// the quantize/pack/GPTQ scale paths.
#[inline]
pub fn exp2i(e: i32) -> f32 {
    f32::from_bits((((e + 127) as u32) & 0xff) << 23)
}

/// Exact `2^e` over the full f32 range including subnormal results
/// (`e` in `[-149, -127]`). Used to turn the per-element division by a
/// power-of-two block scale into a multiplication by its exact inverse:
/// for `s = 2^e`, `x * 2^-e` and `x / 2^e` are the same correctly-rounded
/// value, and `2^-e` needs the subnormal range when `e = 127`.
#[inline]
pub fn exp2i_ext(e: i32) -> f32 {
    if e >= -126 {
        exp2i(e)
    } else if e >= -149 {
        f32::from_bits(1u32 << (e + 149))
    } else {
        0.0
    }
}

/// QDQ in the scaled domain for a floating-point element format
/// (round-to-nearest-even on the mantissa grid, saturating, subnormal-aware).
#[inline]
pub fn fp_qdq(v: f32, fmt: ElementFormat) -> f32 {
    debug_assert!(fmt.is_fp);
    let bias = (1 << (fmt.ebits - 1)) - 1;
    let emin = 1 - bias;
    let a = v.abs().min(fmt.maxval());
    let e = floor_log2(a).clamp(emin, fmt.emax);
    let step = exp2i(e - fmt.mbits);
    let q = (a / step).round_ties_even() * step;
    let q = q.min(fmt.maxval());
    if v == 0.0 {
        0.0
    } else {
        q.copysign(v)
    }
}

/// QDQ in the scaled domain for INT4: round + clamp to [-8, 7].
#[inline]
pub fn int_qdq(v: f32, fmt: ElementFormat) -> f32 {
    debug_assert!(!fmt.is_fp);
    let lo = -((1 << fmt.mbits) as f32);
    let hi = ((1 << fmt.mbits) - 1) as f32;
    v.round_ties_even().clamp(lo, hi)
}

#[inline]
pub fn element_qdq(v: f32, fmt: ElementFormat) -> f32 {
    if fmt.is_fp {
        fp_qdq(v, fmt)
    } else {
        int_qdq(v, fmt)
    }
}

/// Encode a scaled FP4 value to its 4-bit code (sign + e2m1), and back.
/// Used by the bit-packing layer. Branchless: after `fp_qdq` snaps `v`
/// onto the grid {0, .5, 1, 1.5, 2, 3, 4, 6}, the code is read straight
/// out of the exponent/mantissa bit fields instead of a cascade of
/// magnitude compares (bit-exact with the old compare chain — see the
/// `fp4_encode_matches_compare_chain` test).
#[inline]
pub fn fp4_encode(v: f32) -> u8 {
    let q = fp_qdq(v, FP4_E2M1);
    let bits = q.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32;
    let m = ((bits >> 22) & 1) as i32;
    // exp 0 -> code 0; exp 126 (0.5) -> 1; exp 127.. -> 2*(exp-126) + m
    let t = exp - 126;
    let code = (t.max(0) * 2 + m + (t == 0) as i32) as u8;
    // sign nibble only for nonzero codes (-0.0 encodes as +0, like before)
    let sign = (((bits >> 31) as u8) << 3) * (code != 0) as u8;
    sign | code
}

#[inline]
pub fn fp4_decode(code: u8) -> f32 {
    const GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
    let v = GRID[(code & 7) as usize];
    if code & 8 != 0 {
        -v
    } else {
        v
    }
}

/// Encode a scaled INT4 value to its 4-bit two's-complement code, and back.
#[inline]
pub fn int4_encode(v: f32) -> u8 {
    (int_qdq(v, INT4) as i32 & 0xf) as u8
}

#[inline]
pub fn int4_decode(code: u8) -> f32 {
    let s = ((code as i8) << 4) >> 4; // sign-extend low nibble
    s as f32
}

/// Encode a scaled FP8 value to its 8-bit code (sign + e4m3), and back.
/// Same discipline as [`fp4_encode`]: `fp_qdq` snaps `v` onto the E4M3
/// grid, then the code is read straight out of the f32 bit fields. Unlike
/// the 4-bit codec the zero sign survives (E4M3 has a -0 encoding), so
/// `fp8_decode(fp8_encode(v))` reproduces `fp_qdq(v, FP8_E4M3)` bit-exactly
/// including signed zeros — the KV page codec relies on that to stay
/// bit-identical with [`super::quantize::qdq_block`].
#[inline]
pub fn fp8_encode(v: f32) -> u8 {
    let q = fp_qdq(v, FP8_E4M3);
    let bits = q.to_bits();
    let sign = ((bits >> 31) as u8) << 7;
    let exp = ((bits >> 23) & 0xff) as i32 - 127; // unbiased f32 exponent
    if bits & 0x7fff_ffff == 0 {
        return sign; // +-0
    }
    if exp >= -6 {
        // normal e4m3: biased exponent 1..=15, top 3 mantissa bits
        let e_field = (exp + 7) as u8;
        let m = ((bits >> 20) & 0x7) as u8;
        sign | (e_field << 3) | m
    } else {
        // subnormal e4m3: q = m * 2^-9, m in 1..=7 (exact on the grid)
        let m = (q.abs() * 512.0) as u8;
        sign | m
    }
}

#[inline]
pub fn fp8_decode(code: u8) -> f32 {
    let e = ((code >> 3) & 0xf) as i32;
    let m = (code & 7) as u32;
    let mag = if e == 0 {
        // subnormal: m * 2^-9 (exact integer-times-power-of-two product)
        m as f32 * exp2i(-9)
    } else {
        f32::from_bits((((e - 7 + 127) as u32) << 23) | (m << 20))
    };
    if code & 0x80 != 0 {
        -mag
    } else {
        mag
    }
}

/// Code byte -> decoded FP8 element: the 8-bit sibling of the nibble-pair
/// LUTs below (one element per byte, so a plain 256-entry value table).
/// The KV page decode hot path walks this.
pub fn fp8_lut() -> &'static [f32; 256] {
    static LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0.0f32; 256];
        for (b, slot) in t.iter_mut().enumerate() {
            *slot = fp8_decode(b as u8);
        }
        t
    })
}

fn pair_lut(decode: fn(u8) -> f32) -> [[f32; 2]; 256] {
    let mut t = [[0.0f32; 2]; 256];
    for b in 0..256usize {
        t[b] = [decode((b & 0xf) as u8), decode((b >> 4) as u8)];
    }
    t
}

/// Packed byte -> two decoded FP4 elements (low nibble first). Decoding a
/// byte becomes one 2 KiB-table load instead of two shift/branch nibble
/// decodes — the unpack hot path walks this table.
pub fn fp4_pair_lut() -> &'static [[f32; 2]; 256] {
    static LUT: std::sync::OnceLock<[[f32; 2]; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| pair_lut(fp4_decode))
}

/// Packed byte -> two decoded INT4 elements (low nibble first).
pub fn int4_pair_lut() -> &'static [[f32; 2]; 256] {
    static LUT: std::sync::OnceLock<[[f32; 2]; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| pair_lut(int4_decode))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp4_grid_exact() {
        for v in [0.0f32, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0] {
            assert_eq!(fp_qdq(v, FP4_E2M1), v);
            assert_eq!(fp_qdq(-v, FP4_E2M1), -v);
        }
    }

    #[test]
    fn fp4_saturates_and_ties_even() {
        assert_eq!(fp_qdq(100.0, FP4_E2M1), 6.0);
        assert_eq!(fp_qdq(2.5, FP4_E2M1), 2.0); // tie -> even mantissa
        assert_eq!(fp_qdq(3.5, FP4_E2M1), 4.0);
        assert_eq!(fp_qdq(0.25, FP4_E2M1), 0.0); // subnormal tie -> 0
    }

    #[test]
    fn fp8_max_and_ints() {
        assert_eq!(fp_qdq(1e9, FP8_E4M3), 448.0);
        for v in 0..17 {
            assert_eq!(fp_qdq(v as f32, FP8_E4M3), v as f32);
        }
    }

    #[test]
    fn int4_range() {
        assert_eq!(int_qdq(100.0, INT4), 7.0);
        assert_eq!(int_qdq(-100.0, INT4), -8.0);
        for k in -8..=7 {
            assert_eq!(int_qdq(k as f32, INT4), k as f32);
        }
    }

    #[test]
    fn floor_log2_exact() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(0.9999999), -1);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(3.9999998), 1);
        assert_eq!(floor_log2(4.0), 2);
        assert_eq!(floor_log2(0.5), -1);
    }

    #[test]
    fn fp4_codec_roundtrip() {
        for code in 0u8..16 {
            let v = fp4_decode(code);
            let rt = fp4_decode(fp4_encode(v));
            assert_eq!(v, rt, "code {code}");
        }
    }

    #[test]
    fn int4_codec_roundtrip() {
        for code in 0u8..16 {
            let v = int4_decode(code);
            assert_eq!(int4_decode(int4_encode(v)), v);
        }
    }

    #[test]
    fn fp4_encode_matches_compare_chain() {
        // the retired compare-chain encoder lives on as the retained oracle
        use crate::mx::reference::fp4_encode_ref as encode_ref;
        let mut v = -8.0f32;
        while v < 8.0 {
            assert_eq!(fp4_encode(v), encode_ref(v), "v={v}");
            v += 0.0625;
        }
        for v in [0.0f32, -0.0, f32::INFINITY, f32::NEG_INFINITY, 1e-40, -1e-40, 1e30] {
            assert_eq!(fp4_encode(v), encode_ref(v), "v={v}");
        }
    }

    #[test]
    fn pair_luts_match_nibble_decodes() {
        for b in 0..=255u8 {
            let fp = fp4_pair_lut()[b as usize];
            assert_eq!(fp[0].to_bits(), fp4_decode(b & 0xf).to_bits());
            assert_eq!(fp[1].to_bits(), fp4_decode(b >> 4).to_bits());
            let iv = int4_pair_lut()[b as usize];
            assert_eq!(iv[0].to_bits(), int4_decode(b & 0xf).to_bits());
            assert_eq!(iv[1].to_bits(), int4_decode(b >> 4).to_bits());
        }
    }

    #[test]
    fn fp8_codec_roundtrip_all_codes() {
        // every code decodes to a grid value that encodes back to itself —
        // except the two OCP NaN slots (S.1111.111), which the saturating
        // encoder never emits
        for code in 0u8..=255 {
            if code & 0x7f == 0x7f {
                continue;
            }
            let v = fp8_decode(code);
            assert_eq!(fp8_encode(v), code, "code {code} -> {v}");
            assert_eq!(fp8_decode(fp8_encode(v)).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn fp8_encode_is_fp_qdq_bitwise() {
        // decode(encode(v)) == fp_qdq(v) exactly, signed zeros included —
        // the invariant the KV page codec's MXFP8 bit-parity rests on
        let mut v = -500.0f32;
        while v < 500.0 {
            let q = fp_qdq(v, FP8_E4M3);
            assert_eq!(fp8_decode(fp8_encode(v)).to_bits(), q.to_bits(), "v={v}");
            v += 0.3137;
        }
        for v in [
            0.0f32,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            1e-40,
            -1e-40,
            1e30,
            -1e-10,
            2f32.powi(-9),
            -3.0 * 2f32.powi(-9),
            448.0,
            -448.0,
        ] {
            let q = fp_qdq(v, FP8_E4M3);
            assert_eq!(fp8_decode(fp8_encode(v)).to_bits(), q.to_bits(), "v={v}");
        }
    }

    #[test]
    fn fp8_lut_matches_decode() {
        let lut = fp8_lut();
        for b in 0..=255u8 {
            assert_eq!(lut[b as usize].to_bits(), fp8_decode(b).to_bits());
        }
    }

    #[test]
    fn exp2i_ext_exact_incl_subnormals() {
        for e in -126..=127 {
            assert_eq!(exp2i_ext(e).to_bits(), exp2i(e).to_bits(), "e={e}");
            assert_eq!(exp2i_ext(e), (e as f64).exp2() as f32, "e={e}");
        }
        assert_eq!(exp2i_ext(-127), f32::from_bits(1 << 22));
        assert_eq!(exp2i_ext(-149), f32::from_bits(1));
        assert_eq!(exp2i_ext(-150), 0.0);
        // the inverse identity the codec relies on: x / 2^e == x * 2^-e
        for e in [-127i32, -126, -1, 0, 1, 126, 127] {
            let s = exp2i(e);
            if s == 0.0 {
                continue;
            }
            let inv = exp2i_ext(-e);
            for x in [1.0f32, 3.7, 1e-30, -2.5e20, 6.0] {
                assert_eq!((x / s).to_bits(), (x * inv).to_bits(), "e={e} x={x}");
            }
        }
    }
}
