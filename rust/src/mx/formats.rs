//! Element codecs for MX formats (OCP MX spec v1.0). Bit-exact mirror of
//! `python/compile/mx/formats.py` — see that module for the semantics.

/// A narrow element format inside an MX block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ElementFormat {
    pub name: &'static str,
    pub is_fp: bool,
    pub ebits: i32,
    pub mbits: i32,
    /// Exponent of the max representable value — the paper's `r_max`.
    pub emax: i32,
    pub maxval_bits: u32, // f32 bits of maxval (const-friendly)
    pub bits: u32,
}

impl ElementFormat {
    #[inline]
    pub fn maxval(&self) -> f32 {
        f32::from_bits(self.maxval_bits)
    }
}

pub const FP4_E2M1: ElementFormat = ElementFormat {
    name: "fp4_e2m1", is_fp: true, ebits: 2, mbits: 1, emax: 2,
    maxval_bits: 0x40c00000, // 6.0
    bits: 4,
};
pub const FP6_E2M3: ElementFormat = ElementFormat {
    name: "fp6_e2m3", is_fp: true, ebits: 2, mbits: 3, emax: 2,
    maxval_bits: 0x40f00000, // 7.5
    bits: 6,
};
pub const FP8_E4M3: ElementFormat = ElementFormat {
    name: "fp8_e4m3", is_fp: true, ebits: 4, mbits: 3, emax: 8,
    maxval_bits: 0x43e00000, // 448.0
    bits: 8,
};
pub const INT4: ElementFormat = ElementFormat {
    name: "int4", is_fp: false, ebits: 0, mbits: 3, emax: 2,
    maxval_bits: 0x40e00000, // 7.0
    bits: 4,
};

/// Exact floor(log2(a)) for positive finite normal f32 (exponent-field
/// extraction). Values below the smallest normal return -127, matching the
/// python `max(a, 1e-38)` guard once downstream clamps (>= -126) apply.
#[inline]
pub fn floor_log2(a: f32) -> i32 {
    debug_assert!(a >= 0.0);
    let exp = ((a.to_bits() >> 23) & 0xff) as i32;
    if exp == 0 {
        -127
    } else {
        exp - 127
    }
}

#[inline]
fn exp2i(e: i32) -> f32 {
    // exact for e in [-126, 127]
    f32::from_bits((((e + 127) as u32) & 0xff) << 23)
}

/// QDQ in the scaled domain for a floating-point element format
/// (round-to-nearest-even on the mantissa grid, saturating, subnormal-aware).
#[inline]
pub fn fp_qdq(v: f32, fmt: ElementFormat) -> f32 {
    debug_assert!(fmt.is_fp);
    let bias = (1 << (fmt.ebits - 1)) - 1;
    let emin = 1 - bias;
    let a = v.abs().min(fmt.maxval());
    let e = floor_log2(a).clamp(emin, fmt.emax);
    let step = exp2i(e - fmt.mbits);
    let q = (a / step).round_ties_even() * step;
    let q = q.min(fmt.maxval());
    if v == 0.0 {
        0.0
    } else {
        q.copysign(v)
    }
}

/// QDQ in the scaled domain for INT4: round + clamp to [-8, 7].
#[inline]
pub fn int_qdq(v: f32, fmt: ElementFormat) -> f32 {
    debug_assert!(!fmt.is_fp);
    let lo = -((1 << fmt.mbits) as f32);
    let hi = ((1 << fmt.mbits) - 1) as f32;
    v.round_ties_even().clamp(lo, hi)
}

#[inline]
pub fn element_qdq(v: f32, fmt: ElementFormat) -> f32 {
    if fmt.is_fp {
        fp_qdq(v, fmt)
    } else {
        int_qdq(v, fmt)
    }
}

/// Encode a scaled FP4 value to its 4-bit code (sign + e2m1), and back.
/// Used by the bit-packing layer.
#[inline]
pub fn fp4_encode(v: f32) -> u8 {
    let q = fp_qdq(v, FP4_E2M1);
    let sign = if q.is_sign_negative() && q != 0.0 { 8u8 } else { 0 };
    let a = q.abs();
    // grid: 0, .5, 1, 1.5, 2, 3, 4, 6 -> codes 0..7
    let code = match a {
        x if x < 0.25 => 0,
        x if x < 0.75 => 1,
        x if x < 1.25 => 2,
        x if x < 1.75 => 3,
        x if x < 2.5 => 4,
        x if x < 3.5 => 5,
        x if x < 5.0 => 6,
        _ => 7,
    };
    sign | code
}

#[inline]
pub fn fp4_decode(code: u8) -> f32 {
    const GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
    let v = GRID[(code & 7) as usize];
    if code & 8 != 0 {
        -v
    } else {
        v
    }
}

/// Encode a scaled INT4 value to its 4-bit two's-complement code, and back.
#[inline]
pub fn int4_encode(v: f32) -> u8 {
    (int_qdq(v, INT4) as i32 & 0xf) as u8
}

#[inline]
pub fn int4_decode(code: u8) -> f32 {
    let s = ((code as i8) << 4) >> 4; // sign-extend low nibble
    s as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp4_grid_exact() {
        for v in [0.0f32, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0] {
            assert_eq!(fp_qdq(v, FP4_E2M1), v);
            assert_eq!(fp_qdq(-v, FP4_E2M1), -v);
        }
    }

    #[test]
    fn fp4_saturates_and_ties_even() {
        assert_eq!(fp_qdq(100.0, FP4_E2M1), 6.0);
        assert_eq!(fp_qdq(2.5, FP4_E2M1), 2.0); // tie -> even mantissa
        assert_eq!(fp_qdq(3.5, FP4_E2M1), 4.0);
        assert_eq!(fp_qdq(0.25, FP4_E2M1), 0.0); // subnormal tie -> 0
    }

    #[test]
    fn fp8_max_and_ints() {
        assert_eq!(fp_qdq(1e9, FP8_E4M3), 448.0);
        for v in 0..17 {
            assert_eq!(fp_qdq(v as f32, FP8_E4M3), v as f32);
        }
    }

    #[test]
    fn int4_range() {
        assert_eq!(int_qdq(100.0, INT4), 7.0);
        assert_eq!(int_qdq(-100.0, INT4), -8.0);
        for k in -8..=7 {
            assert_eq!(int_qdq(k as f32, INT4), k as f32);
        }
    }

    #[test]
    fn floor_log2_exact() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(0.9999999), -1);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(3.9999998), 1);
        assert_eq!(floor_log2(4.0), 2);
        assert_eq!(floor_log2(0.5), -1);
    }

    #[test]
    fn fp4_codec_roundtrip() {
        for code in 0u8..16 {
            let v = fp4_decode(code);
            let rt = fp4_decode(fp4_encode(v));
            assert_eq!(v, rt, "code {code}");
        }
    }

    #[test]
    fn int4_codec_roundtrip() {
        for code in 0u8..16 {
            let v = int4_decode(code);
            assert_eq!(int4_decode(int4_encode(v)), v);
        }
    }
}
