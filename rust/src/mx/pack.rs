//! True bit-packed MX storage: two 4-bit element codes per byte plus one
//! E8M0 scale byte per block. This is what an MXFP4/MXINT4 tensor costs in
//! memory (4.25 bits/elem at B=32) — used by the footprint accounting in
//! `quantize-info` and by the codec throughput benches in the perf pass.

use super::formats::{floor_log2, fp4_decode, fp4_encode, int4_decode, int4_encode};
use super::quantize::{MxConfig, SCALE_EMAX, SCALE_EMIN};

/// A bit-packed MX tensor (4-bit element formats only).
#[derive(Clone, Debug)]
pub struct PackedMx {
    pub cfg: MxConfig,
    pub len: usize,
    /// One E8M0 byte per block: biased exponent (e + 127).
    pub scales: Vec<u8>,
    /// Two element codes per byte, low nibble first.
    pub codes: Vec<u8>,
}

#[inline]
fn exp2i(e: i32) -> f32 {
    f32::from_bits((((e + 127) as u32) & 0xff) << 23)
}

impl PackedMx {
    /// Pack `x` (blocks along the flat axis). Requires a 4-bit element
    /// format ("mxfp4" or "mxint4") and `x.len() % block_size == 0`.
    pub fn pack(x: &[f32], cfg: MxConfig) -> PackedMx {
        assert!(cfg.name == "mxfp4" || cfg.name == "mxint4", "pack: 4-bit formats only");
        assert_eq!(x.len() % cfg.block_size, 0);
        let nb = x.len() / cfg.block_size;
        let mut scales = Vec::with_capacity(nb);
        let mut codes = vec![0u8; (x.len() + 1) / 2];
        let is_fp = cfg.element.is_fp;
        for (bi, block) in x.chunks(cfg.block_size).enumerate() {
            let amax = block.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let e = if amax > 0.0 {
                (floor_log2(amax) - cfg.element.emax).clamp(SCALE_EMIN, SCALE_EMAX)
            } else {
                0
            };
            scales.push((e + 127) as u8);
            let s = exp2i(e);
            let base = bi * cfg.block_size;
            for (j, &v) in block.iter().enumerate() {
                let code = if is_fp { fp4_encode(v / s) } else { int4_encode(v / s) };
                let idx = base + j;
                if idx % 2 == 0 {
                    codes[idx / 2] |= code;
                } else {
                    codes[idx / 2] |= code << 4;
                }
            }
        }
        PackedMx { cfg, len: x.len(), scales, codes }
    }

    /// Unpack to f32 (the dequantized values).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.unpack_into(&mut out);
        out
    }

    /// Unpack into a preallocated buffer (hot-path variant).
    pub fn unpack_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        let b = self.cfg.block_size;
        let is_fp = self.cfg.element.is_fp;
        for (bi, chunk) in out.chunks_mut(b).enumerate() {
            let s = exp2i(self.scales[bi] as i32 - 127);
            let base = bi * b;
            for (j, o) in chunk.iter_mut().enumerate() {
                let idx = base + j;
                let byte = self.codes[idx / 2];
                let code = if idx % 2 == 0 { byte & 0xf } else { byte >> 4 };
                let v = if is_fp { fp4_decode(code) } else { int4_decode(code) };
                *o = v * s;
            }
        }
    }

    /// Total packed bytes (codes + scales).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.scales.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::quantize::mx_qdq;
    use crate::util::Pcg64;

    #[test]
    fn pack_unpack_equals_qdq() {
        let mut rng = Pcg64::seed(11);
        for name in ["mxfp4", "mxint4"] {
            let cfg = MxConfig::from_name(name, Some(32)).unwrap();
            let x = rng.normal_vec(256, 4.0);
            let packed = PackedMx::pack(&x, cfg);
            let unpacked = packed.unpack();
            let qdq = mx_qdq(&x, 256, &cfg);
            for (i, (a, b)) in unpacked.iter().zip(&qdq).enumerate() {
                assert!((a - b).abs() < 1e-6, "{name} idx {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn footprint_is_4_25_bits() {
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let x = vec![1.0f32; 1024];
        let p = PackedMx::pack(&x, cfg);
        let bits = p.bytes() as f64 * 8.0 / 1024.0;
        assert!((bits - 4.25).abs() < 1e-9, "{bits}");
    }

    #[test]
    fn pack_idempotent_on_qdq_values() {
        let mut rng = Pcg64::seed(12);
        let cfg = MxConfig::from_name("mxfp4", Some(16)).unwrap();
        let x = mx_qdq(&rng.normal_vec(64, 2.0), 64, &cfg);
        let p = PackedMx::pack(&x, cfg);
        assert_eq!(p.unpack(), x);
    }
}
