//! True bit-packed MX storage: two 4-bit element codes per byte plus one
//! E8M0 scale byte per block. This is what an MXFP4/MXINT4 tensor costs in
//! memory (4.25 bits/elem at B=32) — used by the footprint accounting in
//! `quantize-info` and by the codec throughput benches in the perf pass.
//!
//! Hot-path layout choices (property-tested bit-exact against the scalar
//! loops in `mx::reference`):
//! - encode walks byte pairs (`chunks_exact(2)`) — no per-element `idx % 2`
//!   nibble branch;
//! - the block scale is applied as a multiply by its exact power-of-two
//!   inverse instead of a division;
//! - decode reads two elements per packed byte from the 256-entry LUTs in
//!   [`super::formats`];
//! - blocks fan out over the scoped thread pool (`util::par`) above
//!   [`crate::util::par::PAR_MIN_LEN`] elements.

use super::formats::{
    exp2i, exp2i_ext, floor_log2, fp4_encode, fp4_pair_lut, int4_encode, int4_pair_lut,
};
use super::quantize::{MxConfig, SCALE_EMAX, SCALE_EMIN};
use crate::util::par;

/// A bit-packed MX tensor (4-bit element formats only).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMx {
    pub cfg: MxConfig,
    pub len: usize,
    /// One E8M0 byte per block: biased exponent (e + 127).
    pub scales: Vec<u8>,
    /// Two element codes per byte, low nibble first.
    pub codes: Vec<u8>,
}

impl PackedMx {
    /// Pack `x` (blocks along the flat axis). Requires a single-level
    /// 4-bit element format — the guard is structural (`element.bits == 4`)
    /// so future 4-bit formats pack without touching this codec; NVFP4 is
    /// excluded because its second-level FP8 scale does not fit the E8M0
    /// scale byte.
    pub fn pack(x: &[f32], cfg: MxConfig) -> PackedMx {
        assert!(
            cfg.element.bits == 4 && !cfg.nv && cfg.name != "none",
            "pack: single-level 4-bit element formats only, got {}",
            cfg.name
        );
        assert_eq!(x.len() % cfg.block_size, 0);
        if cfg.block_size % 2 != 0 {
            // odd block sizes straddle byte boundaries; the scalar
            // reference's global idx%2 indexing handles them (off any hot
            // path — real MX blocks are 16/32)
            let (scales, codes) = super::reference::pack_ref(x, &cfg);
            return PackedMx { cfg, len: x.len(), scales, codes };
        }
        let b = cfg.block_size;
        let nb = x.len() / b;
        let mut scales = vec![0u8; nb];
        let mut codes = vec![0u8; x.len() / 2];
        let is_fp = cfg.element.is_fp;
        let emax = cfg.element.emax;
        let encode = move |v: f32| if is_fp { fp4_encode(v) } else { int4_encode(v) };
        let do_block = |bi: usize, scale: &mut u8, cbytes: &mut [u8]| {
            let block = &x[bi * b..(bi + 1) * b];
            let amax = block.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let e = if amax > 0.0 {
                (floor_log2(amax) - emax).clamp(SCALE_EMIN, SCALE_EMAX)
            } else {
                0
            };
            *scale = (e + 127) as u8;
            let s = exp2i(e);
            if s == 0.0 {
                // denormal-range block: keep the reference division semantics
                for (pair, byte) in block.chunks_exact(2).zip(cbytes.iter_mut()) {
                    *byte = encode(pair[0] / s) | (encode(pair[1] / s) << 4);
                }
            } else {
                let s_inv = exp2i_ext(-e);
                for (pair, byte) in block.chunks_exact(2).zip(cbytes.iter_mut()) {
                    *byte = encode(pair[0] * s_inv) | (encode(pair[1] * s_inv) << 4);
                }
            }
        };
        if x.len() < par::PAR_MIN_LEN {
            for bi in 0..nb {
                let (lo, hi) = (bi * b / 2, (bi + 1) * b / 2);
                do_block(bi, &mut scales[bi], &mut codes[lo..hi]);
            }
        } else {
            par::for_each_chunk2(&mut scales, 1, &mut codes, b / 2, |bi, sc, cb| {
                do_block(bi, &mut sc[0], cb)
            });
        }
        PackedMx { cfg, len: x.len(), scales, codes }
    }

    /// Unpack to f32 (the dequantized values).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.unpack_into(&mut out);
        out
    }

    /// Unpack into a preallocated buffer (hot-path variant): one LUT load
    /// per packed byte, two multiplies out.
    pub fn unpack_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        let b = self.cfg.block_size;
        if b % 2 != 0 {
            let v = super::reference::unpack_ref(&self.cfg, self.len, &self.scales, &self.codes);
            out.copy_from_slice(&v);
            return;
        }
        let lut = if self.cfg.element.is_fp { fp4_pair_lut() } else { int4_pair_lut() };
        let scales = &self.scales;
        let codes = &self.codes;
        let do_block = |bi: usize, chunk: &mut [f32]| {
            let s = exp2i(scales[bi] as i32 - 127);
            let cb = &codes[bi * b / 2..bi * b / 2 + chunk.len() / 2];
            for (pair, byte) in chunk.chunks_exact_mut(2).zip(cb) {
                let d = &lut[*byte as usize];
                pair[0] = d[0] * s;
                pair[1] = d[1] * s;
            }
        };
        if out.len() < par::PAR_MIN_LEN {
            for (bi, chunk) in out.chunks_mut(b).enumerate() {
                do_block(bi, chunk);
            }
        } else {
            par::for_each_chunk(out, b, do_block);
        }
    }

    /// Total packed bytes (codes + scales).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.scales.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::quantize::mx_qdq;
    use crate::mx::reference;
    use crate::util::Pcg64;

    #[test]
    fn pack_unpack_equals_qdq() {
        let mut rng = Pcg64::seed(11);
        for name in ["mxfp4", "mxint4"] {
            let cfg = MxConfig::from_name(name, Some(32)).unwrap();
            let x = rng.normal_vec(256, 4.0);
            let packed = PackedMx::pack(&x, cfg);
            let unpacked = packed.unpack();
            let qdq = mx_qdq(&x, 256, &cfg);
            for (i, (a, b)) in unpacked.iter().zip(&qdq).enumerate() {
                assert!((a - b).abs() < 1e-6, "{name} idx {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn footprint_is_4_25_bits() {
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let x = vec![1.0f32; 1024];
        let p = PackedMx::pack(&x, cfg);
        let bits = p.bytes() as f64 * 8.0 / 1024.0;
        assert!((bits - 4.25).abs() < 1e-9, "{bits}");
    }

    #[test]
    fn pack_idempotent_on_qdq_values() {
        let mut rng = Pcg64::seed(12);
        let cfg = MxConfig::from_name("mxfp4", Some(16)).unwrap();
        let x = mx_qdq(&rng.normal_vec(64, 2.0), 64, &cfg);
        let p = PackedMx::pack(&x, cfg);
        assert_eq!(p.unpack(), x);
    }

    #[test]
    fn matches_scalar_reference_bits() {
        let mut rng = Pcg64::seed(13);
        for name in ["mxfp4", "mxint4"] {
            let cfg = MxConfig::from_name(name, Some(32)).unwrap();
            let x = rng.normal_vec(2048, 3.0);
            let p = PackedMx::pack(&x, cfg);
            let (scales, codes) = reference::pack_ref(&x, &cfg);
            assert_eq!(p.scales, scales, "{name} scales");
            assert_eq!(p.codes, codes, "{name} codes");
            let un = p.unpack();
            let un_ref = reference::unpack_ref(&cfg, x.len(), &scales, &codes);
            for (i, (a, b)) in un.iter().zip(&un_ref).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{name} idx {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn odd_block_size_still_packs() {
        // pre-existing behavior: odd block sizes straddle code bytes
        let mut rng = Pcg64::seed(14);
        let mut cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        cfg.block_size = 31;
        let x = rng.normal_vec(31 * 5, 2.0);
        let p = PackedMx::pack(&x, cfg);
        let (scales, codes) = reference::pack_ref(&x, &cfg);
        assert_eq!(p.scales, scales);
        assert_eq!(p.codes, codes);
        let un_ref = reference::unpack_ref(&cfg, x.len(), &scales, &codes);
        for (a, b) in p.unpack().iter().zip(&un_ref) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "4-bit")]
    fn six_bit_formats_rejected() {
        let cfg = MxConfig::from_name("mxfp6", Some(32)).unwrap();
        PackedMx::pack(&[0.0; 32], cfg);
    }

    #[test]
    #[should_panic(expected = "4-bit")]
    fn nvfp4_two_level_rejected() {
        let cfg = MxConfig::from_name("nvfp4", Some(16)).unwrap();
        PackedMx::pack(&[0.0; 32], cfg);
    }
}
