//! Microscaling (MX) data-format substrate — request-path Rust side.
//!
//! Mirrors `python/compile/mx/` bit-for-bit for the E8M0-scaled formats
//! (cross-checked against golden files in `rust/tests/golden_mx.rs`); the
//! NVFP4 path divides by non-power-of-two scales and is checked to 1-2 ULP.
//!
//! Four layers:
//! - [`formats`] — element codecs (FP4 E2M1 / INT4 / FP6 E2M3 / FP8 E4M3),
//!   plus the branchless encoders and byte-pair decode LUTs the hot path
//!   uses.
//! - [`quantize`] — block quantize-dequantize (Eq. 1 of the paper),
//!   exponent-arithmetic scales, parallel over blocks.
//! - [`pack`] — true bit-packed storage (4-bit nibbles + E8M0 scale bytes),
//!   used for footprint accounting and the codec throughput benches.
//! - [`page`] — page-granular row encode/decode for the paged KV cache
//!   (quantize-on-write, LUT decode on gather).
//! - [`reference`] — the retained scalar implementation, the bit-exactness
//!   oracle for the fast path.

pub mod formats;
pub mod pack;
pub mod page;
pub mod quantize;
pub mod reference;

pub use formats::{ElementFormat, FP4_E2M1, FP6_E2M3, FP8_E4M3, INT4};
pub use pack::PackedMx;
pub use quantize::{mx_qdq, mx_qdq_rows, MxConfig};
