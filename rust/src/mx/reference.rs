//! Retained scalar reference codec — the pre-LUT, pre-thread-pool
//! implementation kept verbatim as the bit-exactness oracle for the
//! optimized hot path (`rust/tests/codec_props.rs` asserts the fast codec
//! agrees bit-for-bit on every format, block size, and edge input).
//!
//! Nothing here runs on a hot path (the fast codec only delegates here for
//! odd block sizes, which real MX configs never use); do not "optimize"
//! this module — its value is that it stays the naive per-element
//! division/branch code the Python mirror was validated against.

use super::formats::{
    element_qdq, exp2i, floor_log2, fp4_decode, fp_qdq, int4_decode, int_qdq, ElementFormat,
    FP4_E2M1, FP8_E4M3, INT4,
};
use super::quantize::{block_scale, nv_tensor_scale, MxConfig, SCALE_EMAX, SCALE_EMIN};

/// Scalar compare-chain FP4 encoder (original implementation).
pub fn fp4_encode_ref(v: f32) -> u8 {
    let q = fp_qdq(v, FP4_E2M1);
    let sign = if q.is_sign_negative() && q != 0.0 { 8u8 } else { 0 };
    let a = q.abs();
    // grid: 0, .5, 1, 1.5, 2, 3, 4, 6 -> codes 0..7
    let code = match a {
        x if x < 0.25 => 0,
        x if x < 0.75 => 1,
        x if x < 1.25 => 2,
        x if x < 1.75 => 3,
        x if x < 2.5 => 4,
        x if x < 3.5 => 5,
        x if x < 5.0 => 6,
        _ => 7,
    };
    sign | code
}

/// Scalar INT4 encoder (original implementation).
pub fn int4_encode_ref(v: f32) -> u8 {
    (int_qdq(v, INT4) as i32 & 0xf) as u8
}

/// QDQ one block, per-element division by the block scale (original).
pub fn qdq_block_ref(x: &mut [f32], cfg: &MxConfig, nv_tensor_scale: f32) {
    let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if cfg.nv {
        let ts = nv_tensor_scale;
        let s0 = fp_qdq(amax / (FP4_E2M1.maxval() * ts), FP8_E4M3);
        let s = if s0 > 0.0 { s0 } else { 1.0 } * ts;
        for v in x.iter_mut() {
            *v = s * fp_qdq(*v / s, FP4_E2M1);
        }
    } else {
        let s = block_scale(amax, cfg.element.emax);
        for v in x.iter_mut() {
            *v = s * element_qdq(*v / s, cfg.element);
        }
    }
}

/// Serial row/block QDQ loop (original).
pub fn mx_qdq_rows_ref(x: &mut [f32], row_len: usize, cfg: &MxConfig) {
    if cfg.name == "none" {
        return;
    }
    assert_eq!(x.len() % row_len, 0);
    assert_eq!(row_len % cfg.block_size, 0, "row {row_len} vs block {}", cfg.block_size);
    let ts = if cfg.nv { nv_tensor_scale(x) } else { 1.0 };
    for row in x.chunks_mut(row_len) {
        for block in row.chunks_mut(cfg.block_size) {
            qdq_block_ref(block, cfg, ts);
        }
    }
}

/// QDQ a copy through the scalar reference.
pub fn mx_qdq_ref(x: &[f32], row_len: usize, cfg: &MxConfig) -> Vec<f32> {
    let mut out = x.to_vec();
    mx_qdq_rows_ref(&mut out, row_len, cfg);
    out
}

#[inline]
fn encode_ref(v: f32, fmt: ElementFormat) -> u8 {
    if fmt.is_fp {
        fp4_encode_ref(v)
    } else {
        int4_encode_ref(v)
    }
}

/// Per-element scalar bit-pack (original `PackedMx::pack` loop):
/// returns `(scales, codes)`, one E8M0 byte per block, two nibbles per
/// code byte with the `idx % 2` selection.
pub fn pack_ref(x: &[f32], cfg: &MxConfig) -> (Vec<u8>, Vec<u8>) {
    assert_eq!(x.len() % cfg.block_size, 0);
    let nb = x.len() / cfg.block_size;
    let mut scales = Vec::with_capacity(nb);
    let mut codes = vec![0u8; (x.len() + 1) / 2];
    for (bi, block) in x.chunks(cfg.block_size).enumerate() {
        let amax = block.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let e = if amax > 0.0 {
            (floor_log2(amax) - cfg.element.emax).clamp(SCALE_EMIN, SCALE_EMAX)
        } else {
            0
        };
        scales.push((e + 127) as u8);
        let s = exp2i(e);
        let base = bi * cfg.block_size;
        for (j, &v) in block.iter().enumerate() {
            let code = encode_ref(v / s, cfg.element);
            let idx = base + j;
            if idx % 2 == 0 {
                codes[idx / 2] |= code;
            } else {
                codes[idx / 2] |= code << 4;
            }
        }
    }
    (scales, codes)
}

/// Per-element scalar unpack (original `PackedMx::unpack_into` loop).
pub fn unpack_ref(cfg: &MxConfig, len: usize, scales: &[u8], codes: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; len];
    let b = cfg.block_size;
    let is_fp = cfg.element.is_fp;
    for (bi, chunk) in out.chunks_mut(b).enumerate() {
        let s = exp2i(scales[bi] as i32 - 127);
        let base = bi * b;
        for (j, o) in chunk.iter_mut().enumerate() {
            let idx = base + j;
            let byte = codes[idx / 2];
            let code = if idx % 2 == 0 { byte & 0xf } else { byte >> 4 };
            let v = if is_fp { fp4_decode(code) } else { int4_decode(code) };
            *o = v * s;
        }
    }
    out
}
