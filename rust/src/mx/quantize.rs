//! Block quantize-dequantize (Eq. 1): shared power-of-two (E8M0) scale per
//! block + element codec, plus the NVFP4 two-level variant.

use super::formats::{
    element_qdq, exp2i, exp2i_ext, floor_log2, fp_qdq, ElementFormat, FP4_E2M1, FP6_E2M3,
    FP8_E4M3, INT4,
};
use crate::util::par;

pub const SCALE_EMIN: i32 = -127;
pub const SCALE_EMAX: i32 = 127;

/// Full MX tensor-quantization configuration (mirror of python `MXConfig`).
///
/// ```
/// use latmix::mx::{mx_qdq, MxConfig};
/// let cfg = MxConfig::from_name("mxfp4", None).unwrap();
/// assert_eq!((cfg.block_size, cfg.element.bits), (32, 4));
/// // 4-bit elements + one shared 8-bit scale per 32-element block (Eq. 1)
/// assert!((cfg.bits_per_element() - 4.25).abs() < 1e-12);
/// // quantization is idempotent: the representable grid maps to itself
/// let x: Vec<f32> = (0..32).map(|i| i as f32 / 7.0).collect();
/// let q = mx_qdq(&x, 32, &cfg);
/// assert_eq!(mx_qdq(&q, 32, &cfg), q);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MxConfig {
    pub name: &'static str,
    pub element: ElementFormat,
    pub block_size: usize,
    pub nv: bool,
}

impl MxConfig {
    pub fn from_name(name: &str, block_size: Option<usize>) -> anyhow::Result<MxConfig> {
        let bs = block_size;
        let cfg = |name, element, block_size, nv| MxConfig { name, element, block_size, nv };
        Ok(match name {
            "none" => cfg("none", FP4_E2M1, bs.unwrap_or(32), false),
            "mxfp4" => cfg("mxfp4", FP4_E2M1, bs.unwrap_or(32), false),
            "mxint4" => cfg("mxint4", INT4, bs.unwrap_or(32), false),
            "mxfp6" => cfg("mxfp6", FP6_E2M3, bs.unwrap_or(32), false),
            "mxfp8" => cfg("mxfp8", FP8_E4M3, bs.unwrap_or(32), false),
            "nvfp4" => cfg("nvfp4", FP4_E2M1, bs.unwrap_or(16), true),
            other => anyhow::bail!("unknown quant format {other:?}"),
        })
    }

    /// Storage bits per element including the amortized shared scale.
    pub fn bits_per_element(&self) -> f64 {
        if self.name == "none" {
            return 32.0;
        }
        self.element.bits as f64 + 8.0 / self.block_size as f64
    }
}

/// Shared E8M0 scale exponent of one block from its abs-max (Eq. 1).
#[inline]
pub fn block_scale_exp(amax: f32, emax: i32) -> i32 {
    (floor_log2(amax) - emax).clamp(SCALE_EMIN, SCALE_EMAX)
}

/// Shared E8M0 scale of one block from its abs-max (Eq. 1).
#[inline]
pub fn block_scale(amax: f32, emax: i32) -> f32 {
    if amax <= 0.0 {
        return 1.0;
    }
    exp2i(block_scale_exp(amax, emax))
}

/// QDQ one contiguous block in place.
///
/// Hot path: the per-element `v / s` division is replaced with a multiply
/// by the exact power-of-two inverse `2^-e` — bit-identical (both are the
/// correctly-rounded value of the same real quotient) and ~4x cheaper per
/// element. The reference division loop survives in `mx::reference` and is
/// property-tested against this.
pub fn qdq_block(x: &mut [f32], cfg: &MxConfig, nv_tensor_scale: f32) {
    let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if cfg.nv {
        // non-power-of-two scale: division semantics must stay as-is
        let s = nv_block_scale(amax, nv_tensor_scale);
        for v in x.iter_mut() {
            *v = s * fp_qdq(*v / s, FP4_E2M1);
        }
        return;
    }
    let (e, s) = if amax > 0.0 {
        let e = block_scale_exp(amax, cfg.element.emax);
        (e, exp2i(e))
    } else {
        (0, 1.0)
    };
    if s == 0.0 {
        // e == SCALE_EMIN: 2^-127 underflows the E8M0 bit construction to
        // 0.0; keep the reference division-by-zero semantics for this
        // denormal-range block (rare, off any real hot path).
        for v in x.iter_mut() {
            *v = s * element_qdq(*v / s, cfg.element);
        }
    } else {
        let s_inv = exp2i_ext(-e);
        for v in x.iter_mut() {
            *v = s * element_qdq(*v * s_inv, cfg.element);
        }
    }
}

/// NVFP4 per-block scale: the E4M3-quantized ratio of the block abs-max
/// to the FP4 range, times the second-level per-tensor scale. The single
/// source of truth shared by [`qdq_block`]'s NVFP4 branch and
/// [`block_clip_threshold`].
#[inline]
pub fn nv_block_scale(amax: f32, tensor_scale: f32) -> f32 {
    let s0 = fp_qdq(amax / (FP4_E2M1.maxval() * tensor_scale), FP8_E4M3);
    let s = if s0 > 0.0 { s0 } else { 1.0 };
    s * tensor_scale
}

/// Per-block clipping knee of the Eq. 1 quantizer, from the block's
/// abs-max: elements with `|v| <= threshold` land on the in-range part of
/// the element grid; larger magnitudes saturate to `scale * maxval`. Used
/// by the `latmix` clipped-STE backward (Sec. 3.2) to gate gradient flow
/// through the fake quantizer; built from the same scale helpers
/// ([`block_scale`] / [`nv_block_scale`]) as [`qdq_block`], so knee and
/// quantizer cannot drift apart (pass `nv_tensor_scale(x)` for NVFP4,
/// `1.0` otherwise).
pub fn block_clip_threshold(amax: f32, cfg: &MxConfig, nv_tensor_scale: f32) -> f32 {
    if cfg.nv {
        return nv_block_scale(amax, nv_tensor_scale) * FP4_E2M1.maxval();
    }
    block_scale(amax, cfg.element.emax) * cfg.element.maxval()
}

/// NVFP4 second-level per-tensor scale (mirror of python `nv_tensor_scale`).
pub fn nv_tensor_scale(x: &[f32]) -> f32 {
    let tmax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if tmax > 0.0 {
        tmax / (FP4_E2M1.maxval() * FP8_E4M3.maxval())
    } else {
        1.0
    }
}

/// QDQ a flat tensor whose last axis is `row_len`, blocks along that axis.
///
/// Blocks are independent given the (tensor-wide) NVFP4 scale, so large
/// tensors fan blocks out over the scoped thread pool; the contiguous
/// partition makes the result bit-identical for any worker count.
pub fn mx_qdq_rows(x: &mut [f32], row_len: usize, cfg: &MxConfig) {
    if cfg.name == "none" {
        return;
    }
    assert_eq!(x.len() % row_len, 0);
    assert_eq!(row_len % cfg.block_size, 0, "row {row_len} vs block {}", cfg.block_size);
    let ts = if cfg.nv { nv_tensor_scale(x) } else { 1.0 };
    if x.len() < par::PAR_MIN_LEN {
        for block in x.chunks_mut(cfg.block_size) {
            qdq_block(block, cfg, ts);
        }
    } else {
        par::for_each_chunk(x, cfg.block_size, |_, block| qdq_block(block, cfg, ts));
    }
}

/// Convenience: QDQ a copy.
pub fn mx_qdq(x: &[f32], row_len: usize, cfg: &MxConfig) -> Vec<f32> {
    let mut out = x.to_vec();
    mx_qdq_rows(&mut out, row_len, cfg);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn zero_block_is_zero() {
        let mut x = vec![0.0f32; 32];
        qdq_block(&mut x, &MxConfig::from_name("mxfp4", None).unwrap(), 1.0);
        assert!(x.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn scale_is_power_of_two() {
        assert_eq!(block_scale(6.0, 2), 1.0); // floor(log2 6)=2 -> 2^(2-2)
        assert_eq!(block_scale(1.0, 2), 0.25);
        assert_eq!(block_scale(8.0, 2), 2.0);
    }

    #[test]
    fn qdq_idempotent_fp4() {
        let mut rng = Pcg64::seed(9);
        let cfg = MxConfig::from_name("mxfp4", Some(16)).unwrap();
        let x = rng.normal_vec(128, 3.0);
        let q1 = mx_qdq(&x, 64, &cfg);
        let q2 = mx_qdq(&q1, 64, &cfg);
        assert_eq!(q1, q2);
    }

    #[test]
    fn error_bounded() {
        let mut rng = Pcg64::seed(10);
        for name in ["mxfp4", "mxint4", "mxfp6", "mxfp8"] {
            let cfg = MxConfig::from_name(name, Some(32)).unwrap();
            let x = rng.normal_vec(256, 10.0);
            let q = mx_qdq(&x, 256, &cfg);
            for (block_x, block_q) in x.chunks(32).zip(q.chunks(32)) {
                let amax = block_x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                for (a, b) in block_x.iter().zip(block_q) {
                    assert!((a - b).abs() <= amax * 0.5 + 1e-6);
                }
            }
        }
    }

    #[test]
    fn clip_threshold_bounds_qdq_output() {
        // |Q(v)| never exceeds the block's clipping knee, and the knee is
        // itself representable (saturating inputs hit it exactly).
        let mut rng = Pcg64::seed(11);
        for name in ["mxfp4", "mxint4", "mxfp6", "mxfp8", "nvfp4"] {
            let cfg = MxConfig::from_name(name, Some(16)).unwrap();
            let x = rng.normal_vec(256, 8.0);
            let ts = if cfg.nv { nv_tensor_scale(&x) } else { 1.0 };
            let q = mx_qdq(&x, 256, &cfg);
            for (bx, bq) in x.chunks(16).zip(q.chunks(16)) {
                let amax = bx.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let thr = block_clip_threshold(amax, &cfg, ts);
                // int formats reach -(maxval + 1) on the negative side
                let slack = if cfg.element.is_fp { 1.0 } else { 8.0 / 7.0 };
                for v in bq {
                    assert!(v.abs() <= thr * slack * (1.0 + 1e-6), "{v} vs {thr} ({name})");
                }
            }
        }
    }

    #[test]
    fn bits_accounting() {
        let c = MxConfig::from_name("mxfp4", None).unwrap();
        assert!((c.bits_per_element() - 4.25).abs() < 1e-9);
        let n = MxConfig::from_name("nvfp4", None).unwrap();
        assert!((n.bits_per_element() - 4.5).abs() < 1e-9);
    }
}
