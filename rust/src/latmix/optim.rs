//! AdamW + cosine learning-rate schedule (App. D.1: AdamW with decoupled
//! weight decay, linear warmup, cosine decay) — the Rust mirror of
//! `python/compile/optim.py`, driving the Sec. 3.2 transform-learning loop
//! in [`super`].

/// AdamW optimizer state over one flat `f32` parameter vector.
///
/// Mirrors `python/compile/optim.py::adamw_update` exactly: bias-corrected
/// first/second moments (Loshchilov & Hutter 2019), decoupled weight decay
/// applied as `lr * wd * p`.
///
/// ```
/// use latmix::latmix::{cosine_lr, AdamW};
/// // Minimize f(p) = p^2 starting from p = 1; the gradient is 2p.
/// let mut p = vec![1.0f32];
/// let mut opt = AdamW::new(1);
/// for step in 0..100 {
///     let g = [2.0 * p[0]];
///     opt.update(&mut p, &g, cosine_lr(step, 100, 0.1, 10), 0.0);
/// }
/// assert!(p[0].abs() < 0.05, "did not converge: {}", p[0]);
/// ```
#[derive(Clone, Debug)]
pub struct AdamW {
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
    /// First-moment decay (default 0.9).
    pub b1: f32,
    /// Second-moment decay (default 0.999).
    pub b2: f32,
    /// Denominator fuzz (default 1e-8).
    pub eps: f32,
}

impl AdamW {
    /// Zero-initialized state for `n` parameters.
    pub fn new(n: usize) -> AdamW {
        AdamW { m: vec![0.0; n], v: vec![0.0; n], t: 0, b1: 0.9, b2: 0.999, eps: 1e-8 }
    }

    /// Number of parameters this state covers.
    pub fn len(&self) -> usize {
        self.m.len()
    }

    /// True when covering zero parameters.
    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }

    /// One update step in place:
    /// `p -= lr * m_hat / (sqrt(v_hat) + eps) + lr * wd * p`.
    pub fn update(&mut self, params: &mut [f32], grads: &[f32], lr: f32, wd: f32) {
        assert_eq!(params.len(), self.m.len(), "param count mismatch");
        assert_eq!(grads.len(), self.m.len(), "grad count mismatch");
        self.t += 1;
        let bc1 = 1.0 - self.b1.powi(self.t);
        let bc2 = 1.0 - self.b2.powi(self.t);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            *m = self.b1 * *m + (1.0 - self.b1) * g;
            *v = self.b2 * *v + (1.0 - self.b2) * g * g;
            let step = lr * (*m / bc1) / ((*v / bc2).sqrt() + self.eps);
            *p -= step + lr * wd * *p;
        }
    }
}

/// Linear warmup (`0.1 -> 1` over `warmup` steps) then cosine decay to
/// `0.1 * base_lr` — mirror of `python/compile/optim.py::cosine_lr`.
pub fn cosine_lr(step: usize, total_steps: usize, base_lr: f32, warmup: usize) -> f32 {
    const START: f32 = 0.1;
    let s = step as f32;
    let w = warmup as f32;
    if step < warmup {
        base_lr * (START + (1.0 - START) * s / w.max(1.0))
    } else {
        let denom = total_steps.saturating_sub(warmup).max(1) as f32;
        let prog = ((s - w) / denom).clamp(0.0, 1.0);
        let cos = 0.1 + 0.9 * 0.5 * (1.0 + (std::f32::consts::PI * prog).cos());
        base_lr * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_descends_quadratic() {
        // f(p) = sum (p_i - c_i)^2 converges to c from a distant start.
        let target = [3.0f32, -2.0, 0.5];
        let mut p = vec![0.0f32; 3];
        let mut opt = AdamW::new(3);
        for step in 0..400 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(pi, ci)| 2.0 * (pi - ci)).collect();
            opt.update(&mut p, &g, cosine_lr(step, 400, 0.05, 40), 0.0);
        }
        for (pi, ci) in p.iter().zip(&target) {
            assert!((pi - ci).abs() < 0.05, "{pi} vs {ci}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        // Zero gradient, pure decay: p decays geometrically by (1 - lr*wd).
        let mut p = vec![1.0f32];
        let mut opt = AdamW::new(1);
        for _ in 0..10 {
            opt.update(&mut p, &[0.0], 0.1, 0.5);
        }
        let expect = (1.0f32 - 0.1 * 0.5).powi(10);
        assert!((p[0] - expect).abs() < 1e-5, "{} vs {expect}", p[0]);
    }

    #[test]
    fn cosine_schedule_shape() {
        let base = 1.0f32;
        // warmup starts at 0.1 * base and rises
        assert!((cosine_lr(0, 100, base, 10) - 0.1).abs() < 1e-6);
        assert!(cosine_lr(5, 100, base, 10) > cosine_lr(0, 100, base, 10));
        // peak at end of warmup
        assert!((cosine_lr(10, 100, base, 10) - 1.0).abs() < 1e-6);
        // decays monotonically to 0.1 * base
        assert!(cosine_lr(50, 100, base, 10) < 1.0);
        assert!((cosine_lr(100, 100, base, 10) - 0.1).abs() < 1e-3);
        // zero-warmup edge: step 0 is the cosine peak, no division blowup
        assert!((cosine_lr(0, 10, base, 0) - 1.0).abs() < 1e-6);
    }
}
