//! Synthetic feature generators for the paper's numerical studies: the
//! Sec. 3.1 Dirac-delta illustration and the Fig. 2 outlier-channel
//! setting. Shared by the property tests, the microbench `fig2_learned`
//! row, and the `latmix learn --features dirac|outlier` CLI path, so all
//! three exercise the same distributions.

use crate::util::Pcg64;

/// Fig. 2-style features: i.i.d. `N(0, sigma^2)` rows with two planted
/// massive-outlier channels (the residual-stream pattern Sec. 3.1 argues
/// breaks per-block scaling) — channel `3 mod d` at `+20` and channel
/// `5d/8` at `-12`.
pub fn outlier_features(rows: usize, d: usize, sigma: f32, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::seed(seed);
    let mut x = rng.normal_vec(rows * d, sigma);
    let (c1, c2) = (3 % d, 5 * d / 8);
    for r in 0..rows {
        x[r * d + c1] = 20.0 + rng.normal();
        if c2 != c1 {
            x[r * d + c2] = -12.0 + 0.5 * rng.normal();
        }
    }
    x
}

/// Sec. 3.1 Dirac-delta features: near-zero rows with a single spike
/// channel at magnitude 10 — the worked example where one outlier forces
/// the whole block's scale up and flushes every small element to zero.
pub fn dirac_features(rows: usize, d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::seed(seed);
    let mut x = rng.normal_vec(rows * d, 0.05);
    for r in 0..rows {
        x[r * d] = 10.0 + 0.1 * rng.normal();
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outlier_channels_are_planted() {
        let d = 64;
        let x = outlier_features(16, d, 0.05, 1);
        assert_eq!(x.len(), 16 * d);
        for r in 0..16 {
            assert!(x[r * d + 3] > 15.0);
            assert!(x[r * d + 40] < -9.0);
            assert!(x[r * d + 10].abs() < 1.0);
        }
    }

    #[test]
    fn dirac_spike_dominates() {
        let d = 32;
        let x = dirac_features(8, d, 2);
        for r in 0..8 {
            let row = &x[r * d..(r + 1) * d];
            let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            assert!((amax - row[0].abs()).abs() < 1e-6, "spike must be the max");
            assert!(row[0] > 9.0);
        }
    }
}
