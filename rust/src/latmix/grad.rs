//! Hand-derived reverse-mode gradients for the Sec. 3.2 feature objective:
//! the transformation MSE `E(T)` (Eq. 2) differentiated through the affine
//! map, the matrix inverse, and the MX fake quantizer with a *clipped*
//! straight-through estimator, plus the log-volume regularizer (Eq. 7/9).
//!
//! The forward graph (row-vector convention, `X` is `(n, d)` feature rows):
//!
//! ```text
//! Y    = X A + v                      (transform)
//! Q    = mx_qdq_rows(Y)               (Eq. 1 fake quant, value-exact)
//! back = (Q_ste - v) A^{-1}           (inverse transform)
//! E    = ||back - X||_F^2 / (n d)     (Eq. 2)
//! loss = E + w_of * overflow + lam * (log|det A|)^2
//! ```
//!
//! where `Q_ste` is the clipped STE surrogate: on elements within the
//! per-block clipping knee (`|y| <= scale * maxval`, see
//! [`crate::mx::quantize::block_clip_threshold`]) the quantizer
//! backpropagates as the identity; clipped elements are treated as
//! constants. Plain STE is *degenerate* for `E(T)`: its differentiable
//! path reconstructs `X` exactly (`A` and `A^{-1}` cancel), leaving no
//! signal. Gating on the clipping knee restores the outlier-reduction
//! gradient, and the soft `overflow` penalty
//! (`mean relu(|y| - knee)^2`) steers energy below the knee — the same
//! surrogate as `python/compile/latmix.py::learn_feature_transform`.
//!
//! With `G = dE/d(back)`, `B = A^{-1}` and `M` the not-clipped mask, the
//! closed-form gradients implemented here are:
//!
//! ```text
//! dE/dA = X^T [(G B^T) . M]  -  B^T (Q - v)^T G B^T
//! dE/dv = colsum[(G B^T) . M] - colsum[G B^T]
//! d/dA lam (log|det A|)^2 = 2 lam log|det A| * B^T
//! ```
//!
//! (`.` is elementwise; the overflow term adds
//! `w_of * 2/(nd) * relu(|y| - knee) * sign(y)` into the `Y`-cotangent.)
//! The formulas are finite-difference-checked against the frozen STE
//! surrogate in `rust/tests/latmix_props.rs`.

use crate::linalg::Mat;
use crate::mx::quantize::{block_clip_threshold, nv_tensor_scale};
use crate::mx::{mx_qdq_rows, MxConfig};

/// One evaluation of the Sec. 3.2 objective and its gradients.
#[derive(Clone, Debug)]
pub struct EtGrads {
    /// `E(T)` (Eq. 2) of the current iterate on the batch — the *true*
    /// quantization MSE (the STE changes gradients, not values).
    pub mse: f64,
    /// Full objective: `mse + w_of * overflow + lam * (log|det A|)^2`.
    pub loss: f64,
    /// Cotangent of the transform matrix `A`.
    pub grad_a: Mat,
    /// Cotangent of the bias `v`.
    pub grad_v: Vec<f32>,
}

/// Evaluate loss and hand-derived gradients at `(a, v)` on feature rows
/// `x` (flat, `d` columns). Returns `None` when `a` is numerically
/// singular (the caller should stop and keep its best iterate).
pub fn et_loss_and_grads(
    x: &[f32],
    d: usize,
    a: &Mat,
    v: &[f32],
    cfg: &MxConfig,
    lam: f32,
    overflow_weight: f32,
) -> Option<EtGrads> {
    assert_eq!(a.rows, d, "A dim mismatch");
    assert_eq!(a.cols, d, "A must be square");
    assert_eq!(v.len(), d, "v dim mismatch");
    assert!(d > 0 && x.len() % d == 0, "features not (n, {d})");
    assert!(cfg.block_size > 0 && d % cfg.block_size == 0, "MX block must tile d");
    let n = x.len() / d;
    // one LU factorization yields both the inverse and log|det|
    let (b, logdet) = a.inverse_logdet()?;

    // forward: Y = X A + v, Q = fake-quant(Y), back = (Q - v) B
    let xm = Mat::from_vec(n, d, x.to_vec());
    let mut y = xm.matmul(a);
    for row in y.data.chunks_mut(d) {
        for (yi, vi) in row.iter_mut().zip(v) {
            *yi += *vi;
        }
    }
    let nv_ts = if cfg.nv { nv_tensor_scale(&y.data) } else { 1.0 };
    let bs = cfg.block_size;
    let thr: Vec<f32> = y
        .data
        .chunks(bs)
        .map(|blk| {
            let amax = blk.iter().fold(0.0f32, |m, t| m.max(t.abs()));
            block_clip_threshold(amax, cfg, nv_ts)
        })
        .collect();
    let mut q = y.data.clone();
    mx_qdq_rows(&mut q, d, cfg);
    let mut qmv = Mat::from_vec(n, d, q);
    for row in qmv.data.chunks_mut(d) {
        for (qi, vi) in row.iter_mut().zip(v) {
            *qi -= *vi;
        }
    }
    let back = qmv.matmul(&b);

    // E(T) and its cotangent G = 2/(nd) * (back - X)
    let scale = 2.0 / (n as f64 * d as f64);
    let mut mse = 0.0f64;
    let mut g = Mat::zeros(n, d);
    for ((gi, bi), xi) in g.data.iter_mut().zip(&back.data).zip(&xm.data) {
        let r = (*bi - *xi) as f64;
        mse += r * r;
        *gi = (scale * r) as f32;
    }
    mse /= n as f64 * d as f64;

    let bt = b.t();
    // path through B = A^{-1}: dL/dA = -B^T (Q - v)^T G B^T
    let dldb = qmv.t().matmul(&g);
    let mut grad_a = bt.matmul(&dldb).matmul(&bt).scale(-1.0);
    // path through Q_ste and the overflow penalty: Y-cotangent
    let gq = g.matmul(&bt); // dL/dQ_ste, also the direct -v path below
    let mut gy = Mat::zeros(n, d);
    let mut overflow = 0.0f64;
    let of_scale = (overflow_weight as f64 * scale) as f32;
    for i in 0..y.data.len() {
        let yi = y.data[i];
        let t = thr[i / bs];
        if yi.abs() <= t {
            gy.data[i] = gq.data[i];
        }
        let over = yi.abs() - t;
        if over > 0.0 {
            overflow += (over as f64) * (over as f64);
            gy.data[i] += of_scale * over * yi.signum();
        }
    }
    overflow /= n as f64 * d as f64;
    grad_a = grad_a.add(&xm.t().matmul(&gy));
    // volume regularizer (Eq. 7/9, log form): d/dA (log|det A|)^2 = 2 log|det A| B^T
    let reg_coeff = (2.0 * lam as f64 * logdet) as f32;
    grad_a = grad_a.add(&bt.scale(reg_coeff));

    // dL/dv: + colsum(Gy) from the Y path, - colsum(G B^T) from `back`
    let mut grad_v = vec![0.0f32; d];
    for (gy_row, gq_row) in gy.data.chunks(d).zip(gq.data.chunks(d)) {
        for ((gv, gyi), gqi) in grad_v.iter_mut().zip(gy_row).zip(gq_row) {
            *gv += gyi - gqi;
        }
    }

    let loss = mse + overflow_weight as f64 * overflow + lam as f64 * logdet * logdet;
    Some(EtGrads { mse, loss, grad_a, grad_v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn setup(d: usize, n: usize, seed: u64) -> (Vec<f32>, Mat, Vec<f32>) {
        let mut rng = Pcg64::seed(seed);
        let mut x = rng.normal_vec(n * d, 1.0);
        for r in 0..n {
            x[r * d + 2] += 8.0; // force clipping structure
        }
        let mut a = Mat::eye(d);
        for e in a.data.iter_mut() {
            *e += 0.05 * rng.normal();
        }
        let v = rng.normal_vec(d, 0.1);
        (x, a, v)
    }

    #[test]
    fn mse_matches_transformation_mse() {
        // The value path of the STE surrogate is the true E(T).
        let (x, a, v) = setup(8, 12, 1);
        let cfg = MxConfig::from_name("mxfp4", Some(4)).unwrap();
        let g = et_loss_and_grads(&x, 8, &a, &v, &cfg, 0.1, 0.1).unwrap();
        let t = crate::transform::Affine::new(a, v).unwrap();
        let direct = crate::transform::transformation_mse(&x, 8, &t, &cfg);
        assert!((g.mse - direct).abs() < 1e-4 * direct.max(1e-6), "{} vs {direct}", g.mse);
    }

    #[test]
    fn singular_matrix_returns_none() {
        let (x, _, v) = setup(8, 4, 2);
        let cfg = MxConfig::from_name("mxfp4", Some(4)).unwrap();
        let a = Mat::zeros(8, 8);
        assert!(et_loss_and_grads(&x, 8, &a, &v, &cfg, 0.1, 0.1).is_none());
    }

    #[test]
    fn exactly_representable_input_gives_regularizer_only_gradient() {
        // x in {-0.25, +0.25}: block amax is a power of two, 0.25/s = 4 is
        // on the FP4 grid, and the knee (6s) is not reached — so Q == Y,
        // E(T) == 0, and the degenerate-STE cancellation (A against
        // A^{-1}) is exact: every gradient except the regularizer's is 0.
        let mut rng = Pcg64::seed(3);
        let d = 8;
        let x: Vec<f32> = (0..d * 6)
            .map(|_| if rng.below(2) == 0 { 0.25 } else { -0.25 })
            .collect();
        let a = Mat::eye(d);
        let v = vec![0.0f32; d];
        let cfg = MxConfig::from_name("mxfp4", Some(4)).unwrap();
        let g = et_loss_and_grads(&x, d, &a, &v, &cfg, 0.0, 0.1).unwrap();
        assert!(g.mse == 0.0, "grid points must round-trip: {}", g.mse);
        for gv in &g.grad_v {
            assert!(gv.abs() < 1e-7, "bias grad should cancel: {gv}");
        }
        for ga in &g.grad_a.data {
            assert!(ga.abs() < 1e-6, "lam = 0: A grad should cancel: {ga}");
        }
    }

    #[test]
    fn volume_regularizer_gradient_only() {
        // On an exactly-reconstructing config (no clipping, lam > 0) the A
        // gradient reduces to 2 lam log|det A| A^{-T}; check against the
        // closed form for a diagonal matrix.
        let d = 4;
        let x = vec![0.01f32; d * 4];
        let mut a = Mat::eye(d);
        a[(0, 0)] = 2.0; // log|det| = ln 2
        let v = vec![0.0f32; d];
        let cfg = MxConfig::from_name("mxfp4", Some(4)).unwrap();
        let lam = 0.5f32;
        let g = et_loss_and_grads(&x, d, &a, &v, &cfg, lam, 0.0).unwrap();
        let logdet = 2.0f64.ln();
        // A^{-T} diagonal: [1/2, 1, 1, 1]
        let expect00 = (2.0 * lam as f64 * logdet * 0.5) as f32;
        let expect11 = (2.0 * lam as f64 * logdet) as f32;
        assert!((g.grad_a[(0, 0)] - expect00).abs() < 1e-4, "{}", g.grad_a[(0, 0)]);
        assert!((g.grad_a[(1, 1)] - expect11).abs() < 1e-4, "{}", g.grad_a[(1, 1)]);
        assert!(g.grad_a[(0, 1)].abs() < 1e-4);
        // with overflow_weight = 0 the objective decomposes exactly
        assert!((g.loss - (g.mse + lam as f64 * logdet * logdet)).abs() < 1e-12);
        assert!(g.mse < 1e-4, "tiny inputs: residual quant error only, got {}", g.mse);
    }
}
