//! Rust-native LATMiX transform learning (Sec. 3.2, Fig. 2): learn an
//! invertible affine transformation `T(x) = x A + v` that minimizes the
//! transformation MSE `E(T)` (Eq. 2) on captured features, with MX
//! fake quantization in the loop.
//!
//! This is the request-path port of
//! `python/compile/latmix.py::learn_feature_transform` — the part of the
//! paper's method that *produces* transforms, complementing the analysis
//! substrate in [`crate::transform`] which applies and measures them:
//!
//! - [`grad`] — hand-derived reverse-mode gradients of the Eq. 2 objective
//!   through the affine map, the matrix inverse, and the MX fake quantizer
//!   (clipped straight-through estimator), plus the Eq. 7/9 volume
//!   regularizer in log-det form.
//! - [`optim`] — AdamW + cosine LR with linear warmup (App. D.1), the
//!   mirror of `python/compile/optim.py`.
//! - [`synthetic`] — the Sec. 3.1 Dirac-delta and Fig. 2 outlier feature
//!   generators shared by tests, benches, and the CLI.
//! - [`learn_feature_transform`] — the optimization driver (direct dense
//!   parameterization of `A`, App. D block-Hadamard-plus-noise init,
//!   best-iterate selection by true `E(T)`).
//! - [`learn_from_model`] — the end-to-end Fig. 2 path: capture
//!   residual-stream activations from the pure-Rust interpreter
//!   (`model::forward`) and learn `T` directly on them.
//!
//! Remaining python-only surfaces (named follow-ups in ROADMAP.md): the
//! full-model KL distillation objective (Eq. 8) and per-head T2 learning.

pub mod grad;
pub mod optim;
pub mod synthetic;

pub use grad::{et_loss_and_grads, EtGrads};
pub use optim::{cosine_lr, AdamW};
pub use synthetic::{dirac_features, outlier_features};

use anyhow::{Context, Result};

use crate::linalg::{block_diag, hadamard, Mat};
use crate::model::{GraphSpec, NativeWeights};
use crate::mx::MxConfig;
use crate::transform::Affine;
use crate::util::Pcg64;

/// Initial `A0` for the learning loop (Table 7 strategies).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitStrategy {
    /// `A0 = I` — the no-transform start.
    Identity,
    /// Full `d x d` randomized Hadamard (`H diag(+-1)`).
    Hadamard,
    /// App. D default: block-diagonal randomized Hadamard blocks with
    /// small Gaussian noise on the off-block zeros, so gradients can grow
    /// cross-block structure.
    BdHadamardNoise {
        /// Sub-block size (a power of two dividing `d`).
        block: usize,
        /// Noise scale on the zero entries.
        noise: f32,
    },
}

/// Hyperparameters of [`learn_feature_transform`] (defaults follow
/// App. D.1 and `python/compile/latmix.py`).
#[derive(Clone, Copy, Debug)]
pub struct LearnConfig {
    /// Optimizer steps (default 300).
    pub steps: usize,
    /// Peak AdamW learning rate (default 3e-3).
    pub lr: f32,
    /// Volume-regularizer weight `lam` of Eq. 7/9 (default 0.1).
    pub lam: f32,
    /// Weight of the soft clipped-mass penalty (default 0.1).
    pub overflow_weight: f32,
    /// Initialization strategy (default 32-block Hadamard + 1e-3 noise).
    pub init: InitStrategy,
    /// RNG seed for the init.
    pub seed: u64,
    /// Record a [`TraceRow`] every this many steps (0 disables tracing).
    pub trace_every: usize,
}

impl Default for LearnConfig {
    fn default() -> LearnConfig {
        LearnConfig {
            steps: 300,
            lr: 3e-3,
            lam: 0.1,
            overflow_weight: 0.1,
            init: InitStrategy::BdHadamardNoise { block: 32, noise: 1e-3 },
            seed: 0,
            trace_every: 25,
        }
    }
}

/// One logged optimization state (the Fig. 2 learning curves).
#[derive(Clone, Copy, Debug)]
pub struct TraceRow {
    /// Step index the row was recorded at (before that step's update).
    pub step: usize,
    /// True `E(T)` (Eq. 2) of the iterate on the training features.
    pub mse: f64,
    /// Full objective (E(T) + overflow penalty + volume regularizer).
    pub loss: f64,
    /// Learning rate applied at this step.
    pub lr: f32,
}

/// Result of a learning run: the best iterate by true `E(T)`.
#[derive(Clone, Debug)]
pub struct LearnedTransform {
    /// Learned transform matrix.
    pub a: Mat,
    /// Learned bias.
    pub v: Vec<f32>,
    /// `E(T)` of `(a, v)` on the training features.
    pub best_mse: f64,
    /// Logged learning curve (empty when `trace_every == 0`).
    pub trace: Vec<TraceRow>,
    /// Steps actually run (< `steps` only if an iterate went singular).
    pub steps_run: usize,
}

impl LearnedTransform {
    /// Validate and convert into an [`Affine`] (see
    /// [`Affine::from_learned`] for the conditioning gate).
    pub fn into_affine(self) -> Result<Affine> {
        Affine::from_learned(self.a, self.v)
    }
}

/// Full `d x d` randomized Hadamard `H diag(+-1)` — the paper's strongest
/// *fixed* baseline (the "random Hadamard" rows of Fig. 2 / Table 2).
/// `d` must be a power of two.
pub fn randomized_hadamard(d: usize, rng: &mut Pcg64) -> Mat {
    let mut h = hadamard(d);
    for j in 0..d {
        if rng.below(2) == 1 {
            for i in 0..d {
                h[(i, j)] = -h[(i, j)];
            }
        }
    }
    h
}

/// Build the initial `A0` for a strategy (mirror of
/// `python/compile/transforms.py::init_matrix`).
pub fn init_matrix(d: usize, init: InitStrategy, rng: &mut Pcg64) -> Result<Mat> {
    match init {
        InitStrategy::Identity => Ok(Mat::eye(d)),
        InitStrategy::Hadamard => {
            anyhow::ensure!(d.is_power_of_two(), "Hadamard init needs power-of-two d, got {d}");
            Ok(randomized_hadamard(d, rng))
        }
        InitStrategy::BdHadamardNoise { block, noise } => {
            let block = block.min(d);
            anyhow::ensure!(
                block.is_power_of_two() && d % block == 0,
                "init block {block} must be a power of two dividing d = {d}"
            );
            let blocks: Vec<Mat> =
                (0..d / block).map(|_| randomized_hadamard(block, rng)).collect();
            let mut a = block_diag(&blocks);
            if noise > 0.0 {
                for e in a.data.iter_mut() {
                    if *e == 0.0 {
                        *e = noise * rng.normal();
                    }
                }
            }
            Ok(a)
        }
    }
}

/// Learn an affine transform minimizing `E(T)` (Eq. 2) on feature rows
/// `feats` (flat, `d` columns) under the MX config `cfg` — the Fig. 2
/// "learned" curves, ported from
/// `python/compile/latmix.py::learn_feature_transform`.
///
/// STE gradients through the quantizer are noisy, so the returned iterate
/// is the *best by true `E(T)`* seen during the run, not the last one; a
/// numerically singular iterate stops the run early with the best so far.
pub fn learn_feature_transform(
    feats: &[f32],
    d: usize,
    cfg: &MxConfig,
    lc: &LearnConfig,
) -> Result<LearnedTransform> {
    anyhow::ensure!(d > 0 && feats.len() % d == 0, "features are not rows of dim {d}");
    anyhow::ensure!(!feats.is_empty(), "no feature rows");
    anyhow::ensure!(cfg.name != "none", "cannot learn against the identity quantizer");
    anyhow::ensure!(
        cfg.block_size > 0 && d % cfg.block_size == 0,
        "MX block {} does not tile feature dim {d}",
        cfg.block_size
    );
    let mut rng = Pcg64::seed(lc.seed);
    let mut a = init_matrix(d, lc.init, &mut rng)?;
    let mut v = vec![0.0f32; d];
    let mut opt_a = AdamW::new(d * d);
    let mut opt_v = AdamW::new(d);
    let warmup = (lc.steps / 10).max(1);
    let mut best: Option<(f64, Mat, Vec<f32>)> = None;
    fn better(mse: f64, a: &Mat, v: &[f32], best: &mut Option<(f64, Mat, Vec<f32>)>) {
        if best.as_ref().map_or(true, |b| mse < b.0) {
            *best = Some((mse, a.clone(), v.to_vec()));
        }
    }
    let mut trace = Vec::new();
    let mut steps_run = 0;
    for step in 0..lc.steps {
        let Some(g) = et_loss_and_grads(feats, d, &a, &v, cfg, lc.lam, lc.overflow_weight)
        else {
            break; // singular iterate: stop and keep the best seen
        };
        better(g.mse, &a, &v, &mut best);
        let lr = cosine_lr(step, lc.steps, lc.lr, warmup);
        if lc.trace_every > 0 && (step % lc.trace_every == 0 || step + 1 == lc.steps) {
            trace.push(TraceRow { step, mse: g.mse, loss: g.loss, lr });
        }
        opt_a.update(&mut a.data, &g.grad_a.data, lr, 0.0);
        opt_v.update(&mut v, &g.grad_v, lr, 0.0);
        steps_run = step + 1;
    }
    // the post-update final iterate may be the best one
    if let Some(g) = et_loss_and_grads(feats, d, &a, &v, cfg, lc.lam, lc.overflow_weight) {
        better(g.mse, &a, &v, &mut best);
    }
    let (best_mse, a, v) = best.context("every iterate was singular (bad init?)")?;
    anyhow::ensure!(best_mse.is_finite(), "learning diverged (E(T) = {best_mse})");
    Ok(LearnedTransform { a, v, best_mse, trace, steps_run })
}

/// End-to-end Fig. 2 driver: run the pure-Rust interpreter over `tokens`
/// (`(batch, t)`, full precision), capture the residual stream entering
/// block `layer`, and learn `T` on those rows. Returns the captured
/// features alongside the result so callers can evaluate baselines on the
/// same data.
pub fn learn_from_model(
    w: &NativeWeights,
    layer: usize,
    tokens: &[i32],
    batch: usize,
    t: usize,
    cfg: &MxConfig,
    lc: &LearnConfig,
) -> Result<(Vec<f32>, LearnedTransform)> {
    let feats = w.capture_residual(tokens, batch, t, &GraphSpec::fp(), layer)?;
    let lt = learn_feature_transform(&feats, w.dims.d_model, cfg, lc)?;
    Ok((feats, lt))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_matrices_are_orthogonal_ish() {
        let mut rng = Pcg64::seed(1);
        let id = init_matrix(16, InitStrategy::Identity, &mut rng).unwrap();
        assert_eq!(id, Mat::eye(16));
        let h = init_matrix(16, InitStrategy::Hadamard, &mut rng).unwrap();
        assert!(h.t().matmul(&h).sub(&Mat::eye(16)).max_abs() < 1e-4);
        let bd = init_matrix(
            64,
            InitStrategy::BdHadamardNoise { block: 32, noise: 1e-3 },
            &mut rng,
        )
        .unwrap();
        // near-orthogonal: off-block noise is tiny
        assert!(bd.t().matmul(&bd).sub(&Mat::eye(64)).max_abs() < 0.1);
        // noise actually planted off the blocks
        assert!(bd[(0, 40)] != 0.0 && bd[(0, 40)].abs() < 0.01);
    }

    #[test]
    fn init_rejects_bad_shapes() {
        let mut rng = Pcg64::seed(2);
        assert!(init_matrix(24, InitStrategy::Hadamard, &mut rng).is_err());
        assert!(init_matrix(
            48,
            InitStrategy::BdHadamardNoise { block: 32, noise: 0.0 },
            &mut rng
        )
        .is_err());
    }

    #[test]
    fn learn_rejects_bad_configs() {
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let lc = LearnConfig::default();
        // d not a multiple of the MX block
        assert!(learn_feature_transform(&[0.0; 48], 16, &cfg, &lc).is_err());
        // ragged rows
        assert!(learn_feature_transform(&[0.0; 33], 32, &cfg, &lc).is_err());
        // identity quantizer: E(T) trivially 0, nothing to learn
        let none = MxConfig::from_name("none", Some(32)).unwrap();
        assert!(learn_feature_transform(&[0.0; 64], 32, &none, &lc).is_err());
        // zero block size (e.g. a mis-parsed --block flag) errors, no panic
        let zero = MxConfig::from_name("mxfp4", Some(0)).unwrap();
        assert!(learn_feature_transform(&[0.0; 64], 32, &zero, &lc).is_err());
    }

    #[test]
    fn zero_steps_returns_validated_init() {
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let lc = LearnConfig { steps: 0, ..Default::default() };
        let x = outlier_features(8, 32, 0.05, 3);
        let lt = learn_feature_transform(&x, 32, &cfg, &lc).unwrap();
        assert_eq!(lt.steps_run, 0);
        assert!(lt.best_mse.is_finite());
        // init is a (noised) Hadamard: invertible
        lt.into_affine().unwrap();
    }
}
