//! Rust-native LATMiX transform learning (Sec. 3.2, Fig. 2): learn an
//! invertible affine transformation `T(x) = x A + v` that minimizes the
//! transformation MSE `E(T)` (Eq. 2) on captured features, with MX
//! fake quantization in the loop.
//!
//! This is the request-path port of
//! `python/compile/latmix.py::learn_feature_transform` — the part of the
//! paper's method that *produces* transforms, complementing the analysis
//! substrate in [`crate::transform`] which applies and measures them:
//!
//! - [`grad`] — hand-derived reverse-mode gradients of the Eq. 2 objective
//!   through the affine map, the matrix inverse, and the MX fake quantizer
//!   (clipped straight-through estimator), plus the Eq. 7/9 volume
//!   regularizer in log-det form.
//! - [`optim`] — AdamW + cosine LR with linear warmup (App. D.1), the
//!   mirror of `python/compile/optim.py`.
//! - [`synthetic`] — the Sec. 3.1 Dirac-delta and Fig. 2 outlier feature
//!   generators shared by tests, benches, and the CLI.
//! - [`learn_feature_transform`] — the optimization driver (direct dense
//!   parameterization of `A`, App. D block-Hadamard-plus-noise init,
//!   best-iterate selection by true `E(T)`).
//! - [`learn_from_model`] — the end-to-end Fig. 2 path: capture
//!   residual-stream activations from the pure-Rust interpreter
//!   (`model::forward`) and learn `T` directly on them.
//! - [`learn_spec`] — the per-site generalization (Sec. 3.2, Table 1):
//!   learn a whole [`TransformSpec`] — global T1 on the residual stream,
//!   per-layer per-head `dh x dh` T2 on the attention values, per-layer
//!   FfnDown on the down-proj input — each site against its own captured
//!   features, reusing the same Eq. 2 objective and [`grad`] machinery at
//!   the site's dimensionality. The result feeds `latmix fold` and the
//!   native serving path.
//!
//! Remaining python-only surface (named follow-up in ROADMAP.md): the
//! full-model KL distillation objective (Eq. 8).

pub mod grad;
pub mod optim;
pub mod synthetic;

pub use grad::{et_loss_and_grads, EtGrads};
pub use optim::{cosine_lr, AdamW};
pub use synthetic::{dirac_features, outlier_features};

use anyhow::{Context, Result};

use crate::linalg::{block_diag, hadamard, Mat};
use crate::model::{GraphSpec, NativeWeights};
use crate::mx::MxConfig;
use crate::transform::{Affine, TransformSite, TransformSpec};
use crate::util::Pcg64;

/// Initial `A0` for the learning loop (Table 7 strategies).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitStrategy {
    /// `A0 = I` — the no-transform start.
    Identity,
    /// Full `d x d` randomized Hadamard (`H diag(+-1)`).
    Hadamard,
    /// App. D default: block-diagonal randomized Hadamard blocks with
    /// small Gaussian noise on the off-block zeros, so gradients can grow
    /// cross-block structure.
    BdHadamardNoise {
        /// Sub-block size (a power of two dividing `d`).
        block: usize,
        /// Noise scale on the zero entries.
        noise: f32,
    },
}

/// Hyperparameters of [`learn_feature_transform`] (defaults follow
/// App. D.1 and `python/compile/latmix.py`).
#[derive(Clone, Copy, Debug)]
pub struct LearnConfig {
    /// Optimizer steps (default 300).
    pub steps: usize,
    /// Peak AdamW learning rate (default 3e-3).
    pub lr: f32,
    /// Volume-regularizer weight `lam` of Eq. 7/9 (default 0.1).
    pub lam: f32,
    /// Weight of the soft clipped-mass penalty (default 0.1).
    pub overflow_weight: f32,
    /// Initialization strategy (default 32-block Hadamard + 1e-3 noise).
    pub init: InitStrategy,
    /// RNG seed for the init.
    pub seed: u64,
    /// Record a [`TraceRow`] every this many steps (0 disables tracing).
    pub trace_every: usize,
}

impl Default for LearnConfig {
    fn default() -> LearnConfig {
        LearnConfig {
            steps: 300,
            lr: 3e-3,
            lam: 0.1,
            overflow_weight: 0.1,
            init: InitStrategy::BdHadamardNoise { block: 32, noise: 1e-3 },
            seed: 0,
            trace_every: 25,
        }
    }
}

/// One logged optimization state (the Fig. 2 learning curves).
#[derive(Clone, Copy, Debug)]
pub struct TraceRow {
    /// Step index the row was recorded at (before that step's update).
    pub step: usize,
    /// True `E(T)` (Eq. 2) of the iterate on the training features.
    pub mse: f64,
    /// Full objective (E(T) + overflow penalty + volume regularizer).
    pub loss: f64,
    /// Learning rate applied at this step.
    pub lr: f32,
}

/// Result of a learning run: the best iterate by true `E(T)`.
#[derive(Clone, Debug)]
pub struct LearnedTransform {
    /// Learned transform matrix.
    pub a: Mat,
    /// Learned bias.
    pub v: Vec<f32>,
    /// `E(T)` of `(a, v)` on the training features.
    pub best_mse: f64,
    /// Logged learning curve (empty when `trace_every == 0`).
    pub trace: Vec<TraceRow>,
    /// Steps actually run (< `steps` only if an iterate went singular).
    pub steps_run: usize,
}

impl LearnedTransform {
    /// Validate and convert into an [`Affine`] (see
    /// [`Affine::from_learned`] for the conditioning gate).
    pub fn into_affine(self) -> Result<Affine> {
        Affine::from_learned(self.a, self.v)
    }
}

/// Full `d x d` randomized Hadamard `H diag(+-1)` — the paper's strongest
/// *fixed* baseline (the "random Hadamard" rows of Fig. 2 / Table 2).
/// `d` must be a power of two.
pub fn randomized_hadamard(d: usize, rng: &mut Pcg64) -> Mat {
    let mut h = hadamard(d);
    for j in 0..d {
        if rng.below(2) == 1 {
            for i in 0..d {
                h[(i, j)] = -h[(i, j)];
            }
        }
    }
    h
}

/// Build the initial `A0` for a strategy (mirror of
/// `python/compile/transforms.py::init_matrix`).
pub fn init_matrix(d: usize, init: InitStrategy, rng: &mut Pcg64) -> Result<Mat> {
    match init {
        InitStrategy::Identity => Ok(Mat::eye(d)),
        InitStrategy::Hadamard => {
            anyhow::ensure!(d.is_power_of_two(), "Hadamard init needs power-of-two d, got {d}");
            Ok(randomized_hadamard(d, rng))
        }
        InitStrategy::BdHadamardNoise { block, noise } => {
            let block = block.min(d);
            anyhow::ensure!(
                block.is_power_of_two() && d % block == 0,
                "init block {block} must be a power of two dividing d = {d}"
            );
            let blocks: Vec<Mat> =
                (0..d / block).map(|_| randomized_hadamard(block, rng)).collect();
            let mut a = block_diag(&blocks);
            if noise > 0.0 {
                for e in a.data.iter_mut() {
                    if *e == 0.0 {
                        *e = noise * rng.normal();
                    }
                }
            }
            Ok(a)
        }
    }
}

/// Learn an affine transform minimizing `E(T)` (Eq. 2) on feature rows
/// `feats` (flat, `d` columns) under the MX config `cfg` — the Fig. 2
/// "learned" curves, ported from
/// `python/compile/latmix.py::learn_feature_transform`.
///
/// STE gradients through the quantizer are noisy, so the returned iterate
/// is the *best by true `E(T)`* seen during the run, not the last one; a
/// numerically singular iterate stops the run early with the best so far.
pub fn learn_feature_transform(
    feats: &[f32],
    d: usize,
    cfg: &MxConfig,
    lc: &LearnConfig,
) -> Result<LearnedTransform> {
    anyhow::ensure!(d > 0 && feats.len() % d == 0, "features are not rows of dim {d}");
    anyhow::ensure!(!feats.is_empty(), "no feature rows");
    anyhow::ensure!(cfg.name != "none", "cannot learn against the identity quantizer");
    anyhow::ensure!(
        cfg.block_size > 0 && d % cfg.block_size == 0,
        "MX block {} does not tile feature dim {d}",
        cfg.block_size
    );
    let mut rng = Pcg64::seed(lc.seed);
    let mut a = init_matrix(d, lc.init, &mut rng)?;
    let mut v = vec![0.0f32; d];
    let mut opt_a = AdamW::new(d * d);
    let mut opt_v = AdamW::new(d);
    let warmup = (lc.steps / 10).max(1);
    let mut best: Option<(f64, Mat, Vec<f32>)> = None;
    fn better(mse: f64, a: &Mat, v: &[f32], best: &mut Option<(f64, Mat, Vec<f32>)>) {
        if best.as_ref().map_or(true, |b| mse < b.0) {
            *best = Some((mse, a.clone(), v.to_vec()));
        }
    }
    let mut trace = Vec::new();
    let mut steps_run = 0;
    for step in 0..lc.steps {
        let Some(g) = et_loss_and_grads(feats, d, &a, &v, cfg, lc.lam, lc.overflow_weight)
        else {
            break; // singular iterate: stop and keep the best seen
        };
        better(g.mse, &a, &v, &mut best);
        let lr = cosine_lr(step, lc.steps, lc.lr, warmup);
        if lc.trace_every > 0 && (step % lc.trace_every == 0 || step + 1 == lc.steps) {
            trace.push(TraceRow { step, mse: g.mse, loss: g.loss, lr });
        }
        opt_a.update(&mut a.data, &g.grad_a.data, lr, 0.0);
        opt_v.update(&mut v, &g.grad_v, lr, 0.0);
        steps_run = step + 1;
    }
    // the post-update final iterate may be the best one
    if let Some(g) = et_loss_and_grads(feats, d, &a, &v, cfg, lc.lam, lc.overflow_weight) {
        better(g.mse, &a, &v, &mut best);
    }
    let (best_mse, a, v) = best.context("every iterate was singular (bad init?)")?;
    anyhow::ensure!(best_mse.is_finite(), "learning diverged (E(T) = {best_mse})");
    Ok(LearnedTransform { a, v, best_mse, trace, steps_run })
}

/// End-to-end Fig. 2 driver: run the pure-Rust interpreter over `tokens`
/// (`(batch, t)`, full precision), capture the residual stream entering
/// block `layer`, and learn `T` on those rows. Returns the captured
/// features alongside the result so callers can evaluate baselines on the
/// same data.
pub fn learn_from_model(
    w: &NativeWeights,
    layer: usize,
    tokens: &[i32],
    batch: usize,
    t: usize,
    cfg: &MxConfig,
    lc: &LearnConfig,
) -> Result<(Vec<f32>, LearnedTransform)> {
    let feats = w.capture_residual(tokens, batch, t, &GraphSpec::fp(), layer)?;
    let lt = learn_feature_transform(&feats, w.dims.d_model, cfg, lc)?;
    Ok((feats, lt))
}

/// Per-site learning outcome: the learned `E(T)` next to the fixed
/// baselines evaluated on the *same* captured features (the Fig. 2 / Table
/// 2 comparison, per site).
#[derive(Clone, Debug)]
pub struct SiteReport {
    pub site: TransformSite,
    /// Feature/transform dimensionality of the site.
    pub dim: usize,
    /// MX block size the site was learned against (the deployment block
    /// clamped into the site dim, see [`site_block`]).
    pub block: usize,
    /// `E(T)` of the learned transform on the training features.
    pub e_learned: f64,
    /// `E(I)` — no transform.
    pub e_identity: f64,
    /// `E(H D)` for a randomized Hadamard (`None` when `dim` is not a
    /// power of two).
    pub e_hadamard: Option<f64>,
    /// Optimizer steps actually run.
    pub steps_run: usize,
    /// Condition number of the learned `A`.
    pub cond: f32,
}

/// The MX block size a site is learned against: the deployment block
/// clamped to the site's dimensionality via gcd, so it always tiles the
/// site features (per-head `dh` may be smaller than the deployment block —
/// `gcd` keeps powers of two intact: `gcd(32, dh=16) = 16`).
pub fn site_block(deploy_block: usize, dim: usize) -> usize {
    gcd(deploy_block, dim)
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Learn a full [`TransformSpec`] on features captured from `w` — the
/// per-site generalization of [`learn_feature_transform`]: the same Eq. 2
/// objective, STE gradients, and AdamW loop run once per site at that
/// site's dimensionality (`d_model` for the residual T1, `head_dim` for
/// each per-head T2, `d_ff` for FfnDown).
///
/// - `sites` — which transforms to learn. Per-head captures are shared
///   across sites in the same layer.
/// - `residual_layer` — which block's input residual stream the
///   `Residual` site trains on (the paper captures mid-depth).
/// - `capture` — the graph spec features are captured under; use
///   [`GraphSpec::fp`] with the deployment T3 flag so FfnDown sites see
///   the post-rotation rows they will reshape when served.
/// - `cfg`/`lc` — the deployment MX config and base hyperparameters; the
///   per-site seed is offset by the site index so sites don't share RNG
///   streams.
///
/// Returns the learned spec (validated invertible/conditioned via
/// [`Affine::from_learned`]) plus one [`SiteReport`] per site.
#[allow(clippy::too_many_arguments)]
pub fn learn_spec(
    w: &NativeWeights,
    sites: &[TransformSite],
    tokens: &[i32],
    batch: usize,
    t: usize,
    residual_layer: usize,
    capture: &GraphSpec,
    cfg: &MxConfig,
    lc: &LearnConfig,
) -> Result<(TransformSpec, Vec<SiteReport>)> {
    anyhow::ensure!(!sites.is_empty(), "no transform sites requested");
    let dims = w.dims;
    let mut head_cache: std::collections::BTreeMap<usize, Vec<Vec<f32>>> =
        std::collections::BTreeMap::new();
    let mut spec = TransformSpec::new();
    let mut reports = Vec::with_capacity(sites.len());
    for (idx, site) in sites.iter().enumerate() {
        site.validate(&dims)?;
        let dim = site.dim(&dims);
        let feats: Vec<f32> = match *site {
            TransformSite::Residual => {
                w.capture_residual(tokens, batch, t, capture, residual_layer)?
            }
            TransformSite::PerHeadValue { layer, head } => {
                let heads = match head_cache.entry(layer) {
                    std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
                    std::collections::btree_map::Entry::Vacant(e) => {
                        e.insert(w.capture_head_values(tokens, batch, t, capture, layer)?)
                    }
                };
                heads[head].clone()
            }
            TransformSite::FfnDown { layer } => {
                w.capture_ffn_input(tokens, batch, t, capture, layer)?
            }
        };
        let block = site_block(cfg.block_size, dim);
        anyhow::ensure!(
            block > 1,
            "deployment block {} shares no usable factor with site {site} dim {dim}",
            cfg.block_size
        );
        let dcfg = MxConfig { block_size: block, ..*cfg };
        let mut site_lc = *lc;
        site_lc.seed = lc.seed.wrapping_add(idx as u64);
        if let InitStrategy::BdHadamardNoise { block: ib, noise } = site_lc.init {
            site_lc.init = InitStrategy::BdHadamardNoise { block: gcd(ib, dim).max(1), noise };
        }
        let lt = learn_feature_transform(&feats, dim, &dcfg, &site_lc)
            .with_context(|| format!("learning site {site}"))?;
        let e_learned = lt.best_mse;
        let steps_run = lt.steps_run;
        let learned = lt.into_affine().with_context(|| format!("site {site}"))?;
        let e_identity =
            crate::transform::transformation_mse(&feats, dim, &Affine::identity(dim), &dcfg);
        let e_hadamard = if dim.is_power_of_two() {
            // offset into a stream disjoint from every site's learning
            // seed (those are lc.seed + idx), so the baseline draw is
            // independent of the next site's init
            let mut hrng = Pcg64::seed(site_lc.seed.wrapping_add(0x4841_4441));
            let h = Affine::new(randomized_hadamard(dim, &mut hrng), vec![0.0; dim])?;
            Some(crate::transform::transformation_mse(&feats, dim, &h, &dcfg))
        } else {
            None
        };
        reports.push(SiteReport {
            site: *site,
            dim,
            block,
            e_learned,
            e_identity,
            e_hadamard,
            steps_run,
            cond: learned.a.condition(),
        });
        spec.insert(*site, learned);
    }
    Ok((spec, reports))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_matrices_are_orthogonal_ish() {
        let mut rng = Pcg64::seed(1);
        let id = init_matrix(16, InitStrategy::Identity, &mut rng).unwrap();
        assert_eq!(id, Mat::eye(16));
        let h = init_matrix(16, InitStrategy::Hadamard, &mut rng).unwrap();
        assert!(h.t().matmul(&h).sub(&Mat::eye(16)).max_abs() < 1e-4);
        let bd = init_matrix(
            64,
            InitStrategy::BdHadamardNoise { block: 32, noise: 1e-3 },
            &mut rng,
        )
        .unwrap();
        // near-orthogonal: off-block noise is tiny
        assert!(bd.t().matmul(&bd).sub(&Mat::eye(64)).max_abs() < 0.1);
        // noise actually planted off the blocks
        assert!(bd[(0, 40)] != 0.0 && bd[(0, 40)].abs() < 0.01);
    }

    #[test]
    fn init_rejects_bad_shapes() {
        let mut rng = Pcg64::seed(2);
        assert!(init_matrix(24, InitStrategy::Hadamard, &mut rng).is_err());
        assert!(init_matrix(
            48,
            InitStrategy::BdHadamardNoise { block: 32, noise: 0.0 },
            &mut rng
        )
        .is_err());
    }

    #[test]
    fn learn_rejects_bad_configs() {
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let lc = LearnConfig::default();
        // d not a multiple of the MX block
        assert!(learn_feature_transform(&[0.0; 48], 16, &cfg, &lc).is_err());
        // ragged rows
        assert!(learn_feature_transform(&[0.0; 33], 32, &cfg, &lc).is_err());
        // identity quantizer: E(T) trivially 0, nothing to learn
        let none = MxConfig::from_name("none", Some(32)).unwrap();
        assert!(learn_feature_transform(&[0.0; 64], 32, &none, &lc).is_err());
        // zero block size (e.g. a mis-parsed --block flag) errors, no panic
        let zero = MxConfig::from_name("mxfp4", Some(0)).unwrap();
        assert!(learn_feature_transform(&[0.0; 64], 32, &zero, &lc).is_err());
    }

    #[test]
    fn site_block_clamps_into_dim() {
        assert_eq!(site_block(32, 64), 32);
        assert_eq!(site_block(32, 16), 16); // per-head dh below deploy block
        assert_eq!(site_block(32, 48), 16);
        assert_eq!(site_block(16, 384), 16);
    }

    #[test]
    fn learn_spec_covers_all_requested_sites() {
        let dims = crate::model::NativeDims {
            vocab: 32,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            kv_seq: 24,
            prefill_len: 8,
        };
        let w = NativeWeights::synthetic(dims, 41);
        let mut rng = Pcg64::seed(42);
        let tokens: Vec<i32> = (0..2 * 8).map(|_| rng.below(32) as i32).collect();
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let lc = LearnConfig { steps: 8, trace_every: 0, ..Default::default() };
        let sites = [
            TransformSite::Residual,
            TransformSite::PerHeadValue { layer: 0, head: 0 },
            TransformSite::PerHeadValue { layer: 0, head: 1 },
            TransformSite::FfnDown { layer: 1 },
        ];
        let capture = GraphSpec::fp();
        let (spec, reports) =
            learn_spec(&w, &sites, &tokens, 2, 8, 1, &capture, &cfg, &lc).unwrap();
        assert_eq!(spec.len(), 4);
        assert_eq!(reports.len(), 4);
        spec.validate(&dims).unwrap();
        for r in &reports {
            assert_eq!(r.dim, r.site.dim(&dims));
            assert!(r.block > 1 && r.dim % r.block == 0);
            assert!(r.e_learned.is_finite() && r.e_identity.is_finite());
            assert!(r.cond.is_finite() && r.cond > 0.5, "cond {}", r.cond);
        }
        // per-head sites learned at head_dim against a clamped block
        assert_eq!(reports[1].dim, 16);
        assert_eq!(reports[1].block, 16);
        // out-of-range site rejected
        let bad = [TransformSite::PerHeadValue { layer: 9, head: 0 }];
        assert!(learn_spec(&w, &bad, &tokens, 2, 8, 1, &capture, &cfg, &lc).is_err());
        // empty site list rejected
        assert!(learn_spec(&w, &[], &tokens, 2, 8, 1, &capture, &cfg, &lc).is_err());
    }

    #[test]
    fn zero_steps_returns_validated_init() {
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let lc = LearnConfig { steps: 0, ..Default::default() };
        let x = outlier_features(8, 32, 0.05, 3);
        let lt = learn_feature_transform(&x, 32, &cfg, &lc).unwrap();
        assert_eq!(lt.steps_run, 0);
        assert!(lt.best_mse.is_finite());
        // init is a (noised) Hadamard: invertible
        lt.into_affine().unwrap();
    }
}
