//! Theorem 3.3 numerics: evaluate the two factors of the MX quantization
//! error bound —  `||A^{-1}||_σ^2 / N_B * Σ_i M_i`  with
//! `M_i = E[(max_{j∈I_i} |T(x)_j|)^2]` — on empirical features.
//!
//! The bench `fig2_mse` prints both the empirical E(T) and this bound to
//! show they move together (the paper's design argument), and
//! `examples/error_analysis.rs` walks through the Dirac-delta example of
//! Sec. 3.1.

use super::Affine;

/// `M_i` estimates: expected squared block max of the transformed features.
pub fn block_max_moments(x: &[f32], d: usize, t: &Affine, block: usize) -> Vec<f64> {
    assert_eq!(d % block, 0);
    let y = t.forward_rows(x);
    let nb = d / block;
    let rows = x.len() / d;
    let mut out = vec![0.0f64; nb];
    for r in 0..rows {
        for i in 0..nb {
            let mut m = 0.0f32;
            for j in 0..block {
                m = m.max(y[r * d + i * block + j].abs());
            }
            out[i] += (m as f64) * (m as f64);
        }
    }
    for o in out.iter_mut() {
        *o /= rows as f64;
    }
    out
}

/// The Theorem 3.3 upper-bound surrogate (up to the fixed format constant):
/// `||A^{-1}||_σ^2 * mean_i M_i`.
pub fn theorem_bound(x: &[f32], d: usize, t: &Affine, block: usize) -> f64 {
    let inv_norm = t.inverse_matrix().spectral_norm() as f64;
    let moments = block_max_moments(x, d, t, block);
    let mean_m: f64 = moments.iter().sum::<f64>() / moments.len() as f64;
    inv_norm * inv_norm * mean_m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{hadamard, Mat};
    use crate::mx::MxConfig;
    use crate::transform::transformation_mse;
    use crate::util::Pcg64;

    #[test]
    fn bound_dominates_error_up_to_constant() {
        // The bound differs from E(T) by the format constant C_Q 2^{-2 r};
        // check monotone consistency instead of absolute domination.
        let mut rng = Pcg64::seed(31);
        let d = 64;
        let rows = 64;
        let mut x = rng.normal_vec(d * rows, 0.1);
        for r in 0..rows {
            x[r * d + 5] = 15.0;
        }
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let id = Affine::identity(d);
        let h = Affine::new(hadamard(d), vec![0.0; d]).unwrap();
        let e_id = transformation_mse(&x, d, &id, &cfg);
        let e_h = transformation_mse(&x, d, &h, &cfg);
        let b_id = theorem_bound(&x, d, &id, 32);
        let b_h = theorem_bound(&x, d, &h, 32);
        assert!(e_h < e_id);
        assert!(b_h < b_id, "bound should track: {b_h} vs {b_id}");
    }

    #[test]
    fn dirac_example_from_section_3_1() {
        // x = [10, 1, 0.5, 0.5], B = 2: H_4 reduces block-1 max but raises
        // block-2 max — exactly the paper's illustration.
        let x = [10.0f32, 1.0, 0.5, 0.5];
        let id = Affine::identity(4);
        // normalized Walsh-Hadamard: x H = [6, 4.5, 5, 4.5] as in the paper
        let h4 = Affine::new(hadamard(4), vec![0.0; 4]).unwrap();
        let m_id = block_max_moments(&x, 4, &id, 2);
        let m_h = block_max_moments(&x, 4, &h4, 2);
        assert!(m_h[0] < m_id[0], "block 1 improves: {m_h:?} vs {m_id:?}");
        assert!(m_h[1] > m_id[1], "block 2 degrades: {m_h:?} vs {m_id:?}");
    }

    #[test]
    fn inverse_norm_tradeoff() {
        // Shrinking one direction of A reduces block maxima but blows up
        // ||A^{-1}||_σ — the tension Theorem 3.3 formalizes.
        let d = 8;
        let mut a = Mat::eye(d);
        a[(0, 0)] = 0.01;
        let t = Affine::new(a, vec![0.0; d]).unwrap();
        let inv_norm = t.inverse_matrix().spectral_norm();
        assert!(inv_norm > 50.0);
    }
}
