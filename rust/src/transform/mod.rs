//! Affine-transform substrate: apply/invert transforms, measure the
//! transformation MSE E(T) (Eq. 2), evaluate the Theorem 3.3 bound — the
//! machinery behind the Fig. 2 benches and `examples/error_analysis.rs` —
//! and, since the [`spec`] module, the *per-site* [`spec::TransformSpec`]
//! pipeline: an [`Affine`] is one leaf of a spec that maps transform sites
//! (residual stream, per-head values, down-proj input) to transforms, with
//! fold/unfold algebra and `.lxt` serialization.

pub mod bound;
pub mod spec;

pub use spec::{TransformMode, TransformSite, TransformSpec};

use crate::linalg::Mat;
use crate::mx::{mx_qdq_rows, MxConfig};

/// An invertible affine transformation `T(x) = x A + v` (row-vector
/// convention, matching `python/compile/transforms.py`), with its inverse
/// factored once at construction.
///
/// ```
/// use latmix::linalg::Mat;
/// use latmix::transform::Affine;
/// let t = Affine::new(Mat::eye(4).scale(2.0), vec![0.5; 4]).unwrap();
/// // forward: y = x A + v
/// let y = t.forward_rows(&[1.0, 2.0, 3.0, 4.0]);
/// assert_eq!(y, vec![2.5, 4.5, 6.5, 8.5]);
/// // backward: x = (y - v) A^{-1} — an exact round-trip here
/// let x = t.backward_rows(&y);
/// for (got, want) in x.iter().zip([1.0f32, 2.0, 3.0, 4.0]) {
///     assert!((got - want).abs() < 1e-6);
/// }
/// ```
#[derive(Clone, Debug)]
pub struct Affine {
    pub a: Mat,
    pub v: Vec<f32>,
    a_inv: Mat,
}

impl Affine {
    pub fn new(a: Mat, v: Vec<f32>) -> anyhow::Result<Affine> {
        anyhow::ensure!(a.rows == a.cols, "A must be square");
        anyhow::ensure!(v.len() == a.cols, "v dim mismatch");
        let a_inv = a
            .inverse()
            .ok_or_else(|| anyhow::anyhow!("transform matrix is singular"))?;
        Ok(Affine { a, v, a_inv })
    }

    /// Build from a learned `(A, v)` pair (the output of
    /// `latmix::learn_feature_transform`), additionally rejecting
    /// ill-conditioned matrices: a transform with a huge condition number
    /// has a huge `||A^{-1}||_sigma`, so the Theorem 3.3 error bound —
    /// and the deployed dequantization path — would amplify quantization
    /// noise instead of reducing it.
    pub fn from_learned(a: Mat, v: Vec<f32>) -> anyhow::Result<Affine> {
        const MAX_COND: f32 = 1e4;
        let t = Affine::new(a, v)?;
        // condition number from the inverse `new` already factored
        let cond = t.a.spectral_norm() * t.a_inv.spectral_norm();
        anyhow::ensure!(
            cond.is_finite() && cond < MAX_COND,
            "learned transform is ill-conditioned (cond {cond:.1} >= {MAX_COND})"
        );
        Ok(t)
    }

    pub fn identity(d: usize) -> Affine {
        Affine { a: Mat::eye(d), v: vec![0.0; d], a_inv: Mat::eye(d) }
    }

    pub fn dim(&self) -> usize {
        self.a.rows
    }

    pub fn inverse_matrix(&self) -> &Mat {
        &self.a_inv
    }

    /// `y = x A + v` for each row of `x` (flat, row-major, `d` columns).
    pub fn forward_rows(&self, x: &[f32]) -> Vec<f32> {
        let d = self.dim();
        assert_eq!(x.len() % d, 0);
        let mut out = Vec::with_capacity(x.len());
        for row in x.chunks(d) {
            out.extend(self.a.apply_affine(row, Some(&self.v)));
        }
        out
    }

    /// `y = x A` for each row of `x` — the bias-free output-side fold
    /// application (block outputs re-enter the residual stream with the
    /// `A`-part only; `v` enters the stream once, at the embedding).
    pub fn linear_rows(&self, x: &[f32]) -> Vec<f32> {
        let d = self.dim();
        assert_eq!(x.len() % d, 0);
        let mut out = Vec::with_capacity(x.len());
        for row in x.chunks(d) {
            out.extend(self.a.apply_affine(row, None));
        }
        out
    }

    /// `x = (y - v) A^{-1}` for each row of `y`.
    pub fn backward_rows(&self, y: &[f32]) -> Vec<f32> {
        let d = self.dim();
        assert_eq!(y.len() % d, 0);
        let mut out = Vec::with_capacity(y.len());
        let mut tmp = vec![0.0f32; d];
        for row in y.chunks(d) {
            for (t, (a, b)) in tmp.iter_mut().zip(row.iter().zip(&self.v)) {
                *t = a - b;
            }
            out.extend(self.a_inv.apply_affine(&tmp, None));
        }
        out
    }
}

/// Transformation MSE `E(T)` (Eq. 2) estimated on feature rows `x`:
/// `mean_rows ||x - T^{-1}(Q(T(x)))||^2 / d`.
pub fn transformation_mse(x: &[f32], d: usize, t: &Affine, cfg: &MxConfig) -> f64 {
    assert_eq!(x.len() % d, 0);
    let mut y = t.forward_rows(x);
    mx_qdq_rows(&mut y, d, cfg);
    let back = t.backward_rows(&y);
    let n_rows = x.len() / d;
    let mut total = 0.0f64;
    for (a, b) in x.iter().zip(&back) {
        let e = (*a - *b) as f64;
        total += e * e;
    }
    total / (n_rows as f64) / (d as f64)
}

/// Per-MX-block quantization error profile (Fig. 2c):
/// `E_B^i(T) = mean over rows of mean_j ((x - T^{-1} Q T x)_j)^2` per block i.
pub fn per_block_error(x: &[f32], d: usize, t: &Affine, cfg: &MxConfig) -> Vec<f64> {
    let b = cfg.block_size;
    assert_eq!(d % b, 0);
    let mut y = t.forward_rows(x);
    mx_qdq_rows(&mut y, d, cfg);
    let back = t.backward_rows(&y);
    let nb = d / b;
    let n_rows = x.len() / d;
    let mut out = vec![0.0f64; nb];
    for r in 0..n_rows {
        for i in 0..nb {
            for j in 0..b {
                let idx = r * d + i * b + j;
                let e = (x[idx] - back[idx]) as f64;
                out[i] += e * e;
            }
        }
    }
    for o in out.iter_mut() {
        *o /= (n_rows * b) as f64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{hadamard, random_orthogonal};
    use crate::util::Pcg64;

    #[test]
    fn forward_backward_roundtrip() {
        let mut rng = Pcg64::seed(21);
        let a = random_orthogonal(32, &mut rng);
        let v = rng.normal_vec(32, 1.0);
        let t = Affine::new(a, v).unwrap();
        let x = rng.normal_vec(32 * 4, 2.0);
        let back = t.backward_rows(&t.forward_rows(&x));
        for (p, q) in x.iter().zip(&back) {
            assert!((p - q).abs() < 1e-3, "{p} vs {q}");
        }
    }

    #[test]
    fn identity_mse_equals_plain_qdq_error() {
        let mut rng = Pcg64::seed(22);
        let d = 64;
        let x = rng.normal_vec(d * 16, 1.0);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let t = Affine::identity(d);
        let e = transformation_mse(&x, d, &t, &cfg);
        // direct computation
        let q = crate::mx::mx_qdq(&x, d, &cfg);
        let direct: f64 = x
            .iter()
            .zip(&q)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / 16.0
            / d as f64;
        assert!((e - direct).abs() < 1e-9);
    }

    #[test]
    fn from_learned_gates_on_conditioning() {
        let mut rng = Pcg64::seed(25);
        let q = random_orthogonal(16, &mut rng);
        assert!(Affine::from_learned(q, vec![0.0; 16]).is_ok());
        let mut bad = Mat::eye(16);
        bad[(0, 0)] = 1e-6; // cond ~ 1e6
        assert!(Affine::from_learned(bad, vec![0.0; 16]).is_err());
        assert!(Affine::from_learned(Mat::zeros(16, 16), vec![0.0; 16]).is_err());
    }

    #[test]
    fn hadamard_reduces_outlier_mse() {
        // One huge channel: full Hadamard spreads it -> lower E(T).
        let mut rng = Pcg64::seed(23);
        let d = 64;
        let rows = 32;
        let mut x = rng.normal_vec(d * rows, 0.05);
        for r in 0..rows {
            x[r * d + 3] = 20.0 + rng.normal();
        }
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let e_id = transformation_mse(&x, d, &Affine::identity(d), &cfg);
        let h = hadamard(d);
        let t = Affine::new(h, vec![0.0; d]).unwrap();
        let e_h = transformation_mse(&x, d, &t, &cfg);
        assert!(e_h < e_id, "hadamard {e_h} vs identity {e_id}");
    }

    #[test]
    fn per_block_error_sums_to_mse() {
        let mut rng = Pcg64::seed(24);
        let d = 64;
        let x = rng.normal_vec(d * 8, 1.5);
        let cfg = MxConfig::from_name("mxfp4", Some(16)).unwrap();
        let t = Affine::identity(d);
        let blocks = per_block_error(&x, d, &t, &cfg);
        let mse = transformation_mse(&x, d, &t, &cfg);
        let avg: f64 = blocks.iter().sum::<f64>() / blocks.len() as f64;
        assert!((avg - mse).abs() < 1e-9, "{avg} vs {mse}");
    }
}
