//! Per-site transform specification — the unit the LATMiX method actually
//! learns and deploys (Sec. 3.2): not one free-floating [`Affine`] but a
//! typed map from *transform sites* in the model graph to invertible
//! affines, with fold/unfold algebra and `.lxt` serialization.
//!
//! ## Site map
//!
//! ```text
//! site                         dim      applied to                    fold target
//! ---------------------------  -------  ----------------------------  --------------------------
//! Residual          (T1)       d_model  the whole residual stream     embed, wq/wk/wv/wg/wu (in),
//!                                                                     wo/wd (out), lm head
//! PerHeadValue{l,h} (T2)       head_dim layer l / head h value rows   wv column block (out),
//!                                       and attention output          wo row block (in)
//! FfnDown{l}                   d_ff     layer l down-proj input       wd (inverse only — the
//!                                       (after the online T3)         forward stays ONLINE)
//! ```
//!
//! ## Fold semantics (App. B/C of the paper, row-vector convention)
//!
//! [`TransformSpec::fold_into`] rewrites a [`NativeWeights`] so the
//! transformed model runs with zero per-token transform cost at the
//! `Residual` and `PerHeadValue` sites:
//!
//! - T1: `embed' = E A1 + v1`; block inputs `W' = A1^-1 W`,
//!   `b' = b - v1 A1^-1 W`; block outputs `W' = W A1`, `b' = b A1`
//!   (`v1` enters the stream once, at the embedding); lm head like a
//!   block input.
//! - T2 (per layer l, head h): value-proj column block
//!   `Wv[:,h]' = Wv[:,h] A2`, `bv[h]' = bv[h] A2 + v2`; out-proj row
//!   block `Wo[h]' = A2^-1 Wo[h]`, `bo' = bo - v2 A2^-1 Wo[h]`. The `v2`
//!   bias passes through attention exactly because softmax rows sum to 1.
//! - FfnDown: the transform sits behind the SiLU-gating nonlinearity, so
//!   its *forward* application cannot be folded into any producer weight —
//!   it stays an online op (exactly like the fixed T3 Hadamard). Only the
//!   inverse folds: `wd' = Af^-1 wd`, `bd' = bd - vf Af^-1 wd`.
//!   `fold_into` therefore returns the folded weights *plus* the online
//!   remainder spec the serving path must keep applying.
//!
//! The two execution modes of the same spec are captured by
//! [`TransformMode`]: `Unfolded` (reference semantics on original weights
//! — forward before each quantizer, inverse after) and `Folded`
//! (deployment semantics on folded weights — only the online remainder
//! runs). `model::forward` implements both; the parity between them is the
//! end-to-end gate in `rust/tests/spec_pipeline.rs`.
//!
//! One semantic caveat, inherited from the paper (and from
//! QuaRot/SpinQuant before it): a `Residual` transform commutes with
//! RMSNorm only when `A1` is orthogonal and `v1 = 0`
//! (`rmsnorm(x A1 + v1) != rmsnorm(x) A1 + v1` in general), so folding a
//! learned T1 defines a *transformed model* rather than an exact rewrite
//! of the base model — the thing the paper's KL objective (Eq. 8) trains
//! toward the teacher. T2 and FfnDown have no norm between forward and
//! inverse and cancel exactly in full precision. What this module
//! guarantees unconditionally is folded == unfolded for the same spec.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use super::Affine;
use crate::io::lxt::{load_lxt, save_lxt, Tensor};
use crate::linalg::Mat;
use crate::model::{NativeDims, NativeWeights};

/// A transform site in the model graph (see the module-level site map).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TransformSite {
    /// Global residual-stream transform (the paper's T1), dim `d_model`.
    Residual,
    /// Per-layer, per-head transform on the attention values (T2),
    /// dim `head_dim`.
    PerHeadValue { layer: usize, head: usize },
    /// Per-layer transform on the down-projection input (after the online
    /// T3 block-Hadamard when enabled), dim `d_ff`. Online-forward site.
    FfnDown { layer: usize },
}

impl TransformSite {
    /// Feature/transform dimensionality of this site under `dims`.
    pub fn dim(&self, dims: &NativeDims) -> usize {
        match self {
            TransformSite::Residual => dims.d_model,
            TransformSite::PerHeadValue { .. } => dims.head_dim(),
            TransformSite::FfnDown { .. } => dims.d_ff,
        }
    }

    /// True when the site's forward transform must stay an online op after
    /// folding (cannot be absorbed into a producer weight).
    pub fn is_online(&self) -> bool {
        matches!(self, TransformSite::FfnDown { .. })
    }

    /// Stable string key used for `.lxt` tensor names and manifest
    /// annotations: `t1`, `t2.<layer>.<head>`, `ffn.<layer>`.
    pub fn key(&self) -> String {
        match self {
            TransformSite::Residual => "t1".to_string(),
            TransformSite::PerHeadValue { layer, head } => format!("t2.{layer}.{head}"),
            TransformSite::FfnDown { layer } => format!("ffn.{layer}"),
        }
    }

    /// Inverse of [`Self::key`].
    pub fn parse_key(key: &str) -> Result<TransformSite> {
        if key == "t1" {
            return Ok(TransformSite::Residual);
        }
        if let Some(rest) = key.strip_prefix("t2.") {
            let (l, h) = rest
                .split_once('.')
                .with_context(|| format!("bad per-head site key {key:?}"))?;
            return Ok(TransformSite::PerHeadValue {
                layer: l.parse().with_context(|| format!("bad layer in {key:?}"))?,
                head: h.parse().with_context(|| format!("bad head in {key:?}"))?,
            });
        }
        if let Some(l) = key.strip_prefix("ffn.") {
            return Ok(TransformSite::FfnDown {
                layer: l.parse().with_context(|| format!("bad layer in {key:?}"))?,
            });
        }
        anyhow::bail!("unknown transform-site key {key:?} (want t1 | t2.L.H | ffn.L)")
    }

    /// Bounds-check the site against model dimensions.
    pub fn validate(&self, dims: &NativeDims) -> Result<()> {
        match self {
            TransformSite::Residual => Ok(()),
            TransformSite::PerHeadValue { layer, head } => {
                anyhow::ensure!(
                    *layer < dims.n_layers && *head < dims.n_heads,
                    "site {self} out of range (model has {} layers x {} heads)",
                    dims.n_layers,
                    dims.n_heads
                );
                Ok(())
            }
            TransformSite::FfnDown { layer } => {
                anyhow::ensure!(
                    *layer < dims.n_layers,
                    "site {self} out of range (model has {} layers)",
                    dims.n_layers
                );
                Ok(())
            }
        }
    }
}

impl fmt::Display for TransformSite {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.key())
    }
}

/// How a spec is applied by the interpreter (`model::forward`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransformMode {
    /// Reference semantics on *unfolded* weights: every site transform is
    /// applied forward before its quantizer and inverted after it.
    Unfolded,
    /// Deployment semantics on *folded* weights: only the online remainder
    /// (FfnDown forwards) is applied; all inverses are baked into the
    /// weights. A spec run in this mode must contain online sites only.
    Folded,
}

/// A typed map from [`TransformSite`] to invertible [`Affine`] transforms —
/// what `latmix learn` produces, `latmix fold` consumes, and the native
/// serving path applies.
#[derive(Clone, Debug, Default)]
pub struct TransformSpec {
    sites: BTreeMap<TransformSite, Affine>,
}

impl TransformSpec {
    pub fn new() -> TransformSpec {
        TransformSpec::default()
    }

    /// Insert (or replace) the transform at `site`.
    pub fn insert(&mut self, site: TransformSite, t: Affine) {
        self.sites.insert(site, t);
    }

    pub fn get(&self, site: &TransformSite) -> Option<&Affine> {
        self.sites.get(site)
    }

    /// The global residual transform, if present.
    pub fn residual(&self) -> Option<&Affine> {
        self.sites.get(&TransformSite::Residual)
    }

    /// The per-head value transform at `(layer, head)`, if present.
    pub fn per_head(&self, layer: usize, head: usize) -> Option<&Affine> {
        self.sites.get(&TransformSite::PerHeadValue { layer, head })
    }

    /// The down-proj input transform at `layer`, if present.
    pub fn ffn_down(&self, layer: usize) -> Option<&Affine> {
        self.sites.get(&TransformSite::FfnDown { layer })
    }

    pub fn len(&self) -> usize {
        self.sites.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&TransformSite, &Affine)> {
        self.sites.iter()
    }

    /// True when every site's forward transform is an online op — the only
    /// kind of spec [`TransformMode::Folded`] execution accepts.
    pub fn online_only(&self) -> bool {
        self.sites.keys().all(TransformSite::is_online)
    }

    /// Comma-joined site keys (manifest annotation, log lines).
    pub fn site_list(&self) -> String {
        self.sites.keys().map(TransformSite::key).collect::<Vec<_>>().join(",")
    }

    /// Check every site is in range and every transform has the site's
    /// dimensionality.
    pub fn validate(&self, dims: &NativeDims) -> Result<()> {
        for (site, t) in &self.sites {
            site.validate(dims)?;
            anyhow::ensure!(
                t.dim() == site.dim(dims),
                "site {site}: transform dim {} != site dim {}",
                t.dim(),
                site.dim(dims)
            );
        }
        Ok(())
    }

    // -- serialization ------------------------------------------------------

    /// Encode as `.lxt` tensors: `spec.<key>.a` (`d x d`) and
    /// `spec.<key>.v` (`d`) per site, plus a `spec.version` marker.
    pub fn to_tensors(&self) -> BTreeMap<String, Tensor> {
        let mut out = BTreeMap::new();
        out.insert("spec.version".to_string(), Tensor::i32(vec![1], vec![SPEC_VERSION]));
        for (site, t) in &self.sites {
            let d = t.dim();
            let key = site.key();
            out.insert(format!("spec.{key}.a"), Tensor::f32(vec![d, d], t.a.data.clone()));
            out.insert(format!("spec.{key}.v"), Tensor::f32(vec![d], t.v.clone()));
        }
        out
    }

    /// Inverse of [`Self::to_tensors`]. Rejects unknown spec versions and
    /// singular transform matrices (via [`Affine::new`]).
    pub fn from_tensors(map: &BTreeMap<String, Tensor>) -> Result<TransformSpec> {
        if let Some(ver) = map.get("spec.version") {
            let v = ver.as_i32()?;
            anyhow::ensure!(
                v.len() == 1 && v[0] == SPEC_VERSION,
                "transform spec version {v:?} not supported (this build reads {SPEC_VERSION})"
            );
        }
        let mut spec = TransformSpec::new();
        for (name, t) in map {
            let Some(rest) = name.strip_prefix("spec.") else { continue };
            let Some(key) = rest.strip_suffix(".a") else { continue };
            let site = TransformSite::parse_key(key)?;
            anyhow::ensure!(
                t.dims.len() == 2 && t.dims[0] == t.dims[1],
                "{name}: expected square matrix, got dims {:?}",
                t.dims
            );
            let d = t.dims[0];
            let a = Mat::from_vec(d, d, t.as_f32()?.to_vec());
            let vname = format!("spec.{key}.v");
            let v = match map.get(&vname) {
                Some(vt) => {
                    anyhow::ensure!(vt.dims == [d], "{vname}: dims {:?} != [{d}]", vt.dims);
                    vt.as_f32()?.to_vec()
                }
                None => vec![0.0; d],
            };
            spec.insert(site, Affine::new(a, v).with_context(|| format!("site {site}"))?);
        }
        Ok(spec)
    }

    /// Write the spec to an `.lxt` file.
    pub fn save(&self, path: &Path) -> Result<()> {
        save_lxt(path, &self.to_tensors())
    }

    /// Load a spec from an `.lxt` file.
    pub fn load(path: &Path) -> Result<TransformSpec> {
        TransformSpec::from_tensors(&load_lxt(path)?)
            .with_context(|| format!("parse transform spec {path:?}"))
    }

    /// Load and validate an artifact descriptor's online transform
    /// remainder (`transform.online` in a version-2 manifest), ready to
    /// run in [`TransformMode::Folded`]. Returns `None` when the artifact
    /// set declares no online transforms. The single entry point shared by
    /// the serving executor and the eval backend, so the two paths can
    /// never diverge on how folded artifacts are interpreted.
    pub fn load_online(
        desc: &crate::model::ModelDesc,
    ) -> Result<Option<(TransformSpec, TransformMode)>> {
        let Some(path) = desc.transform_online_path() else {
            return Ok(None);
        };
        let spec = TransformSpec::load(&path)?;
        spec.validate(&crate::model::NativeDims::from_desc(desc))?;
        anyhow::ensure!(
            spec.online_only(),
            "manifest transform.online spec has non-online sites [{}] — \
             those must be folded into the weights, not applied at run time",
            spec.site_list()
        );
        Ok(Some((spec, TransformMode::Folded)))
    }

    // -- fold algebra -------------------------------------------------------

    /// Fold this spec into a weight set (the App. B/C rewrite — see the
    /// module docs for the per-site algebra). Returns the folded weights
    /// plus the *online remainder*: the sub-spec of forward transforms the
    /// serving path must still apply ([`TransformSite::is_online`] sites).
    pub fn fold_into(&self, w: &NativeWeights) -> Result<(NativeWeights, TransformSpec)> {
        let dims = w.dims;
        self.validate(&dims)?;
        let (d, dh) = (dims.d_model, dims.head_dim());
        let mut out = w.clone();

        if let Some(t1) = self.residual() {
            let a1 = &t1.a;
            let a1_inv = t1.inverse_matrix();
            // embedding rows: E' = E A1 + v1
            out.embed = out.embed.matmul(a1);
            for row in out.embed.data.chunks_mut(d) {
                for (e, v) in row.iter_mut().zip(&t1.v) {
                    *e += *v;
                }
            }
            // lm head like a block input: W' = A1^-1 W, b' = b - v1 W'
            out.head = a1_inv.matmul(&w.head);
            let shift = out.head.apply_affine(&t1.v, None);
            for (b, s) in out.bhead.iter_mut().zip(&shift) {
                *b -= *s;
            }
            for lw in out.layers.iter_mut() {
                for (wm, bv) in [
                    (&mut lw.wq, &mut lw.bq),
                    (&mut lw.wk, &mut lw.bk),
                    (&mut lw.wv, &mut lw.bv),
                    (&mut lw.wg, &mut lw.bg),
                    (&mut lw.wu, &mut lw.bu),
                ] {
                    *wm = a1_inv.matmul(wm);
                    let shift = wm.apply_affine(&t1.v, None);
                    for (b, s) in bv.iter_mut().zip(&shift) {
                        *b -= *s;
                    }
                }
                // block outputs: A1 only (v1 enters the stream once)
                lw.wo = lw.wo.matmul(a1);
                lw.bo = a1.apply_affine(&lw.bo, None);
                lw.wd = lw.wd.matmul(a1);
                lw.bd = a1.apply_affine(&lw.bd, None);
            }
        }

        for (site, t2) in &self.sites {
            let TransformSite::PerHeadValue { layer, head } = *site else { continue };
            let lw = &mut out.layers[layer];
            let (c0, c1) = (head * dh, (head + 1) * dh);
            // value-proj column block: Wv[:,h]' = Wv[:,h] A2 (+ v2 on bv)
            for r in 0..d {
                let row = lw.wv.row_mut(r);
                let seg = t2.a.apply_affine(&row[c0..c1], None);
                row[c0..c1].copy_from_slice(&seg);
            }
            let bseg = t2.a.apply_affine(&lw.bv[c0..c1], Some(&t2.v));
            lw.bv[c0..c1].copy_from_slice(&bseg);
            // out-proj row block: Wo[h]' = A2^-1 Wo[h], bo' = bo - v2 Wo[h]'
            let block = Mat::from_vec(dh, d, lw.wo.data[c0 * d..c1 * d].to_vec());
            let folded = t2.inverse_matrix().matmul(&block);
            lw.wo.data[c0 * d..c1 * d].copy_from_slice(&folded.data);
            let shift = folded.apply_affine(&t2.v, None);
            for (b, s) in lw.bo.iter_mut().zip(&shift) {
                *b -= *s;
            }
        }

        let mut online = TransformSpec::new();
        for (site, tf) in &self.sites {
            let TransformSite::FfnDown { layer } = *site else { continue };
            let lw = &mut out.layers[layer];
            // inverse only: wd' = Af^-1 wd, bd' = bd - vf wd'
            lw.wd = tf.inverse_matrix().matmul(&lw.wd);
            let shift = lw.wd.apply_affine(&tf.v, None);
            for (b, s) in lw.bd.iter_mut().zip(&shift) {
                *b -= *s;
            }
            // the forward application stays online
            online.insert(*site, tf.clone());
        }
        Ok((out, online))
    }
}

/// Spec `.lxt` format version this build reads and writes.
pub const SPEC_VERSION: i32 = 1;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::random_orthogonal;
    use crate::util::Pcg64;

    fn dims() -> NativeDims {
        NativeDims {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            kv_seq: 24,
            prefill_len: 8,
        }
    }

    fn rand_affine(d: usize, rng: &mut Pcg64) -> Affine {
        let mut a = random_orthogonal(d, rng);
        for e in a.data.iter_mut() {
            *e += 0.02 * rng.normal();
        }
        Affine::new(a, rng.normal_vec(d, 0.1)).unwrap()
    }

    #[test]
    fn site_keys_roundtrip() {
        for site in [
            TransformSite::Residual,
            TransformSite::PerHeadValue { layer: 3, head: 1 },
            TransformSite::FfnDown { layer: 0 },
        ] {
            assert_eq!(TransformSite::parse_key(&site.key()).unwrap(), site);
        }
        assert!(TransformSite::parse_key("t2.x.1").is_err());
        assert!(TransformSite::parse_key("bogus").is_err());
    }

    #[test]
    fn site_dims_and_online() {
        let d = dims();
        assert_eq!(TransformSite::Residual.dim(&d), 16);
        assert_eq!(TransformSite::PerHeadValue { layer: 0, head: 0 }.dim(&d), 8);
        assert_eq!(TransformSite::FfnDown { layer: 0 }.dim(&d), 32);
        assert!(!TransformSite::Residual.is_online());
        assert!(TransformSite::FfnDown { layer: 0 }.is_online());
    }

    #[test]
    fn validate_rejects_out_of_range_and_wrong_dims() {
        let d = dims();
        let mut rng = Pcg64::seed(3);
        let mut spec = TransformSpec::new();
        spec.insert(TransformSite::PerHeadValue { layer: 9, head: 0 }, rand_affine(8, &mut rng));
        assert!(spec.validate(&d).is_err());
        let mut spec = TransformSpec::new();
        spec.insert(TransformSite::Residual, rand_affine(8, &mut rng)); // want 16
        assert!(spec.validate(&d).is_err());
        let mut spec = TransformSpec::new();
        spec.insert(TransformSite::Residual, rand_affine(16, &mut rng));
        spec.insert(TransformSite::FfnDown { layer: 1 }, rand_affine(32, &mut rng));
        assert!(spec.validate(&d).is_ok());
        assert!(!spec.online_only());
        assert_eq!(spec.site_list(), "t1,ffn.1");
    }

    #[test]
    fn tensor_roundtrip_preserves_sites() {
        let mut rng = Pcg64::seed(5);
        let mut spec = TransformSpec::new();
        spec.insert(TransformSite::Residual, rand_affine(16, &mut rng));
        spec.insert(TransformSite::PerHeadValue { layer: 1, head: 1 }, rand_affine(8, &mut rng));
        spec.insert(TransformSite::FfnDown { layer: 0 }, rand_affine(32, &mut rng));
        let back = TransformSpec::from_tensors(&spec.to_tensors()).unwrap();
        assert_eq!(back.len(), 3);
        for (site, t) in spec.iter() {
            let bt = back.get(site).expect("site lost in round-trip");
            assert_eq!(bt.a, t.a);
            assert_eq!(bt.v, t.v);
        }
    }

    #[test]
    fn from_tensors_rejects_future_version_and_singular() {
        let mut map = BTreeMap::new();
        map.insert("spec.version".to_string(), Tensor::i32(vec![1], vec![SPEC_VERSION + 1]));
        assert!(TransformSpec::from_tensors(&map).is_err());
        let mut map = BTreeMap::new();
        map.insert("spec.t1.a".to_string(), Tensor::f32(vec![4, 4], vec![0.0; 16]));
        assert!(TransformSpec::from_tensors(&map).is_err());
    }

    #[test]
    fn fold_returns_online_remainder() {
        let d = dims();
        let w = NativeWeights::synthetic(d, 7);
        let mut rng = Pcg64::seed(9);
        let mut spec = TransformSpec::new();
        spec.insert(TransformSite::Residual, rand_affine(16, &mut rng));
        spec.insert(TransformSite::PerHeadValue { layer: 0, head: 1 }, rand_affine(8, &mut rng));
        spec.insert(TransformSite::FfnDown { layer: 1 }, rand_affine(32, &mut rng));
        let (folded, online) = spec.fold_into(&w).unwrap();
        // T1/T2 fold fully; only the FfnDown forward remains online
        assert_eq!(online.len(), 1);
        assert!(online.online_only());
        assert!(online.ffn_down(1).is_some());
        // folded weights actually changed at every touched tensor
        assert_ne!(folded.embed, w.embed);
        assert_ne!(folded.layers[0].wv, w.layers[0].wv);
        assert_ne!(folded.layers[0].wo, w.layers[0].wo);
        assert_ne!(folded.layers[1].wd, w.layers[1].wd);
        // untouched: the other head's wv columns at layer 1
        assert_eq!(folded.layers[1].wq.rows, 16);
    }

    #[test]
    fn empty_spec_fold_is_identity() {
        let d = dims();
        let w = NativeWeights::synthetic(d, 8);
        let (folded, online) = TransformSpec::new().fold_into(&w).unwrap();
        assert!(online.is_empty());
        assert_eq!(folded, w);
    }
}
