//! Evaluation harness over an execution [`Backend`]: perplexity and
//! zero-shot task accuracy — the Rust mirror of
//! `python/compile/evaluate.py`, operating on the `logits_*` graphs with
//! any weight variant as arguments. Generic over the backend, so the same
//! harness runs on PJRT (`backend-xla`) and on the pure-Rust interpreter.
//!
//! Scoring protocol (LM-eval-harness style): for each instance, score all
//! four `BOS + prompt + choice` sequences by mean per-token log-likelihood
//! of the choice span; predict the argmax.

use anyhow::{Context, Result};

use crate::data::TaskSet;
use crate::model::WeightSet;
use crate::runtime::Backend;

/// Evaluate perplexity of a weight variant under a quant graph tag
/// (`fp`, `mxfp4_b32_t3`, ...). Corpus: flat (n, t) tokens.
pub fn perplexity<B: Backend>(
    rt: &B,
    tag: &str,
    ws: &WeightSet,
    corpus: &[i32],
    n: usize,
    t: usize,
) -> Result<f64> {
    let graph = format!("logits_ppl_{tag}");
    let (gb, gt) = rt.desc().ppl_shape;
    anyhow::ensure!(t == gt, "corpus seq len {t} != graph {gt}");
    let weights = rt.stage(ws)?;
    let vocab = rt.desc().vocab;
    let mut total_nll = 0.0f64;
    let mut count = 0usize;
    let mut batch_tokens = vec![0i32; gb * gt];
    let mut rows_done = 0usize;
    while rows_done < n {
        let rows = (n - rows_done).min(gb);
        batch_tokens.fill(0);
        batch_tokens[..rows * gt]
            .copy_from_slice(&corpus[rows_done * gt..(rows_done + rows) * gt]);
        let logits = rt.logits(&graph, &weights, &batch_tokens, gb, gt)?;
        for r in 0..rows {
            for pos in 0..gt - 1 {
                let tgt = batch_tokens[r * gt + pos + 1] as usize;
                let row = &logits[(r * gt + pos) * vocab..(r * gt + pos + 1) * vocab];
                total_nll += nll_of(row, tgt);
                count += 1;
            }
        }
        rows_done += rows;
    }
    Ok((total_nll / count as f64).exp())
}

fn nll_of(logits: &[f32], target: usize) -> f64 {
    // stable log-softmax
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b)) as f64;
    let lse: f64 = logits.iter().map(|x| ((*x as f64) - m).exp()).sum::<f64>().ln() + m;
    lse - logits[target] as f64
}

/// Zero-shot accuracy per task + macro average.
pub fn zero_shot<B: Backend>(
    rt: &B,
    tag: &str,
    ws: &WeightSet,
    tasks: &[TaskSet],
) -> Result<Vec<(String, f64)>> {
    let graph = format!("logits_score_{tag}");
    let (gb, gt) = rt.desc().score_shape;
    let weights = rt.stage(ws)?;
    let vocab = rt.desc().vocab;
    let mut out = Vec::new();
    let mut sum = 0.0;
    for task in tasks {
        anyhow::ensure!(task.max_len == gt, "task len {} != graph {gt}", task.max_len);
        let total = task.n * 4;
        let mut scores = vec![0.0f64; total];
        let mut done = 0usize;
        let mut batch_tokens = vec![0i32; gb * gt];
        while done < total {
            let rows = (total - done).min(gb);
            batch_tokens.fill(0);
            batch_tokens[..rows * gt]
                .copy_from_slice(&task.tokens[done * gt..(done + rows) * gt]);
            let logits = rt.logits(&graph, &weights, &batch_tokens, gb, gt)?;
            for r in 0..rows {
                let flat = done + r;
                let inst = flat / 4;
                let plen = task.prompt_len[inst] as usize;
                let tlen = task.len[flat] as usize;
                let mut nll = 0.0f64;
                let mut cnt = 0usize;
                for pos in (plen - 1)..(tlen - 1) {
                    let tgt = batch_tokens[r * gt + pos + 1] as usize;
                    let row = &logits[(r * gt + pos) * vocab..(r * gt + pos + 1) * vocab];
                    nll += nll_of(row, tgt);
                    cnt += 1;
                }
                scores[flat] = -(nll / cnt.max(1) as f64);
            }
            done += rows;
        }
        let mut correct = 0usize;
        for inst in 0..task.n {
            let s = &scores[inst * 4..(inst + 1) * 4];
            let pred = s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .context("empty scores")?;
            if pred as i32 == task.label[inst] {
                correct += 1;
            }
        }
        let acc = correct as f64 / task.n as f64;
        sum += acc;
        out.push((task.name.clone(), acc));
    }
    out.push(("avg".into(), sum / tasks.len() as f64));
    Ok(out)
}

/// Accuracy-recovery percentage vs a full-precision reference.
pub fn recovery(acc: f64, fp_acc: f64) -> f64 {
    if fp_acc > 0.0 {
        100.0 * acc / fp_acc
    } else {
        0.0
    }
}
