//! Fused packed-MX GEMM: matmul directly on bit-packed FP4/INT4 weights.
//!
//! [`PackedMat`] stores a weight matrix as one flat [`PackedMx`] over its
//! row-major data — `cols % block_size == 0` guarantees every MX block
//! lies inside a single weight row, so row `k` of the matrix is exactly
//! the byte range of blocks `k*bpr .. (k+1)*bpr` (`bpr = cols / block`).
//! [`packed_matmul`] streams those bytes through the 256-entry byte-pair
//! LUTs in `mx::formats`, applies the E8M0 block scale in-register as a
//! multiply by `exp2i(e)` (the scale is an exact power of two, so the
//! decoded value is bit-identical to `PackedMx::unpack`), accumulates in
//! f32, and fans output-row bands out over the `util::par` pool. The f32
//! weight matrix is never materialized: resident weight bytes drop ~7.5x
//! (4.25 packed bits vs 32) and the kernel's memory traffic with them.
//!
//! Bit-exactness contract (property-tested in
//! `rust/tests/packed_gemm_props.rs` against the `mx::reference` scalar
//! oracle): `packed_matmul(a, &PackedMat::pack(w, cfg)?)` equals
//! `a.matmul(&dequantized_w)` bit-for-bit, where `dequantized_w` is the
//! scalar-reference dequantization of the same packed bytes. The kernel
//! replays the dense [`Mat::matmul`] accumulation order per output row
//! (4-wide k-unroll, then the scalar remainder), so fusing the decode
//! changes nothing about the float semantics — engine token streams are
//! identical packed-vs-dequantized (`rust/tests/serving_pipeline.rs`).

use anyhow::{ensure, Result};

use super::{matmul_rows_into, Mat};
use crate::mx::formats::{exp2i, fp4_pair_lut, int4_pair_lut};
use crate::mx::pack::PackedMx;
use crate::mx::quantize::MxConfig;
use crate::util::{par, scratch};

/// Output rows per parallel work item in [`packed_matmul`]: amortizes the
/// k-panel decode across a band of rows while keeping enough chunks for
/// the pool to balance.
const ROW_BAND: usize = 8;

/// A weight matrix held in bit-packed MX form (two 4-bit codes per byte +
/// one E8M0 scale byte per block), decodable row-by-row.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMat {
    pub rows: usize,
    pub cols: usize,
    packed: PackedMx,
}

impl PackedMat {
    /// Pack a row-major weight matrix. Requires a single-level 4-bit
    /// element format with an even block size that tiles `cols`, so MX
    /// blocks align to weight rows and nibble pairs never straddle bytes
    /// — the layout row-wise decode depends on.
    pub fn pack(w: &Mat, cfg: MxConfig) -> Result<PackedMat> {
        ensure!(
            cfg.element.bits == 4 && !cfg.nv && cfg.name != "none",
            "PackedMat: single-level 4-bit element formats only, got {}",
            cfg.name
        );
        ensure!(
            cfg.block_size % 2 == 0,
            "PackedMat: odd block size {} straddles code bytes",
            cfg.block_size
        );
        ensure!(
            w.cols % cfg.block_size == 0,
            "PackedMat: cols {} not a multiple of block size {}",
            w.cols,
            cfg.block_size
        );
        Ok(PackedMat { rows: w.rows, cols: w.cols, packed: PackedMx::pack(&w.data, cfg) })
    }

    pub fn config(&self) -> MxConfig {
        self.packed.cfg
    }

    /// Total packed bytes (codes + scales) — the resident footprint.
    pub fn bytes(&self) -> usize {
        self.packed.bytes()
    }

    /// Dequantize back to a dense matrix (off the hot path; parity tests
    /// and the dequantized serving mode use this).
    pub fn unpack(&self) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.packed.unpack())
    }

    /// Decode weight rows `k0 .. k0+count` into `dst` (row-major
    /// `count x cols`). Per-element semantics are exactly
    /// `PackedMx::unpack_into`: one LUT load per packed byte, two
    /// multiplies by the power-of-two block scale out.
    pub fn decode_rows(&self, k0: usize, count: usize, dst: &mut [f32]) {
        self.decode_rows_window(k0, count, 0, self.cols, dst);
    }

    /// [`PackedMat::decode_rows`] restricted to the block-aligned column
    /// window `[cb0, cb1)` — `dst` is row-major `count x (cb1 - cb0)`.
    /// Lets the column-sliced shard GEMM decode only the blocks its head /
    /// FFN band touches instead of whole weight rows. Identical per-element
    /// semantics (each element depends only on its own block's bytes).
    pub fn decode_rows_window(
        &self,
        k0: usize,
        count: usize,
        cb0: usize,
        cb1: usize,
        dst: &mut [f32],
    ) {
        let n = self.cols;
        let w = cb1 - cb0;
        if w == 0 || count == 0 {
            return;
        }
        let b = self.packed.cfg.block_size;
        debug_assert!(cb0 % b == 0 && cb1 % b == 0 && cb1 <= n, "window not block-aligned");
        let bpr = n / b;
        let lut = if self.packed.cfg.element.is_fp { fp4_pair_lut() } else { int4_pair_lut() };
        let scales = &self.packed.scales;
        let codes = &self.packed.codes;
        for (r, row) in dst.chunks_exact_mut(w).take(count).enumerate() {
            let bi0 = (k0 + r) * bpr + cb0 / b;
            for (j, chunk) in row.chunks_exact_mut(b).enumerate() {
                let bi = bi0 + j;
                let s = exp2i(scales[bi] as i32 - 127);
                let cb = &codes[bi * b / 2..(bi + 1) * b / 2];
                for (pair, byte) in chunk.chunks_exact_mut(2).zip(cb) {
                    let d = &lut[*byte as usize];
                    pair[0] = d[0] * s;
                    pair[1] = d[1] * s;
                }
            }
        }
    }
}

/// `a @ w` with `w` kept in packed MX form end to end.
///
/// Decodes a 4-row k-panel of `w` at a time into a small scratch buffer
/// and replays the dense [`Mat::matmul`] micro-kernel over it, so each
/// output row sees the identical sequence of f32 operations as
/// `a.matmul(&w.unpack())` — bit-exact, and (since rows are independent)
/// invariant to the worker count. Output rows fan out over `util::par`
/// in bands of [`ROW_BAND`] above [`par::PAR_MIN_LEN`] output elements.
pub fn packed_matmul(a: &Mat, w: &PackedMat) -> Mat {
    let (m, n) = (a.rows, w.cols);
    let mut out = Mat::zeros(m, n);
    packed_matmul_into(&a.data, m, w, &mut out.data);
    out
}

/// [`packed_matmul`] into a caller-provided zeroed `out` — the
/// allocation-free spelling the decode hot path uses with `util::scratch`
/// buffers. The per-band decode panels are checked out of the executing
/// thread's scratch arena (pool workers keep theirs warm across steps),
/// so a steady-state call performs no heap allocation. Kernel and fan-out
/// are byte-for-byte the old `packed_matmul` body: bit-exactness and
/// worker-count invariance carry over untouched.
pub fn packed_matmul_into(a: &[f32], m: usize, w: &PackedMat, out: &mut [f32]) {
    let (kd, n) = (w.rows, w.cols);
    assert_eq!(a.len(), m * kd, "packed_matmul shape mismatch");
    assert_eq!(out.len(), m * n, "packed_matmul out shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    // `i0` = first output row of the band, `oband` = its slice of `out`.
    let do_band = |i0: usize, oband: &mut [f32]| {
        let band_rows = oband.len() / n;
        let mut panel = scratch::take(4 * n);
        let mut k = 0;
        while k + 4 <= kd {
            w.decode_rows(k, 4, &mut panel);
            let (b0, rest) = panel.split_at(n);
            let (b1, rest) = rest.split_at(n);
            let (b2, b3) = rest.split_at(n);
            for r in 0..band_rows {
                let arow = &a[(i0 + r) * kd..(i0 + r + 1) * kd];
                let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
                let orow = &mut oband[r * n..(r + 1) * n];
                for j in 0..n {
                    orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
            }
            k += 4;
        }
        while k < kd {
            w.decode_rows(k, 1, &mut panel[..n]);
            let brow = &panel[..n];
            for r in 0..band_rows {
                let av = a[(i0 + r) * kd + k];
                let orow = &mut oband[r * n..(r + 1) * n];
                for (o, b) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * b;
                }
            }
            k += 1;
        }
        scratch::give(panel);
    };
    if m * n < par::PAR_MIN_LEN {
        do_band(0, out);
    } else {
        par::for_each_chunk(out, ROW_BAND * n, |bi, band| do_band(bi * ROW_BAND, band));
    }
}

/// The `[c0, c1)` output-column slice of `x @ w` with `w` kept packed.
///
/// Decodes only the block-aligned window of each 4-row k-panel that covers
/// `[c0, c1)` and replays the dense [`Mat::matmul_cols`] kernel over the
/// slice, so the result is bit-identical to the same columns of
/// [`packed_matmul`] — and hence to `x.matmul(&w.unpack())` sliced. Serial
/// on purpose: shard workers own disjoint column ranges.
pub fn packed_matmul_cols(a: &Mat, w: &PackedMat, c0: usize, c1: usize) -> Mat {
    assert_eq!(a.cols, w.rows, "packed_matmul_cols shape mismatch");
    assert!(c0 <= c1 && c1 <= w.cols, "column slice out of range");
    let (m, kd, nc) = (a.rows, a.cols, c1 - c0);
    let mut out = Mat { rows: m, cols: nc, data: scratch::take(m * nc) };
    if m == 0 || nc == 0 {
        return out;
    }
    let b = w.config().block_size;
    let cb0 = c0 / b * b;
    let cb1 = (c1 + b - 1) / b * b;
    let pw = cb1 - cb0;
    let (o0, o1) = (c0 - cb0, c0 - cb0 + nc);
    let mut panel = scratch::take(4 * pw);
    let mut k = 0;
    while k + 4 <= kd {
        w.decode_rows_window(k, 4, cb0, cb1, &mut panel);
        let (p0, rest) = panel.split_at(pw);
        let (p1, rest) = rest.split_at(pw);
        let (p2, p3) = rest.split_at(pw);
        let (b0, b1, b2, b3) = (&p0[o0..o1], &p1[o0..o1], &p2[o0..o1], &p3[o0..o1]);
        for i in 0..m {
            let arow = &a.data[i * kd..(i + 1) * kd];
            let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
            let orow = &mut out.data[i * nc..(i + 1) * nc];
            for j in 0..nc {
                orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
        }
        k += 4;
    }
    while k < kd {
        w.decode_rows_window(k, 1, cb0, cb1, &mut panel[..pw]);
        let brow = &panel[o0..o1];
        for i in 0..m {
            let av = a.data[i * kd + k];
            let orow = &mut out.data[i * nc..(i + 1) * nc];
            for (o, bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
        k += 1;
    }
    scratch::give(panel);
    out
}

/// The row-band partial `a_seg @ w[r0..r1, :]` with `w` kept packed —
/// the packed twin of [`Mat::matmul_band`], decoding 4-row k-panels at
/// `r0 + k` and replaying the same kernel so packed-sharded equals
/// dense-sharded bit for bit.
pub fn packed_matmul_band(a_seg: &Mat, w: &PackedMat, r0: usize, r1: usize) -> Mat {
    assert!(r0 <= r1 && r1 <= w.rows, "row band out of range");
    assert_eq!(a_seg.cols, r1 - r0, "packed_matmul_band shape mismatch");
    let (m, kd, n) = (a_seg.rows, r1 - r0, w.cols);
    let mut out = Mat { rows: m, cols: n, data: scratch::take(m * n) };
    if m == 0 || n == 0 {
        return out;
    }
    let mut panel = scratch::take(4 * n);
    let mut k = 0;
    while k + 4 <= kd {
        w.decode_rows(r0 + k, 4, &mut panel);
        let (b0, rest) = panel.split_at(n);
        let (b1, rest) = rest.split_at(n);
        let (b2, b3) = rest.split_at(n);
        for i in 0..m {
            let arow = &a_seg.data[i * kd..(i + 1) * kd];
            let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
        }
        k += 4;
    }
    while k < kd {
        w.decode_rows(r0 + k, 1, &mut panel[..n]);
        let brow = &panel[..n];
        for i in 0..m {
            let av = a_seg.data[i * kd + k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (o, bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
        k += 1;
    }
    scratch::give(panel);
    out
}

/// The shape a linear-layer weight can take in the native forward pass:
/// dense f32 ([`Mat`]) or bit-packed MX ([`PackedMat`]). `model::linear`
/// is generic over this, which is what lets `NativeWeights` keep weights
/// packed from `.lxt` load all the way through the serving hot path.
/// `Send + Sync` because the sharded forward pass hands `&LayerWeights<W>`
/// to fork-join shard workers (`util::par::run_workers`).
pub trait WeightMatrix: Clone + std::fmt::Debug + Send + Sync {
    /// Input (K) dimension — weight layout is `(in, out)`, `y = x W + b`.
    fn in_dim(&self) -> usize;
    /// Output (N) dimension.
    fn out_dim(&self) -> usize;
    /// `x @ W` for a row-major activation matrix `x`.
    fn matmul_pre(&self, x: &Mat) -> Mat;
    /// `x @ W` for `n_rows` row-major activation rows, accumulated into
    /// the caller-provided zeroed `out` — the allocation-free twin of
    /// [`WeightMatrix::matmul_pre`] (same kernel, same accumulation
    /// order, bit-identical output). The decode hot path calls this with
    /// `util::scratch` buffers.
    fn matmul_pre_into(&self, x: &[f32], n_rows: usize, out: &mut [f32]);
    /// The `[c0, c1)` output-column slice of `x @ W` — bit-identical to
    /// slicing [`WeightMatrix::matmul_pre`]'s result (same per-element
    /// k-order; output columns never interact). Shard workers use this to
    /// own disjoint head / FFN column ranges.
    fn matmul_cols(&self, x: &Mat, c0: usize, c1: usize) -> Mat;
    /// The row-band partial `x_seg @ W[r0..r1, :]` (`x_seg` = the matching
    /// `[r0, r1)` column slice of the activation). Summing a fixed band
    /// partition in ascending order is the sharded row-split reduction;
    /// within a band the k-order replays the dense kernel, so dense and
    /// packed storage produce bit-identical partials from the same bytes.
    fn matmul_band(&self, x_seg: &Mat, r0: usize, r1: usize) -> Mat;
    /// Resident bytes of the weight storage itself.
    fn weight_bytes(&self) -> usize;
}

impl WeightMatrix for Mat {
    fn in_dim(&self) -> usize {
        self.rows
    }

    fn out_dim(&self) -> usize {
        self.cols
    }

    fn matmul_pre(&self, x: &Mat) -> Mat {
        x.matmul(self)
    }

    fn matmul_pre_into(&self, x: &[f32], n_rows: usize, out: &mut [f32]) {
        matmul_rows_into(x, n_rows, self, out);
    }

    fn matmul_cols(&self, x: &Mat, c0: usize, c1: usize) -> Mat {
        Mat::matmul_cols(self, x, c0, c1)
    }

    fn matmul_band(&self, x_seg: &Mat, r0: usize, r1: usize) -> Mat {
        Mat::matmul_band(self, x_seg, r0, r1)
    }

    fn weight_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

impl WeightMatrix for PackedMat {
    fn in_dim(&self) -> usize {
        self.rows
    }

    fn out_dim(&self) -> usize {
        self.cols
    }

    fn matmul_pre(&self, x: &Mat) -> Mat {
        packed_matmul(x, self)
    }

    fn matmul_pre_into(&self, x: &[f32], n_rows: usize, out: &mut [f32]) {
        packed_matmul_into(x, n_rows, self, out);
    }

    fn matmul_cols(&self, x: &Mat, c0: usize, c1: usize) -> Mat {
        packed_matmul_cols(x, self, c0, c1)
    }

    fn matmul_band(&self, x_seg: &Mat, r0: usize, r1: usize) -> Mat {
        packed_matmul_band(x_seg, self, r0, r1)
    }

    fn weight_bytes(&self) -> usize {
        self.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut r = Pcg64::seed(seed);
        Mat::from_vec(rows, cols, r.normal_vec(rows * cols, 1.5))
    }

    #[test]
    fn pack_roundtrip_matches_flat_unpack() {
        for fmt in ["mxfp4", "mxint4"] {
            let cfg = MxConfig::from_name(fmt, Some(16)).unwrap();
            let w = rand_mat(13, 48, 21);
            let p = PackedMat::pack(&w, cfg).unwrap();
            let u = p.unpack();
            assert_eq!((u.rows, u.cols), (13, 48));
            // row-wise decode agrees with the flat unpack, any offset/count
            let mut rows = vec![0.0f32; 3 * 48];
            p.decode_rows(5, 3, &mut rows);
            assert_eq!(&rows, &u.data[5 * 48..8 * 48], "{fmt}");
        }
    }

    #[test]
    fn packed_matmul_matches_dense_on_unpacked() {
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        // kd = 37 exercises the 4-wide remainder; m = 1 is the GEMV decode shape
        for (m, kd, n) in [(1usize, 37usize, 64usize), (6, 32, 96), (4, 7, 32)] {
            let a = rand_mat(m, kd, 31);
            let w = rand_mat(kd, n, 32);
            let p = PackedMat::pack(&w, cfg).unwrap();
            let fused = packed_matmul(&a, &p);
            let dense = a.matmul(&p.unpack());
            for (i, (x, y)) in fused.data.iter().zip(&dense.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "m={m} kd={kd} n={n} idx {i}");
            }
        }
    }

    #[test]
    fn packed_cols_and_band_match_dense_on_unpacked_bitwise() {
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        // kd = 37 exercises the 4-wide remainder; slices include
        // non-block-aligned column windows (decode window over-covers)
        let a = rand_mat(5, 37, 41);
        let w = rand_mat(37, 96, 42);
        let p = PackedMat::pack(&w, cfg).unwrap();
        let u = p.unpack();
        for (c0, c1) in [(0usize, 96usize), (32, 64), (40, 72), (7, 11)] {
            let fused = packed_matmul_cols(&a, &p, c0, c1);
            let dense = u.matmul_cols(&a, c0, c1);
            for (i, (x, y)) in fused.data.iter().zip(&dense.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "cols [{c0},{c1}) idx {i}");
            }
        }
        let wb = rand_mat(96, 64, 43);
        let pb = PackedMat::pack(&wb, cfg).unwrap();
        let ub = pb.unpack();
        for (r0, r1) in [(0usize, 96usize), (48, 96), (13, 50)] {
            let mut seg = Vec::new();
            for i in 0..5 {
                seg.extend_from_slice(&rand_mat(5, 96, 44).data[i * 96 + r0..i * 96 + r1]);
            }
            let a_seg = Mat::from_vec(5, r1 - r0, seg);
            let fused = packed_matmul_band(&a_seg, &pb, r0, r1);
            let dense = ub.matmul_band(&a_seg, r0, r1);
            for (i, (x, y)) in fused.data.iter().zip(&dense.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "band [{r0},{r1}) idx {i}");
            }
        }
    }

    #[test]
    fn decode_window_matches_full_rows() {
        let cfg = MxConfig::from_name("mxint4", Some(16)).unwrap();
        let w = rand_mat(9, 64, 45);
        let p = PackedMat::pack(&w, cfg).unwrap();
        let mut full = vec![0.0f32; 3 * 64];
        p.decode_rows(4, 3, &mut full);
        let mut win = vec![0.0f32; 3 * 32];
        p.decode_rows_window(4, 3, 16, 48, &mut win);
        for r in 0..3 {
            assert_eq!(&win[r * 32..(r + 1) * 32], &full[r * 64 + 16..r * 64 + 48]);
        }
    }

    #[test]
    fn pack_rejects_bad_layouts() {
        let w = rand_mat(8, 48, 33);
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        assert!(PackedMat::pack(&w, cfg).is_err(), "48 cols not a multiple of 32");
        let mut odd = MxConfig::from_name("mxfp4", Some(16)).unwrap();
        odd.block_size = 3;
        assert!(PackedMat::pack(&w, odd).is_err(), "odd block size");
        let eight = MxConfig::from_name("mxfp8", Some(16)).unwrap();
        assert!(PackedMat::pack(&w, eight).is_err(), "8-bit elements");
        let nv = MxConfig::from_name("nvfp4", Some(16)).unwrap();
        assert!(PackedMat::pack(&w, nv).is_err(), "two-level scales");
    }

    #[test]
    fn weight_matrix_dims_and_bytes() {
        let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
        let w = rand_mat(64, 128, 34);
        let p = PackedMat::pack(&w, cfg).unwrap();
        assert_eq!((p.in_dim(), p.out_dim()), (w.in_dim(), w.out_dim()));
        assert_eq!(w.weight_bytes(), 64 * 128 * 4);
        // 4.25 bits/elem at B=32 vs 32 bits dense: ~7.5x smaller
        let ratio = w.weight_bytes() as f64 / p.weight_bytes() as f64;
        assert!((ratio - 32.0 / 4.25).abs() < 1e-9, "{ratio}");
    }

    #[test]
    fn degenerate_shapes() {
        let cfg = MxConfig::from_name("mxint4", Some(16)).unwrap();
        let w = PackedMat::pack(&rand_mat(5, 16, 35), cfg).unwrap();
        let empty = packed_matmul(&Mat::zeros(0, 5), &w);
        assert_eq!((empty.rows, empty.cols), (0, 16));
    }
}
