//! Dense linear algebra substrate (row-major `f32` matrices).
//!
//! Provides exactly what the LATMiX analysis path needs: matmul, LU-based
//! inverse/solve, QR, Hadamard construction, spectral norm (power
//! iteration), condition number, block-diagonal assembly. Not a general
//! BLAS — shapes stay ≤ a few hundred per side — but since the native
//! executor landed (`model/forward.rs`), `linear()` over [`Mat::matmul`]
//! *is* the serving hot path, so the matmul micro-kernel is tuned (4-wide
//! k-unroll, row fan-out over `util::par`) and [`packed`] adds the fused
//! GEMM that consumes bit-packed MX weights without dequantizing them.

pub mod hadamard;
pub mod packed;

pub use hadamard::{block_hadamard_apply, hadamard};
pub use packed::{
    packed_matmul, packed_matmul_band, packed_matmul_cols, packed_matmul_into, PackedMat,
    WeightMatrix,
};

use crate::util::{par, scratch};

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn t(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// `self @ other` — tiled i-k-j micro-kernel. The k-loop is unrolled
    /// 4-wide so the inner j-loop fuses four B rows per pass (4x the
    /// arithmetic intensity per `out` traversal), and the old `a == 0.0`
    /// zero-skip branch is gone: on dense data it only bought branch
    /// mispredictions in the innermost loop. Output rows fan out over the
    /// `util::par` pool above [`par::PAR_MIN_LEN`] output elements; each
    /// row's accumulation order is fixed, so results are bit-identical
    /// for any worker count (property-tested in `packed_gemm_props.rs`).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, n) = (self.rows, other.cols);
        let mut out = Mat::zeros(m, n);
        matmul_rows_into(&self.data, m, other, &mut out.data);
        out
    }

    /// The `[c0, c1)` output-column slice of `x @ self` — bit-identical to
    /// slicing the full [`Mat::matmul`] product, because each output
    /// element's k-loop replays the exact dense order (4-wide unroll, then
    /// the scalar remainder) and output columns never interact. Serial on
    /// purpose: in the sharded forward pass the shard workers supply the
    /// parallelism, each owning a disjoint head / FFN column range.
    pub fn matmul_cols(&self, x: &Mat, c0: usize, c1: usize) -> Mat {
        assert_eq!(x.cols, self.rows, "matmul_cols shape mismatch");
        assert!(c0 <= c1 && c1 <= self.cols, "column slice out of range");
        let (m, kd, n, nc) = (x.rows, self.rows, self.cols, c1 - c0);
        // Shard-path hot call: back the output with the scratch arena so a
        // steady-state decode step recycles it (callers `give` the data).
        let mut out = Mat { rows: m, cols: nc, data: scratch::take(m * nc) };
        if m == 0 || nc == 0 {
            return out;
        }
        for (i, orow) in out.data.chunks_mut(nc).enumerate() {
            let arow = &x.data[i * kd..(i + 1) * kd];
            let mut k = 0;
            while k + 4 <= kd {
                let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
                let b0 = &self.data[k * n + c0..k * n + c1];
                let b1 = &self.data[(k + 1) * n + c0..(k + 1) * n + c1];
                let b2 = &self.data[(k + 2) * n + c0..(k + 2) * n + c1];
                let b3 = &self.data[(k + 3) * n + c0..(k + 3) * n + c1];
                for j in 0..nc {
                    orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
                k += 4;
            }
            while k < kd {
                let a = arow[k];
                let brow = &self.data[k * n + c0..k * n + c1];
                for (o, b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
                k += 1;
            }
        }
        out
    }

    /// The row-band partial `x_seg @ self[r0..r1, :]`, where `x_seg` holds
    /// the matching `[r0, r1)` column slice of the full activation. This is
    /// the shard side of a row-split GEMM: summing the partials of a fixed
    /// band partition in ascending band order — then adding the bias — is
    /// one fixed sequence of f32 adds, so the reduction is bit-identical
    /// for any worker count. Within a band the k-loop replays the dense
    /// [`Mat::matmul`] order. Serial on purpose (see [`Mat::matmul_cols`]).
    pub fn matmul_band(&self, x_seg: &Mat, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows, "row band out of range");
        assert_eq!(x_seg.cols, r1 - r0, "matmul_band shape mismatch");
        let (m, kd, n) = (x_seg.rows, r1 - r0, self.cols);
        // Scratch-backed like [`Mat::matmul_cols`]: shard reductions consume
        // and recycle these partials every step.
        let mut out = Mat { rows: m, cols: n, data: scratch::take(m * n) };
        if m == 0 || n == 0 {
            return out;
        }
        for (i, orow) in out.data.chunks_mut(n).enumerate() {
            let arow = &x_seg.data[i * kd..(i + 1) * kd];
            let mut k = 0;
            while k + 4 <= kd {
                let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
                let b0 = &self.data[(r0 + k) * n..(r0 + k + 1) * n];
                let b1 = &self.data[(r0 + k + 1) * n..(r0 + k + 2) * n];
                let b2 = &self.data[(r0 + k + 2) * n..(r0 + k + 3) * n];
                let b3 = &self.data[(r0 + k + 3) * n..(r0 + k + 4) * n];
                for j in 0..n {
                    orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
                k += 4;
            }
            while k < kd {
                let a = arow[k];
                let brow = &self.data[(r0 + k) * n..(r0 + k + 1) * n];
                for (o, b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
                k += 1;
            }
        }
        out
    }

    /// `x @ self + v` for a row vector `x` (the affine-transform hot call).
    pub fn apply_affine(&self, x: &[f32], v: Option<&[f32]>) -> Vec<f32> {
        assert_eq!(x.len(), self.rows);
        let mut out = match v {
            Some(v) => v.to_vec(),
            None => vec![0.0; self.cols],
        };
        for (k, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = self.row(k);
            for (o, r) in out.iter_mut().zip(row) {
                *o += xv * r;
            }
        }
        out
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.iter().map(|x| x * s).collect())
    }

    pub fn add(&self, o: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&o.data).map(|(a, b)| a + b).collect(),
        )
    }

    pub fn sub(&self, o: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&o.data).map(|(a, b)| a - b).collect(),
        )
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// LU decomposition with partial pivoting. Returns (LU-packed, perm,
    /// sign) or None if singular.
    pub fn lu(&self) -> Option<(Mat, Vec<usize>, f32)> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0f32;
        for k in 0..n {
            // pivot
            let mut p = k;
            let mut best = a[(k, k)].abs();
            for i in k + 1..n {
                let v = a[(i, k)].abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            if best < 1e-12 {
                return None;
            }
            if p != k {
                for j in 0..n {
                    let tmp = a[(k, j)];
                    a[(k, j)] = a[(p, j)];
                    a[(p, j)] = tmp;
                }
                perm.swap(k, p);
                sign = -sign;
            }
            let piv = a[(k, k)];
            for i in k + 1..n {
                let f = a[(i, k)] / piv;
                a[(i, k)] = f;
                for j in k + 1..n {
                    let akj = a[(k, j)];
                    a[(i, j)] -= f * akj;
                }
            }
        }
        Some((a, perm, sign))
    }

    /// Solve `self @ x = b` for each column of `b`.
    pub fn solve(&self, b: &Mat) -> Option<Mat> {
        let n = self.rows;
        assert_eq!(b.rows, n);
        let (lu, perm, _) = self.lu()?;
        let mut x = Mat::zeros(n, b.cols);
        for c in 0..b.cols {
            // forward (apply perm)
            let mut y = vec![0.0f32; n];
            for i in 0..n {
                let mut s = b[(perm[i], c)];
                for j in 0..i {
                    s -= lu[(i, j)] * y[j];
                }
                y[i] = s;
            }
            // backward
            for i in (0..n).rev() {
                let mut s = y[i];
                for j in i + 1..n {
                    s -= lu[(i, j)] * x[(j, c)];
                }
                x[(i, c)] = s / lu[(i, i)];
            }
        }
        Some(x)
    }

    pub fn inverse(&self) -> Option<Mat> {
        self.solve(&Mat::eye(self.rows))
    }

    /// Inverse and `ln|det|` from a single LU factorization — the
    /// transform-learning loop needs both every optimizer step, and one
    /// O(n^3) factorization covers the two. Bit-identical to
    /// [`Mat::inverse`] (same factorization, same solve loops).
    pub fn inverse_logdet(&self) -> Option<(Mat, f64)> {
        let n = self.rows;
        let (lu, perm, _) = self.lu()?;
        let mut logdet = 0.0f64;
        for i in 0..n {
            logdet += (lu[(i, i)].abs() as f64).ln();
        }
        // solve A X = I with the factorization (the loops of `solve`,
        // with the permuted identity column inlined)
        let mut x = Mat::zeros(n, n);
        for c in 0..n {
            let mut y = vec![0.0f32; n];
            for i in 0..n {
                let mut s = if perm[i] == c { 1.0 } else { 0.0 };
                for j in 0..i {
                    s -= lu[(i, j)] * y[j];
                }
                y[i] = s;
            }
            for i in (0..n).rev() {
                let mut s = y[i];
                for j in i + 1..n {
                    s -= lu[(i, j)] * x[(j, c)];
                }
                x[(i, c)] = s / lu[(i, i)];
            }
        }
        Some((x, logdet))
    }

    pub fn det(&self) -> f32 {
        match self.lu() {
            None => 0.0,
            Some((lu, _, sign)) => {
                let mut d = sign;
                for i in 0..self.rows {
                    d *= lu[(i, i)];
                }
                d
            }
        }
    }

    /// Spectral norm (largest singular value) by power iteration on AᵀA.
    pub fn spectral_norm(&self) -> f32 {
        let mut v = vec![1.0f32; self.cols];
        let norm = |x: &[f32]| x.iter().map(|a| a * a).sum::<f32>().sqrt();
        let mut prev = 0.0f32;
        for _ in 0..200 {
            // w = A v ; u = Aᵀ w
            let mut w = vec![0.0f32; self.rows];
            for i in 0..self.rows {
                w[i] = self.row(i).iter().zip(&v).map(|(a, b)| a * b).sum();
            }
            let mut u = vec![0.0f32; self.cols];
            for i in 0..self.rows {
                let wi = w[i];
                for (uj, aij) in u.iter_mut().zip(self.row(i)) {
                    *uj += aij * wi;
                }
            }
            let n = norm(&u);
            if n == 0.0 {
                return 0.0;
            }
            for x in u.iter_mut() {
                *x /= n;
            }
            let sigma = n.sqrt();
            if (sigma - prev).abs() <= 1e-6 * sigma.max(1e-12) {
                return sigma;
            }
            prev = sigma;
            v = u;
        }
        prev
    }

    /// Condition number estimate sigma_max(A) * sigma_max(A^-1).
    pub fn condition(&self) -> f32 {
        match self.inverse() {
            None => f32::INFINITY,
            Some(inv) => self.spectral_norm() * inv.spectral_norm(),
        }
    }

    /// Zero out the `block x block` diagonal blocks (Fig. 3b metric).
    pub fn off_block_diagonal(&self, block: usize) -> Mat {
        let mut m = self.clone();
        let n = self.rows;
        for o in (0..n).step_by(block) {
            for i in o..(o + block).min(n) {
                for j in o..(o + block).min(n) {
                    m[(i, j)] = 0.0;
                }
            }
        }
        m
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

/// `X @ w` for `m` row-major activation rows in `x`, accumulated into the
/// caller-provided zeroed `out` — the allocation-free spelling of
/// [`Mat::matmul`], which delegates here. The decode hot path calls this
/// with `util::scratch` buffers so a steady-state token step performs no
/// heap allocation. Kernel, parallel-fan threshold, and accumulation order
/// are byte-for-byte those of the old `Mat::matmul` body, so results stay
/// bit-identical (packed_gemm_props gates this against the packed GEMM).
pub fn matmul_rows_into(x: &[f32], m: usize, w: &Mat, out: &mut [f32]) {
    let (kd, n) = (w.rows, w.cols);
    assert_eq!(x.len(), m * kd, "matmul_rows_into lhs shape mismatch");
    assert_eq!(out.len(), m * n, "matmul_rows_into out shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    let row_kernel = |i: usize, orow: &mut [f32]| {
        let arow = &x[i * kd..(i + 1) * kd];
        let mut k = 0;
        while k + 4 <= kd {
            let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
            let b0 = &w.data[k * n..(k + 1) * n];
            let b1 = &w.data[(k + 1) * n..(k + 2) * n];
            let b2 = &w.data[(k + 2) * n..(k + 3) * n];
            let b3 = &w.data[(k + 3) * n..(k + 4) * n];
            for j in 0..n {
                orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            k += 4;
        }
        while k < kd {
            let a = arow[k];
            let brow = &w.data[k * n..(k + 1) * n];
            for (o, b) in orow.iter_mut().zip(brow.iter()) {
                *o += a * b;
            }
            k += 1;
        }
    };
    if m < 2 || m * n < par::PAR_MIN_LEN {
        for (i, orow) in out.chunks_mut(n).enumerate() {
            row_kernel(i, orow);
        }
    } else {
        par::for_each_chunk(out, n, row_kernel);
    }
}

/// Assemble a block-diagonal matrix from square blocks.
pub fn block_diag(blocks: &[Mat]) -> Mat {
    let n: usize = blocks.iter().map(|b| b.rows).sum();
    let mut out = Mat::zeros(n, n);
    let mut o = 0;
    for b in blocks {
        assert_eq!(b.rows, b.cols);
        for i in 0..b.rows {
            for j in 0..b.cols {
                out[(o + i, o + j)] = b[(i, j)];
            }
        }
        o += b.rows;
    }
    out
}

/// Random orthogonal matrix via Gram-Schmidt QR of a Gaussian matrix.
pub fn random_orthogonal(n: usize, rng: &mut crate::util::Pcg64) -> Mat {
    let g = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
    // modified Gram-Schmidt on columns
    let mut q = g.t(); // rows of q = columns of g
    for i in 0..n {
        for j in 0..i {
            let dot: f32 = (0..n).map(|k| q[(i, k)] * q[(j, k)]).sum();
            for k in 0..n {
                let v = q[(j, k)];
                q[(i, k)] -= dot * v;
            }
        }
        let norm: f32 = (0..n).map(|k| q[(i, k)] * q[(i, k)]).sum::<f32>().sqrt();
        for k in 0..n {
            q[(i, k)] /= norm.max(1e-12);
        }
    }
    q.t()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn rand_mat(n: usize, seed: u64) -> Mat {
        let mut r = Pcg64::seed(seed);
        Mat::from_vec(n, n, r.normal_vec(n * n, 1.0))
    }

    #[test]
    fn matmul_identity() {
        let a = rand_mat(16, 1);
        let i = Mat::eye(16);
        assert!(a.matmul(&i).sub(&a).max_abs() < 1e-6);
        assert!(i.matmul(&a).sub(&a).max_abs() < 1e-6);
    }

    #[test]
    fn inverse_roundtrip() {
        let a = rand_mat(24, 2);
        let inv = a.inverse().unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.sub(&Mat::eye(24)).max_abs() < 1e-3, "{}", prod.sub(&Mat::eye(24)).max_abs());
    }

    #[test]
    fn solve_matches_inverse() {
        let a = rand_mat(12, 3);
        let b = rand_mat(12, 4);
        let x = a.solve(&b).unwrap();
        assert!(a.matmul(&x).sub(&b).max_abs() < 1e-3);
    }

    #[test]
    fn inverse_logdet_matches_separate_calls() {
        let a = rand_mat(24, 2);
        let (inv, logdet) = a.inverse_logdet().unwrap();
        assert_eq!(inv, a.inverse().unwrap(), "must be bit-identical to inverse()");
        assert!((logdet - (a.det().abs() as f64).ln()).abs() < 1e-3, "{logdet}");
        assert!(Mat::zeros(8, 8).inverse_logdet().is_none());
    }

    #[test]
    fn det_of_diag() {
        let mut d = Mat::eye(4);
        d[(0, 0)] = 2.0;
        d[(1, 1)] = 3.0;
        assert!((d.det() - 6.0).abs() < 1e-5);
    }

    #[test]
    fn spectral_norm_of_diag() {
        let mut d = Mat::eye(8);
        d[(3, 3)] = -5.0;
        assert!((d.spectral_norm() - 5.0).abs() < 1e-3);
    }

    #[test]
    fn orthogonal_is_orthogonal() {
        let mut rng = Pcg64::seed(4);
        let q = random_orthogonal(32, &mut rng);
        let qtq = q.t().matmul(&q);
        assert!(qtq.sub(&Mat::eye(32)).max_abs() < 1e-4);
        assert!((q.condition() - 1.0).abs() < 1e-2);
    }

    #[test]
    fn block_diag_assembly() {
        let b = Mat::eye(2).scale(2.0);
        let m = block_diag(&[b.clone(), b]);
        assert_eq!(m.rows, 4);
        assert_eq!(m[(0, 0)], 2.0);
        assert_eq!(m[(2, 2)], 2.0);
        assert_eq!(m[(0, 2)], 0.0);
    }

    #[test]
    fn off_block_diagonal_zeroes_blocks() {
        let a = rand_mat(8, 5);
        let off = a.off_block_diagonal(4);
        assert_eq!(off[(0, 0)], 0.0);
        assert_eq!(off[(5, 6)], 0.0);
        assert_eq!(off[(0, 5)], a[(0, 5)]);
    }

    #[test]
    fn matmul_cols_slices_full_product_bitwise() {
        let mut r = Pcg64::seed(9);
        // kd = 37 exercises the 4-wide remainder
        let a = Mat::from_vec(5, 37, r.normal_vec(5 * 37, 1.0));
        let w = Mat::from_vec(37, 24, r.normal_vec(37 * 24, 1.0));
        let full = a.matmul(&w);
        for (c0, c1) in [(0usize, 24usize), (8, 16), (5, 7), (24, 24)] {
            let cols = w.matmul_cols(&a, c0, c1);
            assert_eq!((cols.rows, cols.cols), (5, c1 - c0));
            for i in 0..5 {
                for j in c0..c1 {
                    assert_eq!(
                        cols[(i, j - c0)].to_bits(),
                        full[(i, j)].to_bits(),
                        "cols [{c0},{c1}) elem ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_band_full_range_matches_matmul_bitwise() {
        let mut r = Pcg64::seed(10);
        let a = Mat::from_vec(3, 37, r.normal_vec(3 * 37, 1.0));
        let w = Mat::from_vec(37, 16, r.normal_vec(37 * 16, 1.0));
        // a single band spanning all weight rows is the whole GEMM — same
        // k-order, so bit-identical to matmul
        let band = w.matmul_band(&a, 0, 37);
        let full = a.matmul(&w);
        for (x, y) in band.data.iter().zip(&full.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // partial bands sum to the full product up to f32 association
        let lo = w.matmul_band(&Mat::from_vec(3, 20, cols_slice(&a, 0, 20)), 0, 20);
        let hi = w.matmul_band(&Mat::from_vec(3, 17, cols_slice(&a, 20, 37)), 20, 37);
        let sum = lo.add(&hi);
        assert!(sum.sub(&full).max_abs() < 1e-4);
    }

    fn cols_slice(a: &Mat, c0: usize, c1: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(a.rows * (c1 - c0));
        for i in 0..a.rows {
            out.extend_from_slice(&a.data[i * a.cols + c0..i * a.cols + c1]);
        }
        out
    }

    #[test]
    fn affine_apply_matches_matmul() {
        let a = rand_mat(8, 6);
        let mut r = Pcg64::seed(7);
        let x = r.normal_vec(8, 1.0);
        let v = r.normal_vec(8, 1.0);
        let y = a.apply_affine(&x, Some(&v));
        for j in 0..8 {
            let expect: f32 = (0..8).map(|k| x[k] * a[(k, j)]).sum::<f32>() + v[j];
            assert!((y[j] - expect).abs() < 1e-4);
        }
    }
}
