//! Normalized Sylvester Hadamard matrices and the fast in-place block
//! transform (the serving-side mirror of the L1 `hadamard.py` kernel; used
//! by the analysis benches and the quantization substrate).

use super::Mat;

/// Normalized Hadamard matrix (H Hᵀ = I); `n` must be a power of two.
pub fn hadamard(n: usize) -> Mat {
    assert!(n.is_power_of_two(), "Hadamard size {n} not a power of 2");
    let mut m = Mat::zeros(n, n);
    let scale = 1.0 / (n as f32).sqrt();
    for i in 0..n {
        for j in 0..n {
            // H[i][j] = (-1)^{popcount(i & j)} (Sylvester construction)
            let sign = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
            m[(i, j)] = sign * scale;
        }
    }
    m
}

/// Fast Walsh-Hadamard transform of one `block`-sized chunk, in place.
/// O(B log B) butterflies + 1/sqrt(B) normalization.
#[inline]
pub fn fwht_inplace(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += h * 2;
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Apply the normalized block-Hadamard to each `block`-sized group of `x`
/// (the online T3 transform). `x.len()` must be a multiple of `block`.
pub fn block_hadamard_apply(x: &mut [f32], block: usize) {
    assert_eq!(x.len() % block, 0);
    for chunk in x.chunks_mut(block) {
        fwht_inplace(chunk);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn hadamard_orthogonal() {
        for n in [2usize, 8, 32] {
            let h = hadamard(n);
            let hth = h.t().matmul(&h);
            assert!(hth.sub(&Mat::eye(n)).max_abs() < 1e-5);
        }
    }

    #[test]
    fn fwht_matches_matrix() {
        let mut rng = Pcg64::seed(1);
        let x = rng.normal_vec(32, 1.0);
        let h = hadamard(32);
        let expect = h.apply_affine(&x, None);
        // NOTE: apply_affine computes x @ H; the FWHT computes H x — the
        // Sylvester H is symmetric so these coincide.
        let mut got = x.clone();
        fwht_inplace(&mut got);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-4, "{g} vs {e}");
        }
    }

    #[test]
    fn fwht_energy_preserving_and_involutive() {
        let mut rng = Pcg64::seed(2);
        let x = rng.normal_vec(64, 2.0);
        let norm = |v: &[f32]| v.iter().map(|a| a * a).sum::<f32>().sqrt();
        let mut y = x.clone();
        fwht_inplace(&mut y);
        assert!((norm(&x) - norm(&y)).abs() < 1e-3);
        fwht_inplace(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn block_apply_is_per_block() {
        let mut x = vec![0.0f32; 64];
        x[0] = 1.0; // only first block affected
        block_hadamard_apply(&mut x, 32);
        assert!(x[..32].iter().all(|v| v.abs() > 0.0));
        assert!(x[32..].iter().all(|v| *v == 0.0));
    }
}
