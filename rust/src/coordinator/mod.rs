//! L3 coordinator: the serving stack that drives the AOT-compiled decode /
//! prefill graphs — request router, admission layer, KV-slot manager, and
//! the continuous-batching engine loop (std-thread + channels; tokio is
//! not vendorable offline, and a single-node CPU serving loop does not
//! need it).
//!
//! Shape of the system (vLLM-style, scaled to this testbed):
//!
//! ```text
//!  clients ──▶ Router ──▶ admission queue ──▶ Batcher ──▶ Engine step loop
//!                 ▲      (bounded, deadlines,    │            │
//!                 │       cancel, backpressure)  │            ├─▶ TokenSink
//!                 └──── results ◀────────────────┴── KvCache ◀┘   (stream)
//! ```
//!
//! The engine is **continuously batched**: requests join and leave
//! mid-decode. Each iteration sweeps deadlines/cancellations (evicted
//! lanes free their KV slot immediately), admits waiting requests into the
//! freed slots (prefill), then runs one decode step over all running
//! lanes, re-bucketed per step to the compiled batch sizes (1/2/4/8). The
//! pre-refactor static-cohort loop survives as [`LockstepEngine`], the
//! token-parity reference. The paper's runtime claim (Fig. 4) falls out
//! here: all quantized methods share one decode executable, so their
//! throughput is identical by construction and measured as such.

pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod lockstep;
pub mod request;
pub mod router;
pub mod scheduler;

pub use batcher::{Batcher, PushOutcome};
pub use engine::{Engine, EngineConfig, EngineStats};
pub use kv_cache::{KvCache, KvFormat, KvSpec};
pub use lockstep::LockstepEngine;
pub use request::{FinishReason, GenRequest, GenResult, RequestId, StreamEvent, TokenSink};
pub use router::Router;
pub use scheduler::{SchedEvent, SchedulerPolicy, StepPlan};
