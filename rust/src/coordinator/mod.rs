//! L3 coordinator: the serving stack that drives the AOT-compiled decode /
//! prefill graphs — request router, continuous batcher, KV-slot manager,
//! and the engine loop (std-thread + channels; tokio is not vendorable
//! offline, and a single-node CPU serving loop does not need it).
//!
//! Shape of the system (vLLM-style, scaled to this testbed):
//!
//! ```text
//!  clients ──▶ Router ──▶ admission queue ──▶ Batcher ──▶ Engine step loop
//!                 ▲                              │            │
//!                 └──── completions ◀────────────┴── KvCache ◀┘
//! ```
//!
//! The engine interleaves prefill and decode: each iteration admits up to
//! one prefill batch of waiting requests (if slots are free), then runs one
//! decode step over all running sequences, bucketed to the compiled batch
//! sizes (1/2/4/8). The paper's runtime claim (Fig. 4) falls out here: all
//! quantized methods share one decode executable, so their throughput is
//! identical by construction and measured as such.

pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod request;
pub mod router;
pub mod scheduler;

pub use batcher::Batcher;
pub use engine::{Engine, EngineConfig, EngineStats};
pub use kv_cache::KvCache;
pub use request::{GenRequest, GenResult, RequestId};
pub use router::Router;
pub use scheduler::{SchedulerPolicy, StepPlan};
