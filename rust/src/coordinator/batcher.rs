//! Continuous batcher: FIFO admission queue + batch-size bucketing.
//! Method-agnostic by the paper's Sec. 4.1 design: every quantized
//! transform shares one decode executable per batch size, so bucketing
//! never depends on which transform produced the weights.
//!
//! The AOT artifacts are compiled at fixed batch sizes (1/2/4/8); the
//! batcher picks, for a given number of ready lanes, the bucket that
//! maximizes occupancy (smallest compiled size >= lanes, else the largest
//! size, repeatedly). Invariants (property-tested): no request is lost or
//! duplicated; admission order is FIFO; a formed batch never exceeds the
//! requested capacity.

use std::collections::VecDeque;

use super::request::GenRequest;

pub struct Batcher {
    queue: VecDeque<GenRequest>,
    /// Compiled batch sizes, ascending.
    pub buckets: Vec<usize>,
    admitted: u64,
    enqueued: u64,
}

impl Batcher {
    pub fn new(mut buckets: Vec<usize>) -> Self {
        buckets.sort_unstable();
        assert!(!buckets.is_empty());
        Batcher { queue: VecDeque::new(), buckets, admitted: 0, enqueued: 0 }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn counters(&self) -> (u64, u64) {
        (self.enqueued, self.admitted)
    }

    /// Smallest compiled bucket that covers `lanes`, or the largest bucket.
    pub fn bucket_for(&self, lanes: usize) -> usize {
        for &b in &self.buckets {
            if b >= lanes {
                return b;
            }
        }
        *self.buckets.last().unwrap()
    }

    /// Admit up to `max_lanes` queued requests (FIFO), bounded by the
    /// largest bucket. Returns the admitted requests (possibly empty).
    pub fn admit(&mut self, max_lanes: usize) -> Vec<GenRequest> {
        let cap = max_lanes.min(*self.buckets.last().unwrap());
        let n = cap.min(self.queue.len());
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.queue.pop_front().unwrap());
        }
        self.admitted += out.len() as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> GenRequest {
        GenRequest::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(vec![1, 2, 4]);
        for id in 0..5 {
            b.push(req(id));
        }
        let batch = b.admit(4);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn bucket_selection() {
        let b = Batcher::new(vec![1, 2, 4, 8]);
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(3), 4);
        assert_eq!(b.bucket_for(8), 8);
        assert_eq!(b.bucket_for(20), 8);
    }

    #[test]
    fn admit_respects_capacity() {
        let mut b = Batcher::new(vec![1, 2, 4]);
        for id in 0..10 {
            b.push(req(id));
        }
        assert_eq!(b.admit(2).len(), 2);
        assert_eq!(b.admit(100).len(), 4); // clamped to largest bucket
        assert_eq!(b.pending(), 4);
    }

    #[test]
    fn counters_conserved() {
        let mut b = Batcher::new(vec![2]);
        for id in 0..7 {
            b.push(req(id));
        }
        let mut admitted = 0;
        while b.pending() > 0 {
            admitted += b.admit(2).len();
        }
        let (enq, adm) = b.counters();
        assert_eq!(enq, 7);
        assert_eq!(adm, 7);
        assert_eq!(admitted, 7);
    }
}
