//! Admission layer: bounded FIFO queue with backpressure, cancellation,
//! deadline expiry, and batch-size bucketing.
//! Method-agnostic by the paper's Sec. 4.1 design: every quantized
//! transform shares one decode executable per batch size, so bucketing
//! never depends on which transform produced the weights.
//!
//! The AOT artifacts are compiled at fixed batch sizes (1/2/4/8); the
//! batcher picks, for a given number of ready lanes, the bucket that
//! maximizes occupancy (smallest compiled size >= lanes, else the largest
//! size, repeatedly). The queue is optionally bounded (`queue_depth`):
//! when full, [`Batcher::try_push`] refuses the request instead of
//! enqueuing it, and the engine turns that refusal into an explicit
//! `RejectedQueueFull` outcome — backpressure the client can see.
//!
//! Invariants (property-tested): no request is lost or duplicated;
//! admission order is FIFO; a formed batch never exceeds the requested
//! capacity; enqueued == admitted + cancelled + expired + still-pending.

use std::collections::VecDeque;

use super::request::GenRequest;

/// Outcome of [`Batcher::try_push`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// Request joined the queue.
    Queued,
    /// Bounded queue was full; the request was NOT enqueued.
    Rejected,
}

pub struct Batcher {
    queue: VecDeque<GenRequest>,
    /// Compiled batch sizes, ascending.
    pub buckets: Vec<usize>,
    /// Maximum queued requests (None = unbounded).
    pub queue_depth: Option<usize>,
    admitted: u64,
    enqueued: u64,
}

impl Batcher {
    pub fn new(mut buckets: Vec<usize>) -> Self {
        buckets.sort_unstable();
        assert!(!buckets.is_empty());
        Batcher { queue: VecDeque::new(), buckets, queue_depth: None, admitted: 0, enqueued: 0 }
    }

    /// Bound the admission queue at `depth` requests.
    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = Some(depth);
        self
    }

    /// Unbounded enqueue (pre-admission-layer API, kept for closed-loop
    /// drivers that submit their whole workload up front).
    pub fn push(&mut self, req: GenRequest) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    /// Enqueue with backpressure: refuses (without consuming a counter
    /// slot in `enqueued`) when the bounded queue is full.
    pub fn try_push(&mut self, req: GenRequest) -> PushOutcome {
        if self.queue_depth.is_some_and(|d| self.queue.len() >= d) {
            return PushOutcome::Rejected;
        }
        self.push(req);
        PushOutcome::Queued
    }

    /// Remove a still-queued request by id (client cancellation before the
    /// request reached a KV slot). Returns the request if found.
    pub fn cancel(&mut self, id: u64) -> Option<GenRequest> {
        let at = self.queue.iter().position(|r| r.id == id)?;
        self.queue.remove(at)
    }

    /// Remove every queued request whose deadline has passed, preserving
    /// FIFO order of the survivors. Returns the expired requests.
    pub fn expire_deadlines(&mut self) -> Vec<GenRequest> {
        let mut expired = Vec::new();
        let mut keep = VecDeque::with_capacity(self.queue.len());
        for req in self.queue.drain(..) {
            if req.expired() {
                expired.push(req);
            } else {
                keep.push_back(req);
            }
        }
        self.queue = keep;
        expired
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn counters(&self) -> (u64, u64) {
        (self.enqueued, self.admitted)
    }

    /// Smallest compiled bucket that covers `lanes`, or the largest bucket.
    pub fn bucket_for(&self, lanes: usize) -> usize {
        for &b in &self.buckets {
            if b >= lanes {
                return b;
            }
        }
        *self.buckets.last().unwrap()
    }

    /// Admit up to `max_lanes` queued requests (FIFO), bounded by the
    /// largest bucket. Returns the admitted requests (possibly empty).
    pub fn admit(&mut self, max_lanes: usize) -> Vec<GenRequest> {
        let cap = max_lanes.min(*self.buckets.last().unwrap());
        let n = cap.min(self.queue.len());
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.queue.pop_front().unwrap());
        }
        self.admitted += out.len() as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;

    fn req(id: u64) -> GenRequest {
        GenRequest::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(vec![1, 2, 4]);
        for id in 0..5 {
            b.push(req(id));
        }
        let batch = b.admit(4);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn bucket_selection() {
        let b = Batcher::new(vec![1, 2, 4, 8]);
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(3), 4);
        assert_eq!(b.bucket_for(8), 8);
        assert_eq!(b.bucket_for(20), 8);
    }

    #[test]
    fn admit_respects_capacity() {
        let mut b = Batcher::new(vec![1, 2, 4]);
        for id in 0..10 {
            b.push(req(id));
        }
        assert_eq!(b.admit(2).len(), 2);
        assert_eq!(b.admit(100).len(), 4); // clamped to largest bucket
        assert_eq!(b.pending(), 4);
    }

    #[test]
    fn counters_conserved() {
        let mut b = Batcher::new(vec![2]);
        for id in 0..7 {
            b.push(req(id));
        }
        let mut admitted = 0;
        while b.pending() > 0 {
            admitted += b.admit(2).len();
        }
        let (enq, adm) = b.counters();
        assert_eq!(enq, 7);
        assert_eq!(adm, 7);
        assert_eq!(admitted, 7);
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let mut b = Batcher::new(vec![4]).with_queue_depth(2);
        assert_eq!(b.try_push(req(0)), PushOutcome::Queued);
        assert_eq!(b.try_push(req(1)), PushOutcome::Queued);
        assert_eq!(b.try_push(req(2)), PushOutcome::Rejected);
        assert_eq!(b.pending(), 2);
        // draining re-opens admission
        b.admit(1);
        assert_eq!(b.try_push(req(3)), PushOutcome::Queued);
        let (enq, _) = b.counters();
        assert_eq!(enq, 3, "rejected request never counted as enqueued");
    }

    #[test]
    fn cancel_mid_queue() {
        let mut b = Batcher::new(vec![4]);
        for id in 0..4 {
            b.push(req(id));
        }
        assert_eq!(b.cancel(2).map(|r| r.id), Some(2));
        assert!(b.cancel(2).is_none());
        let ids: Vec<_> = b.admit(4).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 3], "FIFO order preserved for survivors");
    }

    #[test]
    fn deadline_sweep_evicts_expired_only() {
        let mut b = Batcher::new(vec![4]);
        b.push(req(0).with_deadline(Duration::ZERO));
        b.push(req(1).with_deadline(Duration::from_secs(3600)));
        b.push(req(2).with_deadline(Duration::ZERO));
        b.push(req(3));
        std::thread::sleep(Duration::from_millis(1));
        let expired: Vec<_> = b.expire_deadlines().iter().map(|r| r.id).collect();
        assert_eq!(expired, vec![0, 2]);
        let ids: Vec<_> = b.admit(4).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3]);
    }
}
