//! Retained lockstep (static-cohort) reference engine.
//!
//! This is the serving loop the continuous-batching [`super::Engine`]
//! replaced: admit a fixed cohort of requests, prefill them together, then
//! decode until **every** lane in the cohort finishes before admitting the
//! next cohort — one long request stalls the whole batch, which is exactly
//! the inefficiency continuous batching removes.
//!
//! It is kept (and kept deliberately simple and independent — no shared
//! scheduling code with `Engine`) as the correctness anchor for the
//! refactor: because the model forward is lane-independent, a closed-loop
//! workload with no cancellations must produce **bit-identical per-request
//! token sequences** on both engines; only the decode interleaving may
//! differ. `rust/tests/serving_pipeline.rs` gates this on every run.
//!
//! After the paged-KV refactor this anchor carries extra weight: the
//! lockstep lanes keep plain **dense** per-lane planes (below), so the
//! parity gate also pins the continuous engine's paged f32 cache —
//! page-table gather, copy-on-write prefix sharing, append-on-decode —
//! to the dense layout bit for bit.

use std::collections::VecDeque;

use anyhow::Result;

use super::engine::{argmax, EngineConfig, StepExecutor};
use super::request::{FinishReason, GenRequest, GenResult};

/// Per-lane state within a cohort.
struct Lane {
    req: GenRequest,
    prompt_len: usize,
    generated: Vec<i32>,
    token_s: Vec<f64>,
    /// One `(kv_seq, row)` plane per (layer, k/v).
    kv: Vec<Vec<f32>>,
    pos: usize,
    done: Option<FinishReason>,
}

/// Static-cohort lockstep engine: the pre-refactor serving loop, retained
/// as the token-parity reference (`queue_depth`/cancellation are not
/// supported here — it exists to replay closed-loop workloads).
pub struct LockstepEngine<E: StepExecutor> {
    pub exec: E,
    pub cfg: EngineConfig,
    queue: VecDeque<GenRequest>,
}

impl<E: StepExecutor> LockstepEngine<E> {
    pub fn new(exec: E, cfg: EngineConfig) -> Self {
        LockstepEngine { exec, cfg, queue: VecDeque::new() }
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Smallest compiled bucket covering `lanes`, else the largest —
    /// mirrors `Batcher::bucket_for` without sharing its state.
    fn bucket_for(&self, lanes: usize) -> usize {
        let sizes = self.exec.batch_sizes();
        sizes.iter().copied().find(|b| *b >= lanes).unwrap_or(*sizes.last().unwrap())
    }

    /// Drain the queue cohort by cohort; returns results sorted by id.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        let mut results = Vec::new();
        while !self.queue.is_empty() {
            let cohort_cap = self.cfg.max_slots.min(*self.exec.batch_sizes().last().unwrap());
            let n = cohort_cap.min(self.queue.len());
            let cohort: Vec<GenRequest> = self.queue.drain(..n).collect();
            results.extend(self.run_cohort(cohort)?);
        }
        results.sort_by_key(|r| r.id);
        Ok(results)
    }

    /// Prefill one cohort, then decode until every lane finishes.
    fn run_cohort(&mut self, cohort: Vec<GenRequest>) -> Result<Vec<GenResult>> {
        let pl = self.exec.prefill_len();
        let vocab = self.exec.vocab();
        let kv_seq = self.exec.kv_seq();
        let plane = kv_seq * self.exec.kv_row();
        let n_planes = self.exec.n_layers() * 2;

        // prefill the whole cohort in one bucketed batch
        let batch = self.bucket_for(cohort.len());
        let mut tokens = vec![0i32; batch * pl];
        let mut lens = vec![1i32; batch];
        for (i, r) in cohort.iter().enumerate() {
            let l = r.prompt.len().min(pl);
            tokens[i * pl..i * pl + l].copy_from_slice(&r.prompt[..l]);
            lens[i] = l as i32;
        }
        let (logits, kv_planes) = self.exec.prefill(&tokens, &lens, batch)?;

        let mut lanes: Vec<Lane> = Vec::with_capacity(cohort.len());
        for (i, req) in cohort.into_iter().enumerate() {
            let prompt_len = req.prompt.len().min(pl);
            let kv: Vec<Vec<f32>> = (0..n_planes)
                .map(|li| kv_planes[li][i * plane..(i + 1) * plane].to_vec())
                .collect();
            let first = argmax(&logits[i * vocab..(i + 1) * vocab]);
            let t = req.arrived.elapsed().as_secs_f64();
            let done = if first == self.cfg.eos {
                Some(FinishReason::Eos)
            } else if req.max_new_tokens <= 1 {
                Some(FinishReason::Length)
            } else {
                None
            };
            lanes.push(Lane {
                req,
                prompt_len,
                generated: vec![first],
                token_s: vec![t],
                kv,
                pos: prompt_len,
                done,
            });
        }

        // lockstep decode: the cohort is not refilled — finished lanes sit
        // idle until the slowest lane drains
        while lanes.iter().any(|l| l.done.is_none()) {
            let active: Vec<usize> =
                (0..lanes.len()).filter(|i| lanes[*i].done.is_none()).collect();
            let batch = self.bucket_for(active.len());
            let mut tokens = vec![0i32; batch];
            let mut pos = vec![0i32; batch];
            let mut kv_in = vec![vec![0.0f32; batch * plane]; n_planes];
            for (lane, i) in active.iter().enumerate() {
                let l = &lanes[*i];
                tokens[lane] = *l.generated.last().unwrap();
                pos[lane] = l.pos as i32;
                for (li, buf) in kv_in.iter_mut().enumerate() {
                    buf[lane * plane..(lane + 1) * plane].copy_from_slice(&l.kv[li]);
                }
            }
            let (logits, kv_out) = self.exec.decode(&tokens, &pos, &kv_in, batch)?;
            for (lane, i) in active.iter().enumerate() {
                let l = &mut lanes[*i];
                for (li, buf) in kv_out.iter().enumerate() {
                    l.kv[li].copy_from_slice(&buf[lane * plane..(lane + 1) * plane]);
                }
                l.pos += 1;
                let next = argmax(&logits[lane * vocab..(lane + 1) * vocab]);
                l.generated.push(next);
                l.token_s.push(l.req.arrived.elapsed().as_secs_f64());
                l.done = if next == self.cfg.eos {
                    Some(FinishReason::Eos)
                } else if l.generated.len() >= l.req.max_new_tokens {
                    Some(FinishReason::Length)
                } else if l.prompt_len + l.generated.len() >= kv_seq {
                    Some(FinishReason::KvLimit)
                } else {
                    None
                };
            }
        }

        Ok(lanes
            .into_iter()
            .map(|l| GenResult {
                id: l.req.id,
                prompt_len: l.prompt_len,
                ttft_s: l.token_s.first().copied().unwrap_or(0.0),
                total_s: l.req.arrived.elapsed().as_secs_f64(),
                outcome: l.done.unwrap(),
                tokens: l.generated,
                token_s: l.token_s,
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::MockExecutor;
    use super::*;

    fn engine() -> LockstepEngine<MockExecutor> {
        LockstepEngine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 4, eos: -1, ..Default::default() },
        )
    }

    #[test]
    fn single_request_matches_mock_semantics() {
        let mut e = engine();
        e.submit(GenRequest::new(1, vec![5, 6], 4));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out[0].tokens, vec![11, 12, 13, 14]);
        assert_eq!(out[0].outcome, FinishReason::Length);
    }

    #[test]
    fn cohorts_drain_everything() {
        let mut e = engine();
        for id in 0..10 {
            e.submit(GenRequest::new(id, vec![id as i32], 3));
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 10);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 3);
        }
    }

    #[test]
    fn mixed_lengths_cohort_waits_for_slowest() {
        let mut e = engine();
        e.submit(GenRequest::new(0, vec![1], 2));
        e.submit(GenRequest::new(1, vec![2], 9));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out[0].tokens.len(), 2);
        assert_eq!(out[1].tokens.len(), 9);
    }

    #[test]
    fn eos_finishes_lane() {
        let mut e = LockstepEngine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 2, eos: 12, ..Default::default() },
        );
        e.submit(GenRequest::new(1, vec![5, 6], 10));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out[0].tokens, vec![11, 12]);
        assert_eq!(out[0].outcome, FinishReason::Eos);
    }
}
