//! The serving engine: continuous-batching generation loop over an abstract
//! [`StepExecutor`] — the measurement loop behind the paper's Fig. 4
//! claim that all MX methods serve at indistinguishable throughput.
//! Two real backends implement it — `XlaExecutor` (PJRT,
//! behind the `backend-xla` feature) and [`NativeExecutor`] (pure-Rust
//! interpreter, always available) — while unit and property tests use
//! [`MockExecutor`]. Both real executors discover their compiled batch
//! sizes through the shared [`crate::runtime::decode_batch_sizes`] parser,
//! so batch selection can never disagree across backends.
//!
//! Since the continuous-batching refactor the engine is a three-stage
//! pipeline driven one [`Engine::step`] at a time:
//!
//! 1. **admission** — a bounded queue ([`Batcher`]) with backpressure
//!    ([`Engine::try_submit`] refuses with `RejectedQueueFull` when full),
//!    client cancellation, and deadline expiry;
//! 2. **schedule + decode** — at every step boundary, expired/cancelled
//!    lanes are evicted and their KV slots reclaimed, waiting requests
//!    refill the freed slots (prefill), and all running lanes decode one
//!    token, re-bucketed per step via `Batcher::bucket_for`;
//! 3. **stream** — each generated token is pushed to an optional
//!    [`TokenSink`] as it is produced, and every scheduling decision is
//!    appended to the [`SchedEvent`] log that the cross-backend parity
//!    fingerprints hash.
//!
//! Correctness anchor: because the native forward is lane-independent
//! (padding lanes are zeroed, per-lane loops), a closed-loop workload with
//! no cancellations produces **bit-identical per-request token sequences**
//! to the retained [`super::lockstep::LockstepEngine`] reference — decode
//! order may differ, tokens may not (gated in
//! `rust/tests/serving_pipeline.rs`).

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::{Batcher, PushOutcome};
use super::kv_cache::{KvCache, KvSpec};
use super::request::{FinishReason, GenRequest, GenResult, RequestId, StreamEvent, TokenSink};
use super::scheduler::{plan_admit, SchedEvent, SchedulerPolicy};
use crate::model::{
    GraphSpec, ModelDesc, NativeDims, NativeWeights, PackedNativeWeights, ShardPlan, SpecRun,
    WeightSet,
};
use crate::runtime::decode_batch_sizes;
use crate::transform::{TransformMode, TransformSpec};
use crate::util::{par, scratch};
#[cfg(feature = "backend-xla")]
use crate::runtime::{f32_literal, i32_literal, literal_to_f32, Runtime};

/// One model-step backend: prefill a batch of prompts / decode one token.
pub trait StepExecutor {
    fn vocab(&self) -> usize;
    fn n_layers(&self) -> usize;
    fn kv_seq(&self) -> usize;
    fn kv_row(&self) -> usize;
    fn prefill_len(&self) -> usize;
    /// Supported (compiled) batch sizes, ascending.
    fn batch_sizes(&self) -> Vec<usize>;

    /// `tokens`: (batch, prefill_len) padded; `lens`: true prompt lengths.
    /// Returns (last-position logits (batch, vocab), KV planes — one
    /// `(batch, kv_seq, row)` buffer per (layer, k/v)).
    fn prefill(&self, tokens: &[i32], lens: &[i32], batch: usize)
        -> Result<(Vec<f32>, Vec<Vec<f32>>)>;

    /// One decode step at per-lane positions.
    fn decode(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)>;

    /// One decode step returning the *appended* K/V rows instead of full
    /// planes: `rows[li]` is the fresh `(batch, kv_row)` row each lane
    /// writes at its `pos` (k before v per layer) — what the paged
    /// `KvCache` quantizes on write. The default adapter slices the row
    /// out of a full [`StepExecutor::decode`] output; executors with an
    /// append-native forward override it to skip materializing
    /// `O(kv_seq)` output planes per step.
    fn decode_append(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let (logits, kv_out) = self.decode(tokens, pos, kv, batch)?;
        let (row, s_max) = (self.kv_row(), self.kv_seq());
        let plane = s_max * row;
        let rows = kv_out
            .iter()
            .map(|buf| {
                let mut out = vec![0.0f32; batch * row];
                for b in 0..batch.min(pos.len()) {
                    let p = pos[b];
                    if p >= 0 && (p as usize) < s_max {
                        let at = b * plane + (p as usize) * row;
                        out[b * row..(b + 1) * row].copy_from_slice(&buf[at..at + row]);
                    }
                }
                out
            })
            .collect();
        Ok((logits, rows))
    }

    /// The executor's persistent fork-join pool, if it owns one. The engine
    /// installs it around its own parallel stages (KV gather fan-out) so a
    /// steady-state decode step never spawns scoped threads — pool workers
    /// keep their scratch arenas warm, which is what the zero-allocation
    /// gate (`rust/tests/alloc_steady_state.rs`) measures. `None` (the
    /// default) means those stages run on ephemeral scoped threads.
    fn pool(&self) -> Option<Arc<par::WorkerPool>> {
        None
    }
}

// ---------------------------------------------------------------------------

/// PJRT-backed executor for one (graph tag, weight set) pair.
#[cfg(feature = "backend-xla")]
pub struct XlaExecutor<'rt> {
    pub rt: &'rt Runtime,
    pub tag: String,
    weights: Vec<xla::Literal>,
    batches: Vec<usize>,
}

#[cfg(feature = "backend-xla")]
impl<'rt> XlaExecutor<'rt> {
    /// `tag` is the graph quant tag, e.g. "fp" or "mxfp4_b32_t3".
    pub fn new(rt: &'rt Runtime, tag: &str, ws: &WeightSet) -> Result<Self> {
        let weights = rt.stage_weights(ws)?;
        let batches = decode_batch_sizes(&rt.desc.graphs, tag);
        anyhow::ensure!(!batches.is_empty(), "no decode graphs for tag {tag}");
        Ok(XlaExecutor { rt, tag: tag.to_string(), weights, batches })
    }

    fn desc(&self) -> &ModelDesc {
        &self.rt.desc
    }
}

#[cfg(feature = "backend-xla")]
impl StepExecutor for XlaExecutor<'_> {
    fn vocab(&self) -> usize {
        self.desc().vocab
    }
    fn n_layers(&self) -> usize {
        self.desc().n_layers
    }
    fn kv_seq(&self) -> usize {
        self.desc().kv_seq
    }
    fn kv_row(&self) -> usize {
        self.desc().d_model
    }
    fn prefill_len(&self) -> usize {
        self.desc().prefill_len
    }
    fn batch_sizes(&self) -> Vec<usize> {
        self.batches.clone()
    }

    fn prefill(&self, tokens: &[i32], lens: &[i32], batch: usize)
        -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let graph = format!("prefill_{}_b{}", self.tag, batch);
        let t = i32_literal(tokens, &[batch as i64, self.prefill_len() as i64])?;
        let l = i32_literal(lens, &[batch as i64])?;
        // borrow staged weights — no per-call weight copies
        let mut inputs: Vec<&xla::Literal> = vec![&t, &l];
        inputs.extend(self.weights.iter());
        let parts = self.rt.execute(&graph, &inputs)?;
        split_logits_kv(parts)
    }

    fn decode(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let graph = format!("decode_{}_b{}", self.tag, batch);
        let desc = self.desc();
        let t = i32_literal(tokens, &[batch as i64])?;
        let p = i32_literal(pos, &[batch as i64])?;
        let kv_dims = [
            batch as i64,
            desc.kv_seq as i64,
            desc.n_heads as i64,
            desc.head_dim() as i64,
        ];
        let kv_lits = kv
            .iter()
            .map(|plane| f32_literal(plane, &kv_dims))
            .collect::<Result<Vec<_>>>()?;
        let mut inputs: Vec<&xla::Literal> = vec![&t, &p];
        inputs.extend(self.weights.iter());
        inputs.extend(kv_lits.iter());
        let parts = self.rt.execute(&graph, &inputs)?;
        split_logits_kv(parts)
    }
}

#[cfg(feature = "backend-xla")]
fn split_logits_kv(mut parts: Vec<xla::Literal>) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
    anyhow::ensure!(!parts.is_empty(), "empty result tuple");
    let rest = parts.split_off(1);
    let logits = literal_to_f32(&parts[0])?;
    let kv = rest.iter().map(literal_to_f32).collect::<Result<Vec<_>>>()?;
    Ok((logits, kv))
}

// ---------------------------------------------------------------------------

/// Pure-Rust executor: the same `.lxt` weights and compiled-batch-size
/// discipline as `XlaExecutor`, with prefill/decode interpreted by
/// [`NativeWeights`] (`linalg::Mat` matmuls, `transform`/Hadamard ops, MX
/// QDQ kernels) instead of PJRT. This is the serving path on machines
/// without the XLA toolchain — stock CI runners included — and the serving
/// path for `latmix fold` output: [`NativeExecutor::new`] picks up a
/// version-2 manifest's online transform remainder automatically, and
/// [`NativeExecutor::from_weights_with_spec`] runs the unfolded reference
/// semantics for parity gates.
#[derive(Clone)]
pub struct NativeExecutor {
    pub tag: String,
    weights: ExecWeights,
    spec: GraphSpec,
    batches: Vec<usize>,
    transforms: Option<(TransformSpec, TransformMode)>,
    /// Tensor-parallel shard plan (`--workers N`). `None` serves on the
    /// original single-worker forward; `Some` routes every step through
    /// the sharded forward, whose output is bit-identical for any worker
    /// count under the same plan (`rust/tests/shard_parity.rs`).
    shard: Option<ShardPlan>,
    /// Persistent fork-join pool: every prefill/decode dispatch installs it
    /// as the `util::par` substrate, so GEMM row fans and shard fork-joins
    /// reuse long-lived pinned workers instead of spawning scoped threads
    /// per stage. Clones share the pool (`Arc`); the last drop shuts it
    /// down and joins the workers.
    pool: Arc<par::WorkerPool>,
}

/// Weight storage mode of a [`NativeExecutor`]: dense f32 matrices, or
/// bit-packed MX ([`PackedNativeWeights`]) consumed in place by the fused
/// `linalg::packed_matmul` kernel. Both run the same generic forward —
/// the enum only picks the `linear()` instantiation.
#[derive(Clone)]
enum ExecWeights {
    Dense(NativeWeights),
    Packed(PackedNativeWeights),
}

impl NativeExecutor {
    /// Artifact-backed constructor: same signature shape as
    /// `XlaExecutor::new` — manifest dims + graph inventory + `.lxt`
    /// weight set, batch sizes parsed from `decode_<tag>_b*` names. Loads
    /// the manifest's online transform spec (`transform.online`) when one
    /// is declared, so folded artifact directories serve correctly with no
    /// further plumbing.
    pub fn new(desc: &ModelDesc, tag: &str, ws: &WeightSet) -> Result<Self> {
        let spec = GraphSpec::from_tag(tag)?;
        let dims = NativeDims::from_desc(desc);
        spec.validate(&dims)?;
        let weights = NativeWeights::from_weight_set(dims, &desc.weight_order, ws)?;
        let batches = decode_batch_sizes(&desc.graphs, tag);
        anyhow::ensure!(!batches.is_empty(), "no decode graphs for tag {tag}");
        let transforms = TransformSpec::load_online(desc)?;
        Ok(NativeExecutor {
            tag: tag.to_string(),
            weights: ExecWeights::Dense(weights),
            spec,
            batches,
            transforms,
            shard: None,
            pool: Arc::new(par::WorkerPool::new()),
        })
    }

    /// Artifact-free constructor (tests, smoke benches): deterministic
    /// random-init weights and an explicit compiled-batch list.
    pub fn synthetic(dims: NativeDims, tag: &str, batches: Vec<usize>, seed: u64) -> Result<Self> {
        NativeExecutor::from_weights(NativeWeights::synthetic(dims, seed), tag, batches)
    }

    /// Wrap pre-built weights (e.g. parsed from an in-memory weight set).
    pub fn from_weights(weights: NativeWeights, tag: &str, batches: Vec<usize>) -> Result<Self> {
        let spec = GraphSpec::from_tag(tag)?;
        spec.validate(&weights.dims)?;
        let batches = normalize_batches(batches)?;
        Ok(NativeExecutor {
            tag: tag.to_string(),
            weights: ExecWeights::Dense(weights),
            spec,
            batches,
            transforms: None,
            shard: None,
            pool: Arc::new(par::WorkerPool::new()),
        })
    }

    /// Wrap pre-built weights with an explicit transform spec:
    /// [`TransformMode::Unfolded`] runs the reference transformed model on
    /// original weights, [`TransformMode::Folded`] applies an online
    /// remainder over folded weights.
    pub fn from_weights_with_spec(
        weights: NativeWeights,
        transforms: TransformSpec,
        mode: TransformMode,
        tag: &str,
        batches: Vec<usize>,
    ) -> Result<Self> {
        transforms.validate(&weights.dims)?;
        if mode == TransformMode::Folded {
            anyhow::ensure!(
                transforms.online_only(),
                "folded-mode executor spec must contain online sites only, got [{}]",
                transforms.site_list()
            );
        }
        let mut exec = NativeExecutor::from_weights(weights, tag, batches)?;
        exec.transforms = Some((transforms, mode));
        Ok(exec)
    }

    /// Switch to packed-weight storage (`--packed-weights`): re-encode
    /// every linear weight matrix into the graph tag's MX format and run
    /// all subsequent prefill/decode GEMMs fused on the packed bytes —
    /// the f32 weight matrices are dropped. Requires a quantized tag (the
    /// fp graph has no MX format to pack into); a no-op if already packed.
    pub fn into_packed(mut self) -> Result<Self> {
        let cfg = self.spec.act.with_context(|| {
            format!("packed weights require a quantized graph tag, got {:?}", self.tag)
        })?;
        self.weights = match self.weights {
            ExecWeights::Dense(w) => ExecWeights::Packed(w.pack_weights(cfg)?),
            packed => packed,
        };
        Ok(self)
    }

    /// Serve with `workers` tensor-parallel shard workers (`--workers N`):
    /// attention sharded along heads, FFN along fixed `d_ff` bands, with
    /// fixed-order shard reductions so logits are bit-identical for any
    /// worker count. `workers == 1` exercises the same segmented kernels
    /// serially. Validates against the model dims (0 workers and
    /// `workers > n_heads` are refused).
    pub fn with_workers(self, workers: usize) -> Result<Self> {
        let plan = ShardPlan::new(workers, self.dims())?;
        self.with_shard_plan(plan)
    }

    /// Like [`NativeExecutor::with_workers`] with an explicit plan — used
    /// when a folded artifact's manifest pins `shard.ffn_block`.
    pub fn with_shard_plan(mut self, plan: ShardPlan) -> Result<Self> {
        plan.validate(self.dims())?;
        self.shard = Some(plan);
        Ok(self)
    }

    /// The active tensor-parallel plan, if any.
    pub fn shard_plan(&self) -> Option<ShardPlan> {
        self.shard
    }

    /// Whether weights are held in bit-packed MX form.
    pub fn packed_weights(&self) -> bool {
        matches!(self.weights, ExecWeights::Packed(_))
    }

    /// Resident bytes of the weight storage (the serve reports print this;
    /// ~7.5x smaller packed vs dense at B=32).
    pub fn resident_weight_bytes(&self) -> usize {
        match &self.weights {
            ExecWeights::Dense(w) => w.weight_bytes(),
            ExecWeights::Packed(w) => w.weight_bytes(),
        }
    }

    fn dims(&self) -> &NativeDims {
        match &self.weights {
            ExecWeights::Dense(w) => &w.dims,
            ExecWeights::Packed(w) => &w.dims,
        }
    }

    fn spec_run(&self) -> SpecRun<'_> {
        self.transforms.as_ref().map(|(s, m)| (s, *m))
    }
}

/// Sort/dedup an explicit compiled-batch list, enforcing the same `> 0`
/// discipline as the shared `decode_<tag>_b*` parser (a 0 bucket would
/// panic deep inside the engine's prefill sizing instead of erroring here).
fn normalize_batches(mut batches: Vec<usize>) -> Result<Vec<usize>> {
    anyhow::ensure!(!batches.is_empty(), "batch list must be non-empty");
    anyhow::ensure!(
        batches.iter().all(|b| *b > 0),
        "batch sizes must be positive: {batches:?}"
    );
    batches.sort_unstable();
    batches.dedup();
    Ok(batches)
}

impl StepExecutor for NativeExecutor {
    fn vocab(&self) -> usize {
        self.dims().vocab
    }
    fn n_layers(&self) -> usize {
        self.dims().n_layers
    }
    fn kv_seq(&self) -> usize {
        self.dims().kv_seq
    }
    fn kv_row(&self) -> usize {
        self.dims().d_model
    }
    fn prefill_len(&self) -> usize {
        self.dims().prefill_len
    }
    fn batch_sizes(&self) -> Vec<usize> {
        self.batches.clone()
    }

    fn prefill(&self, tokens: &[i32], lens: &[i32], batch: usize)
        -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        self.pool.install(|| match (&self.weights, &self.shard) {
            (ExecWeights::Dense(w), None) => {
                w.forward_prefill_spec(tokens, lens, batch, &self.spec, self.spec_run())
            }
            (ExecWeights::Packed(w), None) => {
                w.forward_prefill_spec(tokens, lens, batch, &self.spec, self.spec_run())
            }
            (ExecWeights::Dense(w), Some(plan)) => {
                w.forward_prefill_shard_spec(tokens, lens, batch, &self.spec, self.spec_run(), plan)
            }
            (ExecWeights::Packed(w), Some(plan)) => {
                w.forward_prefill_shard_spec(tokens, lens, batch, &self.spec, self.spec_run(), plan)
            }
        })
    }

    fn decode(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        self.pool.install(|| match (&self.weights, &self.shard) {
            (ExecWeights::Dense(w), None) => {
                w.forward_decode_spec(tokens, pos, kv, batch, &self.spec, self.spec_run())
            }
            (ExecWeights::Packed(w), None) => {
                w.forward_decode_spec(tokens, pos, kv, batch, &self.spec, self.spec_run())
            }
            (ExecWeights::Dense(w), Some(plan)) => w.forward_decode_shard_spec(
                tokens,
                pos,
                kv,
                batch,
                &self.spec,
                self.spec_run(),
                plan,
            ),
            (ExecWeights::Packed(w), Some(plan)) => w.forward_decode_shard_spec(
                tokens,
                pos,
                kv,
                batch,
                &self.spec,
                self.spec_run(),
                plan,
            ),
        })
    }

    fn decode_append(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        self.pool.install(|| match (&self.weights, &self.shard) {
            (ExecWeights::Dense(w), None) => {
                w.forward_decode_append_spec(tokens, pos, kv, batch, &self.spec, self.spec_run())
            }
            (ExecWeights::Packed(w), None) => {
                w.forward_decode_append_spec(tokens, pos, kv, batch, &self.spec, self.spec_run())
            }
            (ExecWeights::Dense(w), Some(plan)) => w.forward_decode_append_shard_spec(
                tokens,
                pos,
                kv,
                batch,
                &self.spec,
                self.spec_run(),
                plan,
            ),
            (ExecWeights::Packed(w), Some(plan)) => w.forward_decode_append_shard_spec(
                tokens,
                pos,
                kv,
                batch,
                &self.spec,
                self.spec_run(),
                plan,
            ),
        })
    }

    fn pool(&self) -> Option<Arc<par::WorkerPool>> {
        Some(Arc::clone(&self.pool))
    }
}

// ---------------------------------------------------------------------------

/// Deterministic mock executor: "logits" prefer token `(sum of context) %
/// vocab`; KV planes count processed tokens so tests can check plumbing.
pub struct MockExecutor {
    pub vocab: usize,
    pub n_layers: usize,
    pub kv_seq: usize,
    pub kv_row: usize,
    pub prefill_len: usize,
    pub batches: Vec<usize>,
}

impl Default for MockExecutor {
    fn default() -> Self {
        MockExecutor {
            vocab: 64,
            n_layers: 2,
            kv_seq: 32,
            kv_row: 4,
            prefill_len: 8,
            batches: vec![1, 2, 4],
        }
    }
}

impl StepExecutor for MockExecutor {
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn n_layers(&self) -> usize {
        self.n_layers
    }
    fn kv_seq(&self) -> usize {
        self.kv_seq
    }
    fn kv_row(&self) -> usize {
        self.kv_row
    }
    fn prefill_len(&self) -> usize {
        self.prefill_len
    }
    fn batch_sizes(&self) -> Vec<usize> {
        self.batches.clone()
    }

    fn prefill(&self, tokens: &[i32], lens: &[i32], batch: usize)
        -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let mut logits = vec![0.0f32; batch * self.vocab];
        let plane = self.kv_seq * self.kv_row;
        let mut kv = vec![vec![0.0f32; batch * plane]; self.n_layers * 2];
        for b in 0..batch {
            let l = lens[b] as usize;
            let s: i64 = tokens[b * self.prefill_len..b * self.prefill_len + l]
                .iter()
                .map(|t| *t as i64)
                .sum();
            logits[b * self.vocab + (s as usize % self.vocab)] = 1.0;
            for planebuf in kv.iter_mut() {
                // mark `l` processed positions
                for p in 0..l {
                    planebuf[b * plane + p * self.kv_row] = 1.0;
                }
            }
        }
        Ok((logits, kv))
    }

    fn decode(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let mut logits = vec![0.0f32; batch * self.vocab];
        let plane = self.kv_seq * self.kv_row;
        let mut out = kv.to_vec();
        for b in 0..batch.min(tokens.len()) {
            let next = (tokens[b] as usize + 1) % self.vocab;
            logits[b * self.vocab + next] = 1.0;
            for planebuf in out.iter_mut() {
                planebuf[b * plane + (pos[b] as usize) * self.kv_row] = 1.0;
            }
        }
        Ok((logits, out))
    }
}

// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub max_slots: usize,
    pub policy: SchedulerPolicy,
    /// Stop token (EOS); generation also stops at max_new_tokens.
    pub eos: i32,
    /// Admission-queue bound for [`Engine::try_submit`] backpressure
    /// (None = unbounded; [`Engine::submit`] always bypasses the bound).
    pub queue_depth: Option<usize>,
    /// Paged-KV storage configuration (format + tokens per page).
    pub kv: KvSpec,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_slots: 8,
            policy: SchedulerPolicy::PrefillPriority,
            eos: 3,
            queue_depth: None,
            kv: KvSpec::default(),
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub prefill_batches: u64,
    pub decode_steps: u64,
    pub decode_lanes: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub wall_s: f64,
}

impl EngineStats {
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.decode_tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

struct RunningSeq {
    req: GenRequest,
    prompt_len: usize,
    generated: Vec<i32>,
    /// Arrival-relative emission time of each generated token.
    token_s: Vec<f64>,
    ttft_s: Option<f64>,
}

/// Engine-owned staging reused across decode steps (the zero-allocation
/// steady state: cleared and refilled in place, never reallocated once
/// warm). Taken out of the engine with `mem::take` for the duration of a
/// step so its buffers can be borrowed while `&mut self` methods run.
#[derive(Default)]
struct StepScratch {
    /// Running lane ids, rebuilt each decode step.
    ids: Vec<RequestId>,
    /// Per-lane last generated token, padded to the compiled bucket.
    tokens: Vec<i32>,
    /// Per-lane decode position, padded to the compiled bucket.
    pos: Vec<i32>,
    /// KV gather staging — one `(batch, kv_seq, row)` plane per
    /// (layer, k/v), rebuilt in place by `KvCache::gather_batch_into`.
    gather: Vec<Vec<f32>>,
    /// Stream events staged during the lane walk, emitted after it (the
    /// sink needs `&mut self` while the walk borrows the running lanes).
    stream: Vec<StreamEvent>,
    /// Lanes that hit EOS / length / KV limits this step.
    finished: Vec<(RequestId, FinishReason)>,
}

/// The continuous-batching generation engine (admission → schedule/decode →
/// stream; see the module docs for the full state machine).
pub struct Engine<E: StepExecutor> {
    pub exec: E,
    pub cfg: EngineConfig,
    batcher: Batcher,
    kv: KvCache,
    running: Vec<RunningSeq>,
    /// Cancellations targeting running lanes, applied at the next step
    /// boundary (queued requests are cancelled immediately).
    cancels: HashSet<RequestId>,
    pub stats: EngineStats,
    results: Vec<GenResult>,
    events: Vec<SchedEvent>,
    sink: Option<TokenSink>,
    /// Largest compiled batch bucket (cached: `batch_sizes()` clones).
    max_bucket: usize,
    /// Reusable per-step staging buffers (see [`StepScratch`]).
    scratch: StepScratch,
}

impl<E: StepExecutor> Engine<E> {
    pub fn new(exec: E, cfg: EngineConfig) -> Self {
        let mut batcher = Batcher::new(exec.batch_sizes());
        if let Some(d) = cfg.queue_depth {
            batcher = batcher.with_queue_depth(d);
        }
        let kv = KvCache::with_spec(
            cfg.max_slots,
            exec.n_layers(),
            exec.kv_seq(),
            exec.kv_row(),
            cfg.kv,
        );
        let max_bucket = *exec.batch_sizes().last().expect("empty batch list");
        Engine {
            exec,
            cfg,
            batcher,
            kv,
            running: Vec::new(),
            cancels: HashSet::new(),
            stats: EngineStats::default(),
            results: Vec::new(),
            events: Vec::new(),
            sink: None,
            max_bucket,
            scratch: StepScratch::default(),
        }
    }

    /// Attach a per-token streaming callback; replaces any previous sink.
    pub fn set_sink(&mut self, sink: TokenSink) {
        self.sink = Some(sink);
    }

    /// Unbounded submit (closed-loop drivers that stage a whole workload).
    pub fn submit(&mut self, req: GenRequest) {
        self.batcher.push(req);
    }

    /// Submit with backpressure: when the bounded queue is full the
    /// request is refused and a `RejectedQueueFull` result is recorded —
    /// every submission still yields exactly one result.
    pub fn try_submit(&mut self, req: GenRequest) -> PushOutcome {
        let (id, prompt_len, arrived) = (req.id, req.prompt.len(), req.arrived);
        match self.batcher.try_push(req) {
            PushOutcome::Queued => PushOutcome::Queued,
            PushOutcome::Rejected => {
                self.drop_request(id, prompt_len, arrived, FinishReason::RejectedQueueFull);
                PushOutcome::Rejected
            }
        }
    }

    /// Cancel a request wherever it is: removed from the queue
    /// immediately, or evicted from its lane at the next step boundary.
    /// Returns false if the id is unknown (e.g. already finished).
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(req) = self.batcher.cancel(id) {
            self.drop_request(req.id, req.prompt.len(), req.arrived, FinishReason::Cancelled);
            true
        } else if self.running.iter().any(|r| r.req.id == id) {
            self.cancels.insert(id);
            true
        } else {
            false
        }
    }

    pub fn pending(&self) -> usize {
        self.batcher.pending() + self.running.len()
    }

    /// The scheduling event log so far (admit/evict/drop, in engine order).
    pub fn events(&self) -> &[SchedEvent] {
        &self.events
    }

    /// Bytes of KV page storage currently resident (the lazy page pool's
    /// high-water mark — grows with actual occupancy, not `max_slots`).
    pub fn kv_resident_bytes(&self) -> usize {
        self.kv.resident_bytes()
    }

    /// Cumulative KV pages mapped via prompt-prefix sharing instead of
    /// being written.
    pub fn kv_pages_shared(&self) -> u64 {
        self.kv.pages_shared()
    }

    /// What the pre-paging dense per-slot cache would hold resident.
    pub fn kv_dense_bytes(&self) -> usize {
        self.kv.dense_bytes()
    }

    /// Drain results finished since the last call (open-loop drivers poll
    /// this between steps; closed-loop drivers use `run_to_completion`).
    pub fn take_results(&mut self) -> Vec<GenResult> {
        std::mem::take(&mut self.results)
    }

    /// Run until all submitted requests complete; returns results (sorted
    /// by request id).
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        let t0 = Instant::now();
        while self.pending() > 0 {
            self.step()?;
        }
        self.stats.wall_s = t0.elapsed().as_secs_f64();
        let mut out = std::mem::take(&mut self.results);
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// One engine iteration: sweep deadlines/cancellations, refill freed
    /// slots (prefill), then one decode step over all running lanes.
    pub fn step(&mut self) -> Result<()> {
        self.sweep_queue();
        self.evict_running();
        let admit = plan_admit(
            self.cfg.policy,
            self.batcher.pending(),
            self.running.len(),
            self.kv.free_slots(),
            self.max_bucket,
        );
        if admit > 0 {
            let reqs = self.batcher.admit(admit.min(self.kv.free_slots()));
            self.prefill_batch(reqs)?;
        }
        if !self.running.is_empty() {
            self.decode_step()?;
        }
        Ok(())
    }

    /// Queue-side deadline sweep: expired requests never reach a slot.
    fn sweep_queue(&mut self) {
        for req in self.batcher.expire_deadlines() {
            self.drop_request(req.id, req.prompt.len(), req.arrived, FinishReason::TimedOut);
        }
    }

    /// Lane-side sweep: evict cancelled/expired running lanes, keeping
    /// their partial tokens; the freed slots are refilled this same step.
    fn evict_running(&mut self) {
        let mut evict: Vec<(usize, FinishReason)> = Vec::new();
        for (i, rs) in self.running.iter().enumerate() {
            if self.cancels.contains(&rs.req.id) {
                evict.push((i, FinishReason::Cancelled));
            } else if rs.req.expired() {
                evict.push((i, FinishReason::TimedOut));
            }
        }
        for (i, reason) in evict.into_iter().rev() {
            let rs = self.running.remove(i);
            self.cancels.remove(&rs.req.id);
            self.finish(rs, reason);
        }
    }

    fn prefill_batch(&mut self, reqs: Vec<GenRequest>) -> Result<()> {
        if reqs.is_empty() {
            return Ok(());
        }
        let lanes = reqs.len();
        let batch = self.batcher.bucket_for(lanes);
        let pl = self.exec.prefill_len();
        let mut tokens = vec![0i32; batch * pl];
        let mut lens = vec![1i32; batch];
        for (i, r) in reqs.iter().enumerate() {
            let l = r.prompt.len().min(pl);
            tokens[i * pl..i * pl + l].copy_from_slice(&r.prompt[..l]);
            lens[i] = l as i32;
        }
        let (logits, kv_planes) = self.exec.prefill(&tokens, &lens, batch)?;
        self.stats.prefill_batches += 1;
        self.stats.prefill_tokens += lens[..lanes].iter().map(|l| *l as u64).sum::<u64>();
        let vocab = self.exec.vocab();
        for (lane, req) in reqs.into_iter().enumerate() {
            let prompt_len = req.prompt.len().min(pl);
            let alloc = self.kv.alloc(req.id)?;
            self.events.push(SchedEvent::Admit {
                id: req.id,
                slot: alloc.slot,
                refill: alloc.refill,
            });
            // map this lane's prefill rows into pages (shared-prefix pages
            // are mapped by refcount bump instead of being rewritten)
            self.kv
                .write_prefill(req.id, &req.prompt[..prompt_len], &kv_planes, lane)?;
            let first = argmax(&logits[lane * vocab..(lane + 1) * vocab]);
            let t = req.arrived.elapsed().as_secs_f64();
            // Reserve the full generation budget up front so the per-step
            // `push` in `decode_step` never reallocates mid-stream.
            let cap = req.max_new_tokens.max(1);
            let mut generated = Vec::with_capacity(cap);
            generated.push(first);
            let mut token_s = Vec::with_capacity(cap);
            token_s.push(t);
            let rs = RunningSeq { req, prompt_len, generated, token_s, ttft_s: Some(t) };
            self.stats.decode_tokens += 1;
            self.emit(StreamEvent::Token { id: rs.req.id, index: 0, token: first, t_s: t });
            if first == self.cfg.eos {
                self.finish(rs, FinishReason::Eos);
            } else if rs.req.max_new_tokens <= 1 {
                self.finish(rs, FinishReason::Length);
            } else {
                self.running.push(rs);
            }
        }
        Ok(())
    }

    fn decode_step(&mut self) -> Result<()> {
        // The staging buffers live in `self.scratch` so a steady-state step
        // reuses them in place; take them out for the duration of the step
        // so chunk slices can be held across `&mut self` calls.
        let mut ss = std::mem::take(&mut self.scratch);
        let out = self.decode_step_inner(&mut ss);
        self.scratch = ss;
        out
    }

    fn decode_step_inner(&mut self, ss: &mut StepScratch) -> Result<()> {
        // decode all running lanes, chunked into per-step re-selected
        // compiled buckets
        ss.ids.clear();
        ss.ids.extend(self.running.iter().map(|r| r.req.id));
        ss.finished.clear();
        let pool = self.exec.pool();
        let vocab = self.exec.vocab();
        let kv_seq = self.exec.kv_seq();
        for chunk in ss.ids.chunks(self.max_bucket) {
            let batch = self.batcher.bucket_for(chunk.len());
            ss.tokens.clear();
            ss.tokens.resize(batch, 0);
            ss.pos.clear();
            ss.pos.resize(batch, 0);
            for (lane, id) in chunk.iter().enumerate() {
                let rs = self.running.iter().find(|r| r.req.id == *id).unwrap();
                ss.tokens[lane] = *rs.generated.last().unwrap();
                ss.pos[lane] = self.kv.pos_of(*id).unwrap() as i32;
            }
            // The gather fan-out is an engine-side parallel stage: run it on
            // the executor's persistent pool so no scoped threads spawn (and
            // the pool workers' scratch arenas stay warm).
            par::with_pool(pool.as_deref(), || {
                self.kv.gather_batch_into(chunk, batch, &mut ss.gather)
            })?;
            let (logits, new_rows) =
                self.exec.decode_append(&ss.tokens, &ss.pos, &ss.gather, batch)?;
            self.kv.append_step(chunk, batch, &new_rows)?;
            self.stats.decode_steps += 1;
            self.stats.decode_lanes += chunk.len() as u64;
            ss.stream.clear();
            for (lane, id) in chunk.iter().enumerate() {
                let rs = self.running.iter_mut().find(|r| r.req.id == *id).unwrap();
                let next = argmax(&logits[lane * vocab..(lane + 1) * vocab]);
                let t = rs.req.arrived.elapsed().as_secs_f64();
                rs.generated.push(next);
                rs.token_s.push(t);
                self.stats.decode_tokens += 1;
                ss.stream.push(StreamEvent::Token {
                    id: *id,
                    index: rs.generated.len() - 1,
                    token: next,
                    t_s: t,
                });
                if next == self.cfg.eos {
                    ss.finished.push((*id, FinishReason::Eos));
                } else if rs.generated.len() >= rs.req.max_new_tokens {
                    ss.finished.push((*id, FinishReason::Length));
                } else if rs.prompt_len + rs.generated.len() >= kv_seq {
                    ss.finished.push((*id, FinishReason::KvLimit));
                }
            }
            // The executor checked these out of the step arena; recycle them
            // now that argmax / append_step consumed them.
            scratch::give(logits);
            scratch::give_rows(new_rows);
            for ev in ss.stream.drain(..) {
                self.emit(ev);
            }
        }
        for (id, reason) in ss.finished.drain(..) {
            let idx = self.running.iter().position(|r| r.req.id == id).unwrap();
            let rs = self.running.remove(idx);
            self.finish(rs, reason);
        }
        Ok(())
    }

    /// Retire a lane: reclaim its KV slot, log the eviction, record the
    /// result, notify the stream.
    fn finish(&mut self, rs: RunningSeq, reason: FinishReason) {
        let slot = self.kv.free(rs.req.id).expect("finishing lane without a slot");
        self.events.push(SchedEvent::Evict { id: rs.req.id, slot, reason });
        self.emit(StreamEvent::Finished {
            id: rs.req.id,
            outcome: reason,
            n_tokens: rs.generated.len(),
        });
        self.results.push(GenResult {
            id: rs.req.id,
            prompt_len: rs.prompt_len,
            tokens: rs.generated,
            outcome: reason,
            token_s: rs.token_s,
            ttft_s: rs.ttft_s.unwrap_or(0.0),
            total_s: rs.req.arrived.elapsed().as_secs_f64(),
        });
    }

    /// Record a queue-level terminal outcome (rejected / cancelled /
    /// expired before reaching a slot): no tokens, no KV slot involved.
    fn drop_request(
        &mut self,
        id: RequestId,
        prompt_len: usize,
        arrived: Instant,
        reason: FinishReason,
    ) {
        self.events.push(SchedEvent::Drop { id, reason });
        self.emit(StreamEvent::Finished { id, outcome: reason, n_tokens: 0 });
        self.results.push(GenResult {
            id,
            prompt_len,
            tokens: Vec::new(),
            outcome: reason,
            token_s: Vec::new(),
            ttft_s: 0.0,
            total_s: arrived.elapsed().as_secs_f64(),
        });
    }

    fn emit(&mut self, ev: StreamEvent) {
        if let Some(sink) = self.sink.as_mut() {
            sink(&ev);
        }
    }
}

pub(crate) fn argmax(v: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, x) in v.iter().enumerate() {
        if *x > bv {
            bv = *x;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use std::cell::RefCell;
    use std::rc::Rc;
    use std::time::Duration;

    use super::*;

    fn engine() -> Engine<MockExecutor> {
        Engine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 4, eos: -1, ..Default::default() },
        )
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine();
        e.submit(GenRequest::new(1, vec![5, 6], 4));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 4);
        // mock: prefill emits sum%vocab=11, then +1 each step
        assert_eq!(out[0].tokens, vec![11, 12, 13, 14]);
        assert_eq!(out[0].outcome, FinishReason::Length);
        assert_eq!(out[0].token_s.len(), 4);
    }

    #[test]
    fn many_requests_all_complete_in_order() {
        let mut e = engine();
        for id in 0..10 {
            e.submit(GenRequest::new(id, vec![id as i32], 3));
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 10);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 3);
        }
        // slots never exceeded capacity: implied by successful alloc
        assert_eq!(e.stats.decode_tokens, 30);
        // churn visible in the event log: 10 admits, 10 evictions, and —
        // with 4 slots for 10 requests — at least one slot refill
        let admits =
            e.events().iter().filter(|ev| matches!(ev, SchedEvent::Admit { .. })).count();
        let refills = e
            .events()
            .iter()
            .filter(|ev| matches!(ev, SchedEvent::Admit { refill: true, .. }))
            .count();
        let evicts =
            e.events().iter().filter(|ev| matches!(ev, SchedEvent::Evict { .. })).count();
        assert_eq!(admits, 10);
        assert_eq!(evicts, 10);
        assert!(refills > 0, "expected slot reuse under churn");
    }

    #[test]
    fn eos_stops_generation() {
        let mut e = Engine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 2, eos: 12, ..Default::default() },
        );
        e.submit(GenRequest::new(1, vec![5, 6], 10)); // first token 11, next 12=eos
        let out = e.run_to_completion().unwrap();
        assert_eq!(out[0].tokens, vec![11, 12]);
        assert_eq!(out[0].outcome, FinishReason::Eos);
    }

    #[test]
    fn stats_accumulate() {
        let mut e = engine();
        for id in 0..3 {
            e.submit(GenRequest::new(id, vec![1, 2, 3], 2));
        }
        e.run_to_completion().unwrap();
        assert!(e.stats.prefill_batches >= 1);
        assert_eq!(e.stats.prefill_tokens, 9);
        assert_eq!(e.stats.decode_tokens, 6);
    }

    #[test]
    fn bounded_queue_backpressure() {
        let mut e = Engine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 2, eos: -1, queue_depth: Some(2), ..Default::default() },
        );
        for id in 0..5 {
            e.try_submit(GenRequest::new(id, vec![1], 2));
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 5, "every submission yields a result");
        let rejected: Vec<_> = out
            .iter()
            .filter(|r| r.outcome == FinishReason::RejectedQueueFull)
            .map(|r| r.id)
            .collect();
        assert_eq!(rejected, vec![2, 3, 4]);
        assert!(out
            .iter()
            .filter(|r| r.outcome.is_complete())
            .all(|r| r.tokens.len() == 2));
    }

    #[test]
    fn cancel_queued_and_running() {
        let mut e = Engine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 1, eos: -1, ..Default::default() },
        );
        e.submit(GenRequest::new(0, vec![1], 8));
        e.submit(GenRequest::new(1, vec![2], 8));
        e.step().unwrap(); // req 0 admitted (slot 0), req 1 still queued
        assert!(e.cancel(1), "cancel mid-queue");
        assert!(e.cancel(0), "cancel mid-decode");
        assert!(!e.cancel(42), "unknown id");
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].outcome, FinishReason::Cancelled);
        assert!(!out[0].tokens.is_empty(), "partial tokens kept on lane cancel");
        assert_eq!(out[1].outcome, FinishReason::Cancelled);
        assert!(out[1].tokens.is_empty());
    }

    #[test]
    fn deadline_eviction_mid_decode() {
        let mut e = engine();
        e.submit(GenRequest::new(0, vec![1], 1000).with_deadline(Duration::ZERO));
        e.submit(GenRequest::new(1, vec![2], 3));
        std::thread::sleep(Duration::from_millis(1));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].outcome, FinishReason::TimedOut);
        assert_eq!(out[1].outcome, FinishReason::Length);
        assert_eq!(out[1].tokens.len(), 3);
    }

    #[test]
    fn streaming_sink_sees_every_token() {
        let seen: Rc<RefCell<Vec<StreamEvent>>> = Rc::default();
        let seen2 = Rc::clone(&seen);
        let mut e = engine();
        e.set_sink(Box::new(move |ev| seen2.borrow_mut().push(ev.clone())));
        e.submit(GenRequest::new(7, vec![5, 6], 3));
        let out = e.run_to_completion().unwrap();
        let evs = seen.borrow();
        let tokens: Vec<i32> = evs
            .iter()
            .filter_map(|ev| match ev {
                StreamEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(tokens, out[0].tokens, "streamed tokens match the final result");
        assert!(matches!(
            evs.last().unwrap(),
            StreamEvent::Finished { id: 7, outcome: FinishReason::Length, n_tokens: 3 }
        ));
    }
}
