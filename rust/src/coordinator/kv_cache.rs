//! KV-cache slot manager with a fixed slot pool and a free-list.
//!
//! The decode graph's KV tensors have a fixed batch dimension (one lane per
//! slot — the Sec. 4.1 AOT deployment model, where graphs are compiled at
//! fixed batch sizes); this module owns the host-side KV state per
//! *sequence* and the slot accounting. Because PJRT literals round-trip
//! host memory on this testbed, the cache holds each sequence's K/V rows as
//! flat `f32` vectors (`n_layers * 2 * kv_seq * n_heads * head_dim`) that
//! the engine gathers into batch literals per step.
//!
//! Since the continuous-batching refactor the `capacity` slot buffers are
//! allocated once up front and *reused*: when a lane finishes, is
//! cancelled, or times out, its slot returns to the free-list and the next
//! admitted request takes it over at a step boundary (lowest free slot
//! first, so slot assignment is deterministic for a given event order).
//! Reused buffers are zeroed on [`KvCache::alloc`] — a refilled lane must
//! never see the previous occupant's rows (property-tested).
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs`):
//! - a slot is never double-allocated;
//! - free() returns capacity exactly once;
//! - the set of live sequence ids equals the set of allocated slots;
//! - a reused slot starts fully zeroed (no stale-row leak).

use std::collections::HashMap;

use super::request::RequestId;

/// Per-sequence KV state (host side).
#[derive(Clone)]
pub struct SeqKv {
    /// `[layer][k_or_v]` flat `(kv_seq, n_heads, head_dim)` row-major.
    pub data: Vec<Vec<f32>>,
    /// Number of valid positions (= tokens processed so far).
    pub pos: usize,
}

/// Result of a slot allocation: which slot, and whether it is a *refill*
/// (the slot served a previous occupant since engine start — the
/// continuous-batching churn signal the scheduling event log records).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotAlloc {
    pub slot: usize,
    pub refill: bool,
}

pub struct KvCache {
    pub capacity: usize,
    pub n_layers: usize,
    pub kv_seq: usize,
    pub kv_row: usize, // n_heads * head_dim
    /// The fixed slot pool; `slots[i]` is reused across occupants.
    slots: Vec<SeqKv>,
    /// Per-slot occupant (None = free).
    owner: Vec<Option<RequestId>>,
    /// id -> slot for the live set.
    index: HashMap<RequestId, usize>,
    /// Free slot indices, sorted descending so `pop()` yields the lowest.
    free_list: Vec<usize>,
    /// Slot has had at least one prior occupant (refill detection).
    used_before: Vec<bool>,
}

impl KvCache {
    pub fn new(capacity: usize, n_layers: usize, kv_seq: usize, kv_row: usize) -> Self {
        let plane = kv_seq * kv_row;
        let slots = (0..capacity)
            .map(|_| SeqKv { data: vec![vec![0.0f32; plane]; n_layers * 2], pos: 0 })
            .collect();
        KvCache {
            capacity,
            n_layers,
            kv_seq,
            kv_row,
            slots,
            owner: vec![None; capacity],
            index: HashMap::new(),
            free_list: (0..capacity).rev().collect(),
            used_before: vec![false; capacity],
        }
    }

    pub fn free_slots(&self) -> usize {
        self.free_list.len()
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.index.contains_key(&id)
    }

    /// The slot currently holding sequence `id`.
    pub fn slot_of(&self, id: RequestId) -> Option<usize> {
        self.index.get(&id).copied()
    }

    /// Allocate the lowest free slot for `id`, zeroing its buffers. Err if
    /// full or duplicate. Returns the slot index and whether it is a reuse.
    pub fn alloc(&mut self, id: RequestId) -> anyhow::Result<SlotAlloc> {
        anyhow::ensure!(!self.free_list.is_empty(), "kv cache full");
        anyhow::ensure!(!self.index.contains_key(&id), "slot {id} double-alloc");
        let slot = self.free_list.pop().unwrap();
        let refill = self.used_before[slot];
        let seq = &mut self.slots[slot];
        for plane in seq.data.iter_mut() {
            plane.fill(0.0);
        }
        seq.pos = 0;
        self.owner[slot] = Some(id);
        self.index.insert(id, slot);
        Ok(SlotAlloc { slot, refill })
    }

    /// Release `id`'s slot back to the free-list; returns the slot index if
    /// `id` was live.
    pub fn free(&mut self, id: RequestId) -> Option<usize> {
        let slot = self.index.remove(&id)?;
        self.owner[slot] = None;
        self.used_before[slot] = true;
        // keep the free-list sorted descending (lowest slot pops first)
        let at = self.free_list.partition_point(|s| *s > slot);
        self.free_list.insert(at, slot);
        Some(slot)
    }

    pub fn get(&self, id: RequestId) -> Option<&SeqKv> {
        self.index.get(&id).map(|s| &self.slots[*s])
    }

    pub fn get_mut(&mut self, id: RequestId) -> Option<&mut SeqKv> {
        let slot = *self.index.get(&id)?;
        Some(&mut self.slots[slot])
    }

    /// Live sequence ids, ascending.
    pub fn ids(&self) -> Vec<RequestId> {
        let mut v: Vec<_> = self.index.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Live sequence ids ordered by slot index — the engine's canonical
    /// lane order, stable under churn (a refilled lane re-enters at its
    /// slot's position).
    pub fn ids_by_slot(&self) -> Vec<RequestId> {
        self.owner.iter().filter_map(|o| *o).collect()
    }

    /// Gather lanes `ids` into one batch KV buffer per (layer, k/v), shaped
    /// `(batch, kv_seq, row)` flat — the decode graph's input layout. Lanes
    /// beyond `ids.len()` (padding) are zeroed.
    ///
    /// Each (layer, k/v) buffer is an independent write target, so at
    /// serving dims the plane copies fan out over the scoped thread pool.
    pub fn gather_batch(&self, ids: &[RequestId], batch: usize) -> Vec<Vec<f32>> {
        let plane = self.kv_seq * self.kv_row;
        let mut out = vec![vec![0.0f32; batch * plane]; self.n_layers * 2];
        if batch * plane * out.len() < crate::util::par::PAR_MIN_LEN {
            for (lane, id) in ids.iter().enumerate() {
                let seq = &self.slots[self.index[id]];
                for (li, buf) in out.iter_mut().enumerate() {
                    buf[lane * plane..(lane + 1) * plane].copy_from_slice(&seq.data[li]);
                }
            }
        } else {
            crate::util::par::for_each_chunk(&mut out, 1, |li, bufs| {
                let buf = &mut bufs[0];
                for (lane, id) in ids.iter().enumerate() {
                    let seq = &self.slots[self.index[id]];
                    buf[lane * plane..(lane + 1) * plane].copy_from_slice(&seq.data[li]);
                }
            });
        }
        out
    }

    /// Scatter updated batch KV back into the per-sequence state and bump
    /// positions.
    ///
    /// One `iter_mut` pass over the slot pool yields simultaneous `&mut`
    /// borrows of the distinct live sequences, so at serving dims each
    /// (lane, sequence) copy-back runs on its own pool worker.
    pub fn scatter_batch(&mut self, ids: &[RequestId], batch: usize, planes: &[Vec<f32>]) {
        let plane = self.kv_seq * self.kv_row;
        assert_eq!(planes.len(), self.n_layers * 2);
        if batch * plane * planes.len() >= crate::util::par::PAR_MIN_LEN {
            let owner = &self.owner;
            let mut pairs: Vec<(usize, &mut SeqKv)> = self
                .slots
                .iter_mut()
                .enumerate()
                .filter_map(|(si, seq)| {
                    owner[si]
                        .and_then(|id| ids.iter().position(|x| *x == id))
                        .map(|lane| (lane, seq))
                })
                .collect();
            // One pair per distinct live id: only equivalent to the serial
            // loop when every id resolved and none repeat — otherwise fall
            // through to the serial path, which preserves the original
            // doubled-scatter / missing-slot-panic semantics exactly.
            if pairs.len() == ids.len() {
                crate::util::par::for_each_chunk(&mut pairs, 1, |_, pair| {
                    let (lane, seq) = &mut pair[0];
                    debug_assert!(*lane < batch);
                    for (li, buf) in planes.iter().enumerate() {
                        seq.data[li].copy_from_slice(&buf[*lane * plane..(*lane + 1) * plane]);
                    }
                    seq.pos += 1;
                });
                return;
            }
        }
        for (lane, id) in ids.iter().enumerate() {
            debug_assert!(lane < batch);
            let slot = *self.index.get(id).expect("scatter into missing slot");
            let seq = &mut self.slots[slot];
            for (li, buf) in planes.iter().enumerate() {
                seq.data[li].copy_from_slice(&buf[lane * plane..(lane + 1) * plane]);
            }
            seq.pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> KvCache {
        KvCache::new(4, 2, 8, 4)
    }

    #[test]
    fn alloc_free_cycle() {
        let mut c = cache();
        assert_eq!(c.free_slots(), 4);
        c.alloc(1).unwrap();
        c.alloc(2).unwrap();
        assert_eq!(c.free_slots(), 2);
        assert!(c.free(1).is_some());
        assert!(c.free(1).is_none());
        assert_eq!(c.free_slots(), 3);
    }

    #[test]
    fn double_alloc_rejected() {
        let mut c = cache();
        c.alloc(7).unwrap();
        assert!(c.alloc(7).is_err());
    }

    #[test]
    fn full_rejected() {
        let mut c = cache();
        for id in 0..4 {
            c.alloc(id).unwrap();
        }
        assert!(c.alloc(99).is_err());
    }

    #[test]
    fn lowest_slot_first_and_refill_flag() {
        let mut c = cache();
        assert_eq!(c.alloc(10).unwrap(), SlotAlloc { slot: 0, refill: false });
        assert_eq!(c.alloc(11).unwrap(), SlotAlloc { slot: 1, refill: false });
        assert_eq!(c.alloc(12).unwrap(), SlotAlloc { slot: 2, refill: false });
        // free the middle slot; the next alloc reuses it and reports refill
        assert_eq!(c.free(11), Some(1));
        assert_eq!(c.alloc(13).unwrap(), SlotAlloc { slot: 1, refill: true });
        assert_eq!(c.slot_of(13), Some(1));
        assert_eq!(c.ids_by_slot(), vec![10, 13, 12]);
    }

    #[test]
    fn reused_slot_is_zeroed() {
        let mut c = cache();
        c.alloc(1).unwrap();
        let seq = c.get_mut(1).unwrap();
        for plane in seq.data.iter_mut() {
            plane.fill(7.5);
        }
        seq.pos = 5;
        c.free(1);
        let a = c.alloc(2).unwrap();
        assert_eq!(a, SlotAlloc { slot: 0, refill: true });
        let seq = c.get(2).unwrap();
        assert_eq!(seq.pos, 0);
        assert!(seq.data.iter().all(|p| p.iter().all(|x| *x == 0.0)), "stale rows leaked");
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut c = cache();
        c.alloc(1).unwrap();
        c.alloc(2).unwrap();
        // write recognizable data
        c.get_mut(1).unwrap().data[0][0] = 11.0;
        c.get_mut(2).unwrap().data[0][0] = 22.0;
        let g = c.gather_batch(&[1, 2], 4);
        assert_eq!(g[0][0], 11.0);
        assert_eq!(g[0][8 * 4], 22.0); // lane 1 offset = plane
        // mutate and scatter back
        let mut g2 = g.clone();
        g2[0][0] = 110.0;
        c.scatter_batch(&[1, 2], 4, &g2);
        assert_eq!(c.get(1).unwrap().data[0][0], 110.0);
        assert_eq!(c.get(1).unwrap().pos, 1);
        assert_eq!(c.get(2).unwrap().pos, 1);
    }
}
