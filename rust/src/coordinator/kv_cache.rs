//! KV-cache slot manager.
//!
//! The decode graph's KV tensors have a fixed batch dimension (one lane per
//! slot — the Sec. 4.1 AOT deployment model, where graphs are compiled at
//! fixed batch sizes); this module owns the host-side KV state per
//! *sequence* and the
//! slot accounting. Because PJRT literals round-trip host memory on this
//! testbed, the cache holds each sequence's K/V rows as flat `f32` vectors
//! (`n_layers * 2 * kv_seq * n_heads * head_dim`) that the engine gathers
//! into batch literals per step.
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs`):
//! - a slot is never double-allocated;
//! - free() returns capacity exactly once;
//! - the set of live sequence ids equals the set of allocated slots.

use std::collections::HashMap;

use super::request::RequestId;

/// Per-sequence KV state (host side).
#[derive(Clone)]
pub struct SeqKv {
    /// `[layer][k_or_v]` flat `(kv_seq, n_heads, head_dim)` row-major.
    pub data: Vec<Vec<f32>>,
    /// Number of valid positions (= tokens processed so far).
    pub pos: usize,
}

pub struct KvCache {
    pub capacity: usize,
    pub n_layers: usize,
    pub kv_seq: usize,
    pub kv_row: usize, // n_heads * head_dim
    live: HashMap<RequestId, SeqKv>,
}

impl KvCache {
    pub fn new(capacity: usize, n_layers: usize, kv_seq: usize, kv_row: usize) -> Self {
        KvCache { capacity, n_layers, kv_seq, kv_row, live: HashMap::new() }
    }

    pub fn free_slots(&self) -> usize {
        self.capacity - self.live.len()
    }

    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.live.contains_key(&id)
    }

    /// Allocate a zeroed sequence slot. Err if full or duplicate.
    pub fn alloc(&mut self, id: RequestId) -> anyhow::Result<()> {
        anyhow::ensure!(self.live.len() < self.capacity, "kv cache full");
        anyhow::ensure!(!self.live.contains_key(&id), "slot {id} double-alloc");
        let plane = self.kv_seq * self.kv_row;
        let data = vec![vec![0.0f32; plane]; self.n_layers * 2];
        self.live.insert(id, SeqKv { data, pos: 0 });
        Ok(())
    }

    pub fn free(&mut self, id: RequestId) -> bool {
        self.live.remove(&id).is_some()
    }

    pub fn get(&self, id: RequestId) -> Option<&SeqKv> {
        self.live.get(&id)
    }

    pub fn get_mut(&mut self, id: RequestId) -> Option<&mut SeqKv> {
        self.live.get_mut(&id)
    }

    pub fn ids(&self) -> Vec<RequestId> {
        let mut v: Vec<_> = self.live.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Gather lanes `ids` into one batch KV buffer per (layer, k/v), shaped
    /// `(batch, kv_seq, row)` flat — the decode graph's input layout. Lanes
    /// beyond `ids.len()` (padding) are zeroed.
    ///
    /// Each (layer, k/v) buffer is an independent write target, so at
    /// serving dims the plane copies fan out over the scoped thread pool.
    pub fn gather_batch(&self, ids: &[RequestId], batch: usize) -> Vec<Vec<f32>> {
        let plane = self.kv_seq * self.kv_row;
        let mut out = vec![vec![0.0f32; batch * plane]; self.n_layers * 2];
        if batch * plane * out.len() < crate::util::par::PAR_MIN_LEN {
            for (lane, id) in ids.iter().enumerate() {
                let seq = &self.live[id];
                for (li, buf) in out.iter_mut().enumerate() {
                    buf[lane * plane..(lane + 1) * plane].copy_from_slice(&seq.data[li]);
                }
            }
        } else {
            crate::util::par::for_each_chunk(&mut out, 1, |li, bufs| {
                let buf = &mut bufs[0];
                for (lane, id) in ids.iter().enumerate() {
                    let seq = &self.live[id];
                    buf[lane * plane..(lane + 1) * plane].copy_from_slice(&seq.data[li]);
                }
            });
        }
        out
    }

    /// Scatter updated batch KV back into the per-sequence state and bump
    /// positions.
    ///
    /// One `iter_mut` pass over the slot map yields simultaneous `&mut`
    /// borrows of the distinct live sequences, so at serving dims each
    /// (lane, sequence) copy-back runs on its own pool worker.
    pub fn scatter_batch(&mut self, ids: &[RequestId], batch: usize, planes: &[Vec<f32>]) {
        let plane = self.kv_seq * self.kv_row;
        assert_eq!(planes.len(), self.n_layers * 2);
        if batch * plane * planes.len() >= crate::util::par::PAR_MIN_LEN {
            let mut pairs: Vec<(usize, &mut SeqKv)> = self
                .live
                .iter_mut()
                .filter_map(|(id, seq)| ids.iter().position(|x| x == id).map(|lane| (lane, seq)))
                .collect();
            // One pair per distinct live id: only equivalent to the serial
            // loop when every id resolved and none repeat — otherwise fall
            // through to the serial path, which preserves the original
            // doubled-scatter / missing-slot-panic semantics exactly.
            if pairs.len() == ids.len() {
                crate::util::par::for_each_chunk(&mut pairs, 1, |_, pair| {
                    let (lane, seq) = &mut pair[0];
                    debug_assert!(*lane < batch);
                    for (li, buf) in planes.iter().enumerate() {
                        seq.data[li].copy_from_slice(&buf[*lane * plane..(*lane + 1) * plane]);
                    }
                    seq.pos += 1;
                });
                return;
            }
        }
        for (lane, id) in ids.iter().enumerate() {
            debug_assert!(lane < batch);
            let seq = self.live.get_mut(id).expect("scatter into missing slot");
            for (li, buf) in planes.iter().enumerate() {
                seq.data[li].copy_from_slice(&buf[lane * plane..(lane + 1) * plane]);
            }
            seq.pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> KvCache {
        KvCache::new(4, 2, 8, 4)
    }

    #[test]
    fn alloc_free_cycle() {
        let mut c = cache();
        assert_eq!(c.free_slots(), 4);
        c.alloc(1).unwrap();
        c.alloc(2).unwrap();
        assert_eq!(c.free_slots(), 2);
        assert!(c.free(1));
        assert!(!c.free(1));
        assert_eq!(c.free_slots(), 3);
    }

    #[test]
    fn double_alloc_rejected() {
        let mut c = cache();
        c.alloc(7).unwrap();
        assert!(c.alloc(7).is_err());
    }

    #[test]
    fn full_rejected() {
        let mut c = cache();
        for id in 0..4 {
            c.alloc(id).unwrap();
        }
        assert!(c.alloc(99).is_err());
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut c = cache();
        c.alloc(1).unwrap();
        c.alloc(2).unwrap();
        // write recognizable data
        c.get_mut(1).unwrap().data[0][0] = 11.0;
        c.get_mut(2).unwrap().data[0][0] = 22.0;
        let g = c.gather_batch(&[1, 2], 4);
        assert_eq!(g[0][0], 11.0);
        assert_eq!(g[0][8 * 4], 22.0); // lane 1 offset = plane
        // mutate and scatter back
        let mut g2 = g.clone();
        g2[0][0] = 110.0;
        c.scatter_batch(&[1, 2], 4, &g2);
        assert_eq!(c.get(1).unwrap().data[0][0], 110.0);
        assert_eq!(c.get(1).unwrap().pos, 1);
        assert_eq!(c.get(2).unwrap().pos, 1);
    }
}
