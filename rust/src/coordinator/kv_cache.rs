//! Paged KV cache: block-table paging over a shared page pool, with
//! refcounted copy-on-write prefix sharing and optional MX quantization of
//! cached K/V (quantize-on-write, LUT decode on gather).
//!
//! The decode graph's KV tensors have a fixed batch dimension (one lane per
//! slot — the Sec. 4.1 AOT deployment model), so the *slot* accounting from
//! the continuous-batching refactor survives unchanged: `capacity` lanes, a
//! descending free-list (lowest slot pops first, deterministic for a given
//! event order), and a `refill` bit per re-used slot. What changed is the
//! storage behind a slot: instead of one dense `f32` plane per (layer, k/v)
//! per slot, each live sequence owns a **block table** — a list of
//! fixed-size pages (`KvSpec::block` tokens each, covering all
//! `n_layers * 2` planes) allocated from one shared [`PagePool`].
//!
//! Page lifecycle:
//! - `alloc(id)` claims a slot but maps no pages (a fresh sequence is an
//!   empty table).
//! - `write_prefill` maps `ceil(prompt_len / block)` pages and writes the
//!   prompt's K/V rows. Each page's span of the prompt is keyed by an
//!   FNV-1a hash of `prompt[..end]`; on a registry hit (verified by full
//!   token comparison, so hash collisions cannot alias) the existing page
//!   is mapped with `refcount + 1` instead of copied — prefix sharing.
//! - `append_step` writes one decoded row per live lane. Writing into a
//!   page with `refcount > 1` first clones it (copy-on-write), so sharers
//!   diverge only at their first divergent write.
//! - `free(id)` unmaps the table; pages drop to the free-list when their
//!   refcount reaches zero (and their share-registry entry is retired).
//!
//! Validity is tracked by `pos`: `gather_batch` materializes exactly rows
//! `[0, pos)` per lane and zero-fills the rest, so recycled pages can never
//! leak a previous occupant's rows into a decode step (property-tested).
//!
//! With `KvFormat::Mxfp8`/`Mxfp4`, rows are stored as MX bytes (one E8M0
//! scale byte per `mx::page::kv_block(kv_row)` elements + 8- or 4-bit
//! element codes) and decoded through the 256-entry LUTs on gather. The
//! write sits *after* attention consumed the fresh row, and after the
//! per-head T2 transform conditioned the V stream — so the cache stores
//! transformed, quantization-friendly rows, and the fp32 path stays
//! bit-identical to the dense reference (`LockstepEngine`).
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs`):
//! - a slot is never double-allocated; free() returns capacity exactly once;
//! - no page leaks or double-maps under join/leave/cancel churn: the sum of
//!   live table references equals the sum of refcounts of non-free pages;
//! - COW pages diverge only on the first write into a shared page;
//! - quantized gather round-trips bit-exactly against the `mx` reference
//!   codecs at page boundaries and ragged final pages.

use std::collections::HashMap;

use super::request::RequestId;
use crate::mx::page;
use crate::mx::MxConfig;

/// KV storage element format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvFormat {
    /// Dense f32 rows (bit-identical to the pre-paging cache).
    F32,
    /// MXFP8 (E4M3 + E8M0 block scale): ~4x smaller, near-lossless.
    Mxfp8,
    /// MXFP4 (E2M1 + E8M0 block scale): ~8x smaller.
    Mxfp4,
}

/// Paged-KV configuration: storage format + tokens per page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvSpec {
    pub format: KvFormat,
    /// Tokens per page (the paging block size; 16 is the vLLM-ish default).
    pub block: usize,
}

impl Default for KvSpec {
    fn default() -> Self {
        KvSpec { format: KvFormat::F32, block: 16 }
    }
}

impl KvSpec {
    /// CLI mapping for `--kv-bits {32,8,4}`.
    pub fn from_bits(bits: usize) -> anyhow::Result<KvSpec> {
        let format = match bits {
            32 => KvFormat::F32,
            8 => KvFormat::Mxfp8,
            4 => KvFormat::Mxfp4,
            other => anyhow::bail!("--kv-bits must be 32, 8 or 4 (got {other})"),
        };
        Ok(KvSpec { format, ..KvSpec::default() })
    }

    pub fn label(&self) -> &'static str {
        match self.format {
            KvFormat::F32 => "f32",
            KvFormat::Mxfp8 => "mxfp8",
            KvFormat::Mxfp4 => "mxfp4",
        }
    }

    /// The MX config used for page storage at row length `kv_row`
    /// (None for f32). Block size adapts to the row so any `kv_row`
    /// quantizes with row-aligned blocks.
    pub fn mx_config(&self, kv_row: usize) -> Option<MxConfig> {
        let name = match self.format {
            KvFormat::F32 => return None,
            KvFormat::Mxfp8 => "mxfp8",
            KvFormat::Mxfp4 => "mxfp4",
        };
        let mut cfg = MxConfig::from_name(name, None).expect("static mx name");
        cfg.block_size = page::kv_block(kv_row);
        Some(cfg)
    }
}

/// Result of a slot allocation: which slot, and whether it is a *refill*
/// (the slot served a previous occupant since engine start — the
/// continuous-batching churn signal the scheduling event log records).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotAlloc {
    pub slot: usize,
    pub refill: bool,
}

/// Prefix-share registry entry: the page holding rows for `toks` (a whole
/// prompt prefix ending at a page boundary or a ragged prompt tail). The
/// full token vector is kept so a hash hit is verified by comparison —
/// a collision degrades to a missed share, never to aliased KV.
struct ShareEntry {
    page: usize,
    toks: Vec<i32>,
}

/// Per-sequence state: slot, valid length, and the block table.
struct SeqState {
    slot: usize,
    pos: usize,
    table: Vec<usize>,
}

/// The shared page arena. A page spans `n_planes * block` rows; arenas grow
/// lazily (resident bytes = allocated pages, not `capacity * kv_seq`) and
/// never shrink, so `resident_bytes` reports the high-water footprint.
struct PagePool {
    format: KvFormat,
    cfg: Option<MxConfig>,
    n_planes: usize,
    block: usize,
    row: usize,
    row_scales: usize,
    row_codes: usize,
    data: Vec<f32>,
    scales: Vec<u8>,
    codes: Vec<u8>,
    refcount: Vec<u32>,
    share_key: Vec<Option<u64>>,
    free: Vec<usize>,
}

impl PagePool {
    fn new(spec: KvSpec, n_planes: usize, row: usize) -> PagePool {
        let cfg = spec.mx_config(row);
        let (row_scales, row_codes) = match &cfg {
            Some(c) => (page::scale_bytes(c, row), page::code_bytes(c, row)),
            None => (0, 0),
        };
        PagePool {
            format: spec.format,
            cfg,
            n_planes,
            block: spec.block,
            row,
            row_scales,
            row_codes,
            data: Vec::new(),
            scales: Vec::new(),
            codes: Vec::new(),
            refcount: Vec::new(),
            share_key: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Rows per page across all planes.
    fn rows_per_page(&self) -> usize {
        self.n_planes * self.block
    }

    /// Storage bytes per page.
    fn page_bytes(&self) -> usize {
        match self.format {
            KvFormat::F32 => self.rows_per_page() * self.row * 4,
            _ => self.rows_per_page() * (self.row_scales + self.row_codes),
        }
    }

    fn n_pages(&self) -> usize {
        self.refcount.len()
    }

    fn alloc_page(&mut self) -> usize {
        if let Some(p) = self.free.pop() {
            debug_assert!(self.share_key[p].is_none());
            self.refcount[p] = 1;
            return p;
        }
        let p = self.refcount.len();
        match self.format {
            KvFormat::F32 => {
                let n = self.rows_per_page() * self.row;
                self.data.resize(self.data.len() + n, 0.0);
            }
            _ => {
                self.scales.resize(self.scales.len() + self.rows_per_page() * self.row_scales, 0);
                self.codes.resize(self.codes.len() + self.rows_per_page() * self.row_codes, 0);
            }
        }
        self.refcount.push(1);
        self.share_key.push(None);
        p
    }

    #[inline]
    fn row_index(&self, p: usize, li: usize, r: usize) -> usize {
        (p * self.n_planes + li) * self.block + r
    }

    /// Quantize-on-write of one row into page `p`, plane `li`, page row `r`.
    fn write_row(&mut self, p: usize, li: usize, r: usize, src: &[f32]) {
        debug_assert_eq!(src.len(), self.row);
        debug_assert!(r < self.block);
        let ri = self.row_index(p, li, r);
        match &self.cfg {
            None => {
                let at = ri * self.row;
                self.data[at..at + self.row].copy_from_slice(src);
            }
            Some(cfg) => {
                let sa = ri * self.row_scales;
                let ca = ri * self.row_codes;
                page::encode_run(
                    src,
                    cfg,
                    &mut self.scales[sa..sa + self.row_scales],
                    &mut self.codes[ca..ca + self.row_codes],
                );
            }
        }
    }

    /// Decode rows `[0, rows)` of plane `li` of page `p` into `dst`.
    fn read_rows(&self, p: usize, li: usize, rows: usize, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), rows * self.row);
        debug_assert!(rows <= self.block);
        let ri = self.row_index(p, li, 0);
        match &self.cfg {
            None => {
                let at = ri * self.row;
                dst.copy_from_slice(&self.data[at..at + rows * self.row]);
            }
            Some(cfg) => {
                let sa = ri * self.row_scales;
                let ca = ri * self.row_codes;
                page::decode_run(
                    cfg,
                    &self.scales[sa..sa + rows * self.row_scales],
                    &self.codes[ca..ca + rows * self.row_codes],
                    dst,
                );
            }
        }
    }

    /// Clone page contents `src -> dst` (the COW copy). Byte-level, so a
    /// quantized clone is exact — no decode/re-encode drift.
    fn copy_page(&mut self, dst: usize, src: usize) {
        let n = self.rows_per_page();
        match self.format {
            KvFormat::F32 => {
                let len = n * self.row;
                self.data.copy_within(src * len..(src + 1) * len, dst * len);
            }
            _ => {
                let len = n * self.row_scales;
                self.scales.copy_within(src * len..(src + 1) * len, dst * len);
                let len = n * self.row_codes;
                self.codes.copy_within(src * len..(src + 1) * len, dst * len);
            }
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv_step(mut h: u64, t: i32) -> u64 {
    for b in t.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

pub struct KvCache {
    pub capacity: usize,
    pub n_layers: usize,
    pub kv_seq: usize,
    pub kv_row: usize, // n_heads * head_dim
    spec: KvSpec,
    pool: PagePool,
    seqs: HashMap<RequestId, SeqState>,
    /// Per-slot occupant (None = free).
    owner: Vec<Option<RequestId>>,
    /// Free slot indices, sorted descending so `pop()` yields the lowest.
    free_list: Vec<usize>,
    /// Slot has had at least one prior occupant (refill detection).
    used_before: Vec<bool>,
    /// Prefix-share registry: FNV(prompt[..end]) -> page.
    share: HashMap<u64, ShareEntry>,
    /// Cumulative count of pages mapped via the registry instead of written.
    shared_hits: u64,
}

impl KvCache {
    pub fn new(capacity: usize, n_layers: usize, kv_seq: usize, kv_row: usize) -> Self {
        Self::with_spec(capacity, n_layers, kv_seq, kv_row, KvSpec::default())
    }

    pub fn with_spec(
        capacity: usize,
        n_layers: usize,
        kv_seq: usize,
        kv_row: usize,
        spec: KvSpec,
    ) -> Self {
        assert!(spec.block > 0, "kv page size must be positive");
        if spec.format == KvFormat::Mxfp4 {
            assert!(kv_row % 2 == 0, "mxfp4 KV needs an even row length (got {kv_row})");
        }
        KvCache {
            capacity,
            n_layers,
            kv_seq,
            kv_row,
            spec,
            pool: PagePool::new(spec, n_layers * 2, kv_row),
            seqs: HashMap::new(),
            owner: vec![None; capacity],
            free_list: (0..capacity).rev().collect(),
            used_before: vec![false; capacity],
            share: HashMap::new(),
            shared_hits: 0,
        }
    }

    pub fn spec(&self) -> KvSpec {
        self.spec
    }

    pub fn free_slots(&self) -> usize {
        self.free_list.len()
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.seqs.contains_key(&id)
    }

    /// The slot currently holding sequence `id`.
    pub fn slot_of(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.slot)
    }

    /// Valid KV length (tokens processed so far) of sequence `id`.
    pub fn pos_of(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.pos)
    }

    /// Allocate the lowest free slot for `id` with an empty block table.
    /// Err if full or duplicate. Returns the slot index and whether it is a
    /// reuse.
    pub fn alloc(&mut self, id: RequestId) -> anyhow::Result<SlotAlloc> {
        anyhow::ensure!(!self.free_list.is_empty(), "kv cache full");
        anyhow::ensure!(!self.seqs.contains_key(&id), "slot {id} double-alloc");
        let slot = self.free_list.pop().unwrap();
        let refill = self.used_before[slot];
        self.owner[slot] = Some(id);
        self.seqs.insert(id, SeqState { slot, pos: 0, table: Vec::new() });
        Ok(SlotAlloc { slot, refill })
    }

    /// Release `id`'s slot and unmap its pages; returns the slot index if
    /// `id` was live.
    pub fn free(&mut self, id: RequestId) -> Option<usize> {
        let seq = self.seqs.remove(&id)?;
        for p in &seq.table {
            self.release_page(*p);
        }
        let slot = seq.slot;
        self.owner[slot] = None;
        self.used_before[slot] = true;
        // keep the free-list sorted descending (lowest slot pops first)
        let at = self.free_list.partition_point(|s| *s > slot);
        self.free_list.insert(at, slot);
        Some(slot)
    }

    fn release_page(&mut self, p: usize) {
        debug_assert!(self.pool.refcount[p] > 0, "double-release of page {p}");
        self.pool.refcount[p] -= 1;
        if self.pool.refcount[p] == 0 {
            if let Some(k) = self.pool.share_key[p].take() {
                self.share.remove(&k);
            }
            self.pool.free.push(p);
        }
    }

    /// Live sequence ids, ascending.
    pub fn ids(&self) -> Vec<RequestId> {
        let mut v: Vec<_> = self.seqs.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Live sequence ids ordered by slot index — the engine's canonical
    /// lane order, stable under churn (a refilled lane re-enters at its
    /// slot's position).
    pub fn ids_by_slot(&self) -> Vec<RequestId> {
        self.owner.iter().filter_map(|o| *o).collect()
    }

    /// Map pages for a freshly prefilled sequence and write its prompt K/V
    /// rows (rows `[0, prompt.len())` of lane `lane` in the prefill-shaped
    /// `(batch, kv_seq, kv_row)` plane buffers). Pages whose token prefix
    /// matches a registered page are mapped shared instead of written.
    pub fn write_prefill(
        &mut self,
        id: RequestId,
        prompt: &[i32],
        planes: &[Vec<f32>],
        lane: usize,
    ) -> anyhow::Result<()> {
        let n_planes = self.n_layers * 2;
        anyhow::ensure!(planes.len() == n_planes, "prefill: expected {n_planes} planes");
        let prompt_len = prompt.len();
        anyhow::ensure!(prompt_len <= self.kv_seq, "prefill longer than kv_seq");
        let plane = self.kv_seq * self.kv_row;
        for buf in planes {
            anyhow::ensure!(buf.len() >= (lane + 1) * plane, "prefill plane too short for lane");
        }
        {
            let seq = self
                .seqs
                .get(&id)
                .ok_or_else(|| anyhow::anyhow!("prefill into unmapped sequence {id}"))?;
            anyhow::ensure!(
                seq.pos == 0 && seq.table.is_empty(),
                "prefill into non-fresh sequence {id}"
            );
        }
        let block = self.spec.block;
        let mut table = Vec::with_capacity(prompt_len.div_ceil(block));
        let mut hash = FNV_OFFSET;
        for pi in 0..prompt_len.div_ceil(block) {
            let start = pi * block;
            let end = ((pi + 1) * block).min(prompt_len);
            for &t in &prompt[start..end] {
                hash = fnv_step(hash, t);
            }
            let hit = self.share.get(&hash).and_then(|e| {
                (e.toks.len() == end && e.toks[..] == prompt[..end]).then_some(e.page)
            });
            if let Some(p) = hit {
                self.pool.refcount[p] += 1;
                self.shared_hits += 1;
                table.push(p);
                continue;
            }
            let p = self.pool.alloc_page();
            for (li, buf) in planes.iter().enumerate() {
                for r in start..end {
                    let at = lane * plane + r * self.kv_row;
                    self.pool.write_row(p, li, r - start, &buf[at..at + self.kv_row]);
                }
            }
            if !self.share.contains_key(&hash) {
                self.share.insert(hash, ShareEntry { page: p, toks: prompt[..end].to_vec() });
                self.pool.share_key[p] = Some(hash);
            }
            table.push(p);
        }
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.table = table;
        seq.pos = prompt_len;
        Ok(())
    }

    /// Append one decoded K/V row per lane. `rows[li]` is the fresh
    /// `(batch, kv_row)` row buffer for plane `li` (k before v per layer).
    /// A write into a page shared with another sequence clones it first
    /// (copy-on-write); positions advance by one.
    pub fn append_step(
        &mut self,
        ids: &[RequestId],
        batch: usize,
        rows: &[Vec<f32>],
    ) -> anyhow::Result<()> {
        let n_planes = self.n_layers * 2;
        anyhow::ensure!(rows.len() == n_planes, "append: expected {n_planes} row planes");
        anyhow::ensure!(ids.len() <= batch, "append: more lanes than batch");
        for buf in rows {
            anyhow::ensure!(buf.len() == batch * self.kv_row, "append: bad row buffer length");
        }
        for (lane, id) in ids.iter().enumerate() {
            let (pos, mapped) = {
                let seq = self
                    .seqs
                    .get(id)
                    .ok_or_else(|| anyhow::anyhow!("append into unmapped sequence {id}"))?;
                (seq.pos, seq.table.len())
            };
            anyhow::ensure!(pos < self.kv_seq, "append past kv_seq for sequence {id}");
            let pi = pos / self.spec.block;
            let r = pos % self.spec.block;
            let pid = if pi >= mapped {
                debug_assert_eq!(pi, mapped, "block table gap");
                let p = self.pool.alloc_page();
                self.seqs.get_mut(id).unwrap().table.push(p);
                p
            } else {
                let p = self.seqs.get(id).unwrap().table[pi];
                if self.pool.refcount[p] > 1 {
                    // first divergent write into a shared page
                    let fresh = self.pool.alloc_page();
                    self.pool.copy_page(fresh, p);
                    self.release_page(p);
                    self.seqs.get_mut(id).unwrap().table[pi] = fresh;
                    fresh
                } else {
                    p
                }
            };
            for (li, buf) in rows.iter().enumerate() {
                self.pool.write_row(pid, li, r, &buf[lane * self.kv_row..(lane + 1) * self.kv_row]);
            }
            self.seqs.get_mut(id).unwrap().pos = pos + 1;
        }
        Ok(())
    }

    /// Gather lanes `ids` into one batch KV buffer per (layer, k/v), shaped
    /// `(batch, kv_seq, row)` flat — the decode graph's input layout. Rows
    /// `[pos, kv_seq)` and lanes beyond `ids.len()` are zeroed; an id with
    /// no mapped sequence is an error (page-table bugs fail loud instead of
    /// decoding garbage).
    ///
    /// Each (layer, k/v) buffer is an independent write target, so at
    /// serving dims the page decodes fan out over the `util::par`
    /// substrate (contiguous partition: bit-identical for any worker
    /// count, pool or scoped).
    pub fn gather_batch(&self, ids: &[RequestId], batch: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let mut out = Vec::new();
        self.gather_batch_into(ids, batch, &mut out)?;
        Ok(out)
    }

    /// [`KvCache::gather_batch`] into caller-owned storage. `out` is resized
    /// to `n_layers * 2` planes of `batch * kv_seq * row` f32s — existing
    /// buffers (e.g. the engine's per-step gather staging) are reused, so a
    /// steady-state decode step performs no gather allocations. Each plane
    /// is zero-filled before the page decodes land, exactly matching the
    /// fresh-buffer semantics of `gather_batch`.
    pub fn gather_batch_into(
        &self,
        ids: &[RequestId],
        batch: usize,
        out: &mut Vec<Vec<f32>>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(ids.len() <= batch, "gather: more lanes than batch");
        for id in ids {
            anyhow::ensure!(self.seqs.contains_key(id), "gather of unmapped sequence {id}");
        }
        let plane = self.kv_seq * self.kv_row;
        let n_planes = self.n_layers * 2;
        out.resize_with(n_planes, Vec::new);
        let (block, row, pool, seqs) = (self.spec.block, self.kv_row, &self.pool, &self.seqs);
        let fill = |li: usize, buf: &mut Vec<f32>| {
            buf.clear();
            buf.resize(batch * plane, 0.0);
            for (lane, id) in ids.iter().enumerate() {
                let seq = &seqs[id];
                let base = lane * plane;
                for (pi, &pid) in seq.table.iter().enumerate() {
                    let start = pi * block;
                    debug_assert!(seq.pos > start, "page beyond pos");
                    let rows = (seq.pos - start).min(block);
                    pool.read_rows(
                        pid,
                        li,
                        rows,
                        &mut buf[base + start * row..base + (start + rows) * row],
                    );
                }
            }
        };
        if batch * plane * out.len() < crate::util::par::PAR_MIN_LEN {
            for (li, buf) in out.iter_mut().enumerate() {
                fill(li, buf);
            }
        } else {
            crate::util::par::for_each_chunk(out, 1, |li, bufs| fill(li, &mut bufs[0]));
        }
        Ok(())
    }

    /// Bytes of page storage currently resident (arena high-water mark —
    /// pages on the free-list stay allocated).
    pub fn resident_bytes(&self) -> usize {
        self.pool.n_pages() * self.pool.page_bytes()
    }

    /// What the pre-paging dense cache would hold resident: every slot's
    /// full f32 planes, live or not.
    pub fn dense_bytes(&self) -> usize {
        self.capacity * self.n_layers * 2 * self.kv_seq * self.kv_row * 4
    }

    /// Cumulative number of pages mapped via prefix sharing instead of
    /// being written.
    pub fn pages_shared(&self) -> u64 {
        self.shared_hits
    }

    // --- introspection for tests/benches ---

    /// Total pages in the arena (free + mapped).
    pub fn total_pages(&self) -> usize {
        self.pool.n_pages()
    }

    /// Pages on the free-list.
    pub fn free_pages(&self) -> usize {
        self.pool.free.len()
    }

    /// The block table of `id` (physical page ids in position order).
    pub fn pages_of(&self, id: RequestId) -> Option<Vec<usize>> {
        self.seqs.get(&id).map(|s| s.table.clone())
    }

    /// Reference count of a physical page.
    pub fn page_refcount(&self, p: usize) -> u32 {
        self.pool.refcount[p]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> KvCache {
        KvCache::with_spec(4, 2, 8, 4, KvSpec { format: KvFormat::F32, block: 4 })
    }

    /// Single-lane prefill plane buffers with row r holding
    /// `base + li*1000 + r*10 + j`.
    fn planes(c: &KvCache, base: f32) -> Vec<Vec<f32>> {
        let plane = c.kv_seq * c.kv_row;
        (0..c.n_layers * 2)
            .map(|li| {
                (0..plane)
                    .map(|i| {
                        let (r, j) = (i / c.kv_row, i % c.kv_row);
                        base + li as f32 * 1000.0 + r as f32 * 10.0 + j as f32
                    })
                    .collect()
            })
            .collect()
    }

    fn row(c: &KvCache, base: f32) -> Vec<Vec<f32>> {
        (0..c.n_layers * 2)
            .map(|li| (0..c.kv_row).map(|j| base + li as f32 * 1000.0 + j as f32).collect())
            .collect()
    }

    #[test]
    fn alloc_free_cycle() {
        let mut c = cache();
        assert_eq!(c.free_slots(), 4);
        c.alloc(1).unwrap();
        c.alloc(2).unwrap();
        assert_eq!(c.free_slots(), 2);
        assert!(c.free(1).is_some());
        assert!(c.free(1).is_none());
        assert_eq!(c.free_slots(), 3);
    }

    #[test]
    fn double_alloc_rejected() {
        let mut c = cache();
        c.alloc(7).unwrap();
        assert!(c.alloc(7).is_err());
    }

    #[test]
    fn full_rejected() {
        let mut c = cache();
        for id in 0..4 {
            c.alloc(id).unwrap();
        }
        assert!(c.alloc(99).is_err());
    }

    #[test]
    fn lowest_slot_first_and_refill_flag() {
        let mut c = cache();
        assert_eq!(c.alloc(10).unwrap(), SlotAlloc { slot: 0, refill: false });
        assert_eq!(c.alloc(11).unwrap(), SlotAlloc { slot: 1, refill: false });
        assert_eq!(c.alloc(12).unwrap(), SlotAlloc { slot: 2, refill: false });
        // free the middle slot; the next alloc reuses it and reports refill
        assert_eq!(c.free(11), Some(1));
        assert_eq!(c.alloc(13).unwrap(), SlotAlloc { slot: 1, refill: true });
        assert_eq!(c.slot_of(13), Some(1));
        assert_eq!(c.ids_by_slot(), vec![10, 13, 12]);
    }

    #[test]
    fn prefill_gather_append_roundtrip() {
        let mut c = cache();
        c.alloc(1).unwrap();
        let p = planes(&c, 0.5);
        c.write_prefill(1, &[7, 8, 9, 10, 11], &p, 0).unwrap(); // ragged second page
        assert_eq!(c.pos_of(1), Some(5));
        assert_eq!(c.pages_of(1).unwrap().len(), 2);
        let g = c.gather_batch(&[1], 2).unwrap();
        let plane = c.kv_seq * c.kv_row;
        for li in 0..c.n_layers * 2 {
            // valid rows round-trip, the rest is zero (both lanes)
            assert_eq!(g[li][..5 * c.kv_row], p[li][..5 * c.kv_row]);
            assert!(g[li][5 * c.kv_row..plane].iter().all(|v| *v == 0.0));
            assert!(g[li][plane..].iter().all(|v| *v == 0.0));
        }
        // appends continue the ragged page up to the kv window
        for step in 0..3 {
            c.append_step(&[1], 1, &row(&c, 100.0 + step as f32)).unwrap();
        }
        assert_eq!(c.pos_of(1), Some(8));
        assert_eq!(c.pages_of(1).unwrap().len(), 2);
        let g = c.gather_batch(&[1], 1).unwrap();
        for li in 0..c.n_layers * 2 {
            assert_eq!(g[li][5 * c.kv_row], 100.0 + li as f32 * 1000.0);
            assert_eq!(g[li][7 * c.kv_row], 102.0 + li as f32 * 1000.0);
        }
        // the window is full: a further append fails loud
        assert!(c.append_step(&[1], 1, &row(&c, 9.0)).is_err());
    }

    #[test]
    fn gather_of_missing_id_errors() {
        let mut c = cache();
        c.alloc(1).unwrap();
        c.write_prefill(1, &[5], &planes(&c, 0.0), 0).unwrap();
        let err = c.gather_batch(&[1, 42], 2).unwrap_err().to_string();
        assert!(err.contains("unmapped sequence 42"), "diagnosable error, got: {err}");
        // a freed id is unmapped again — stale lane references fail loud
        c.free(1);
        assert!(c.gather_batch(&[1], 1).is_err());
        // and lane count may never exceed the batch shape
        let mut c = cache();
        c.alloc(1).unwrap();
        c.alloc(2).unwrap();
        c.alloc(3).unwrap();
        let err = c.gather_batch(&[1, 2, 3], 2).unwrap_err().to_string();
        assert!(err.contains("more lanes than batch"), "got: {err}");
    }

    #[test]
    fn prefix_sharing_and_cow_divergence() {
        let mut c = cache();
        c.alloc(1).unwrap();
        c.alloc(2).unwrap();
        let p = planes(&c, 0.25);
        c.write_prefill(1, &[3, 4, 5], &p, 0).unwrap();
        c.write_prefill(2, &[3, 4, 5], &p, 0).unwrap();
        // same ragged prefix -> same physical page, refcount 2
        let (t1, t2) = (c.pages_of(1).unwrap(), c.pages_of(2).unwrap());
        assert_eq!(t1, t2);
        assert_eq!(c.page_refcount(t1[0]), 2);
        assert_eq!(c.pages_shared(), 1);
        assert_eq!(c.total_pages(), 1);
        // first divergent write clones the shared page
        c.append_step(&[2], 1, &row(&c, 50.0)).unwrap();
        let t2b = c.pages_of(2).unwrap();
        assert_ne!(t2b[0], t1[0]);
        assert_eq!(c.page_refcount(t1[0]), 1);
        assert_eq!(c.page_refcount(t2b[0]), 1);
        // sequence 1's view is untouched by 2's append
        let g1 = c.gather_batch(&[1], 1).unwrap();
        assert_eq!(g1[0][..3 * c.kv_row], p[0][..3 * c.kv_row]);
        assert!(g1[0][3 * c.kv_row..].iter().all(|v| *v == 0.0));
        // a second append to 2 stays on the private clone (no new page)
        let before = c.total_pages();
        c.append_step(&[2], 1, &row(&c, 60.0)).unwrap();
        assert_eq!(c.total_pages(), before + 1); // pos 4 -> opens page 1
        assert_eq!(c.pages_of(2).unwrap()[0], t2b[0]);
    }

    #[test]
    fn freed_pages_recycle_without_leaking_rows() {
        let mut c = cache();
        c.alloc(1).unwrap();
        c.write_prefill(1, &[1, 2, 3, 4, 5, 6, 7, 8], &planes(&c, 9.0), 0).unwrap();
        let used = c.total_pages();
        c.free(1);
        assert_eq!(c.free_pages(), used);
        // a shorter re-use of the recycled pages never exposes stale rows
        c.alloc(2).unwrap();
        c.write_prefill(2, &[9, 9], &planes(&c, 1.0), 0).unwrap();
        assert_eq!(c.total_pages(), used); // recycled, not grown
        let g = c.gather_batch(&[2], 1).unwrap();
        for li in 0..c.n_layers * 2 {
            assert!(g[li][2 * c.kv_row..].iter().all(|v| *v == 0.0), "stale rows leaked");
        }
    }

    #[test]
    fn quantized_pages_round_trip_and_shrink() {
        let spec = KvSpec { format: KvFormat::Mxfp8, block: 4 };
        let mut c = KvCache::with_spec(4, 2, 8, 4, spec);
        c.alloc(1).unwrap();
        let p = planes(&c, 0.37);
        c.write_prefill(1, &[2, 3, 4, 5, 6], &p, 0).unwrap();
        let g = c.gather_batch(&[1], 1).unwrap();
        let cfg = spec.mx_config(c.kv_row).unwrap();
        for li in 0..c.n_layers * 2 {
            let want = crate::mx::mx_qdq(&p[li][..5 * c.kv_row], c.kv_row, &cfg);
            for (a, b) in g[li][..5 * c.kv_row].iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "quantized gather not bit-exact");
            }
        }
        // mxfp8 pages are ~3.5x smaller than f32 pages here
        let dense = KvCache::with_spec(4, 2, 8, 4, KvSpec { format: KvFormat::F32, block: 4 });
        assert!(c.resident_bytes() * 3 < dense.dense_bytes());
    }

    #[test]
    fn kv_spec_from_bits() {
        assert_eq!(KvSpec::from_bits(32).unwrap().format, KvFormat::F32);
        assert_eq!(KvSpec::from_bits(8).unwrap().format, KvFormat::Mxfp8);
        assert_eq!(KvSpec::from_bits(4).unwrap().format, KvFormat::Mxfp4);
        assert!(KvSpec::from_bits(16).is_err());
    }
}
