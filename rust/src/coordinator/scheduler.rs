//! Step scheduler: decides, per engine iteration, whether to run a prefill
//! (admit waiting requests into free KV slots) and which running sequences
//! join the decode step — the loop whose step latency the Fig. 4
//! throughput measurements bound.
//!
//! Policy: **prefill-priority with decode fairness** — admit waiting work
//! whenever slots are free (prefill batches amortize well), then decode all
//! running lanes, oldest first, in buckets. This mirrors vLLM's default
//! behaviour at this scale.
//!
//! Alongside the per-step plan, this module defines the scheduling **event
//! log** ([`SchedEvent`]): every admit / refill / evict / finish / reject
//! decision the engine makes, in order. Backends must agree on this log —
//! `runtime::sched_fingerprint` hashes it and the parity tests compare the
//! hashes, so a native and an XLA engine driven by the same workload are
//! provably making the same scheduling decisions even when their lane
//! arithmetic runs on different devices.

use super::request::{FinishReason, RequestId};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// Admit prefill whenever possible (default).
    PrefillPriority,
    /// Only admit when fewer than `low_watermark` lanes are running.
    DecodePriority { low_watermark: usize },
}

/// One scheduling decision, in engine order. The full log is the engine's
/// scheduling trace; [`crate::runtime::sched_fingerprint`] folds it into a
/// u64 for cross-backend lockstep checks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedEvent {
    /// Request `id` entered KV slot `slot`; `refill` is true when the slot
    /// is being reused after a previous occupant left mid-run (the
    /// continuous-batching churn path).
    Admit { id: RequestId, slot: usize, refill: bool },
    /// Request `id` left slot `slot` with a terminal `reason` — natural
    /// completion (Eos/Length/KvLimit) or mid-decode eviction
    /// (Cancelled/TimedOut).
    Evict { id: RequestId, slot: usize, reason: FinishReason },
    /// Request `id` never reached a slot: rejected at admission or removed
    /// from the queue (cancel / deadline expiry).
    Drop { id: RequestId, reason: FinishReason },
}

impl SchedEvent {
    /// Stable (tag, id, a, b) encoding used by the fingerprint hash.
    pub fn encode(self) -> (u8, u64, u64, u64) {
        match self {
            SchedEvent::Admit { id, slot, refill } => (1, id, slot as u64, refill as u64),
            SchedEvent::Evict { id, slot, reason } => {
                (2, id, slot as u64, reason.label().len() as u64 ^ hash_label(reason))
            }
            SchedEvent::Drop { id, reason } => (3, id, hash_label(reason), 0),
        }
    }
}

fn hash_label(reason: FinishReason) -> u64 {
    // FNV-1a over the stable label — keeps the encoding independent of
    // enum discriminant order.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in reason.label().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The plan for one engine iteration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepPlan {
    /// How many waiting requests to admit (prefill) this step.
    pub admit: usize,
    /// Running sequence ids to decode this step (all of them, bucketed by
    /// the engine).
    pub decode: Vec<RequestId>,
}

pub fn plan_step(
    policy: SchedulerPolicy,
    waiting: usize,
    running: &[RequestId],
    free_slots: usize,
    max_prefill_batch: usize,
) -> StepPlan {
    let admit = plan_admit(policy, waiting, running.len(), free_slots, max_prefill_batch);
    StepPlan { admit, decode: running.to_vec() }
}

/// Allocation-free core of [`plan_step`]: just the admit count. The engine
/// steady-state loop calls this directly (it already owns the running-lane
/// list, so cloning it into a [`StepPlan`] every step is pure waste — the
/// zero-allocation decode gate counts it).
pub fn plan_admit(
    policy: SchedulerPolicy,
    waiting: usize,
    running: usize,
    free_slots: usize,
    max_prefill_batch: usize,
) -> usize {
    match policy {
        SchedulerPolicy::PrefillPriority => waiting.min(free_slots).min(max_prefill_batch),
        SchedulerPolicy::DecodePriority { low_watermark } => {
            if running < low_watermark {
                waiting.min(free_slots).min(max_prefill_batch)
            } else {
                0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_priority_admits_up_to_free() {
        let p = plan_step(SchedulerPolicy::PrefillPriority, 10, &[1, 2], 3, 8);
        assert_eq!(p.admit, 3);
        assert_eq!(p.decode, vec![1, 2]);
    }

    #[test]
    fn prefill_bounded_by_batch() {
        let p = plan_step(SchedulerPolicy::PrefillPriority, 10, &[], 8, 4);
        assert_eq!(p.admit, 4);
    }

    #[test]
    fn decode_priority_defers_admission() {
        let policy = SchedulerPolicy::DecodePriority { low_watermark: 2 };
        let p = plan_step(policy, 5, &[1, 2, 3], 4, 8);
        assert_eq!(p.admit, 0);
        let p2 = plan_step(policy, 5, &[1], 4, 8);
        assert!(p2.admit > 0);
    }

    #[test]
    fn no_waiting_no_admit() {
        let p = plan_step(SchedulerPolicy::PrefillPriority, 0, &[7], 3, 8);
        assert_eq!(p.admit, 0);
    }

    #[test]
    fn event_encoding_distinguishes_variants() {
        let a = SchedEvent::Admit { id: 1, slot: 0, refill: false };
        let b = SchedEvent::Admit { id: 1, slot: 0, refill: true };
        let c = SchedEvent::Evict { id: 1, slot: 0, reason: FinishReason::Eos };
        let d = SchedEvent::Drop { id: 1, reason: FinishReason::Cancelled };
        let codes = [a.encode(), b.encode(), c.encode(), d.encode()];
        for i in 0..codes.len() {
            for j in (i + 1)..codes.len() {
                assert_ne!(codes[i], codes[j]);
            }
        }
    }

    #[test]
    fn evict_reasons_distinct() {
        let eos = SchedEvent::Evict { id: 9, slot: 2, reason: FinishReason::Eos };
        let timeout = SchedEvent::Evict { id: 9, slot: 2, reason: FinishReason::TimedOut };
        assert_ne!(eos.encode(), timeout.encode());
    }
}
