//! Step scheduler: decides, per engine iteration, whether to run a prefill
//! (admit waiting requests into free KV slots) and which running sequences
//! join the decode step — the loop whose step latency the Fig. 4
//! throughput measurements bound.
//!
//! Policy: **prefill-priority with decode fairness** — admit waiting work
//! whenever slots are free (prefill batches amortize well), then decode all
//! running lanes, oldest first, in buckets. This mirrors vLLM's default
//! behaviour at this scale.

use super::request::RequestId;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// Admit prefill whenever possible (default).
    PrefillPriority,
    /// Only admit when fewer than `low_watermark` lanes are running.
    DecodePriority { low_watermark: usize },
}

/// The plan for one engine iteration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepPlan {
    /// How many waiting requests to admit (prefill) this step.
    pub admit: usize,
    /// Running sequence ids to decode this step (all of them, bucketed by
    /// the engine).
    pub decode: Vec<RequestId>,
}

pub fn plan_step(
    policy: SchedulerPolicy,
    waiting: usize,
    running: &[RequestId],
    free_slots: usize,
    max_prefill_batch: usize,
) -> StepPlan {
    let admit = match policy {
        SchedulerPolicy::PrefillPriority => waiting.min(free_slots).min(max_prefill_batch),
        SchedulerPolicy::DecodePriority { low_watermark } => {
            if running.len() < low_watermark {
                waiting.min(free_slots).min(max_prefill_batch)
            } else {
                0
            }
        }
    };
    StepPlan { admit, decode: running.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_priority_admits_up_to_free() {
        let p = plan_step(SchedulerPolicy::PrefillPriority, 10, &[1, 2], 3, 8);
        assert_eq!(p.admit, 3);
        assert_eq!(p.decode, vec![1, 2]);
    }

    #[test]
    fn prefill_bounded_by_batch() {
        let p = plan_step(SchedulerPolicy::PrefillPriority, 10, &[], 8, 4);
        assert_eq!(p.admit, 4);
    }

    #[test]
    fn decode_priority_defers_admission() {
        let policy = SchedulerPolicy::DecodePriority { low_watermark: 2 };
        let p = plan_step(policy, 5, &[1, 2, 3], 4, 8);
        assert_eq!(p.admit, 0);
        let p2 = plan_step(policy, 5, &[1], 4, 8);
        assert!(p2.admit > 0);
    }

    #[test]
    fn no_waiting_no_admit() {
        let p = plan_step(SchedulerPolicy::PrefillPriority, 0, &[7], 3, 8);
        assert_eq!(p.admit, 0);
    }
}
