//! Request router: the front door of the serving stack.
//!
//! Assigns request ids, tracks in-flight state, and (when running multiple
//! engine workers) routes by least-loaded worker. On this single-node CPU
//! testbed there is one engine; the router still provides the id/state
//! machinery and the load-balancing policy used by the property tests.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use super::request::{GenRequest, RequestId};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    Queued,
    Running,
    Done,
}

pub struct Router {
    next_id: AtomicU64,
    states: HashMap<RequestId, ReqState>,
    /// Outstanding request count per worker.
    worker_load: Vec<usize>,
    assignment: HashMap<RequestId, usize>,
}

impl Router {
    pub fn new(workers: usize) -> Router {
        assert!(workers > 0);
        Router {
            next_id: AtomicU64::new(1),
            states: HashMap::new(),
            worker_load: vec![0; workers],
            assignment: HashMap::new(),
        }
    }

    /// Create a request and route it to the least-loaded worker.
    /// Returns (request, worker index).
    pub fn route(&mut self, prompt: Vec<i32>, max_new: usize) -> (GenRequest, usize) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let worker = self.assign(id);
        (GenRequest::new(id, prompt, max_new), worker)
    }

    /// Tag an externally-created request id with the least-loaded worker
    /// and track it as queued. Multi-worker serving uses this to label
    /// each request with its owning shard worker *without* changing the
    /// engine's admission order — the tensor-parallel engine executes all
    /// lanes, so assignment is bookkeeping, not a scheduling input, and
    /// `sched_fingerprint` stays invariant across worker counts.
    pub fn assign(&mut self, id: RequestId) -> usize {
        let worker = self
            .worker_load
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| **l)
            .map(|(i, _)| i)
            .unwrap();
        self.worker_load[worker] += 1;
        self.states.insert(id, ReqState::Queued);
        self.assignment.insert(id, worker);
        worker
    }

    pub fn mark_running(&mut self, id: RequestId) {
        self.states.insert(id, ReqState::Running);
    }

    pub fn mark_done(&mut self, id: RequestId) {
        if let Some(w) = self.assignment.get(&id) {
            self.worker_load[*w] = self.worker_load[*w].saturating_sub(1);
        }
        self.states.insert(id, ReqState::Done);
    }

    pub fn state(&self, id: RequestId) -> Option<ReqState> {
        self.states.get(&id).copied()
    }

    pub fn loads(&self) -> &[usize] {
        &self.worker_load
    }

    pub fn in_flight(&self) -> usize {
        self.states
            .values()
            .filter(|s| **s != ReqState::Done)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_unique_and_monotone() {
        let mut r = Router::new(1);
        let (a, _) = r.route(vec![1], 4);
        let (b, _) = r.route(vec![2], 4);
        assert!(b.id > a.id);
    }

    #[test]
    fn least_loaded_routing() {
        let mut r = Router::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..9 {
            let (_, w) = r.route(vec![1], 4);
            counts[w] += 1;
        }
        assert_eq!(counts, [3, 3, 3]);
    }

    #[test]
    fn assign_tags_external_ids_least_loaded() {
        let mut r = Router::new(2);
        // externally numbered requests (engine-side ids) round-robin while
        // loads are level, and completion rebalances
        assert_eq!(r.assign(100), 0);
        assert_eq!(r.assign(200), 1);
        r.mark_done(100);
        assert_eq!(r.assign(300), 0, "freed worker is least-loaded again");
        assert_eq!(r.state(300), Some(ReqState::Queued));
        assert_eq!(r.loads(), &[1, 1]);
    }

    #[test]
    fn completion_frees_load() {
        let mut r = Router::new(2);
        let (a, wa) = r.route(vec![1], 4);
        assert_eq!(r.loads()[wa], 1);
        r.mark_done(a.id);
        assert_eq!(r.loads()[wa], 0);
        assert_eq!(r.state(a.id), Some(ReqState::Done));
        assert_eq!(r.in_flight(), 0);
    }
}
