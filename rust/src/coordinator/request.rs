//! Request / result types shared across the serving stack.

use std::time::Instant;

pub type RequestId = u64;

/// A generation request entering the router.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Greedy decoding when None; top-k sampling seed otherwise.
    pub sample_seed: Option<u64>,
    pub arrived: Instant,
}

impl GenRequest {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        GenRequest { id, prompt, max_new_tokens, sample_seed: None, arrived: Instant::now() }
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Time from arrival to first generated token.
    pub ttft_s: f64,
    /// Time from arrival to completion.
    pub total_s: f64,
}

impl GenResult {
    pub fn decode_tokens(&self) -> usize {
        self.tokens.len()
    }
}
