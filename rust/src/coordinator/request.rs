//! Request / result / stream-event types shared across the serving stack.
//!
//! Every request that enters the admission layer leaves it with exactly one
//! [`GenResult`] whose [`FinishReason`] says how: generated to EOS/length,
//! evicted on deadline, cancelled, or rejected at the queue. Conservation
//! of this invariant (no request lost, duplicated, or reordered within a
//! lane) is property-tested in `rust/tests/coordinator_props.rs`.

use std::time::{Duration, Instant};

pub type RequestId = u64;

/// Why a request's lifecycle ended — the admission/decode/stream pipeline's
/// terminal states.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FinishReason {
    /// Generated the EOS token.
    Eos,
    /// Hit `max_new_tokens`.
    Length,
    /// Hit the KV sequence capacity.
    KvLimit,
    /// Cancelled by the client (mid-queue or mid-decode).
    Cancelled,
    /// Deadline expired (mid-queue or mid-decode); partial tokens kept.
    TimedOut,
    /// Refused at admission: the bounded queue was full (backpressure).
    RejectedQueueFull,
}

impl FinishReason {
    /// True for natural completions (the request got its full generation
    /// opportunity): EOS / length / KV-capacity stops.
    pub fn is_complete(self) -> bool {
        matches!(self, FinishReason::Eos | FinishReason::Length | FinishReason::KvLimit)
    }

    /// Short stable label (events, JSON reports).
    pub fn label(self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::Length => "length",
            FinishReason::KvLimit => "kv_limit",
            FinishReason::Cancelled => "cancelled",
            FinishReason::TimedOut => "timed_out",
            FinishReason::RejectedQueueFull => "rejected_queue_full",
        }
    }
}

/// A generation request entering the admission queue.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Greedy decoding when None; top-k sampling seed otherwise.
    pub sample_seed: Option<u64>,
    pub arrived: Instant,
    /// Optional latency SLO: the request is evicted with
    /// [`FinishReason::TimedOut`] once `arrived + deadline` passes, whether
    /// it is still queued or already decoding.
    pub deadline: Option<Duration>,
}

impl GenRequest {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            sample_seed: None,
            arrived: Instant::now(),
            deadline: None,
        }
    }

    /// Attach a deadline relative to arrival.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Has this request's deadline passed?
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| self.arrived.elapsed() > d)
    }
}

/// A finished lifecycle: one per submitted request, whatever the outcome.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: RequestId,
    pub prompt_len: usize,
    /// Generated tokens (possibly partial for TimedOut/Cancelled, empty
    /// for queue-level outcomes).
    pub tokens: Vec<i32>,
    /// How the lifecycle ended.
    pub outcome: FinishReason,
    /// Arrival-relative emission time of each generated token (seconds);
    /// `token_s[0]` is the TTFT sample, consecutive differences are the
    /// inter-token latency samples.
    pub token_s: Vec<f64>,
    /// Time from arrival to first generated token (0 if none).
    pub ttft_s: f64,
    /// Time from arrival to the end of the lifecycle.
    pub total_s: f64,
}

impl GenResult {
    pub fn decode_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Inter-token latency samples (seconds): differences of consecutive
    /// token emission times. Empty for < 2 tokens.
    pub fn inter_token_s(&self) -> Vec<f64> {
        self.token_s.windows(2).map(|w| (w[1] - w[0]).max(0.0)).collect()
    }
}

/// Per-token streaming event, delivered to the engine's sink as tokens are
/// produced — the serving front-end's streaming surface (collect-at-end
/// [`GenResult`]s remain the batch/bench surface).
#[derive(Clone, Debug, PartialEq)]
pub enum StreamEvent {
    /// `index`-th generated token of request `id` at arrival-relative
    /// time `t_s`.
    Token { id: RequestId, index: usize, token: i32, t_s: f64 },
    /// Request `id` left the pipeline; `n_tokens` tokens were streamed.
    Finished { id: RequestId, outcome: FinishReason, n_tokens: usize },
}

/// Boxed per-token callback (`None` = no streaming consumer).
pub type TokenSink = Box<dyn FnMut(&StreamEvent)>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_expiry() {
        let r = GenRequest::new(1, vec![1], 4);
        assert!(!r.expired(), "no deadline never expires");
        let r = r.with_deadline(Duration::from_secs(3600));
        assert!(!r.expired());
        let r = GenRequest::new(2, vec![1], 4).with_deadline(Duration::ZERO);
        std::thread::sleep(Duration::from_millis(1));
        assert!(r.expired());
    }

    #[test]
    fn inter_token_samples() {
        let r = GenResult {
            id: 1,
            prompt_len: 2,
            tokens: vec![10, 11, 12],
            outcome: FinishReason::Length,
            token_s: vec![0.010, 0.013, 0.019],
            ttft_s: 0.010,
            total_s: 0.019,
        };
        let itl = r.inter_token_s();
        assert_eq!(itl.len(), 2);
        assert!((itl[0] - 0.003).abs() < 1e-12 && (itl[1] - 0.006).abs() < 1e-12);
    }

    #[test]
    fn outcome_classes() {
        assert!(FinishReason::Eos.is_complete());
        assert!(FinishReason::Length.is_complete());
        assert!(FinishReason::KvLimit.is_complete());
        assert!(!FinishReason::TimedOut.is_complete());
        assert!(!FinishReason::Cancelled.is_complete());
        assert!(!FinishReason::RejectedQueueFull.is_complete());
        assert_eq!(FinishReason::RejectedQueueFull.label(), "rejected_queue_full");
    }
}
