//! Criterion-lite bench harness (criterion is not vendorable offline).
//!
//! `cargo bench` runs each `[[bench]]` binary with `harness = false`; they
//! use [`Bencher`] for timed sections and [`Table`] to print the paper's
//! rows/series as markdown, mirrored into `artifacts/results/<id>.md`.

use std::time::Instant;

use crate::util::Summary;

/// Timed measurement: warmup, then `iters` timed runs, p50/p99 + throughput.
pub struct Bencher {
    pub name: String,
    pub warmup: usize,
    pub iters: usize,
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub std_s: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

impl Bencher {
    pub fn new(name: &str) -> Self {
        Bencher { name: name.to_string(), warmup: 3, iters: 10 }
    }

    pub fn with_iters(mut self, warmup: usize, iters: usize) -> Self {
        self.warmup = warmup;
        self.iters = iters;
        self
    }

    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            s.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: self.name.clone(),
            mean_s: s.mean(),
            p50_s: s.percentile(50.0),
            p99_s: s.percentile(99.0),
            std_s: s.std(),
            iters: self.iters,
        }
    }
}

/// Markdown table builder that prints to stdout and saves to
/// `artifacts/results/<id>.md`.
pub struct Table {
    pub id: String,
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(id: &str, title: &str, header: &[&str]) -> Table {
        Table {
            id: id.to_string(),
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut out = format!("## {} — {}\n\n", self.id, self.title);
        out += &format!("| {} |\n", self.header.join(" | "));
        out += &format!("|{}\n", "---|".repeat(self.header.len()));
        for r in &self.rows {
            out += &format!("| {} |\n", r.join(" | "));
        }
        out
    }

    /// Print and persist under `artifacts/results/`.
    pub fn emit(&self) {
        let text = self.render();
        println!("{text}");
        let dir = crate::artifacts_dir().join("results");
        if std::fs::create_dir_all(&dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{}.md", self.id)), &text);
        }
    }
}

/// Machine-readable bench report: collects [`BenchResult`]s and writes
/// `BENCH_<id>.json` at the repo root so every PR's perf trajectory is
/// diffable in version control. Schema v3 (documented in README.md §Perf
/// methodology) — every row records which executor produced it, and the
/// schema additively admits timer-free counter rows — see
/// [`JsonReport::push_value`], e.g. `allocs_per_step` — alongside the
/// timed ones:
///
/// ```json
/// {
///   "bench": "microbench",
///   "schema": 3,
///   "results": [
///     {"op": "mx_qdq 64K f32", "backend": "native",
///      "mean_s": 1.2e-4, "p50_s": ..., "p99_s": ...,
///      "std_s": ..., "iters": 20,
///      "throughput": 5.4e8, "throughput_unit": "elem/s"},
///     {"op": "allocs_per_step native decode fp w=4", "backend": "native",
///      "value": 0, "value_unit": "alloc/step"}
///   ]
/// }
/// ```
pub struct JsonReport {
    pub id: String,
    entries: Vec<String>,
}

impl JsonReport {
    pub fn new(id: &str) -> JsonReport {
        JsonReport { id: id.to_string(), entries: Vec::new() }
    }

    /// Record one result from the pure-Rust ("native") execution path;
    /// `throughput` is `(unit, units_per_iter)`.
    pub fn push(&mut self, r: &BenchResult, throughput: Option<(&str, f64)>) {
        self.push_for(r, throughput, "native");
    }

    /// Record one result, stating which backend produced it ("native" for
    /// pure-Rust kernels/executors, "xla" for PJRT-measured rows).
    pub fn push_for(&mut self, r: &BenchResult, throughput: Option<(&str, f64)>, backend: &str) {
        let mut s = format!(
            "{{\"op\": {}, \"backend\": {}, \"mean_s\": {:e}, \"p50_s\": {:e}, \"p99_s\": {:e}, \"std_s\": {:e}, \"iters\": {}",
            json_str(&r.name),
            json_str(backend),
            r.mean_s,
            r.p50_s,
            r.p99_s,
            r.std_s,
            r.iters
        );
        if let Some((unit, units_per_iter)) = throughput {
            s += &format!(
                ", \"throughput\": {:e}, \"throughput_unit\": {}",
                r.throughput(units_per_iter),
                json_str(unit)
            );
        }
        s += "}";
        self.entries.push(s);
    }

    /// Record a timer-free counter row (schema v3): a bare measured value
    /// with its unit, e.g. `allocs_per_step` from the counting-allocator
    /// harness. Consumers keying on `mean_s`/`throughput` skip these rows;
    /// `scripts/bench_diff.py` inspects them for regressions.
    pub fn push_value(&mut self, name: &str, value: f64, unit: &str) {
        self.entries.push(format!(
            "{{\"op\": {}, \"backend\": {}, \"value\": {}, \"value_unit\": {}}}",
            json_str(name),
            json_str("native"),
            value,
            json_str(unit)
        ));
    }

    pub fn render(&self) -> String {
        let mut out = format!("{{\n  \"bench\": {},\n  \"schema\": 3,\n  \"results\": [\n", json_str(&self.id));
        out += &self
            .entries
            .iter()
            .map(|e| format!("    {e}"))
            .collect::<Vec<_>>()
            .join(",\n");
        out += "\n  ]\n}\n";
        out
    }

    /// Write `BENCH_<id>.json` into the repo root (nearest ancestor with a
    /// `ROADMAP.md`, overridable via `LATMIX_BENCH_DIR`), returning the path.
    pub fn emit(&self) -> std::path::PathBuf {
        let dir = match std::env::var("LATMIX_BENCH_DIR") {
            Ok(d) => std::path::PathBuf::from(d),
            Err(_) => repo_root(),
        };
        let path = dir.join(format!("BENCH_{}.json", self.id));
        if let Err(e) = std::fs::write(&path, self.render()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        }
        path
    }
}

/// Nearest ancestor of cwd containing `ROADMAP.md` (the repo root), else cwd.
pub fn repo_root() -> std::path::PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut dir = cwd.clone();
    loop {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            return cwd;
        }
    }
}

/// Minimal JSON string escaper shared by the bench + serving reports.
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format seconds human-readably.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures() {
        let r = Bencher::new("spin").with_iters(1, 5).run(|| {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.mean_s > 0.0);
        assert!(r.p99_s >= r.p50_s);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("test", "Test table", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| a | b |"));
        assert!(s.contains("| 1 | 2 |"));
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-5).ends_with("µs"));
        assert!(fmt_time(2e-2).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }

    #[test]
    fn json_report_renders() {
        let r = BenchResult {
            name: "op \"x\"".into(),
            mean_s: 1.5e-4,
            p50_s: 1.4e-4,
            p99_s: 2.0e-4,
            std_s: 1.0e-5,
            iters: 7,
        };
        let mut j = JsonReport::new("unit");
        j.push(&r, Some(("elem/s", 1000.0)));
        j.push_for(&r, None, "xla");
        j.push_value("allocs_per_step decode fp w=4", 0.0, "alloc/step");
        let s = j.render();
        assert!(s.contains("\"bench\": \"unit\""));
        assert!(s.contains("\"schema\": 3"));
        assert!(s.contains("\"value\": 0, \"value_unit\": \"alloc/step\""));
        assert!(s.contains("\"op\": \"op \\\"x\\\"\""));
        assert!(s.contains("\"backend\": \"native\""));
        assert!(s.contains("\"backend\": \"xla\""));
        assert!(s.contains("\"iters\": 7"));
        assert!(s.contains("\"throughput_unit\": \"elem/s\""));
        // numbers must be bare JSON literals, not NaN/inf
        assert!(!s.contains("NaN") && !s.contains("inf"));
    }

    #[test]
    fn repo_root_has_roadmap_or_is_cwd() {
        let root = repo_root();
        assert!(root.join("ROADMAP.md").exists() || root == std::env::current_dir().unwrap());
    }
}
