//! Property-testing mini-framework (proptest is not vendorable offline).
//!
//! `forall(cases, gen, prop)` runs `prop` on `cases` generated inputs; on
//! failure it performs a bounded greedy shrink via the generator's
//! `shrink` hook and reports the smallest failing case. Deterministic:
//! seeded from the property name unless `LATMIX_PT_SEED` is set.
//!
//! Used for the coordinator invariants (routing, batching, KV-slot state)
//! and the MX codec round-trip properties — see `rust/tests/`.

use crate::util::Pcg64;

/// A generator of random cases plus an optional shrinker.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;
    /// Candidate smaller versions of `v` (tried in order).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` on `cases` random inputs; panic with the minimal failing case.
pub fn forall<G: Gen>(
    name: &str,
    cases: usize,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let seed = std::env::var("LATMIX_PT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| fnv1a(name.as_bytes()));
    let mut rng = Pcg64::seed(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // greedy shrink, bounded
            let mut best = v.clone();
            let mut best_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrink(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property {name} failed (case {case}, seed {seed}):\n  input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Generator: f32 vector with log-uniform magnitude spread (stress for MX).
pub struct VecGen {
    pub min_len: usize,
    pub max_len: usize,
    pub multiple_of: usize,
    pub log_scale_range: (f32, f32),
}

impl Gen for VecGen {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let span = (self.max_len - self.min_len) / self.multiple_of;
        let len = self.min_len + self.multiple_of * rng.below(span as u64 + 1) as usize;
        let (lo, hi) = self.log_scale_range;
        let scale = (lo + rng.f32() * (hi - lo)).exp2();
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() - self.multiple_of].to_vec());
            out.push(v[self.multiple_of..].to_vec());
        }
        // zero half the entries
        if v.iter().any(|x| *x != 0.0) {
            let mut z = v.clone();
            for x in z.iter_mut().skip(1).step_by(2) {
                *x = 0.0;
            }
            if &z != v {
                out.push(z);
            }
        }
        out
    }
}

/// Generator: small usize in [lo, hi].
pub struct UsizeGen(pub usize, pub usize);

impl Gen for UsizeGen {
    type Value = usize;

    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.below((self.1 - self.0 + 1) as u64) as usize
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        if *v > self.0 {
            vec![self.0, (self.0 + *v) / 2, *v - 1]
        } else {
            vec![]
        }
    }
}

/// Generator: a random "event script" for the coordinator state machines —
/// a list of (op_code, value) pairs interpreted by the test.
pub struct ScriptGen {
    pub max_len: usize,
    pub ops: usize,
    pub max_value: u64,
}

impl Gen for ScriptGen {
    type Value = Vec<(u8, u64)>;

    fn generate(&self, rng: &mut Pcg64) -> Vec<(u8, u64)> {
        let len = 1 + rng.below(self.max_len as u64) as usize;
        (0..len)
            .map(|_| (rng.below(self.ops as u64) as u8, rng.below(self.max_value.max(1))))
            .collect()
    }

    fn shrink(&self, v: &Vec<(u8, u64)>) -> Vec<Vec<(u8, u64)>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[v.len() / 2..].to_vec());
            out.push(v[..v.len() - 1].to_vec());
            out.push(v[1..].to_vec());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        let gen = VecGen { min_len: 8, max_len: 64, multiple_of: 8, log_scale_range: (-4.0, 4.0) };
        forall("sum_nonneg", 50, &gen, |v| {
            let s: f32 = v.iter().map(|x| x * x).sum();
            if s >= 0.0 { Ok(()) } else { Err(format!("negative {s}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property must_fail failed")]
    fn failing_property_shrinks() {
        forall("must_fail", 10, &UsizeGen(0, 100), |v| {
            if *v < 3 { Ok(()) } else { Err("too big".into()) }
        });
    }
}
