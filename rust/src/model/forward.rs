//! Pure-Rust transformer interpreter — the XLA-free execution path.
//!
//! Mirrors `python/compile/model.py` op for op (pre-RMSNorm Llama-style
//! blocks, RoPE, SiLU-gated FFN, activation QDQ at every linear input —
//! the paper's Sec. 4.1 deployment graph with Eq. 1 fake quantization —
//! optional online T3 block-Hadamard on the down-proj input) over the same
//! `.lxt` weight sets and the same `(batch, kv_seq, n_heads, head_dim)` KV
//! plane layout as the AOT graphs. `NativeExecutor` (serving) and
//! `NativeBackend` (eval) are thin wrappers over [`NativeWeights`], so the
//! whole continuous-batching loop and the perplexity/zero-shot harness run
//! on machines without the XLA toolchain.
//!
//! Numerics note: this path is float-faithful to the model definition but
//! not bit-identical to the compiled HLO (different summation orders inside
//! XLA fusions). Internal consistency — prefill+decode vs full-sequence —
//! is property-tested below; cross-backend agreement with PJRT is covered
//! by the artifact-gated integration tests.
//!
//! ## Transform-spec execution
//!
//! Every entry point has a `*_spec` variant taking an optional
//! `(&TransformSpec, TransformMode)` pair (see `transform::spec`):
//!
//! - `Unfolded` runs the *reference* transformed model on original
//!   weights — T1 forward at the embedding / backward at every linear
//!   input / A-only forward at block outputs, per-head T2 forward on the
//!   value rows (so the KV cache holds transformed values, exactly as a
//!   folded `wv` would produce) / backward on the attention output after
//!   its QDQ, and FfnDown forward before / backward after the down-proj
//!   QDQ.
//! - `Folded` runs *deployment* semantics on folded weights: only the
//!   online remainder (FfnDown forwards) is applied.
//!
//! The two modes compute the same function up to f32 association error —
//! the end-to-end gate in `rust/tests/spec_pipeline.rs`.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::io::lxt::Tensor;
use crate::linalg::{block_hadamard_apply, Mat, PackedMat, WeightMatrix};
use crate::mx::{mx_qdq_rows, MxConfig};
use crate::transform::spec::{TransformMode, TransformSpec};
use crate::transform::Affine;
use crate::util::{par, scratch, Pcg64};

/// Optional spec-application argument of the `*_spec` entry points.
pub type SpecRun<'a> = Option<(&'a TransformSpec, TransformMode)>;

use super::{ModelDesc, WeightSet};

/// RMSNorm epsilon (mirror of python `model.EPS`).
pub const EPS: f32 = 1e-5;
/// RoPE base (mirror of python `ModelConfig.rope_theta`; not in the
/// manifest because every artifact set uses the default).
pub const ROPE_THETA: f32 = 10000.0;

/// Static model dimensions the interpreter needs — a [`ModelDesc`] without
/// the artifact inventory, so executors can exist with no artifacts on disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NativeDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub kv_seq: usize,
    pub prefill_len: usize,
}

impl NativeDims {
    pub fn from_desc(d: &ModelDesc) -> NativeDims {
        NativeDims {
            vocab: d.vocab,
            d_model: d.d_model,
            n_layers: d.n_layers,
            n_heads: d.n_heads,
            d_ff: d.d_ff,
            kv_seq: d.kv_seq,
            prefill_len: d.prefill_len,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// The dimensions of the real latmix-tiny artifact set — the default
    /// for artifact-free benches so native numbers are comparable.
    pub fn latmix_tiny() -> NativeDims {
        NativeDims {
            vocab: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 384,
            kv_seq: 160,
            prefill_len: 32,
        }
    }
}

/// Tensor-parallel shard plan: how the forward pass splits across
/// `workers` fork-join shard workers ([`crate::util::par::run_workers`]).
///
/// The *partition* is fixed by the model, never by the worker count:
/// attention has one unit per head (Q/K/V/O column/row slices, per-head
/// T2, per-head KV plane slices), the FFN has one unit per
/// `ffn_block`-wide `d_ff` band (gate/up column slices, `wd` row bands).
/// Workers only take ownership of contiguous unit runs; per-unit results
/// are assembled — and the two row-split reductions (`wo`, `wd`) summed —
/// serially in ascending unit order. That makes logits, token streams,
/// and scheduling fingerprints bit-identical for any worker count
/// (`rust/tests/shard_parity.rs`). T1/residual/norm/QDQ full-row ops are
/// replicated serially between the fork-join stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Fork-join worker count. `1` runs the same segmented kernels
    /// serially — the baseline of the 1-vs-N parity suite.
    pub workers: usize,
    /// Width of the fixed `d_ff` band partition (the `shard.ffn_block`
    /// manifest key), persisted per artifact so every host slices a
    /// folded weight set the same way.
    pub ffn_block: usize,
}

impl ShardPlan {
    /// Default band width: 8 bands, so every supported worker count
    /// (`workers <= n_heads <= 8` on the tiny models) stays busy through
    /// the FFN stages.
    pub fn default_ffn_block(d_ff: usize) -> usize {
        ((d_ff + 7) / 8).max(1)
    }

    /// Plan with the default band partition for these dimensions.
    pub fn new(workers: usize, dims: &NativeDims) -> Result<ShardPlan> {
        let plan = ShardPlan { workers, ffn_block: Self::default_ffn_block(dims.d_ff) };
        plan.validate(dims)?;
        Ok(plan)
    }

    pub fn validate(&self, dims: &NativeDims) -> Result<()> {
        anyhow::ensure!(
            self.workers >= 1,
            "shard plan needs at least 1 worker (workers=0 is not a valid tensor-parallel split)"
        );
        anyhow::ensure!(
            self.workers <= dims.n_heads,
            "workers {} exceeds n_heads {}: attention shards along heads, extra workers would own no head",
            self.workers,
            dims.n_heads
        );
        anyhow::ensure!(self.ffn_block >= 1, "shard plan ffn_block must be >= 1");
        Ok(())
    }

    fn ffn_bands(&self, d_ff: usize) -> usize {
        (d_ff + self.ffn_block - 1) / self.ffn_block
    }
}

/// Activation-side quantization spec parsed from a graph quant tag
/// (`fp` | `<fmt>_b<bs>` | `<fmt>_b<bs>_t3`, see `quant_tag` in
/// `python/compile/aot.py`). What differs per compiled graph is exactly
/// this: the activation QDQ config and the online T3 Hadamard.
#[derive(Clone, Copy, Debug)]
pub struct GraphSpec {
    pub act: Option<MxConfig>,
    /// Online T3 block-Hadamard block size applied to the down-proj input.
    pub t3: Option<usize>,
}

impl GraphSpec {
    /// The T3 block size every artifact set uses (python `t3=32`).
    pub const T3_BLOCK: usize = 32;

    pub fn fp() -> GraphSpec {
        GraphSpec { act: None, t3: None }
    }

    pub fn from_tag(tag: &str) -> Result<GraphSpec> {
        if tag == "fp" {
            return Ok(GraphSpec::fp());
        }
        let (base, t3) = match tag.strip_suffix("_t3") {
            Some(b) => (b, Some(Self::T3_BLOCK)),
            None => (tag, None),
        };
        let (fmt, bs) = base
            .rsplit_once("_b")
            .with_context(|| format!("malformed quant tag {tag:?} (want fp or <fmt>_b<bs>[_t3])"))?;
        let bs: usize = bs
            .parse()
            .with_context(|| format!("malformed block size in quant tag {tag:?}"))?;
        let act = MxConfig::from_name(fmt, Some(bs))?;
        Ok(GraphSpec { act: Some(act), t3 })
    }

    /// Parse the tag out of a full-sequence logits graph name
    /// (`logits_ppl_<tag>` / `logits_score_<tag>`).
    pub fn from_graph_name(graph: &str) -> Result<GraphSpec> {
        let tag = graph
            .strip_prefix("logits_ppl_")
            .or_else(|| graph.strip_prefix("logits_score_"))
            .with_context(|| format!("{graph:?} is not a logits graph"))?;
        GraphSpec::from_tag(tag)
    }

    /// Check the spec is runnable at these dimensions (MX blocks must tile
    /// both linear-input widths; T3 must tile the FFN width).
    pub fn validate(&self, dims: &NativeDims) -> Result<()> {
        if let Some(cfg) = &self.act {
            anyhow::ensure!(
                dims.d_model % cfg.block_size == 0 && dims.d_ff % cfg.block_size == 0,
                "act block {} does not tile d_model {} / d_ff {}",
                cfg.block_size,
                dims.d_model,
                dims.d_ff
            );
        }
        if let Some(b) = self.t3 {
            anyhow::ensure!(
                b.is_power_of_two() && dims.d_ff % b == 0,
                "t3 block {b} does not tile d_ff {}",
                dims.d_ff
            );
        }
        Ok(())
    }
}

/// One transformer block's parameters (row-vector convention: `y = x W + b`,
/// `W: (in, out)` — identical to the python pytree). Generic over the
/// weight-matrix storage `W` ([`linalg::WeightMatrix`]): dense f32 [`Mat`]
/// by default, or bit-packed [`PackedMat`] in the packed serving mode.
/// Norm gains and biases are small and stay f32 either way.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerWeights<W = Mat> {
    pub ln1: Vec<f32>,
    pub wq: W,
    pub bq: Vec<f32>,
    pub wk: W,
    pub bk: Vec<f32>,
    pub wv: W,
    pub bv: Vec<f32>,
    pub wo: W,
    pub bo: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wg: W,
    pub bg: Vec<f32>,
    pub wu: W,
    pub bu: Vec<f32>,
    pub wd: W,
    pub bd: Vec<f32>,
}

/// A full parsed weight set plus its dimensions — the native analogue of a
/// staged PJRT literal vector. Generic over linear-layer weight storage
/// (see [`LayerWeights`]); the embedding stays a dense [`Mat`] in every
/// mode because it is only ever read row-wise (`embed_rows` gathers, the
/// GEMM never touches it).
#[derive(Clone, Debug, PartialEq)]
pub struct NativeWeights<W = Mat> {
    pub dims: NativeDims,
    pub embed: Mat,
    pub layers: Vec<LayerWeights<W>>,
    pub lnf: Vec<f32>,
    pub head: W,
    pub bhead: Vec<f32>,
}

/// Weights held in bit-packed MX form: every linear matmul runs the fused
/// `linalg::packed_matmul` LUT kernel and the f32 weight matrices are
/// never materialized (~7.5x fewer resident weight bytes at B=32).
pub type PackedNativeWeights = NativeWeights<PackedMat>;

impl NativeWeights {
    /// Parse an `.lxt` weight set using the manifest's canonical argument
    /// order (`aot.weight_names`). Shape-checks every tensor.
    pub fn from_weight_set(
        dims: NativeDims,
        order: &[String],
        ws: &WeightSet,
    ) -> Result<NativeWeights> {
        anyhow::ensure!(
            order.len() == ws.tensors.len(),
            "weight order has {} names but weight set {:?} has {} tensors",
            order.len(),
            ws.tag,
            ws.tensors.len()
        );
        let map: HashMap<&str, &Tensor> = order
            .iter()
            .map(String::as_str)
            .zip(ws.tensors.iter())
            .collect();
        let vec1 = |name: &str, len: usize| -> Result<Vec<f32>> {
            let t = *map
                .get(name)
                .with_context(|| format!("weight set {:?} missing {name}", ws.tag))?;
            let v = t.as_f32().with_context(|| format!("{name} is not f32"))?;
            anyhow::ensure!(v.len() == len, "{name}: len {} != expected {len}", v.len());
            Ok(v.to_vec())
        };
        let mat2 = |name: &str, rows: usize, cols: usize| -> Result<Mat> {
            let t = *map
                .get(name)
                .with_context(|| format!("weight set {:?} missing {name}", ws.tag))?;
            let v = t.as_f32().with_context(|| format!("{name} is not f32"))?;
            anyhow::ensure!(
                t.dims == [rows, cols],
                "{name}: dims {:?} != expected [{rows}, {cols}]",
                t.dims
            );
            Ok(Mat::from_vec(rows, cols, v.to_vec()))
        };
        let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
        let mut layers = Vec::with_capacity(dims.n_layers);
        for i in 0..dims.n_layers {
            let p = |k: &str| format!("layers.{i}.{k}");
            layers.push(LayerWeights {
                ln1: vec1(&p("ln1"), d)?,
                wq: mat2(&p("wq"), d, d)?,
                bq: vec1(&p("bq"), d)?,
                wk: mat2(&p("wk"), d, d)?,
                bk: vec1(&p("bk"), d)?,
                wv: mat2(&p("wv"), d, d)?,
                bv: vec1(&p("bv"), d)?,
                wo: mat2(&p("wo"), d, d)?,
                bo: vec1(&p("bo"), d)?,
                ln2: vec1(&p("ln2"), d)?,
                wg: mat2(&p("wg"), d, f)?,
                bg: vec1(&p("bg"), f)?,
                wu: mat2(&p("wu"), d, f)?,
                bu: vec1(&p("bu"), f)?,
                wd: mat2(&p("wd"), f, d)?,
                bd: vec1(&p("bd"), d)?,
            });
        }
        Ok(NativeWeights {
            dims,
            embed: mat2("embed", v, d)?,
            layers,
            lnf: vec1("lnf", d)?,
            head: mat2("head", d, v)?,
            bhead: vec1("bhead", v)?,
        })
    }

    /// Deterministic random-init weights (scaled-normal matrices, unit
    /// norms, zero biases — mirror of python `init_params`) for
    /// artifact-free tests and benches.
    pub fn synthetic(dims: NativeDims, seed: u64) -> NativeWeights {
        let mut rng = Pcg64::seed(seed);
        let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
        let mut mat = |r: usize, c: usize, scale: f32| -> Mat {
            Mat::from_vec(r, c, rng.normal_vec(r * c, scale))
        };
        let d_scale = (d as f32).powf(-0.5);
        let o_scale = (2 * d * dims.n_layers) as f32;
        let o_scale = o_scale.powf(-0.5);
        let dn_scale = (2 * f * dims.n_layers) as f32;
        let dn_scale = dn_scale.powf(-0.5);
        let embed = mat(v, d, 1.0);
        let mut layers = Vec::with_capacity(dims.n_layers);
        for _ in 0..dims.n_layers {
            layers.push(LayerWeights {
                ln1: vec![1.0; d],
                wq: mat(d, d, d_scale),
                bq: vec![0.0; d],
                wk: mat(d, d, d_scale),
                bk: vec![0.0; d],
                wv: mat(d, d, d_scale),
                bv: vec![0.0; d],
                wo: mat(d, d, o_scale),
                bo: vec![0.0; d],
                ln2: vec![1.0; d],
                wg: mat(d, f, d_scale),
                bg: vec![0.0; f],
                wu: mat(d, f, d_scale),
                bu: vec![0.0; f],
                wd: mat(f, d, dn_scale),
                bd: vec![0.0; d],
            });
        }
        let head = mat(d, v, d_scale);
        NativeWeights {
            dims,
            embed,
            layers,
            lnf: vec![1.0; d],
            head,
            bhead: vec![0.0; v],
        }
    }

    /// Serialize back into the canonical argument order — gives tests a
    /// real [`WeightSet`] (and its `weight_order`) without any artifacts.
    pub fn to_weight_set(&self, tag: &str) -> (Vec<String>, WeightSet) {
        let dims = &self.dims;
        let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
        let mut items: Vec<(String, Tensor)> = Vec::new();
        items.push(("embed".into(), Tensor::f32(vec![v, d], self.embed.data.clone())));
        for (i, lw) in self.layers.iter().enumerate() {
            let p = |k: &str| format!("layers.{i}.{k}");
            items.extend([
                (p("ln1"), Tensor::f32(vec![d], lw.ln1.clone())),
                (p("wq"), Tensor::f32(vec![d, d], lw.wq.data.clone())),
                (p("bq"), Tensor::f32(vec![d], lw.bq.clone())),
                (p("wk"), Tensor::f32(vec![d, d], lw.wk.data.clone())),
                (p("bk"), Tensor::f32(vec![d], lw.bk.clone())),
                (p("wv"), Tensor::f32(vec![d, d], lw.wv.data.clone())),
                (p("bv"), Tensor::f32(vec![d], lw.bv.clone())),
                (p("wo"), Tensor::f32(vec![d, d], lw.wo.data.clone())),
                (p("bo"), Tensor::f32(vec![d], lw.bo.clone())),
                (p("ln2"), Tensor::f32(vec![d], lw.ln2.clone())),
                (p("wg"), Tensor::f32(vec![d, f], lw.wg.data.clone())),
                (p("bg"), Tensor::f32(vec![f], lw.bg.clone())),
                (p("wu"), Tensor::f32(vec![d, f], lw.wu.data.clone())),
                (p("bu"), Tensor::f32(vec![f], lw.bu.clone())),
                (p("wd"), Tensor::f32(vec![f, d], lw.wd.data.clone())),
                (p("bd"), Tensor::f32(vec![d], lw.bd.clone())),
            ]);
        }
        items.push(("lnf".into(), Tensor::f32(vec![d], self.lnf.clone())));
        items.push(("head".into(), Tensor::f32(vec![d, v], self.head.data.clone())));
        items.push(("bhead".into(), Tensor::f32(vec![v], self.bhead.clone())));
        let mut order = Vec::with_capacity(items.len());
        let mut tensors = Vec::with_capacity(items.len());
        for (name, t) in items {
            order.push(name);
            tensors.push(t);
        }
        let param_count = tensors.iter().map(|t| t.len()).sum();
        (
            order,
            WeightSet { tag: tag.to_string(), tensors, param_count },
        )
    }

    /// Re-encode every linear weight matrix into bit-packed MX storage
    /// (`cfg` is the graph tag's activation format — the packed serving
    /// mode reuses it for weights). The embedding, norm gains, and biases
    /// stay f32. Fails on formats `PackedMat` cannot hold (non-4-bit,
    /// two-level scales, blocks that do not tile a weight width).
    pub fn pack_weights(&self, cfg: MxConfig) -> Result<PackedNativeWeights> {
        let pk = |w: &Mat, name: &str| -> Result<PackedMat> {
            PackedMat::pack(w, cfg).with_context(|| format!("packing weight {name}"))
        };
        let mut layers = Vec::with_capacity(self.layers.len());
        for (i, lw) in self.layers.iter().enumerate() {
            let p = |k: &str| format!("layers.{i}.{k}");
            layers.push(LayerWeights {
                ln1: lw.ln1.clone(),
                wq: pk(&lw.wq, &p("wq"))?,
                bq: lw.bq.clone(),
                wk: pk(&lw.wk, &p("wk"))?,
                bk: lw.bk.clone(),
                wv: pk(&lw.wv, &p("wv"))?,
                bv: lw.bv.clone(),
                wo: pk(&lw.wo, &p("wo"))?,
                bo: lw.bo.clone(),
                ln2: lw.ln2.clone(),
                wg: pk(&lw.wg, &p("wg"))?,
                bg: lw.bg.clone(),
                wu: pk(&lw.wu, &p("wu"))?,
                bu: lw.bu.clone(),
                wd: pk(&lw.wd, &p("wd"))?,
                bd: lw.bd.clone(),
            });
        }
        Ok(NativeWeights {
            dims: self.dims,
            embed: self.embed.clone(),
            layers,
            lnf: self.lnf.clone(),
            head: pk(&self.head, "head")?,
            bhead: self.bhead.clone(),
        })
    }
}

impl PackedNativeWeights {
    /// Dequantize every packed weight back to dense f32 — the *same*
    /// packed bytes, decoded once up front instead of inside the GEMM.
    /// Running this twin through the engine is the packed-vs-dequantized
    /// parity gate: token streams must be bit-identical because
    /// `packed_matmul` replays the dense kernel's accumulation order.
    pub fn unpack_weights(&self) -> NativeWeights {
        let layers = self
            .layers
            .iter()
            .map(|lw| LayerWeights {
                ln1: lw.ln1.clone(),
                wq: lw.wq.unpack(),
                bq: lw.bq.clone(),
                wk: lw.wk.unpack(),
                bk: lw.bk.clone(),
                wv: lw.wv.unpack(),
                bv: lw.bv.clone(),
                wo: lw.wo.unpack(),
                bo: lw.bo.clone(),
                ln2: lw.ln2.clone(),
                wg: lw.wg.unpack(),
                bg: lw.bg.clone(),
                wu: lw.wu.unpack(),
                bu: lw.bu.clone(),
                wd: lw.wd.unpack(),
                bd: lw.bd.clone(),
            })
            .collect();
        NativeWeights {
            dims: self.dims,
            embed: self.embed.clone(),
            layers,
            lnf: self.lnf.clone(),
            head: self.head.unpack(),
            bhead: self.bhead.clone(),
        }
    }
}

impl<W: WeightMatrix> NativeWeights<W> {
    /// Resident bytes of all weight storage (embedding + linear matrices
    /// + norms/biases) — what the serve report prints as
    /// `resident_weight_bytes`.
    pub fn weight_bytes(&self) -> usize {
        let f32s = std::mem::size_of::<f32>();
        let vecs = |v: &Vec<f32>| v.len() * f32s;
        let mut total = self.embed.data.len() * f32s
            + vecs(&self.lnf)
            + vecs(&self.bhead)
            + self.head.weight_bytes();
        for lw in &self.layers {
            total += lw.wq.weight_bytes()
                + lw.wk.weight_bytes()
                + lw.wv.weight_bytes()
                + lw.wo.weight_bytes()
                + lw.wg.weight_bytes()
                + lw.wu.weight_bytes()
                + lw.wd.weight_bytes()
                + vecs(&lw.ln1)
                + vecs(&lw.ln2)
                + vecs(&lw.bq)
                + vecs(&lw.bk)
                + vecs(&lw.bv)
                + vecs(&lw.bo)
                + vecs(&lw.bg)
                + vecs(&lw.bu)
                + vecs(&lw.bd);
        }
        total
    }

    // -- entry points -------------------------------------------------------

    /// Residual-stream capture for transform learning (Sec. 3.2 / Fig. 2):
    /// run the full-sequence forward and return the `(batch * t, d_model)`
    /// residual rows *entering* block `layer` (`0` = post-embedding,
    /// `n_layers` = input to the final norm) — the features the paper
    /// learns `T1` on. `latmix::learn_from_model` drives this.
    pub fn capture_residual(
        &self,
        tokens: &[i32],
        batch: usize,
        t: usize,
        spec: &GraphSpec,
        layer: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == batch * t, "tokens len != batch * t");
        anyhow::ensure!(
            layer <= self.dims.n_layers,
            "layer {layer} out of range (model has {} blocks)",
            self.dims.n_layers
        );
        spec.validate(&self.dims)?;
        let mut x = self.embed_rows(tokens);
        let lens = vec![t; batch];
        for (li, lw) in self.layers[..layer].iter().enumerate() {
            self.block_full(li, lw, &mut x, batch, t, &lens, spec, None);
        }
        Ok(x)
    }

    /// Per-head feature capture for T2 learning (Sec. 3.2): run blocks
    /// `0..layer` untransformed, then return the per-head attention-output
    /// rows of block `layer` — one `(batch * t, head_dim)` flat buffer per
    /// head, taken *before* the output QDQ. These are convex mixes of the
    /// value rows (softmax rows sum to 1), i.e. exactly the per-head
    /// coordinates the deployed model quantizes at the `wo` input, which a
    /// `PerHeadValue` transform reshapes. `latmix::learn_spec` drives this.
    pub fn capture_head_values(
        &self,
        tokens: &[i32],
        batch: usize,
        t: usize,
        spec: &GraphSpec,
        layer: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let dims = &self.dims;
        anyhow::ensure!(tokens.len() == batch * t, "tokens len != batch * t");
        anyhow::ensure!(
            layer < dims.n_layers,
            "layer {layer} out of range (model has {} blocks)",
            dims.n_layers
        );
        spec.validate(dims)?;
        let (d, h) = (dims.d_model, dims.n_heads);
        let dh = dims.head_dim();
        let mut x = self.embed_rows(tokens);
        let lens = vec![t; batch];
        for (li, lw) in self.layers[..layer].iter().enumerate() {
            self.block_full(li, lw, &mut x, batch, t, &lens, spec, None);
        }
        let lw = &self.layers[layer];
        let mut hq = rmsnorm_rows(&x, d, &lw.ln1);
        qdq_rows(&mut hq, d, spec);
        let mut q = linear(&hq, &lw.wq, &lw.bq);
        let mut k = linear(&hq, &lw.wk, &lw.bk);
        let v = linear(&hq, &lw.wv, &lw.bv);
        let pos: Vec<i32> = (0..batch * t).map(|i| (i % t) as i32).collect();
        apply_rope_rows(&mut q, h, dh, &pos);
        apply_rope_rows(&mut k, h, dh, &pos);
        let o = attention_full(&q, &k, &v, batch, t, &lens, h, dh);
        let mut out = vec![Vec::new(); h];
        for row in o.chunks(d) {
            for (head, buf) in out.iter_mut().enumerate() {
                buf.extend_from_slice(&row[head * dh..(head + 1) * dh]);
            }
        }
        Ok(out)
    }

    /// Down-proj input capture for `FfnDown` learning: run blocks
    /// `0..layer` plus block `layer`'s attention untransformed, then return
    /// the gated FFN activation rows `(batch * t, d_ff)` after the online
    /// T3 Hadamard (when `spec.t3` is set) and before the down-proj QDQ —
    /// the tensor an `FfnDown` transform reshapes.
    pub fn capture_ffn_input(
        &self,
        tokens: &[i32],
        batch: usize,
        t: usize,
        spec: &GraphSpec,
        layer: usize,
    ) -> Result<Vec<f32>> {
        let dims = &self.dims;
        anyhow::ensure!(tokens.len() == batch * t, "tokens len != batch * t");
        anyhow::ensure!(
            layer < dims.n_layers,
            "layer {layer} out of range (model has {} blocks)",
            dims.n_layers
        );
        spec.validate(dims)?;
        let mut x = self.embed_rows(tokens);
        let lens = vec![t; batch];
        for (li, lw) in self.layers[..layer].iter().enumerate() {
            self.block_full(li, lw, &mut x, batch, t, &lens, spec, None);
        }
        let lw = &self.layers[layer];
        self.attn_block(layer, lw, &mut x, batch, t, &lens, spec, None);
        Ok(self.ffn_gate(lw, &x, spec, None))
    }

    /// Full-sequence causal logits: tokens (batch, t) -> flat
    /// (batch * t * vocab). The native form of the `logits_*` graphs.
    pub fn forward_seq(
        &self,
        tokens: &[i32],
        batch: usize,
        t: usize,
        spec: &GraphSpec,
    ) -> Result<Vec<f32>> {
        self.forward_seq_spec(tokens, batch, t, spec, None)
    }

    /// [`Self::forward_seq`] with optional transform-spec application.
    pub fn forward_seq_spec(
        &self,
        tokens: &[i32],
        batch: usize,
        t: usize,
        spec: &GraphSpec,
        tf: SpecRun,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == batch * t, "tokens len != batch * t");
        spec.validate(&self.dims)?;
        validate_spec_run(&self.dims, tf)?;
        let mut x = self.embed_rows(tokens);
        if let Some(t1) = residual_of(tf) {
            x = t1.forward_rows(&x);
        }
        let lens = vec![t; batch];
        for (li, lw) in self.layers.iter().enumerate() {
            self.block_full(li, lw, &mut x, batch, t, &lens, spec, tf);
        }
        let mut xf = rmsnorm_rows(&x, self.dims.d_model, &self.lnf);
        if let Some(t1) = residual_of(tf) {
            xf = t1.backward_rows(&xf);
        }
        Ok(linear(&xf, &self.head, &self.bhead))
    }

    /// Prefill: tokens (batch, prefill_len) padded, `lens` true prompt
    /// lengths. Returns (last-position logits (batch, vocab), KV planes —
    /// one `(batch, kv_seq, d_model)` buffer per (layer, k/v), k before v).
    pub fn forward_prefill(
        &self,
        tokens: &[i32],
        lens: &[i32],
        batch: usize,
        spec: &GraphSpec,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        self.forward_prefill_spec(tokens, lens, batch, spec, None)
    }

    /// [`Self::forward_prefill`] with optional transform-spec application.
    /// Under a spec the exported V planes hold *transformed* values —
    /// exactly what a folded `wv` would write — so folded and unfolded
    /// executors exchange bit-compatible caches.
    pub fn forward_prefill_spec(
        &self,
        tokens: &[i32],
        lens: &[i32],
        batch: usize,
        spec: &GraphSpec,
        tf: SpecRun,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let dims = &self.dims;
        let (t, d, s_max, v) = (dims.prefill_len, dims.d_model, dims.kv_seq, dims.vocab);
        anyhow::ensure!(tokens.len() == batch * t, "tokens len != batch * prefill_len");
        anyhow::ensure!(lens.len() == batch, "lens len != batch");
        anyhow::ensure!(t <= s_max, "prefill_len {t} exceeds kv_seq {s_max}");
        spec.validate(dims)?;
        validate_spec_run(dims, tf)?;
        let lens_u: Vec<usize> = lens.iter().map(|l| (*l).clamp(0, t as i32) as usize).collect();
        let mut x = self.embed_rows(tokens);
        if let Some(t1) = residual_of(tf) {
            x = t1.forward_rows(&x);
        }
        let mut kv = Vec::with_capacity(self.layers.len() * 2);
        for (li, lw) in self.layers.iter().enumerate() {
            let (k_rows, v_rows) = self.block_full(li, lw, &mut x, batch, t, &lens_u, spec, tf);
            kv.push(export_plane(&k_rows, batch, t, s_max, d));
            kv.push(export_plane(&v_rows, batch, t, s_max, d));
        }
        let mut xf = rmsnorm_rows(&x, d, &self.lnf);
        if let Some(t1) = residual_of(tf) {
            xf = t1.backward_rows(&xf);
        }
        let all = linear(&xf, &self.head, &self.bhead);
        let mut logits = vec![0.0f32; batch * v];
        for b in 0..batch {
            // python: last = clip(len - 1, 0, t - 1)
            let last = lens_u[b].max(1).min(t) - 1;
            logits[b * v..(b + 1) * v]
                .copy_from_slice(&all[(b * t + last) * v..(b * t + last + 1) * v]);
        }
        Ok((logits, kv))
    }

    /// One decode step at per-lane positions over cached KV planes (same
    /// layout as [`Self::forward_prefill`] emits). Returns updated planes.
    pub fn forward_decode(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
        spec: &GraphSpec,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        self.forward_decode_spec(tokens, pos, kv, batch, spec, None)
    }

    /// [`Self::forward_decode`] with optional transform-spec application
    /// (new V rows are scattered into the cache already transformed, see
    /// [`Self::forward_prefill_spec`]).
    pub fn forward_decode_spec(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
        spec: &GraphSpec,
        tf: SpecRun,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let dims = &self.dims;
        let (d, s_max, h) = (dims.d_model, dims.kv_seq, dims.n_heads);
        let dh = dims.head_dim();
        anyhow::ensure!(tokens.len() == batch && pos.len() == batch, "decode batch mismatch");
        anyhow::ensure!(kv.len() == dims.n_layers * 2, "kv plane count mismatch");
        for plane in kv {
            anyhow::ensure!(plane.len() == batch * s_max * d, "kv plane size mismatch");
        }
        spec.validate(dims)?;
        validate_spec_run(dims, tf)?;
        let mut out_kv: Vec<Vec<f32>> = kv.to_vec();
        let mut x = self.embed_rows(tokens);
        if let Some(t1) = residual_of(tf) {
            let tx = t1.forward_rows(&x);
            scratch::give(std::mem::replace(&mut x, tx));
        }
        let scale = 1.0 / (dh as f32).sqrt();
        for (li, lw) in self.layers.iter().enumerate() {
            let (left, right) = out_kv.split_at_mut(2 * li + 1);
            let kc = &mut left[2 * li];
            let vc = &mut right[0];
            let mut hq = rmsnorm_rows(&x, d, &lw.ln1);
            qdq_rows(&mut hq, d, spec);
            let hb = match residual_of(tf) {
                Some(t1) => {
                    let hb = t1.backward_rows(&hq);
                    scratch::give(hq);
                    hb
                }
                None => hq,
            };
            let mut q = linear(&hb, &lw.wq, &lw.bq);
            let mut kn = linear(&hb, &lw.wk, &lw.bk);
            let mut vn = linear(&hb, &lw.wv, &lw.bv);
            scratch::give(hb);
            per_head_forward(&mut vn, d, dh, li, tf);
            apply_rope_rows(&mut q, h, dh, pos);
            apply_rope_rows(&mut kn, h, dh, pos);
            let mut o = scratch::take(batch * d);
            let mut scores = scratch::take(s_max);
            for b in 0..batch {
                let p = pos[b];
                // scatter the new K/V row (one-hot in the graph: an
                // out-of-range position writes nothing)
                if p >= 0 && (p as usize) < s_max {
                    let at = b * s_max * d + (p as usize) * d;
                    kc[at..at + d].copy_from_slice(&kn[b * d..(b + 1) * d]);
                    vc[at..at + d].copy_from_slice(&vn[b * d..(b + 1) * d]);
                }
                for hh in 0..h {
                    let qrow = &q[b * d + hh * dh..b * d + hh * dh + dh];
                    for (s, sc) in scores.iter_mut().enumerate() {
                        *sc = if (s as i32) <= p {
                            let at = b * s_max * d + s * d + hh * dh;
                            dot(qrow, &kc[at..at + dh]) * scale
                        } else {
                            -1e9
                        };
                    }
                    softmax_inplace(&mut scores);
                    let orow = &mut o[b * d + hh * dh..b * d + hh * dh + dh];
                    for (s, w) in scores.iter().enumerate() {
                        let at = b * s_max * d + s * d + hh * dh;
                        axpy(orow, *w, &vc[at..at + dh]);
                    }
                }
            }
            scratch::give(q);
            scratch::give(kn);
            scratch::give(vn);
            scratch::give(scores);
            qdq_rows(&mut o, d, spec);
            per_head_backward(&mut o, d, dh, li, tf);
            let y = linear(&o, &lw.wo, &lw.bo);
            scratch::give(o);
            add_block_output(&mut x, &y, tf);
            scratch::give(y);
            self.ffn(li, lw, &mut x, spec, tf);
        }
        let mut xf = rmsnorm_rows(&x, d, &self.lnf);
        scratch::give(x);
        if let Some(t1) = residual_of(tf) {
            let txf = t1.backward_rows(&xf);
            scratch::give(std::mem::replace(&mut xf, txf));
        }
        let logits = linear(&xf, &self.head, &self.bhead);
        scratch::give(xf);
        Ok((logits, out_kv))
    }

    /// [`Self::forward_decode_spec`] for the paged KV cache: instead of
    /// scattering the fresh K/V row into (and returning) full per-lane
    /// planes, returns just the new `(batch, d_model)` row per plane —
    /// k before v, post-RoPE / post-T2 — for quantize-on-write append.
    ///
    /// Bit-identical to [`Self::forward_decode_spec`]: the fresh row is
    /// read from `kn`/`vn` directly where the dense path reads it back out
    /// of the scattered cache, positions `s > p` score `-1e9` whose
    /// softmax weight underflows to exactly `0.0` (so the `axpy` over
    /// cached rows beyond `p` is a bitwise no-op in both paths), and every
    /// other operation is shared.
    pub fn forward_decode_append_spec(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
        spec: &GraphSpec,
        tf: SpecRun,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let dims = &self.dims;
        let (d, s_max, h) = (dims.d_model, dims.kv_seq, dims.n_heads);
        let dh = dims.head_dim();
        anyhow::ensure!(tokens.len() == batch && pos.len() == batch, "decode batch mismatch");
        anyhow::ensure!(kv.len() == dims.n_layers * 2, "kv plane count mismatch");
        for plane in kv {
            anyhow::ensure!(plane.len() == batch * s_max * d, "kv plane size mismatch");
        }
        spec.validate(dims)?;
        validate_spec_run(dims, tf)?;
        let mut new_rows: Vec<Vec<f32>> = scratch::take_rows(dims.n_layers * 2);
        let mut x = self.embed_rows(tokens);
        if let Some(t1) = residual_of(tf) {
            let tx = t1.forward_rows(&x);
            scratch::give(std::mem::replace(&mut x, tx));
        }
        let scale = 1.0 / (dh as f32).sqrt();
        for (li, lw) in self.layers.iter().enumerate() {
            let kc = &kv[2 * li];
            let vc = &kv[2 * li + 1];
            let mut hq = rmsnorm_rows(&x, d, &lw.ln1);
            qdq_rows(&mut hq, d, spec);
            let hb = match residual_of(tf) {
                Some(t1) => {
                    let hb = t1.backward_rows(&hq);
                    scratch::give(hq);
                    hb
                }
                None => hq,
            };
            let mut q = linear(&hb, &lw.wq, &lw.bq);
            let mut kn = linear(&hb, &lw.wk, &lw.bk);
            let mut vn = linear(&hb, &lw.wv, &lw.bv);
            scratch::give(hb);
            per_head_forward(&mut vn, d, dh, li, tf);
            apply_rope_rows(&mut q, h, dh, pos);
            apply_rope_rows(&mut kn, h, dh, pos);
            let mut o = scratch::take(batch * d);
            let mut scores = scratch::take(s_max);
            for b in 0..batch {
                let p = pos[b];
                for hh in 0..h {
                    let qrow = &q[b * d + hh * dh..b * d + hh * dh + dh];
                    let krow = &kn[b * d + hh * dh..b * d + hh * dh + dh];
                    for (s, sc) in scores.iter_mut().enumerate() {
                        *sc = if (s as i32) < p {
                            let at = b * s_max * d + s * d + hh * dh;
                            dot(qrow, &kc[at..at + dh]) * scale
                        } else if s as i32 == p {
                            dot(qrow, krow) * scale
                        } else {
                            -1e9
                        };
                    }
                    softmax_inplace(&mut scores);
                    let orow = &mut o[b * d + hh * dh..b * d + hh * dh + dh];
                    for (s, w) in scores.iter().enumerate() {
                        if s as i32 == p {
                            axpy(orow, *w, &vn[b * d + hh * dh..b * d + hh * dh + dh]);
                        } else {
                            let at = b * s_max * d + s * d + hh * dh;
                            axpy(orow, *w, &vc[at..at + dh]);
                        }
                    }
                }
            }
            scratch::give(q);
            scratch::give(scores);
            qdq_rows(&mut o, d, spec);
            per_head_backward(&mut o, d, dh, li, tf);
            let y = linear(&o, &lw.wo, &lw.bo);
            scratch::give(o);
            add_block_output(&mut x, &y, tf);
            scratch::give(y);
            self.ffn(li, lw, &mut x, spec, tf);
            new_rows.push(kn);
            new_rows.push(vn);
        }
        let mut xf = rmsnorm_rows(&x, d, &self.lnf);
        scratch::give(x);
        if let Some(t1) = residual_of(tf) {
            let txf = t1.backward_rows(&xf);
            scratch::give(std::mem::replace(&mut xf, txf));
        }
        let logits = linear(&xf, &self.head, &self.bhead);
        scratch::give(xf);
        Ok((logits, new_rows))
    }

    /// [`Self::forward_prefill_spec`] executed under a tensor-parallel
    /// [`ShardPlan`]. Bit-identical for any worker count (the partition
    /// is fixed per-head / per-band; see [`ShardPlan`]); differs from the
    /// unsharded path only in the f32 association of the two row-split
    /// reductions (`wo` summed per head, `wd` summed per `d_ff` band).
    pub fn forward_prefill_shard_spec(
        &self,
        tokens: &[i32],
        lens: &[i32],
        batch: usize,
        spec: &GraphSpec,
        tf: SpecRun,
        plan: &ShardPlan,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let dims = &self.dims;
        let (t, d, s_max, v) = (dims.prefill_len, dims.d_model, dims.kv_seq, dims.vocab);
        anyhow::ensure!(tokens.len() == batch * t, "tokens len != batch * prefill_len");
        anyhow::ensure!(lens.len() == batch, "lens len != batch");
        anyhow::ensure!(t <= s_max, "prefill_len {t} exceeds kv_seq {s_max}");
        spec.validate(dims)?;
        validate_spec_run(dims, tf)?;
        plan.validate(dims)?;
        let lens_u: Vec<usize> = lens.iter().map(|l| (*l).clamp(0, t as i32) as usize).collect();
        let mut x = self.embed_rows(tokens);
        if let Some(t1) = residual_of(tf) {
            x = t1.forward_rows(&x);
        }
        let mut kv = Vec::with_capacity(self.layers.len() * 2);
        for (li, lw) in self.layers.iter().enumerate() {
            let (k_rows, v_rows) =
                self.attn_block_shard(li, lw, &mut x, batch, t, &lens_u, spec, tf, plan);
            self.ffn_shard(li, lw, &mut x, spec, tf, plan);
            kv.push(export_plane(&k_rows, batch, t, s_max, d));
            kv.push(export_plane(&v_rows, batch, t, s_max, d));
        }
        let mut xf = rmsnorm_rows(&x, d, &self.lnf);
        if let Some(t1) = residual_of(tf) {
            xf = t1.backward_rows(&xf);
        }
        let all = linear(&xf, &self.head, &self.bhead);
        let mut logits = vec![0.0f32; batch * v];
        for b in 0..batch {
            let last = lens_u[b].max(1).min(t) - 1;
            logits[b * v..(b + 1) * v]
                .copy_from_slice(&all[(b * t + last) * v..(b * t + last + 1) * v]);
        }
        Ok((logits, kv))
    }

    /// [`Self::forward_decode_append_spec`] executed under a tensor-parallel
    /// [`ShardPlan`]: each head unit computes its own fresh K/V row,
    /// reads its own `hh*dh` slice of the cached planes, and runs its
    /// attention; the `wo` / `wd` row-splits reduce in fixed unit order.
    pub fn forward_decode_append_shard_spec(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
        spec: &GraphSpec,
        tf: SpecRun,
        plan: &ShardPlan,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let dims = &self.dims;
        let (d, s_max, h) = (dims.d_model, dims.kv_seq, dims.n_heads);
        let dh = dims.head_dim();
        anyhow::ensure!(tokens.len() == batch && pos.len() == batch, "decode batch mismatch");
        anyhow::ensure!(kv.len() == dims.n_layers * 2, "kv plane count mismatch");
        for plane in kv {
            anyhow::ensure!(plane.len() == batch * s_max * d, "kv plane size mismatch");
        }
        spec.validate(dims)?;
        validate_spec_run(dims, tf)?;
        plan.validate(dims)?;
        let mut new_rows: Vec<Vec<f32>> = scratch::take_rows(dims.n_layers * 2);
        let mut x = self.embed_rows(tokens);
        if let Some(t1) = residual_of(tf) {
            let tx = t1.forward_rows(&x);
            scratch::give(std::mem::replace(&mut x, tx));
        }
        let scale = 1.0 / (dh as f32).sqrt();
        for (li, lw) in self.layers.iter().enumerate() {
            let kc = &kv[2 * li];
            let vc = &kv[2 * li + 1];
            let mut hq = rmsnorm_rows(&x, d, &lw.ln1);
            qdq_rows(&mut hq, d, spec);
            let hb = match residual_of(tf) {
                Some(t1) => {
                    let hb = t1.backward_rows(&hq);
                    scratch::give(hq);
                    hb
                }
                None => hq,
            };
            let hb = Mat::from_vec(batch, d, hb);
            // stage 1 fork-join: each head owns its fresh K/V row and its
            // dh-slice of the cached planes
            let heads = run_units(plan.workers, h, |hh| {
                let (c0, c1) = (hh * dh, (hh + 1) * dh);
                let mut q = linear_cols(&hb, &lw.wq, &lw.bq, c0, c1);
                let mut kn = linear_cols(&hb, &lw.wk, &lw.bk, c0, c1);
                let mut vn = linear_cols(&hb, &lw.wv, &lw.bv, c0, c1);
                head_seg_forward(&mut vn, dh, li, hh, tf);
                apply_rope_rows(&mut q, 1, dh, pos);
                apply_rope_rows(&mut kn, 1, dh, pos);
                let mut o = scratch::take(batch * dh);
                let mut scores = scratch::take(s_max);
                for b in 0..batch {
                    let p = pos[b];
                    let qrow = &q[b * dh..(b + 1) * dh];
                    let krow = &kn[b * dh..(b + 1) * dh];
                    for (s, sc) in scores.iter_mut().enumerate() {
                        *sc = if (s as i32) < p {
                            let at = b * s_max * d + s * d + c0;
                            dot(qrow, &kc[at..at + dh]) * scale
                        } else if s as i32 == p {
                            dot(qrow, krow) * scale
                        } else {
                            -1e9
                        };
                    }
                    softmax_inplace(&mut scores);
                    let orow = &mut o[b * dh..(b + 1) * dh];
                    for (s, w) in scores.iter().enumerate() {
                        if s as i32 == p {
                            axpy(orow, *w, &vn[b * dh..(b + 1) * dh]);
                        } else {
                            let at = b * s_max * d + s * d + c0;
                            axpy(orow, *w, &vc[at..at + dh]);
                        }
                    }
                }
                scratch::give(q);
                scratch::give(scores);
                (kn, vn, o)
            });
            scratch::give(hb.data);
            // fixed-order assembly into (batch, d) row buffers
            let mut kn = scratch::take(batch * d);
            let mut vn = scratch::take(batch * d);
            let mut o = scratch::take(batch * d);
            for (hh, (kh, vh, oh)) in heads.into_iter().enumerate() {
                scatter_cols(&mut kn, d, &kh, hh * dh, dh);
                scatter_cols(&mut vn, d, &vh, hh * dh, dh);
                scatter_cols(&mut o, d, &oh, hh * dh, dh);
                scratch::give(kh);
                scratch::give(vh);
                scratch::give(oh);
            }
            qdq_rows(&mut o, d, spec);
            per_head_backward(&mut o, d, dh, li, tf);
            let y = self.attn_out_shard(lw, &o, plan);
            scratch::give(o);
            add_block_output(&mut x, &y, tf);
            scratch::give(y);
            self.ffn_shard(li, lw, &mut x, spec, tf, plan);
            new_rows.push(kn);
            new_rows.push(vn);
        }
        let mut xf = rmsnorm_rows(&x, d, &self.lnf);
        scratch::give(x);
        if let Some(t1) = residual_of(tf) {
            let txf = t1.backward_rows(&xf);
            scratch::give(std::mem::replace(&mut xf, txf));
        }
        let logits = linear(&xf, &self.head, &self.bhead);
        scratch::give(xf);
        Ok((logits, new_rows))
    }

    /// [`Self::forward_decode_spec`] under a shard plan: runs the append
    /// variant (bit-identical to full-plane decode by the argument on
    /// [`Self::forward_decode_append_spec`]) and scatters the fresh rows
    /// into copies of the input planes.
    pub fn forward_decode_shard_spec(
        &self,
        tokens: &[i32],
        pos: &[i32],
        kv: &[Vec<f32>],
        batch: usize,
        spec: &GraphSpec,
        tf: SpecRun,
        plan: &ShardPlan,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let (logits, new_rows) =
            self.forward_decode_append_shard_spec(tokens, pos, kv, batch, spec, tf, plan)?;
        let (d, s_max) = (self.dims.d_model, self.dims.kv_seq);
        let mut out_kv = kv.to_vec();
        for (plane, rows) in out_kv.iter_mut().zip(&new_rows) {
            for b in 0..batch {
                let p = pos[b];
                if p >= 0 && (p as usize) < s_max {
                    let at = b * s_max * d + (p as usize) * d;
                    plane[at..at + d].copy_from_slice(&rows[b * d..(b + 1) * d]);
                }
            }
        }
        Ok((logits, out_kv))
    }

    // -- internals ----------------------------------------------------------

    fn embed_rows(&self, tokens: &[i32]) -> Vec<f32> {
        let d = self.dims.d_model;
        let mut x = scratch::take(tokens.len() * d);
        for (i, &tk) in tokens.iter().enumerate() {
            // XLA gather clamps out-of-range indices; mirror that.
            let row = (tk.max(0) as usize).min(self.dims.vocab - 1);
            x[i * d..(i + 1) * d].copy_from_slice(self.embed.row(row));
        }
        x
    }

    /// One block over (batch * t, d) rows with causal + `s < lens[lane]`
    /// masking; returns the RoPE'd (batch * t, d) K and V rows.
    #[allow(clippy::too_many_arguments)]
    fn block_full(
        &self,
        li: usize,
        lw: &LayerWeights<W>,
        x: &mut Vec<f32>,
        batch: usize,
        t: usize,
        lens: &[usize],
        spec: &GraphSpec,
        tf: SpecRun,
    ) -> (Vec<f32>, Vec<f32>) {
        let (k, v) = self.attn_block(li, lw, x, batch, t, lens, spec, tf);
        self.ffn(li, lw, x, spec, tf);
        (k, v)
    }

    /// The attention sub-block (pre-norm attention + residual add), in
    /// place; returns the RoPE'd K and (possibly T2-transformed) V rows.
    #[allow(clippy::too_many_arguments)]
    fn attn_block(
        &self,
        li: usize,
        lw: &LayerWeights<W>,
        x: &mut Vec<f32>,
        batch: usize,
        t: usize,
        lens: &[usize],
        spec: &GraphSpec,
        tf: SpecRun,
    ) -> (Vec<f32>, Vec<f32>) {
        let dims = &self.dims;
        let (d, h) = (dims.d_model, dims.n_heads);
        let dh = dims.head_dim();
        let n = batch * t;
        let mut hq = rmsnorm_rows(x, d, &lw.ln1);
        qdq_rows(&mut hq, d, spec);
        let hb = match residual_of(tf) {
            Some(t1) => {
                let hb = t1.backward_rows(&hq);
                scratch::give(hq);
                hb
            }
            None => hq,
        };
        let mut q = linear(&hb, &lw.wq, &lw.bq);
        let mut k = linear(&hb, &lw.wk, &lw.bk);
        let mut v = linear(&hb, &lw.wv, &lw.bv);
        scratch::give(hb);
        per_head_forward(&mut v, d, dh, li, tf);
        let pos: Vec<i32> = (0..n).map(|i| (i % t) as i32).collect();
        apply_rope_rows(&mut q, h, dh, &pos);
        apply_rope_rows(&mut k, h, dh, &pos);
        let mut o = attention_full(&q, &k, &v, batch, t, lens, h, dh);
        scratch::give(q);
        qdq_rows(&mut o, d, spec);
        per_head_backward(&mut o, d, dh, li, tf);
        let y = linear(&o, &lw.wo, &lw.bo);
        scratch::give(o);
        add_block_output(x, &y, tf);
        scratch::give(y);
        (k, v)
    }

    /// Pre-norm SiLU-gated FFN with optional online T3 Hadamard and
    /// optional `FfnDown` transform, in place.
    fn ffn(
        &self,
        li: usize,
        lw: &LayerWeights<W>,
        x: &mut Vec<f32>,
        spec: &GraphSpec,
        tf: SpecRun,
    ) {
        let mut ff = self.ffn_gate(lw, x, spec, tf);
        let tfd = tf.and_then(|(s, _)| s.ffn_down(li));
        if let Some(tfd) = tfd {
            let tff = tfd.forward_rows(&ff);
            scratch::give(std::mem::replace(&mut ff, tff));
        }
        qdq_rows(&mut ff, self.dims.d_ff, spec);
        // in Folded mode the inverse is baked into wd; the forward above is
        // the online remainder (same split as the fixed T3 Hadamard, whose
        // inverse lives in pre-folded artifact weights)
        if let (Some(tfd), Some((_, TransformMode::Unfolded))) = (tfd, tf) {
            let tff = tfd.backward_rows(&ff);
            scratch::give(std::mem::replace(&mut ff, tff));
        }
        let y = linear(&ff, &lw.wd, &lw.bd);
        scratch::give(ff);
        add_block_output(x, &y, tf);
        scratch::give(y);
    }

    /// The FFN up to (and including) the online T3 Hadamard: the rows an
    /// `FfnDown` transform — and `capture_ffn_input` — operate on.
    fn ffn_gate(
        &self,
        lw: &LayerWeights<W>,
        x: &[f32],
        spec: &GraphSpec,
        tf: SpecRun,
    ) -> Vec<f32> {
        let d = self.dims.d_model;
        let mut hq = rmsnorm_rows(x, d, &lw.ln2);
        qdq_rows(&mut hq, d, spec);
        let hb = match residual_of(tf) {
            Some(t1) => {
                let hb = t1.backward_rows(&hq);
                scratch::give(hq);
                hb
            }
            None => hq,
        };
        let mut ff = linear(&hb, &lw.wg, &lw.bg);
        silu_in_place(&mut ff);
        let up = linear(&hb, &lw.wu, &lw.bu);
        scratch::give(hb);
        for (g, u) in ff.iter_mut().zip(&up) {
            *g *= *u;
        }
        scratch::give(up);
        if let Some(tb) = spec.t3 {
            block_hadamard_apply(&mut ff, tb);
        }
        ff
    }

    // -- sharded internals --------------------------------------------------

    /// [`Self::attn_block`] split over shard workers: one unit per head
    /// (Q/K/V column slices, per-head T2 + RoPE + full-sequence attention),
    /// then the `wo` row-split reduced in fixed head order. The norm / QDQ
    /// / T1 / T2-backward full-row ops run serially between the stages,
    /// exactly as in the unsharded path.
    #[allow(clippy::too_many_arguments)]
    fn attn_block_shard(
        &self,
        li: usize,
        lw: &LayerWeights<W>,
        x: &mut Vec<f32>,
        batch: usize,
        t: usize,
        lens: &[usize],
        spec: &GraphSpec,
        tf: SpecRun,
        plan: &ShardPlan,
    ) -> (Vec<f32>, Vec<f32>) {
        let dims = &self.dims;
        let (d, h) = (dims.d_model, dims.n_heads);
        let dh = dims.head_dim();
        let n = batch * t;
        let mut hq = rmsnorm_rows(x, d, &lw.ln1);
        qdq_rows(&mut hq, d, spec);
        let hb = match residual_of(tf) {
            Some(t1) => {
                let hb = t1.backward_rows(&hq);
                scratch::give(hq);
                hb
            }
            None => hq,
        };
        let hb = Mat::from_vec(n, d, hb);
        let pos: Vec<i32> = (0..n).map(|i| (i % t) as i32).collect();
        // stage 1 fork-join: per-head Q/K/V, T2, RoPE, attention
        let heads = run_units(plan.workers, h, |hh| {
            let (c0, c1) = (hh * dh, (hh + 1) * dh);
            let mut q = linear_cols(&hb, &lw.wq, &lw.bq, c0, c1);
            let mut k = linear_cols(&hb, &lw.wk, &lw.bk, c0, c1);
            let mut v = linear_cols(&hb, &lw.wv, &lw.bv, c0, c1);
            head_seg_forward(&mut v, dh, li, hh, tf);
            apply_rope_rows(&mut q, 1, dh, &pos);
            apply_rope_rows(&mut k, 1, dh, &pos);
            let o = attention_full(&q, &k, &v, batch, t, lens, 1, dh);
            scratch::give(q);
            (k, v, o)
        });
        scratch::give(hb.data);
        let mut k_rows = scratch::take(n * d);
        let mut v_rows = scratch::take(n * d);
        let mut o = scratch::take(n * d);
        for (hh, (kh, vh, oh)) in heads.into_iter().enumerate() {
            scatter_cols(&mut k_rows, d, &kh, hh * dh, dh);
            scatter_cols(&mut v_rows, d, &vh, hh * dh, dh);
            scatter_cols(&mut o, d, &oh, hh * dh, dh);
            scratch::give(kh);
            scratch::give(vh);
            scratch::give(oh);
        }
        qdq_rows(&mut o, d, spec);
        per_head_backward(&mut o, d, dh, li, tf);
        let y = self.attn_out_shard(lw, &o, plan);
        scratch::give(o);
        add_block_output(x, &y, tf);
        scratch::give(y);
        (k_rows, v_rows)
    }

    /// `o @ wo + bo` as a head-partitioned row-split: stage-2 fork-join
    /// computes one `matmul_band` partial per head; the partials are
    /// summed serially in ascending head order, then the bias is added.
    /// One fixed sequence of f32 adds per output element, whatever the
    /// worker count.
    fn attn_out_shard(&self, lw: &LayerWeights<W>, o: &[f32], plan: &ShardPlan) -> Vec<f32> {
        let (d, h) = (self.dims.d_model, self.dims.n_heads);
        let dh = self.dims.head_dim();
        let n = o.len() / d;
        let partials = run_units(plan.workers, h, |hh| {
            let seg = cols_of(o, d, hh * dh, (hh + 1) * dh);
            let p = lw.wo.matmul_band(&seg, hh * dh, (hh + 1) * dh).data;
            scratch::give(seg.data);
            p
        });
        let mut y = scratch::take(n * d);
        for p in partials {
            add_in_place(&mut y, &p);
            scratch::give(p);
        }
        for row in y.chunks_mut(d) {
            for (ov, bb) in row.iter_mut().zip(&lw.bo) {
                *ov += *bb;
            }
        }
        y
    }

    /// [`Self::ffn`] split over shard workers: one unit per
    /// `ffn_block`-wide `d_ff` band (gate/up column slices + SiLU + gate
    /// multiply, then the `wd` row-band partials reduced in fixed band
    /// order). The online T3 Hadamard, FfnDown transform, and QDQ are
    /// full-row ops and run serially between the stages.
    fn ffn_shard(
        &self,
        li: usize,
        lw: &LayerWeights<W>,
        x: &mut Vec<f32>,
        spec: &GraphSpec,
        tf: SpecRun,
        plan: &ShardPlan,
    ) {
        let (d, f) = (self.dims.d_model, self.dims.d_ff);
        let n = x.len() / d;
        let mut hq = rmsnorm_rows(x, d, &lw.ln2);
        qdq_rows(&mut hq, d, spec);
        let hb = match residual_of(tf) {
            Some(t1) => {
                let hb = t1.backward_rows(&hq);
                scratch::give(hq);
                hb
            }
            None => hq,
        };
        let hb = Mat::from_vec(n, d, hb);
        let fb = plan.ffn_block;
        let n_bands = plan.ffn_bands(f);
        let band = |u: usize| (u * fb, ((u + 1) * fb).min(f));
        // stage 1 fork-join: gate/up/SiLU per band
        let bands = run_units(plan.workers, n_bands, |u| {
            let (c0, c1) = band(u);
            let mut g = linear_cols(&hb, &lw.wg, &lw.bg, c0, c1);
            silu_in_place(&mut g);
            let up = linear_cols(&hb, &lw.wu, &lw.bu, c0, c1);
            for (gv, uv) in g.iter_mut().zip(&up) {
                *gv *= *uv;
            }
            scratch::give(up);
            g
        });
        scratch::give(hb.data);
        let mut ff = scratch::take(n * f);
        for (u, bvals) in bands.into_iter().enumerate() {
            let (c0, c1) = band(u);
            scatter_cols(&mut ff, f, &bvals, c0, c1 - c0);
            scratch::give(bvals);
        }
        if let Some(tb) = spec.t3 {
            block_hadamard_apply(&mut ff, tb);
        }
        let tfd = tf.and_then(|(s, _)| s.ffn_down(li));
        if let Some(tfd) = tfd {
            let tx = tfd.forward_rows(&ff);
            scratch::give(std::mem::replace(&mut ff, tx));
        }
        qdq_rows(&mut ff, f, spec);
        if let (Some(tfd), Some((_, TransformMode::Unfolded))) = (tfd, tf) {
            let tx = tfd.backward_rows(&ff);
            scratch::give(std::mem::replace(&mut ff, tx));
        }
        // stage 2 fork-join: wd row bands, fixed ascending-band reduction
        let partials = run_units(plan.workers, n_bands, |u| {
            let (r0, r1) = band(u);
            let seg = cols_of(&ff, f, r0, r1);
            let p = lw.wd.matmul_band(&seg, r0, r1).data;
            scratch::give(seg.data);
            p
        });
        scratch::give(ff);
        let mut y = scratch::take(n * d);
        for p in partials {
            add_in_place(&mut y, &p);
            scratch::give(p);
        }
        for row in y.chunks_mut(d) {
            for (ov, bb) in row.iter_mut().zip(&lw.bd) {
                *ov += *bb;
            }
        }
        add_block_output(x, &y, tf);
        scratch::give(y);
    }
}

// -- free helpers -----------------------------------------------------------

/// Causal multi-head attention over flat (batch * t, n_heads * dh) q/k/v
/// rows (lane `b` owns rows `b*t..(b+1)*t`); key positions `s` attend iff
/// `s <= tq && s < lens[b]`. Returns the (batch * t, d) output rows.
#[allow(clippy::too_many_arguments)]
fn attention_full(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    batch: usize,
    t: usize,
    lens: &[usize],
    h: usize,
    dh: usize,
) -> Vec<f32> {
    let d = h * dh;
    let n = batch * t;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut o = scratch::take(n * d);
    let mut scores = scratch::take(t);
    for b in 0..batch {
        let len = lens[b];
        let base = b * t * d;
        for hh in 0..h {
            for tq in 0..t {
                let qrow = &q[base + tq * d + hh * dh..base + tq * d + hh * dh + dh];
                for (s, sc) in scores.iter_mut().enumerate() {
                    *sc = if s <= tq && s < len {
                        let at = base + s * d + hh * dh;
                        dot(qrow, &k[at..at + dh]) * scale
                    } else {
                        -1e9
                    };
                }
                softmax_inplace(&mut scores);
                let orow = &mut o[base + tq * d + hh * dh..base + tq * d + hh * dh + dh];
                for (s, w) in scores.iter().enumerate() {
                    let at = base + s * d + hh * dh;
                    axpy(orow, *w, &v[at..at + dh]);
                }
            }
        }
    }
    scratch::give(scores);
    o
}

/// The residual (T1) transform of a spec run, when present.
fn residual_of<'a>(tf: SpecRun<'a>) -> Option<&'a Affine> {
    tf.and_then(|(s, _)| s.residual())
}

/// Reject dimension/range-invalid specs, and non-online sites in
/// [`TransformMode::Folded`] runs (their inverses must already be folded —
/// applying them again would silently double-transform).
fn validate_spec_run(dims: &NativeDims, tf: SpecRun) -> Result<()> {
    let Some((s, mode)) = tf else { return Ok(()) };
    s.validate(dims)?;
    if mode == TransformMode::Folded {
        anyhow::ensure!(
            s.online_only(),
            "folded-mode spec must contain online sites only, got [{}]",
            s.site_list()
        );
    }
    Ok(())
}

/// Apply each present per-head T2 *forward* (`v' = v A2 + v2`) to its head
/// segment of every (n, d) row, in place.
fn per_head_forward(rows: &mut [f32], d: usize, dh: usize, layer: usize, tf: SpecRun) {
    let Some((spec, _)) = tf else { return };
    for head in 0..d / dh {
        let Some(t2) = spec.per_head(layer, head) else { continue };
        let (c0, c1) = (head * dh, (head + 1) * dh);
        for row in rows.chunks_mut(d) {
            let seg = t2.a.apply_affine(&row[c0..c1], Some(&t2.v));
            row[c0..c1].copy_from_slice(&seg);
        }
    }
}

/// Fan `n_units` fixed work units out over `workers` fork-join shard
/// workers and return the per-unit results in unit order. Ownership
/// mirrors `par::for_each_chunk`'s partition — worker `w` owns the
/// contiguous run `[w*per, (w+1)*per)`, `per = ceil(n_units / workers)` —
/// so a result depends only on its unit index, never on which worker
/// computed it or how many workers there were.
fn run_units<R: Send>(workers: usize, n_units: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let workers = workers.clamp(1, n_units.max(1));
    let per = (n_units + workers - 1) / workers;
    let chunks = par::run_workers(workers, |w| {
        let lo = (w * per).min(n_units);
        let hi = ((w + 1) * per).min(n_units);
        (lo..hi).map(&f).collect::<Vec<R>>()
    });
    let mut units = Vec::with_capacity(n_units);
    for c in chunks {
        units.extend(c);
    }
    units
}

/// Copy the `[c0, c1)` column slice of flat `(n, d)` rows into its own
/// `(n, c1-c0)` matrix — the input shape `matmul_band` wants.
fn cols_of(rows: &[f32], d: usize, c0: usize, c1: usize) -> Mat {
    let n = rows.len() / d;
    let w = c1 - c0;
    let mut out = Mat { rows: n, cols: w, data: scratch::take(n * w) };
    for i in 0..n {
        out.data[i * w..(i + 1) * w].copy_from_slice(&rows[i * d + c0..i * d + c1]);
    }
    out
}

/// Scatter `(n, w)` unit rows into columns `[c0, c0+w)` of flat `(n, d)`
/// rows — the fixed-order assembly step after a fork-join stage.
fn scatter_cols(dst: &mut [f32], d: usize, src: &[f32], c0: usize, w: usize) {
    for (i, srow) in src.chunks(w).enumerate() {
        dst[i * d + c0..i * d + c0 + w].copy_from_slice(srow);
    }
}

/// Columns `[c0, c1)` of `linear(x, w, b)`: column-sliced GEMM plus the
/// matching bias slice. Bit-identical to slicing `linear`'s output —
/// per-column work never crosses the slice boundary.
fn linear_cols<W: WeightMatrix>(x: &Mat, w: &W, b: &[f32], c0: usize, c1: usize) -> Vec<f32> {
    let nc = c1 - c0;
    let mut out = w.matmul_cols(x, c0, c1).data;
    for row in out.chunks_mut(nc) {
        for (o, bb) in row.iter_mut().zip(&b[c0..c1]) {
            *o += *bb;
        }
    }
    out
}

/// [`per_head_forward`] for a single head's own `(n, dh)` segment buffer —
/// the shard-worker form. Applies the same `apply_affine` to the same
/// slice values, so the transformed rows are bit-identical.
fn head_seg_forward(rows: &mut [f32], dh: usize, layer: usize, head: usize, tf: SpecRun) {
    let Some((spec, _)) = tf else { return };
    let Some(t2) = spec.per_head(layer, head) else { return };
    for row in rows.chunks_mut(dh) {
        let seg = t2.a.apply_affine(row, Some(&t2.v));
        row.copy_from_slice(&seg);
    }
}

/// Apply each present per-head T2 *backward* (`o = (o' - v2) A2^-1`) to its
/// head segment of every (n, d) row, in place.
fn per_head_backward(rows: &mut [f32], d: usize, dh: usize, layer: usize, tf: SpecRun) {
    let Some((spec, _)) = tf else { return };
    for head in 0..d / dh {
        let Some(t2) = spec.per_head(layer, head) else { continue };
        let (c0, c1) = (head * dh, (head + 1) * dh);
        for row in rows.chunks_mut(d) {
            let seg = t2.backward_rows(&row[c0..c1]);
            row[c0..c1].copy_from_slice(&seg);
        }
    }
}

/// Add a block output into the residual stream — through the T1 `A`-part
/// when a residual transform is in play (the stream lives in transformed
/// coordinates; `v1` entered once, at the embedding).
fn add_block_output(x: &mut [f32], y: &[f32], tf: SpecRun) {
    match residual_of(tf) {
        Some(t1) => add_in_place(x, &t1.linear_rows(y)),
        None => add_in_place(x, y),
    }
}

fn rmsnorm_rows(x: &[f32], d: usize, g: &[f32]) -> Vec<f32> {
    let mut out = scratch::take(x.len());
    for (row_in, row_out) in x.chunks(d).zip(out.chunks_mut(d)) {
        let ms = row_in.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + EPS).sqrt();
        for ((o, v), gg) in row_out.iter_mut().zip(row_in).zip(g) {
            *o = v * r * gg;
        }
    }
    out
}

/// `x @ w + b` for row-major `x` with `x.len() / w.in_dim()` rows.
/// Generic over the weight storage: a dense [`Mat`] runs `Mat::matmul`, a
/// [`PackedMat`] runs the fused `linalg::packed_matmul` LUT kernel on the
/// packed bytes directly — the serving hot path's single dispatch point.
/// The output is checked out of the `util::scratch` arena (no input copy,
/// no fresh allocation in steady state); callers on the decode hot path
/// `scratch::give` it back once dead.
fn linear<W: WeightMatrix>(x: &[f32], w: &W, b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len() % w.in_dim(), 0);
    let n = x.len() / w.in_dim();
    let mut out = scratch::take(n * w.out_dim());
    w.matmul_pre_into(x, n, &mut out);
    for row in out.chunks_mut(w.out_dim()) {
        for (o, bb) in row.iter_mut().zip(b) {
            *o += *bb;
        }
    }
    out
}

fn qdq_rows(x: &mut [f32], row_len: usize, spec: &GraphSpec) {
    if let Some(cfg) = &spec.act {
        mx_qdq_rows(x, row_len, cfg);
    }
}

/// RoPE over head-major rows: `x` is (n, n_heads * dh), `pos` gives the
/// sequence position of each row. Pairs (even, odd) rotate exactly as
/// python `apply_rope`.
fn apply_rope_rows(x: &mut [f32], n_heads: usize, dh: usize, pos: &[i32]) {
    let half = dh / 2;
    let d = n_heads * dh;
    // position-independent inverse frequencies, hoisted out of the row loop
    let mut inv = scratch::take(half);
    for (i, v) in inv.iter_mut().enumerate() {
        *v = 1.0 / ROPE_THETA.powf((2 * i) as f32 / dh as f32);
    }
    for (row, &p) in x.chunks_mut(d).zip(pos) {
        for (i, &invf) in inv.iter().enumerate() {
            let ang = p as f32 * invf;
            let (sin, cos) = ang.sin_cos();
            for hh in 0..n_heads {
                let at = hh * dh + 2 * i;
                let x1 = row[at];
                let x2 = row[at + 1];
                row[at] = x1 * cos - x2 * sin;
                row[at + 1] = x1 * sin + x2 * cos;
            }
        }
    }
    scratch::give(inv);
}

fn softmax_inplace(s: &mut [f32]) {
    let m = s.iter().fold(f32::NEG_INFINITY, |a, b| a.max(*b));
    let mut z = 0.0f32;
    for v in s.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    for v in s.iter_mut() {
        *v *= inv;
    }
}

fn silu_in_place(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v /= 1.0 + (-*v).exp();
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    for (o, v) in out.iter_mut().zip(x) {
        *o += a * v;
    }
}

fn add_in_place(x: &mut [f32], y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (a, b) in x.iter_mut().zip(y) {
        *a += *b;
    }
}

/// Copy per-lane (t, d) K/V rows into a zero-padded (batch, s_max, d) plane.
fn export_plane(rows: &[f32], batch: usize, t: usize, s_max: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * s_max * d];
    for b in 0..batch {
        out[b * s_max * d..b * s_max * d + t * d]
            .copy_from_slice(&rows[b * t * d..(b + 1) * t * d]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NativeDims {
        NativeDims {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            kv_seq: 24,
            prefill_len: 8,
        }
    }

    fn quantizable() -> NativeDims {
        NativeDims {
            vocab: 32,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            kv_seq: 24,
            prefill_len: 8,
        }
    }

    fn argmax(v: &[f32]) -> i32 {
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, x) in v.iter().enumerate() {
            if *x > bv {
                bv = *x;
                best = i;
            }
        }
        best as i32
    }

    #[test]
    fn spec_parse() {
        let fp = GraphSpec::from_tag("fp").unwrap();
        assert!(fp.act.is_none() && fp.t3.is_none());
        let q = GraphSpec::from_tag("mxfp4_b32_t3").unwrap();
        let cfg = q.act.unwrap();
        assert_eq!(cfg.name, "mxfp4");
        assert_eq!(cfg.block_size, 32);
        assert_eq!(q.t3, Some(32));
        let nv = GraphSpec::from_tag("nvfp4_b16").unwrap();
        assert!(nv.act.unwrap().nv && nv.t3.is_none());
        assert!(GraphSpec::from_tag("bogus").is_err());
        assert!(GraphSpec::from_tag("mxfp4_bXX").is_err());
        let g = GraphSpec::from_graph_name("logits_ppl_mxfp4_b32").unwrap();
        assert_eq!(g.act.unwrap().block_size, 32);
        assert!(GraphSpec::from_graph_name("decode_fp_b1").is_err());
    }

    #[test]
    fn spec_validate_blocks() {
        let spec = GraphSpec::from_tag("mxfp4_b32").unwrap();
        assert!(spec.validate(&quantizable()).is_ok());
        // d_model 16 is not tiled by block 32
        assert!(spec.validate(&tiny()).is_err());
        assert!(GraphSpec::fp().validate(&tiny()).is_ok());
    }

    #[test]
    fn weight_set_roundtrip() {
        let w = NativeWeights::synthetic(tiny(), 11);
        let (order, ws) = w.to_weight_set("fp_test");
        assert_eq!(order.len(), 1 + 16 * 2 + 3);
        let back = NativeWeights::from_weight_set(tiny(), &order, &ws).unwrap();
        assert_eq!(w, back);
    }

    #[test]
    fn packed_weights_forward_parity() {
        let dims = quantizable();
        let w = NativeWeights::synthetic(dims, 41);
        let g = GraphSpec::from_tag("mxfp4_b32").unwrap();
        let packed = w.pack_weights(g.act.unwrap()).unwrap();
        assert!(
            packed.weight_bytes() < w.weight_bytes(),
            "{} !< {}",
            packed.weight_bytes(),
            w.weight_bytes()
        );
        let dq = packed.unpack_weights();
        let toks: Vec<i32> = (0..6).collect();
        let a = packed.forward_seq(&toks, 1, 6, &g).unwrap();
        let b = dq.forward_seq(&toks, 1, 6, &g).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "fused vs dequantized idx {i}");
        }
        // packing IS weight quantization: the fp-weight model must differ
        let raw = w.forward_seq(&toks, 1, 6, &g).unwrap();
        assert_ne!(a, raw, "packing the weights must change the function");
        // blocks that do not tile a weight width are rejected
        let w16 = NativeWeights::synthetic(tiny(), 41);
        assert!(w16.pack_weights(g.act.unwrap()).is_err(), "d_model 16 vs block 32");
    }

    #[test]
    fn prefill_ignores_padding() {
        let w = NativeWeights::synthetic(tiny(), 3);
        let spec = GraphSpec::fp();
        let t = tiny().prefill_len;
        let mut a = vec![0i32; 2 * t];
        a[..4].copy_from_slice(&[1, 5, 9, 2]);
        a[t..t + 3].copy_from_slice(&[7, 7, 7]);
        let mut b = a.clone();
        // scribble over the padding region of both lanes
        for x in b[4..t].iter_mut() {
            *x = 31;
        }
        for x in b[t + 3..].iter_mut() {
            *x = 13;
        }
        let lens = [4i32, 3];
        let (la, _) = w.forward_prefill(&a, &lens, 2, &spec).unwrap();
        let (lb, _) = w.forward_prefill(&b, &lens, 2, &spec).unwrap();
        assert_eq!(la, lb, "padding tokens leaked into last-position logits");
    }

    #[test]
    fn prefill_decode_matches_forward_seq() {
        // Greedy continuation through the KV path must match argmax
        // chaining on full-sequence logits — the native mirror of the
        // artifact-gated `decode_matches_logits_graph` integration test.
        let dims = tiny();
        let w = NativeWeights::synthetic(dims, 21);
        let spec = GraphSpec::fp();
        let prompt = [1i32, 4, 9, 2];
        let t = dims.prefill_len;
        let v = dims.vocab;

        // KV path
        let mut tokens = vec![0i32; t];
        tokens[..prompt.len()].copy_from_slice(&prompt);
        let (logits, mut kv) = w
            .forward_prefill(&tokens, &[prompt.len() as i32], 1, &spec)
            .unwrap();
        let mut via_kv = vec![argmax(&logits)];
        let mut pos = prompt.len() as i32;
        for _ in 0..3 {
            let (lg, kv2) = w
                .forward_decode(&[*via_kv.last().unwrap()], &[pos], &kv, 1, &spec)
                .unwrap();
            via_kv.push(argmax(&lg));
            kv = kv2;
            pos += 1;
        }

        // full-sequence reference
        let mut seq: Vec<i32> = prompt.to_vec();
        let mut via_seq = Vec::new();
        for _ in 0..4 {
            let n = seq.len();
            let lg = w.forward_seq(&seq, 1, n, &spec).unwrap();
            let next = argmax(&lg[(n - 1) * v..n * v]);
            via_seq.push(next);
            seq.push(next);
        }
        assert_eq!(via_kv, via_seq, "KV decode path diverges from full-seq path");
    }

    #[test]
    fn capture_residual_layers() {
        let dims = tiny();
        let w = NativeWeights::synthetic(dims, 13);
        let spec = GraphSpec::fp();
        let toks: Vec<i32> = (0..8).collect();
        // layer 0 is exactly the embedding rows
        let l0 = w.capture_residual(&toks, 2, 4, &spec, 0).unwrap();
        assert_eq!(l0.len(), 8 * dims.d_model);
        for (i, &tk) in toks.iter().enumerate() {
            let d = dims.d_model;
            assert_eq!(&l0[i * d..(i + 1) * d], w.embed.row(tk as usize));
        }
        // deeper captures change and stay finite
        let l1 = w.capture_residual(&toks, 2, 4, &spec, 1).unwrap();
        let l2 = w.capture_residual(&toks, 2, 4, &spec, dims.n_layers).unwrap();
        assert_ne!(l0, l1);
        assert_ne!(l1, l2);
        assert!(l2.iter().all(|v| v.is_finite()));
        // out of range rejected
        assert!(w.capture_residual(&toks, 2, 4, &spec, dims.n_layers + 1).is_err());
    }

    fn head_spec(dims: &NativeDims, seed: u64) -> TransformSpec {
        use crate::linalg::random_orthogonal;
        let mut rng = Pcg64::seed(seed);
        let dh = dims.head_dim();
        let mut spec = TransformSpec::new();
        let site = |d: usize, rng: &mut Pcg64| {
            let mut a = random_orthogonal(d, rng);
            for e in a.data.iter_mut() {
                *e += 0.02 * rng.normal();
            }
            Affine::new(a, rng.normal_vec(d, 0.05)).unwrap()
        };
        spec.insert(crate::transform::TransformSite::Residual, site(dims.d_model, &mut rng));
        spec.insert(
            crate::transform::TransformSite::PerHeadValue { layer: 0, head: 0 },
            site(dh, &mut rng),
        );
        spec.insert(
            crate::transform::TransformSite::FfnDown { layer: 1 },
            site(dims.d_ff, &mut rng),
        );
        spec
    }

    #[test]
    fn capture_head_values_shapes_and_range() {
        let dims = tiny();
        let w = NativeWeights::synthetic(dims, 19);
        let spec = GraphSpec::fp();
        let toks: Vec<i32> = (0..8).collect();
        let heads = w.capture_head_values(&toks, 2, 4, &spec, 1).unwrap();
        assert_eq!(heads.len(), dims.n_heads);
        for h in &heads {
            assert_eq!(h.len(), 8 * dims.head_dim());
            assert!(h.iter().all(|v| v.is_finite()));
        }
        assert_ne!(heads[0], heads[1], "distinct heads must produce distinct features");
        assert!(w.capture_head_values(&toks, 2, 4, &spec, dims.n_layers).is_err());
    }

    #[test]
    fn capture_ffn_input_respects_t3() {
        let dims = quantizable();
        let w = NativeWeights::synthetic(dims, 23);
        let toks: Vec<i32> = (0..6).collect();
        let plain = w.capture_ffn_input(&toks, 1, 6, &GraphSpec::fp(), 0).unwrap();
        assert_eq!(plain.len(), 6 * dims.d_ff);
        let t3 = GraphSpec { act: None, t3: Some(GraphSpec::T3_BLOCK) };
        let rotated = w.capture_ffn_input(&toks, 1, 6, &t3, 0).unwrap();
        assert_ne!(plain, rotated, "T3 must rotate the captured down-proj input");
        assert!(w.capture_ffn_input(&toks, 1, 6, &GraphSpec::fp(), dims.n_layers).is_err());
    }

    #[test]
    fn unfolded_t2_ffn_cancel_in_fp_but_t1_does_not() {
        // T2 and FfnDown have no nonlinearity between their forward and
        // inverse applications, so in full precision they cancel exactly:
        // the unfolded run computes the base model's function up to f32
        // association error. T1 is different by design: RMSNorm does not
        // commute with a non-orthogonal, biased affine
        // (rmsnorm(xA1 + v1) != rmsnorm(x)A1 + v1), so a Residual site
        // defines a *transformed model* — equivalent to the base only in
        // the orthogonal zero-bias case. What the pipeline guarantees for
        // T1 is folded == unfolded (spec_pipeline.rs), not == base.
        let dims = quantizable();
        let w = NativeWeights::synthetic(dims, 29);
        let full = head_spec(&dims, 3);
        let mut no_t1 = TransformSpec::new();
        for (site, t) in full.iter() {
            if *site != crate::transform::TransformSite::Residual {
                no_t1.insert(*site, t.clone());
            }
        }
        assert_eq!(no_t1.len(), 2);
        let toks: Vec<i32> = (0..6).collect();
        let base = w.forward_seq(&toks, 1, 6, &GraphSpec::fp()).unwrap();
        let tf = w
            .forward_seq_spec(
                &toks,
                1,
                6,
                &GraphSpec::fp(),
                Some((&no_t1, TransformMode::Unfolded)),
            )
            .unwrap();
        for (a, b) in base.iter().zip(&tf) {
            assert!((a - b).abs() < 1e-3, "fp T2/FfnDown run must cancel: {a} vs {b}");
        }
        // and the T1-bearing spec must NOT silently equal the base model —
        // if it did, the transform would be a no-op and folding pointless
        let with_t1 = w
            .forward_seq_spec(&toks, 1, 6, &GraphSpec::fp(), Some((&full, TransformMode::Unfolded)))
            .unwrap();
        let max: f32 =
            base.iter().zip(&with_t1).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(max > 1e-3, "a biased non-orthogonal T1 must change the fp function ({max})");
    }

    #[test]
    fn unfolded_spec_changes_quantized_logits() {
        // Under activation QDQ the transforms reshape what the quantizer
        // sees — the spec path must be live, not a silent no-op.
        let dims = quantizable();
        let w = NativeWeights::synthetic(dims, 31);
        let spec = head_spec(&dims, 5);
        let g = GraphSpec::from_tag("mxfp4_b32").unwrap();
        let toks: Vec<i32> = (0..6).collect();
        let base = w.forward_seq(&toks, 1, 6, &g).unwrap();
        let tf = w
            .forward_seq_spec(&toks, 1, 6, &g, Some((&spec, TransformMode::Unfolded)))
            .unwrap();
        assert_ne!(base, tf, "spec application had no effect under QDQ");
        assert!(tf.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn folded_mode_rejects_non_online_sites() {
        let dims = quantizable();
        let w = NativeWeights::synthetic(dims, 37);
        let spec = head_spec(&dims, 7); // contains Residual + PerHeadValue
        let toks: Vec<i32> = (0..6).collect();
        let err = w
            .forward_seq_spec(&toks, 1, 6, &GraphSpec::fp(), Some((&spec, TransformMode::Folded)))
            .unwrap_err();
        assert!(err.to_string().contains("online"), "{err}");
    }

    #[test]
    fn quant_spec_changes_logits() {
        // The activation-QDQ and T3 paths must actually be live.
        let dims = quantizable();
        let w = NativeWeights::synthetic(dims, 5);
        let toks: Vec<i32> = (0..6).collect();
        let fp = w.forward_seq(&toks, 1, 6, &GraphSpec::fp()).unwrap();
        let q = w
            .forward_seq(&toks, 1, 6, &GraphSpec::from_tag("mxfp4_b32").unwrap())
            .unwrap();
        let qt3 = w
            .forward_seq(&toks, 1, 6, &GraphSpec::from_tag("mxfp4_b32_t3").unwrap())
            .unwrap();
        assert_ne!(fp, q, "activation QDQ had no effect");
        assert_ne!(q, qt3, "online T3 Hadamard had no effect");
        for x in fp.iter().chain(&q).chain(&qt3) {
            assert!(x.is_finite());
        }
    }

    #[test]
    fn decode_scatters_at_position() {
        let dims = tiny();
        let w = NativeWeights::synthetic(dims, 8);
        let spec = GraphSpec::fp();
        let d = dims.d_model;
        let plane = dims.kv_seq * d;
        let kv: Vec<Vec<f32>> = vec![vec![0.0; plane]; dims.n_layers * 2];
        let (_, kv2) = w.forward_decode(&[3], &[5], &kv, 1, &spec).unwrap();
        // position 5 must now hold a nonzero K row in layer 0, others stay 0
        let krow = &kv2[0][5 * d..6 * d];
        assert!(krow.iter().any(|x| *x != 0.0));
        assert!(kv2[0][..5 * d].iter().all(|x| *x == 0.0));
        assert!(kv2[0][6 * d..].iter().all(|x| *x == 0.0));
    }

    #[test]
    fn shard_plan_validation() {
        let dims = tiny(); // n_heads = 2
        assert!(ShardPlan::new(1, &dims).is_ok());
        assert!(ShardPlan::new(2, &dims).is_ok());
        let zero = ShardPlan::new(0, &dims).unwrap_err();
        assert!(zero.to_string().contains("at least 1 worker"), "{zero}");
        let over = ShardPlan::new(3, &dims).unwrap_err();
        assert!(over.to_string().contains("exceeds n_heads"), "{over}");
        assert_eq!(ShardPlan::default_ffn_block(384), 48);
        assert_eq!(ShardPlan::default_ffn_block(3), 1);
    }

    #[test]
    fn run_units_order_is_worker_count_invariant() {
        for workers in [1usize, 2, 3, 4, 7] {
            assert_eq!(run_units(workers, 7, |u| u * u), vec![0, 1, 4, 9, 16, 25, 36]);
        }
        assert_eq!(run_units(3, 0, |u| u), Vec::<usize>::new());
    }

    /// Greedy-decode `steps` tokens through the sharded prefill/decode
    /// path and return (tokens, every logits vector bit-cast to u32).
    fn shard_run(
        w: &NativeWeights,
        spec: &GraphSpec,
        tf: SpecRun,
        plan: &ShardPlan,
        steps: usize,
    ) -> (Vec<i32>, Vec<Vec<u32>>) {
        let dims = &w.dims;
        let t = dims.prefill_len;
        let prompt = [1i32, 4, 9, 2];
        let mut tokens = vec![0i32; t];
        tokens[..prompt.len()].copy_from_slice(&prompt);
        let (logits, mut kv) = w
            .forward_prefill_shard_spec(&tokens, &[prompt.len() as i32], 1, spec, tf, plan)
            .unwrap();
        let mut bits = vec![logits.iter().map(|x| x.to_bits()).collect::<Vec<u32>>()];
        let mut out = vec![argmax(&logits)];
        let mut pos = prompt.len() as i32;
        for _ in 0..steps {
            let (lg, kv2) = w
                .forward_decode_shard_spec(&[*out.last().unwrap()], &[pos], &kv, 1, spec, tf, plan)
                .unwrap();
            bits.push(lg.iter().map(|x| x.to_bits()).collect());
            out.push(argmax(&lg));
            kv = kv2;
            pos += 1;
        }
        (out, bits)
    }

    #[test]
    fn sharded_forward_bit_identical_across_worker_counts() {
        // 1-vs-2 workers on tiny (n_heads = 2), fp and quantized+T3 specs
        for (dims, tag) in [(tiny(), "fp"), (quantizable(), "mxfp4_b32_t3")] {
            let w = NativeWeights::synthetic(dims, 77);
            let spec = GraphSpec::from_tag(tag).unwrap();
            let p1 = ShardPlan::new(1, &dims).unwrap();
            let p2 = ShardPlan::new(2, &dims).unwrap();
            let (t1, b1) = shard_run(&w, &spec, None, &p1, 4);
            let (t2, b2) = shard_run(&w, &spec, None, &p2, 4);
            assert_eq!(t1, t2, "{tag}: token streams differ across worker counts");
            assert_eq!(b1, b2, "{tag}: logits bits differ across worker counts");
        }
    }

    #[test]
    fn sharded_ragged_head_count_bit_identical() {
        // n_heads = 3 with workers = 2: worker 0 owns heads {0,1},
        // worker 1 owns {2} — the ragged ownership split must not matter
        let dims = NativeDims {
            vocab: 32,
            d_model: 24,
            n_layers: 2,
            n_heads: 3,
            d_ff: 36,
            kv_seq: 24,
            prefill_len: 8,
        };
        let w = NativeWeights::synthetic(dims, 91);
        let spec = GraphSpec::fp();
        // ffn_block 5 over d_ff 36: 8 bands, last band ragged (width 1)
        let mk = |workers| ShardPlan { workers, ffn_block: 5 };
        let (t1, b1) = shard_run(&w, &spec, None, &mk(1), 4);
        let (t2, b2) = shard_run(&w, &spec, None, &mk(2), 4);
        let (t3, b3) = shard_run(&w, &spec, None, &mk(3), 4);
        assert_eq!(t1, t2);
        assert_eq!(t1, t3);
        assert_eq!(b1, b2);
        assert_eq!(b1, b3);
    }

    #[test]
    fn sharded_tracks_unsharded_within_association_error() {
        // the sharded path reassociates the two row-split reductions, so
        // it is NOT bit-equal to the legacy path — but it must stay within
        // f32 association error and produce the same greedy tokens here
        let dims = tiny();
        let w = NativeWeights::synthetic(dims, 55);
        let spec = GraphSpec::fp();
        let plan = ShardPlan::new(2, &dims).unwrap();
        let t = dims.prefill_len;
        let prompt = [1i32, 4, 9, 2];
        let mut tokens = vec![0i32; t];
        tokens[..prompt.len()].copy_from_slice(&prompt);
        let (legacy, _) = w.forward_prefill(&tokens, &[prompt.len() as i32], 1, &spec).unwrap();
        let (sharded, _) = w
            .forward_prefill_shard_spec(&tokens, &[prompt.len() as i32], 1, &spec, None, &plan)
            .unwrap();
        let max = legacy
            .iter()
            .zip(&sharded)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max < 1e-3, "sharded drifted past association error: {max}");
        assert_eq!(argmax(&legacy), argmax(&sharded));
    }

    #[test]
    fn sharded_decode_append_matches_full_plane_bitwise() {
        let dims = tiny();
        let w = NativeWeights::synthetic(dims, 66);
        let spec = GraphSpec::fp();
        let plan = ShardPlan::new(2, &dims).unwrap();
        let t = dims.prefill_len;
        let toks: Vec<i32> = (0..t as i32).collect();
        let (_, kv) = w
            .forward_prefill_shard_spec(&toks, &[t as i32], 1, &spec, None, &plan)
            .unwrap();
        let (lg_full, kv_full) = w
            .forward_decode_shard_spec(&[3], &[t as i32], &kv, 1, &spec, None, &plan)
            .unwrap();
        let (lg_app, rows) = w
            .forward_decode_append_shard_spec(&[3], &[t as i32], &kv, 1, &spec, None, &plan)
            .unwrap();
        assert_eq!(lg_full, lg_app);
        let d = dims.d_model;
        for (plane, row) in kv_full.iter().zip(&rows) {
            assert_eq!(&plane[t * d..(t + 1) * d], &row[..]);
        }
    }
}
