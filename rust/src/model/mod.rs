//! Model descriptor + weight-set handling: the Rust view of the AOT
//! artifacts. A [`ModelDesc`] is parsed from `artifacts/manifest.txt`; a
//! [`WeightSet`] is one `.lxt` file reordered into the canonical
//! argument order shared with `python/compile/aot.py`.

pub mod forward;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use forward::{GraphSpec, LayerWeights, NativeDims, NativeWeights};

use crate::io::{load_lxt, Manifest, Tensor};

/// Static model + artifact dimensions (mirror of python `ModelConfig` plus
/// the AOT shapes).
#[derive(Clone, Debug)]
pub struct ModelDesc {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub kv_seq: usize,
    pub prefill_len: usize,
    pub ppl_shape: (usize, usize),
    pub score_shape: (usize, usize),
    pub weight_order: Vec<String>,
    pub graphs: Vec<String>,
    pub artifacts: PathBuf,
}

impl ModelDesc {
    pub fn load(artifacts: &Path) -> Result<ModelDesc> {
        let m = Manifest::load(&artifacts.join("manifest.txt"))?;
        let shape = |key: &str| -> Result<(usize, usize)> {
            let raw = m
                .values
                .get(key)
                .with_context(|| format!("manifest missing {key}"))?;
            let (a, b) = raw.split_once('x').context("bad shape")?;
            Ok((a.parse()?, b.parse()?))
        };
        Ok(ModelDesc {
            vocab: m.int("model.vocab")?,
            d_model: m.int("model.d_model")?,
            n_layers: m.int("model.n_layers")?,
            n_heads: m.int("model.n_heads")?,
            d_ff: m.int("model.d_ff")?,
            kv_seq: m.int("kv_seq")?,
            prefill_len: m.int("prefill_len")?,
            ppl_shape: shape("ppl_shape")?,
            score_shape: shape("score_shape")?,
            weight_order: m.weight_order.clone(),
            graphs: m.graphs.clone(),
            artifacts: artifacts.to_path_buf(),
        })
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn graph_path(&self, name: &str) -> PathBuf {
        self.artifacts.join("graphs").join(format!("{name}.hlo.txt"))
    }

    pub fn weights_path(&self, tag: &str) -> PathBuf {
        self.artifacts.join("weights").join(format!("{tag}.lxt"))
    }
}

/// One model variant's weights, ordered for direct use as PJRT arguments.
#[derive(Clone, Debug)]
pub struct WeightSet {
    pub tag: String,
    pub tensors: Vec<Tensor>,
    /// Total f32 parameter count (for footprint reporting).
    pub param_count: usize,
}

impl WeightSet {
    /// Load `artifacts/weights/<tag>.lxt` and order per the manifest.
    pub fn load(desc: &ModelDesc, tag: &str) -> Result<WeightSet> {
        let path = desc.weights_path(tag);
        let mut map = load_lxt(&path)?;
        let mut tensors = Vec::with_capacity(desc.weight_order.len());
        let mut count = 0usize;
        for name in &desc.weight_order {
            let t = map
                .remove(name)
                .with_context(|| format!("{path:?} missing weight {name}"))?;
            count += t.len();
            tensors.push(t);
        }
        Ok(WeightSet { tag: tag.to_string(), tensors, param_count: count })
    }

    /// Names of weight variants currently present under artifacts/weights.
    pub fn available(desc: &ModelDesc) -> Vec<String> {
        let dir = desc.artifacts.join("weights");
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.flatten() {
                if let Some(name) = e.file_name().to_str() {
                    if let Some(tag) = name.strip_suffix(".lxt") {
                        out.push(tag.to_string());
                    }
                }
            }
        }
        out.sort();
        out
    }
}
