//! Model descriptor + weight-set handling: the Rust view of the AOT
//! artifacts. A [`ModelDesc`] is parsed from `artifacts/manifest.txt`; a
//! [`WeightSet`] is one `.lxt` file reordered into the canonical
//! argument order shared with `python/compile/aot.py`.

pub mod forward;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use forward::{
    GraphSpec, LayerWeights, NativeDims, NativeWeights, PackedNativeWeights, ShardPlan, SpecRun,
};

use std::collections::BTreeMap;

use crate::io::{load_lxt, save_lxt, Manifest, Tensor};

/// Static model + artifact dimensions (mirror of python `ModelConfig` plus
/// the AOT shapes), and — for version-2 manifests written by `latmix
/// fold` — the transform-deployment annotations.
#[derive(Clone, Debug)]
pub struct ModelDesc {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub kv_seq: usize,
    pub prefill_len: usize,
    pub ppl_shape: (usize, usize),
    pub score_shape: (usize, usize),
    pub weight_order: Vec<String>,
    pub graphs: Vec<String>,
    pub artifacts: PathBuf,
    /// Manifest format version (1 = original python AOT layout).
    pub version: usize,
    /// Comma-joined site keys folded into the weight sets (informational;
    /// `transform.folded`).
    pub transform_folded: Option<String>,
    /// Artifacts-relative path of the online-remainder transform spec the
    /// serving path must apply (`transform.online`). Folded artifact sets
    /// with online sites are native-only: the AOT HLO graphs predate the
    /// fold, so the XLA lane refuses them.
    pub transform_online: Option<String>,
    /// Attention shard axis of the tensor-parallel plan (`shard.attn`;
    /// only `head` is defined). Additive version-2 key — absent on older
    /// manifests, which serve on the single-worker path.
    pub shard_attn: Option<String>,
    /// Fixed d_ff band width of the FFN shard partition
    /// (`shard.ffn_block`). Persisted so every host slices a folded
    /// artifact identically; `latmix serve --workers N` feeds it into
    /// [`forward::ShardPlan`].
    pub shard_ffn_block: Option<usize>,
}

impl ModelDesc {
    pub fn load(artifacts: &Path) -> Result<ModelDesc> {
        let m = Manifest::load(&artifacts.join("manifest.txt"))?;
        let shape = |key: &str| -> Result<(usize, usize)> {
            let raw = m
                .values
                .get(key)
                .with_context(|| format!("manifest missing {key}"))?;
            let (a, b) = raw.split_once('x').context("bad shape")?;
            Ok((a.parse()?, b.parse()?))
        };
        Ok(ModelDesc {
            vocab: m.int("model.vocab")?,
            d_model: m.int("model.d_model")?,
            n_layers: m.int("model.n_layers")?,
            n_heads: m.int("model.n_heads")?,
            d_ff: m.int("model.d_ff")?,
            kv_seq: m.int("kv_seq")?,
            prefill_len: m.int("prefill_len")?,
            ppl_shape: shape("ppl_shape")?,
            score_shape: shape("score_shape")?,
            weight_order: m.weight_order.clone(),
            graphs: m.graphs.clone(),
            artifacts: artifacts.to_path_buf(),
            version: m.version(),
            transform_folded: m.values.get("transform.folded").cloned(),
            transform_online: m.values.get("transform.online").cloned(),
            shard_attn: m.values.get("shard.attn").cloned(),
            shard_ffn_block: match m.values.get("shard.ffn_block") {
                Some(_) => Some(m.int("shard.ffn_block")?),
                None => None,
            },
        })
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn graph_path(&self, name: &str) -> PathBuf {
        self.artifacts.join("graphs").join(format!("{name}.hlo.txt"))
    }

    pub fn weights_path(&self, tag: &str) -> PathBuf {
        self.artifacts.join("weights").join(format!("{tag}.lxt"))
    }

    /// Artifacts-absolute path of the online transform spec, if any.
    pub fn transform_online_path(&self) -> Option<PathBuf> {
        self.transform_online.as_ref().map(|p| self.artifacts.join(p))
    }

    /// Write `manifest.txt` for this descriptor into `dir` (always at the
    /// current `MANIFEST_VERSION`). Used by `latmix fold` to emit a folded
    /// artifact directory that [`ModelDesc::load`] reads back.
    pub fn write_manifest(&self, dir: &Path) -> Result<()> {
        let mut values = BTreeMap::new();
        let mut put = |k: &str, v: String| values.insert(k.to_string(), v);
        put("model.vocab", self.vocab.to_string());
        put("model.d_model", self.d_model.to_string());
        put("model.n_layers", self.n_layers.to_string());
        put("model.n_heads", self.n_heads.to_string());
        put("model.d_ff", self.d_ff.to_string());
        put("kv_seq", self.kv_seq.to_string());
        put("prefill_len", self.prefill_len.to_string());
        put("ppl_shape", format!("{}x{}", self.ppl_shape.0, self.ppl_shape.1));
        put("score_shape", format!("{}x{}", self.score_shape.0, self.score_shape.1));
        if let Some(folded) = &self.transform_folded {
            put("transform.folded", folded.clone());
        }
        if let Some(online) = &self.transform_online {
            put("transform.online", online.clone());
        }
        if let Some(attn) = &self.shard_attn {
            put("shard.attn", attn.clone());
        }
        if let Some(fb) = self.shard_ffn_block {
            put("shard.ffn_block", fb.to_string());
        }
        let m = Manifest {
            values,
            graphs: self.graphs.clone(),
            weight_order: self.weight_order.clone(),
        };
        m.save(&dir.join("manifest.txt"))
    }
}

/// One model variant's weights, ordered for direct use as PJRT arguments.
#[derive(Clone, Debug)]
pub struct WeightSet {
    pub tag: String,
    pub tensors: Vec<Tensor>,
    /// Total f32 parameter count (for footprint reporting).
    pub param_count: usize,
}

impl WeightSet {
    /// Load `artifacts/weights/<tag>.lxt` and order per the manifest.
    pub fn load(desc: &ModelDesc, tag: &str) -> Result<WeightSet> {
        let path = desc.weights_path(tag);
        let mut map = load_lxt(&path)?;
        let mut tensors = Vec::with_capacity(desc.weight_order.len());
        let mut count = 0usize;
        for name in &desc.weight_order {
            let t = map
                .remove(name)
                .with_context(|| format!("{path:?} missing weight {name}"))?;
            count += t.len();
            tensors.push(t);
        }
        Ok(WeightSet { tag: tag.to_string(), tensors, param_count: count })
    }

    /// Write this weight set as `.lxt` under tensor names `order` (the
    /// inverse of [`WeightSet::load`]'s reordering).
    pub fn save(&self, path: &Path, order: &[String]) -> Result<()> {
        anyhow::ensure!(
            order.len() == self.tensors.len(),
            "weight order has {} names but weight set {:?} has {} tensors",
            order.len(),
            self.tag,
            self.tensors.len()
        );
        let map: BTreeMap<String, Tensor> = order
            .iter()
            .cloned()
            .zip(self.tensors.iter().cloned())
            .collect();
        anyhow::ensure!(map.len() == order.len(), "duplicate names in weight order");
        save_lxt(path, &map)
    }

    /// Names of weight variants currently present under artifacts/weights.
    pub fn available(desc: &ModelDesc) -> Vec<String> {
        let dir = desc.artifacts.join("weights");
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.flatten() {
                if let Some(name) = e.file_name().to_str() {
                    if let Some(tag) = name.strip_suffix(".lxt") {
                        out.push(tag.to_string());
                    }
                }
            }
        }
        out.sort();
        out
    }
}
