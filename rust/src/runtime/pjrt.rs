//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from the
//! request path. Wraps the `xla` crate (xla_extension 0.5.1, CPU plugin).
//! Compiled only with the `backend-xla` cargo feature (default-on).
//!
//! Design notes:
//! - **HLO text** is the interchange format (see `python/compile/aot.py`).
//! - Executables are compiled lazily and cached per graph name — the serving
//!   engine touches only `execute`.
//! - Weights are staged as `Literal`s once per [`WeightSet`] and reused
//!   across calls; per-step inputs (tokens, positions, KV) are the only
//!   per-call allocations. (PJRT buffer donation is not exposed by the
//!   0.1.6 crate, so KV round-trips host memory — acceptable at this scale
//!   and measured in EXPERIMENTS.md §Perf.)

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::io::lxt::{Tensor, TensorData};
use crate::model::{ModelDesc, WeightSet};

use super::Backend;

/// Lazily-compiled executable cache over a single PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    pub desc: ModelDesc,
}

impl Runtime {
    pub fn new(desc: ModelDesc) -> Result<Runtime> {
        // Folded artifact sets with an online transform remainder are
        // native-only: the AOT HLO graphs predate the fold, so executing
        // them here would silently skip the online FfnDown transforms.
        anyhow::ensure!(
            desc.transform_online.is_none(),
            "artifact set {:?} carries online transforms ({}); serve it with --backend native",
            desc.artifacts,
            desc.transform_online.as_deref().unwrap_or("?")
        );
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()), desc })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch) the executable for a graph name.
    pub fn executable(&self, graph: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(graph) {
            return Ok(e.clone());
        }
        let path = self.desc.graph_path(graph);
        let exe = self.compile_path(&path)?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(graph.to_string(), exe.clone());
        Ok(exe)
    }

    fn compile_path(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile {path:?}"))
    }

    /// Execute a graph on literal inputs; returns the flattened tuple leaves.
    ///
    /// Accepts anything that borrows `Literal` — pass `&[&Literal]` on hot
    /// paths to avoid cloning staged weights per call (EXPERIMENTS.md §Perf:
    /// the per-step weight re-staging was the top L3 bottleneck).
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        graph: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(graph)?;
        let result = exe.execute::<L>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let parts = lit.to_tuple()?;
        Ok(parts)
    }

    /// Stage a weight set as literals (done once per variant).
    pub fn stage_weights(&self, ws: &WeightSet) -> Result<Vec<xla::Literal>> {
        ws.tensors.iter().map(tensor_to_literal).collect()
    }
}

impl Backend for Runtime {
    type Staged = Vec<xla::Literal>;

    fn desc(&self) -> &ModelDesc {
        &self.desc
    }

    fn platform(&self) -> String {
        Runtime::platform(self)
    }

    fn id(&self) -> &'static str {
        "xla"
    }

    fn stage(&self, ws: &WeightSet) -> Result<Vec<xla::Literal>> {
        self.stage_weights(ws)
    }

    fn logits(
        &self,
        graph: &str,
        weights: &Self::Staged,
        tokens: &[i32],
        rows: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        let tok = i32_literal(tokens, &[rows as i64, seq as i64])?;
        let mut inputs: Vec<&xla::Literal> = vec![&tok];
        inputs.extend(weights.iter());
        let parts = self.execute(graph, &inputs)?;
        literal_to_f32(&parts[0])
    }
}

/// Convert an `.lxt` tensor to an XLA literal with the right shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.dims.iter().map(|d| *d as i64).collect();
    let lit = match &t.data {
        TensorData::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
        TensorData::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
    };
    Ok(lit)
}

/// Make an i32 literal from a slice with shape.
pub fn i32_literal(v: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(v).reshape(dims)?)
}

/// Make an f32 literal from a slice with shape.
pub fn f32_literal(v: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(v).reshape(dims)?)
}

/// Extract f32 data from a literal.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
