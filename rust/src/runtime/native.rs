//! Pure-Rust backend: runs the evaluation logits path through the
//! `model::forward` interpreter instead of compiled HLO. Always available —
//! this is what makes the eval harness and its benches runnable on machines
//! without the XLA toolchain (stock CI runners included) — and the only
//! backend that can serve *folded* artifact sets carrying an online
//! transform remainder (`transform.online` in a version-2 manifest).

use anyhow::Result;

use crate::model::{GraphSpec, ModelDesc, NativeDims, NativeWeights, SpecRun, WeightSet};
use crate::transform::{TransformMode, TransformSpec};

use super::Backend;

/// Interpreter-backed [`Backend`]. "Staging" a weight set parses it into
/// [`NativeWeights`] once; graph names select only the quant spec (the
/// activation QDQ config and online T3 Hadamard), exactly as the compiled
/// graph inventory does. When the artifact manifest names an online
/// transform spec, it is applied in [`TransformMode::Folded`] — construct
/// via [`NativeBackend::from_desc`] so it gets loaded.
pub struct NativeBackend {
    pub desc: ModelDesc,
    transforms: Option<(TransformSpec, TransformMode)>,
}

impl NativeBackend {
    /// Wrap a descriptor with no transform application. Artifact sets that
    /// declare `transform.online` refuse to run through this constructor's
    /// backend (see [`Backend::logits`]) — use [`NativeBackend::from_desc`].
    pub fn new(desc: ModelDesc) -> NativeBackend {
        NativeBackend { desc, transforms: None }
    }

    /// Load the descriptor's online transform spec (when present) so
    /// folded artifact sets evaluate with their FfnDown remainder applied.
    pub fn from_desc(desc: ModelDesc) -> Result<NativeBackend> {
        let transforms = TransformSpec::load_online(&desc)?;
        Ok(NativeBackend { desc, transforms })
    }

    /// Explicit transform application (tests, unfolded reference runs).
    pub fn with_transforms(
        desc: ModelDesc,
        spec: TransformSpec,
        mode: TransformMode,
    ) -> NativeBackend {
        NativeBackend { desc, transforms: Some((spec, mode)) }
    }

    fn spec_run(&self) -> SpecRun<'_> {
        self.transforms.as_ref().map(|(s, m)| (s, *m))
    }
}

impl Backend for NativeBackend {
    type Staged = NativeWeights;

    fn desc(&self) -> &ModelDesc {
        &self.desc
    }

    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn id(&self) -> &'static str {
        "native"
    }

    fn stage(&self, ws: &WeightSet) -> Result<NativeWeights> {
        let dims = NativeDims::from_desc(&self.desc);
        NativeWeights::from_weight_set(dims, &self.desc.weight_order, ws)
    }

    fn logits(
        &self,
        graph: &str,
        weights: &Self::Staged,
        tokens: &[i32],
        rows: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        // Stay faithful to the compiled-graph inventory: the XLA backend
        // errors on graphs the artifact set never lowered, so the native
        // lane must too — otherwise the two lanes silently publish tables
        // over different variant sets.
        anyhow::ensure!(
            self.desc.graphs.iter().any(|g| g == graph),
            "graph {graph:?} not in the artifact manifest"
        );
        // A manifest that declares an online remainder must have it loaded
        // — running without it would silently drop the FfnDown transforms.
        anyhow::ensure!(
            self.desc.transform_online.is_none() || self.transforms.is_some(),
            "artifact set declares transform.online but this backend was built without it; \
             construct via NativeBackend::from_desc"
        );
        let spec = GraphSpec::from_graph_name(graph)?;
        weights.forward_seq_spec(tokens, rows, seq, &spec, self.spec_run())
    }
}
