//! Pure-Rust backend: runs the evaluation logits path through the
//! `model::forward` interpreter instead of compiled HLO. Always available —
//! this is what makes the eval harness and its benches runnable on machines
//! without the XLA toolchain (stock CI runners included).

use anyhow::Result;

use crate::model::{GraphSpec, ModelDesc, NativeDims, NativeWeights, WeightSet};

use super::Backend;

/// Interpreter-backed [`Backend`]. "Staging" a weight set parses it into
/// [`NativeWeights`] once; graph names select only the quant spec (the
/// activation QDQ config and online T3 Hadamard), exactly as the compiled
/// graph inventory does.
pub struct NativeBackend {
    pub desc: ModelDesc,
}

impl NativeBackend {
    pub fn new(desc: ModelDesc) -> NativeBackend {
        NativeBackend { desc }
    }
}

impl Backend for NativeBackend {
    type Staged = NativeWeights;

    fn desc(&self) -> &ModelDesc {
        &self.desc
    }

    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn id(&self) -> &'static str {
        "native"
    }

    fn stage(&self, ws: &WeightSet) -> Result<NativeWeights> {
        NativeWeights::from_weight_set(NativeDims::from_desc(&self.desc), &self.desc.weight_order, ws)
    }

    fn logits(
        &self,
        graph: &str,
        weights: &Self::Staged,
        tokens: &[i32],
        rows: usize,
        seq: usize,
    ) -> Result<Vec<f32>> {
        // Stay faithful to the compiled-graph inventory: the XLA backend
        // errors on graphs the artifact set never lowered, so the native
        // lane must too — otherwise the two lanes silently publish tables
        // over different variant sets.
        anyhow::ensure!(
            self.desc.graphs.iter().any(|g| g == graph),
            "graph {graph:?} not in the artifact manifest"
        );
        let spec = GraphSpec::from_graph_name(graph)?;
        weights.forward_seq(tokens, rows, seq, &spec)
    }
}
