//! Execution backends for the AOT'd model graphs — the deployment seam of
//! the paper's Sec. 4.1 serving claim: every quantized method runs the
//! same decode executable, so backend choice and transform choice are
//! orthogonal.
//!
//! Two implementations of the [`Backend`] trait:
//!
//! - [`NativeBackend`] (always compiled) — the pure-Rust interpreter over
//!   `model::forward`, no native libraries required.
//! - `Runtime` (in `runtime::pjrt`, behind the default-on `backend-xla`
//!   cargo feature — not linkable from no-default-feature docs) — the
//!   PJRT/XLA runtime that compiles and executes the HLO-text artifacts.
//!
//! The serving engine abstracts one step further ([`StepExecutor`] in
//! `coordinator::engine`); this trait covers the full-sequence logits path
//! the evaluation harness needs, plus weight staging so the XLA side keeps
//! its stage-once / borrow-per-call discipline.
//!
//! [`StepExecutor`]: crate::coordinator::engine::StepExecutor

mod native;
#[cfg(feature = "backend-xla")]
mod pjrt;

pub use native::NativeBackend;
#[cfg(feature = "backend-xla")]
pub use pjrt::{f32_literal, i32_literal, literal_to_f32, tensor_to_literal, Runtime};

use anyhow::Result;

use crate::coordinator::SchedEvent;
use crate::model::{ModelDesc, WeightSet};

/// A graph-execution backend: stages weight sets once, then runs the
/// full-sequence `logits_*` graphs the eval harness consumes.
pub trait Backend {
    /// Backend-specific staged weight representation (PJRT literals for
    /// XLA, parsed [`crate::model::NativeWeights`] for the interpreter).
    type Staged;

    fn desc(&self) -> &ModelDesc;

    /// Human-readable platform name (e.g. PJRT's "cpu", or "native-cpu").
    fn platform(&self) -> String;

    /// Short backend id recorded in bench snapshots: "xla" | "native".
    fn id(&self) -> &'static str;

    /// Stage a weight set for repeated graph calls.
    fn stage(&self, ws: &WeightSet) -> Result<Self::Staged>;

    /// Run a full-sequence logits graph (`logits_ppl_<tag>` /
    /// `logits_score_<tag>`) on a (rows, seq) token batch; returns flat
    /// (rows * seq * vocab) logits.
    fn logits(
        &self,
        graph: &str,
        weights: &Self::Staged,
        tokens: &[i32],
        rows: usize,
        seq: usize,
    ) -> Result<Vec<f32>>;
}

/// The backend this build evaluates on by default: PJRT when `backend-xla`
/// is enabled, the pure-Rust interpreter otherwise. Benches use this so one
/// source runs on both kinds of machine.
#[cfg(feature = "backend-xla")]
pub type DefaultBackend = Runtime;
#[cfg(not(feature = "backend-xla"))]
pub type DefaultBackend = NativeBackend;

#[cfg(feature = "backend-xla")]
pub fn default_backend(desc: ModelDesc) -> Result<DefaultBackend> {
    Runtime::new(desc)
}

#[cfg(not(feature = "backend-xla"))]
pub fn default_backend(desc: ModelDesc) -> Result<DefaultBackend> {
    NativeBackend::from_desc(desc)
}

/// Compiled decode batch sizes for `tag`, parsed from the manifest graph
/// inventory (`decode_<tag>_b<batch>`). Shared by both executors so batch
/// selection always agrees across backends. Malformed batch suffixes are
/// reported with a warning instead of being silently dropped (they used to
/// vanish through `parse().ok()`), so a corrupted manifest is visible.
pub fn decode_batch_sizes(graphs: &[String], tag: &str) -> Vec<usize> {
    let prefix = format!("decode_{tag}_b");
    let mut out = Vec::new();
    for g in graphs {
        if let Some(suffix) = g.strip_prefix(prefix.as_str()) {
            match suffix.parse::<usize>() {
                Ok(b) if b > 0 => out.push(b),
                _ => eprintln!(
                    "warning: decode graph {g:?} for tag {tag:?} has malformed batch \
                     suffix {suffix:?}; ignoring it for batch selection"
                ),
            }
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Fold a scheduling event log into one u64 (FNV-1a over each event's
/// stable encoding) — the cross-backend lockstep contract for the
/// continuous-batching engine. Two engines that admit, refill, and evict
/// the same requests into the same slots in the same order produce the
/// same fingerprint, whatever device ran the lane arithmetic; the parity
/// suites (`backend_parity.rs`, `integration_runtime.rs`) compare these
/// alongside the token streams.
pub fn sched_fingerprint(events: &[SchedEvent]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for ev in events {
        let (tag, id, a, b) = ev.encode();
        mix(tag as u64);
        mix(id);
        mix(a);
        mix(b);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FinishReason;

    fn graphs(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn fingerprint_sensitive_to_order_and_content() {
        let a = SchedEvent::Admit { id: 1, slot: 0, refill: false };
        let b = SchedEvent::Evict { id: 1, slot: 0, reason: FinishReason::Eos };
        assert_eq!(sched_fingerprint(&[a, b]), sched_fingerprint(&[a, b]));
        assert_ne!(sched_fingerprint(&[a, b]), sched_fingerprint(&[b, a]));
        assert_ne!(sched_fingerprint(&[a]), sched_fingerprint(&[a, b]));
        let c = SchedEvent::Evict { id: 1, slot: 0, reason: FinishReason::TimedOut };
        assert_ne!(sched_fingerprint(&[a, b]), sched_fingerprint(&[a, c]));
    }

    #[test]
    fn batch_sizes_parsed_sorted_deduped() {
        let g = graphs(&[
            "decode_fp_b8",
            "decode_fp_b1",
            "decode_fp_b2",
            "decode_fp_b2",
            "prefill_fp_b4",
            "logits_ppl_fp",
        ]);
        assert_eq!(decode_batch_sizes(&g, "fp"), vec![1, 2, 8]);
    }

    #[test]
    fn malformed_suffixes_dropped_with_warning() {
        let g = graphs(&["decode_fp_b1", "decode_fp_bXX", "decode_fp_b0", "decode_fp_b"]);
        // bXX / b0 / trailing-empty are surfaced (stderr) but never selected
        assert_eq!(decode_batch_sizes(&g, "fp"), vec![1]);
    }

    #[test]
    fn tags_do_not_cross_match() {
        let g = graphs(&[
            "decode_mxfp4_b32_t3_b4",
            "decode_mxfp4_b32_t3_b1",
            "decode_mxfp4_b32_b2",
        ]);
        assert_eq!(decode_batch_sizes(&g, "mxfp4_b32_t3"), vec![1, 4]);
        assert_eq!(decode_batch_sizes(&g, "mxfp4_b32"), vec![2]);
        assert_eq!(decode_batch_sizes(&g, "fp"), Vec::<usize>::new());
    }
}
