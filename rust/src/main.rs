//! `latmix` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info                          artifact + model summary
//!   eval   --weights TAG --quant TAG [--ppl-only] [--backend B]
//!   serve  --weights TAG --quant TAG [--requests N] [--slots N] [--max-new N] [--backend B]
//!          [--open-loop] [--arrival-rate R] [--deadline-ms MS] [--queue-depth N]
//!          [--seed N] [--synthetic] [--packed-weights] [--workers N]
//!          [--kv-bits 32|8|4] [--kv-block N] [--shared-prefix N]
//!   learn  [--steps N] [--lr F] [--block N] [--bits N] [--features model|outlier|dirac]
//!          [--sites residual,t2,ffn] [--heads 0,1] [--save-spec PATH]
//!   fold   --weights TAG --spec PATH --out DIR [--tag TAG]
//!   quantize-info --weights TAG   MX footprint accounting
//!   variants                      list available weight variants
//!
//! `--backend` picks the execution backend: `xla` (PJRT, needs the
//! `backend-xla` build feature — the default when available) or `native`
//! (pure-Rust interpreter, works on any machine). `learn` runs the
//! Sec. 3.2 / Fig. 2 transform-learning loop (`latmix::latmix`) on the
//! native backend — no artifacts or XLA toolchain required. With
//! `--sites` it learns a full per-site `TransformSpec` (T1 + per-head T2 +
//! FfnDown); `fold` bakes a saved spec into an `.lxt` weight set and
//! writes a version-2 artifact directory that `serve --backend native`
//! serves directly — the whole learn → fold → serve loop with zero
//! Python.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use latmix::cli::Args;
use latmix::data::{load_ppl_corpus, load_tasks};
use latmix::eval::{perplexity, zero_shot};
use latmix::model::{ModelDesc, NativeDims, NativeWeights, ShardPlan, WeightSet};
use latmix::mx::{MxConfig, pack::PackedMx};
use latmix::runtime::{Backend, NativeBackend};
#[cfg(feature = "backend-xla")]
use latmix::runtime::Runtime;
use latmix::coordinator::KvSpec;
use latmix::server::{run_open_loop_native, run_serving_native, serve_open_loop};
#[cfg(feature = "backend-xla")]
use latmix::server::{run_open_loop, run_serving};
use latmix::server::{
    OpenLoopConfig, Residency, ServeOptions, ServeReport, ServingReport, WeightResidency,
};
use latmix::transform::{TransformSite, TransformSpec};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("info") => info(),
        Some("variants") => variants(),
        Some("eval") => eval(&args),
        Some("serve") => serve(&args),
        Some("learn") => learn(&args),
        Some("fold") => fold(&args),
        Some("quantize-info") => quantize_info(&args),
        _ => {
            eprintln!(
                "usage: latmix <info|variants|eval|serve|learn|fold|quantize-info> [options]\n\
                 \n\
                 eval   --weights TAG --quant TAG [--ppl-only] [--backend xla|native]\n\
                 serve  --weights TAG --quant TAG [--requests N] [--slots N] [--max-new N] [--backend xla|native]\n\
                 \x20       [--open-loop] [--arrival-rate R] [--deadline-ms MS] [--queue-depth N]\n\
                 \x20       [--seed N] [--synthetic] [--packed-weights] [--workers N]\n\
                 \x20       [--kv-bits 32|8|4] [--kv-block N] [--shared-prefix N]\n\
                 learn  [--steps N] [--lr F] [--block N] [--bits 4|6|8] [--format FMT]\n\
                 \x20       [--features model|outlier|dirac] [--layer N] [--d N] [--rows N]\n\
                 \x20       [--init bd_hadamard|hadamard|identity] [--seed N]\n\
                 \x20       [--sites residual,t2,ffn|t2:L:H|ffn:L] [--heads 0,1] [--t3]\n\
                 \x20       [--save-spec PATH]\n\
                 fold   --weights TAG --spec PATH --out DIR [--tag TAG]\n\
                 quantize-info --weights TAG [--format mxfp4]"
            );
            Ok(())
        }
    }
}

fn desc() -> Result<ModelDesc> {
    let art = latmix::artifacts_dir();
    ModelDesc::load(&art)
        .with_context(|| format!("load manifest from {art:?} (run `make artifacts` first)"))
}

/// The backend to use: explicit `--backend`, else XLA when compiled in.
fn backend_name(args: &Args) -> &str {
    args.opt("backend").unwrap_or(if cfg!(feature = "backend-xla") {
        "xla"
    } else {
        "native"
    })
}

fn unknown_backend(name: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "unknown backend {name:?} (this build supports: native{})",
        if cfg!(feature = "backend-xla") { ", xla" } else { "" }
    )
}

fn info() -> Result<()> {
    let d = desc()?;
    println!(
        "latmix-tiny: d_model={} layers={} heads={} d_ff={} vocab={}",
        d.d_model, d.n_layers, d.n_heads, d.d_ff, d.vocab
    );
    println!("kv_seq={} prefill_len={} graphs={}", d.kv_seq, d.prefill_len, d.graphs.len());
    if cfg!(feature = "backend-xla") {
        println!("backends: xla (default), native");
    } else {
        println!("backends: native (built without backend-xla)");
    }
    for g in &d.graphs {
        println!("  graph {g}");
    }
    Ok(())
}

fn variants() -> Result<()> {
    let d = desc()?;
    for v in WeightSet::available(&d) {
        println!("{v}");
    }
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let d = desc()?;
    match backend_name(args) {
        // from_desc: folded artifact sets carry an online transform
        // remainder the eval path must apply
        "native" => eval_on(&NativeBackend::from_desc(d)?, args),
        #[cfg(feature = "backend-xla")]
        "xla" => eval_on(&Runtime::new(d)?, args),
        other => Err(unknown_backend(other)),
    }
}

fn eval_on<B: Backend>(rt: &B, args: &Args) -> Result<()> {
    let wtag = args.opt("weights").context("--weights required")?;
    let qtag = args.opt("quant").unwrap_or("fp");
    let ws = WeightSet::load(rt.desc(), wtag)?;
    let art = latmix::artifacts_dir();
    let (corpus, n, t) = load_ppl_corpus(&art)?;
    let ppl = perplexity(rt, qtag, &ws, &corpus, n, t)?;
    println!("backend={} weights={wtag} quant={qtag} ppl={ppl:.3}", rt.id());
    if !args.flag("ppl-only") {
        let tasks = load_tasks(&art)?;
        for (name, acc) in zero_shot(rt, qtag, &ws, &tasks)? {
            println!("  {name}: {:.2}%", acc * 100.0);
        }
    }
    Ok(())
}

/// Parse `--workers` (tensor-parallel shard worker count; native-only).
/// `None` keeps the original single-worker forward. Plan validation
/// (0 workers, workers > n_heads) happens against the model dims when the
/// executor is built.
fn shard_workers(args: &Args) -> Result<Option<usize>> {
    args.opt("workers")
        .map(|w| w.parse::<usize>().with_context(|| format!("bad --workers {w:?}")))
        .transpose()
}

/// Parse `--kv-bits` / `--kv-block` into the paged-KV storage spec.
fn kv_spec(args: &Args) -> Result<KvSpec> {
    let mut kv = KvSpec::from_bits(args.opt_usize("kv-bits", 32))?;
    kv.block = args.opt_usize("kv-block", kv.block);
    anyhow::ensure!(kv.block > 0, "--kv-block must be > 0");
    Ok(kv)
}

/// One "resident weights / kv cache" footprint summary line.
fn print_residency(r: &Residency, packed: bool, kv: &KvSpec) {
    if r.weight_bytes > 0 {
        println!(
            "resident weights: {:.2} MiB ({})",
            r.weight_bytes as f64 / (1 << 20) as f64,
            if packed { "MX-packed" } else { "dense f32" }
        );
    }
    if r.kv_bytes > 0 {
        println!(
            "kv cache: {:.3} MiB resident ({}, {}-token pages, {} page(s) prefix-shared)",
            r.kv_bytes as f64 / (1 << 20) as f64,
            kv.label(),
            kv.block,
            r.kv_pages_shared
        );
    }
}

fn serve(args: &Args) -> Result<()> {
    if args.flag("open-loop") {
        return serve_open(args);
    }
    let d = desc()?;
    let packed = args.flag("packed-weights");
    let workers = shard_workers(args)?;
    let kv = kv_spec(args)?;
    let mut opts = ServeOptions::default()
        .tags(args.opt("quant").unwrap_or("fp"), args.opt("weights").unwrap_or("fp16"))
        .requests(args.opt_usize("requests", 16))
        .max_new(args.opt_usize("max-new", 32))
        .slots(args.opt_usize("slots", 8))
        .seed(args.opt_usize("seed", 42) as u64)
        .residency(if packed { WeightResidency::Packed } else { WeightResidency::Dense })
        .kv(kv);
    if let Some(w) = workers {
        opts = opts.workers(w);
    }
    let rep: ServeReport = match backend_name(args) {
        "native" => run_serving_native(&d, &opts)?,
        #[cfg(feature = "backend-xla")]
        "xla" => {
            anyhow::ensure!(!packed, "--packed-weights is native-only (use --backend native)");
            anyhow::ensure!(
                workers.is_none(),
                "--workers is native-only (use --backend native)"
            );
            let rt = Runtime::new(d)?;
            run_serving(&rt, &opts)?
        }
        other => return Err(unknown_backend(other)),
    };
    print_residency(&rep.core.residency, packed, &opts.kv);
    if !rep.core.worker_requests.is_empty() {
        let loads: Vec<String> =
            rep.core.worker_requests.iter().map(|n| n.to_string()).collect();
        println!(
            "shard workers: {} (requests per worker: [{}])",
            rep.core.worker_requests.len(),
            loads.join(", ")
        );
    }
    if rep.is_empty() {
        println!(
            "serve: 0 requests completed (graph={} weights={}) — no latency percentiles \
             to report; run with --requests N > 0",
            rep.core.tag, rep.core.weights
        );
        return Ok(());
    }
    println!(
        "graph={} weights={} requests={} wall={:.2}s decode_tok/s={:.1} total_tok/s={:.1}",
        rep.core.tag,
        rep.core.weights,
        rep.core.requests,
        rep.core.wall_s,
        rep.core.decode_tok_per_s,
        rep.total_tok_per_s
    );
    println!(
        "ttft p50={:.1}ms p99={:.1}ms  latency p50={:.1}ms p99={:.1}ms",
        rep.ttft_p50_ms, rep.ttft_p99_ms, rep.latency_p50_ms, rep.latency_p99_ms
    );
    Ok(())
}

/// `latmix serve --open-loop`: Poisson arrivals at `--arrival-rate` req/s
/// over the weighted payload classes, with optional `--queue-depth`
/// backpressure and `--deadline-ms` SLO eviction. Writes the per-class
/// p50/p90/p99 TTFT + inter-token latency snapshot to `BENCH_serving.json`.
/// `--synthetic` serves deterministic latmix-tiny weights with no artifact
/// directory at all (the CI smoke path). `--shared-prefix N` gives every
/// prompt the same N post-BOS tokens, turning the prefix into refcounted
/// shared KV pages; `--kv-bits 8|4` stores KV pages MX-quantized.
fn serve_open(args: &Args) -> Result<()> {
    let cfg = OpenLoopConfig {
        n_requests: args.opt_usize("requests", 64),
        arrival_rate: args.opt_f64("arrival-rate", 100.0),
        max_slots: args.opt_usize("slots", 8),
        queue_depth: args
            .opt("queue-depth")
            .map(|d| d.parse::<usize>().with_context(|| format!("bad --queue-depth {d:?}")))
            .transpose()?,
        deadline: args
            .opt("deadline-ms")
            .map(|m| -> Result<_> {
                let ms: f64 = m.parse().with_context(|| format!("bad --deadline-ms {m:?}"))?;
                anyhow::ensure!(ms >= 0.0, "--deadline-ms must be >= 0");
                Ok(std::time::Duration::from_secs_f64(ms / 1e3))
            })
            .transpose()?,
        shared_prefix: args.opt_usize("shared-prefix", 0),
        seed: args.opt_usize("seed", 42) as u64,
    };
    anyhow::ensure!(cfg.arrival_rate > 0.0, "--arrival-rate must be > 0");
    let packed = args.flag("packed-weights");
    let workers = shard_workers(args)?;
    let mut opts = ServeOptions::default()
        .tags(args.opt("quant").unwrap_or("fp"), args.opt("weights").unwrap_or("fp16"))
        .residency(if packed { WeightResidency::Packed } else { WeightResidency::Dense })
        .kv(kv_spec(args)?);
    if let Some(w) = workers {
        opts = opts.workers(w);
    }
    let rep: ServingReport = if args.flag("synthetic") {
        use latmix::coordinator::engine::NativeExecutor;
        let mut exec = NativeExecutor::synthetic(
            NativeDims::latmix_tiny(),
            &opts.graph_tag,
            vec![1, 2, 4, 8],
            cfg.seed,
        )?;
        if packed {
            exec = exec.into_packed()?;
        }
        if let Some(w) = workers {
            exec = exec.with_workers(w)?;
        }
        let bytes = exec.resident_weight_bytes();
        let synth = opts.clone().tags(&opts.graph_tag, "synthetic");
        let mut rep = serve_open_loop(exec, &synth, "synthetic", &cfg)?;
        rep.core.residency.weight_bytes = bytes;
        rep
    } else {
        let d = desc()?;
        match backend_name(args) {
            "native" => run_open_loop_native(&d, &opts, &cfg)?,
            #[cfg(feature = "backend-xla")]
            "xla" => {
                anyhow::ensure!(!packed, "--packed-weights is native-only (use --backend native)");
                anyhow::ensure!(
                    workers.is_none(),
                    "--workers is native-only (use --backend native)"
                );
                let rt = Runtime::new(d)?;
                run_open_loop(&rt, &opts, &cfg)?
            }
            other => return Err(unknown_backend(other)),
        }
    };
    if rep.core.requests == 0 {
        println!("serve --open-loop: 0 requests submitted — nothing to report");
        return Ok(());
    }
    println!(
        "open-loop: backend={} graph={} weights={} rate={:.1}req/s requests={} lost={} \
         wall={:.2}s decode_tok/s={:.1}",
        rep.core.backend,
        rep.core.tag,
        rep.core.weights,
        rep.arrival_rate,
        rep.core.requests,
        rep.lost,
        rep.core.wall_s,
        rep.core.decode_tok_per_s
    );
    print_residency(&rep.core.residency, packed, &opts.kv);
    let mut table = latmix::bench::Table::new(
        "serving_slo",
        "Per-class SLO percentiles (open-loop)",
        &[
            "class", "reqs", "done", "rej", "timeout", "ttft p50/p90/p99 ms",
            "itl p50/p90/p99 ms",
        ],
    );
    for c in &rep.classes {
        table.row(vec![
            c.class.clone(),
            c.requests.to_string(),
            c.completed.to_string(),
            c.rejected.to_string(),
            c.timed_out.to_string(),
            format!("{:.2} / {:.2} / {:.2}", c.ttft_ms[0], c.ttft_ms[1], c.ttft_ms[2]),
            format!("{:.2} / {:.2} / {:.2}", c.itl_ms[0], c.itl_ms[1], c.itl_ms[2]),
        ]);
    }
    table.emit();
    let path = rep.emit();
    println!("serving snapshot -> {}", path.display());
    if rep.lost > 0 {
        anyhow::bail!("{} request(s) lost — conservation bug in the serving pipeline", rep.lost);
    }
    Ok(())
}

/// `latmix learn` — the Sec. 3.2 / Fig. 2 transform-learning loop, fully
/// in Rust on the native backend. Learns `T` on residual-stream features
/// captured from a synthetic latmix-tiny model (`--features model`, the
/// default) or on the paper's synthetic distributions
/// (`--features outlier|dirac`), then reports `E(T)` (Eq. 2) and the
/// Theorem 3.3 bound against the identity and random-Hadamard baselines.
///
/// With `--sites` it learns a per-site `TransformSpec` instead of a single
/// transform: `residual` (T1 at `--layer`'s input stream), `t2` (per-head
/// value transforms at `--layer`, heads from `--heads`, or explicit
/// `t2:L:H`), `ffn` (down-proj input at `--layer`, or explicit `ffn:L`).
/// `--t3` captures the FfnDown features after the online T3 Hadamard, and
/// `--save-spec` writes the learned spec as `.lxt` for `latmix fold`.
fn learn(args: &Args) -> Result<()> {
    use latmix::latmix::{
        dirac_features, learn_feature_transform, outlier_features, InitStrategy, LearnConfig,
    };
    use latmix::transform::{bound::theorem_bound, transformation_mse, Affine};

    if args.opt("sites").is_some() {
        return learn_sites(args);
    }

    // only override the block size when given: each format keeps its
    // canonical default otherwise (32 for mx*, 16 for nvfp4)
    let block: Option<usize> = args.opt("block").and_then(|b| b.parse().ok());
    let fmt = match args.opt("format") {
        Some(f) => f.to_string(),
        None => match args.opt_usize("bits", 4) {
            4 => "mxfp4".to_string(),
            6 => "mxfp6".to_string(),
            8 => "mxfp8".to_string(),
            other => anyhow::bail!("--bits {other} unsupported (4|6|8; use --format for more)"),
        },
    };
    let cfg = MxConfig::from_name(&fmt, block)?;
    let seed = args.opt_usize("seed", 0) as u64;
    let mut lc = LearnConfig {
        steps: args.opt_usize("steps", 300),
        lr: args.opt_f64("lr", 3e-3) as f32,
        seed,
        ..Default::default()
    };
    let features = args.opt("features").unwrap_or("model");
    let (feats, d, source) = match features {
        "model" => {
            let dims = latmix::model::NativeDims::latmix_tiny();
            let w = latmix::model::NativeWeights::synthetic(dims, seed ^ 0x6c61746d);
            let layer = args.opt_usize("layer", 2).min(dims.n_layers);
            let (batch, t) = (8usize, dims.prefill_len);
            let mut rng = latmix::util::Pcg64::seed(seed);
            let tokens: Vec<i32> =
                (0..batch * t).map(|_| rng.below(dims.vocab as u64) as i32).collect();
            let spec = latmix::model::GraphSpec::fp();
            let feats = w.capture_residual(&tokens, batch, t, &spec, layer)?;
            (feats, dims.d_model, format!("residual stream, layer {layer} (native backend)"))
        }
        "outlier" => {
            let d = args.opt_usize("d", 64);
            let rows = args.opt_usize("rows", 128);
            (outlier_features(rows, d, 0.05, seed), d, "synthetic outlier channels".into())
        }
        "dirac" => {
            let d = args.opt_usize("d", 32);
            let rows = args.opt_usize("rows", 128);
            (dirac_features(rows, d, seed), d, "Sec. 3.1 Dirac-delta".into())
        }
        other => anyhow::bail!("unknown --features {other:?} (model|outlier|dirac)"),
    };
    lc.init = match args.opt("init").unwrap_or("bd_hadamard") {
        "bd_hadamard" => InitStrategy::BdHadamardNoise { block: 32.min(d), noise: 1e-3 },
        "hadamard" => InitStrategy::Hadamard,
        "identity" => InitStrategy::Identity,
        other => anyhow::bail!("unknown --init {other:?} (bd_hadamard|hadamard|identity)"),
    };

    println!(
        "learn: {} rows x {d} dims ({source}), {} b{}, steps={} lr={}",
        feats.len() / d,
        cfg.name,
        cfg.block_size,
        lc.steps,
        lc.lr
    );
    let lt = learn_feature_transform(&feats, d, &cfg, &lc)?;
    for row in &lt.trace {
        println!(
            "  step {:4}  E(T) {:.6}  loss {:.6}  lr {:.2e}",
            row.step, row.mse, row.loss, row.lr
        );
    }
    let best_mse = lt.best_mse;
    let learned = lt.into_affine()?;

    let mut table = latmix::bench::Table::new(
        "fig2_learn",
        "E(T) and Theorem 3.3 bound: learned vs fixed baselines",
        &["transform", "E(T)", "thm 3.3 bound", "vs identity"],
    );
    let id = Affine::identity(d);
    let e_id = transformation_mse(&feats, d, &id, &cfg);
    let mut report = |name: &str, t: &Affine| {
        let e = transformation_mse(&feats, d, t, &cfg);
        let b = theorem_bound(&feats, d, t, cfg.block_size);
        table.row(vec![
            name.into(),
            format!("{e:.6}"),
            format!("{b:.4}"),
            format!("{:.2}x", e_id / e.max(1e-12)),
        ]);
    };
    report("identity", &id);
    if d.is_power_of_two() {
        let mut hrng = latmix::util::Pcg64::seed(seed.wrapping_add(1));
        let h = latmix::latmix::randomized_hadamard(d, &mut hrng);
        report("random hadamard", &Affine::new(h, vec![0.0; d])?);
    }
    report("learned (this run)", &learned);
    table.emit();
    println!("learned transform: cond = {:.2}, best E(T) = {best_mse:.6}", learned.a.condition());
    Ok(())
}

/// The validated `--layer` target (default: mid-depth). Used both for the
/// `Residual` capture depth and the layer of `t2`/`ffn` site tokens, so
/// one consistent block index governs the whole spec.
fn site_layer(args: &Args, dims: &NativeDims) -> Result<usize> {
    let layer = args.opt_usize("layer", dims.n_layers / 2);
    anyhow::ensure!(
        layer < dims.n_layers,
        "--layer {layer} out of range (model has {} blocks)",
        dims.n_layers
    );
    Ok(layer)
}

/// Parse `--sites` / `--heads` / `--layer` into concrete transform sites.
fn parse_sites(args: &Args, dims: &NativeDims) -> Result<Vec<TransformSite>> {
    let layer = site_layer(args, dims)?;
    let heads: Vec<usize> = match args.opt("heads") {
        Some(spec) => spec
            .split(',')
            .map(|h| h.trim().parse().with_context(|| format!("bad --heads entry {h:?}")))
            .collect::<Result<_>>()?,
        None => (0..dims.n_heads).collect(),
    };
    let mut sites = Vec::new();
    for tok in args.opt("sites").unwrap_or("residual").split(',') {
        match tok.trim() {
            "residual" | "t1" => sites.push(TransformSite::Residual),
            "t2" => {
                for &head in &heads {
                    sites.push(TransformSite::PerHeadValue { layer, head });
                }
            }
            "ffn" => sites.push(TransformSite::FfnDown { layer }),
            other => {
                // explicit forms t2:L:H / ffn:L reuse the spec key syntax
                let key = other.replace(':', ".");
                sites.push(TransformSite::parse_key(&key).with_context(|| {
                    format!("bad --sites entry {other:?} (residual | t2 | ffn | t2:L:H | ffn:L)")
                })?);
            }
        }
    }
    Ok(sites)
}

/// The `--sites` path of `latmix learn`: learn a per-site spec on the
/// synthetic latmix-tiny model and report each site against its fixed
/// baselines.
fn learn_sites(args: &Args) -> Result<()> {
    use latmix::latmix::{learn_spec, InitStrategy, LearnConfig};
    use latmix::model::GraphSpec;

    // same format/init flag semantics as the single-transform learn path
    let block: Option<usize> = args.opt("block").and_then(|b| b.parse().ok());
    let fmt = match args.opt("format") {
        Some(f) => f.to_string(),
        None => match args.opt_usize("bits", 4) {
            4 => "mxfp4".to_string(),
            6 => "mxfp6".to_string(),
            8 => "mxfp8".to_string(),
            other => anyhow::bail!("--bits {other} unsupported (4|6|8; use --format for more)"),
        },
    };
    let cfg = MxConfig::from_name(&fmt, block)?;
    let seed = args.opt_usize("seed", 0) as u64;
    let init = match args.opt("init").unwrap_or("bd_hadamard") {
        // learn_spec clamps the init block into each site's dim via gcd
        "bd_hadamard" => InitStrategy::BdHadamardNoise { block: 32, noise: 1e-3 },
        "hadamard" => InitStrategy::Hadamard,
        "identity" => InitStrategy::Identity,
        other => anyhow::bail!("unknown --init {other:?} (bd_hadamard|hadamard|identity)"),
    };
    let lc = LearnConfig {
        steps: args.opt_usize("steps", 300),
        lr: args.opt_f64("lr", 3e-3) as f32,
        seed,
        init,
        trace_every: 0,
        ..Default::default()
    };
    let dims = NativeDims::latmix_tiny();
    let w = NativeWeights::synthetic(dims, seed ^ 0x6c61746d);
    let sites = parse_sites(args, &dims)?;
    let residual_layer = site_layer(args, &dims)?;
    let capture = GraphSpec {
        act: None,
        t3: args.flag("t3").then_some(GraphSpec::T3_BLOCK),
    };
    let (batch, t) = (8usize, dims.prefill_len);
    let mut rng = latmix::util::Pcg64::seed(seed);
    let tokens: Vec<i32> = (0..batch * t).map(|_| rng.below(dims.vocab as u64) as i32).collect();
    println!(
        "learn_spec: {} sites on latmix-tiny ({} b{}), steps={} lr={}",
        sites.len(),
        cfg.name,
        cfg.block_size,
        lc.steps,
        lc.lr
    );
    let (spec, reports) =
        learn_spec(&w, &sites, &tokens, batch, t, residual_layer, &capture, &cfg, &lc)?;
    let mut table = latmix::bench::Table::new(
        "learn_spec",
        "Per-site E(T): learned vs fixed baselines",
        &["site", "dim", "block", "learned", "identity", "hadamard", "vs identity", "cond"],
    );
    for r in &reports {
        table.row(vec![
            r.site.key(),
            r.dim.to_string(),
            r.block.to_string(),
            format!("{:.6}", r.e_learned),
            format!("{:.6}", r.e_identity),
            r.e_hadamard.map_or("-".into(), |e| format!("{e:.6}")),
            format!("{:.2}x", r.e_identity / r.e_learned.max(1e-12)),
            format!("{:.1}", r.cond),
        ]);
    }
    table.emit();
    if let Some(path) = args.opt("save-spec") {
        spec.save(Path::new(path))?;
        println!("spec ({} sites: {}) -> {path}", spec.len(), spec.site_list());
        println!("next: latmix fold --weights TAG --spec {path} --out DIR");
    }
    Ok(())
}

/// `latmix fold` — bake a learned `TransformSpec` into an `.lxt` weight
/// set (App. B/C algebra, `TransformSpec::fold_into`) and write a
/// version-2 artifact directory: folded weights, a manifest annotated with
/// the folded sites, and the online transform remainder (FfnDown forwards)
/// the native serving path applies. `serve --backend native` against the
/// output directory serves logits matching the unfolded reference to float
/// association error — the parity gate in `rust/tests/spec_pipeline.rs`.
fn fold(args: &Args) -> Result<()> {
    let d = desc()?;
    let wtag = args.opt("weights").context("--weights required")?;
    let spec_path = args
        .opt("spec")
        .context("--spec required (an .lxt from `latmix learn --sites ... --save-spec`)")?;
    let out = args.opt("out").context("--out required")?;
    let out_tag = args.opt("tag").unwrap_or(wtag);
    let ws = WeightSet::load(&d, wtag)?;
    let dims = NativeDims::from_desc(&d);
    let weights = NativeWeights::from_weight_set(dims, &d.weight_order, &ws)?;
    let spec = TransformSpec::load(Path::new(spec_path))?;
    spec.validate(&dims)?;
    let (folded, online) = spec.fold_into(&weights)?;

    let out_dir = PathBuf::from(out);
    std::fs::create_dir_all(out_dir.join("weights"))
        .with_context(|| format!("create {out_dir:?}/weights"))?;
    let (order, fws) = folded.to_weight_set(out_tag);
    let wpath = out_dir.join("weights").join(format!("{out_tag}.lxt"));
    fws.save(&wpath, &order)?;
    let mut out_desc = d.clone();
    out_desc.artifacts = out_dir.clone();
    out_desc.weight_order = order;
    out_desc.transform_folded = Some(spec.site_list());
    // pin the tensor-parallel shard plan (additive version-2 keys) so
    // `serve --workers N` slices this artifact identically on every host
    out_desc.shard_attn = Some("head".to_string());
    out_desc.shard_ffn_block = Some(ShardPlan::default_ffn_block(d.d_ff));
    out_desc.transform_online = if online.is_empty() {
        None
    } else {
        std::fs::create_dir_all(out_dir.join("transforms"))?;
        online.save(&out_dir.join("transforms").join("online.lxt"))?;
        Some("transforms/online.lxt".to_string())
    };
    out_desc.write_manifest(&out_dir)?;
    // carry the eval datasets over (when present) so `latmix eval` works
    // against the folded directory too
    let eval_src = d.artifacts.join("eval");
    if eval_src.is_dir() {
        std::fs::create_dir_all(out_dir.join("eval"))?;
        for e in std::fs::read_dir(&eval_src)?.flatten() {
            std::fs::copy(e.path(), out_dir.join("eval").join(e.file_name()))?;
        }
    }
    println!(
        "folded {} site(s) [{}] of {spec_path} into {}",
        spec.len(),
        spec.site_list(),
        wpath.display()
    );
    if online.is_empty() {
        println!("online remainder: none (fully folded)");
    } else {
        println!("online remainder: [{}] -> transforms/online.lxt", online.site_list());
    }
    println!(
        "serve it: LATMIX_ARTIFACTS={} latmix serve --weights {out_tag} --quant <TAG> --backend native",
        out_dir.display()
    );
    Ok(())
}

fn quantize_info(args: &Args) -> Result<()> {
    let d = desc()?;
    let wtag = args.opt("weights").context("--weights required")?;
    let fmt = args.opt("format").unwrap_or("mxfp4");
    let ws = WeightSet::load(&d, wtag)?;
    let cfg = MxConfig::from_name(fmt, None)?;
    let mut total_f32 = 0usize;
    let mut total_packed = 0usize;
    for (name, t) in d.weight_order.iter().zip(&ws.tensors) {
        if let Ok(data) = t.as_f32() {
            total_f32 += data.len() * 4;
            // pack 2-D block-linear weights only (dims divisible by block)
            if t.dims.len() == 2 && data.len() % cfg.block_size == 0 && name.contains("w") {
                let packed = PackedMx::pack(data, cfg);
                total_packed += packed.bytes();
            } else {
                total_packed += data.len() * 4;
            }
        }
    }
    println!(
        "weights={wtag} params={} f32={:.2}MiB packed({})={:.2}MiB ratio={:.2}x",
        ws.param_count,
        total_f32 as f64 / (1 << 20) as f64,
        fmt,
        total_packed as f64 / (1 << 20) as f64,
        total_f32 as f64 / total_packed as f64
    );
    Ok(())
}
