//! `latmix` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info                          artifact + model summary
//!   eval   --weights TAG --quant TAG [--ppl-only] [--backend B]
//!   serve  --weights TAG --quant TAG [--requests N] [--slots N] [--max-new N] [--backend B]
//!   quantize-info --weights TAG   MX footprint accounting
//!   variants                      list available weight variants
//!
//! `--backend` picks the execution backend: `xla` (PJRT, needs the
//! `backend-xla` build feature — the default when available) or `native`
//! (pure-Rust interpreter, works on any machine).

use anyhow::{Context, Result};

use latmix::cli::Args;
use latmix::data::{load_ppl_corpus, load_tasks};
use latmix::eval::{perplexity, zero_shot};
use latmix::model::{ModelDesc, WeightSet};
use latmix::mx::{MxConfig, pack::PackedMx};
use latmix::runtime::{Backend, NativeBackend};
#[cfg(feature = "backend-xla")]
use latmix::runtime::Runtime;
use latmix::server::run_serving_native;
#[cfg(feature = "backend-xla")]
use latmix::server::run_serving;
use latmix::server::ServeReport;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("info") => info(),
        Some("variants") => variants(),
        Some("eval") => eval(&args),
        Some("serve") => serve(&args),
        Some("quantize-info") => quantize_info(&args),
        _ => {
            eprintln!(
                "usage: latmix <info|variants|eval|serve|quantize-info> [options]\n\
                 \n\
                 eval   --weights TAG --quant TAG [--ppl-only] [--backend xla|native]\n\
                 serve  --weights TAG --quant TAG [--requests N] [--slots N] [--max-new N] [--backend xla|native]\n\
                 quantize-info --weights TAG [--format mxfp4]"
            );
            Ok(())
        }
    }
}

fn desc() -> Result<ModelDesc> {
    let art = latmix::artifacts_dir();
    ModelDesc::load(&art).with_context(|| format!("load manifest from {art:?} (run `make artifacts` first)"))
}

/// The backend to use: explicit `--backend`, else XLA when compiled in.
fn backend_name(args: &Args) -> &str {
    args.opt("backend").unwrap_or(if cfg!(feature = "backend-xla") {
        "xla"
    } else {
        "native"
    })
}

fn unknown_backend(name: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "unknown backend {name:?} (this build supports: native{})",
        if cfg!(feature = "backend-xla") { ", xla" } else { "" }
    )
}

fn info() -> Result<()> {
    let d = desc()?;
    println!("latmix-tiny: d_model={} layers={} heads={} d_ff={} vocab={}", d.d_model, d.n_layers, d.n_heads, d.d_ff, d.vocab);
    println!("kv_seq={} prefill_len={} graphs={}", d.kv_seq, d.prefill_len, d.graphs.len());
    if cfg!(feature = "backend-xla") {
        println!("backends: xla (default), native");
    } else {
        println!("backends: native (built without backend-xla)");
    }
    for g in &d.graphs {
        println!("  graph {g}");
    }
    Ok(())
}

fn variants() -> Result<()> {
    let d = desc()?;
    for v in WeightSet::available(&d) {
        println!("{v}");
    }
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let d = desc()?;
    match backend_name(args) {
        "native" => eval_on(&NativeBackend::new(d), args),
        #[cfg(feature = "backend-xla")]
        "xla" => eval_on(&Runtime::new(d)?, args),
        other => Err(unknown_backend(other)),
    }
}

fn eval_on<B: Backend>(rt: &B, args: &Args) -> Result<()> {
    let wtag = args.opt("weights").context("--weights required")?;
    let qtag = args.opt("quant").unwrap_or("fp");
    let ws = WeightSet::load(rt.desc(), wtag)?;
    let art = latmix::artifacts_dir();
    let (corpus, n, t) = load_ppl_corpus(&art)?;
    let ppl = perplexity(rt, qtag, &ws, &corpus, n, t)?;
    println!("backend={} weights={wtag} quant={qtag} ppl={ppl:.3}", rt.id());
    if !args.flag("ppl-only") {
        let tasks = load_tasks(&art)?;
        for (name, acc) in zero_shot(rt, qtag, &ws, &tasks)? {
            println!("  {name}: {:.2}%", acc * 100.0);
        }
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let d = desc()?;
    let wtag = args.opt("weights").unwrap_or("fp16").to_string();
    let qtag = args.opt("quant").unwrap_or("fp").to_string();
    let requests = args.opt_usize("requests", 16);
    let slots = args.opt_usize("slots", 8);
    let max_new = args.opt_usize("max-new", 32);
    let rep: ServeReport = match backend_name(args) {
        "native" => run_serving_native(&d, &qtag, &wtag, requests, max_new, slots, 42)?,
        #[cfg(feature = "backend-xla")]
        "xla" => {
            let rt = Runtime::new(d)?;
            run_serving(&rt, &qtag, &wtag, requests, max_new, slots, 42)?
        }
        other => return Err(unknown_backend(other)),
    };
    println!(
        "graph={} weights={} requests={} wall={:.2}s decode_tok/s={:.1} total_tok/s={:.1}",
        rep.tag, rep.weights, rep.requests, rep.wall_s, rep.decode_tok_per_s, rep.total_tok_per_s
    );
    println!(
        "ttft p50={:.1}ms p99={:.1}ms  latency p50={:.1}ms p99={:.1}ms",
        rep.ttft_p50_ms, rep.ttft_p99_ms, rep.latency_p50_ms, rep.latency_p99_ms
    );
    Ok(())
}

fn quantize_info(args: &Args) -> Result<()> {
    let d = desc()?;
    let wtag = args.opt("weights").context("--weights required")?;
    let fmt = args.opt("format").unwrap_or("mxfp4");
    let ws = WeightSet::load(&d, wtag)?;
    let cfg = MxConfig::from_name(fmt, None)?;
    let mut total_f32 = 0usize;
    let mut total_packed = 0usize;
    for (name, t) in d.weight_order.iter().zip(&ws.tensors) {
        if let Ok(data) = t.as_f32() {
            total_f32 += data.len() * 4;
            // pack 2-D block-linear weights only (dims divisible by block)
            if t.dims.len() == 2 && data.len() % cfg.block_size == 0 && name.contains("w") {
                let packed = PackedMx::pack(data, cfg);
                total_packed += packed.bytes();
            } else {
                total_packed += data.len() * 4;
            }
        }
    }
    println!(
        "weights={wtag} params={} f32={:.2}MiB packed({})={:.2}MiB ratio={:.2}x",
        ws.param_count,
        total_f32 as f64 / (1 << 20) as f64,
        fmt,
        total_packed as f64 / (1 << 20) as f64,
        total_f32 as f64 / total_packed as f64
    );
    Ok(())
}
