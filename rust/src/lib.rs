//! # latmix
//!
//! Production-grade reproduction of **LATMiX: Learnable Affine
//! Transformations for Microscaling Quantization of LLMs** as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the request-path coordinator: PJRT runtime,
//!   continuous-batching serving engine, KV-cache manager, evaluation
//!   harness, plus every substrate the paper's evaluation needs (MX format
//!   codecs, dense linear algebra, affine-transform analysis, RTN/GPTQ,
//!   and — since the `latmix` module — the Sec. 3.2 transform-learning
//!   loop itself, generalized to per-site `TransformSpec`s (global T1,
//!   per-head T2, FfnDown) that fold natively into `.lxt` weight sets:
//!   the whole learn → fold → serve loop runs without Python).
//! - **L2/L1 (python/, build-time only)** — the JAX transformer, the Pallas
//!   MX kernels, full-model KL-distillation transform learning, and the
//!   AOT lowering that produces `artifacts/` (HLO text + `.lxt` weight
//!   sets). Python never runs on the request path.
//!
//! See `ARCHITECTURE.md` at the repo root for the module map and data
//! flow.
//!
//! The offline build environment vendors only the `xla` + `anyhow` crates;
//! everything usually pulled from crates.io (CLI parsing, config, RNG,
//! property testing, bench harness, async runtime) is implemented in-repo —
//! see `DESIGN.md` §3.1.
//!
//! The `xla` dependency sits behind the default-on **`backend-xla`** cargo
//! feature. `--no-default-features` builds the pure-Rust core — the
//! `runtime::NativeBackend` eval path and the `coordinator`'s
//! `NativeExecutor` serving path interpret the same `.lxt` artifacts with
//! in-repo kernels, so every machine (stock CI runners included) can
//! build, test, and bench the quantization stack. See README §Feature
//! matrix.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod io;
pub mod latmix;
pub mod linalg;
pub mod model;
pub mod mx;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod testing;
pub mod transform;
pub mod util;

/// Repo-root-relative artifacts directory (overridable via `LATMIX_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("LATMIX_ARTIFACTS") {
        return p.into();
    }
    // Look upward from cwd for an `artifacts/manifest.txt`.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.txt").exists() || cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
