//! `artifacts/manifest.txt` parser/writer: model dimensions, graph
//! inventory, the canonical weight-argument order shared with
//! `python/compile/aot.py`, and — since manifest version 2 — the
//! transform-deployment annotations written by `latmix fold`
//! (`transform.folded`, `transform.online`).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Highest manifest version this build reads and the version it writes.
/// Version history:
/// - 1 (implicit): python AOT output — dims, graphs, weight_order.
/// - 2: adds `manifest.version` plus the optional `transform.folded`
///   (comma-joined folded site keys) and `transform.online`
///   (artifacts-relative path of the online-remainder transform spec)
///   annotations produced by `latmix fold`.
pub const MANIFEST_VERSION: usize = 2;

#[derive(Clone, Debug)]
pub struct Manifest {
    pub values: BTreeMap<String, String>,
    pub graphs: Vec<String>,
    pub weight_order: Vec<String>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let mut values = BTreeMap::new();
        let mut graphs = Vec::new();
        let mut weight_order = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else { continue };
            match k {
                "graph" => graphs.push(v.to_string()),
                "weight_order" => {
                    weight_order = v.split(',').map(|s| s.to_string()).collect()
                }
                _ => {
                    values.insert(k.to_string(), v.to_string());
                }
            }
        }
        let m = Manifest { values, graphs, weight_order };
        anyhow::ensure!(
            m.version() <= MANIFEST_VERSION,
            "{path:?}: manifest version {} is newer than this build reads ({MANIFEST_VERSION})",
            m.version()
        );
        Ok(m)
    }

    /// Manifest format version (`manifest.version`; absent = 1, the
    /// original python AOT layout).
    pub fn version(&self) -> usize {
        self.values
            .get("manifest.version")
            .and_then(|v| v.parse().ok())
            .unwrap_or(1)
    }

    pub fn int(&self, key: &str) -> Result<usize> {
        self.values
            .get(key)
            .with_context(|| format!("manifest missing {key}"))?
            .parse()
            .with_context(|| format!("manifest {key} not an int"))
    }

    pub fn has_graph(&self, name: &str) -> bool {
        self.graphs.iter().any(|g| g == name)
    }

    /// Write the manifest back out (always stamps the current
    /// [`MANIFEST_VERSION`]). Round-trips through [`Manifest::load`].
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        writeln!(f, "manifest.version={MANIFEST_VERSION}")?;
        for (k, v) in &self.values {
            if k != "manifest.version" {
                writeln!(f, "{k}={v}")?;
            }
        }
        if !self.weight_order.is_empty() {
            writeln!(f, "weight_order={}", self.weight_order.join(","))?;
        }
        for g in &self.graphs {
            writeln!(f, "graph={g}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest() {
        let tmp = std::env::temp_dir().join("latmix_manifest_test.txt");
        std::fs::write(
            &tmp,
            "model.d_model=128\nkv_seq=160\nweight_order=embed,lnf\ngraph=decode_fp_b1\ngraph=logits_ppl_fp\n",
        )
        .unwrap();
        let m = Manifest::load(&tmp).unwrap();
        assert_eq!(m.int("model.d_model").unwrap(), 128);
        assert_eq!(m.weight_order, vec!["embed", "lnf"]);
        assert!(m.has_graph("decode_fp_b1"));
        assert!(!m.has_graph("nope"));
        // no manifest.version key: the original python layout, version 1
        assert_eq!(m.version(), 1);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn save_load_roundtrip_stamps_version() {
        let tmp = std::env::temp_dir().join("latmix_manifest_rt_test.txt");
        let mut values = BTreeMap::new();
        values.insert("model.d_model".to_string(), "64".to_string());
        values.insert("transform.folded".to_string(), "t1,t2.0.1".to_string());
        let m = Manifest {
            values,
            graphs: vec!["decode_fp_b1".to_string(), "decode_fp_b4".to_string()],
            weight_order: vec!["embed".to_string(), "lnf".to_string()],
        };
        m.save(&tmp).unwrap();
        let back = Manifest::load(&tmp).unwrap();
        assert_eq!(back.version(), MANIFEST_VERSION);
        assert_eq!(back.int("model.d_model").unwrap(), 64);
        assert_eq!(back.values.get("transform.folded").unwrap(), "t1,t2.0.1");
        assert_eq!(back.weight_order, m.weight_order);
        assert_eq!(back.graphs, m.graphs);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn future_version_rejected() {
        let tmp = std::env::temp_dir().join("latmix_manifest_future_test.txt");
        std::fs::write(&tmp, format!("manifest.version={}\n", MANIFEST_VERSION + 1)).unwrap();
        let err = Manifest::load(&tmp).unwrap_err();
        assert!(err.to_string().contains("newer"), "{err}");
        std::fs::remove_file(&tmp).ok();
    }
}
