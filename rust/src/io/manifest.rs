//! `artifacts/manifest.txt` parser: model dimensions, graph inventory, and
//! the canonical weight-argument order shared with `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

#[derive(Clone, Debug)]
pub struct Manifest {
    pub values: BTreeMap<String, String>,
    pub graphs: Vec<String>,
    pub weight_order: Vec<String>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let mut values = BTreeMap::new();
        let mut graphs = Vec::new();
        let mut weight_order = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else { continue };
            match k {
                "graph" => graphs.push(v.to_string()),
                "weight_order" => {
                    weight_order = v.split(',').map(|s| s.to_string()).collect()
                }
                _ => {
                    values.insert(k.to_string(), v.to_string());
                }
            }
        }
        Ok(Manifest { values, graphs, weight_order })
    }

    pub fn int(&self, key: &str) -> Result<usize> {
        self.values
            .get(key)
            .with_context(|| format!("manifest missing {key}"))?
            .parse()
            .with_context(|| format!("manifest {key} not an int"))
    }

    pub fn has_graph(&self, name: &str) -> bool {
        self.graphs.iter().any(|g| g == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest() {
        let tmp = std::env::temp_dir().join("latmix_manifest_test.txt");
        std::fs::write(
            &tmp,
            "model.d_model=128\nkv_seq=160\nweight_order=embed,lnf\ngraph=decode_fp_b1\ngraph=logits_ppl_fp\n",
        )
        .unwrap();
        let m = Manifest::load(&tmp).unwrap();
        assert_eq!(m.int("model.d_model").unwrap(), 128);
        assert_eq!(m.weight_order, vec!["embed", "lnf"]);
        assert!(m.has_graph("decode_fp_b1"));
        assert!(!m.has_graph("nope"));
        std::fs::remove_file(&tmp).ok();
    }
}
