//! Artifact I/O: the `.lxt` tensor container and the build manifest.

pub mod lxt;
pub mod manifest;

pub use lxt::{load_lxt, save_lxt, Tensor};
pub use manifest::{Manifest, MANIFEST_VERSION};
