//! Artifact I/O: the `.lxt` tensor container and the build manifest.
//!
//! `.lxt` weight sets are f32 on disk in every storage mode — the
//! `--packed-weights` serving path re-packs linear weights into MX bytes
//! at executor construction ([`crate::coordinator::engine::NativeExecutor`]
//! `::into_packed`), never in the artifact container, so one artifact
//! serves both the dense and packed modes.

pub mod lxt;
pub mod manifest;

pub use lxt::{load_lxt, save_lxt, Tensor};
pub use manifest::{Manifest, MANIFEST_VERSION};
