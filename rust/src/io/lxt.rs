//! `.lxt` — the LATMiX tensor container (Rust reader/writer).
//!
//! Byte-level contract with `python/compile/lxt.py` (little-endian):
//!
//! ```text
//! magic  b"LXT1"
//! u32    n_tensors
//! per tensor:
//!   u16  name_len, name (utf-8)
//!   u8   dtype (0 = f32, 1 = i32)
//!   u8   ndim
//!   u32 * ndim  dims
//!   raw  data
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A named dense tensor (f32 or i32).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data: TensorData::I32(data) }
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }
}

const MAGIC: &[u8; 4] = b"LXT1";

pub fn save_lxt(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        let dt: u8 = match t.data {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
        };
        f.write_all(&[dt, t.dims.len() as u8])?;
        for d in &t.dims {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

pub fn load_lxt(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let raw = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
    let mut cur = std::io::Cursor::new(raw);
    let mut magic = [0u8; 4];
    cur.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let n = read_u32(&mut cur)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = read_u16(&mut cur)? as usize;
        let mut nb = vec![0u8; name_len];
        cur.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)?;
        let mut hdr = [0u8; 2];
        cur.read_exact(&mut hdr)?;
        let (dt, ndim) = (hdr[0], hdr[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut cur)? as usize);
        }
        let count: usize = if ndim == 0 { 1 } else { dims.iter().product() };
        let data = match dt {
            0 => {
                let mut v = vec![0f32; count];
                let mut buf = vec![0u8; count * 4];
                cur.read_exact(&mut buf)?;
                for (i, c) in buf.chunks_exact(4).enumerate() {
                    v[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                TensorData::F32(v)
            }
            1 => {
                let mut v = vec![0i32; count];
                let mut buf = vec![0u8; count * 4];
                cur.read_exact(&mut buf)?;
                for (i, c) in buf.chunks_exact(4).enumerate() {
                    v[i] = i32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                TensorData::I32(v)
            }
            other => bail!("{path:?}: unknown dtype {other}"),
        };
        out.insert(name, Tensor { dims, data });
    }
    Ok(out)
}

fn read_u32(c: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    c.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(c: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    c.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.5, 1e-7]));
        m.insert("b".into(), Tensor::i32(vec![4], vec![1, -2, 3, 4]));
        let tmp = std::env::temp_dir().join("latmix_lxt_test.lxt");
        save_lxt(&tmp, &m).unwrap();
        let back = load_lxt(&tmp).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(&tmp).ok();
    }
}
