//! Thread-local recycling arena for decode-step temporaries.
//!
//! The decode hot path (`model/forward.rs`, `linalg/packed.rs`,
//! `coordinator/engine.rs`) needs a handful of short-lived `Vec<f32>`
//! buffers per token step: linear outputs, attention score rows, packed
//! decode panels, fresh KV rows. Allocating them per step is the single
//! largest source of steady-state allocator traffic, so they are checked
//! out of a per-thread freelist instead:
//!
//! * [`take`] returns a zero-filled `Vec<f32>` of the requested length,
//!   reusing the best-fitting recycled buffer (smallest capacity that
//!   already holds `len`, else the largest available so one `resize`
//!   upgrades it in place).
//! * [`give`] returns a buffer to the freelist for the next step.
//!
//! `take(len)` is observably identical to `vec![0.0f32; len]` — callers
//! that forget to `give` merely allocate, which is exactly what the
//! counting-allocator regression test (`rust/tests/alloc_steady_state.rs`)
//! is there to catch. The freelist is thread-local on purpose: the
//! persistent `util::par::WorkerPool` threads keep their arenas warm
//! across steps, which is what lets parallel stages (packed GEMM panels)
//! hit the zero-allocation steady state; scoped fallback threads die after
//! each stage and start cold.
//!
//! Capacity discipline: buffer sizes in a serving process are drawn from a
//! small fixed set (model dims x bucket sizes), so the freelist converges
//! after a warmup step or two and is capped at [`MAX_FREE`] entries per
//! thread to bound worst-case retention.

use std::cell::RefCell;

/// Per-thread freelist cap (buffers, not bytes). Decode needs well under
/// this many live temporaries per step; anything beyond it is freed.
const MAX_FREE: usize = 64;

thread_local! {
    static F32_FREE: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
    static ROWS_FREE: RefCell<Vec<Vec<Vec<f32>>>> = RefCell::new(Vec::new());
}

/// Check a zero-filled `Vec<f32>` of length `len` out of the calling
/// thread's arena. Behaves exactly like `vec![0.0f32; len]`; pair with
/// [`give`] to recycle the buffer once it is dead.
pub fn take(len: usize) -> Vec<f32> {
    if len == 0 {
        return Vec::new();
    }
    let mut v = F32_FREE.with(|c| {
        let mut free = c.borrow_mut();
        if free.is_empty() {
            return Vec::new();
        }
        // Best fit: smallest capacity >= len; else the largest buffer, so
        // the in-place `resize` below upgrades the arena toward the
        // working set's true high-water marks.
        let mut best = 0usize;
        for i in 1..free.len() {
            let (cap, best_cap) = (free[i].capacity(), free[best].capacity());
            let better = if best_cap >= len {
                cap >= len && cap < best_cap
            } else {
                cap > best_cap
            };
            if better {
                best = i;
            }
        }
        free.swap_remove(best)
    });
    v.clear();
    v.resize(len, 0.0);
    v
}

/// Return a buffer taken with [`take`] (or any plain `Vec<f32>`) to the
/// calling thread's arena. Contents are discarded; capacity is kept.
pub fn give(mut v: Vec<f32>) {
    if v.capacity() == 0 {
        return;
    }
    v.clear();
    F32_FREE.with(|c| {
        let mut free = c.borrow_mut();
        if free.len() < MAX_FREE {
            free.push(v);
        }
    });
}

/// Check out an empty `Vec<Vec<f32>>` with capacity for at least `n`
/// inner rows. Callers fill it with [`take`]n rows and hand the whole
/// thing back with [`give_rows`].
pub fn take_rows(n: usize) -> Vec<Vec<f32>> {
    let mut outer: Vec<Vec<f32>> =
        ROWS_FREE.with(|c| c.borrow_mut().pop()).unwrap_or_default();
    outer.clear();
    outer.reserve(n);
    outer
}

/// Return a row set from [`take_rows`]: inner rows go back to the `f32`
/// freelist, the outer vec keeps its capacity for the next step.
pub fn give_rows(mut rows: Vec<Vec<f32>>) {
    for r in rows.drain(..) {
        give(r);
    }
    ROWS_FREE.with(|c| {
        let mut free = c.borrow_mut();
        if free.len() < MAX_FREE {
            free.push(rows);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_like_vec_macro() {
        let mut v = take(8);
        assert_eq!(v, vec![0.0f32; 8]);
        v.iter_mut().for_each(|x| *x = 7.0);
        give(v);
        // Recycled buffer comes back zeroed at the requested length.
        let v2 = take(5);
        assert_eq!(v2, vec![0.0f32; 5]);
        give(v2);
    }

    #[test]
    fn best_fit_prefers_tightest_capacity() {
        give(Vec::with_capacity(100));
        give(Vec::with_capacity(10));
        give(Vec::with_capacity(40));
        let v = take(30);
        assert_eq!(v.capacity(), 40, "smallest capacity >= len wins");
        give(v);
    }

    #[test]
    fn undersized_arena_grows_largest_buffer() {
        // Drain this thread's arena so the test owns its contents.
        loop {
            let v = take(1);
            if v.capacity() <= 1 {
                break;
            }
            // Buffer came from a prior test; drop it on the floor.
            drop(v);
        }
        give(Vec::with_capacity(4));
        give(Vec::with_capacity(16));
        let v = take(64);
        assert_eq!(v.len(), 64);
        assert!(v.capacity() >= 64, "largest buffer is resized in place");
        give(v);
    }

    #[test]
    fn rows_roundtrip_recycles_inners() {
        let mut rows = take_rows(3);
        for _ in 0..3 {
            rows.push(take(32));
        }
        give_rows(rows);
        let again = take_rows(3);
        assert!(again.capacity() >= 3);
        assert!(again.is_empty());
        // Inners were recycled into the f32 freelist.
        let r = take(32);
        assert!(r.capacity() >= 32);
        give(r);
        give_rows(again);
    }

    #[test]
    fn zero_len_take_leaves_arena_alone() {
        give(Vec::with_capacity(8));
        let v = take(0);
        assert_eq!(v.capacity(), 0);
        let w = take(8);
        assert!(w.capacity() >= 8);
        give(w);
    }
}
