//! PCG-64 (XSL-RR) pseudo-random generator + the distributions this crate
//! needs. The `rand` crate is not vendorable offline; this is a minimal,
//! deterministic, well-tested replacement (O'Neill 2014).

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xor-shift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seeded constructor; distinct `stream` values give independent streams.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, n)` (Lemire-style rejection-free for our sizes).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // widening multiply keeps bias < 2^-64 * n — negligible here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Fill with i.i.d. N(0, sigma^2).
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * sigma).collect()
    }

    /// Random permutation of 0..n (Fisher-Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seed(7);
        let mut b = Pcg64::seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg64::seed(1);
        let m: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(2);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg64::seed(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Pcg64::seed(4);
        let mut p = r.permutation(50);
        p.sort_unstable();
        assert_eq!(p, (0..50).collect::<Vec<_>>());
    }
}
