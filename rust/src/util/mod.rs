//! Small utilities: deterministic RNG, summary statistics, the fork-join
//! substrate (persistent worker pool + scoped fallback), and the recycling
//! scratch arena used by the decode hot paths.

pub mod par;
pub mod rng;
pub mod scratch;
pub mod stats;

pub use rng::Pcg64;
pub use stats::Summary;
