//! Small utilities: deterministic RNG and summary statistics.

pub mod rng;
pub mod stats;

pub use rng::Pcg64;
pub use stats::Summary;
