//! Small utilities: deterministic RNG, summary statistics, and the scoped
//! thread pool used by the quantization hot paths.

pub mod par;
pub mod rng;
pub mod stats;

pub use rng::Pcg64;
pub use stats::Summary;
