//! Scoped data-parallel substrate (rayon is not vendorable offline).
//!
//! The quantization hot paths (MX QDQ, pack/unpack, RTN/GPTQ, KV
//! gather/scatter) all reduce to "apply an independent kernel to disjoint
//! chunks of one buffer". [`for_each_chunk`] and [`for_each_chunk2`] fan
//! those chunks out over `std::thread::scope` workers. The partition is
//! deterministic and each chunk's computation is self-contained, so results
//! are bit-identical for any worker count — property-tested in
//! `rust/tests/codec_props.rs`.

use std::cell::Cell;

/// Buffers smaller than this (in elements) are not worth a thread spawn;
/// callers use it to keep tiny inputs on the serial path.
pub const PAR_MIN_LEN: usize = 1 << 12;

thread_local! {
    static THREAD_OVERRIDE: Cell<Option<usize>> = Cell::new(None);
}

/// Worker count: [`with_threads`] override > `LATMIX_THREADS` env >
/// available parallelism.
pub fn num_threads() -> usize {
    if let Some(n) = THREAD_OVERRIDE.with(|c| c.get()) {
        return n.max(1);
    }
    if let Ok(s) = std::env::var("LATMIX_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` with the worker count pinned to `n` on the calling thread.
/// Tests use this to compare 1-thread vs N-thread runs without the races
/// of mutating process-global environment variables.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    THREAD_OVERRIDE.with(|c| {
        let prev = c.replace(Some(n));
        let out = f();
        c.set(prev);
        out
    })
}

#[inline]
fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Fork-join stage for the tensor-parallel shard workers: run
/// `f(worker_index)` on `n` scoped threads and return the results in
/// worker order. `n == 1` runs inline on the caller — the single-worker
/// shard path stays an ordinary serial call, which is what makes 1-vs-N
/// bit-parity checkable (`rust/tests/shard_parity.rs`). Unlike
/// [`for_each_chunk`] this ignores [`num_threads`]: the caller's shard
/// plan *is* the worker count.
pub fn run_workers<R: Send>(n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let n = n.max(1);
    if n == 1 {
        return vec![f(0)];
    }
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n).map(|w| s.spawn(move || f(w))).collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

/// Apply `f(chunk_index, chunk)` to consecutive `chunk_len`-sized chunks of
/// `data` (the last chunk may be shorter), fanned out over scoped worker
/// threads. Workers own contiguous runs of chunks, so side effects equal
/// the serial loop exactly for any worker count.
pub fn for_each_chunk<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0);
    let n_chunks = ceil_div(data.len(), chunk_len);
    let threads = num_threads().min(n_chunks);
    if threads <= 1 {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci, chunk);
        }
        return;
    }
    let per = ceil_div(n_chunks, threads);
    let f = &f;
    std::thread::scope(|s| {
        for (ti, span) in data.chunks_mut(per * chunk_len).enumerate() {
            s.spawn(move || {
                for (ci, chunk) in span.chunks_mut(chunk_len).enumerate() {
                    f(ti * per + ci, chunk);
                }
            });
        }
    });
}

/// Two-buffer variant: chunk `a` by `ca` and `b` by `cb` (equal chunk
/// counts required) and apply `f(chunk_index, a_chunk, b_chunk)` to each
/// pair. Used where one logical work item spans two output buffers, e.g.
/// `PackedMx::pack` writing one scale byte + `block/2` code bytes per block.
pub fn for_each_chunk2<A, B, F>(a: &mut [A], ca: usize, b: &mut [B], cb: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(ca > 0 && cb > 0);
    let n_chunks = ceil_div(a.len(), ca);
    assert_eq!(n_chunks, ceil_div(b.len(), cb), "chunk count mismatch");
    let threads = num_threads().min(n_chunks);
    if threads <= 1 {
        for (ci, (x, y)) in a.chunks_mut(ca).zip(b.chunks_mut(cb)).enumerate() {
            f(ci, x, y);
        }
        return;
    }
    let per = ceil_div(n_chunks, threads);
    let f = &f;
    std::thread::scope(|s| {
        for (ti, (sa, sb)) in a.chunks_mut(per * ca).zip(b.chunks_mut(per * cb)).enumerate() {
            s.spawn(move || {
                for (ci, (x, y)) in sa.chunks_mut(ca).zip(sb.chunks_mut(cb)).enumerate() {
                    f(ti * per + ci, x, y);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_matches_serial() {
        let n = 10_000usize;
        let mut par: Vec<u64> = (0..n as u64).collect();
        let mut ser = par.clone();
        for (ci, chunk) in ser.chunks_mut(7).enumerate() {
            for v in chunk.iter_mut() {
                *v = v.wrapping_mul(31).wrapping_add(ci as u64);
            }
        }
        with_threads(5, || {
            for_each_chunk(&mut par, 7, |ci, chunk| {
                for v in chunk.iter_mut() {
                    *v = v.wrapping_mul(31).wrapping_add(ci as u64);
                }
            });
        });
        assert_eq!(par, ser);
    }

    #[test]
    fn chunk2_pairs_align() {
        // a: 1 item per chunk; b: 4 items per chunk, last short
        let mut a = vec![0usize; 10];
        let mut b = vec![0u8; 38];
        with_threads(3, || {
            for_each_chunk2(&mut a, 1, &mut b, 4, |ci, x, y| {
                x[0] = ci * 100 + y.len();
                for v in y.iter_mut() {
                    *v = ci as u8;
                }
            });
        });
        for (ci, x) in a.iter().enumerate() {
            let expect_len = if ci == 9 { 2 } else { 4 };
            assert_eq!(*x, ci * 100 + expect_len);
        }
        assert!(b.chunks(4).enumerate().all(|(ci, c)| c.iter().all(|v| *v == ci as u8)));
    }

    #[test]
    fn empty_and_single() {
        let mut empty: Vec<u32> = Vec::new();
        for_each_chunk(&mut empty, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![1u32; 3];
        with_threads(8, || {
            for_each_chunk(&mut one, 8, |ci, c| {
                assert_eq!(ci, 0);
                for v in c.iter_mut() {
                    *v += 1;
                }
            });
        });
        assert_eq!(one, vec![2, 2, 2]);
    }

    #[test]
    fn run_workers_ordered_results() {
        // results come back in worker order, for 1 and N workers alike
        assert_eq!(run_workers(1, |w| w * 10), vec![0]);
        assert_eq!(run_workers(4, |w| w * 10), vec![0, 10, 20, 30]);
        assert_eq!(run_workers(0, |w| w), vec![0], "clamped to 1");
    }

    #[test]
    fn override_pins_count() {
        assert_eq!(with_threads(3, num_threads), 3);
        assert_eq!(with_threads(0, num_threads), 1); // clamped
        assert!(num_threads() >= 1);
    }
}
