//! Deterministic data-parallel substrate (rayon is not vendorable offline).
//!
//! The quantization hot paths (MX QDQ, pack/unpack, RTN/GPTQ, KV
//! gather/scatter) all reduce to "apply an independent kernel to disjoint
//! chunks of one buffer". [`for_each_chunk`] and [`for_each_chunk2`] fan
//! those chunks out over worker threads. The partition is deterministic and
//! each chunk's computation is self-contained, so results are bit-identical
//! for any worker count — property-tested in `rust/tests/codec_props.rs`.
//!
//! Two execution substrates share that partition:
//!
//! * **Scoped fallback** — `std::thread::scope` spawns fresh OS threads per
//!   fork-join stage. Always available; used when no pool is installed.
//! * **[`WorkerPool`]** — long-lived threads parked on a condvar, installed
//!   ambiently with [`with_pool`]. A fork-join [`WorkerPool::run_on`]
//!   dispatches task indices to the same spans the scoped path would have
//!   spawned, so switching substrates cannot change any result bit. The
//!   serving executor owns one pool and installs it around every step; pool
//!   threads also keep the `util::scratch` thread-local arenas warm, which
//!   is what makes the zero-allocation decode steady state possible
//!   (scoped threads die after each stage and take their arenas with them).
//!
//! Work is assigned by *task index*, never by arrival order: span `ti`
//! always covers chunks `[ti * per, (ti + 1) * per)`. Any executor that
//! preserves the index → work mapping is bit-identical to the serial loop,
//! which is why the pool carries every existing parity gate (codec_props
//! thread-determinism, shard_parity 1-vs-N, packed-vs-dense) unchanged.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Buffers smaller than this (in elements) are not worth a thread spawn;
/// callers use it to keep tiny inputs on the serial path.
pub const PAR_MIN_LEN: usize = 1 << 12;

thread_local! {
    static THREAD_OVERRIDE: Cell<Option<usize>> = Cell::new(None);
    static CURRENT_POOL: RefCell<Option<Arc<PoolInner>>> = RefCell::new(None);
}

static DEFAULT_THREADS: OnceLock<usize> = OnceLock::new();
static LIVE_POOL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// `LATMIX_THREADS` env > available parallelism, resolved once per process.
/// `num_threads()` is called inside per-block codec loops and the decode
/// hot path, where a per-call `std::env::var` both takes a lock and
/// allocates; the env is only ever set at process launch (CI matrix), so
/// caching cannot change observable behavior.
fn default_threads() -> usize {
    *DEFAULT_THREADS.get_or_init(|| {
        if let Ok(s) = std::env::var("LATMIX_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Worker count: [`with_threads`] override > `LATMIX_THREADS` env >
/// available parallelism (the latter two cached in a `OnceLock`).
pub fn num_threads() -> usize {
    if let Some(n) = THREAD_OVERRIDE.with(|c| c.get()) {
        return n.max(1);
    }
    default_threads()
}

/// Run `f` with the worker count pinned to `n` on the calling thread.
/// Tests use this to compare 1-thread vs N-thread runs without the races
/// of mutating process-global environment variables.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    THREAD_OVERRIDE.with(|c| {
        let prev = c.replace(Some(n));
        let out = f();
        c.set(prev);
        out
    })
}

#[inline]
fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

/// Type-erased pointer to the current fork-join closure. Only dereferenced
/// by workers between job post and join, while the closure is guaranteed
/// alive on the dispatching thread's stack (`pool_run` blocks until
/// `remaining == 0`).
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared &-access from many threads is fine)
// and the pointer never outlives the blocking dispatch that created it.
unsafe impl Send for JobPtr {}

struct JobSlot {
    /// Incremented per dispatched job; workers compare against the last
    /// epoch they executed so one `notify_all` cannot double-run a task.
    epoch: u64,
    job: Option<JobPtr>,
    n_tasks: usize,
    remaining: usize,
    shutdown: bool,
    panicked: bool,
}

struct PoolInner {
    job: Mutex<JobSlot>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The dispatcher parks here until `remaining == 0`.
    done: Condvar,
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Serializes concurrent dispatchers (e.g. two engines sharing a cloned
    /// executor) over the single job slot.
    dispatch: Mutex<()>,
}

fn worker_loop(inner: Arc<PoolInner>, w: usize) {
    LIVE_POOL_THREADS.fetch_add(1, Ordering::SeqCst);
    let mut seen = 0u64;
    loop {
        let (ptr, epoch) = {
            let mut g = inner.job.lock().unwrap();
            loop {
                if g.shutdown {
                    drop(g);
                    LIVE_POOL_THREADS.fetch_sub(1, Ordering::SeqCst);
                    return;
                }
                if g.epoch != seen && w < g.n_tasks {
                    if let Some(ptr) = g.job {
                        break (ptr, g.epoch);
                    }
                }
                g = inner.work.wait(g).unwrap();
            }
        };
        seen = epoch;
        // SAFETY: see `JobPtr` — the closure outlives this job's join.
        let f = unsafe { &*ptr.0 };
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(w))).is_ok();
        let mut g = inner.job.lock().unwrap();
        if !ok {
            g.panicked = true;
        }
        g.remaining -= 1;
        if g.remaining == 0 {
            inner.done.notify_all();
        }
    }
}

/// Grow the pool to at least `n` parked workers. Worker `w`'s index is
/// fixed at spawn, so task → thread assignment is stable for the pool's
/// lifetime. Spawning only happens the first time a larger fan-out is
/// requested; the steady state parks and wakes existing threads.
fn ensure_workers(inner: &Arc<PoolInner>, n: usize) {
    let mut handles = inner.handles.lock().unwrap();
    while handles.len() < n {
        let w = handles.len();
        let arc = Arc::clone(inner);
        let h = std::thread::Builder::new()
            .name(format!("latmix-pool-{w}"))
            .spawn(move || worker_loop(arc, w))
            .expect("spawn pool worker");
        handles.push(h);
    }
}

/// Fork-join on the pool: post `f` as tasks `0..n_tasks`, wake the parked
/// workers, block until every task has finished. Worker `w` runs exactly
/// task `w`, mirroring the scoped path's spawn-per-span assignment.
fn pool_run(inner: &Arc<PoolInner>, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    if n_tasks == 0 {
        return;
    }
    let _serial = inner.dispatch.lock().unwrap();
    ensure_workers(inner, n_tasks);
    {
        let mut g = inner.job.lock().unwrap();
        g.epoch += 1;
        g.job = Some(JobPtr(f as *const _));
        g.n_tasks = n_tasks;
        g.remaining = n_tasks;
        g.panicked = false;
        inner.work.notify_all();
    }
    let mut g = inner.job.lock().unwrap();
    while g.remaining > 0 {
        g = inner.done.wait(g).unwrap();
    }
    g.job = None;
    let panicked = g.panicked;
    drop(g);
    if panicked {
        // Matches the scoped substrate, where a worker panic propagates
        // through the join on the dispatching thread.
        panic!("worker pool task panicked");
    }
}

/// Long-lived fork-join pool: threads are spawned lazily on first use,
/// parked on a condvar between jobs, and joined on drop. Hold it in an
/// `Arc` and install it ambiently with [`with_pool`] (or
/// [`WorkerPool::install`]) to route [`for_each_chunk`],
/// [`for_each_chunk2`], and [`run_workers`] onto persistent threads
/// instead of per-stage `std::thread::scope` spawns.
pub struct WorkerPool {
    inner: Arc<PoolInner>,
}

impl WorkerPool {
    /// Create an empty pool; workers are spawned on demand by the first
    /// fork-join that needs them and reused afterwards.
    pub fn new() -> Self {
        WorkerPool {
            inner: Arc::new(PoolInner {
                job: Mutex::new(JobSlot {
                    epoch: 0,
                    job: None,
                    n_tasks: 0,
                    remaining: 0,
                    shutdown: false,
                    panicked: false,
                }),
                work: Condvar::new(),
                done: Condvar::new(),
                handles: Mutex::new(Vec::new()),
                dispatch: Mutex::new(()),
            }),
        }
    }

    /// Fork-join: run `f(task_index)` for every index in `0..n_tasks` on
    /// pool workers and return once all have finished. Task `w` always
    /// runs on worker `w` — the index → work mapping is the contract that
    /// keeps pool execution bit-identical to the scoped substrate.
    pub fn run_on(&self, n_tasks: usize, f: impl Fn(usize) + Sync) {
        pool_run(&self.inner, n_tasks, &f);
    }

    /// Number of spawned (live) worker threads.
    pub fn size(&self) -> usize {
        self.inner.handles.lock().unwrap().len()
    }

    /// Run `f` with this pool installed as the calling thread's fork-join
    /// substrate. Shorthand for `with_pool(Some(self), f)`.
    pub fn install<R>(&self, f: impl FnOnce() -> R) -> R {
        with_pool(Some(self), f)
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::new()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut g = self.inner.job.lock().unwrap();
            g.shutdown = true;
            self.inner.work.notify_all();
        }
        let handles = std::mem::take(&mut *self.inner.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Number of pool worker threads currently alive across all pools.
/// [`WorkerPool`]'s drop joins its workers, so after the last clone of a
/// pool is dropped this reflects the decrement — used by the pool
/// lifecycle tests to prove engines do not leak threads.
pub fn live_pool_threads() -> usize {
    LIVE_POOL_THREADS.load(Ordering::SeqCst)
}

/// Install `pool` (or clear the installation with `None`) as the calling
/// thread's fork-join substrate for the duration of `f`. Nested installs
/// restore the previous substrate on exit, including on unwind. Installing
/// is allocation-free (an `Arc` refcount bump), so the serving executor
/// can wrap every step without disturbing the zero-allocation gate.
pub fn with_pool<R>(pool: Option<&WorkerPool>, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<Arc<PoolInner>>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0.take();
            CURRENT_POOL.with(|c| *c.borrow_mut() = prev);
        }
    }
    let next = pool.map(|p| Arc::clone(&p.inner));
    let prev = CURRENT_POOL.with(|c| std::mem::replace(&mut *c.borrow_mut(), next));
    let _restore = Restore(prev);
    f()
}

fn current_pool_inner() -> Option<Arc<PoolInner>> {
    CURRENT_POOL.with(|c| c.borrow().clone())
}

// ---------------------------------------------------------------------------
// Fork-join entry points
// ---------------------------------------------------------------------------

/// Fork-join stage for the tensor-parallel shard workers: run
/// `f(worker_index)` on `n` workers and return the results in worker
/// order. `n == 1` runs inline on the caller — the single-worker shard
/// path stays an ordinary serial call, which is what makes 1-vs-N
/// bit-parity checkable (`rust/tests/shard_parity.rs`). Unlike
/// [`for_each_chunk`] this ignores [`num_threads`]: the caller's shard
/// plan *is* the worker count. Runs on the installed [`WorkerPool`] when
/// one is present, scoped threads otherwise; worker `w` computes the same
/// result either way.
pub fn run_workers<R: Send>(n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let n = n.max(1);
    if n == 1 {
        return vec![f(0)];
    }
    if let Some(pool) = current_pool_inner() {
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let base = slots.as_mut_ptr() as usize;
        let f = &f;
        let task = move |w: usize| {
            // SAFETY: each task writes only slot `w` (disjoint), and
            // `pool_run` joins all tasks before `slots` is read or freed.
            let slot = unsafe { &mut *(base as *mut Option<R>).add(w) };
            *slot = Some(f(w));
        };
        pool_run(&pool, n, &task);
        return slots
            .into_iter()
            .map(|s| s.expect("pool worker result missing"))
            .collect();
    }
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n).map(|w| s.spawn(move || f(w))).collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

/// Apply `f(chunk_index, chunk)` to consecutive `chunk_len`-sized chunks of
/// `data` (the last chunk may be shorter), fanned out over worker threads.
/// Workers own contiguous runs of chunks, so side effects equal the serial
/// loop exactly for any worker count — and for either substrate: span `ti`
/// covers chunks `[ti * per, (ti + 1) * per)` whether it lands on a scoped
/// thread or a parked pool worker.
pub fn for_each_chunk<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0);
    let n_chunks = ceil_div(data.len(), chunk_len);
    let threads = num_threads().min(n_chunks);
    if threads <= 1 {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci, chunk);
        }
        return;
    }
    let per = ceil_div(n_chunks, threads);
    if let Some(pool) = current_pool_inner() {
        let n_spans = ceil_div(n_chunks, per);
        let len = data.len();
        let base = data.as_mut_ptr() as usize;
        let f = &f;
        let task = move |ti: usize| {
            let start = ti * per * chunk_len;
            let end = (start + per * chunk_len).min(len);
            // SAFETY: spans are disjoint per task index, and `pool_run`
            // joins every task before returning, so the exclusive borrow
            // of `data` outlives all reconstructed sub-slices.
            let span = unsafe {
                std::slice::from_raw_parts_mut((base as *mut T).add(start), end - start)
            };
            for (ci, chunk) in span.chunks_mut(chunk_len).enumerate() {
                f(ti * per + ci, chunk);
            }
        };
        pool_run(&pool, n_spans, &task);
        return;
    }
    let f = &f;
    std::thread::scope(|s| {
        for (ti, span) in data.chunks_mut(per * chunk_len).enumerate() {
            s.spawn(move || {
                for (ci, chunk) in span.chunks_mut(chunk_len).enumerate() {
                    f(ti * per + ci, chunk);
                }
            });
        }
    });
}

/// Two-buffer variant: chunk `a` by `ca` and `b` by `cb` (equal chunk
/// counts required) and apply `f(chunk_index, a_chunk, b_chunk)` to each
/// pair. Used where one logical work item spans two output buffers, e.g.
/// `PackedMx::pack` writing one scale byte + `block/2` code bytes per block.
pub fn for_each_chunk2<A, B, F>(a: &mut [A], ca: usize, b: &mut [B], cb: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(ca > 0 && cb > 0);
    let n_chunks = ceil_div(a.len(), ca);
    let nb_chunks = ceil_div(b.len(), cb);
    assert_eq!(
        n_chunks, nb_chunks,
        "for_each_chunk2 chunk count mismatch: a => {} chunks ({} elems / chunk {}), \
         b => {} chunks ({} elems / chunk {})",
        n_chunks,
        a.len(),
        ca,
        nb_chunks,
        b.len(),
        cb
    );
    let threads = num_threads().min(n_chunks);
    if threads <= 1 {
        for (ci, (x, y)) in a.chunks_mut(ca).zip(b.chunks_mut(cb)).enumerate() {
            f(ci, x, y);
        }
        return;
    }
    let per = ceil_div(n_chunks, threads);
    if let Some(pool) = current_pool_inner() {
        let n_spans = ceil_div(n_chunks, per);
        let (la, lb) = (a.len(), b.len());
        let base_a = a.as_mut_ptr() as usize;
        let base_b = b.as_mut_ptr() as usize;
        let f = &f;
        let task = move |ti: usize| {
            let (sa0, sb0) = (ti * per * ca, ti * per * cb);
            let (sa1, sb1) = ((sa0 + per * ca).min(la), (sb0 + per * cb).min(lb));
            // SAFETY: same disjoint-span argument as `for_each_chunk`,
            // applied to both buffers.
            let (sa, sb) = unsafe {
                (
                    std::slice::from_raw_parts_mut((base_a as *mut A).add(sa0), sa1 - sa0),
                    std::slice::from_raw_parts_mut((base_b as *mut B).add(sb0), sb1 - sb0),
                )
            };
            for (ci, (x, y)) in sa.chunks_mut(ca).zip(sb.chunks_mut(cb)).enumerate() {
                f(ti * per + ci, x, y);
            }
        };
        pool_run(&pool, n_spans, &task);
        return;
    }
    let f = &f;
    std::thread::scope(|s| {
        for (ti, (sa, sb)) in a.chunks_mut(per * ca).zip(b.chunks_mut(per * cb)).enumerate() {
            s.spawn(move || {
                for (ci, (x, y)) in sa.chunks_mut(ca).zip(sb.chunks_mut(cb)).enumerate() {
                    f(ti * per + ci, x, y);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pool-creating tests share this lock so `live_pool_threads()`
    /// assertions are not perturbed by a concurrently running test.
    static POOL_TEST_LOCK: Mutex<()> = Mutex::new(());

    fn pool_lock() -> std::sync::MutexGuard<'static, ()> {
        POOL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn chunk_matches_serial() {
        let n = 10_000usize;
        let mut par: Vec<u64> = (0..n as u64).collect();
        let mut ser = par.clone();
        for (ci, chunk) in ser.chunks_mut(7).enumerate() {
            for v in chunk.iter_mut() {
                *v = v.wrapping_mul(31).wrapping_add(ci as u64);
            }
        }
        with_threads(5, || {
            for_each_chunk(&mut par, 7, |ci, chunk| {
                for v in chunk.iter_mut() {
                    *v = v.wrapping_mul(31).wrapping_add(ci as u64);
                }
            });
        });
        assert_eq!(par, ser);
    }

    #[test]
    fn chunk2_pairs_align() {
        // a: 1 item per chunk; b: 4 items per chunk, last short
        let mut a = vec![0usize; 10];
        let mut b = vec![0u8; 38];
        with_threads(3, || {
            for_each_chunk2(&mut a, 1, &mut b, 4, |ci, x, y| {
                x[0] = ci * 100 + y.len();
                for v in y.iter_mut() {
                    *v = ci as u8;
                }
            });
        });
        for (ci, x) in a.iter().enumerate() {
            let expect_len = if ci == 9 { 2 } else { 4 };
            assert_eq!(*x, ci * 100 + expect_len);
        }
        assert!(b.chunks(4).enumerate().all(|(ci, c)| c.iter().all(|v| *v == ci as u8)));
    }

    #[test]
    #[should_panic(expected = "for_each_chunk2 chunk count mismatch")]
    fn chunk2_mismatch_reports_counts() {
        let mut a = vec![0usize; 10]; // 10 chunks of 1
        let mut b = vec![0u8; 50]; // 13 chunks of 4
        for_each_chunk2(&mut a, 1, &mut b, 4, |_, _, _| {});
    }

    #[test]
    fn empty_and_single() {
        let mut empty: Vec<u32> = Vec::new();
        for_each_chunk(&mut empty, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![1u32; 3];
        with_threads(8, || {
            for_each_chunk(&mut one, 8, |ci, c| {
                assert_eq!(ci, 0);
                for v in c.iter_mut() {
                    *v += 1;
                }
            });
        });
        assert_eq!(one, vec![2, 2, 2]);
    }

    #[test]
    fn run_workers_ordered_results() {
        // results come back in worker order, for 1 and N workers alike
        assert_eq!(run_workers(1, |w| w * 10), vec![0]);
        assert_eq!(run_workers(4, |w| w * 10), vec![0, 10, 20, 30]);
        assert_eq!(run_workers(0, |w| w), vec![0], "clamped to 1");
    }

    #[test]
    fn override_pins_count() {
        assert_eq!(with_threads(3, num_threads), 3);
        assert_eq!(with_threads(0, num_threads), 1); // clamped
        assert!(num_threads() >= 1);
    }

    #[test]
    fn pool_chunk_matches_scoped() {
        let _guard = pool_lock();
        let n = 10_000usize;
        let mut scoped: Vec<u64> = (0..n as u64).collect();
        let mut pooled = scoped.clone();
        let kernel = |ci: usize, chunk: &mut [u64]| {
            for v in chunk.iter_mut() {
                *v = v.wrapping_mul(31).wrapping_add(ci as u64);
            }
        };
        with_threads(5, || for_each_chunk(&mut scoped, 7, kernel));
        let pool = WorkerPool::new();
        pool.install(|| with_threads(5, || for_each_chunk(&mut pooled, 7, kernel)));
        assert_eq!(pooled, scoped);
        assert!(pool.size() >= 2, "parallel fan-out should have spawned workers");
    }

    #[test]
    fn pool_chunk2_matches_scoped() {
        let _guard = pool_lock();
        let run = |use_pool: bool| {
            let mut a = vec![0usize; 10];
            let mut b = vec![0u8; 38];
            let body = |a: &mut Vec<usize>, b: &mut Vec<u8>| {
                with_threads(3, || {
                    for_each_chunk2(a, 1, b, 4, |ci, x, y| {
                        x[0] = ci * 100 + y.len();
                        for v in y.iter_mut() {
                            *v = ci as u8;
                        }
                    });
                });
            };
            if use_pool {
                let pool = WorkerPool::new();
                pool.install(|| body(&mut a, &mut b));
            } else {
                body(&mut a, &mut b);
            }
            (a, b)
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn pool_run_workers_ordered_results() {
        let _guard = pool_lock();
        let pool = WorkerPool::new();
        pool.install(|| {
            assert_eq!(run_workers(4, |w| w * 10), vec![0, 10, 20, 30]);
            assert_eq!(run_workers(1, |w| w), vec![0], "n == 1 stays inline");
        });
    }

    #[test]
    fn pool_drop_joins_workers() {
        let _guard = pool_lock();
        let before = live_pool_threads();
        for _ in 0..4 {
            let pool = WorkerPool::new();
            pool.run_on(3, |_| {});
            assert_eq!(live_pool_threads(), before + 3);
            drop(pool);
            assert_eq!(live_pool_threads(), before, "drop must join all workers");
        }
    }

    #[test]
    fn pool_grows_on_demand_and_reuses_threads() {
        let _guard = pool_lock();
        let before = live_pool_threads();
        let pool = WorkerPool::new();
        assert_eq!(pool.size(), 0, "workers spawn lazily");
        pool.run_on(2, |_| {});
        assert_eq!(pool.size(), 2);
        pool.run_on(4, |_| {});
        assert_eq!(pool.size(), 4);
        for _ in 0..100 {
            pool.run_on(4, |_| {});
        }
        assert_eq!(pool.size(), 4, "repeat dispatch must not spawn new threads");
        drop(pool);
        assert_eq!(live_pool_threads(), before);
    }

    #[test]
    fn pool_task_panic_propagates_and_pool_survives() {
        let _guard = pool_lock();
        let pool = WorkerPool::new();
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_on(3, |w| {
                if w == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(hit.is_err(), "worker panic must propagate to the dispatcher");
        // The pool stays usable after a task panic.
        let done = std::sync::atomic::AtomicUsize::new(0);
        pool.run_on(3, |_| {
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn with_pool_restores_previous_substrate() {
        let _guard = pool_lock();
        let pool = WorkerPool::new();
        assert!(current_pool_inner().is_none());
        pool.install(|| {
            assert!(current_pool_inner().is_some());
            with_pool(None, || assert!(current_pool_inner().is_none()));
            assert!(current_pool_inner().is_some());
        });
        assert!(current_pool_inner().is_none());
    }
}
