//! Summary statistics for benches and metrics (mean / std / percentiles).

#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    /// Percentile via linear interpolation on the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }
}
