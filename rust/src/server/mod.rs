//! Serving front-end: drives the engine with synthetic request workloads
//! and reports throughput/latency — the Fig. 4 measurement path and the
//! `latmix serve` subcommand. The measurement loops are generic over
//! [`StepExecutor`], so the same benchmarks run on the PJRT executor
//! (`backend-xla` feature) and the pure-Rust [`NativeExecutor`].
//!
//! Entry points take a typed [`ServeOptions`] (what to serve: graph/weight
//! tags, workload size, weight residency, KV-cache format) instead of the
//! old positional argument strings; the open-loop runner layers
//! [`OpenLoopConfig`] (how load arrives: Poisson rate, queue bound,
//! deadline, shared prefix) on top. Report types live in [`report`].
//!
//! Two load models:
//!
//! - **closed-loop** ([`serve_with_executor`]): the whole workload is
//!   staged up front and the engine drains it — an offline-throughput
//!   measurement where latency is dominated by queueing behind the batch.
//! - **open-loop** ([`serve_open_loop`]): requests arrive on a Poisson
//!   schedule that does not wait for completions, drawn from weighted
//!   payload classes, with optional queue bound and per-request deadline.
//!   This exercises the full admission/decode/stream pipeline and reports
//!   p50/p90/p99 TTFT + inter-token latency **per class** into
//!   `BENCH_serving.json` (schema documented in README.md).

pub mod report;

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::engine::{NativeExecutor, StepExecutor};
#[cfg(feature = "backend-xla")]
use crate::coordinator::engine::XlaExecutor;
use crate::coordinator::{Engine, EngineConfig, GenRequest, GenResult, KvSpec};
use crate::data::{
    default_payload_classes, open_loop_workload_shared, serving_workload,
};
use crate::coordinator::Router;
use crate::model::{ModelDesc, NativeDims, ShardPlan, WeightSet};
#[cfg(feature = "backend-xla")]
use crate::runtime::Runtime;

pub use report::{ClassLatency, ReportCore, Residency, ServeReport, ServingReport};

/// How model weights sit in executor memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightResidency {
    /// Dequantized f32 weights, dense GEMM.
    Dense,
    /// True bit-packed MX bytes, fused packed GEMM (quantized tags only).
    Packed,
}

/// What to serve: the typed replacement for the old positional
/// `(graph_tag, weights_tag, n_requests, max_new, max_slots, seed,
/// packed)` argument runs. Build with `Default` + the chainable setters:
///
/// ```ignore
/// let opts = ServeOptions::default()
///     .tags("mxfp4_latmix", "mxfp4_latmix")
///     .requests(64)
///     .residency(WeightResidency::Packed)
///     .kv(KvSpec::from_bits(8)?);
/// ```
#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub graph_tag: String,
    pub weights_tag: String,
    /// Closed-loop workload size (open-loop runs take theirs from
    /// [`OpenLoopConfig::n_requests`]).
    pub n_requests: usize,
    pub max_new: usize,
    /// Closed-loop engine slots (open-loop: [`OpenLoopConfig::max_slots`]).
    pub max_slots: usize,
    /// Closed-loop workload seed (open-loop: [`OpenLoopConfig::seed`]).
    pub seed: u64,
    pub residency: WeightResidency,
    /// Paged-KV storage: format (f32 / MXFP8 / MXFP4) + tokens per page.
    pub kv: KvSpec,
    /// Tensor-parallel shard workers (`--workers N`). `None` serves the
    /// original single-worker forward; `Some(n)` slices attention along
    /// heads and the FFN along manifest-pinned `d_ff` bands, with output
    /// bit-identical for any worker count.
    pub workers: Option<usize>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            graph_tag: "fp".to_string(),
            weights_tag: "fp16".to_string(),
            n_requests: 16,
            max_new: 32,
            max_slots: 8,
            seed: 42,
            residency: WeightResidency::Dense,
            kv: KvSpec::default(),
            workers: None,
        }
    }
}

impl ServeOptions {
    pub fn tags(mut self, graph: &str, weights: &str) -> Self {
        self.graph_tag = graph.to_string();
        self.weights_tag = weights.to_string();
        self
    }

    pub fn requests(mut self, n: usize) -> Self {
        self.n_requests = n;
        self
    }

    pub fn max_new(mut self, n: usize) -> Self {
        self.max_new = n;
        self
    }

    pub fn slots(mut self, n: usize) -> Self {
        self.max_slots = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn residency(mut self, r: WeightResidency) -> Self {
        self.residency = r;
        self
    }

    pub fn kv(mut self, kv: KvSpec) -> Self {
        self.kv = kv;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n);
        self
    }

    /// Load this option set's weights and build the native executor
    /// (packing them when [`WeightResidency::Packed`], sharding when
    /// `--workers` is set — honoring the manifest's `shard.ffn_block`
    /// band width so every host slices the artifact identically).
    fn build_native(&self, desc: &ModelDesc) -> Result<NativeExecutor> {
        let ws = WeightSet::load(desc, &self.weights_tag)?;
        let exec = NativeExecutor::new(desc, &self.graph_tag, &ws)?;
        let exec = match self.residency {
            WeightResidency::Dense => exec,
            WeightResidency::Packed => exec.into_packed()?,
        };
        match self.workers {
            None => Ok(exec),
            Some(w) => {
                let dims = NativeDims::from_desc(desc);
                let plan = match desc.shard_ffn_block {
                    Some(fb) => ShardPlan { workers: w, ffn_block: fb },
                    None => {
                        ShardPlan { workers: w, ffn_block: ShardPlan::default_ffn_block(dims.d_ff) }
                    }
                };
                exec.with_shard_plan(plan)
            }
        }
    }
}

/// Closed-loop serving benchmark over any step executor: submit
/// `opts.n_requests` prompts, run the engine to completion, report
/// throughput. KV residency/sharing counters are read off the engine;
/// `backend` and weight bytes are filled by the runner wrappers.
pub fn serve_with_executor<E: StepExecutor>(exec: E, opts: &ServeOptions) -> Result<ServeReport> {
    let max_prompt = exec.prefill_len();
    // Least-loaded worker assignment: with `--workers N` every request is
    // tagged with an owning shard worker. The single tensor-parallel
    // engine still executes every lane — assignment is ownership
    // bookkeeping for the report, not a scheduling input, so admission
    // order (and with it `sched_fingerprint`) is identical for any
    // worker count.
    let mut router = Router::new(opts.workers.unwrap_or(1).max(1));
    let mut engine = Engine::new(
        exec,
        EngineConfig { max_slots: opts.max_slots, eos: -1, kv: opts.kv, ..Default::default() },
    );
    for (i, (prompt, m)) in
        serving_workload(opts.n_requests, max_prompt, opts.max_new, opts.seed)
            .into_iter()
            .enumerate()
    {
        router.assign(i as u64);
        engine.submit(GenRequest::new(i as u64, prompt, m));
    }
    let assigned = router.loads().to_vec();
    let results = engine.run_to_completion()?;
    for r in &results {
        router.mark_done(r.id);
    }
    let mut rep =
        ServeReport::from_results(&opts.graph_tag, &opts.weights_tag, &results, &engine.stats);
    rep.core.residency.kv_bytes = engine.kv_resident_bytes();
    rep.core.residency.kv_pages_shared = engine.kv_pages_shared();
    if opts.workers.is_some() {
        rep.core.worker_requests = assigned;
    }
    Ok(rep)
}

/// Run the serving benchmark on the PJRT executor.
#[cfg(feature = "backend-xla")]
pub fn run_serving(rt: &Runtime, opts: &ServeOptions) -> Result<ServeReport> {
    let ws = WeightSet::load(&rt.desc, &opts.weights_tag)?;
    let exec = XlaExecutor::new(rt, &opts.graph_tag, &ws)?;
    let mut rep = serve_with_executor(exec, opts)?;
    rep.core.backend = "xla".to_string();
    Ok(rep)
}

/// Run the serving benchmark on the pure-Rust executor (no XLA toolchain
/// needed; same `.lxt` weights and compiled-batch discipline). Under
/// [`WeightResidency::Packed`], weights are repacked into MX bytes at
/// load and the fused packed GEMM decodes them in-register (quantized
/// graph tags only).
pub fn run_serving_native(desc: &ModelDesc, opts: &ServeOptions) -> Result<ServeReport> {
    let exec = opts.build_native(desc)?;
    let bytes = exec.resident_weight_bytes();
    let mut rep = serve_with_executor(exec, opts)?;
    rep.core.backend = "native".to_string();
    rep.core.residency.weight_bytes = bytes;
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Open-loop load generator + per-class SLO report

/// Knobs for one open-loop run (CLI flags map 1:1 onto these). Where a
/// field shadows [`ServeOptions`] (`n_requests`, `max_slots`, `seed`),
/// the open-loop runner uses **this** struct's value — `ServeOptions`
/// contributes what to serve (tags, residency, KV spec), this one how
/// the load arrives.
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    pub n_requests: usize,
    /// Poisson arrival rate, requests/second.
    pub arrival_rate: f64,
    pub max_slots: usize,
    /// Admission-queue bound (None = unbounded, nothing is rejected).
    pub queue_depth: Option<usize>,
    /// Per-request latency SLO (None = no deadline eviction).
    pub deadline: Option<Duration>,
    /// Post-BOS tokens every prompt shares (0 = fully random prompts).
    /// With a paged KV cache this turns the common prefix into shared
    /// refcounted pages — `kv_pages_shared` counts the hits.
    pub shared_prefix: usize,
    pub seed: u64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        OpenLoopConfig {
            n_requests: 64,
            arrival_rate: 100.0,
            max_slots: 8,
            queue_depth: None,
            deadline: None,
            shared_prefix: 0,
            seed: 7,
        }
    }
}

/// Open-loop serving benchmark: requests arrive on a Poisson schedule
/// (they do NOT wait for completions — the queue grows when the engine
/// falls behind), drawn from the default payload classes. Streams tokens
/// through the engine sink and aggregates per-class SLO percentiles.
pub fn serve_open_loop<E: StepExecutor>(
    exec: E,
    opts: &ServeOptions,
    backend: &str,
    cfg: &OpenLoopConfig,
) -> Result<ServingReport> {
    let classes = default_payload_classes();
    let workload = open_loop_workload_shared(
        cfg.n_requests,
        cfg.arrival_rate,
        exec.prefill_len(),
        &classes,
        cfg.shared_prefix,
        cfg.seed,
    );
    let class_of: Vec<usize> = workload.iter().map(|r| r.class).collect();
    let mut engine = Engine::new(
        exec,
        EngineConfig {
            max_slots: cfg.max_slots,
            eos: -1,
            queue_depth: cfg.queue_depth,
            kv: opts.kv,
            ..Default::default()
        },
    );
    let t0 = Instant::now();
    let mut results: Vec<GenResult> = Vec::with_capacity(workload.len());
    let mut next = 0usize;
    while next < workload.len() || engine.pending() > 0 {
        // inject every arrival that is due by now
        let now = t0.elapsed().as_secs_f64();
        while next < workload.len() && workload[next].arrival_s <= now {
            let w = &workload[next];
            let mut req = GenRequest::new(next as u64, w.prompt.clone(), w.max_new);
            if let Some(d) = cfg.deadline {
                req = req.with_deadline(d);
            }
            engine.try_submit(req);
            next += 1;
        }
        if engine.pending() > 0 {
            engine.step()?;
            results.append(&mut engine.take_results());
        } else if next < workload.len() {
            // idle until the next arrival (capped so injection stays timely)
            let wait = workload[next].arrival_s - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait.min(0.010)));
            }
        }
    }
    results.append(&mut engine.take_results());
    engine.stats.wall_s = t0.elapsed().as_secs_f64();

    let lost = cfg.n_requests - results.len().min(cfg.n_requests);
    Ok(ServingReport {
        core: ReportCore {
            tag: opts.graph_tag.clone(),
            weights: opts.weights_tag.clone(),
            backend: backend.to_string(),
            requests: cfg.n_requests,
            wall_s: engine.stats.wall_s,
            decode_tok_per_s: engine.stats.decode_tok_per_s(),
            residency: Residency {
                weight_bytes: 0,
                kv_bytes: engine.kv_resident_bytes(),
                kv_pages_shared: engine.kv_pages_shared(),
            },
            worker_requests: Vec::new(),
        },
        arrival_rate: cfg.arrival_rate,
        queue_depth: cfg.queue_depth,
        deadline_ms: cfg.deadline.map(|d| d.as_secs_f64() * 1e3),
        lost,
        classes: ServingReport::aggregate(&classes, &class_of, &results),
    })
}

/// Open-loop run over artifact-backed native weights. Under
/// [`WeightResidency::Packed`], weights stay MX-packed and the fused
/// packed GEMM serves them.
pub fn run_open_loop_native(
    desc: &ModelDesc,
    opts: &ServeOptions,
    cfg: &OpenLoopConfig,
) -> Result<ServingReport> {
    let exec = opts.build_native(desc)?;
    let bytes = exec.resident_weight_bytes();
    let mut rep = serve_open_loop(exec, opts, "native", cfg)?;
    rep.core.residency.weight_bytes = bytes;
    Ok(rep)
}

/// Open-loop run over the PJRT executor.
#[cfg(feature = "backend-xla")]
pub fn run_open_loop(
    rt: &Runtime,
    opts: &ServeOptions,
    cfg: &OpenLoopConfig,
) -> Result<ServingReport> {
    let ws = WeightSet::load(&rt.desc, &opts.weights_tag)?;
    let exec = XlaExecutor::new(rt, &opts.graph_tag, &ws)?;
    serve_open_loop(exec, opts, "xla", cfg)
}

#[cfg(test)]
mod tests {
    use crate::coordinator::engine::MockExecutor;
    use crate::coordinator::{EngineStats, FinishReason, KvFormat};

    use super::*;

    #[test]
    fn empty_results_yield_zero_report() {
        let rep = ServeReport::from_results("fp", "fp16", &[], &EngineStats::default());
        assert!(rep.is_empty());
        assert_eq!(rep.core.requests, 0);
        assert_eq!(rep.ttft_p50_ms, 0.0);
        assert_eq!(rep.latency_p99_ms, 0.0);
        assert!(rep.ttft_p99_ms.is_finite() && rep.latency_p50_ms.is_finite());
    }

    #[test]
    fn incomplete_outcomes_excluded_from_percentiles() {
        let complete = GenResult {
            id: 0,
            prompt_len: 4,
            tokens: vec![1, 2],
            outcome: FinishReason::Length,
            token_s: vec![0.001, 0.002],
            ttft_s: 0.001,
            total_s: 0.002,
        };
        let rejected = GenResult {
            id: 1,
            prompt_len: 4,
            tokens: vec![],
            outcome: FinishReason::RejectedQueueFull,
            token_s: vec![],
            ttft_s: 0.0,
            total_s: 0.0,
        };
        let rep = ServeReport::from_results(
            "fp",
            "fp16",
            &[complete, rejected],
            &EngineStats::default(),
        );
        assert_eq!(rep.core.requests, 1, "only the completed request counts");
        assert!(rep.ttft_p50_ms > 0.0);
    }

    #[test]
    fn serve_options_builder_chains() {
        let opts = ServeOptions::default()
            .tags("mxfp4_latmix", "mxfp4_latmix")
            .requests(64)
            .max_new(12)
            .slots(4)
            .seed(9)
            .residency(WeightResidency::Packed)
            .kv(KvSpec::from_bits(8).unwrap())
            .workers(2);
        assert_eq!(opts.graph_tag, "mxfp4_latmix");
        assert_eq!(opts.weights_tag, "mxfp4_latmix");
        assert_eq!(opts.n_requests, 64);
        assert_eq!(opts.max_new, 12);
        assert_eq!(opts.max_slots, 4);
        assert_eq!(opts.seed, 9);
        assert_eq!(opts.residency, WeightResidency::Packed);
        assert!(matches!(opts.kv.format, KvFormat::Mxfp8));
        assert_eq!(opts.workers, Some(2));
        assert_eq!(ServeOptions::default().workers, None, "legacy path by default");
    }

    #[test]
    fn closed_loop_worker_assignment_balances() {
        let opts = ServeOptions::default().tags("fp", "mock").requests(9).workers(3);
        let rep = serve_with_executor(MockExecutor::default(), &opts).unwrap();
        assert_eq!(rep.core.worker_requests, vec![3, 3, 3], "least-loaded spread");
        let legacy = ServeOptions::default().tags("fp", "mock").requests(9);
        let rep = serve_with_executor(MockExecutor::default(), &legacy).unwrap();
        assert!(rep.core.worker_requests.is_empty(), "no worker tags without --workers");
    }

    #[test]
    fn open_loop_conserves_requests_and_reports_classes() {
        let cfg = OpenLoopConfig {
            n_requests: 24,
            arrival_rate: 2000.0,
            max_slots: 4,
            ..Default::default()
        };
        let opts = ServeOptions::default().tags("fp", "mock");
        let rep = serve_open_loop(MockExecutor::default(), &opts, "native", &cfg).unwrap();
        assert_eq!(rep.lost, 0, "no request may vanish");
        assert_eq!(rep.core.requests, 24);
        let total: usize = rep.classes.iter().map(|c| c.requests).sum();
        assert_eq!(total, 24, "every result lands in exactly one class");
        let completed: usize = rep.classes.iter().map(|c| c.completed).sum();
        assert_eq!(completed, 24, "unbounded queue, no deadline: all complete");
        for c in rep.classes.iter().filter(|c| c.completed > 0) {
            assert!(c.ttft_ms[2] >= c.ttft_ms[0], "p99 >= p50");
        }
        assert!(rep.core.residency.kv_bytes > 0, "paged pool reports residency");
    }

    #[test]
    fn open_loop_backpressure_rejects_but_conserves() {
        let cfg = OpenLoopConfig {
            n_requests: 32,
            arrival_rate: 1e6, // everything arrives at once
            max_slots: 2,
            queue_depth: Some(2),
            ..Default::default()
        };
        let opts = ServeOptions::default().tags("fp", "mock");
        let rep = serve_open_loop(MockExecutor::default(), &opts, "native", &cfg).unwrap();
        assert_eq!(rep.lost, 0);
        let rejected: usize = rep.classes.iter().map(|c| c.rejected).sum();
        let completed: usize = rep.classes.iter().map(|c| c.completed).sum();
        assert!(rejected > 0, "flood + tiny queue must reject");
        assert_eq!(rejected + completed, 32);
    }

    #[test]
    fn open_loop_shared_prefix_shares_pages() {
        // Shared 4-token prefix + 4-token pages on the mock executor:
        // every admitted prompt's first page must map to the same pooled
        // page, so the shared counter climbs above zero.
        let cfg = OpenLoopConfig {
            n_requests: 16,
            arrival_rate: 5000.0,
            max_slots: 4,
            shared_prefix: 7,
            ..Default::default()
        };
        let opts = ServeOptions::default()
            .tags("fp", "mock")
            .kv(KvSpec { format: KvFormat::F32, block: 4 });
        let rep = serve_open_loop(MockExecutor::default(), &opts, "native", &cfg).unwrap();
        assert_eq!(rep.lost, 0);
        assert!(
            rep.core.residency.kv_pages_shared > 0,
            "shared-prefix workload must hit the page-share registry"
        );
    }

    #[test]
    fn serving_json_well_formed() {
        let cfg = OpenLoopConfig { n_requests: 8, arrival_rate: 5000.0, ..Default::default() };
        let opts = ServeOptions::default().tags("fp", "mock");
        let rep = serve_open_loop(MockExecutor::default(), &opts, "native", &cfg).unwrap();
        let s = rep.render_json();
        assert!(s.contains("\"bench\": \"serving\""));
        assert!(s.contains("\"schema\": 1"));
        assert!(s.contains("\"lost\": 0"));
        assert!(s.contains("\"resident_weight_bytes\": 0"));
        assert!(s.contains("\"kv_resident_bytes\""));
        assert!(s.contains("\"kv_pages_shared\""));
        assert!(s.contains("\"ttft_p90_ms\""));
        assert!(s.contains("\"itl_p99_ms\""));
        assert!(!s.contains("NaN") && !s.contains("inf"));
        // crude balance check on braces/brackets
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }
}
