//! Serving front-end: drives the engine with a synthetic request workload
//! and reports throughput/latency — the Fig. 4 measurement path and the
//! `latmix serve` subcommand. The measurement loop is generic over
//! [`StepExecutor`], so the same closed-loop benchmark runs on the PJRT
//! executor (`backend-xla` feature) and the pure-Rust [`NativeExecutor`].

use anyhow::Result;

use crate::coordinator::engine::{NativeExecutor, StepExecutor};
#[cfg(feature = "backend-xla")]
use crate::coordinator::engine::XlaExecutor;
use crate::coordinator::{Engine, EngineConfig, GenRequest, GenResult};
use crate::data::serving_workload;
use crate::model::{ModelDesc, WeightSet};
#[cfg(feature = "backend-xla")]
use crate::runtime::Runtime;
use crate::util::Summary;

/// Aggregated serving metrics for one run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub tag: String,
    pub weights: String,
    pub requests: usize,
    pub wall_s: f64,
    pub decode_tok_per_s: f64,
    pub total_tok_per_s: f64,
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
}

impl ServeReport {
    pub fn from_results(
        tag: &str,
        weights: &str,
        results: &[GenResult],
        stats: &crate::coordinator::EngineStats,
    ) -> ServeReport {
        let mut ttft = Summary::new();
        let mut lat = Summary::new();
        let mut total_toks = 0usize;
        for r in results {
            ttft.push(r.ttft_s * 1e3);
            lat.push(r.total_s * 1e3);
            total_toks += r.prompt_len + r.tokens.len();
        }
        ServeReport {
            tag: tag.to_string(),
            weights: weights.to_string(),
            requests: results.len(),
            wall_s: stats.wall_s,
            decode_tok_per_s: stats.decode_tok_per_s(),
            total_tok_per_s: total_toks as f64 / stats.wall_s.max(1e-9),
            ttft_p50_ms: ttft.percentile(50.0),
            ttft_p99_ms: ttft.percentile(99.0),
            latency_p50_ms: lat.percentile(50.0),
            latency_p99_ms: lat.percentile(99.0),
        }
    }
}

/// Closed-loop serving benchmark over any step executor: submit
/// `n_requests` prompts, run the engine to completion, report throughput.
pub fn serve_with_executor<E: StepExecutor>(
    exec: E,
    graph_tag: &str,
    weights_tag: &str,
    n_requests: usize,
    max_new: usize,
    max_slots: usize,
    seed: u64,
) -> Result<ServeReport> {
    let max_prompt = exec.prefill_len();
    let mut engine = Engine::new(
        exec,
        EngineConfig { max_slots, eos: -1, ..Default::default() },
    );
    for (i, (prompt, m)) in serving_workload(n_requests, max_prompt, max_new, seed)
        .into_iter()
        .enumerate()
    {
        engine.submit(GenRequest::new(i as u64, prompt, m));
    }
    let results = engine.run_to_completion()?;
    Ok(ServeReport::from_results(graph_tag, weights_tag, &results, &engine.stats))
}

/// Run the serving benchmark on the PJRT executor.
#[cfg(feature = "backend-xla")]
pub fn run_serving(
    rt: &Runtime,
    graph_tag: &str,
    weights_tag: &str,
    n_requests: usize,
    max_new: usize,
    max_slots: usize,
    seed: u64,
) -> Result<ServeReport> {
    let ws = WeightSet::load(&rt.desc, weights_tag)?;
    let exec = XlaExecutor::new(rt, graph_tag, &ws)?;
    serve_with_executor(exec, graph_tag, weights_tag, n_requests, max_new, max_slots, seed)
}

/// Run the serving benchmark on the pure-Rust executor (no XLA toolchain
/// needed; same `.lxt` weights and compiled-batch discipline).
pub fn run_serving_native(
    desc: &ModelDesc,
    graph_tag: &str,
    weights_tag: &str,
    n_requests: usize,
    max_new: usize,
    max_slots: usize,
    seed: u64,
) -> Result<ServeReport> {
    let ws = WeightSet::load(desc, weights_tag)?;
    let exec = NativeExecutor::new(desc, graph_tag, &ws)?;
    serve_with_executor(exec, graph_tag, weights_tag, n_requests, max_new, max_slots, seed)
}
