//! Serving front-end: drives the engine with synthetic request workloads
//! and reports throughput/latency — the Fig. 4 measurement path and the
//! `latmix serve` subcommand. The measurement loops are generic over
//! [`StepExecutor`], so the same benchmarks run on the PJRT executor
//! (`backend-xla` feature) and the pure-Rust [`NativeExecutor`].
//!
//! Two load models:
//!
//! - **closed-loop** ([`serve_with_executor`]): the whole workload is
//!   staged up front and the engine drains it — an offline-throughput
//!   measurement where latency is dominated by queueing behind the batch.
//! - **open-loop** ([`serve_open_loop`]): requests arrive on a Poisson
//!   schedule that does not wait for completions, drawn from weighted
//!   payload classes, with optional queue bound and per-request deadline.
//!   This exercises the full admission/decode/stream pipeline and reports
//!   p50/p90/p99 TTFT + inter-token latency **per class** into
//!   `BENCH_serving.json` (schema documented in README.md).

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::engine::{NativeExecutor, StepExecutor};
#[cfg(feature = "backend-xla")]
use crate::coordinator::engine::XlaExecutor;
use crate::coordinator::{Engine, EngineConfig, FinishReason, GenRequest, GenResult};
use crate::data::{default_payload_classes, open_loop_workload, serving_workload, PayloadClass};
use crate::model::{ModelDesc, WeightSet};
#[cfg(feature = "backend-xla")]
use crate::runtime::Runtime;
use crate::util::Summary;

/// Aggregated serving metrics for one closed-loop run. Percentiles are
/// computed over **completed** requests only (EOS/length/KV-limit);
/// rejected or evicted lifecycles have no meaningful latency sample.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub tag: String,
    pub weights: String,
    /// Completed requests (the percentile population).
    pub requests: usize,
    pub wall_s: f64,
    pub decode_tok_per_s: f64,
    pub total_tok_per_s: f64,
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    /// Bytes of model weights resident in the executor (packed MX bytes
    /// when `--packed-weights`, f32 bytes otherwise). 0 when the executor
    /// does not expose a footprint (mock/XLA paths).
    pub resident_weight_bytes: usize,
}

impl ServeReport {
    pub fn from_results(
        tag: &str,
        weights: &str,
        results: &[GenResult],
        stats: &crate::coordinator::EngineStats,
    ) -> ServeReport {
        let completed: Vec<&GenResult> = results.iter().filter(|r| r.outcome.is_complete()).collect();
        if completed.is_empty() {
            // Explicit zero-request report: percentiles over an empty
            // sample set are meaningless, so report zeros instead of
            // whatever an empty Summary would produce.
            return ServeReport {
                tag: tag.to_string(),
                weights: weights.to_string(),
                requests: 0,
                wall_s: stats.wall_s,
                decode_tok_per_s: 0.0,
                total_tok_per_s: 0.0,
                ttft_p50_ms: 0.0,
                ttft_p99_ms: 0.0,
                latency_p50_ms: 0.0,
                latency_p99_ms: 0.0,
                resident_weight_bytes: 0,
            };
        }
        let mut ttft = Summary::new();
        let mut lat = Summary::new();
        let mut total_toks = 0usize;
        for r in &completed {
            ttft.push(r.ttft_s * 1e3);
            lat.push(r.total_s * 1e3);
            total_toks += r.prompt_len + r.tokens.len();
        }
        ServeReport {
            tag: tag.to_string(),
            weights: weights.to_string(),
            requests: completed.len(),
            wall_s: stats.wall_s,
            decode_tok_per_s: stats.decode_tok_per_s(),
            total_tok_per_s: total_toks as f64 / stats.wall_s.max(1e-9),
            ttft_p50_ms: ttft.percentile(50.0),
            ttft_p99_ms: ttft.percentile(99.0),
            latency_p50_ms: lat.percentile(50.0),
            latency_p99_ms: lat.percentile(99.0),
            resident_weight_bytes: 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.requests == 0
    }
}

/// Closed-loop serving benchmark over any step executor: submit
/// `n_requests` prompts, run the engine to completion, report throughput.
pub fn serve_with_executor<E: StepExecutor>(
    exec: E,
    graph_tag: &str,
    weights_tag: &str,
    n_requests: usize,
    max_new: usize,
    max_slots: usize,
    seed: u64,
) -> Result<ServeReport> {
    let max_prompt = exec.prefill_len();
    let mut engine = Engine::new(
        exec,
        EngineConfig { max_slots, eos: -1, ..Default::default() },
    );
    for (i, (prompt, m)) in serving_workload(n_requests, max_prompt, max_new, seed)
        .into_iter()
        .enumerate()
    {
        engine.submit(GenRequest::new(i as u64, prompt, m));
    }
    let results = engine.run_to_completion()?;
    Ok(ServeReport::from_results(graph_tag, weights_tag, &results, &engine.stats))
}

/// Run the serving benchmark on the PJRT executor.
#[cfg(feature = "backend-xla")]
pub fn run_serving(
    rt: &Runtime,
    graph_tag: &str,
    weights_tag: &str,
    n_requests: usize,
    max_new: usize,
    max_slots: usize,
    seed: u64,
) -> Result<ServeReport> {
    let ws = WeightSet::load(&rt.desc, weights_tag)?;
    let exec = XlaExecutor::new(rt, graph_tag, &ws)?;
    serve_with_executor(exec, graph_tag, weights_tag, n_requests, max_new, max_slots, seed)
}

/// Run the serving benchmark on the pure-Rust executor (no XLA toolchain
/// needed; same `.lxt` weights and compiled-batch discipline). With
/// `packed`, weights are repacked into MX bytes at load and the fused
/// packed GEMM decodes them in-register (quantized graph tags only).
pub fn run_serving_native(
    desc: &ModelDesc,
    graph_tag: &str,
    weights_tag: &str,
    n_requests: usize,
    max_new: usize,
    max_slots: usize,
    seed: u64,
    packed: bool,
) -> Result<ServeReport> {
    let ws = WeightSet::load(desc, weights_tag)?;
    let mut exec = NativeExecutor::new(desc, graph_tag, &ws)?;
    if packed {
        exec = exec.into_packed()?;
    }
    let bytes = exec.resident_weight_bytes();
    let mut rep =
        serve_with_executor(exec, graph_tag, weights_tag, n_requests, max_new, max_slots, seed)?;
    rep.resident_weight_bytes = bytes;
    Ok(rep)
}

// ---------------------------------------------------------------------------
// Open-loop load generator + per-class SLO report

/// Knobs for one open-loop run (CLI flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    pub n_requests: usize,
    /// Poisson arrival rate, requests/second.
    pub arrival_rate: f64,
    pub max_slots: usize,
    /// Admission-queue bound (None = unbounded, nothing is rejected).
    pub queue_depth: Option<usize>,
    /// Per-request latency SLO (None = no deadline eviction).
    pub deadline: Option<Duration>,
    pub seed: u64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        OpenLoopConfig {
            n_requests: 64,
            arrival_rate: 100.0,
            max_slots: 8,
            queue_depth: None,
            deadline: None,
            seed: 7,
        }
    }
}

/// Per-payload-class SLO aggregation: outcome counts + TTFT and
/// inter-token-latency percentiles over the class's completed requests.
#[derive(Clone, Debug)]
pub struct ClassLatency {
    pub class: String,
    pub requests: usize,
    pub completed: usize,
    pub rejected: usize,
    pub timed_out: usize,
    pub cancelled: usize,
    /// [p50, p90, p99] time-to-first-token, milliseconds.
    pub ttft_ms: [f64; 3],
    /// [p50, p90, p99] inter-token latency, milliseconds.
    pub itl_ms: [f64; 3],
}

/// One open-loop serving run, aggregated per class — serialized to
/// `BENCH_serving.json` (schema 1) for in-repo regression diffing.
#[derive(Clone, Debug)]
pub struct ServingReport {
    pub tag: String,
    pub weights: String,
    /// "native" | "xla" — which executor decoded.
    pub backend: String,
    pub arrival_rate: f64,
    pub queue_depth: Option<usize>,
    pub deadline_ms: Option<f64>,
    /// Requests submitted (arrival schedule length).
    pub requests: usize,
    /// Submitted requests that produced no result — must be 0; anything
    /// else is a conservation bug and CI's serving smoke fails on it.
    pub lost: usize,
    pub wall_s: f64,
    pub decode_tok_per_s: f64,
    /// Bytes of model weights resident in the executor (packed MX bytes
    /// when `--packed-weights`, f32 bytes otherwise; 0 when unknown).
    pub resident_weight_bytes: usize,
    pub classes: Vec<ClassLatency>,
}

impl ServingReport {
    fn aggregate(
        classes: &[PayloadClass],
        class_of: &[usize],
        results: &[GenResult],
    ) -> Vec<ClassLatency> {
        let mut out: Vec<ClassLatency> = classes
            .iter()
            .map(|c| ClassLatency {
                class: c.name.to_string(),
                requests: 0,
                completed: 0,
                rejected: 0,
                timed_out: 0,
                cancelled: 0,
                ttft_ms: [0.0; 3],
                itl_ms: [0.0; 3],
            })
            .collect();
        let mut ttft: Vec<Summary> = classes.iter().map(|_| Summary::new()).collect();
        let mut itl: Vec<Summary> = classes.iter().map(|_| Summary::new()).collect();
        for r in results {
            let ci = class_of[r.id as usize];
            out[ci].requests += 1;
            match r.outcome {
                o if o.is_complete() => {
                    out[ci].completed += 1;
                    ttft[ci].push(r.ttft_s * 1e3);
                    for s in r.inter_token_s() {
                        itl[ci].push(s * 1e3);
                    }
                }
                FinishReason::RejectedQueueFull => out[ci].rejected += 1,
                FinishReason::TimedOut => out[ci].timed_out += 1,
                FinishReason::Cancelled => out[ci].cancelled += 1,
                _ => unreachable!("is_complete covers the remaining outcomes"),
            }
        }
        for (ci, c) in out.iter_mut().enumerate() {
            if c.completed > 0 {
                for (k, p) in [50.0, 90.0, 99.0].into_iter().enumerate() {
                    c.ttft_ms[k] = ttft[ci].percentile(p);
                    c.itl_ms[k] = itl[ci].percentile(p);
                }
            }
        }
        out
    }

    /// Render as the `BENCH_serving.json` document (schema 1):
    ///
    /// ```json
    /// {
    ///   "bench": "serving", "schema": 1, "backend": "native",
    ///   "tag": "fp", "weights": "fp16",
    ///   "arrival_rate": 100.0, "requests": 64, "lost": 0,
    ///   "wall_s": ..., "decode_tok_per_s": ...,
    ///   "resident_weight_bytes": 0,
    ///   "classes": [
    ///     {"class": "short", "requests": 40, "completed": 40,
    ///      "rejected": 0, "timed_out": 0, "cancelled": 0,
    ///      "ttft_p50_ms": ..., "ttft_p90_ms": ..., "ttft_p99_ms": ...,
    ///      "itl_p50_ms": ..., "itl_p90_ms": ..., "itl_p99_ms": ...}
    ///   ]
    /// }
    /// ```
    pub fn render_json(&self) -> String {
        use crate::bench::json_str;
        let mut out = String::from("{\n");
        out += "  \"bench\": \"serving\",\n  \"schema\": 1,\n";
        out += &format!("  \"backend\": {},\n", json_str(&self.backend));
        out += &format!("  \"tag\": {},\n", json_str(&self.tag));
        out += &format!("  \"weights\": {},\n", json_str(&self.weights));
        out += &format!("  \"arrival_rate\": {:e},\n", self.arrival_rate);
        match self.queue_depth {
            Some(d) => out += &format!("  \"queue_depth\": {d},\n"),
            None => out += "  \"queue_depth\": null,\n",
        }
        match self.deadline_ms {
            Some(d) => out += &format!("  \"deadline_ms\": {d:e},\n"),
            None => out += "  \"deadline_ms\": null,\n",
        }
        out += &format!("  \"requests\": {},\n", self.requests);
        out += &format!("  \"lost\": {},\n", self.lost);
        out += &format!("  \"wall_s\": {:e},\n", self.wall_s);
        out += &format!("  \"decode_tok_per_s\": {:e},\n", self.decode_tok_per_s);
        out += &format!("  \"resident_weight_bytes\": {},\n", self.resident_weight_bytes);
        out += "  \"classes\": [\n";
        let rows: Vec<String> = self
            .classes
            .iter()
            .map(|c| {
                format!(
                    "    {{\"class\": {}, \"requests\": {}, \"completed\": {}, \
                     \"rejected\": {}, \"timed_out\": {}, \"cancelled\": {}, \
                     \"ttft_p50_ms\": {:e}, \"ttft_p90_ms\": {:e}, \"ttft_p99_ms\": {:e}, \
                     \"itl_p50_ms\": {:e}, \"itl_p90_ms\": {:e}, \"itl_p99_ms\": {:e}}}",
                    json_str(&c.class),
                    c.requests,
                    c.completed,
                    c.rejected,
                    c.timed_out,
                    c.cancelled,
                    c.ttft_ms[0],
                    c.ttft_ms[1],
                    c.ttft_ms[2],
                    c.itl_ms[0],
                    c.itl_ms[1],
                    c.itl_ms[2],
                )
            })
            .collect();
        out += &rows.join(",\n");
        out += "\n  ]\n}\n";
        out
    }

    /// Write `BENCH_serving.json` at the repo root (or `LATMIX_BENCH_DIR`),
    /// mirroring the microbench snapshot conventions. Returns the path.
    pub fn emit(&self) -> std::path::PathBuf {
        let dir = match std::env::var("LATMIX_BENCH_DIR") {
            Ok(d) => std::path::PathBuf::from(d),
            Err(_) => crate::bench::repo_root(),
        };
        let path = dir.join("BENCH_serving.json");
        if let Err(e) = std::fs::write(&path, self.render_json()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        }
        path
    }
}

/// Open-loop serving benchmark: requests arrive on a Poisson schedule
/// (they do NOT wait for completions — the queue grows when the engine
/// falls behind), drawn from the default payload classes. Streams tokens
/// through the engine sink and aggregates per-class SLO percentiles.
pub fn serve_open_loop<E: StepExecutor>(
    exec: E,
    graph_tag: &str,
    weights_tag: &str,
    backend: &str,
    cfg: &OpenLoopConfig,
) -> Result<ServingReport> {
    let classes = default_payload_classes();
    let workload = open_loop_workload(
        cfg.n_requests,
        cfg.arrival_rate,
        exec.prefill_len(),
        &classes,
        cfg.seed,
    );
    let class_of: Vec<usize> = workload.iter().map(|r| r.class).collect();
    let mut engine = Engine::new(
        exec,
        EngineConfig {
            max_slots: cfg.max_slots,
            eos: -1,
            queue_depth: cfg.queue_depth,
            ..Default::default()
        },
    );
    let t0 = Instant::now();
    let mut results: Vec<GenResult> = Vec::with_capacity(workload.len());
    let mut next = 0usize;
    while next < workload.len() || engine.pending() > 0 {
        // inject every arrival that is due by now
        let now = t0.elapsed().as_secs_f64();
        while next < workload.len() && workload[next].arrival_s <= now {
            let w = &workload[next];
            let mut req = GenRequest::new(next as u64, w.prompt.clone(), w.max_new);
            if let Some(d) = cfg.deadline {
                req = req.with_deadline(d);
            }
            engine.try_submit(req);
            next += 1;
        }
        if engine.pending() > 0 {
            engine.step()?;
            results.append(&mut engine.take_results());
        } else if next < workload.len() {
            // idle until the next arrival (capped so injection stays timely)
            let wait = workload[next].arrival_s - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait.min(0.010)));
            }
        }
    }
    results.append(&mut engine.take_results());
    engine.stats.wall_s = t0.elapsed().as_secs_f64();

    let lost = cfg.n_requests - results.len().min(cfg.n_requests);
    Ok(ServingReport {
        tag: graph_tag.to_string(),
        weights: weights_tag.to_string(),
        backend: backend.to_string(),
        arrival_rate: cfg.arrival_rate,
        queue_depth: cfg.queue_depth,
        deadline_ms: cfg.deadline.map(|d| d.as_secs_f64() * 1e3),
        requests: cfg.n_requests,
        lost,
        wall_s: engine.stats.wall_s,
        decode_tok_per_s: engine.stats.decode_tok_per_s(),
        resident_weight_bytes: 0,
        classes: ServingReport::aggregate(&classes, &class_of, &results),
    })
}

/// Open-loop run over artifact-backed native weights. With `packed`,
/// weights stay MX-packed and the fused packed GEMM serves them.
pub fn run_open_loop_native(
    desc: &ModelDesc,
    graph_tag: &str,
    weights_tag: &str,
    cfg: &OpenLoopConfig,
    packed: bool,
) -> Result<ServingReport> {
    let ws = WeightSet::load(desc, weights_tag)?;
    let mut exec = NativeExecutor::new(desc, graph_tag, &ws)?;
    if packed {
        exec = exec.into_packed()?;
    }
    let bytes = exec.resident_weight_bytes();
    let mut rep = serve_open_loop(exec, graph_tag, weights_tag, "native", cfg)?;
    rep.resident_weight_bytes = bytes;
    Ok(rep)
}

/// Open-loop run over the PJRT executor.
#[cfg(feature = "backend-xla")]
pub fn run_open_loop(
    rt: &Runtime,
    graph_tag: &str,
    weights_tag: &str,
    cfg: &OpenLoopConfig,
) -> Result<ServingReport> {
    let ws = WeightSet::load(&rt.desc, weights_tag)?;
    let exec = XlaExecutor::new(rt, graph_tag, &ws)?;
    serve_open_loop(exec, graph_tag, weights_tag, "xla", cfg)
}

#[cfg(test)]
mod tests {
    use crate::coordinator::engine::MockExecutor;
    use crate::coordinator::EngineStats;

    use super::*;

    #[test]
    fn empty_results_yield_zero_report() {
        let rep = ServeReport::from_results("fp", "fp16", &[], &EngineStats::default());
        assert!(rep.is_empty());
        assert_eq!(rep.requests, 0);
        assert_eq!(rep.ttft_p50_ms, 0.0);
        assert_eq!(rep.latency_p99_ms, 0.0);
        assert!(rep.ttft_p99_ms.is_finite() && rep.latency_p50_ms.is_finite());
    }

    #[test]
    fn incomplete_outcomes_excluded_from_percentiles() {
        let complete = GenResult {
            id: 0,
            prompt_len: 4,
            tokens: vec![1, 2],
            outcome: FinishReason::Length,
            token_s: vec![0.001, 0.002],
            ttft_s: 0.001,
            total_s: 0.002,
        };
        let rejected = GenResult {
            id: 1,
            prompt_len: 4,
            tokens: vec![],
            outcome: FinishReason::RejectedQueueFull,
            token_s: vec![],
            ttft_s: 0.0,
            total_s: 0.0,
        };
        let rep = ServeReport::from_results(
            "fp",
            "fp16",
            &[complete, rejected],
            &EngineStats::default(),
        );
        assert_eq!(rep.requests, 1, "only the completed request counts");
        assert!(rep.ttft_p50_ms > 0.0);
    }

    #[test]
    fn open_loop_conserves_requests_and_reports_classes() {
        let cfg = OpenLoopConfig {
            n_requests: 24,
            arrival_rate: 2000.0,
            max_slots: 4,
            ..Default::default()
        };
        let rep =
            serve_open_loop(MockExecutor::default(), "fp", "mock", "native", &cfg).unwrap();
        assert_eq!(rep.lost, 0, "no request may vanish");
        assert_eq!(rep.requests, 24);
        let total: usize = rep.classes.iter().map(|c| c.requests).sum();
        assert_eq!(total, 24, "every result lands in exactly one class");
        let completed: usize = rep.classes.iter().map(|c| c.completed).sum();
        assert_eq!(completed, 24, "unbounded queue, no deadline: all complete");
        for c in rep.classes.iter().filter(|c| c.completed > 0) {
            assert!(c.ttft_ms[2] >= c.ttft_ms[0], "p99 >= p50");
        }
    }

    #[test]
    fn open_loop_backpressure_rejects_but_conserves() {
        let cfg = OpenLoopConfig {
            n_requests: 32,
            arrival_rate: 1e6, // everything arrives at once
            max_slots: 2,
            queue_depth: Some(2),
            ..Default::default()
        };
        let rep =
            serve_open_loop(MockExecutor::default(), "fp", "mock", "native", &cfg).unwrap();
        assert_eq!(rep.lost, 0);
        let rejected: usize = rep.classes.iter().map(|c| c.rejected).sum();
        let completed: usize = rep.classes.iter().map(|c| c.completed).sum();
        assert!(rejected > 0, "flood + tiny queue must reject");
        assert_eq!(rejected + completed, 32);
    }

    #[test]
    fn serving_json_well_formed() {
        let cfg = OpenLoopConfig { n_requests: 8, arrival_rate: 5000.0, ..Default::default() };
        let rep =
            serve_open_loop(MockExecutor::default(), "fp", "mock", "native", &cfg).unwrap();
        let s = rep.render_json();
        assert!(s.contains("\"bench\": \"serving\""));
        assert!(s.contains("\"schema\": 1"));
        assert!(s.contains("\"lost\": 0"));
        assert!(s.contains("\"resident_weight_bytes\": 0"));
        assert!(s.contains("\"ttft_p90_ms\""));
        assert!(s.contains("\"itl_p99_ms\""));
        assert!(!s.contains("NaN") && !s.contains("inf"));
        // crude balance check on braces/brackets
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }
}
