//! Shared serving-report types: the closed-loop [`ServeReport`] and the
//! open-loop [`ServingReport`] used to carry duplicated tag/weights/
//! throughput/footprint fields; both now wrap one [`ReportCore`] and the
//! JSON/emit path lives here. New footprint keys (`kv_resident_bytes`,
//! `kv_pages_shared`) are **additive**: `BENCH_serving.json` stays
//! schema 1 and `scripts/bench_diff.py` tolerates their absence in old
//! snapshots.

use crate::coordinator::{EngineStats, FinishReason, GenResult};
use crate::data::PayloadClass;
use crate::util::Summary;

/// Memory-footprint block shared by both report kinds.
#[derive(Clone, Copy, Debug, Default)]
pub struct Residency {
    /// Bytes of model weights resident in the executor (packed MX bytes
    /// when `--packed-weights`, f32 bytes otherwise). 0 when the executor
    /// does not expose a footprint (mock/XLA paths).
    pub weight_bytes: usize,
    /// Bytes of KV page storage resident at run end (the lazy page pool's
    /// high-water mark; scale+code bytes under `--kv-bits 8/4`).
    pub kv_bytes: usize,
    /// Cumulative KV pages mapped by prompt-prefix sharing instead of
    /// being written.
    pub kv_pages_shared: u64,
}

impl Residency {
    /// The three footprint keys, one JSON line each — the single render
    /// path both reports use.
    fn render_json_fields(&self) -> String {
        format!(
            "  \"resident_weight_bytes\": {},\n  \"kv_resident_bytes\": {},\n  \
             \"kv_pages_shared\": {},\n",
            self.weight_bytes, self.kv_bytes, self.kv_pages_shared
        )
    }
}

/// Fields common to every serving report, whatever the load model.
#[derive(Clone, Debug, Default)]
pub struct ReportCore {
    pub tag: String,
    pub weights: String,
    /// "native" | "xla" — which executor decoded ("" until a runner
    /// wrapper fills it in).
    pub backend: String,
    /// Closed-loop: completed requests (the percentile population).
    /// Open-loop: requests submitted (arrival schedule length).
    pub requests: usize,
    pub wall_s: f64,
    pub decode_tok_per_s: f64,
    pub residency: Residency,
    /// Requests assigned per shard worker by the router's least-loaded
    /// policy (`--workers N`). Empty on the single-worker legacy path.
    pub worker_requests: Vec<usize>,
}

/// Aggregated serving metrics for one closed-loop run. Percentiles are
/// computed over **completed** requests only (EOS/length/KV-limit);
/// rejected or evicted lifecycles have no meaningful latency sample.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub core: ReportCore,
    pub total_tok_per_s: f64,
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
}

impl ServeReport {
    pub fn from_results(
        tag: &str,
        weights: &str,
        results: &[GenResult],
        stats: &EngineStats,
    ) -> ServeReport {
        let completed: Vec<&GenResult> =
            results.iter().filter(|r| r.outcome.is_complete()).collect();
        let core = ReportCore {
            tag: tag.to_string(),
            weights: weights.to_string(),
            backend: String::new(),
            requests: completed.len(),
            wall_s: stats.wall_s,
            decode_tok_per_s: stats.decode_tok_per_s(),
            residency: Residency::default(),
            worker_requests: Vec::new(),
        };
        if completed.is_empty() {
            // Explicit zero-request report: percentiles over an empty
            // sample set are meaningless, so report zeros instead of
            // whatever an empty Summary would produce.
            return ServeReport {
                core: ReportCore { decode_tok_per_s: 0.0, ..core },
                total_tok_per_s: 0.0,
                ttft_p50_ms: 0.0,
                ttft_p99_ms: 0.0,
                latency_p50_ms: 0.0,
                latency_p99_ms: 0.0,
            };
        }
        let mut ttft = Summary::new();
        let mut lat = Summary::new();
        let mut total_toks = 0usize;
        for r in &completed {
            ttft.push(r.ttft_s * 1e3);
            lat.push(r.total_s * 1e3);
            total_toks += r.prompt_len + r.tokens.len();
        }
        ServeReport {
            core,
            total_tok_per_s: total_toks as f64 / stats.wall_s.max(1e-9),
            ttft_p50_ms: ttft.percentile(50.0),
            ttft_p99_ms: ttft.percentile(99.0),
            latency_p50_ms: lat.percentile(50.0),
            latency_p99_ms: lat.percentile(99.0),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.core.requests == 0
    }
}

/// Per-payload-class SLO aggregation: outcome counts + TTFT and
/// inter-token-latency percentiles over the class's completed requests.
#[derive(Clone, Debug)]
pub struct ClassLatency {
    pub class: String,
    pub requests: usize,
    pub completed: usize,
    pub rejected: usize,
    pub timed_out: usize,
    pub cancelled: usize,
    /// [p50, p90, p99] time-to-first-token, milliseconds.
    pub ttft_ms: [f64; 3],
    /// [p50, p90, p99] inter-token latency, milliseconds.
    pub itl_ms: [f64; 3],
}

/// One open-loop serving run, aggregated per class — serialized to
/// `BENCH_serving.json` (schema 1) for in-repo regression diffing.
#[derive(Clone, Debug)]
pub struct ServingReport {
    pub core: ReportCore,
    pub arrival_rate: f64,
    pub queue_depth: Option<usize>,
    pub deadline_ms: Option<f64>,
    /// Submitted requests that produced no result — must be 0; anything
    /// else is a conservation bug and CI's serving smoke fails on it.
    pub lost: usize,
    pub classes: Vec<ClassLatency>,
}

impl ServingReport {
    pub(crate) fn aggregate(
        classes: &[PayloadClass],
        class_of: &[usize],
        results: &[GenResult],
    ) -> Vec<ClassLatency> {
        let mut out: Vec<ClassLatency> = classes
            .iter()
            .map(|c| ClassLatency {
                class: c.name.to_string(),
                requests: 0,
                completed: 0,
                rejected: 0,
                timed_out: 0,
                cancelled: 0,
                ttft_ms: [0.0; 3],
                itl_ms: [0.0; 3],
            })
            .collect();
        let mut ttft: Vec<Summary> = classes.iter().map(|_| Summary::new()).collect();
        let mut itl: Vec<Summary> = classes.iter().map(|_| Summary::new()).collect();
        for r in results {
            let ci = class_of[r.id as usize];
            out[ci].requests += 1;
            match r.outcome {
                o if o.is_complete() => {
                    out[ci].completed += 1;
                    ttft[ci].push(r.ttft_s * 1e3);
                    for s in r.inter_token_s() {
                        itl[ci].push(s * 1e3);
                    }
                }
                FinishReason::RejectedQueueFull => out[ci].rejected += 1,
                FinishReason::TimedOut => out[ci].timed_out += 1,
                FinishReason::Cancelled => out[ci].cancelled += 1,
                _ => unreachable!("is_complete covers the remaining outcomes"),
            }
        }
        for (ci, c) in out.iter_mut().enumerate() {
            if c.completed > 0 {
                for (k, p) in [50.0, 90.0, 99.0].into_iter().enumerate() {
                    c.ttft_ms[k] = ttft[ci].percentile(p);
                    c.itl_ms[k] = itl[ci].percentile(p);
                }
            }
        }
        out
    }

    /// Render as the `BENCH_serving.json` document (schema 1):
    ///
    /// ```json
    /// {
    ///   "bench": "serving", "schema": 1, "backend": "native",
    ///   "tag": "fp", "weights": "fp16",
    ///   "arrival_rate": 100.0, "requests": 64, "lost": 0,
    ///   "wall_s": ..., "decode_tok_per_s": ...,
    ///   "resident_weight_bytes": 0,
    ///   "kv_resident_bytes": 0, "kv_pages_shared": 0,
    ///   "classes": [
    ///     {"class": "short", "requests": 40, "completed": 40,
    ///      "rejected": 0, "timed_out": 0, "cancelled": 0,
    ///      "ttft_p50_ms": ..., "ttft_p90_ms": ..., "ttft_p99_ms": ...,
    ///      "itl_p50_ms": ..., "itl_p90_ms": ..., "itl_p99_ms": ...}
    ///   ]
    /// }
    /// ```
    pub fn render_json(&self) -> String {
        use crate::bench::json_str;
        let mut out = String::from("{\n");
        out += "  \"bench\": \"serving\",\n  \"schema\": 1,\n";
        out += &format!("  \"backend\": {},\n", json_str(&self.core.backend));
        out += &format!("  \"tag\": {},\n", json_str(&self.core.tag));
        out += &format!("  \"weights\": {},\n", json_str(&self.core.weights));
        out += &format!("  \"arrival_rate\": {:e},\n", self.arrival_rate);
        match self.queue_depth {
            Some(d) => out += &format!("  \"queue_depth\": {d},\n"),
            None => out += "  \"queue_depth\": null,\n",
        }
        match self.deadline_ms {
            Some(d) => out += &format!("  \"deadline_ms\": {d:e},\n"),
            None => out += "  \"deadline_ms\": null,\n",
        }
        out += &format!("  \"requests\": {},\n", self.core.requests);
        out += &format!("  \"lost\": {},\n", self.lost);
        out += &format!("  \"wall_s\": {:e},\n", self.core.wall_s);
        out += &format!("  \"decode_tok_per_s\": {:e},\n", self.core.decode_tok_per_s);
        out += &self.core.residency.render_json_fields();
        out += "  \"classes\": [\n";
        let rows: Vec<String> = self
            .classes
            .iter()
            .map(|c| {
                format!(
                    "    {{\"class\": {}, \"requests\": {}, \"completed\": {}, \
                     \"rejected\": {}, \"timed_out\": {}, \"cancelled\": {}, \
                     \"ttft_p50_ms\": {:e}, \"ttft_p90_ms\": {:e}, \"ttft_p99_ms\": {:e}, \
                     \"itl_p50_ms\": {:e}, \"itl_p90_ms\": {:e}, \"itl_p99_ms\": {:e}}}",
                    json_str(&c.class),
                    c.requests,
                    c.completed,
                    c.rejected,
                    c.timed_out,
                    c.cancelled,
                    c.ttft_ms[0],
                    c.ttft_ms[1],
                    c.ttft_ms[2],
                    c.itl_ms[0],
                    c.itl_ms[1],
                    c.itl_ms[2],
                )
            })
            .collect();
        out += &rows.join(",\n");
        out += "\n  ]\n}\n";
        out
    }

    /// Write `BENCH_serving.json` at the repo root (or `LATMIX_BENCH_DIR`),
    /// mirroring the microbench snapshot conventions. Returns the path.
    pub fn emit(&self) -> std::path::PathBuf {
        let dir = match std::env::var("LATMIX_BENCH_DIR") {
            Ok(d) => std::path::PathBuf::from(d),
            Err(_) => crate::bench::repo_root(),
        };
        let path = dir.join("BENCH_serving.json");
        if let Err(e) = std::fs::write(&path, self.render_json()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        }
        path
    }
}
