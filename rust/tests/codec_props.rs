//! Bit-exactness and determinism properties for the optimized MX codec.
//!
//! The fast path (LUT decode, branchless encode, multiply-by-exact-inverse
//! scales, scoped-pool parallelism) must agree bit-for-bit with the
//! retained scalar reference (`latmix::mx::reference`) on every format,
//! block size, and adversarial edge input — all-zero blocks, negative
//! zeros, denormal-range magnitudes, saturating magnitudes — and must be
//! invariant to the worker count.

use latmix::coordinator::KvCache;
use latmix::mx::pack::PackedMx;
use latmix::mx::reference;
use latmix::mx::{mx_qdq, MxConfig};
use latmix::quant::{gptq_quantize, rtn_quantize};
use latmix::testing::{forall, VecGen};
use latmix::util::{par, Pcg64};

const ALL_FORMATS: [&str; 5] = ["mxfp4", "mxint4", "mxfp6", "mxfp8", "nvfp4"];
const PACK_FORMATS: [&str; 2] = ["mxfp4", "mxint4"];

fn bits_eq(fast: &[f32], reference: &[f32]) -> Result<(), String> {
    if fast.len() != reference.len() {
        return Err(format!("len {} vs {}", fast.len(), reference.len()));
    }
    for (i, (a, b)) in fast.iter().zip(reference).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!(
                "idx {i}: fast {a} ({:#010x}) vs ref {b} ({:#010x})",
                a.to_bits(),
                b.to_bits()
            ));
        }
    }
    Ok(())
}

/// Hand-built adversarial inputs: all zeros, negative zeros, denormal-range
/// magnitudes with mixed signs, and a normal/denormal/saturating mix.
fn edge_inputs(block: usize) -> Vec<Vec<f32>> {
    let n = 2 * block;
    let mut cases = vec![vec![0.0f32; n], vec![-0.0f32; n]];
    let denorm: Vec<f32> = (0..n)
        .map(|i| {
            let v = f32::from_bits(1 + i as u32); // smallest subnormals
            if i % 2 == 0 {
                v
            } else {
                -v
            }
        })
        .collect();
    cases.push(denorm);
    let mut mixed = vec![0.0f32; n];
    mixed[0] = -0.0;
    mixed[1] = f32::MIN_POSITIVE; // smallest normal
    mixed[2] = -f32::MIN_POSITIVE / 2.0; // subnormal
    mixed[3] = f32::MAX;
    mixed[4] = -1.5e-39; // subnormal
    mixed[5] = 1e-44; // near-bottom subnormal
    mixed[block] = 1.0; // second block is ordinary
    mixed[block + 1] = -3.25;
    cases.push(mixed);
    cases
}

#[test]
fn qdq_bit_exact_vs_reference() {
    for fmt in ALL_FORMATS {
        for block in [16usize, 32] {
            let cfg = MxConfig::from_name(fmt, Some(block)).unwrap();
            // log-magnitude spread down into the denormal range and up to
            // overflow-adjacent scales
            let gen = VecGen {
                min_len: block,
                max_len: block * 64,
                multiple_of: block,
                log_scale_range: (-140.0, 30.0),
            };
            forall(&format!("qdq_exact_{fmt}_{block}"), 50, &gen, |v| {
                let fast = mx_qdq(v, v.len(), &cfg);
                let reff = reference::mx_qdq_ref(v, v.len(), &cfg);
                bits_eq(&fast, &reff)
            });
            for (ei, v) in edge_inputs(block).into_iter().enumerate() {
                let fast = mx_qdq(&v, v.len(), &cfg);
                let reff = reference::mx_qdq_ref(&v, v.len(), &cfg);
                bits_eq(&fast, &reff)
                    .unwrap_or_else(|e| panic!("{fmt} b{block} edge case {ei}: {e}"));
            }
        }
    }
}

#[test]
fn pack_bit_exact_vs_reference() {
    for fmt in PACK_FORMATS {
        for block in [16usize, 32] {
            let cfg = MxConfig::from_name(fmt, Some(block)).unwrap();
            let gen = VecGen {
                min_len: block,
                max_len: block * 64,
                multiple_of: block,
                log_scale_range: (-140.0, 30.0),
            };
            let check = |v: &Vec<f32>| -> Result<(), String> {
                let fast = PackedMx::pack(v, cfg);
                let (scales, codes) = reference::pack_ref(v, &cfg);
                if fast.scales != scales {
                    return Err("scale bytes differ from scalar reference".into());
                }
                if fast.codes != codes {
                    return Err("code bytes differ from scalar reference".into());
                }
                let un = fast.unpack();
                let un_ref = reference::unpack_ref(&cfg, v.len(), &scales, &codes);
                bits_eq(&un, &un_ref)
            };
            forall(&format!("pack_exact_{fmt}_{block}"), 50, &gen, &check);
            for (ei, v) in edge_inputs(block).into_iter().enumerate() {
                check(&v).unwrap_or_else(|e| panic!("{fmt} b{block} edge case {ei}: {e}"));
            }
        }
    }
}

/// The parallel fan-out must not change a single bit: 1 worker vs N.
#[test]
fn qdq_thread_count_invariant() {
    let mut rng = Pcg64::seed(77);
    let n = 1 << 15; // above PAR_MIN_LEN -> parallel path engaged
    let x = rng.normal_vec(n, 3.0);
    for fmt in ALL_FORMATS {
        let cfg = MxConfig::from_name(fmt, Some(32)).unwrap();
        let one = par::with_threads(1, || mx_qdq(&x, n, &cfg));
        for t in [2usize, 3, 7, 16] {
            let many = par::with_threads(t, || mx_qdq(&x, n, &cfg));
            bits_eq(&many, &one).unwrap_or_else(|e| panic!("{fmt} threads={t}: {e}"));
        }
    }
}

#[test]
fn pack_thread_count_invariant() {
    let mut rng = Pcg64::seed(78);
    let n = 1 << 15;
    let x = rng.normal_vec(n, 2.0);
    for fmt in PACK_FORMATS {
        let cfg = MxConfig::from_name(fmt, Some(32)).unwrap();
        let p1 = par::with_threads(1, || PackedMx::pack(&x, cfg));
        for t in [2usize, 5, 16] {
            let pt = par::with_threads(t, || PackedMx::pack(&x, cfg));
            assert_eq!(p1.scales, pt.scales, "{fmt} threads={t} scales");
            assert_eq!(p1.codes, pt.codes, "{fmt} threads={t} codes");
            let mut u1 = vec![0.0f32; n];
            let mut ut = vec![0.0f32; n];
            par::with_threads(1, || p1.unpack_into(&mut u1));
            par::with_threads(t, || pt.unpack_into(&mut ut));
            bits_eq(&ut, &u1).unwrap_or_else(|e| panic!("{fmt} threads={t}: {e}"));
        }
    }
}

#[test]
fn rtn_gptq_thread_count_invariant() {
    let mut rng = Pcg64::seed(79);
    let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    // rtn: 128x64 = 8192 elements -> parallel path
    let (d_in, d_out) = (128usize, 64usize);
    let w = rng.normal_vec(d_in * d_out, 0.5);
    let r1 = par::with_threads(1, || rtn_quantize(&w, d_in, d_out, &cfg));
    let rn = par::with_threads(6, || rtn_quantize(&w, d_in, d_out, &cfg));
    bits_eq(&rn, &r1).unwrap_or_else(|e| panic!("rtn: {e}"));
    // gptq: 64x96 = 6144 elements -> parallel path
    let (d_in, d_out) = (64usize, 96usize);
    let w = rng.normal_vec(d_in * d_out, 0.5);
    let mut h = latmix::linalg::Mat::eye(d_in);
    for i in 0..d_in {
        h[(i, i)] += 5.0 + (i % 3) as f32;
    }
    let g1 = par::with_threads(1, || gptq_quantize(&w, d_in, d_out, &h, &cfg, 0.01));
    let gn = par::with_threads(6, || gptq_quantize(&w, d_in, d_out, &h, &cfg, 0.01));
    bits_eq(&gn, &g1).unwrap_or_else(|e| panic!("gptq: {e}"));
}

/// Paged-KV gather above the parallel threshold: the page-table
/// materialization is thread-count invariant and append steps land each
/// lane's row at its own position, bit-exactly.
#[test]
fn kv_batch_ops_parallel_roundtrip() {
    let (layers, seq, row) = (3usize, 64usize, 32usize);
    let mut kv = KvCache::new(6, layers, seq, row);
    let mut rng = Pcg64::seed(80);
    let ids: Vec<u64> = (0..6).collect();
    let plen = 20usize; // ragged against the default 16-token page
    let mut prefills: Vec<Vec<Vec<f32>>> = Vec::new();
    for &id in &ids {
        kv.alloc(id).unwrap();
        // single-lane prefill planes: (1, plen rows live, seq * row total)
        let planes: Vec<Vec<f32>> = (0..layers * 2)
            .map(|_| {
                let mut p = vec![0.0f32; seq * row];
                p[..plen * row].copy_from_slice(&rng.normal_vec(plen * row, 1.0));
                p
            })
            .collect();
        let prompt: Vec<i32> = (0..plen as i32).map(|t| t + id as i32 * 100).collect();
        kv.write_prefill(id, &prompt, &planes, 0).unwrap();
        prefills.push(planes);
    }
    // batch * plane * planes = 6*2048*6 = 73728 >= PAR_MIN_LEN -> parallel
    let g = par::with_threads(4, || kv.gather_batch(&ids, 6).unwrap());
    let g_serial = par::with_threads(1, || kv.gather_batch(&ids, 6).unwrap());
    for (a, b) in g.iter().zip(&g_serial) {
        assert_eq!(a, b, "gather is thread-count invariant");
    }
    for (li, plane) in g.iter().enumerate() {
        for (lane, planes) in prefills.iter().enumerate() {
            assert_eq!(
                &plane[lane * seq * row..lane * seq * row + plen * row],
                &planes[li][..plen * row],
                "lane {lane} plane {li}: prefill rows materialize exactly"
            );
            assert!(
                plane[lane * seq * row + plen * row..(lane + 1) * seq * row]
                    .iter()
                    .all(|v| *v == 0.0),
                "rows beyond pos stay zero"
            );
        }
    }
    // one append step: each lane gets a distinct fresh row at pos = plen
    let rows: Vec<Vec<f32>> =
        (0..layers * 2).map(|_| rng.normal_vec(6 * row, 1.0)).collect();
    kv.append_step(&ids, 6, &rows).unwrap();
    for &id in &ids {
        assert_eq!(kv.pos_of(id), Some(plen + 1), "pos bumped exactly once");
    }
    let g2 = kv.gather_batch(&ids, 6).unwrap();
    for (li, plane) in g2.iter().enumerate() {
        for lane in 0..6 {
            let at = lane * seq * row + plen * row;
            assert_eq!(
                &plane[at..at + row],
                &rows[li][lane * row..(lane + 1) * row],
                "lane {lane} plane {li}: appended row round-trips"
            );
        }
    }
}
