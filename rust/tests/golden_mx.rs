//! Cross-language golden checks: the Rust MX codecs must agree with the
//! Python reference on the exact tensors in `artifacts/golden/mx_qdq.lxt`
//! (written by `python/compile/aot.py::emit_goldens`).
//!
//! Contract: <= 1 ULP everywhere, and bit-exact for the 4-bit grids.
//! XLA's CPU `exp2` can return a power of two 1 ULP low (e.g. 2^-13 as
//! 0x3a9fffff); the Rust side constructs scales exactly from the exponent
//! bits, so fp8/fp6 values (fine mantissa grids) may differ by that ULP
//! while the coarse fp4/int4 grids absorb it.
//! NVFP4 (non-power-of-two scale divisions): <= 2 ULP relative.

use latmix::io::load_lxt;
use latmix::mx::{mx_qdq, MxConfig};

fn golden_path() -> Option<std::path::PathBuf> {
    let p = latmix::artifacts_dir().join("golden").join("mx_qdq.lxt");
    p.exists().then_some(p)
}

#[test]
fn golden_mx_qdq_cross_check() {
    let Some(path) = golden_path() else {
        eprintln!("skipping: artifacts/golden/mx_qdq.lxt missing (run `make artifacts`)");
        return;
    };
    let map = load_lxt(&path).unwrap();
    let input = map["input"].as_f32().unwrap();
    let row = map["input"].dims[1];
    let mut checked = 0;
    for (name, tensor) in &map {
        if name == "input" {
            continue;
        }
        let (fmt, block) = name.rsplit_once("_b").unwrap();
        let cfg = MxConfig::from_name(fmt, Some(block.parse().unwrap())).unwrap();
        let expect = tensor.as_f32().unwrap();
        let got = mx_qdq(input, row, &cfg);
        if fmt == "mxfp4" || fmt == "mxint4" {
            // coarse 4-bit grids absorb XLA's exp2 ULP error: bit-exact.
            for (i, (e, g)) in expect.iter().zip(&got).enumerate() {
                assert!(
                    e.to_bits() == g.to_bits(),
                    "{name}[{i}]: python {e} ({:#x}) vs rust {g} ({:#x})",
                    e.to_bits(),
                    g.to_bits()
                );
            }
        } else {
            // fp6/fp8 mantissa grids expose the exp2 ULP, nvfp4 divides by
            // non-powers-of-two: agree to ~1e-6 relative.
            for (i, (e, g)) in expect.iter().zip(&got).enumerate() {
                let tol = e.abs().max(1e-30) * 1e-6;
                assert!(
                    (e - g).abs() <= tol,
                    "{name}[{i}]: python {e} vs rust {g}"
                );
            }
        }
        checked += 1;
    }
    assert!(checked >= 15, "expected >= 15 golden format/block combos, got {checked}");
}
