//! The 1-vs-N tensor-parallel bit-parity suite for the sharded
//! `NativeExecutor` forward (`--workers N`).
//!
//! The sharded forward owes a hard guarantee: for a fixed shard *plan*
//! (head-sharded attention, fixed `d_ff` band partition), the worker
//! count is pure execution parallelism — per-unit partials are computed
//! over the same logical partition whatever the worker count and reduced
//! serially in ascending unit order, so f32 association never depends on
//! how many threads ran. These tests gate that guarantee end to end:
//!
//! - step-level logits and KV planes bit-identical for 1/2/4 workers, on
//!   the fp and quantized (`mxfp4_b32_t3`) graph specs, for dense *and*
//!   bit-packed MX weights;
//! - whole-engine token streams and `sched_fingerprint` identical across
//!   worker counts, with f32 and MX-paged (mxfp8) KV storage;
//! - ragged ownership (`n_heads % workers != 0`, ragged `d_ff` bands)
//!   changes nothing;
//! - the negative paths fail loud: 0 workers, more workers than heads;
//! - the manifest shard keys are additive: version-2 manifests with (or
//!   without) `shard.*` keys — and with unknown future `shard.*` keys —
//!   load on the appropriate path.

use latmix::coordinator::engine::{Engine, EngineConfig, NativeExecutor, StepExecutor};
use latmix::coordinator::{GenRequest, KvSpec};
use latmix::io::MANIFEST_VERSION;
use latmix::model::{ModelDesc, NativeDims, ShardPlan};
use latmix::runtime::sched_fingerprint;

fn tiny() -> NativeDims {
    NativeDims::latmix_tiny() // 4 heads, d_ff 384: supports 1/2/4 workers
}

/// Build the executor for one (tag, packed, workers) config off one fixed
/// synthetic weight seed, so every worker count serves the same model.
fn exec(tag: &str, packed: bool, workers: usize) -> NativeExecutor {
    let mut e = NativeExecutor::synthetic(tiny(), tag, vec![1, 2, 4], 23).unwrap();
    if packed {
        e = e.into_packed().unwrap();
    }
    e.with_workers(workers).unwrap()
}

/// One closed-loop engine run: per-request token streams plus the
/// scheduling-event fingerprint.
fn run_engine(e: NativeExecutor, kv: KvSpec) -> (Vec<(u64, Vec<i32>)>, u64) {
    let mut engine =
        Engine::new(e, EngineConfig { max_slots: 4, eos: -1, kv, ..Default::default() });
    for i in 0..6u64 {
        let prompt = vec![1, 40 + i as i32, 50, 3 + (i as i32 % 7)];
        engine.submit(GenRequest::new(i, prompt, 6));
    }
    let out = engine.run_to_completion().unwrap();
    let toks = out.iter().map(|r| (r.id, r.tokens.clone())).collect();
    (toks, sched_fingerprint(engine.events()))
}

/// Step-level trace: prefill logits + 3 chained decode_append steps, all
/// captured as exact bit patterns (logits and fresh KV rows).
fn step_trace(e: &NativeExecutor) -> Vec<Vec<u32>> {
    let pl = e.prefill_len();
    let batch = 2;
    let mut tokens = vec![0i32; batch * pl];
    tokens[..4].copy_from_slice(&[1, 9, 2, 200]);
    tokens[pl..pl + 3].copy_from_slice(&[7, 7, 30]);
    let lens = [4i32, 3];
    let (logits, mut kv) = e.prefill(&tokens, &lens, batch).unwrap();
    let mut trace = vec![logits.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()];
    let mut pos = [4i32, 3];
    let mut next = [11i32, 42];
    for _ in 0..3 {
        let (lg, rows) = e.decode_append(&next, &pos, &kv, batch).unwrap();
        trace.push(lg.iter().map(|v| v.to_bits()).collect());
        for r in &rows {
            trace.push(r.iter().map(|v| v.to_bits()).collect());
        }
        // write the fresh rows back into the dense planes (what the paged
        // cache does) so the next step sees them
        let (row, plane) = (e.kv_row(), e.kv_seq() * e.kv_row());
        for (li, r) in rows.iter().enumerate() {
            for b in 0..batch {
                let at = b * plane + pos[b] as usize * row;
                kv[li][at..at + row].copy_from_slice(&r[b * row..(b + 1) * row]);
            }
        }
        let vocab = e.vocab();
        for b in 0..batch {
            next[b] = argmax(&lg[b * vocab..(b + 1) * vocab]);
            pos[b] += 1;
        }
    }
    trace
}

fn argmax(v: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, x) in v.iter().enumerate() {
        if *x > bv {
            bv = *x;
            best = i;
        }
    }
    best as i32
}

#[test]
fn step_logits_bit_identical_across_worker_counts() {
    for tag in ["fp", "mxfp4_b32_t3"] {
        for packed in [false, true] {
            if packed && tag == "fp" {
                continue; // packing requires a quantized tag
            }
            let base = step_trace(&exec(tag, packed, 1));
            for w in [2usize, 4] {
                let got = step_trace(&exec(tag, packed, w));
                assert_eq!(
                    base, got,
                    "tag={tag} packed={packed}: workers=1 vs {w} logits/KV bits diverged"
                );
            }
        }
    }
}

#[test]
fn engine_tokens_and_fingerprint_identical_across_worker_counts() {
    // f32 KV and MX-paged mxfp8 KV: the KV codec quantizes whatever rows
    // the executor appends, so bit-identical rows => identical streams.
    let kvs = [KvSpec::default(), KvSpec::from_bits(8).unwrap()];
    for tag in ["fp", "mxfp4_b32_t3"] {
        for kv in kvs {
            let (toks1, fp1) = run_engine(exec(tag, false, 1), kv);
            for w in [2usize, 4] {
                let (toksw, fpw) = run_engine(exec(tag, false, w), kv);
                assert_eq!(
                    toks1, toksw,
                    "tag={tag} kv={:?}: token streams diverged at workers={w}",
                    kv.format
                );
                assert_eq!(fp1, fpw, "tag={tag}: scheduling fingerprint diverged");
            }
        }
    }
}

#[test]
fn packed_engine_parity_across_worker_counts() {
    // Packed-weight sharding replays the dense kernel's k-order over
    // decoded panels, so the packed executor owes the same 1-vs-N bit
    // parity (checked through the whole engine, mxfp8-paged KV).
    let kv = KvSpec::from_bits(8).unwrap();
    let (toks1, fp1) = run_engine(exec("mxfp4_b32_t3", true, 1), kv);
    for w in [2usize, 4] {
        let (toksw, fpw) = run_engine(exec("mxfp4_b32_t3", true, w), kv);
        assert_eq!(toks1, toksw, "packed token streams diverged at workers={w}");
        assert_eq!(fp1, fpw);
    }
}

#[test]
fn legacy_unsharded_scheduling_fingerprint_matches_sharded() {
    // Sharded logits may differ from the legacy forward by f32 association
    // (two row-split reductions), but scheduling is value-independent here
    // (fixed max_new, no EOS), so the event fingerprint must agree even
    // with the legacy path.
    let legacy = NativeExecutor::synthetic(tiny(), "fp", vec![1, 2, 4], 23).unwrap();
    let (_, fp_legacy) = run_engine(legacy, KvSpec::default());
    let (_, fp_shard) = run_engine(exec("fp", false, 4), KvSpec::default());
    assert_eq!(fp_legacy, fp_shard);
}

#[test]
fn ragged_ownership_is_bit_identical() {
    // workers=3 over 4 heads: the last worker owns no head in stage 1 and
    // a short band run in the FFN; a ragged ffn_block (5 does not divide
    // 384) exercises the short-final-band path too.
    let mk = |workers: usize| {
        let e = NativeExecutor::synthetic(tiny(), "mxfp4_b32_t3", vec![1, 2, 4], 29).unwrap();
        e.with_shard_plan(ShardPlan { workers, ffn_block: 5 }).unwrap()
    };
    let base = step_trace(&mk(1));
    for w in [2usize, 3] {
        assert_eq!(base, step_trace(&mk(w)), "ragged plan diverged at workers={w}");
    }
}

#[test]
fn invalid_worker_counts_fail_loud() {
    let e = NativeExecutor::synthetic(tiny(), "fp", vec![1, 2, 4], 23).unwrap();
    let err = e.clone().with_workers(0).unwrap_err().to_string();
    assert!(err.contains("at least 1 worker"), "got: {err}");
    // tiny() has 4 heads: a 5th worker would own no attention shard
    let err = e.clone().with_workers(5).unwrap_err().to_string();
    assert!(err.contains("exceeds n_heads"), "got: {err}");
    let err = e
        .with_shard_plan(ShardPlan { workers: 2, ffn_block: 0 })
        .unwrap_err()
        .to_string();
    assert!(err.contains("ffn_block"), "got: {err}");
}

#[test]
fn manifest_shard_keys_are_additive() {
    let dims = tiny();
    let dir = std::env::temp_dir().join("latmix_shard_manifest_test");
    std::fs::create_dir_all(&dir).unwrap();
    let desc = |shard: bool| ModelDesc {
        vocab: dims.vocab,
        d_model: dims.d_model,
        n_layers: dims.n_layers,
        n_heads: dims.n_heads,
        d_ff: dims.d_ff,
        kv_seq: dims.kv_seq,
        prefill_len: dims.prefill_len,
        ppl_shape: (4, 16),
        score_shape: (4, 16),
        weight_order: vec!["w".to_string()],
        graphs: vec!["decode_fp_b1".to_string()],
        artifacts: dir.clone(),
        version: MANIFEST_VERSION,
        transform_folded: None,
        transform_online: None,
        shard_attn: if shard { Some("head".to_string()) } else { None },
        shard_ffn_block: if shard { Some(ShardPlan::default_ffn_block(dims.d_ff)) } else { None },
    };

    // no shard keys: loads on the old (single-worker) path
    desc(false).write_manifest(&dir).unwrap();
    let loaded = ModelDesc::load(&dir).unwrap();
    assert_eq!(loaded.version, MANIFEST_VERSION);
    assert_eq!(loaded.shard_attn, None);
    assert_eq!(loaded.shard_ffn_block, None);

    // shard keys present (what `latmix fold` writes): version stays 2 and
    // both keys round-trip
    desc(true).write_manifest(&dir).unwrap();
    let loaded = ModelDesc::load(&dir).unwrap();
    assert_eq!(loaded.version, MANIFEST_VERSION);
    assert_eq!(loaded.shard_attn.as_deref(), Some("head"));
    assert_eq!(loaded.shard_ffn_block, Some(ShardPlan::default_ffn_block(dims.d_ff)));

    // an unknown future shard key is tolerated, not fatal
    let mpath = dir.join("manifest.txt");
    let mut txt = std::fs::read_to_string(&mpath).unwrap();
    txt.push_str("shard.kv=page\n");
    std::fs::write(&mpath, txt).unwrap();
    let loaded = ModelDesc::load(&dir).unwrap();
    assert_eq!(loaded.shard_attn.as_deref(), Some("head"));
    std::fs::remove_dir_all(&dir).ok();
}
