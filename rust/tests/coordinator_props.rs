//! Property tests on the coordinator invariants (routing, batching, KV
//! state) and the MX codecs, using the in-repo `testing` framework
//! (proptest is not vendorable offline; DESIGN.md §3.1). The engine
//! properties run over both `StepExecutor` backends that exist on every
//! build: the mock and the pure-Rust `NativeExecutor`.

use std::time::Duration;

use latmix::coordinator::engine::{Engine, EngineConfig, MockExecutor, NativeExecutor};
use latmix::coordinator::{Batcher, FinishReason, GenRequest, KvCache, KvFormat, KvSpec, Router};
use latmix::model::NativeDims;
use latmix::mx::{mx_qdq, pack::PackedMx, MxConfig};
use latmix::testing::{forall, ScriptGen, UsizeGen, VecGen};
use latmix::util::Pcg64;

/// Small native executor with the same shape knobs as the default mock.
fn native_exec(seed: u64) -> NativeExecutor {
    let dims = NativeDims {
        vocab: 64,
        d_model: 4,
        n_layers: 2,
        n_heads: 2,
        d_ff: 8,
        kv_seq: 32,
        prefill_len: 8,
    };
    NativeExecutor::synthetic(dims, "fp", vec![1, 2, 4], seed).unwrap()
}

#[test]
fn prop_mx_qdq_idempotent_fp_formats() {
    let gen = VecGen { min_len: 32, max_len: 256, multiple_of: 32, log_scale_range: (-8.0, 8.0) };
    for fmt in ["mxfp4", "mxfp6", "mxfp8"] {
        let cfg = MxConfig::from_name(fmt, Some(32)).unwrap();
        forall(&format!("qdq_idempotent_{fmt}"), 40, &gen, |v| {
            let q1 = mx_qdq(v, v.len(), &cfg);
            let q2 = mx_qdq(&q1, v.len(), &cfg);
            if q1 == q2 {
                Ok(())
            } else {
                Err("second QDQ changed values".into())
            }
        });
    }
}

#[test]
fn prop_mx_qdq_sign_and_zero_preserving() {
    let gen = VecGen { min_len: 32, max_len: 128, multiple_of: 32, log_scale_range: (-10.0, 10.0) };
    for fmt in ["mxfp4", "mxint4", "nvfp4"] {
        let cfg = MxConfig::from_name(fmt, Some(16)).unwrap();
        forall(&format!("qdq_sign_{fmt}"), 40, &gen, |v| {
            let q = mx_qdq(v, v.len(), &cfg);
            for (a, b) in v.iter().zip(&q) {
                if *a == 0.0 && *b != 0.0 {
                    return Err(format!("zero became {b}"));
                }
                if a * b < 0.0 {
                    return Err(format!("sign flip {a} -> {b}"));
                }
            }
            Ok(())
        });
    }
}

#[test]
fn prop_pack_unpack_matches_qdq() {
    let gen = VecGen { min_len: 32, max_len: 512, multiple_of: 32, log_scale_range: (-6.0, 6.0) };
    for fmt in ["mxfp4", "mxint4"] {
        let cfg = MxConfig::from_name(fmt, Some(32)).unwrap();
        forall(&format!("pack_roundtrip_{fmt}"), 40, &gen, |v| {
            let packed = PackedMx::pack(v, cfg);
            let un = packed.unpack();
            let qdq = mx_qdq(v, v.len(), &cfg);
            for (i, (a, b)) in un.iter().zip(&qdq).enumerate() {
                if (a - b).abs() > 1e-6 {
                    return Err(format!("idx {i}: packed {a} vs qdq {b}"));
                }
            }
            Ok(())
        });
    }
}

/// Batcher: no request lost or duplicated, FIFO preserved, batch <= cap.
#[test]
fn prop_batcher_conservation() {
    let gen = ScriptGen { max_len: 60, ops: 2, max_value: 9 };
    forall("batcher_conservation", 60, &gen, |script| {
        let mut b = Batcher::new(vec![1, 2, 4, 8]);
        let mut next_id = 0u64;
        let mut pushed = Vec::new();
        let mut admitted = Vec::new();
        for (op, val) in script {
            match op % 2 {
                0 => {
                    b.push(GenRequest::new(next_id, vec![1], 4));
                    pushed.push(next_id);
                    next_id += 1;
                }
                _ => {
                    let batch = b.admit(*val as usize + 1);
                    if batch.len() > 8 {
                        return Err(format!("batch {} exceeds cap", batch.len()));
                    }
                    admitted.extend(batch.iter().map(|r| r.id));
                }
            }
        }
        admitted.extend(b.admit(usize::MAX).iter().map(|r| r.id));
        while b.pending() > 0 {
            admitted.extend(b.admit(usize::MAX).iter().map(|r| r.id));
        }
        if admitted != pushed {
            return Err(format!("order/conservation broken: {admitted:?} vs {pushed:?}"));
        }
        Ok(())
    });
}

/// KV cache: alloc/free scripts never double-allocate, never leak capacity.
#[test]
fn prop_kv_slot_accounting() {
    let gen = ScriptGen { max_len: 80, ops: 2, max_value: 12 };
    forall("kv_slots", 60, &gen, |script| {
        let cap = 6;
        let mut kv = KvCache::new(cap, 2, 8, 4);
        let mut live: Vec<u64> = Vec::new();
        for (op, val) in script {
            match op % 2 {
                0 => {
                    let id = *val;
                    let ok = kv.alloc(id).is_ok();
                    let should = live.len() < cap && !live.contains(&id);
                    if ok != should {
                        return Err(format!("alloc({id}) = {ok}, expected {should}"));
                    }
                    if ok {
                        live.push(id);
                    }
                }
                _ => {
                    let id = *val;
                    let ok = kv.free(id).is_some();
                    let should = live.contains(&id);
                    if ok != should {
                        return Err(format!("free({id}) = {ok}, expected {should}"));
                    }
                    live.retain(|x| *x != id);
                }
            }
            if kv.free_slots() != cap - live.len() {
                return Err("capacity leak".into());
            }
            let mut ids = kv.ids();
            let mut expect = live.clone();
            ids.sort_unstable();
            expect.sort_unstable();
            if ids != expect {
                return Err(format!("live set mismatch {ids:?} vs {expect:?}"));
            }
        }
        Ok(())
    });
}

/// Router: loads are balanced within 1 and conserve in-flight counts.
#[test]
fn prop_router_balance() {
    let gen = UsizeGen(1, 64);
    forall("router_balance", 30, &gen, |n| {
        let mut r = Router::new(4);
        let mut ids = Vec::new();
        for _ in 0..*n {
            let (req, _) = r.route(vec![1], 4);
            ids.push(req.id);
        }
        let max = r.loads().iter().max().unwrap();
        let min = r.loads().iter().min().unwrap();
        if max - min > 1 {
            return Err(format!("imbalance {:?}", r.loads()));
        }
        if r.in_flight() != *n {
            return Err("in-flight count wrong".into());
        }
        for id in ids {
            r.mark_done(id);
        }
        if r.loads().iter().sum::<usize>() != 0 {
            return Err("loads not freed".into());
        }
        Ok(())
    });
}

/// Engine end-to-end (mock executor): every submitted request completes with
/// exactly the requested number of tokens, under random workload shapes.
#[test]
fn prop_engine_completes_all() {
    let gen = ScriptGen { max_len: 12, ops: 1, max_value: 6 };
    forall("engine_completion", 25, &gen, |script| {
        let mut e = Engine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 3, eos: -1, ..Default::default() },
        );
        let mut rng = Pcg64::seed(script.len() as u64);
        let mut want = Vec::new();
        for (i, (_, val)) in script.iter().enumerate() {
            let plen = 1 + (*val as usize % 6);
            let gen_len = 1 + rng.below(5) as usize;
            let prompt: Vec<i32> = (0..plen as i32).collect();
            e.submit(GenRequest::new(i as u64, prompt, gen_len));
            want.push(gen_len);
        }
        let out = e.run_to_completion().map_err(|e| e.to_string())?;
        if out.len() != script.len() {
            return Err(format!("{} of {} completed", out.len(), script.len()));
        }
        for (r, w) in out.iter().zip(&want) {
            if r.tokens.len() != *w {
                return Err(format!("req {} got {} tokens, want {w}", r.id, r.tokens.len()));
            }
        }
        Ok(())
    });
}

/// Same completion property over the pure-Rust executor: the engine loop
/// must not care which real backend is underneath.
#[test]
fn prop_engine_completes_all_native() {
    let gen = ScriptGen { max_len: 8, ops: 1, max_value: 6 };
    forall("engine_completion_native", 10, &gen, |script| {
        let mut e = Engine::new(
            native_exec(5),
            EngineConfig { max_slots: 3, eos: -1, ..Default::default() },
        );
        let mut rng = Pcg64::seed(script.len() as u64);
        let mut want = Vec::new();
        for (i, (_, val)) in script.iter().enumerate() {
            let plen = 1 + (*val as usize % 6);
            let gen_len = 1 + rng.below(5) as usize;
            let prompt: Vec<i32> = (0..plen as i32).collect();
            e.submit(GenRequest::new(i as u64, prompt, gen_len));
            want.push(gen_len);
        }
        let out = e.run_to_completion().map_err(|e| e.to_string())?;
        if out.len() != script.len() {
            return Err(format!("{} of {} completed", out.len(), script.len()));
        }
        for (r, w) in out.iter().zip(&want) {
            if r.tokens.len() != *w {
                return Err(format!("req {} got {} tokens, want {w}", r.id, r.tokens.len()));
            }
            for t in &r.tokens {
                if *t < 0 || *t >= 64 {
                    return Err(format!("req {} emitted out-of-vocab token {t}", r.id));
                }
            }
        }
        Ok(())
    });
}

/// Native-engine determinism: same workload -> same tokens (the interpreter
/// plus gather/scatter must be free of cross-lane state bleed too).
#[test]
fn prop_engine_deterministic_native() {
    let gen = UsizeGen(1, 6);
    forall("engine_deterministic_native", 6, &gen, |n| {
        let run = || {
            let mut e = Engine::new(
                native_exec(9),
                EngineConfig { max_slots: 4, eos: -1, ..Default::default() },
            );
            for i in 0..*n {
                e.submit(GenRequest::new(i as u64, vec![i as i32, 7], 5));
            }
            e.run_to_completion()
                .unwrap()
                .into_iter()
                .map(|r| r.tokens)
                .collect::<Vec<_>>()
        };
        if run() != run() {
            return Err("nondeterministic generation".into());
        }
        Ok(())
    });
}

/// Continuous-batching lifecycle conservation: under random interleavings
/// of submit / step / cancel against a bounded queue, every submitted
/// request yields exactly one result, and every completed (or partially
/// generated) token stream is the mock's arithmetic sequence for that
/// request — no request lost, duplicated, or fed another lane's tokens.
#[test]
fn prop_lifecycle_conservation_under_churn() {
    let gen = ScriptGen { max_len: 40, ops: 3, max_value: 30 };
    forall("lifecycle_conservation", 40, &gen, |script| {
        let mut e = Engine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 2, eos: -1, queue_depth: Some(2), ..Default::default() },
        );
        let mut next_id = 0u64;
        for (op, val) in script {
            match op % 3 {
                0 => {
                    let prompt = vec![next_id as i32];
                    e.try_submit(GenRequest::new(next_id, prompt, 1 + (*val as usize % 5)));
                    next_id += 1;
                }
                1 => {
                    if e.pending() > 0 {
                        e.step().map_err(|e| e.to_string())?;
                    }
                }
                _ => {
                    if next_id > 0 {
                        e.cancel(val % next_id);
                    }
                }
            }
        }
        let out = e.run_to_completion().map_err(|e| e.to_string())?;
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        let expect: Vec<u64> = (0..next_id).collect();
        if ids != expect {
            return Err(format!("conservation broken: got ids {ids:?}, want 0..{next_id}"));
        }
        for r in &out {
            // mock semantics: first token = sum(prompt) % 64, then +1 mod 64
            let s = (r.id % 64) as i32;
            for (k, t) in r.tokens.iter().enumerate() {
                if *t != (s + k as i32) % 64 {
                    return Err(format!(
                        "req {}: token {k} is {t}, want {} — cross-lane bleed or reorder",
                        r.id,
                        (s + k as i32) % 64
                    ));
                }
            }
            if r.outcome.is_complete() && r.tokens.is_empty() {
                return Err(format!("req {} complete with no tokens", r.id));
            }
            if r.outcome == FinishReason::RejectedQueueFull && !r.tokens.is_empty() {
                return Err(format!("req {} rejected but has tokens", r.id));
            }
        }
        Ok(())
    });
}

/// Page recycling never leaks stale rows: prefills poison every written
/// row with 1e9; after frees recycle those pages into new (shorter)
/// sequences, a gather must materialize exactly the live rows and zeros
/// beyond `pos` — recycled page contents may never bleed through.
#[test]
fn prop_kv_refill_never_leaks_stale_rows() {
    let gen = ScriptGen { max_len: 60, ops: 2, max_value: 16 };
    forall("kv_stale_rows", 50, &gen, |script| {
        let cap = 4;
        let (layers, seq_max, row) = (2usize, 8usize, 4usize);
        let mut kv =
            KvCache::with_spec(cap, layers, seq_max, row, KvSpec { format: KvFormat::F32, block: 3 });
        let plane = seq_max * row;
        let mut step = 0i32;
        for (op, val) in script {
            let id = *val;
            match op % 2 {
                0 => {
                    if let Ok(alloc) = kv.alloc(id) {
                        if kv.pos_of(id) != Some(0) || !kv.pages_of(id).unwrap().is_empty() {
                            return Err(format!(
                                "slot {} (refill={}) not fresh",
                                alloc.slot, alloc.refill
                            ));
                        }
                        step += 1;
                        let plen = 1 + (*val as usize % seq_max);
                        // unique tokens per prefill: no cross-sequence sharing
                        let prompt: Vec<i32> =
                            (0..plen as i32).map(|t| step * 100 + t).collect();
                        let planes: Vec<Vec<f32>> =
                            (0..layers * 2).map(|_| vec![1e9f32; plane]).collect();
                        kv.write_prefill(id, &prompt, &planes, 0).unwrap();
                        let g = kv.gather_batch(&[id], 1).map_err(|e| e.to_string())?;
                        for (li, buf) in g.iter().enumerate() {
                            if buf[..plen * row].iter().any(|x| *x != 1e9) {
                                return Err(format!("plane {li}: live rows corrupted"));
                            }
                            if buf[plen * row..].iter().any(|x| *x != 0.0) {
                                return Err(format!(
                                    "plane {li}: stale rows beyond pos {plen} (refill={})",
                                    alloc.refill
                                ));
                            }
                        }
                    }
                }
                _ => {
                    kv.free(id);
                }
            }
        }
        Ok(())
    });
}

/// Page-pool accounting under churn: after every operation, each mapped
/// page's refcount equals the number of block-table references to it, and
/// free + distinct-mapped pages account for the whole arena — no leak, no
/// double-map, no page both free and mapped.
#[test]
fn prop_kv_page_pool_accounting_under_churn() {
    let gen = ScriptGen { max_len: 60, ops: 3, max_value: 12 };
    forall("kv_page_pool", 40, &gen, |script| {
        let cap = 4;
        let (layers, seq_max, row) = (2usize, 8usize, 4usize);
        let mut kv =
            KvCache::with_spec(cap, layers, seq_max, row, KvSpec { format: KvFormat::F32, block: 4 });
        let plane = seq_max * row;
        for (op, val) in script {
            let id = *val;
            match op % 3 {
                0 => {
                    if kv.alloc(id).is_ok() {
                        // shared 4-token lead-in so page sharing and COW
                        // both happen under the script
                        let plen = 4 + (*val as usize % 5);
                        let mut prompt = vec![1i32, 2, 3, 4];
                        while prompt.len() < plen {
                            prompt.push(100 + id as i32);
                        }
                        let planes: Vec<Vec<f32>> =
                            (0..layers * 2).map(|_| vec![id as f32 + 0.5; plane]).collect();
                        kv.write_prefill(id, &prompt, &planes, 0).unwrap();
                    }
                }
                1 => {
                    if kv.contains(id) && kv.pos_of(id).unwrap() < seq_max {
                        let rows: Vec<Vec<f32>> =
                            (0..layers * 2).map(|_| vec![id as f32; row]).collect();
                        kv.append_step(&[id], 1, &rows).unwrap();
                    }
                }
                _ => {
                    kv.free(id);
                }
            }
            let mut refs: std::collections::HashMap<usize, u32> = Default::default();
            for sid in kv.ids() {
                for p in kv.pages_of(sid).unwrap() {
                    *refs.entry(p).or_insert(0) += 1;
                }
            }
            for (&p, &n) in &refs {
                if kv.page_refcount(p) != n {
                    return Err(format!(
                        "page {p}: refcount {} but {n} table refs",
                        kv.page_refcount(p)
                    ));
                }
            }
            if kv.free_pages() + refs.len() != kv.total_pages() {
                return Err(format!(
                    "arena accounting broken: {} free + {} mapped != {} total",
                    kv.free_pages(),
                    refs.len(),
                    kv.total_pages()
                ));
            }
        }
        Ok(())
    });
}

/// Copy-on-write diverges exactly once: two sequences sharing a ragged
/// prefix page split on the sharer's first append and never re-clone on
/// later appends; the passive sharer's gathered rows stay bit-identical
/// throughout.
#[test]
fn prop_kv_cow_diverges_only_on_first_write() {
    let gen = UsizeGen(1, 5);
    forall("kv_cow_first_write", 10, &gen, |n_appends| {
        let (layers, seq_max, row) = (2usize, 8usize, 4usize);
        let mut kv =
            KvCache::with_spec(2, layers, seq_max, row, KvSpec { format: KvFormat::F32, block: 4 });
        let prompt = vec![7i32, 8, 9]; // ragged: 3 tokens on a 4-token page
        let plane = seq_max * row;
        let planes: Vec<Vec<f32>> = (0..layers * 2)
            .map(|li| {
                let mut p = vec![0.0f32; plane];
                for (j, v) in p.iter_mut().enumerate().take(3 * row) {
                    *v = li as f32 + j as f32 * 0.25;
                }
                p
            })
            .collect();
        for id in [1u64, 2] {
            kv.alloc(id).unwrap();
            kv.write_prefill(id, &prompt, &planes, 0).unwrap();
        }
        let (pa, pb) = (kv.pages_of(1).unwrap(), kv.pages_of(2).unwrap());
        if pa != pb {
            return Err("identical prompts must share their page".into());
        }
        if kv.page_refcount(pa[0]) != 2 {
            return Err(format!("shared page refcount {} != 2", kv.page_refcount(pa[0])));
        }
        let passive_before = kv.gather_batch(&[2], 1).map_err(|e| e.to_string())?;
        let mut first_owned: Option<usize> = None;
        for k in 0..*n_appends {
            let rows: Vec<Vec<f32>> =
                (0..layers * 2).map(|_| vec![-1.0 - k as f32; row]).collect();
            kv.append_step(&[1], 1, &rows).map_err(|e| e.to_string())?;
            let head = kv.pages_of(1).unwrap()[0];
            match first_owned {
                None => {
                    if head == pb[0] {
                        return Err("first append into shared page did not clone".into());
                    }
                    first_owned = Some(head);
                }
                Some(h) => {
                    if head != h {
                        return Err(format!("append {k} re-cloned: page {h} -> {head}"));
                    }
                }
            }
            if kv.page_refcount(pb[0]) != 1 || kv.page_refcount(head) != 1 {
                return Err("post-COW refcounts must both be 1".into());
            }
        }
        let passive_after = kv.gather_batch(&[2], 1).map_err(|e| e.to_string())?;
        if passive_before != passive_after {
            return Err("sharer's appends perturbed the passive sequence".into());
        }
        Ok(())
    });
}

/// Quantize-on-write round-trip: MX-paged gathers are bit-identical to
/// `mx_qdq` over the same rows (itself pinned to `mx/reference.rs` by the
/// codec tests) — including page-boundary rows and ragged final pages.
#[test]
fn prop_kv_quantized_pages_match_reference_qdq() {
    let gen = UsizeGen(1, 8);
    for (fmt, kvf) in [("mxfp8", KvFormat::Mxfp8), ("mxfp4", KvFormat::Mxfp4)] {
        let cfg_name = fmt;
        forall(&format!("kv_page_qdq_{fmt}"), 8, &gen, move |plen| {
            let (layers, seq_max, row) = (2usize, 8usize, 4usize);
            let spec = KvSpec { format: kvf, block: 3 }; // ragged vs plen 1..=8
            let mut kv = KvCache::with_spec(1, layers, seq_max, row, spec);
            let cfg = spec.mx_config(row).expect("quantized spec has an MX config");
            if cfg.name != cfg_name {
                return Err(format!("spec resolved {} not {cfg_name}", cfg.name));
            }
            let plane = seq_max * row;
            let mut rng = Pcg64::seed(41 + *plen as u64);
            let planes: Vec<Vec<f32>> = (0..layers * 2)
                .map(|_| {
                    let mut p = vec![0.0f32; plane];
                    let fill = rng.normal_vec(*plen * row, 1.5);
                    p[..*plen * row].copy_from_slice(&fill);
                    p
                })
                .collect();
            kv.alloc(9).unwrap();
            let prompt: Vec<i32> = (0..*plen as i32).collect();
            kv.write_prefill(9, &prompt, &planes, 0).unwrap();
            let g = kv.gather_batch(&[9], 1).map_err(|e| e.to_string())?;
            for (li, buf) in g.iter().enumerate() {
                let want = mx_qdq(&planes[li][..*plen * row], cfg.block_size, &cfg);
                for (j, (a, b)) in buf[..*plen * row].iter().zip(&want).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "plane {li} elem {j}: paged {a} != qdq {b} (plen {plen})"
                        ));
                    }
                }
                if buf[*plen * row..].iter().any(|x| *x != 0.0) {
                    return Err(format!("plane {li}: nonzero beyond pos"));
                }
            }
            Ok(())
        });
    }
}

/// Deadline-expired requests are evicted with `TimedOut`; requests without
/// a deadline complete normally alongside them.
#[test]
fn prop_deadline_expiry_evicts_timed_out() {
    let gen = ScriptGen { max_len: 10, ops: 2, max_value: 5 };
    forall("deadline_timeout", 25, &gen, |script| {
        let mut e = Engine::new(
            MockExecutor::default(),
            EngineConfig { max_slots: 3, eos: -1, ..Default::default() },
        );
        let mut doomed = Vec::new();
        for (i, (op, val)) in script.iter().enumerate() {
            let want = 1 + (*val as usize % 4);
            let req = GenRequest::new(i as u64, vec![i as i32], want);
            if op % 2 == 0 {
                e.submit(req.with_deadline(Duration::ZERO));
                doomed.push(i as u64);
            } else {
                e.submit(req);
            }
        }
        std::thread::sleep(Duration::from_millis(1));
        let out = e.run_to_completion().map_err(|e| e.to_string())?;
        if out.len() != script.len() {
            return Err(format!("{} of {} results", out.len(), script.len()));
        }
        for r in &out {
            let is_doomed = doomed.contains(&r.id);
            match (is_doomed, r.outcome) {
                (true, FinishReason::TimedOut) => {}
                (false, o) if o.is_complete() => {}
                (d, o) => return Err(format!("req {} doomed={d} but outcome {o:?}", r.id)),
            }
        }
        Ok(())
    });
}

/// Mock-engine determinism: same workload -> same tokens (no state bleed
/// between lanes in gather/scatter).
#[test]
fn prop_engine_deterministic() {
    let gen = UsizeGen(1, 8);
    forall("engine_deterministic", 15, &gen, |n| {
        let run = || {
            let mut e = Engine::new(
                MockExecutor::default(),
                EngineConfig { max_slots: 4, eos: -1, ..Default::default() },
            );
            for i in 0..*n {
                e.submit(GenRequest::new(i as u64, vec![i as i32, 7], 5));
            }
            e.run_to_completion()
                .unwrap()
                .into_iter()
                .map(|r| r.tokens)
                .collect::<Vec<_>>()
        };
        if run() != run() {
            return Err("nondeterministic generation".into());
        }
        Ok(())
    });
}
