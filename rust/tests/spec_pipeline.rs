//! End-to-end gates for the per-site TransformSpec pipeline
//! (Sec. 3.2 / App. B+C):
//!
//! - `.lxt` round-trip property: save -> load -> fold -> the folded
//!   weights' logits match the unfolded spec-applying interpreter to
//!   <= 1e-5 on a synthetic 2-layer model — strict on the fp graph spec,
//!   majority-voted over token sets on quantized specs (an isolated FP4
//!   bin flip between the two f32 paths is not an algebra bug; see the
//!   in-test comments);
//! - the learn -> fold -> serve parity gate: `learn_spec` (T1 + per-head
//!   T2 + FfnDown on a synthetic model with planted value-channel
//!   outliers) -> `fold_into` -> a version-2 artifact directory ->
//!   `NativeExecutor::new` serving, with prefill/decode logits matching
//!   the unfolded reference executor to <= 1e-4 and identical greedy
//!   engine tokens, both majority-voted over prompt sets;
//! - per-head learned E(T) strictly beating the identity and
//!   random-Hadamard baselines on the outlier features (margins validated
//!   against a numpy/jax mirror of the exact capture + learning
//!   semantics: learned/hadamard <= 0.51, learned/identity <= 0.20
//!   across seeds — asserted conservatively below).

use latmix::coordinator::engine::{NativeExecutor, StepExecutor};
use latmix::coordinator::{Engine, EngineConfig, GenRequest};
use latmix::io::MANIFEST_VERSION;
use latmix::latmix::{learn_spec, LearnConfig};
use latmix::linalg::random_orthogonal;
use latmix::model::{GraphSpec, ModelDesc, NativeDims, NativeWeights, WeightSet};
use latmix::transform::{Affine, TransformMode, TransformSite, TransformSpec};
use latmix::util::Pcg64;

fn dims2() -> NativeDims {
    NativeDims {
        vocab: 64,
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        d_ff: 128,
        kv_seq: 24,
        prefill_len: 8,
    }
}

fn rand_affine(d: usize, rng: &mut Pcg64, noise: f32, bias: f32) -> Affine {
    let mut a = random_orthogonal(d, rng);
    for e in a.data.iter_mut() {
        *e += noise * rng.normal();
    }
    Affine::new(a, rng.normal_vec(d, bias)).unwrap()
}

fn random_spec(dims: &NativeDims, seed: u64) -> TransformSpec {
    let mut rng = Pcg64::seed(seed);
    let dh = dims.head_dim();
    let mut spec = TransformSpec::new();
    spec.insert(TransformSite::Residual, rand_affine(dims.d_model, &mut rng, 0.05, 0.1));
    spec.insert(
        TransformSite::PerHeadValue { layer: 0, head: 0 },
        rand_affine(dh, &mut rng, 0.05, 0.1),
    );
    spec.insert(
        TransformSite::PerHeadValue { layer: 1, head: 1 },
        rand_affine(dh, &mut rng, 0.05, 0.1),
    );
    spec.insert(TransformSite::FfnDown { layer: 0 }, rand_affine(dims.d_ff, &mut rng, 0.02, 0.05));
    spec
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

/// Satellite property: save -> load -> fold -> logits parity <= 1e-5.
#[test]
fn spec_roundtrip_fold_matches_unfolded_forward() {
    let dims = dims2();
    let w = NativeWeights::synthetic(dims, 7);
    let spec = random_spec(&dims, 11);

    // `.lxt` round-trip first: the folded model must be built from the
    // *deserialized* spec, so serialization is in the proof chain.
    let dir = std::env::temp_dir().join("latmix_spec_roundtrip_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("spec.lxt");
    spec.save(&path).unwrap();
    let loaded = TransformSpec::load(&path).unwrap();
    assert_eq!(loaded.len(), spec.len());
    for (site, t) in spec.iter() {
        let lt = loaded.get(site).expect("site lost in .lxt round-trip");
        assert_eq!(lt.a, t.a, "site {site}: A changed in round-trip");
        assert_eq!(lt.v, t.v, "site {site}: v changed in round-trip");
    }

    let (folded, online) = loaded.fold_into(&w).unwrap();
    assert_eq!(online.len(), 1, "exactly the FfnDown forward stays online");
    let (batch, t) = (2usize, 8usize);
    let toks = |seed: u64| -> Vec<i32> {
        let mut rng = Pcg64::seed(seed);
        (0..batch * t).map(|_| rng.below(dims.vocab as u64) as i32).collect()
    };
    // fp: no quantizer in the path, so the fold algebra must agree to pure
    // f32 association error on every input — strict gate.
    let g = GraphSpec::fp();
    let tokens = toks(13);
    let reference = w
        .forward_seq_spec(&tokens, batch, t, &g, Some((&loaded, TransformMode::Unfolded)))
        .unwrap();
    let deployed = folded
        .forward_seq_spec(&tokens, batch, t, &g, Some((&online, TransformMode::Folded)))
        .unwrap();
    let diff = max_abs_diff(&reference, &deployed);
    assert!(diff <= 1e-5, "fp: folded logits diverge from unfolded by {diff}");
    // Quantized tags: the two paths feed f32-association-different values
    // into the MX quantizer, and an activation landing within ~1e-6
    // relative of an FP4 rounding boundary can flip a bin in one path
    // only (~5e-6 probability per activation, measured in the numpy
    // mirror), which then perturbs downstream logits by O(0.1). A real
    // fold-algebra bug is systematic and fails every input; a bin flip is
    // isolated — so vote over token sets and require a strict majority.
    for tag in ["mxfp4_b32", "mxfp4_b32_t3"] {
        let g = GraphSpec::from_tag(tag).unwrap();
        let mut strict = 0;
        for seed in [13u64, 14, 15] {
            let tokens = toks(seed);
            let reference = w
                .forward_seq_spec(&tokens, batch, t, &g, Some((&loaded, TransformMode::Unfolded)))
                .unwrap();
            let deployed = folded
                .forward_seq_spec(&tokens, batch, t, &g, Some((&online, TransformMode::Folded)))
                .unwrap();
            if max_abs_diff(&reference, &deployed) <= 1e-5 {
                strict += 1;
            }
        }
        assert!(
            strict >= 2,
            "{tag}: folded/unfolded parity failed on {} of 3 token sets",
            3 - strict
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance gate: learn_spec -> fold -> artifact dir ->
/// NativeExecutor serving, with per-head learned E(T) beating both fixed
/// baselines and folded/unfolded logits parity <= 1e-4 end to end.
#[test]
fn learn_fold_serve_end_to_end() {
    let dims = dims2();
    let dh = dims.head_dim();
    let mut w = NativeWeights::synthetic(dims, 11);
    // plant massive value-channel outliers in both heads of layer 1 (the
    // Sec. 3.1 pattern): one transformed channel per head dominates its
    // MX block and flushes the small elements
    for r in 0..dims.d_model {
        w.layers[1].wv[(r, 5)] *= 30.0;
        w.layers[1].wv[(r, 37)] *= 25.0;
    }
    w.layers[1].bv[5] = 15.0;
    w.layers[1].bv[37] = -10.0;

    let mut rng = Pcg64::seed(18);
    let (batch, t) = (4usize, 8usize);
    let tokens: Vec<i32> = (0..batch * t).map(|_| rng.below(dims.vocab as u64) as i32).collect();
    let cfg = latmix::mx::MxConfig::from_name("mxfp4", Some(32)).unwrap();
    let lc = LearnConfig { steps: 100, trace_every: 0, ..Default::default() };

    // T1 + both per-head T2 on fp captures
    let sites = [
        TransformSite::Residual,
        TransformSite::PerHeadValue { layer: 1, head: 0 },
        TransformSite::PerHeadValue { layer: 1, head: 1 },
    ];
    let (mut spec, reports) =
        learn_spec(&w, &sites, &tokens, batch, t, 1, &GraphSpec::fp(), &cfg, &lc).unwrap();
    for r in &reports[1..] {
        let e_h = r.e_hadamard.expect("head_dim is a power of two");
        assert!(
            r.e_learned < 0.75 * e_h,
            "site {}: learned {} must beat random Hadamard {} by >25%",
            r.site,
            r.e_learned,
            e_h
        );
        assert!(
            r.e_learned < 0.5 * r.e_identity,
            "site {}: learned {} must beat identity {} by >2x",
            r.site,
            r.e_learned,
            r.e_identity
        );
    }

    // FfnDown on post-T3 captures (the deployment tag carries _t3), merged
    // into the same spec
    let t3_capture = GraphSpec { act: None, t3: Some(GraphSpec::T3_BLOCK) };
    let (ffn_spec, ffn_reports) = learn_spec(
        &w,
        &[TransformSite::FfnDown { layer: 0 }],
        &tokens,
        batch,
        t,
        1,
        &t3_capture,
        &cfg,
        &lc,
    )
    .unwrap();
    assert!(ffn_reports[0].e_learned.is_finite());
    for (site, tf) in ffn_spec.iter() {
        spec.insert(*site, tf.clone());
    }
    assert_eq!(spec.len(), 4);

    // fold and write a version-2 artifact directory
    let (folded, online) = spec.fold_into(&w).unwrap();
    assert_eq!(online.len(), 1);
    let tag = "latmix_folded";
    let qtag = "mxfp4_b32_t3";
    let dir = std::env::temp_dir().join("latmix_spec_e2e_test");
    std::fs::create_dir_all(dir.join("weights")).unwrap();
    std::fs::create_dir_all(dir.join("transforms")).unwrap();
    let (order, fws) = folded.to_weight_set(tag);
    fws.save(&dir.join("weights").join(format!("{tag}.lxt")), &order).unwrap();
    online.save(&dir.join("transforms").join("online.lxt")).unwrap();
    let desc = ModelDesc {
        vocab: dims.vocab,
        d_model: dims.d_model,
        n_layers: dims.n_layers,
        n_heads: dims.n_heads,
        d_ff: dims.d_ff,
        kv_seq: dims.kv_seq,
        prefill_len: dims.prefill_len,
        ppl_shape: (4, 16),
        score_shape: (4, 16),
        weight_order: order,
        graphs: vec![
            format!("prefill_{qtag}_b4"),
            format!("decode_{qtag}_b1"),
            format!("decode_{qtag}_b2"),
            format!("decode_{qtag}_b4"),
            format!("logits_ppl_{qtag}"),
        ],
        artifacts: dir.clone(),
        version: MANIFEST_VERSION,
        transform_folded: Some(spec.site_list()),
        transform_online: Some("transforms/online.lxt".to_string()),
        shard_attn: None,
        shard_ffn_block: None,
    };
    desc.write_manifest(&dir).unwrap();

    // reload through the real artifact path: manifest -> weight set ->
    // executor (which must pick up the online remainder on its own)
    let loaded = ModelDesc::load(&dir).unwrap();
    assert_eq!(loaded.version, MANIFEST_VERSION);
    assert_eq!(loaded.transform_folded.as_deref(), Some(spec.site_list().as_str()));
    let ws = WeightSet::load(&loaded, tag).unwrap();
    let served = NativeExecutor::new(&loaded, qtag, &ws).unwrap();
    let reference = NativeExecutor::from_weights_with_spec(
        w.clone(),
        spec.clone(),
        TransformMode::Unfolded,
        qtag,
        vec![1, 2, 4],
    )
    .unwrap();

    // serving-surface parity: prefill + chained decode logits <= 1e-4.
    // Voted over prompt sets for the same reason as the round-trip test:
    // an isolated FP4 bin flip between the two f32 paths (~5e-6 per
    // activation, measured) is not an algebra bug; a real fold bug fails
    // every prompt set.
    let pl = dims.prefill_len;
    let vocab = dims.vocab;
    let mut strict = 0;
    for seed in [91u64, 92, 93] {
        let mut rng = Pcg64::seed(seed);
        let mut ptoks = vec![0i32; 2 * pl];
        for x in ptoks[..5].iter_mut().chain(ptoks[pl..pl + 3].iter_mut()) {
            *x = rng.below(vocab as u64) as i32;
        }
        let lens = [5i32, 3];
        let (lg_s, mut kv_s) = served.prefill(&ptoks, &lens, 2).unwrap();
        let (lg_r, mut kv_r) = reference.prefill(&ptoks, &lens, 2).unwrap();
        let mut worst = max_abs_diff(&lg_s, &lg_r);
        let mut next = [argmax(&lg_s[..vocab]), argmax(&lg_s[vocab..])];
        let mut pos = [5i32, 3];
        for _ in 0..3 {
            let (ls, ks) = served.decode(&next, &pos, &kv_s, 2).unwrap();
            let (lr, kr) = reference.decode(&next, &pos, &kv_r, 2).unwrap();
            worst = worst.max(max_abs_diff(&ls, &lr));
            kv_s = ks;
            kv_r = kr;
            next = [argmax(&ls[..vocab]), argmax(&ls[vocab..])];
            pos[0] += 1;
            pos[1] += 1;
        }
        if worst <= 1e-4 {
            strict += 1;
        }
    }
    assert!(strict >= 2, "serving parity failed on {} of 3 prompt sets", 3 - strict);

    // full continuous-batching engine on both executors: identical greedy
    // tokens end to end, voted over workloads (one bin flip rewrites a
    // lane's whole continuation, so equality is per-workload)
    let run = |exec: &NativeExecutor, seed: u64| {
        let mut e = Engine::new(
            exec.clone(),
            EngineConfig { max_slots: 4, eos: -1, ..Default::default() },
        );
        let mut rng = Pcg64::seed(seed);
        for i in 0..3u64 {
            let prompt: Vec<i32> = (0..3).map(|_| rng.below(vocab as u64) as i32).collect();
            e.submit(GenRequest::new(i, prompt, 4));
        }
        e.run_to_completion().unwrap()
    };
    let mut equal_workloads = 0;
    for seed in [5u64, 6, 7] {
        let out_s = run(&served, seed);
        let out_r = run(&reference, seed);
        assert_eq!(out_s.len(), out_r.len());
        if out_s.iter().zip(&out_r).all(|(a, b)| a.id == b.id && a.tokens == b.tokens) {
            equal_workloads += 1;
        }
    }
    assert!(
        equal_workloads >= 2,
        "served tokens diverged from the unfolded reference on {} of 3 workloads",
        3 - equal_workloads
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A folded artifact set that declares an online remainder must refuse to
/// serve without it (guards against silently dropping FfnDown transforms).
#[test]
fn folded_manifest_without_online_spec_fails_loud() {
    let dims = dims2();
    let w = NativeWeights::synthetic(dims, 3);
    let tag = "t";
    let dir = std::env::temp_dir().join("latmix_spec_missing_online_test");
    std::fs::create_dir_all(dir.join("weights")).unwrap();
    let (order, ws) = w.to_weight_set(tag);
    ws.save(&dir.join("weights").join(format!("{tag}.lxt")), &order).unwrap();
    let desc = ModelDesc {
        vocab: dims.vocab,
        d_model: dims.d_model,
        n_layers: dims.n_layers,
        n_heads: dims.n_heads,
        d_ff: dims.d_ff,
        kv_seq: dims.kv_seq,
        prefill_len: dims.prefill_len,
        ppl_shape: (4, 16),
        score_shape: (4, 16),
        weight_order: order,
        graphs: vec!["decode_fp_b1".to_string()],
        artifacts: dir.clone(),
        version: MANIFEST_VERSION,
        transform_folded: None,
        // declared but never written to disk
        transform_online: Some("transforms/online.lxt".to_string()),
        shard_attn: None,
        shard_ffn_block: None,
    };
    desc.write_manifest(&dir).unwrap();
    let loaded = ModelDesc::load(&dir).unwrap();
    let ws = WeightSet::load(&loaded, tag).unwrap();
    assert!(NativeExecutor::new(&loaded, "fp", &ws).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

fn argmax(v: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, x) in v.iter().enumerate() {
        if *x > bv {
            bv = *x;
            best = i;
        }
    }
    best as i32
}
