//! Property tests for the Sec. 3.2 transform-learning loop
//! (`latmix::latmix`):
//!
//! - the hand-derived reverse-mode gradients match central finite
//!   differences of the frozen STE surrogate;
//! - learned transforms stay invertible and well-conditioned;
//! - learned `E(T)` strictly beats the identity *and* random-Hadamard
//!   baselines on synthetic outlier data (the Fig. 2 claim);
//! - the Theorem 3.3 bound tracks the empirical ordering;
//! - the Sec. 3.1 Dirac-delta regression: learned beats identity by >=10x.

use latmix::latmix::{
    dirac_features, et_loss_and_grads, learn_feature_transform, outlier_features,
    randomized_hadamard, InitStrategy, LearnConfig,
};
use latmix::linalg::Mat;
use latmix::mx::quantize::{block_clip_threshold, nv_tensor_scale};
use latmix::mx::{mx_qdq, MxConfig};
use latmix::transform::bound::theorem_bound;
use latmix::transform::{transformation_mse, Affine};
use latmix::util::Pcg64;

fn test_lc(steps: usize) -> LearnConfig {
    LearnConfig { steps, trace_every: 0, ..Default::default() }
}

/// The frozen STE surrogate: the loss whose *analytic* gradient at
/// `(a0, v0)` is what `et_loss_and_grads` computes. Quantizer outputs,
/// clipping knees, and masks are constants taken at the base point; only
/// the differentiable paths (`Y`, `A^{-1}`, `v`, `log|det A|`) move.
fn frozen_surrogate(
    x: &[f32],
    d: usize,
    a: &Mat,
    v: &[f32],
    base_a: &Mat,
    base_v: &[f32],
    cfg: &MxConfig,
    lam: f64,
    ow: f64,
) -> f64 {
    let n = x.len() / d;
    let xm = Mat::from_vec(n, d, x.to_vec());
    let row_add = |m: &Mat, bias: &[f32], sign: f32| -> Mat {
        let mut out = m.clone();
        for row in out.data.chunks_mut(d) {
            for (o, b) in row.iter_mut().zip(bias) {
                *o += sign * b;
            }
        }
        out
    };
    let y0 = row_add(&xm.matmul(base_a), base_v, 1.0);
    let nv_ts = if cfg.nv { nv_tensor_scale(&y0.data) } else { 1.0 };
    let bs = cfg.block_size;
    let thr: Vec<f32> = y0
        .data
        .chunks(bs)
        .map(|blk| {
            let amax = blk.iter().fold(0.0f32, |m, t| m.max(t.abs()));
            block_clip_threshold(amax, cfg, nv_ts)
        })
        .collect();
    let q0 = mx_qdq(&y0.data, d, cfg);
    let y = row_add(&xm.matmul(a), v, 1.0);
    // q_ste: clipped -> frozen q0; else y + (q0 - y0)
    let mut q_ste = Mat::zeros(n, d);
    for i in 0..y.data.len() {
        q_ste.data[i] = if y0.data[i].abs() > thr[i / bs] {
            q0[i]
        } else {
            y.data[i] + (q0[i] - y0.data[i])
        };
    }
    let b = a.inverse().unwrap();
    let back = row_add(&q_ste, v, -1.0).matmul(&b);
    let mut mse = 0.0f64;
    for (bi, xi) in back.data.iter().zip(&xm.data) {
        let r = (*bi - *xi) as f64;
        mse += r * r;
    }
    mse /= (n * d) as f64;
    let mut overflow = 0.0f64;
    for (yi, i) in y.data.iter().zip(0..) {
        let over = (yi.abs() - thr[i / bs]) as f64;
        if over > 0.0 {
            overflow += over * over;
        }
    }
    overflow /= (n * d) as f64;
    let (lu, _, _) = a.lu().unwrap();
    let mut logdet = 0.0f64;
    for i in 0..d {
        logdet += (lu[(i, i)].abs() as f64).ln();
    }
    mse + ow * overflow + lam * logdet * logdet
}

#[test]
fn hand_gradients_match_finite_differences() {
    let (d, n) = (8usize, 12usize);
    let mut rng = Pcg64::seed(40);
    let mut x = rng.normal_vec(n * d, 1.0);
    for r in 0..n {
        x[r * d + 2] += 8.0; // ensure both clipped and unclipped elements
    }
    let mut a = Mat::eye(d);
    for e in a.data.iter_mut() {
        *e += 0.05 * rng.normal();
    }
    let v = rng.normal_vec(d, 0.1);
    let (lam, ow) = (0.1f32, 0.1f32);
    let cfg = MxConfig::from_name("mxfp4", Some(4)).unwrap();
    let g = et_loss_and_grads(&x, d, &a, &v, &cfg, lam, ow).unwrap();
    // central differences on the frozen surrogate; f32 storage limits
    // accuracy, so compare with a mixed absolute/relative tolerance
    let eps = 2e-3f32;
    let mut checked = 0;
    for (i, j) in [(0, 0), (2, 2), (1, 5), (6, 3), (7, 7), (3, 0)] {
        let mut ap = a.clone();
        let mut am = a.clone();
        ap[(i, j)] += eps;
        am[(i, j)] -= eps;
        let fp = frozen_surrogate(&x, d, &ap, &v, &a, &v, &cfg, lam as f64, ow as f64);
        let fm = frozen_surrogate(&x, d, &am, &v, &a, &v, &cfg, lam as f64, ow as f64);
        let fd = (fp - fm) / (2.0 * eps as f64);
        let got = g.grad_a[(i, j)] as f64;
        assert!(
            (fd - got).abs() < 1e-3 + 0.02 * fd.abs(),
            "dL/dA[{i}][{j}]: fd {fd} vs analytic {got}"
        );
        checked += 1;
    }
    for k in [0usize, 3, 7] {
        let mut vp = v.clone();
        let mut vm = v.clone();
        vp[k] += eps;
        vm[k] -= eps;
        let fp = frozen_surrogate(&x, d, &a, &vp, &a, &v, &cfg, lam as f64, ow as f64);
        let fm = frozen_surrogate(&x, d, &a, &vm, &a, &v, &cfg, lam as f64, ow as f64);
        let fd = (fp - fm) / (2.0 * eps as f64);
        let got = g.grad_v[k] as f64;
        assert!(
            (fd - got).abs() < 1e-3 + 0.02 * fd.abs(),
            "dL/dv[{k}]: fd {fd} vs analytic {got}"
        );
        checked += 1;
    }
    assert_eq!(checked, 9);
}

#[test]
fn learned_transform_is_invertible() {
    let d = 64;
    let feats = outlier_features(48, d, 0.05, 7);
    let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    let lt = learn_feature_transform(&feats, d, &cfg, &test_lc(60)).unwrap();
    assert_eq!(lt.steps_run, 60);
    let t = lt.into_affine().unwrap(); // from_learned gates on conditioning
    // A A^{-1} == I within float tolerance
    let prod = t.a.matmul(t.inverse_matrix());
    assert!(prod.sub(&Mat::eye(d)).max_abs() < 1e-2, "{}", prod.sub(&Mat::eye(d)).max_abs());
    // round-trip on fresh data
    let mut rng = Pcg64::seed(50);
    let x = rng.normal_vec(d * 4, 1.0);
    let back = t.backward_rows(&t.forward_rows(&x));
    for (p, q) in x.iter().zip(&back) {
        assert!((p - q).abs() < 1e-2, "{p} vs {q}");
    }
}

#[test]
fn learned_beats_identity_and_random_hadamard() {
    // The Fig. 2 ordering: E(learned) < E(random Hadamard) < E(identity)
    // on outlier-channel data. The numpy mirror of this loop shows ~50-65%
    // margins over the Hadamard baseline across seeds; assert a
    // conservative 10%.
    let d = 64;
    let feats = outlier_features(48, d, 0.05, 7);
    let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    let e_id = transformation_mse(&feats, d, &Affine::identity(d), &cfg);
    let mut hrng = Pcg64::seed(107);
    let h = Affine::new(randomized_hadamard(d, &mut hrng), vec![0.0; d]).unwrap();
    let e_h = transformation_mse(&feats, d, &h, &cfg);
    assert!(e_h < e_id, "hadamard baseline should already help: {e_h} vs {e_id}");

    let lt = learn_feature_transform(&feats, d, &cfg, &test_lc(100)).unwrap();
    let learned = lt.into_affine().unwrap();
    let e_l = transformation_mse(&feats, d, &learned, &cfg);
    assert!(
        e_l < 0.9 * e_h,
        "learned must strictly beat random Hadamard: {e_l} vs {e_h} (identity {e_id})"
    );
}

#[test]
fn learned_tracks_theorem_bound() {
    // Theorem 3.3: E(T) <= C * ||A^{-1}||^2 * mean block-max moment. The
    // bound and the empirical error must order the transforms the same
    // way — the paper's design argument for minimizing the bound's
    // factors.
    let d = 64;
    let feats = outlier_features(48, d, 0.05, 21);
    let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    let id = Affine::identity(d);
    let lt = learn_feature_transform(&feats, d, &cfg, &test_lc(100)).unwrap();
    let learned = lt.into_affine().unwrap();
    let e_id = transformation_mse(&feats, d, &id, &cfg);
    let e_l = transformation_mse(&feats, d, &learned, &cfg);
    let b_id = theorem_bound(&feats, d, &id, cfg.block_size);
    let b_l = theorem_bound(&feats, d, &learned, cfg.block_size);
    assert!(e_l < e_id, "learned must reduce E(T): {e_l} vs {e_id}");
    assert!(b_l < b_id, "bound must track the improvement: {b_l} vs {b_id}");
}

#[test]
fn dirac_delta_regression_10x() {
    // Sec. 3.1 worked example: a single spike channel forces the whole
    // block's scale up and flushes the small elements to zero under
    // identity. `latmix learn` must recover a transform beating identity
    // E(T) by at least 10x (the numpy mirror shows ~40x).
    let d = 32;
    let feats = dirac_features(48, d, 5);
    let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    let e_id = transformation_mse(&feats, d, &Affine::identity(d), &cfg);
    let lt = learn_feature_transform(&feats, d, &cfg, &test_lc(100)).unwrap();
    let learned = lt.into_affine().unwrap();
    let e_l = transformation_mse(&feats, d, &learned, &cfg);
    assert!(
        e_l * 10.0 <= e_id,
        "Dirac regression: learned {e_l} vs identity {e_id} ({:.1}x, want >= 10x)",
        e_id / e_l.max(1e-12)
    );
}

#[test]
fn trace_records_learning_curve() {
    let d = 32;
    let feats = dirac_features(24, d, 9);
    let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    let lc = LearnConfig { steps: 40, trace_every: 10, ..Default::default() };
    let lt = learn_feature_transform(&feats, d, &cfg, &lc).unwrap();
    // rows at steps 0, 10, 20, 30 and the final step 39
    let steps: Vec<usize> = lt.trace.iter().map(|r| r.step).collect();
    assert_eq!(steps, vec![0, 10, 20, 30, 39]);
    // the loop must actually improve over the init
    let first = lt.trace.first().unwrap().mse;
    assert!(lt.best_mse <= first, "best {} vs first {first}", lt.best_mse);
    assert!(lt.trace.iter().all(|r| r.mse.is_finite() && r.loss.is_finite() && r.lr > 0.0));
}

#[test]
fn learn_from_model_end_to_end() {
    // Fig. 2 on real (synthetic-weight) residual streams via the native
    // interpreter: capture -> learn -> invertible transform that does not
    // increase E(T) versus identity on the captured features.
    let dims = latmix::model::NativeDims {
        vocab: 48,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        kv_seq: 24,
        prefill_len: 8,
    };
    let w = latmix::model::NativeWeights::synthetic(dims, 17);
    let mut rng = Pcg64::seed(18);
    let (batch, t) = (4usize, 8usize);
    let tokens: Vec<i32> = (0..batch * t).map(|_| rng.below(48) as i32).collect();
    let cfg = MxConfig::from_name("mxfp4", Some(32)).unwrap();
    // identity init: the best-iterate rule then guarantees the learned
    // result is never worse than no transform at all on these features
    let lc = LearnConfig { init: InitStrategy::Identity, ..test_lc(60) };
    let (feats, lt) =
        latmix::latmix::learn_from_model(&w, 1, &tokens, batch, t, &cfg, &lc).unwrap();
    assert_eq!(feats.len(), batch * t * dims.d_model);
    let learned = lt.into_affine().unwrap();
    let e_id = transformation_mse(&feats, dims.d_model, &Affine::identity(dims.d_model), &cfg);
    let e_l = transformation_mse(&feats, dims.d_model, &learned, &cfg);
    assert!(
        e_l <= e_id,
        "learned transform must not be worse than identity on its own features: {e_l} vs {e_id}"
    );
}
