//! Zero-allocation steady-state decode gate.
//!
//! A counting `#[global_allocator]` wraps the system allocator; each test
//! drives a serving [`Engine`] past its warmup (prefill + a few decode
//! steps, which populate the scratch arenas and grow every staging buffer
//! to its high-water mark) and then asserts that a steady-state decode
//! step performs **zero heap allocations** — across the fp-dense,
//! packed-weights, and paged-mxfp8-KV executors, at pool worker counts 1
//! and 4.
//!
//! Methodology notes:
//!
//! * The allocation counter is process-global, so the measuring tests
//!   serialize on a `Mutex` and take the *minimum* delta over several
//!   measured steps: a page-boundary step legitimately grows the KV page
//!   arena, and the libtest harness itself may allocate on another thread
//!   mid-window. A real regression allocates on *every* step, so
//!   `min == 0` is exactly the steady-state claim.
//! * Worker count matters because parallel stages only stay
//!   allocation-free on the persistent `util::par::WorkerPool` (scoped
//!   thread spawns allocate, and dead threads drop their warm arenas);
//!   the engine installs the executor's pool around every step.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use latmix::coordinator::engine::{Engine, EngineConfig, NativeExecutor};
use latmix::coordinator::{GenRequest, KvFormat, KvSpec};
use latmix::model::NativeDims;
use latmix::util::par;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// The counter is global: measurement windows must not overlap.
static MEASURE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    MEASURE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const BATCH: usize = 4;
const PROMPT_LEN: usize = 12;
const WARMUP_STEPS: usize = 2;
const MEASURED_STEPS: usize = 5;

fn serving_engine(exec: NativeExecutor, kv: KvSpec) -> Engine<NativeExecutor> {
    let cfg = EngineConfig { max_slots: BATCH, eos: -1, kv, ..Default::default() };
    let mut engine = Engine::new(exec, cfg);
    for id in 0..BATCH as u64 {
        // Distinct prompts: prefix-shared pages would put copy-on-write
        // page allocations inside the measured decode steps.
        let prompt: Vec<i32> = (0..PROMPT_LEN as i32).map(|t| t + id as i32 * 100).collect();
        engine.submit(GenRequest::new(id, prompt, 64));
    }
    engine
}

/// Warm up, then return the minimum allocation delta over
/// `MEASURED_STEPS` steady-state decode steps.
fn min_allocs_per_step(exec: NativeExecutor, kv: KvSpec, threads: usize) -> u64 {
    let _guard = lock();
    par::with_threads(threads, || {
        let mut engine = serving_engine(exec, kv);
        // Step 1 admits + prefills all lanes and decodes once; the next
        // steps are pure decode and converge the scratch arenas.
        for _ in 0..1 + WARMUP_STEPS {
            engine.step().unwrap();
        }
        let mut min = u64::MAX;
        for _ in 0..MEASURED_STEPS {
            let before = allocs();
            engine.step().unwrap();
            min = min.min(allocs() - before);
        }
        assert_eq!(engine.pending(), BATCH, "lanes must stay running during measurement");
        min
    })
}

fn assert_zero(label: &str, exec: NativeExecutor, kv: KvSpec, threads: usize) {
    let min = min_allocs_per_step(exec, kv, threads);
    assert_eq!(
        min, 0,
        "{label} w={threads}: steady-state decode step performed {min} heap allocations"
    );
}

fn fp_exec() -> NativeExecutor {
    NativeExecutor::synthetic(NativeDims::latmix_tiny(), "fp", vec![1, 2, 4, 8], 42).unwrap()
}

fn packed_exec() -> NativeExecutor {
    NativeExecutor::synthetic(NativeDims::latmix_tiny(), "mxfp4_b32_t3", vec![1, 2, 4, 8], 42)
        .unwrap()
        .into_packed()
        .unwrap()
}

#[test]
fn fp_dense_zero_alloc_steady_state_w1() {
    assert_zero("fp-dense", fp_exec(), KvSpec::default(), 1);
}

#[test]
fn fp_dense_zero_alloc_steady_state_w4() {
    assert_zero("fp-dense", fp_exec(), KvSpec::default(), 4);
}

#[test]
fn packed_weights_zero_alloc_steady_state_w1() {
    assert_zero("packed", packed_exec(), KvSpec::default(), 1);
}

#[test]
fn packed_weights_zero_alloc_steady_state_w4() {
    assert_zero("packed", packed_exec(), KvSpec::default(), 4);
}

#[test]
fn paged_mxfp8_zero_alloc_steady_state_w1() {
    let kv = KvSpec { format: KvFormat::Mxfp8, ..KvSpec::default() };
    assert_zero("paged-mxfp8", fp_exec(), kv, 1);
}

#[test]
fn paged_mxfp8_zero_alloc_steady_state_w4() {
    let kv = KvSpec { format: KvFormat::Mxfp8, ..KvSpec::default() };
    assert_zero("paged-mxfp8", fp_exec(), kv, 4);
}

/// Dropping an engine joins its executor's pool workers: repeated
/// construct/serve/drop cycles neither leak threads nor accumulate them.
#[test]
fn engine_drop_joins_pool_workers() {
    let _guard = lock();
    let baseline = par::live_pool_threads();
    for round in 0..3 {
        par::with_threads(4, || {
            let mut engine = serving_engine(fp_exec(), KvSpec::default());
            for _ in 0..3 {
                engine.step().unwrap();
            }
            drop(engine);
        });
        assert_eq!(
            par::live_pool_threads(),
            baseline,
            "round {round}: pool workers leaked past engine drop"
        );
    }
}

/// A cloned executor shares one pool; the workers survive until the last
/// clone drops.
#[test]
fn cloned_executor_shares_one_pool() {
    let _guard = lock();
    let baseline = par::live_pool_threads();
    par::with_threads(4, || {
        let exec = fp_exec();
        let clone = exec.clone();
        let mut engine = serving_engine(exec, KvSpec::default());
        for _ in 0..2 {
            engine.step().unwrap();
        }
        let live = par::live_pool_threads();
        assert!(live > baseline, "pool should have spawned workers during prefill");
        drop(engine);
        // The clone still holds the pool: workers stay parked, not joined.
        assert_eq!(par::live_pool_threads(), live, "clone drop must be the joining drop");
        drop(clone);
        assert_eq!(par::live_pool_threads(), baseline, "last clone drop joins the workers");
    });
}
