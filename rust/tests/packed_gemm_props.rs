//! Bit-exactness and determinism properties for the fused packed-MX GEMM.
//!
//! The contract (see `latmix::linalg::packed`): `packed_matmul(a, pw)` must
//! agree bit-for-bit with the two-step oracle — dequantize the same packed
//! bytes through the scalar reference codec (`latmix::mx::reference`) into
//! an f32 matrix, then run the dense [`Mat::matmul`] kernel — on every
//! supported 4-bit tag, block size, shape class (K not a multiple of the
//! block or of the 4-wide unroll, single-row GEMV), and adversarial scale
//! range (denormal-range blocks). Both the packed and the newly parallel
//! dense kernels must also be invariant to the worker count.

use latmix::linalg::{packed_matmul, Mat, PackedMat};
use latmix::mx::reference;
use latmix::mx::MxConfig;
use latmix::testing::{forall, VecGen};
use latmix::util::{par, Pcg64};

const PACK_FORMATS: [&str; 2] = ["mxfp4", "mxint4"];

fn bits_eq(fast: &[f32], reference: &[f32]) -> Result<(), String> {
    if fast.len() != reference.len() {
        return Err(format!("len {} vs {}", fast.len(), reference.len()));
    }
    for (i, (a, b)) in fast.iter().zip(reference).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!(
                "idx {i}: fast {a} ({:#010x}) vs ref {b} ({:#010x})",
                a.to_bits(),
                b.to_bits()
            ));
        }
    }
    Ok(())
}

/// The oracle: dequantize via the scalar reference codec, then dense matmul.
fn oracle_dequant(w: &Mat, cfg: &MxConfig) -> Mat {
    let (scales, codes) = reference::pack_ref(&w.data, cfg);
    let deq = reference::unpack_ref(cfg, w.data.len(), &scales, &codes);
    Mat::from_vec(w.rows, w.cols, deq)
}

/// One full check: pack `w`, assert the decode path reproduces the
/// reference dequant bit-for-bit, then assert the fused GEMM matches
/// dequantize-then-`Mat::matmul` bit-for-bit.
fn check_case(a: &Mat, w: &Mat, cfg: MxConfig) -> Result<(), String> {
    let pw = PackedMat::pack(w, cfg).map_err(|e| e.to_string())?;
    let deq = oracle_dequant(w, &cfg);
    bits_eq(&pw.unpack().data, &deq.data).map_err(|e| format!("decode vs reference: {e}"))?;
    let fused = packed_matmul(a, &pw);
    let dense = a.matmul(&deq);
    bits_eq(&fused.data, &dense.data).map_err(|e| format!("fused vs dense oracle: {e}"))
}

fn rand_mat(rng: &mut Pcg64, rows: usize, cols: usize, scale: f32) -> Mat {
    Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, scale))
}

/// Fixed shape grid: GEMV (m=1), K not a multiple of the block size, K not
/// a multiple of the 4-wide unroll, and multi-block N — for every
/// supported tag and block size.
#[test]
fn packed_matmul_bit_exact_vs_oracle() {
    let mut rng = Pcg64::seed(91);
    for fmt in PACK_FORMATS {
        for block in [16usize, 32] {
            let cfg = MxConfig::from_name(fmt, Some(block)).unwrap();
            // (m, k, n): k deliberately not a multiple of block or 4
            for (m, k, n) in [
                (1usize, 37usize, 2 * block), // single-row GEMV, odd K
                (5, 12, block),
                (4, 64, 3 * block),
                (3, 130, 2 * block), // K % 4 == 2 remainder path
                (2, 3, block),       // K below one unroll step
            ] {
                let a = rand_mat(&mut rng, m, k, 1.5);
                let w = rand_mat(&mut rng, k, n, 0.8);
                check_case(&a, &w, cfg)
                    .unwrap_or_else(|e| panic!("{fmt} b{block} ({m}x{k}x{n}): {e}"));
            }
        }
    }
}

/// Randomized weights spanning the full scale range, down into
/// denormal-range blocks (log-magnitudes to -140) and up to
/// overflow-adjacent scales.
#[test]
fn packed_matmul_bit_exact_randomized() {
    for fmt in PACK_FORMATS {
        for block in [16usize, 32] {
            let cfg = MxConfig::from_name(fmt, Some(block)).unwrap();
            let gen = VecGen {
                min_len: block,
                max_len: block * 64,
                multiple_of: block,
                log_scale_range: (-140.0, 30.0),
            };
            forall(&format!("packed_gemm_{fmt}_{block}"), 50, &gen, |v| {
                // reshape the flat sample into a (K x block) weight so K
                // sweeps arbitrary values while rows stay block-aligned
                let k = v.len() / block;
                let w = Mat::from_vec(k, block, v.clone());
                let mut rng = Pcg64::seed(v.len() as u64);
                let a = rand_mat(&mut rng, 3, k, 1.0);
                check_case(&a, &w, cfg)
            });
        }
    }
}

/// Hand-built adversarial weights: all zeros, negative zeros, and blocks of
/// smallest subnormals with mixed signs — the scale-handling edge cases
/// where decode-then-accumulate and accumulate-then-scale differ.
#[test]
fn packed_matmul_denormal_edge_cases() {
    let mut rng = Pcg64::seed(92);
    for fmt in PACK_FORMATS {
        for block in [16usize, 32] {
            let cfg = MxConfig::from_name(fmt, Some(block)).unwrap();
            let n = 2 * block; // two blocks per weight row
            let mut cases = vec![vec![0.0f32; 4 * n], vec![-0.0f32; 4 * n]];
            let denorm: Vec<f32> = (0..4 * n)
                .map(|i| {
                    let v = f32::from_bits(1 + i as u32); // smallest subnormals
                    if i % 2 == 0 { v } else { -v }
                })
                .collect();
            cases.push(denorm);
            let mut mixed = vec![0.0f32; 4 * n];
            mixed[0] = -0.0;
            mixed[1] = f32::MIN_POSITIVE; // smallest normal
            mixed[2] = -f32::MIN_POSITIVE / 2.0; // subnormal
            mixed[3] = f32::MAX;
            mixed[4] = -1.5e-39; // subnormal
            mixed[n] = 1.0; // second block is ordinary
            mixed[n + 1] = -3.25;
            cases.push(mixed);
            for (ei, v) in cases.into_iter().enumerate() {
                let w = Mat::from_vec(4, n, v);
                let a = rand_mat(&mut rng, 2, 4, 1.0);
                check_case(&a, &w, cfg)
                    .unwrap_or_else(|e| panic!("{fmt} b{block} edge case {ei}: {e}"));
            }
        }
    }
}

/// The row fan-out must not change a single bit: 1 worker vs N, on a shape
/// large enough (m*n >= PAR_MIN_LEN) to engage the parallel path.
#[test]
fn packed_matmul_thread_count_invariant() {
    let mut rng = Pcg64::seed(93);
    let (m, k, n) = (128usize, 96usize, 64usize); // m*n = 8192 >= 4096
    let a = rand_mat(&mut rng, m, k, 1.0);
    for fmt in PACK_FORMATS {
        let cfg = MxConfig::from_name(fmt, Some(32)).unwrap();
        let w = rand_mat(&mut rng, k, n, 0.7);
        let pw = PackedMat::pack(&w, cfg).unwrap();
        let one = par::with_threads(1, || packed_matmul(&a, &pw));
        for t in [2usize, 3, 7, 16] {
            let many = par::with_threads(t, || packed_matmul(&a, &pw));
            bits_eq(&many.data, &one.data).unwrap_or_else(|e| panic!("{fmt} threads={t}: {e}"));
        }
    }
}

/// Satellite of the same PR: the dense `Mat::matmul` row fan-out must also
/// be thread-count invariant (each output row is owned by one worker).
#[test]
fn dense_matmul_thread_count_invariant() {
    let mut rng = Pcg64::seed(94);
    let (m, k, n) = (128usize, 96usize, 64usize);
    let a = rand_mat(&mut rng, m, k, 1.0);
    let b = rand_mat(&mut rng, k, n, 0.7);
    let one = par::with_threads(1, || a.matmul(&b));
    for t in [2usize, 3, 7, 16] {
        let many = par::with_threads(t, || a.matmul(&b));
        bits_eq(&many.data, &one.data).unwrap_or_else(|e| panic!("threads={t}: {e}"));
    }
}
